//! Offline stub of the `xla` (PJRT / XLA) crate.
//!
//! The real backend links the PJRT C API and executes the AOT-compiled
//! HLO artifacts; it is unavailable in the offline build environment, so
//! this stub provides the exact API surface `hydra3d::runtime::service`
//! compiles against and fails *at runtime* with a clear message. Nothing in
//! tier-1 CI exercises the runtime path (engine tests gate on the presence
//! of `artifacts/manifest.json`), so the stub keeps the whole workspace —
//! engines, communicator, perf model, benches — buildable and testable
//! without the FFI toolchain. Swap this for the real `xla` crate in
//! `rust/Cargo.toml` to enable execution.

use std::fmt;

const UNAVAILABLE: &str =
    "PJRT backend unavailable: this build uses the offline `xla` stub \
     (vendor/xla); link the real xla/PJRT crate to execute AOT artifacts";

/// Error type mirroring `xla::Error` as used by the runtime service.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_not_silently() {
        let err = PjRtClient::cpu().map(|_| ()).unwrap_err();
        assert!(err.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(Literal::vec1(&[1.0]).reshape(&[1]).is_err());
    }
}
