//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline with a fixed vendored crate set
//! (DESIGN.md §8), so this workspace vendors the tiny subset of `anyhow`'s
//! API that HYDRA-3D actually uses: [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait.
//! The implementation is message-based: an error is a chain of strings
//! (outermost context first), built from any `std::error::Error` source
//! chain or from formatted messages. Nothing in this repository downcasts
//! errors, so no type information is retained.
//!
//! Swapping in the real `anyhow` later is a one-line `Cargo.toml` change;
//! the API surface used here is source-compatible.

use std::error::Error as StdError;
use std::fmt;

/// A message-chain error. `chain[0]` is the outermost context.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (mirrors `anyhow::Error::context`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The error chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that is what makes this blanket conversion coherent (same as anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result` with the usual defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*)
        }
    };
}

/// Attach context to errors (and to `None`), mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn macros_and_display() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        let f = || -> Result<()> { bail!("nope") };
        assert_eq!(f().unwrap_err().to_string(), "nope");
        let g = |x: usize| -> Result<usize> {
            ensure!(x > 2, "too small: {x}");
            Ok(x)
        };
        assert!(g(1).is_err());
        assert_eq!(g(3).unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let f = || -> Result<String> { Ok(std::fs::read_to_string("/no/such/file")?) };
        assert!(f().is_err());
        let g = || -> Result<usize> { Ok("12x".parse::<usize>()?) };
        assert!(g().is_err());
        let _: Error = io_err().into();
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let e: Result<()> = Err(io_err());
        let e = e.context("opening dataset").unwrap_err();
        assert_eq!(e.to_string(), "opening dataset");
        assert_eq!(format!("{e:#}"), "opening dataset: missing file");
        assert!(format!("{e:?}").contains("Caused by:"));
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["opening dataset", "missing file"]);
    }

    #[test]
    fn with_context_and_option() {
        let r: Result<(), Error> = Err(anyhow!("inner"));
        let e = r.with_context(|| format!("rank {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "rank 3: inner");
        let none: Option<u8> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
    }
}
