"""L1 correctness: Pallas pool3d + fused bn/leaky kernels vs the oracle,
plus the backward rules the shard executables are built from."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pool3d as KP
from compile.kernels import bnorm as KB
from compile.kernels import ref

TOL = dict(rtol=2e-5, atol=2e-6)


def _rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


def assert_close(a, b, **kw):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **(TOL | kw))


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([1, 2]),
    c=st.sampled_from([1, 3, 4, 8]),
    d=st.sampled_from([2, 4, 8]),
    hw=st.sampled_from([2, 4, 6]),
    op=st.sampled_from(["max", "avg"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pool_matches_ref(n, c, d, hw, op, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (n, c, d, hw, hw))
    got = KP.pool3d_pallas(x, op)
    want = ref.maxpool3d(x) if op == "max" else ref.avgpool3d(x)
    assert got.shape == want.shape
    assert_close(got, want)


def test_pool_shard_locality(rng):
    """2^3/stride-2 pooling commutes with even depth splits — why pooling
    needs no halo exchange under the paper's partitioning (DESIGN.md §6)."""
    x = _rand(rng, (1, 4, 8, 4, 4))
    whole = ref.maxpool3d(x)
    parts = [KP.maxpool3d(x[:, :, i * 4 : (i + 1) * 4]) for i in range(2)]
    assert_close(jnp.concatenate(parts, axis=2), whole)


def test_maxpool_bwd_matches_autodiff(rng):
    x = _rand(rng, (2, 3, 4, 4, 4))
    dy_shape = (2, 3, 2, 2, 2)
    dy = _rand(rng, dy_shape)
    y = ref.maxpool3d(x)
    got = ref.maxpool3d_bwd(x, y, dy)
    want = jax.grad(lambda x: jnp.sum(ref.maxpool3d(x) * dy))(x)
    assert_close(got, want)


def test_maxpool_bwd_tie_convention():
    """All-equal window: gradient is shared equally among the 8 ties."""
    x = jnp.ones((1, 1, 2, 2, 2), jnp.float32)
    y = ref.maxpool3d(x)
    dy = jnp.full((1, 1, 1, 1, 1), 8.0, jnp.float32)
    dx = ref.maxpool3d_bwd(x, y, dy)
    np.testing.assert_allclose(np.asarray(dx), np.ones((1, 1, 2, 2, 2)))


def test_avgpool_bwd_matches_autodiff(rng):
    x = _rand(rng, (1, 2, 4, 4, 4))
    dy = _rand(rng, (1, 2, 2, 2, 2))
    got = ref.avgpool3d_bwd(dy)
    want = jax.grad(lambda x: jnp.sum(ref.avgpool3d(x) * dy))(x)
    assert_close(got, want)


@settings(max_examples=15, deadline=None)
@given(
    c=st.sampled_from([1, 2, 4, 8]),
    d=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bn_leaky_fused_matches_ref(c, d, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (2, c, d, 4, 4))
    gamma = _rand(rng, (c,)) + 1.5
    beta = _rand(rng, (c,))
    mean = jnp.mean(x, (0, 2, 3, 4))
    var = jnp.var(x, (0, 2, 3, 4))
    got = KB.bn_leaky_pallas(x, mean, var, gamma, beta)
    want = ref.leaky_relu(ref.bn_apply(x, mean, var, gamma, beta))
    assert_close(got, want)


def test_distributed_bn_stats_compose(rng):
    """Sharded (sum, sumsq, count) partials allreduced == global stats —
    the invariant behind the paper's distributed batch-norm (§III-A)."""
    x = _rand(rng, (4, 3, 8, 4, 4))
    shards = [x[:, :, i * 2 : (i + 1) * 2] for i in range(4)]
    s1 = sum(ref.bn_stats(s)[0] for s in shards)
    s2 = sum(ref.bn_stats(s)[1] for s in shards)
    cnt = sum(float(ref.bn_stats(s)[2]) for s in shards)
    mean, var = s1 / cnt, s2 / cnt - (s1 / cnt) ** 2
    assert_close(mean, jnp.mean(x, (0, 2, 3, 4)))
    assert_close(var, jnp.var(x, (0, 2, 3, 4)), atol=1e-5)


def test_bn_bwd_matches_autodiff(rng):
    """bn_bwd_apply with *global* partials == jax.grad of training-mode BN
    (single group), including the fused leaky backward recomputation used
    by the shard executables."""
    x = _rand(rng, (2, 3, 4, 4, 4))
    gamma = _rand(rng, (3,)) + 1.0
    beta = _rand(rng, (3,))
    dy = _rand(rng, (2, 3, 4, 4, 4))

    def fwd(x, gamma, beta):
        y, _ = ref.bn_fwd_local(x, gamma, beta)
        return jnp.sum(ref.leaky_relu(y) * dy)

    want_dx, want_dg, want_db = jax.grad(fwd, (0, 1, 2))(x, gamma, beta)

    s1, s2, cnt = ref.bn_stats(x)
    mean, var = s1 / cnt, s2 / cnt - (s1 / cnt) ** 2
    y_bn = ref.bn_apply(x, mean, var, gamma, beta)
    dy_bn = ref.leaky_relu_bwd(y_bn, dy)
    g1, g2 = ref.bn_bwd_partials(x, dy_bn, mean, var)
    got_dx = ref.bn_bwd_apply(x, dy_bn, mean, var, gamma, g1, g2, cnt)
    assert_close(got_dx, want_dx, atol=1e-4, rtol=1e-3)
    assert_close(g1, want_dg, atol=1e-4, rtol=1e-3)  # dgamma
    assert_close(g2, want_db, atol=1e-4, rtol=1e-3)  # dbeta


def test_losses_match_autodiff(rng):
    p = _rand(rng, (3, 4))
    t = _rand(rng, (3, 4))
    loss, dp = ref.mse_fwd_bwd(p, t)
    assert_close(loss, ref.mse_loss(p, t))
    assert_close(dp, jax.grad(lambda p: ref.mse_loss(p, t))(p))

    logits = _rand(rng, (2, 3, 4, 4, 4))
    labels = jnp.asarray(np.random.default_rng(1).integers(0, 3, (2, 4, 4, 4)))
    loss, dl = ref.softmax_xent_fwd_bwd(logits, labels, 3)
    assert_close(loss, ref.softmax_xent(logits, labels, 3))
    assert_close(dl, jax.grad(lambda l: ref.softmax_xent(l, labels, 3))(logits),
                 atol=1e-6)


def test_deconv_shard_locality(rng):
    """kernel==stride deconv is shard-local in depth (no halo; DESIGN §6)."""
    x = _rand(rng, (1, 4, 4, 4, 4))
    w = _rand(rng, (4, 2, 2, 2, 2), 0.4)
    whole = ref.deconv3d(x, w)
    parts = [ref.deconv3d(x[:, :, i * 2 : (i + 1) * 2], w) for i in range(2)]
    assert_close(jnp.concatenate(parts, axis=2), whole)


def test_deconv_bwds_match_autodiff(rng):
    x = _rand(rng, (1, 3, 4, 4, 4))
    w = _rand(rng, (3, 2, 2, 2, 2), 0.4)
    dy = _rand(rng, (1, 2, 8, 8, 8))
    got_dx = ref.deconv3d_bwd_data(dy, w, x.shape)
    got_dw = ref.deconv3d_bwd_filter(x, dy, w.shape)
    want_dx, want_dw = jax.grad(
        lambda x, w: jnp.sum(ref.deconv3d(x, w) * dy), (0, 1)
    )(x, w)
    assert_close(got_dx, want_dx, atol=1e-5)
    assert_close(got_dw, want_dw, atol=1e-4)


def test_dice_score_perfect_and_disjoint():
    a = jnp.asarray(np.array([[[[0, 1]]]]))
    assert float(ref.dice_score(a, a, 2)) == pytest.approx(1.0)
    b = 1 - a
    assert float(ref.dice_score(a, b, 2)) == pytest.approx(0.0)
