"""L2 correctness: fused model graphs, parameter tables, and layer plans.

The key cross-layer invariant: the fused ``train_step`` (one jax graph) and
a manual layer-by-layer composition following ``layer_plan`` (what the Rust
hybrid engine executes) produce identical losses and gradients.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.kernels import ref

TOL = dict(rtol=2e-4, atol=2e-5)


def assert_close(a, b, **kw):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **(TOL | kw))


def init_flat(spec, rng, scale=0.2):
    flat = []
    for name, shape in M.param_table(spec):
        if name.endswith(".gamma"):
            flat.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".beta", ".b")):
            flat.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = int(np.prod(shape[1:]))
            flat.append(jnp.asarray(
                rng.standard_normal(shape) / np.sqrt(fan_in), jnp.float32))
    return flat


@pytest.mark.parametrize("name", ["cf-nano", "cf-nano-bn", "cf16", "cf16-bn"])
def test_cosmoflow_forward_shapes(name, rng):
    spec = M.REGISTRY[name]
    flat = init_flat(spec, rng)
    params = {n: a for (n, _), a in zip(M.param_table(spec), flat)}
    x = jnp.asarray(
        rng.standard_normal((2, 1, spec.input_size,) + (spec.input_size,) * 2),
        jnp.float32,
    )
    masks = [jnp.ones((2, f), jnp.float32) for f in spec.fc[:-1]]
    y, stats = M.cosmoflow_fwd(spec, params, x, train=True, masks=masks)
    assert y.shape == (2, spec.n_targets)
    assert len(stats) == (len(spec.channels) if spec.use_bn else 0)


@pytest.mark.parametrize("name", ["unet16", "unet16-bn"])
def test_unet_forward_shapes(name, rng):
    spec = M.REGISTRY[name]
    flat = init_flat(spec, rng)
    params = {n: a for (n, _), a in zip(M.param_table(spec), flat)}
    s = spec.input_size
    x = jnp.asarray(rng.standard_normal((1, 1, s, s, s)), jnp.float32)
    logits, _ = M.unet_fwd(spec, params, x, train=True)
    assert logits.shape == (1, spec.n_classes, s, s, s)


def test_param_table_matches_paper_structure():
    """Parameter census sanity: conv params dominate fc for the U-Net; fc
    dominates for CosmoFlow (as in Table I, where fc1 holds most of the
    9.44M)."""
    cf = M.REGISTRY["cf64"]
    sizes = {n: int(np.prod(s)) for n, s in M.param_table(cf)}
    fc_total = sum(v for k, v in sizes.items() if k.startswith("fc"))
    conv_total = sum(v for k, v in sizes.items() if k.startswith("conv"))
    assert fc_total > conv_total
    # the bn variant adds exactly 2*c per conv layer
    cfb = M.REGISTRY["cf64-bn"]
    extra = sum(
        int(np.prod(s)) for n, s in M.param_table(cfb) if ".gamma" in n or ".beta" in n
    )
    assert extra == 2 * sum(cf.channels)


def test_fused_train_step_grads_match_manual(rng):
    """value_and_grad of the fused graph == loss/grads of an explicit
    forward + hand-chained backward on cf-nano (no BN).

    This pins the semantics the Rust per-layer engine re-implements.
    """
    spec = M.REGISTRY["cf-nano"]
    flat = init_flat(spec, rng)
    x = jnp.asarray(rng.standard_normal((2, 1, 8, 8, 8)), jnp.float32)
    tgt = jnp.asarray(rng.standard_normal((2, spec.n_targets)), jnp.float32)
    masks = [jnp.ones((2, f), jnp.float32) for f in spec.fc[:-1]]

    train = M.make_train_step(spec)
    out = train(x, tgt, *masks, *flat)
    loss, grads = out[0], out[1 : 1 + len(flat)]

    # manual: forward chain saving activations, then reverse chain.
    params = {n: a for (n, _), a in zip(M.param_table(spec), flat)}
    acts = {"x0": x}
    h = x
    for i in range(len(spec.channels)):
        c = ref.conv3d(h, params[f"conv{i}.w"])
        a = ref.leaky_relu(c)
        p = ref.avgpool3d(a)
        acts[f"c{i}"], acts[f"a{i}"], acts[f"p{i}"] = c, a, p
        h = p
    hf = h.reshape(2, -1)
    acts["flat"] = hf
    z0 = ref.dense(hf, params["fc0.w"], params["fc0.b"])
    a0 = ref.leaky_relu(z0) * masks[0]
    z1 = ref.dense(a0, params["fc1.w"], params["fc1.b"])
    want_loss = ref.mse_loss(z1, tgt)
    assert_close(loss, want_loss)

    _, dpred = ref.mse_fwd_bwd(z1, tgt)
    dx1, dw1, db1 = ref.dense_bwd(a0, params["fc1.w"], dpred)
    dz0 = ref.leaky_relu_bwd(z0, dx1 * masks[0])
    dflat, dw0, db0 = ref.dense_bwd(hf, params["fc0.w"], dz0)
    dh = dflat.reshape(h.shape)
    gdict = {"fc1.w": dw1, "fc1.b": db1, "fc0.w": dw0, "fc0.b": db0}
    for i in reversed(range(len(spec.channels))):
        da = ref.avgpool3d_bwd(dh)
        dc = ref.leaky_relu_bwd(acts[f"c{i}"], da)
        src = acts[f"p{i-1}"] if i else x
        gdict[f"conv{i}.w"] = ref.conv3d_bwd_filter(src, dc, params[f"conv{i}.w"].shape)
        dh = ref.conv3d_bwd_data(dc, params[f"conv{i}.w"], src.shape)
    for (name, _), g in zip(M.param_table(spec), grads):
        assert_close(g, gdict[name], atol=1e-4, rtol=1e-3)


def test_predict_eval_mode_uses_running_stats(rng):
    spec = M.REGISTRY["cf-nano-bn"]
    flat = init_flat(spec, rng)
    x = jnp.asarray(rng.standard_normal((2, 1, 8, 8, 8)), jnp.float32)
    n_bn = len(M.bn_layer_names(spec))
    chans = [dict(M.param_table(spec))[f"{n}.gamma"][0]
             for n in M.bn_layer_names(spec)]
    means = [jnp.zeros(c, jnp.float32) for c in chans]
    variances = [jnp.ones(c, jnp.float32) for c in chans]
    predict = M.make_predict(spec)
    (y,) = predict(x, *flat, *means, *variances)
    assert y.shape == (2, spec.n_targets)
    # changing the running stats must change the output
    (y2,) = predict(x, *flat, *[m + 1 for m in means], *variances)
    assert not np.allclose(np.asarray(y), np.asarray(y2))


def test_dropout_mask_semantics(rng):
    """Masks are pre-scaled: mask==1/keep where kept. A kept-everything mask
    at keep=1 equals no dropout; a zero mask kills the fc path."""
    spec = M.REGISTRY["cf-nano"]
    flat = init_flat(spec, rng)
    params = {n: a for (n, _), a in zip(M.param_table(spec), flat)}
    x = jnp.asarray(rng.standard_normal((1, 1, 8, 8, 8)), jnp.float32)
    ones = [jnp.ones((1, f), jnp.float32) for f in spec.fc[:-1]]
    zeros = [jnp.zeros((1, f), jnp.float32) for f in spec.fc[:-1]]
    y1, _ = M.cosmoflow_fwd(spec, params, x, train=True, masks=ones)
    y2, _ = M.cosmoflow_fwd(spec, params, x, train=False)
    assert_close(y1, y2)
    y3, _ = M.cosmoflow_fwd(spec, params, x, train=True, masks=zeros)
    want = params["fc1.b"]  # only the output bias survives
    assert_close(y3[0], want)


@pytest.mark.parametrize("name", ["cf16", "cf16-bn", "cf32", "unet16", "unet16-bn"])
def test_layer_plan_geometry(name):
    """Plans are self-consistent: conv/pool/fc geometry chains correctly and
    matches the spec's analytic feature count."""
    spec = M.REGISTRY[name]
    plan = M.layer_plan(spec)
    if isinstance(spec, M.CosmoFlowSpec):
        convs = [l for l in plan if l["kind"] == "conv"]
        pools = [l for l in plan if l["kind"] == "pool"]
        assert len(convs) == len(spec.channels) == len(pools)
        for a, b in zip(convs, pools):
            assert (a["d"], a["cout"]) == (b["d"], b["c"])
        flat = next(l for l in plan if l["kind"] == "flatten")
        assert flat["c"] * flat["d"] * flat["h"] * flat["w"] == spec.flat_features
        fcs = [l for l in plan if l["kind"] == "fc"]
        assert fcs[0]["fin"] == spec.flat_features
        assert fcs[-1]["fout"] == spec.n_targets
        assert not fcs[-1]["act"]
    else:
        head = [l for l in plan if l["kind"] == "conv"][-1]
        assert head["cout"] == spec.n_classes and head["k"] == 1
        assert plan[-1]["kind"] == "xent"
        assert plan[-1]["d"] == spec.input_size
    # every tagged plan layer has parameters in the table
    table = dict(M.param_table(spec))
    for l in plan:
        if l["kind"] in ("conv", "deconv"):
            assert f"{l['tag']}.w" in table


def test_bn_layer_names_order():
    spec = M.REGISTRY["cf16-bn"]
    assert M.bn_layer_names(spec) == ["conv0", "conv1"]
    assert M.bn_layer_names(M.REGISTRY["cf16"]) == []
