"""L1 correctness: Pallas conv3d vs the pure-jnp oracle.

This is the core correctness signal for the kernel that dominates the
paper's runtime (conv1 is ~half of the 512^3 CosmoFlow iteration, §V-B).
Hypothesis sweeps shapes/strides/paddings/tilings; explicit tests pin the
shard flavour and the custom-vjp backward used by the fused graphs.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv3d as K
from compile.kernels import ref

TOL = dict(rtol=2e-4, atol=2e-5)


def _rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


def assert_close(a, b, **kw):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **(TOL | kw))


@settings(max_examples=25, deadline=None)
@given(
    cin=st.sampled_from([1, 2, 4]),
    cout=st.sampled_from([2, 4, 8]),
    d=st.sampled_from([4, 6, 8]),
    hw=st.sampled_from([4, 8]),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["same", "valid", "valid_d"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv3d_matches_ref(cin, cout, d, hw, k, stride, padding, seed):
    if k == 1 and padding == "valid_d":
        padding = "same"  # identical for k=1; avoid degenerate dup
    rng = np.random.default_rng(seed)
    x = _rand(rng, (1, cin, d, hw, hw))
    w = _rand(rng, (cout, cin, k, k, k), 0.3)
    got = K.conv3d_pallas(x, w, stride, padding)
    want = ref.conv3d(x, w, stride, padding)
    assert got.shape == want.shape
    assert_close(got, want)


@settings(max_examples=10, deadline=None)
@given(
    tc=st.sampled_from([1, 2, 4]),
    td=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv3d_tilings(tc, td, seed):
    """Every legal (TC, TD) tiling computes the same answer — the BlockSpec
    index maps are correct for partial tiles of both grid axes."""
    rng = np.random.default_rng(seed)
    x = _rand(rng, (2, 3, 8, 6, 6))
    w = _rand(rng, (4, 3, 3, 3, 3), 0.3)
    want = ref.conv3d(x, w)
    got = K.conv3d_pallas(x, w, tiling=K.ConvTiling(tc=tc, td=td))
    assert_close(got, want)


def test_conv3d_batch_grid(rng):
    x = _rand(rng, (3, 2, 4, 4, 4))
    w = _rand(rng, (4, 2, 3, 3, 3), 0.3)
    assert_close(K.conv3d_pallas(x, w), ref.conv3d(x, w))


def test_shard_fwd_equals_gather(rng):
    """Depth-sharding with halo exchange reproduces the unsharded conv:
    the algebraic core of the paper's hybrid parallelism (§III-A).

    Simulates what the Rust engine does: pad globally ('same' boundary),
    split depth, give each shard one halo plane per side, run the shard
    executable, concatenate.
    """
    d, ways = 8, 4
    x = _rand(rng, (1, 3, d, 6, 6))
    w = _rand(rng, (5, 3, 3, 3, 3), 0.3)
    want = ref.conv3d(x, w, 1, "same")
    xp = jnp.pad(x, [(0, 0), (0, 0), (1, 1), (0, 0), (0, 0)])
    outs = []
    dsh = d // ways
    for r in range(ways):
        slab = xp[:, :, r * dsh : r * dsh + dsh + 2]
        outs.append(K.conv3d_pallas(slab, w, 1, "valid_d"))
    assert_close(jnp.concatenate(outs, axis=2), want)


def test_custom_vjp_matches_ref_grads(rng):
    x = _rand(rng, (2, 3, 6, 6, 6))
    w = _rand(rng, (4, 3, 3, 3, 3), 0.3)
    co = _rand(rng, (2, 4, 6, 6, 6))  # cotangent

    def f(conv):
        def g(x, w):
            return jnp.sum(conv(x, w) * co)

        return g

    gx, gw = jax.grad(f(lambda x, w: K.conv3d(x, w)), (0, 1))(x, w)
    rx, rw = jax.grad(f(lambda x, w: ref.conv3d(x, w)), (0, 1))(x, w)
    assert_close(gx, rx)
    assert_close(gw, rw, atol=1e-4)


def test_bwd_data_is_exact_transpose(rng):
    """<conv(x), dy> == <x, conv_bwd_data(dy)> — adjoint identity."""
    x = _rand(rng, (1, 2, 6, 4, 4))
    w = _rand(rng, (3, 2, 3, 3, 3), 0.3)
    dy = _rand(rng, (1, 3, 6, 4, 4))
    lhs = jnp.vdot(ref.conv3d(x, w), dy)
    rhs = jnp.vdot(x, ref.conv3d_bwd_data(dy, w, x.shape))
    assert_close(lhs, rhs, rtol=1e-3)


def test_bwd_filter_matches_autodiff(rng):
    x = _rand(rng, (2, 2, 6, 4, 4))
    w_shape = (3, 2, 3, 3, 3)
    dy = _rand(rng, (2, 3, 6, 4, 4))
    got = ref.conv3d_bwd_filter(x, dy, w_shape)
    want = jax.grad(
        lambda w: jnp.sum(ref.conv3d(x, w) * dy)
    )(jnp.zeros(w_shape, jnp.float32))
    assert_close(got, want, atol=1e-4)


def test_pick_tiling_divides_and_fits():
    for cout, dout, cin, hw in [(16, 256, 1, (256, 256)), (256, 4, 128, (4, 4)),
                                (32, 64, 16, (64, 64))]:
        t = K.pick_tiling(cout, dout, cin, hw, 3, 1)
        assert cout % t.tc == 0 and dout % t.td == 0
        rep = K.vmem_report(cout, dout, cin, hw)
        assert rep["vmem_ok"], rep


def test_vmem_report_conv1_paper_scale():
    """The 512^3 conv1 shard (8-way) must fit VMEM with the auto tiling —
    the L1 feasibility claim quoted in EXPERIMENTS.md §Perf."""
    rep = K.vmem_report(16, 64, 1, (512, 512))  # 8-way depth shard of 512^3
    assert rep["vmem_ok"]
    assert rep["flops_per_sample"] > 0


def test_stride2_conv_table1_c4_shape(rng):
    """Paper Table I: c4 is a stride-2 conv (16^3 -> 8^3 at Wi=128)."""
    x = _rand(rng, (1, 4, 16, 16, 16))
    w = _rand(rng, (8, 4, 3, 3, 3), 0.3)
    y = K.conv3d_pallas(x, w, 2, "same")
    assert y.shape == (1, 8, 8, 8, 8)
    assert_close(y, ref.conv3d(x, w, 2, "same"))
