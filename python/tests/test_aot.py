"""AOT pipeline tests: manifest integrity and HLO-text round-trip health.

These run against a freshly-built nano manifest in a temp dir (fast), plus
checks on the repo's real ``artifacts/`` when present.
"""

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile import aot, model as M
from compile.kernels import ref

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__)))), "artifacts")


@pytest.fixture(scope="module")
def nano_manifest(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("art"))
    return aot.build(out, ["cf-nano"], {"cf-nano": [1, 2]}), out


def test_manifest_structure(nano_manifest):
    man, out = nano_manifest
    assert man["version"] == 1
    assert "cf-nano" in man["models"]
    m = man["models"]["cf-nano"]
    assert m["fused"]["train_step"] in man["entries"]
    assert m["fused"]["predict"] in man["entries"]
    for e in man["entries"].values():
        assert os.path.exists(os.path.join(out, e["file"]))
        assert e["inputs"] and e["outputs"]


def test_hlo_text_parses_as_hlo(nano_manifest):
    """Files must be HLO text (the 0.5.1-compatible interchange), not
    stablehlo or proto bytes."""
    man, out = nano_manifest
    name = man["models"]["cf-nano"]["fused"]["train_step"]
    text = open(os.path.join(out, man["entries"][name]["file"])).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_train_step_signature_matches_param_table(nano_manifest):
    man, _ = nano_manifest
    spec = M.REGISTRY["cf-nano"]
    m = man["models"]["cf-nano"]
    e = man["entries"][m["fused"]["train_step"]]
    ptable = M.param_table(spec)
    n_masks = m["fused"]["n_masks"]
    assert len(e["inputs"]) == 2 + n_masks + len(ptable)
    # grads mirror param shapes, in order
    for (name, shape), got in zip(ptable, e["outputs"][1 : 1 + len(ptable)]):
        assert got == list(shape), name
    # loss is a scalar
    assert e["outputs"][0] == []


def test_shard_entries_cover_plan(nano_manifest):
    man, _ = nano_manifest
    m = man["models"]["cf-nano"]
    for ways, plan in m["hybrid"].items():
        for layer in plan:
            if layer["kind"] == "conv":
                for op in ("fwd", "bwd_data", "bwd_filter"):
                    assert layer[op] in man["entries"], (ways, layer["tag"], op)
                e = man["entries"][layer["fwd"]]
                dsh = layer["d"] // int(ways)
                assert e["inputs"][0] == [1, layer["cin"], dsh + 2, layer["h"],
                                          layer["w"]]
                assert e["outputs"][0] == [1, layer["cout"], dsh, layer["h"],
                                           layer["w"]]
            if layer["kind"] == "pool":
                assert layer["fwd"] in man["entries"]
                assert layer["bwd"] in man["entries"]


def test_hlo_audit_no_recompute(nano_manifest):
    """The fused train_step must contain exactly fwd+bwd_data+bwd_filter
    convolutions per conv layer — except the first layer, whose bwd_data is
    dead (the input is a leaf) and must be DCE'd.  Total = 3L - 1; anything
    more means rematerialization crept in."""
    man, out = nano_manifest
    name = man["models"]["cf-nano"]["fused"]["train_step"]
    text = open(os.path.join(out, man["entries"][name]["file"])).read()
    counts = aot.audit_hlo(text)
    n_convs = len(M.REGISTRY["cf-nano"].channels)
    assert counts["convolution"] == 3 * n_convs - 1, counts


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="repo artifacts not built")
def test_repo_artifacts_complete():
    man = json.load(open(os.path.join(ART, "manifest.json")))
    for name in aot.FUSED_MODELS:
        assert name in man["models"], name
    for name, e in man["entries"].items():
        assert os.path.exists(os.path.join(ART, e["file"])), name
    # hybrid sets present
    for name, ways in aot.HYBRID_SETS.items():
        assert sorted(map(int, man["models"][name]["hybrid"])) == sorted(ways)
