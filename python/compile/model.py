"""L2: JAX model graphs for HYDRA-3D (CosmoFlow + 3D U-Net).

Two consumption modes, both AOT-lowered by ``aot.py`` (build-time only —
Python is never on the training path):

* **Fused graphs** — ``train_step`` (``jax.value_and_grad`` over the whole
  model) and ``predict``, one executable per model.  Used by the Rust
  engine's pure data-parallel path and the end-to-end examples.  Forward
  convolutions go through the Pallas kernel (``kernels.conv3d.conv3d`` has a
  custom vjp) unless ``fused_pallas=False`` (default: off for lowering/runtime
  speed on the CPU testbed; flag ``--pallas-fused`` flips it).
* **Layer plans** — a JSON-able description of the network that ``aot.py``
  turns into *per-layer shard executables* for the hybrid-parallel engine
  (conv/pool/bn/fc/losses on depth-partitioned shards, always through the
  Pallas kernels).  The plan is embedded in the manifest so the Rust engine
  builds its graph from the same source of truth.

Model registry (miniaturized per DESIGN.md §4 — resolutions 16^3/32^3/64^3
stand in for the paper's 128^3/256^3/512^3):

=============  =======  =====================  ==================  ====
name           input    conv channels          fc widths           BN
=============  =======  =====================  ==================  ====
cf16           16^3     16, 32                 128, 64, 4          no
cf16-bn        16^3     16, 32                 128, 64, 4          yes
cf32           32^3     16, 32, 64             256, 64, 4          no
cf32-bn        32^3     16, 32, 64             256, 64, 4          yes
cf64           64^3     16, 32, 64, 128, 256   2048, 256, 4        no
cf64-bn        64^3     16, 32, 64, 128, 256   2048, 256, 4        yes
cf-nano        8^3      4, 8                   16, 4               no
cf-nano-bn     8^3      4, 8                   16, 4               yes
unet16         16^3     base 4, 2 levels       (2 classes)         no
unet16-bn      16^3     base 4, 2 levels       (2 classes)         yes
unet32         32^3     base 8, 2 levels       (2 classes)         no
=============  =======  =====================  ==================  ====

Like the paper's Table I family, each halving of the input drops one
conv+pool level so the flattened feature map stays fixed (4^3 here, 2^3 in
the paper).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels import conv3d as kconv
from .kernels import pool3d as kpool
from .kernels import bnorm as kbn

# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CosmoFlowSpec:
    """The extended CosmoFlow regressor of §IV, miniaturized."""

    name: str
    input_size: int
    channels: tuple
    fc: tuple  # hidden widths + output (last entry = n_targets)
    use_bn: bool = False
    in_channels: int = 1
    dropout_keep: float = 0.8
    pool: str = "avg"  # original CosmoFlow pools with average pooling

    @property
    def n_targets(self) -> int:
        return self.fc[-1]

    @property
    def final_spatial(self) -> int:
        return self.input_size >> len(self.channels)

    @property
    def flat_features(self) -> int:
        return self.channels[-1] * self.final_spatial**3


@dataclass(frozen=True)
class UNetSpec:
    """3D U-Net (Çiçek et al.) miniaturized; two 3^3 convs per level,
    2^3-stride-2 max pool down, 2^3-stride-2 deconv up, skip concats,
    1^3 conv head."""

    name: str
    input_size: int
    base_channels: int
    levels: int
    n_classes: int = 2
    use_bn: bool = False
    in_channels: int = 1

    def level_channels(self, i: int) -> int:
        return self.base_channels << i


REGISTRY: dict = {}


def _reg(spec):
    REGISTRY[spec.name] = spec
    return spec


for _bn in (False, True):
    _sfx = "-bn" if _bn else ""
    _reg(CosmoFlowSpec(f"cf-nano{_sfx}", 8, (4, 8), (16, 4), use_bn=_bn))
    _reg(CosmoFlowSpec(f"cf16{_sfx}", 16, (16, 32), (128, 64, 4), use_bn=_bn))
    _reg(CosmoFlowSpec(f"cf32{_sfx}", 32, (16, 32, 64), (256, 64, 4), use_bn=_bn))
    _reg(
        CosmoFlowSpec(
            f"cf64{_sfx}", 64, (16, 32, 64, 128, 256), (2048, 256, 4), use_bn=_bn
        )
    )
    _reg(UNetSpec(f"unet16{_sfx}", 16, 4, 2, use_bn=_bn))
_reg(UNetSpec("unet32", 32, 8, 2))


# ---------------------------------------------------------------------------
# Parameter tables
# ---------------------------------------------------------------------------


def param_table(spec) -> list:
    """Ordered (name, shape) for every trainable parameter.

    The Rust side initializes and owns the parameters; this table fixes the
    order used in every fused executable's signature.
    """
    out = []
    if isinstance(spec, CosmoFlowSpec):
        cin = spec.in_channels
        for i, c in enumerate(spec.channels):
            out.append((f"conv{i}.w", (c, cin, 3, 3, 3)))
            if spec.use_bn:
                out.append((f"conv{i}.gamma", (c,)))
                out.append((f"conv{i}.beta", (c,)))
            cin = c
        fin = spec.flat_features
        for j, f in enumerate(spec.fc):
            out.append((f"fc{j}.w", (f, fin)))
            out.append((f"fc{j}.b", (f,)))
            fin = f
        return out
    assert isinstance(spec, UNetSpec)

    def convpair(tag, cin, c):
        res = []
        for s in ("a", "b"):
            res.append((f"{tag}{s}.w", (c, cin, 3, 3, 3)))
            if spec.use_bn:
                res.append((f"{tag}{s}.gamma", (c,)))
                res.append((f"{tag}{s}.beta", (c,)))
            cin = c
        return res

    cin = spec.in_channels
    for i in range(spec.levels):
        c = spec.level_channels(i)
        out += convpair(f"down{i}.", cin, c)
        cin = c
    cb = spec.level_channels(spec.levels)
    out += convpair("bottom.", cin, cb)
    cin = cb
    for i in reversed(range(spec.levels)):
        c = spec.level_channels(i)
        out.append((f"up{i}.deconv.w", (cin, c, 2, 2, 2)))  # (in, out, k, k, k)
        out += convpair(f"up{i}.", 2 * c, c)
        cin = c
    out.append(("head.w", (spec.n_classes, cin, 1, 1, 1)))
    return out


def bn_layer_names(spec) -> list:
    """Names of the BN-carrying conv layers, in forward order (for running
    statistics bookkeeping on the Rust side)."""
    if not spec.use_bn:
        return []
    return [n[: -len(".gamma")] for n, _ in param_table(spec) if n.endswith(".gamma")]


# ---------------------------------------------------------------------------
# Forward graphs
# ---------------------------------------------------------------------------


def _conv(x, w, use_pallas, stride=1, padding="same"):
    if use_pallas:
        return kconv.conv3d(x, w, stride, padding)
    return ref.conv3d(x, w, stride, padding)


def _pool(x, op):
    return ref.maxpool3d(x) if op == "max" else ref.avgpool3d(x)


def _bn_act(x, gamma, beta, train, running):
    """BN (+ leaky) in train mode (batch stats) or eval mode (running)."""
    if train:
        y, (mean, var) = ref.bn_fwd_local(x, gamma, beta)
        return ref.leaky_relu(y), (mean, var)
    mean, var = running
    return ref.leaky_relu(ref.bn_apply(x, mean, var, gamma, beta)), running


def cosmoflow_fwd(spec, params, x, *, train, masks=None, running=None,
                  use_pallas=False):
    """CosmoFlow forward.  ``params`` dict name->array; returns
    (predictions, list of (mean, var) per BN layer)."""
    stats = []
    h = x
    for i in range(len(spec.channels)):
        h = _conv(h, params[f"conv{i}.w"], use_pallas)
        if spec.use_bn:
            r = None if train else running[i]
            h, s = _bn_act(h, params[f"conv{i}.gamma"], params[f"conv{i}.beta"],
                           train, r)
            stats.append(s)
        else:
            h = ref.leaky_relu(h)
        h = _pool(h, spec.pool)
    h = h.reshape(h.shape[0], -1)
    n_fc = len(spec.fc)
    for j in range(n_fc):
        h = ref.dense(h, params[f"fc{j}.w"], params[f"fc{j}.b"])
        if j < n_fc - 1:
            h = ref.leaky_relu(h)
            if train:
                # masks are pre-scaled (0 or 1/keep) Bernoulli draws supplied
                # by the Rust engine so the graph stays deterministic.
                h = h * masks[j]
    return h, stats


def unet_fwd(spec, params, x, *, train, running=None, use_pallas=False):
    """3D U-Net forward.  Returns (logits, bn stats)."""
    stats = []
    ridx = [0]

    def cbr(tag, h):
        h = _conv(h, params[f"{tag}.w"], use_pallas)
        if spec.use_bn:
            r = None if train else running[ridx[0]]
            h, s = _bn_act(h, params[f"{tag}.gamma"], params[f"{tag}.beta"], train, r)
            stats.append(s)
            ridx[0] += 1
        else:
            h = ref.leaky_relu(h)
        return h

    skips = []
    h = x
    for i in range(spec.levels):
        h = cbr(f"down{i}.a", h)
        h = cbr(f"down{i}.b", h)
        skips.append(h)
        h = ref.maxpool3d(h)
    h = cbr("bottom.a", h)
    h = cbr("bottom.b", h)
    for i in reversed(range(spec.levels)):
        h = ref.deconv3d(h, params[f"up{i}.deconv.w"])
        h = jnp.concatenate([skips[i], h], axis=1)
        h = cbr(f"up{i}.a", h)
        h = cbr(f"up{i}.b", h)
    return ref.conv3d(h, params["head.w"]), stats


# ---------------------------------------------------------------------------
# Fused train/predict entry points (AOT targets)
# ---------------------------------------------------------------------------


def _params_from_flat(spec, flat):
    return {name: a for (name, _), a in zip(param_table(spec), flat)}


def make_train_step(spec, use_pallas=False):
    """Build ``train_step(x, target, [masks...], *params) ->
    (loss, *grads, *bn_means, *bn_vars)``.

    The optimizer (Adam) lives on the Rust side, so the executable is a pure
    function of (batch, params) — the paper's framework splits the same way
    (cuDNN compute vs framework-side update).
    """
    ptable = param_table(spec)
    n_params = len(ptable)

    if isinstance(spec, CosmoFlowSpec):
        n_masks = len(spec.fc) - 1

        def loss_fn(flat, x, target, masks):
            params = _params_from_flat(spec, flat)
            pred, stats = cosmoflow_fwd(
                spec, params, x, train=True, masks=masks, use_pallas=use_pallas
            )
            return ref.mse_loss(pred, target), stats

        def train_step(*args):
            x, target = args[0], args[1]
            masks = list(args[2 : 2 + n_masks])
            flat = list(args[2 + n_masks :])
            (loss, stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                flat, x, target, masks
            )
            means = [m for m, _ in stats]
            variances = [v for _, v in stats]
            return tuple([loss] + list(grads) + means + variances)

        train_step.n_masks = n_masks
    else:

        def loss_fn(flat, x, onehot):
            params = _params_from_flat(spec, flat)
            logits, stats = unet_fwd(spec, params, x, train=True,
                                     use_pallas=use_pallas)
            lse = jax.nn.logsumexp(logits, axis=1, keepdims=True)
            loss = -jnp.mean(jnp.sum(onehot * (logits - lse), axis=1))
            return loss, stats

        def train_step(*args):
            x, onehot = args[0], args[1]
            flat = list(args[2:])
            (loss, stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                flat, x, onehot
            )
            means = [m for m, _ in stats]
            variances = [v for _, v in stats]
            return tuple([loss] + list(grads) + means + variances)

        train_step.n_masks = 0

    train_step.n_params = n_params
    return train_step


def make_predict(spec, use_pallas=False):
    """Build ``predict(x, *params, *bn_means, *bn_vars) -> (output,)`` —
    eval mode: running statistics, no dropout."""
    n_bn = len(bn_layer_names(spec))

    def predict(*args):
        x = args[0]
        flat = list(args[1 : 1 + len(param_table(spec))])
        rest = args[1 + len(param_table(spec)) :]
        running = list(zip(rest[:n_bn], rest[n_bn:])) if n_bn else None
        params = _params_from_flat(spec, flat)
        if isinstance(spec, CosmoFlowSpec):
            out, _ = cosmoflow_fwd(spec, params, x, train=False, running=running,
                                   use_pallas=use_pallas)
        else:
            out, _ = unet_fwd(spec, params, x, train=False, running=running,
                              use_pallas=use_pallas)
        return (out,)

    return predict


# ---------------------------------------------------------------------------
# Layer plans for the hybrid-parallel engine
# ---------------------------------------------------------------------------


def layer_plan(spec) -> list:
    """Flat forward-order layer descriptors for the shard engine.

    Spatial entries carry the *global* activation geometry; ``aot.py``
    divides depth by the partition ways when shaping shard executables.
    The Rust engine executes this plan directly (it is embedded in the
    manifest), inserting halo exchanges around convs, allreduces inside BN,
    and gather/scatter at the flatten boundary.
    """
    plan = []
    if isinstance(spec, CosmoFlowSpec):
        s = spec.input_size
        cin = spec.in_channels
        for i, c in enumerate(spec.channels):
            plan.append(dict(kind="conv", tag=f"conv{i}", cin=cin, cout=c, k=3,
                             stride=1, d=s, h=s, w=s))
            if spec.use_bn:
                plan.append(dict(kind="bn", tag=f"conv{i}", c=c, d=s, h=s, w=s))
            else:
                plan.append(dict(kind="act", c=c, d=s, h=s, w=s))
            plan.append(dict(kind="pool", op=spec.pool, c=c, d=s, h=s, w=s))
            s //= 2
            cin = c
        plan.append(dict(kind="flatten", c=cin, d=s, h=s, w=s))
        fin = spec.flat_features
        for j, f in enumerate(spec.fc):
            last = j == len(spec.fc) - 1
            plan.append(dict(kind="fc", tag=f"fc{j}", fin=fin, fout=f,
                             act=not last, dropout=not last))
            fin = f
        plan.append(dict(kind="mse", n=spec.n_targets))
        return plan

    assert isinstance(spec, UNetSpec)
    s = spec.input_size
    cin = spec.in_channels

    def conv_bn(tag, cin, c, s):
        plan.append(dict(kind="conv", tag=tag, cin=cin, cout=c, k=3, stride=1,
                         d=s, h=s, w=s))
        if spec.use_bn:
            plan.append(dict(kind="bn", tag=tag, c=c, d=s, h=s, w=s))
        else:
            plan.append(dict(kind="act", c=c, d=s, h=s, w=s))

    for i in range(spec.levels):
        c = spec.level_channels(i)
        conv_bn(f"down{i}.a", cin, c, s)
        conv_bn(f"down{i}.b", c, c, s)
        plan.append(dict(kind="save_skip", slot=i, c=c, d=s, h=s, w=s))
        plan.append(dict(kind="pool", op="max", c=c, d=s, h=s, w=s))
        s //= 2
        cin = c
    cb = spec.level_channels(spec.levels)
    conv_bn("bottom.a", cin, cb, s)
    conv_bn("bottom.b", cb, cb, s)
    cin = cb
    for i in reversed(range(spec.levels)):
        c = spec.level_channels(i)
        plan.append(dict(kind="deconv", tag=f"up{i}.deconv", cin=cin, cout=c,
                         k=2, stride=2, d=s, h=s, w=s))
        s *= 2
        plan.append(dict(kind="concat_skip", slot=i, c_skip=c, c_up=c,
                         d=s, h=s, w=s))
        conv_bn(f"up{i}.a", 2 * c, c, s)
        conv_bn(f"up{i}.b", c, c, s)
        cin = c
    plan.append(dict(kind="conv", tag="head", cin=cin, cout=spec.n_classes, k=1,
                     stride=1, d=s, h=s, w=s))
    plan.append(dict(kind="xent", n_classes=spec.n_classes, d=s, h=s, w=s))
    return plan
