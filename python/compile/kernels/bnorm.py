"""L1 Pallas kernel: fused batch-norm-apply + leaky-ReLU.

The paper adds batch normalization after every convolution (§IV) and notes
that on large 3D tensors "operations that are normally considered cheap can
in fact dominate runtime if not well implemented" (§III-A).  Fusing the
normalization with the activation halves the HBM traffic of the pointwise
tail of each conv layer — the TPU analogue of the paper's optimized CUDA
pointwise kernels.

Statistics are *inputs*: the Rust engine computes and allreduces per-channel
(sum, sumsq, count) partials across the partition x batch groups first
(distributed BN, §III-A), so one kernel serves the 1-rank and the N-rank
cases identically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .ref import BN_EPS, LEAKY_SLOPE


def _bn_kernel(x_ref, mean_ref, var_ref, gamma_ref, beta_ref, o_ref, *, eps, slope):
    x = x_ref[0]  # (CT, D, H, W)
    mean = mean_ref[...].reshape(-1, 1, 1, 1)
    inv = gamma_ref[...].reshape(-1, 1, 1, 1) * lax.rsqrt(
        var_ref[...].reshape(-1, 1, 1, 1) + eps
    )
    y = (x - mean) * inv + beta_ref[...].reshape(-1, 1, 1, 1)
    o_ref[0] = jnp.where(y >= 0, y, slope * y)


def bn_leaky_pallas(
    x,
    mean,
    var,
    gamma,
    beta,
    eps: float = BN_EPS,
    slope: float = LEAKY_SLOPE,
    interpret: bool = True,
):
    """Fused ``leaky_relu(bn_apply(x, ...))``; matches the ref composition."""
    n, c, d, h, w = x.shape
    ct = min(c, 32)
    while c % ct:
        ct //= 2
    kern = functools.partial(_bn_kernel, eps=eps, slope=slope)
    cvec = lambda n_, c_: (c_,)  # noqa: E731 — per-channel param tiles
    return pl.pallas_call(
        kern,
        grid=(n, c // ct),
        in_specs=[
            pl.BlockSpec((1, ct, d, h, w), lambda n_, c_: (n_, c_, 0, 0, 0)),
            pl.BlockSpec((ct,), cvec),
            pl.BlockSpec((ct,), cvec),
            pl.BlockSpec((ct,), cvec),
            pl.BlockSpec((ct,), cvec),
        ],
        out_specs=pl.BlockSpec((1, ct, d, h, w), lambda n_, c_: (n_, c_, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=interpret,
    )(x, mean, var, gamma, beta)
