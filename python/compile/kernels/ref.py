"""Pure-jnp reference oracles for every HYDRA-3D kernel.

These are the correctness ground truth for the Pallas kernels (L1) and the
building blocks of the fused L2 model graphs.  Everything is NCDHW and f32.

Conventions
-----------
* ``x``  activations, shape ``(n, c, d, h, w)``.
* ``w``  conv filters, shape ``(c_out, c_in, kd, kh, kw)`` (cuDNN layout, as
  in the paper's notation section).
* ``padding``:
    - ``"same"``    zero-pad all three spatial dims (output size = input/stride).
    - ``"valid"``   no padding.
    - ``"valid_d"`` no padding in depth, "same" in H/W — the *shard* flavor
      used by the hybrid-parallel engine: the Rust coordinator supplies a
      depth-halo-padded shard and the kernel consumes the halo.

All backward functions are exact transposes (conv is bilinear, so vjps taken
at a zero primal are exact); they are verified against ``jax.grad`` of the
forward oracle in ``python/tests``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

DIMNUMS = lax.ConvDimensionNumbers(
    lhs_spec=(0, 1, 2, 3, 4),  # NCDHW
    rhs_spec=(0, 1, 2, 3, 4),  # OIDHW
    out_spec=(0, 1, 2, 3, 4),
)


def _pad_config(padding: str, k):
    """Translate a padding name into per-dim (lo, hi) pairs for lax."""
    same = [((kk - 1) // 2, kk // 2) for kk in k]
    if padding == "same":
        return same
    if padding == "valid":
        return [(0, 0)] * 3
    if padding == "valid_d":
        return [(0, 0), same[1], same[2]]
    raise ValueError(f"unknown padding {padding!r}")


# ---------------------------------------------------------------------------
# 3D convolution
# ---------------------------------------------------------------------------


def conv3d(x, w, stride: int = 1, padding: str = "same"):
    """Reference 3D convolution (no bias — the paper removes conv biases)."""
    k = w.shape[2:]
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride,) * 3,
        padding=_pad_config(padding, k),
        dimension_numbers=DIMNUMS,
    )


def conv3d_bwd_data(dy, w, x_shape, stride: int = 1, padding: str = "same"):
    """dL/dx for conv3d.  Exact: conv is linear in x, so the vjp at x=0 is
    the transpose."""
    zero = jnp.zeros(x_shape, dy.dtype)
    _, vjp = jax.vjp(lambda x: conv3d(x, w, stride, padding), zero)
    return vjp(dy)[0]


def conv3d_bwd_filter(x, dy, w_shape, stride: int = 1, padding: str = "same"):
    """dL/dw for conv3d (linear in w)."""
    zero = jnp.zeros(w_shape, dy.dtype)
    _, vjp = jax.vjp(lambda w: conv3d(x, w, stride, padding), zero)
    return vjp(dy)[0]


# ---------------------------------------------------------------------------
# Transposed 3D convolution (deconvolution; 3D U-Net up-sampling path)
# ---------------------------------------------------------------------------


def deconv3d(x, w, stride: int = 2):
    """2x up-sampling transposed conv with a (stride,)^3 kernel.

    ``w`` has shape (c_in, c_out, kd, kh, kw) — note the in/out order follows
    the transposed-conv convention.  With kernel == stride there is no
    overlap, so the op is shard-local under depth partitioning (each output
    voxel depends on exactly one input voxel): no halo needed.
    """
    return lax.conv_transpose(
        x,
        w,
        strides=(stride,) * 3,
        padding="VALID",
        dimension_numbers=("NCDHW", "IODHW", "NCDHW"),
    )


def deconv3d_bwd_data(dy, w, x_shape, stride: int = 2):
    zero = jnp.zeros(x_shape, dy.dtype)
    _, vjp = jax.vjp(lambda x: deconv3d(x, w, stride), zero)
    return vjp(dy)[0]


def deconv3d_bwd_filter(x, dy, w_shape, stride: int = 2):
    zero = jnp.zeros(w_shape, dy.dtype)
    _, vjp = jax.vjp(lambda w: deconv3d(x, w, stride), zero)
    return vjp(dy)[0]


# ---------------------------------------------------------------------------
# 2^3 stride-2 pooling
# ---------------------------------------------------------------------------


def maxpool3d(x):
    """2x2x2 max pooling with stride 2 (spatial dims must be even)."""
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, 1, 2, 2, 2),
        window_strides=(1, 1, 2, 2, 2),
        padding="VALID",
    )


def avgpool3d(x):
    """2x2x2 average pooling with stride 2."""
    s = lax.reduce_window(
        x,
        0.0,
        lax.add,
        window_dimensions=(1, 1, 2, 2, 2),
        window_strides=(1, 1, 2, 2, 2),
        padding="VALID",
    )
    return s * 0.125


def _up2(y):
    """Nearest-neighbour 2x up-sample of the three spatial dims."""
    for axis in (2, 3, 4):
        y = jnp.repeat(y, 2, axis=axis)
    return y


def maxpool3d_bwd(x, y, dy):
    """dL/dx for maxpool3d given saved input & output.

    Ties share the gradient equally (measure-zero for continuous data; the
    convention only matters for synthetic integer inputs and is covered by
    an explicit test).
    """
    mask = (x == _up2(y)).astype(dy.dtype)
    counts = lax.reduce_window(
        mask,
        0.0,
        lax.add,
        window_dimensions=(1, 1, 2, 2, 2),
        window_strides=(1, 1, 2, 2, 2),
        padding="VALID",
    )
    return mask * _up2(dy / counts)


def avgpool3d_bwd(dy):
    return _up2(dy) * 0.125


# ---------------------------------------------------------------------------
# Batch normalization (training mode, distributed-statistics flavor)
# ---------------------------------------------------------------------------

BN_EPS = 1e-5


def bn_stats(x):
    """Per-channel local partial statistics (sum, sum of squares, count).

    The hybrid engine allreduces these over the sample's partition group and
    the batch group before calling :func:`bn_apply` — this is the paper's
    distributed batch-norm (§III-A).
    """
    s1 = jnp.sum(x, axis=(0, 2, 3, 4))
    s2 = jnp.sum(x * x, axis=(0, 2, 3, 4))
    cnt = jnp.float32(x.shape[0] * x.shape[2] * x.shape[3] * x.shape[4])
    return s1, s2, cnt


def bn_apply(x, mean, var, gamma, beta, eps: float = BN_EPS):
    """Normalize with (already-reduced) global statistics."""
    inv = gamma * lax.rsqrt(var + eps)
    c = mean.reshape(1, -1, 1, 1, 1)
    return (x - c) * inv.reshape(1, -1, 1, 1, 1) + beta.reshape(1, -1, 1, 1, 1)


def bn_fwd_local(x, gamma, beta, eps: float = BN_EPS):
    """Single-group (fused, data-parallel) BN forward.  Returns y and the
    saved stats needed for backward and for running-average updates."""
    s1, s2, cnt = bn_stats(x)
    mean = s1 / cnt
    var = s2 / cnt - mean * mean
    return bn_apply(x, mean, var, gamma, beta, eps), (mean, var)


def bn_bwd_partials(x, dy, mean, var, eps: float = BN_EPS):
    """Local partial sums for the distributed BN backward:
    (sum dy*xhat, sum dy) per channel."""
    xhat = (x - mean.reshape(1, -1, 1, 1, 1)) * lax.rsqrt(
        var.reshape(1, -1, 1, 1, 1) + eps
    )
    g1 = jnp.sum(dy * xhat, axis=(0, 2, 3, 4))
    g2 = jnp.sum(dy, axis=(0, 2, 3, 4))
    return g1, g2


def bn_bwd_apply(x, dy, mean, var, gamma, g1, g2, cnt, eps: float = BN_EPS):
    """dL/dx for training-mode BN given globally-reduced (g1, g2, cnt).

    dgamma = g1 and dbeta = g2 (after the same allreduce)."""
    inv = lax.rsqrt(var + eps).reshape(1, -1, 1, 1, 1)
    xhat = (x - mean.reshape(1, -1, 1, 1, 1)) * inv
    t = dy - (g2 / cnt).reshape(1, -1, 1, 1, 1) - xhat * (g1 / cnt).reshape(
        1, -1, 1, 1, 1
    )
    return gamma.reshape(1, -1, 1, 1, 1) * inv * t


# ---------------------------------------------------------------------------
# Pointwise / dense / losses
# ---------------------------------------------------------------------------

LEAKY_SLOPE = 0.01


def leaky_relu(x, slope: float = LEAKY_SLOPE):
    return jnp.where(x >= 0, x, slope * x)


def leaky_relu_bwd(x, dy, slope: float = LEAKY_SLOPE):
    return jnp.where(x >= 0, dy, slope * dy)


def dense(x, w, b):
    """Fully-connected layer: x (n, f_in), w (f_out, f_in), b (f_out,)."""
    return x @ w.T + b


def dense_bwd(x, w, dy):
    """Returns (dx, dw, db)."""
    return dy @ w, dy.T @ x, jnp.sum(dy, axis=0)


def mse_loss(pred, target):
    """Mean squared error over all elements (CosmoFlow's loss)."""
    d = pred - target
    return jnp.mean(d * d)


def mse_fwd_bwd(pred, target):
    """Loss value and dL/dpred in one pass."""
    d = pred - target
    n = jnp.float32(d.size)
    return jnp.mean(d * d), 2.0 * d / n


def softmax_xent(logits, labels, n_classes: int):
    """Per-voxel softmax cross-entropy for segmentation (3D U-Net).

    logits (n, k, d, h, w); labels (n, d, h, w) int32.  Returns mean loss
    over voxels.
    """
    lse = jax.nn.logsumexp(logits, axis=1, keepdims=True)
    logp = logits - lse
    onehot = jax.nn.one_hot(labels, n_classes, axis=1, dtype=logits.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=1))


def softmax_xent_fwd_bwd(logits, labels, n_classes: int):
    """Loss and dL/dlogits."""
    lse = jax.nn.logsumexp(logits, axis=1, keepdims=True)
    logp = logits - lse
    onehot = jax.nn.one_hot(labels, n_classes, axis=1, dtype=logits.dtype)
    loss = -jnp.mean(jnp.sum(onehot * logp, axis=1))
    m = jnp.float32(labels.size)
    return loss, (jnp.exp(logp) - onehot) / m


def dice_score(pred_labels, labels, n_classes: int):
    """Mean Dice coefficient over classes — the LiTS evaluation metric."""
    scores = []
    for k in range(n_classes):
        p = (pred_labels == k).astype(jnp.float32)
        t = (labels == k).astype(jnp.float32)
        inter = jnp.sum(p * t)
        denom = jnp.sum(p) + jnp.sum(t)
        scores.append(jnp.where(denom > 0, 2 * inter / denom, 1.0))
    return jnp.mean(jnp.stack(scores))


# ---------------------------------------------------------------------------
# Shard-flavoured helpers (what the hybrid engine's executables compute)
# ---------------------------------------------------------------------------


def conv3d_shard_fwd(x_padded, w, stride: int = 1):
    """Forward conv on a depth-halo-padded shard: valid in D, same in H/W.

    The Rust engine always supplies ``halo = (k_d - 1) // 2`` planes on both
    depth ends (boundary ranks get zero planes, interior ranks get neighbour
    data), so one executable serves every rank position."""
    return conv3d(x_padded, w, stride, "valid_d")


def conv3d_shard_bwd_data(dy, w, xp_shape, stride: int = 1):
    """Gradient w.r.t. the *padded* shard input; the engine reverse-exchanges
    and accumulates the halo planes into the owning neighbours."""
    return conv3d_bwd_data(dy, w, xp_shape, stride, "valid_d")


def conv3d_shard_bwd_filter(x_padded, dy, w_shape, stride: int = 1):
    return conv3d_bwd_filter(x_padded, dy, w_shape, stride, "valid_d")
