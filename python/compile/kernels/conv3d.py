"""L1 Pallas kernel: direct 3D convolution (NCDHW, no bias).

This is the compute hot-spot of the paper (conv1 of the 512^3 CosmoFlow
model alone is ~half of end-to-end runtime, §V-B).  The paper's kernels are
cuDNN implicit-GEMM on V100s; per DESIGN.md §3 we re-think the tiling for a
TPU-shaped machine instead of porting the CUDA structure:

* The output tensor is tiled over a grid of ``(sample, Cout-tile, Dout-tile)``
  BlockSpecs.  Each grid step owns an output tile in VMEM, the analogue of a
  threadblock's shared-memory tile.
* The input depth-slab needed by an output tile (``(TD-1)*stride + K``
  planes) is sliced out of the sample once, and the K^3 filter taps are
  accumulated as K^3 MXU-shaped matmuls ``(TC, Cin) x (Cin, TD*Ho*Wo)`` —
  the systolic-array translation of implicit GEMM.
* The HBM<->VMEM schedule that CUDA expresses with cooperative loads is
  expressed here with the BlockSpec index maps plus an in-kernel dynamic
  depth-slab slice (depth tiles overlap by the filter footprint, which
  plain non-overlapping BlockSpecs cannot express).

Kernels are lowered with ``interpret=True``: the CPU PJRT client cannot run
Mosaic custom-calls, so interpret mode is the correctness vehicle and the
TPU performance story is analytic — :func:`vmem_report` computes the VMEM
footprint and MXU-utilization estimate for a tiling (quoted in
EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import ref

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM on current TPU generations
MXU_DIM = 128  # systolic array is 128x128


@dataclass(frozen=True)
class ConvTiling:
    """Tile sizes for the conv kernel grid (must divide Cout and Dout)."""

    tc: int  # output channels per grid step
    td: int  # output depth planes per grid step

    def grid(self, n: int, cout: int, dout: int):
        assert cout % self.tc == 0, (cout, self.tc)
        assert dout % self.td == 0, (dout, self.td)
        return (n, cout // self.tc, dout // self.td)


def pick_tiling(cout: int, dout: int, cin: int, hw, k: int, stride: int) -> ConvTiling:
    """Largest depth tile whose working set fits the VMEM budget.

    Working set per grid step = input depth slab + filter tile + output tile
    (all f32).  We shrink TD first (halving), then TC, mirroring how one
    would shrink a threadblock tile under shared-memory pressure.
    """
    ho, wo = hw
    tc = min(cout, MXU_DIM)
    while cout % tc:
        tc //= 2
    td = dout
    while td > 1 and _tile_bytes(tc, td, cin, ho, wo, k, stride) > VMEM_BYTES:
        td //= 2
    while dout % td:
        td //= 2
    td = max(td, 1)
    # Huge H/W planes (e.g. conv1 of the 512^3 model): a single depth plane
    # can still blow VMEM; shed output channels next, as a CUDA kernel would
    # shrink its threadblock tile.
    while tc > 1 and _tile_bytes(tc, td, cin, ho, wo, k, stride) > VMEM_BYTES:
        tc //= 2
    return ConvTiling(tc=max(tc, 1), td=td)


def _tile_bytes(tc, td, cin, ho, wo, k, stride) -> int:
    td_in = (td - 1) * stride + k
    hin, win = (ho - 1) * stride + k, (wo - 1) * stride + k
    x_slab = cin * td_in * hin * win
    w_tile = tc * cin * k * k * k
    out_tile = tc * td * ho * wo
    return 4 * (x_slab + w_tile + out_tile)


def vmem_report(cout, dout, cin, hw, k=3, stride=1, tiling: ConvTiling | None = None):
    """Analytic VMEM + MXU report for a tiling (the L1 perf deliverable)."""
    t = tiling or pick_tiling(cout, dout, cin, hw, k, stride)
    ho, wo = hw
    tile_bytes = _tile_bytes(t.tc, t.td, cin, ho, wo, k, stride)
    # Each tap is a (tc, cin) x (cin, td*ho*wo) matmul; the MXU runs
    # 128x128x128 blocks, so utilization is the product of the fill factors
    # of each GEMM dimension (m = tc, k = cin, n = td*ho*wo).
    m_fill = min(t.tc, MXU_DIM) / MXU_DIM
    k_fill = min(cin, MXU_DIM) / MXU_DIM
    n = t.td * ho * wo
    n_fill = min(n, MXU_DIM) / MXU_DIM
    flops = 2 * k**3 * cin * cout * dout * ho * wo
    return {
        "tiling": (t.tc, t.td),
        "grid": (cout // t.tc) * (dout // t.td),
        "tile_bytes": tile_bytes,
        "vmem_ok": tile_bytes <= VMEM_BYTES,
        "mxu_util_est": m_fill * k_fill * n_fill,
        "flops_per_sample": flops,
    }


def _conv_kernel(x_ref, w_ref, o_ref, *, k: int, stride: int, td: int, hw_out):
    """Pallas kernel body: one (sample, Cout-tile, Dout-tile) grid step."""
    ho, wo = hw_out
    d_idx = pl.program_id(2)
    td_in = (td - 1) * stride + k
    x = x_ref[0]  # (Cin, Dp, Hp, Wp) — sample slab in VMEM
    slab = lax.dynamic_slice_in_dim(x, d_idx * td * stride, td_in, axis=1)
    cin = slab.shape[0]
    tc = o_ref.shape[1]
    acc = jnp.zeros((tc, td * ho * wo), jnp.float32)
    # K^3 filter taps -> K^3 MXU matmuls accumulated in VMEM.
    for kd in range(k):
        for kh in range(k):
            for kw in range(k):
                xs = slab[
                    :,
                    kd : kd + (td - 1) * stride + 1 : stride,
                    kh : kh + (ho - 1) * stride + 1 : stride,
                    kw : kw + (wo - 1) * stride + 1 : stride,
                ]
                wt = w_ref[:, :, kd, kh, kw]  # (TC, Cin)
                acc = acc + jnp.dot(
                    wt, xs.reshape(cin, -1), preferred_element_type=jnp.float32
                )
    o_ref[0] = acc.reshape(tc, td, ho, wo)


def conv3d_pallas(
    x,
    w,
    stride: int = 1,
    padding: str = "same",
    tiling: ConvTiling | None = None,
    interpret: bool = True,
):
    """3D convolution with the Pallas direct kernel.

    Matches :func:`ref.conv3d` bit-for-bit module reassociation; tested via
    pytest + hypothesis sweeps in ``python/tests/test_conv3d.py``.
    """
    n, cin, d, h, ww = x.shape
    cout, cin2, k, k2, k3 = w.shape
    assert cin == cin2 and k == k2 == k3, "cubic filters only"
    pads = ref._pad_config(padding, (k, k, k))
    xp = jnp.pad(x, [(0, 0), (0, 0)] + [tuple(p) for p in pads])
    dp, hp, wp = xp.shape[2:]
    do = (dp - k) // stride + 1
    ho = (hp - k) // stride + 1
    wo = (wp - k) // stride + 1
    t = tiling or pick_tiling(cout, do, cin, (ho, wo), k, stride)

    kern = functools.partial(
        _conv_kernel, k=k, stride=stride, td=t.td, hw_out=(ho, wo)
    )
    return pl.pallas_call(
        kern,
        grid=t.grid(n, cout, do),
        in_specs=[
            # full padded sample per grid step; depth tiles overlap by the
            # filter footprint so the slab is sliced in-kernel.
            pl.BlockSpec((1, cin, dp, hp, wp), lambda n_, c_, d_: (n_, 0, 0, 0, 0)),
            pl.BlockSpec((t.tc, cin, k, k, k), lambda n_, c_, d_: (c_, 0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, t.tc, t.td, ho, wo), lambda n_, c_, d_: (n_, c_, d_, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((n, cout, do, ho, wo), jnp.float32),
        interpret=interpret,
    )(xp, w)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def conv3d(x, w, stride: int = 1, padding: str = "same"):
    """Differentiable conv3d: Pallas forward, reference-transpose backward.

    ``jax.grad`` cannot differentiate through ``pallas_call``; the backward
    pass uses the (exactly equivalent) XLA transposed convolutions from
    ``ref``.  The fused L2 train-step graphs therefore contain the Pallas
    kernel in their forward segment.
    """
    return conv3d_pallas(x, w, stride, padding)


def _conv3d_fwd(x, w, stride, padding):
    return conv3d_pallas(x, w, stride, padding), (x, w)


def _conv3d_bwd(stride, padding, res, dy):
    x, w = res
    dx = ref.conv3d_bwd_data(dy, w, x.shape, stride, padding)
    dw = ref.conv3d_bwd_filter(x, dy, w.shape, stride, padding)
    return dx, dw


conv3d.defvjp(_conv3d_fwd, _conv3d_bwd)


def conv3d_shard_fwd(x_padded, w, stride: int = 1):
    """Shard flavour (valid in depth, same in H/W) with the Pallas kernel —
    the executable the hybrid engine runs on every rank (see ref.py)."""
    return conv3d_pallas(x_padded, w, stride, "valid_d")
