"""L1 Pallas kernel: 2x2x2 stride-2 max/avg pooling.

The paper's pooling layers are all 2^3 windows with stride 2 ("We use
stride 1 convolution and stride 2 pooling", Table I), which makes pooling
shard-local under any even spatial partitioning: window boundaries align
with shard boundaries, so no halo exchange is needed (DESIGN.md §6).

The kernel grid is ``(sample, C-tile)``; each step reduces its channel tile
with eight strided slices — a vectorized tree-max/-add rather than a
windowed loop, which maps onto the VPU's elementwise lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _slices8(x):
    """The eight stride-2 phases of a (C, D, H, W) tile."""
    for dz in range(2):
        for dy in range(2):
            for dx in range(2):
                yield x[:, dz::2, dy::2, dx::2]


def _pool_kernel(x_ref, o_ref, *, op: str):
    x = x_ref[0]
    it = _slices8(x)
    acc = next(it)
    for s in it:
        acc = jnp.maximum(acc, s) if op == "max" else acc + s
    if op == "avg":
        acc = acc * 0.125
    o_ref[0] = acc


def _pick_ct(c: int) -> int:
    ct = min(c, 32)
    while c % ct:
        ct //= 2
    return max(ct, 1)


def pool3d_pallas(x, op: str = "max", interpret: bool = True):
    """2^3/stride-2 pooling; matches ref.maxpool3d / ref.avgpool3d."""
    assert op in ("max", "avg")
    n, c, d, h, w = x.shape
    assert d % 2 == 0 and h % 2 == 0 and w % 2 == 0, "even dims required"
    ct = _pick_ct(c)
    kern = functools.partial(_pool_kernel, op=op)
    return pl.pallas_call(
        kern,
        grid=(n, c // ct),
        in_specs=[pl.BlockSpec((1, ct, d, h, w), lambda n_, c_: (n_, c_, 0, 0, 0))],
        out_specs=pl.BlockSpec(
            (1, ct, d // 2, h // 2, w // 2), lambda n_, c_: (n_, c_, 0, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((n, c, d // 2, h // 2, w // 2), jnp.float32),
        interpret=interpret,
    )(x)


def maxpool3d(x):
    return pool3d_pallas(x, "max")


def avgpool3d(x):
    return pool3d_pallas(x, "avg")
