"""AOT compiler: lower every HYDRA-3D entry point to HLO text + manifest.

Build-time only (``make artifacts``).  Python never runs on the training
path: the Rust coordinator loads ``artifacts/*.hlo.txt`` through the PJRT C
API and executes them directly.

Interchange format is **HLO text**, not a serialized ``HloModuleProto`` —
jax >= 0.5 emits protos with 64-bit instruction ids that the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Emitted entry families (see model.py for the model registry):

* ``<model>.train_step`` / ``<model>.predict`` — fused whole-model graphs
  for the data-parallel engine and the end-to-end examples.
* ``<model>.w<W>.<layer>.<op>`` — per-layer shard executables for the
  hybrid-parallel engine under W-way depth partitioning: Pallas forward
  kernels (conv3d / pool3d / fused bn+leaky), reference-transpose backward.

``artifacts/manifest.json`` records, for every entry, the HLO file and the
input/output shapes, plus per-model metadata (parameter table, layer plan,
BN layers, hybrid ways) — the single source of truth the Rust engine builds
its graph from.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ref
from .kernels import conv3d as kconv
from .kernels import pool3d as kpool
from .kernels import bnorm as kbn

F32 = jnp.float32

# Default build matrix.  Fused graphs for every registered model; shard sets
# (model, ways) chosen so the functional tests exercise 1/2/4-way depth
# partitioning without exploding artifact count (DESIGN.md §6).
FUSED_MODELS = [
    "cf-nano", "cf-nano-bn", "cf16", "cf16-bn", "cf32", "cf32-bn",
    "cf64", "cf64-bn", "unet16", "unet16-bn", "unet32",
]
HYBRID_SETS = {
    "cf-nano": [1, 2],
    "cf-nano-bn": [1, 2],
    "cf16": [1, 2, 4],
    "cf16-bn": [1, 2, 4],
    "cf32": [1, 4],
    "unet16": [1, 2],
}
# Full 3D spatial grids ("dxhxw" keys): shard executables halo-padded and
# VALID along *all three* axes (the depth sets pad D only). The Rust
# engine looks these up via ModelInfo::hybrid_plan for `--grid dxhxw`.
GRID_SETS = {
    "cf-nano": ["2x2x2"],
    "cf16": ["2x2x2"],
    "unet16": ["2x2x2"],
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries: dict = {}
        self.hlo_ops: dict = {}

    def emit(self, name: str, fn, in_shapes) -> str:
        """Lower ``fn`` at the given f32 input shapes and write HLO text."""
        specs = [jax.ShapeDtypeStruct(tuple(s), F32) for s in in_shapes]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        out_shapes = [list(o.shape) for o in jax.eval_shape(fn, *specs)]
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.entries[name] = {
            "file": fname,
            "inputs": [list(s) for s in in_shapes],
            "outputs": out_shapes,
        }
        self.hlo_ops[name] = audit_hlo(text)
        return name


def audit_hlo(text: str) -> dict:
    """Cheap op-census of an HLO module (L2 perf audit, DESIGN.md §7):
    convolution/dot/fusion/all-op counts let us assert no redundant
    recompute creeps into the lowered graphs."""
    counts = {"convolution": 0, "dot": 0, "fusion": 0, "while": 0, "total": 0}
    for line in text.splitlines():
        s = line.strip()
        if "=" not in s or s.startswith(("HloModule", "ENTRY", "}", "%")):
            pass
        m = re.search(r"=\s+\S+\s+(convolution|dot|fusion|while)\(", s)
        if "=" in s and re.search(r"=\s+[a-z0-9\[\],\{\}\s]+ [a-z-]+\(", s):
            counts["total"] += 1
        if m:
            counts[m.group(1)] += 1
    return counts


# ---------------------------------------------------------------------------
# Fused entries
# ---------------------------------------------------------------------------


def emit_fused(b: Builder, spec, use_pallas: bool) -> dict:
    """train_step + predict for one model; returns the manifest stanza."""
    ptable = M.param_table(spec)
    pshapes = [list(s) for _, s in ptable]
    n_bn = len(M.bn_layer_names(spec))
    batch = 2  # fused executables are lowered at a fixed per-rank batch
    s = spec.input_size

    train = M.make_train_step(spec, use_pallas=use_pallas)
    if isinstance(spec, M.CosmoFlowSpec):
        x_shape = [batch, spec.in_channels, s, s, s]
        tgt_shape = [batch, spec.n_targets]
        mask_shapes = [[batch, f] for f in spec.fc[:-1]]
        train_in = [x_shape, tgt_shape] + mask_shapes + pshapes
        pred_extra = [[c] for c in _bn_channel_list(spec)] * 2
        pred_in = [x_shape] + pshapes + pred_extra
    else:
        x_shape = [batch, spec.in_channels, s, s, s]
        onehot = [batch, spec.n_classes, s, s, s]
        train_in = [x_shape, onehot] + pshapes
        pred_extra = [[c] for c in _bn_channel_list(spec)] * 2
        pred_in = [x_shape] + pshapes + pred_extra

    ts = b.emit(f"{spec.name}.train_step", train, train_in)
    pr = b.emit(f"{spec.name}.predict", M.make_predict(spec, use_pallas), pred_in)
    return {
        "train_step": ts,
        "predict": pr,
        "batch": batch,
        "n_masks": getattr(train, "n_masks", 0),
        "n_bn": n_bn,
    }


def _bn_channel_list(spec):
    """Channel count of each BN layer, forward order."""
    if not spec.use_bn:
        return []
    table = dict(M.param_table(spec))
    return [table[f"{n}.gamma"][0] for n in M.bn_layer_names(spec)]


# ---------------------------------------------------------------------------
# Per-layer shard entries (hybrid engine)
# ---------------------------------------------------------------------------


def emit_shard_set(b: Builder, spec, ways: int) -> list:
    """Shard executables for W-way depth partitioning of one model.

    Returns the plan with entry names attached per layer (what the Rust
    engine walks).  All forward convs/pools/bns go through the Pallas
    kernels; backward ops are the exact reference transposes.
    """
    plan = M.layer_plan(spec)
    pre = f"{spec.name}.w{ways}"
    out_plan = []
    for li, layer in enumerate(plan):
        layer = dict(layer)
        kind = layer["kind"]
        tag = layer.get("tag", f"l{li}")
        name = f"{pre}.{li}.{tag}"
        if kind == "conv":
            dsh = layer["d"] // ways
            halo = (layer["k"] - 1) // 2
            cin, cout, k, st = layer["cin"], layer["cout"], layer["k"], layer["stride"]
            h, w = layer["h"], layer["w"]
            xp = [1, cin, dsh + 2 * halo, h, w]
            dy = [1, cout, dsh, h, w]
            wsh = [cout, cin, k, k, k]
            layer["halo"] = halo
            layer["fwd"] = b.emit(
                f"{name}.fwd",
                lambda x_, w_, st=st: (kconv.conv3d_shard_fwd(x_, w_, st),),
                [xp, wsh],
            )
            layer["bwd_data"] = b.emit(
                f"{name}.bwd_data",
                lambda dy_, w_, xp=tuple(xp), st=st: (
                    ref.conv3d_shard_bwd_data(dy_, w_, xp, st),
                ),
                [dy, wsh],
            )
            layer["bwd_filter"] = b.emit(
                f"{name}.bwd_filter",
                lambda x_, dy_, ws=tuple(wsh), st=st: (
                    ref.conv3d_shard_bwd_filter(x_, dy_, ws, st),
                ),
                [xp, dy],
            )
        elif kind == "deconv":
            dsh = layer["d"] // ways
            cin, cout = layer["cin"], layer["cout"]
            h, w = layer["h"], layer["w"]
            x = [1, cin, dsh, h, w]
            dy = [1, cout, dsh * 2, h * 2, w * 2]
            wsh = [cin, cout, 2, 2, 2]
            layer["fwd"] = b.emit(
                f"{name}.fwd", lambda x_, w_: (ref.deconv3d(x_, w_),), [x, wsh]
            )
            layer["bwd_data"] = b.emit(
                f"{name}.bwd_data",
                lambda dy_, w_, xs=tuple(x): (ref.deconv3d_bwd_data(dy_, w_, xs),),
                [dy, wsh],
            )
            layer["bwd_filter"] = b.emit(
                f"{name}.bwd_filter",
                lambda x_, dy_, ws=tuple(wsh): (ref.deconv3d_bwd_filter(x_, dy_, ws),),
                [x, dy],
            )
        elif kind == "pool":
            dsh = layer["d"] // ways
            c, h, w = layer["c"], layer["h"], layer["w"]
            x = [1, c, dsh, h, w]
            y = [1, c, dsh // 2, h // 2, w // 2]
            op = layer["op"]
            layer["fwd"] = b.emit(
                f"{name}.fwd", lambda x_, op=op: (kpool.pool3d_pallas(x_, op),), [x]
            )
            if op == "max":
                layer["bwd"] = b.emit(
                    f"{name}.bwd",
                    lambda x_, y_, dy_: (ref.maxpool3d_bwd(x_, y_, dy_),),
                    [x, y, y],
                )
            else:
                layer["bwd"] = b.emit(
                    f"{name}.bwd", lambda dy_: (ref.avgpool3d_bwd(dy_),), [y]
                )
        elif kind == "bn":
            dsh = layer["d"] // ways
            c, h, w = layer["c"], layer["h"], layer["w"]
            x = [1, c, dsh, h, w]
            cv = [c]
            layer["apply"] = b.emit(
                f"{name}.apply",
                lambda x_, m_, v_, g_, b_: (kbn.bn_leaky_pallas(x_, m_, v_, g_, b_),),
                [x, cv, cv, cv, cv],
            )

            def bwd_partials(x_, dy_, m_, v_, g_, b_):
                y_bn = ref.bn_apply(x_, m_, v_, g_, b_)
                dyb = ref.leaky_relu_bwd(y_bn, dy_)
                g1, g2 = ref.bn_bwd_partials(x_, dyb, m_, v_)
                return g1, g2

            def bwd_apply(x_, dy_, m_, v_, g_, b_, g1_, g2_, cnt_):
                y_bn = ref.bn_apply(x_, m_, v_, g_, b_)
                dyb = ref.leaky_relu_bwd(y_bn, dy_)
                return (ref.bn_bwd_apply(x_, dyb, m_, v_, g_, g1_, g2_, cnt_),)

            layer["bwd_partials"] = b.emit(
                f"{name}.bwd_partials", bwd_partials, [x, x, cv, cv, cv, cv]
            )
            layer["bwd_apply"] = b.emit(
                f"{name}.bwd_apply", bwd_apply, [x, x, cv, cv, cv, cv, cv, cv, []]
            )
        elif kind == "fc":
            fin, fout = layer["fin"], layer["fout"]
            layer["fwd"] = b.emit(
                f"{name}.fwd",
                lambda x_, w_, b_: (ref.dense(x_, w_, b_),),
                [[1, fin], [fout, fin], [fout]],
            )
            layer["bwd"] = b.emit(
                f"{name}.bwd",
                lambda x_, w_, dy_: ref.dense_bwd(x_, w_, dy_),
                [[1, fin], [fout, fin], [1, fout]],
            )
        elif kind == "mse":
            n = layer["n"]

            def mse_sum(p_, t_):
                d = p_ - t_
                return jnp.sum(d * d), 2.0 * d

            # sum-flavoured: the engine divides by (global batch x n) so the
            # distributed loss matches the fused executable exactly.
            layer["fwd_bwd"] = b.emit(f"{name}.fwd_bwd", mse_sum, [[1, n], [1, n]])
        elif kind == "xent":
            dsh = layer["d"] // ways
            k, h, w = layer["n_classes"], layer["h"], layer["w"]
            sh = [1, k, dsh, h, w]

            def xent_sum(logits, onehot):
                lse = jax.nn.logsumexp(logits, axis=1, keepdims=True)
                logp = logits - lse
                return (
                    -jnp.sum(onehot * logp),
                    jnp.exp(logp) * jnp.sum(onehot, axis=1, keepdims=True) - onehot,
                )

            layer["fwd_bwd"] = b.emit(f"{name}.fwd_bwd", xent_sum, [sh, sh])
        # flatten / act / save_skip / concat_skip are Rust-side-only layers.
        out_plan.append(layer)
    return out_plan


def _conv3d_valid(x, w, stride):
    """Fully-VALID NCDHW conv — consumes input halo-padded on all axes."""
    return jax.lax.conv_general_dilated(
        x, w, (stride,) * 3, "VALID",
        dimension_numbers=("NCDHW", "OIDHW", "NCDHW"),
    )


def _grid_shard(layer, gd, gh, gw, min_extent=1):
    """Per-axis shard extents of one layer under a (gd, gh, gw) grid.

    Fails at build time (instead of emitting zero/truncated-shape
    executables that only blow up mid-training) when a layer extent does
    not divide evenly or a shard would fall below ``min_extent`` (the
    conv halo width needs at least one interior face).
    """
    out = []
    for axis, (ext, g) in enumerate(
        zip((layer["d"], layer["h"], layer["w"]), (gd, gh, gw))
    ):
        if ext % g != 0 or ext // g < min_extent:
            raise ValueError(
                f"grid {gd}x{gh}x{gw}: layer {layer.get('tag', layer['kind'])} "
                f"extent {ext} on axis {axis} does not shard evenly into "
                f">= {min_extent}-wide pieces"
            )
        out.append(ext // g)
    return out


def emit_grid_shard_set(b: Builder, spec, grid_key: str) -> list:
    """Shard executables for a full ``dxhxw`` 3D spatial grid.

    Differs from :func:`emit_shard_set` in that every spatial dim is
    sharded and every conv is VALID along all three axes (the engine's
    sequential per-axis halo exchange supplies the padded input, with
    zero faces at the global boundary = the fused graphs' "same"
    padding).  Backward ops come from ``jax.vjp`` of the same forward, so
    they are the exact transposes by construction.
    """
    gd, gh, gw = (int(p) for p in grid_key.split("x"))
    plan = M.layer_plan(spec)
    pre = f"{spec.name}.g{grid_key}"
    out_plan = []
    for li, layer in enumerate(plan):
        layer = dict(layer)
        kind = layer["kind"]
        tag = layer.get("tag", f"l{li}")
        name = f"{pre}.{li}.{tag}"
        if kind == "conv":
            halo = (layer["k"] - 1) // 2
            cin, cout, k, st = layer["cin"], layer["cout"], layer["k"], layer["stride"]
            dsh, hsh, wsh_ = _grid_shard(layer, gd, gh, gw, max(halo, 1))
            xp = [1, cin, dsh + 2 * halo, hsh + 2 * halo, wsh_ + 2 * halo]
            dy = [1, cout, dsh // st, hsh // st, wsh_ // st]
            ws = [cout, cin, k, k, k]
            layer["halo"] = halo
            layer["fwd"] = b.emit(
                f"{name}.fwd",
                lambda x_, w_, st=st: (_conv3d_valid(x_, w_, st),),
                [xp, ws],
            )
            layer["bwd_data"] = b.emit(
                f"{name}.bwd_data",
                lambda dy_, w_, xp=tuple(xp), st=st: (
                    jax.vjp(lambda x: _conv3d_valid(x, w_, st),
                            jnp.zeros(xp, F32))[1](dy_)[0],
                ),
                [dy, ws],
            )
            layer["bwd_filter"] = b.emit(
                f"{name}.bwd_filter",
                lambda x_, dy_, ws=tuple(ws), st=st: (
                    jax.vjp(lambda w: _conv3d_valid(x_, w, st),
                            jnp.zeros(ws, F32))[1](dy_)[0],
                ),
                [xp, dy],
            )
        elif kind == "deconv":
            cin, cout = layer["cin"], layer["cout"]
            dsh, hsh, wsh_ = _grid_shard(layer, gd, gh, gw)
            x = [1, cin, dsh, hsh, wsh_]
            dy = [1, cout, dsh * 2, hsh * 2, wsh_ * 2]
            ws = [cin, cout, 2, 2, 2]
            layer["fwd"] = b.emit(
                f"{name}.fwd", lambda x_, w_: (ref.deconv3d(x_, w_),), [x, ws]
            )
            layer["bwd_data"] = b.emit(
                f"{name}.bwd_data",
                lambda dy_, w_, xs=tuple(x): (ref.deconv3d_bwd_data(dy_, w_, xs),),
                [dy, ws],
            )
            layer["bwd_filter"] = b.emit(
                f"{name}.bwd_filter",
                lambda x_, dy_, ws=tuple(ws): (ref.deconv3d_bwd_filter(x_, dy_, ws),),
                [x, dy],
            )
        elif kind == "pool":
            c = layer["c"]
            dsh, hsh, wsh_ = _grid_shard(layer, gd, gh, gw, 2)
            x = [1, c, dsh, hsh, wsh_]
            y = [1, c, dsh // 2, hsh // 2, wsh_ // 2]
            op = layer["op"]
            layer["fwd"] = b.emit(
                f"{name}.fwd", lambda x_, op=op: (kpool.pool3d_pallas(x_, op),), [x]
            )
            if op == "max":
                layer["bwd"] = b.emit(
                    f"{name}.bwd",
                    lambda x_, y_, dy_: (ref.maxpool3d_bwd(x_, y_, dy_),),
                    [x, y, y],
                )
            else:
                layer["bwd"] = b.emit(
                    f"{name}.bwd", lambda dy_: (ref.avgpool3d_bwd(dy_),), [y]
                )
        elif kind == "bn":
            c = layer["c"]
            dsh, hsh, wsh_ = _grid_shard(layer, gd, gh, gw)
            x = [1, c, dsh, hsh, wsh_]
            cv = [c]
            layer["apply"] = b.emit(
                f"{name}.apply",
                lambda x_, m_, v_, g_, b_: (kbn.bn_leaky_pallas(x_, m_, v_, g_, b_),),
                [x, cv, cv, cv, cv],
            )

            def bwd_partials(x_, dy_, m_, v_, g_, b_):
                y_bn = ref.bn_apply(x_, m_, v_, g_, b_)
                dyb = ref.leaky_relu_bwd(y_bn, dy_)
                g1, g2 = ref.bn_bwd_partials(x_, dyb, m_, v_)
                return g1, g2

            def bwd_apply(x_, dy_, m_, v_, g_, b_, g1_, g2_, cnt_):
                y_bn = ref.bn_apply(x_, m_, v_, g_, b_)
                dyb = ref.leaky_relu_bwd(y_bn, dy_)
                return (ref.bn_bwd_apply(x_, dyb, m_, v_, g_, g1_, g2_, cnt_),)

            layer["bwd_partials"] = b.emit(
                f"{name}.bwd_partials", bwd_partials, [x, x, cv, cv, cv, cv]
            )
            layer["bwd_apply"] = b.emit(
                f"{name}.bwd_apply", bwd_apply, [x, x, cv, cv, cv, cv, cv, cv, []]
            )
        elif kind == "fc":
            fin, fout = layer["fin"], layer["fout"]
            layer["fwd"] = b.emit(
                f"{name}.fwd",
                lambda x_, w_, b_: (ref.dense(x_, w_, b_),),
                [[1, fin], [fout, fin], [fout]],
            )
            layer["bwd"] = b.emit(
                f"{name}.bwd",
                lambda x_, w_, dy_: ref.dense_bwd(x_, w_, dy_),
                [[1, fin], [fout, fin], [1, fout]],
            )
        elif kind == "mse":
            n = layer["n"]

            def mse_sum(p_, t_):
                d = p_ - t_
                return jnp.sum(d * d), 2.0 * d

            layer["fwd_bwd"] = b.emit(f"{name}.fwd_bwd", mse_sum, [[1, n], [1, n]])
        elif kind == "xent":
            k = layer["n_classes"]
            dsh, hsh, wsh_ = _grid_shard(layer, gd, gh, gw)
            sh = [1, k, dsh, hsh, wsh_]

            def xent_sum(logits, onehot):
                lse = jax.nn.logsumexp(logits, axis=1, keepdims=True)
                logp = logits - lse
                return (
                    -jnp.sum(onehot * logp),
                    jnp.exp(logp) * jnp.sum(onehot, axis=1, keepdims=True) - onehot,
                )

            layer["fwd_bwd"] = b.emit(f"{name}.fwd_bwd", xent_sum, [sh, sh])
        # flatten / act / save_skip / concat_skip are Rust-side-only layers.
        out_plan.append(layer)
    return out_plan


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def build(out_dir: str, fused_models=None, hybrid_sets=None, pallas_fused=False,
          grid_sets=None):
    os.makedirs(out_dir, exist_ok=True)
    b = Builder(out_dir)
    fused_models = FUSED_MODELS if fused_models is None else fused_models
    hybrid_sets = HYBRID_SETS if hybrid_sets is None else hybrid_sets
    grid_sets = GRID_SETS if grid_sets is None else grid_sets

    models = {}
    for name in fused_models:
        spec = M.REGISTRY[name]
        stanza = {
            "kind": "cosmoflow" if isinstance(spec, M.CosmoFlowSpec) else "unet",
            "input_size": spec.input_size,
            "in_channels": spec.in_channels,
            "use_bn": spec.use_bn,
            "params": [[n, list(s)] for n, s in M.param_table(spec)],
            "bn_layers": M.bn_layer_names(spec),
            "plan": M.layer_plan(spec),
            "fused": emit_fused(b, spec, use_pallas=pallas_fused),
            "hybrid": {},
        }
        if isinstance(spec, M.CosmoFlowSpec):
            stanza["channels"] = list(spec.channels)
            stanza["fc"] = list(spec.fc)
            stanza["n_targets"] = spec.n_targets
            stanza["pool"] = spec.pool
            stanza["dropout_keep"] = spec.dropout_keep
        else:
            stanza["base_channels"] = spec.base_channels
            stanza["levels"] = spec.levels
            stanza["n_classes"] = spec.n_classes
        for ways in hybrid_sets.get(name, []):
            print(f"  shard set {name} x{ways}", file=sys.stderr)
            stanza["hybrid"][str(ways)] = emit_shard_set(b, spec, ways)
        for gk in grid_sets.get(name, []):
            print(f"  grid shard set {name} {gk}", file=sys.stderr)
            stanza["hybrid"][gk] = emit_grid_shard_set(b, spec, gk)
        models[name] = stanza
        print(f"emitted {name}", file=sys.stderr)

    manifest = {"version": 1, "entries": b.entries, "models": models}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(out_dir, "hlo_stats.json"), "w") as f:
        json.dump(b.hlo_ops, f, indent=1)
    print(f"wrote {len(b.entries)} entries to {out_dir}/manifest.json",
          file=sys.stderr)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--models", nargs="*", default=None,
                    help="subset of fused models to emit")
    ap.add_argument("--pallas-fused", action="store_true",
                    help="route fused-graph forward convs through Pallas too")
    args = ap.parse_args()
    fused = args.models
    hybrid = None if args.models is None else {
        m: HYBRID_SETS.get(m, []) for m in args.models
    }
    grids = None if args.models is None else {
        m: GRID_SETS.get(m, []) for m in args.models
    }
    build(args.out, fused, hybrid, args.pallas_fused, grids)


if __name__ == "__main__":
    main()
