//! End-to-end CosmoFlow resolution study — the functional reproduction of
//! the paper's Figs. 9 & 10 (§V-D), miniaturized per DESIGN.md §4.
//!
//! One set of "universes" is synthesized at full resolution. Three training
//! regimes see the *same* data:
//!   * full cubes (the paper's 512^3 regime — needs the largest model),
//!   * 8 sub-volumes per cube (the 256^3 analogue),
//!   * 64 sub-volumes per cube (the 128^3 analogue — the prior practice).
//! Because the `large`-scale spectral parameter only lives in full-box
//! modes, sub-volume training hits an accuracy floor; full-resolution
//! training (optionally +BN) breaks through it — the paper's
//! order-of-magnitude claim, reproduced qualitatively.
//!
//!     cargo run --release --example train_cosmoflow [-- --full --steps N]
//!
//! Default (quick) sweep: 32^3 universes -> {8^3, 16^3, 32^3(+bn)}.
//! `--full` adds the 64^3 tier (cf64), several minutes on one CPU core.
//!
//! `--io {inmem,store,store-async}` additionally runs the §III-B I/O
//! pipeline demo: the same universes written to a scratch container and
//! trained hybrid-parallel through grid-aware store ingestion + per-step
//! redistribution, checked bit-identical against the in-memory source.

use anyhow::Result;
use hydra3d::comm::{CommBackend, GradReduce};
use hydra3d::data::container::{write_dataset, Container};
use hydra3d::data::grf::{GrfConfig, GrfDataset};
use hydra3d::engine::dataparallel::{predict_batch, stack_batch, train_fused,
                                    FullSource, FusedOpts};
use hydra3d::engine::hybrid::{train_hybrid, train_hybrid_store, HybridOpts,
                              InMemorySource, IoMode};
use hydra3d::engine::LrSchedule;
use hydra3d::partition::SpatialGrid;
use hydra3d::runtime::RuntimeHandle;
use hydra3d::tensor::Tensor;
use std::sync::Arc;
use std::time::Instant;

struct Tier {
    model: &'static str,
    sub: usize, // sub-volume edge (== model input size)
    label: &'static str,
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let steps = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.parse().unwrap())
        .unwrap_or(300usize);
    let io = args
        .iter()
        .position(|a| a == "--io")
        .and_then(|i| args.get(i + 1))
        .map(|s| IoMode::parse(s))
        .transpose()?
        .unwrap_or(IoMode::InMem);

    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("train_cosmoflow: artifacts/ not built (run `make \
                  artifacts`); skipping the runtime demo");
        return Ok(());
    }
    let rt = RuntimeHandle::start(std::path::Path::new("artifacts"))?;
    let size = if full { 64 } else { 32 };
    let n_train = 24;
    let n_test = 8;
    println!("synthesizing {} universes at {size}^3 (+{n_test} test)...",
             n_train);
    let t0 = Instant::now();
    let train = GrfDataset::generate(&GrfConfig { size, seed: 11 }, n_train);
    let test = GrfDataset::generate(&GrfConfig { size, seed: 1213 }, n_test);
    println!("  synthesis took {:.1}s", t0.elapsed().as_secs_f64());

    let tiers: Vec<Tier> = if full {
        vec![
            Tier { model: "cf16", sub: 16, label: "128^3-analogue (64 sub-volumes)" },
            Tier { model: "cf32", sub: 32, label: "256^3-analogue (8 sub-volumes)" },
            Tier { model: "cf64", sub: 64, label: "512^3-analogue (full cubes)" },
            Tier { model: "cf64-bn", sub: 64, label: "512^3-analogue + BN" },
        ]
    } else {
        vec![
            Tier { model: "cf-nano", sub: 8, label: "128^3-analogue (64 sub-volumes)" },
            Tier { model: "cf16", sub: 16, label: "256^3-analogue (8 sub-volumes)" },
            Tier { model: "cf32", sub: 32, label: "512^3-analogue (full cubes)" },
            Tier { model: "cf32-bn", sub: 32, label: "512^3-analogue + BN" },
        ]
    };

    println!("\nFig. 9 (functional analogue): test MSE by training resolution");
    println!("{:<36} {:>10} {:>12} {:>9}", "regime", "test MSE", "train loss",
             "time[s]");
    let mut results = Vec::new();
    for tier in &tiers {
        let (tr_in, tr_tg) = tier_data(&train, size, tier.sub);
        let (te_in, te_tg) = tier_data(&test, size, tier.sub);
        let t0 = Instant::now();
        let info = rt.manifest().model(tier.model)?.clone();
        let opts = FusedOpts {
            model: tier.model.into(),
            groups: 1,
            batch_global: 4,
            steps,
            seed: 33,
            schedule: LrSchedule { lr0: 2e-3, floor_frac: 0.01, total_steps: steps },
            log_every: 0,
            ckpt: None,
        };
        let rep = train_fused(&rt, &opts,
                              Arc::new(FullSource { inputs: tr_in, targets: tr_tg }))?;
        // test MSE with running stats (eval mode)
        let mse = mse_of(&rt, &info, &rep, &te_in, &te_tg)?;
        let dt = t0.elapsed().as_secs_f64();
        println!("{:<36} {:>10.5} {:>12.5} {:>9.1}", tier.label, mse,
                 rep.final_loss(), dt);
        results.push((tier, rep, te_in, te_tg, mse));
    }

    // Fig. 10 analogue: per-parameter residual spread for worst vs best tier
    println!("\nFig. 10 (functional analogue): residual std per parameter");
    println!("{:<36} {:>8} {:>8} {:>8} {:>8}", "regime", "amp", "tilt",
             "large*", "cut");
    for (tier, rep, te_in, te_tg, _) in &results {
        let info = rt.manifest().model(tier.model)?.clone();
        let stds = residual_stds(&rt, &info, rep, te_in, te_tg)?;
        println!("{:<36} {:>8.4} {:>8.4} {:>8.4} {:>8.4}",
                 tier.label, stds[0], stds[1], stds[2], stds[3]);
    }
    println!("(* `large` is the H_0 analogue: it lives in full-box modes, so it\n\
              improves the most with resolution — compare rows.)");

    let worst = results.first().unwrap().4;
    let best = results.iter().map(|r| r.4).fold(f32::MAX, f32::min);
    println!("\nbest/worst test-MSE ratio: {:.1}x (paper: ~10x from 128^3 to 512^3+BN)",
             worst / best);

    if io != IoMode::InMem {
        io_pipeline_demo(&rt, io)?;
    }
    Ok(())
}

/// §III-B pipeline demo: hybrid training fed by the grid-aware store (epoch-0
/// hyperslab ingestion + per-step redistribution, `--io store-async` staged
/// behind compute) is bit-identical to the in-memory source.
fn io_pipeline_demo(rt: &RuntimeHandle, io: IoMode) -> Result<()> {
    let size = 8usize; // cf-nano input
    let ds = GrfDataset::generate(&GrfConfig { size, seed: 41 }, 8);
    let demo_steps = 6;
    let opts = HybridOpts {
        model: "cf-nano".into(),
        grid: SpatialGrid::depth(2),
        groups: 2,
        batch_global: 2,
        steps: demo_steps,
        seed: 17,
        schedule: LrSchedule { lr0: 2e-3, floor_frac: 0.1,
                               total_steps: demo_steps },
        log_every: 0,
        ckpt: None,
    };
    let inmem = train_hybrid(rt, &opts, Arc::new(InMemorySource {
        inputs: ds.inputs.clone(),
        targets: ds.targets.clone(),
    }))?;

    let mut path = std::env::temp_dir();
    path.push(format!("hydra3d-cf-io-{}", std::process::id()));
    write_dataset(&path, &ds.inputs, &ds.targets, None)?;
    let container = Arc::new(Container::open(&path)?);
    let stored = train_hybrid_store(rt, &opts, container, io,
                                    &CommBackend::Channel, GradReduce::default());
    std::fs::remove_file(&path).ok();
    let stored = stored?;

    let identical = inmem
        .params
        .iter()
        .zip(&stored.params)
        .all(|(a, b)| a.data() == b.data());
    println!(
        "\nI/O pipeline demo [{}, 2 groups x 2-way]: ingest {:.0} KiB, \
         redist {:.0} KiB, exposed {:.3}s / overlapped {:.3}s; parameters \
         bit-identical to inmem: {}",
        io.name(),
        stored.ingest_bytes as f64 / 1024.0,
        stored.redist_bytes as f64 / 1024.0,
        stored.io_exposed,
        stored.io_overlapped,
        identical,
    );
    if !identical {
        anyhow::bail!("store-backed training diverged from the in-memory source");
    }
    Ok(())
}

/// Slice a dataset into the tier's sub-volume view.
fn tier_data(ds: &GrfDataset, size: usize, sub: usize)
             -> (Vec<Tensor>, Vec<Tensor>) {
    if sub == size {
        (ds.inputs.clone(), ds.targets.clone())
    } else {
        let s = ds.split(sub);
        (s.inputs, s.targets)
    }
}

fn mse_of(
    rt: &RuntimeHandle,
    info: &hydra3d::runtime::ModelInfo,
    rep: &hydra3d::engine::TrainReport,
    inputs: &[Tensor],
    targets: &[Tensor],
) -> Result<f32> {
    hydra3d::engine::dataparallel::eval_mse(rt, info, &rep.params, &rep.running,
                                            inputs, targets)
}

fn residual_stds(
    rt: &RuntimeHandle,
    info: &hydra3d::runtime::ModelInfo,
    rep: &hydra3d::engine::TrainReport,
    inputs: &[Tensor],
    targets: &[Tensor],
) -> Result<[f32; 4]> {
    let fb = info.fused.batch;
    let mut residuals: Vec<[f64; 4]> = Vec::new();
    let mut i = 0;
    while i + fb <= inputs.len() {
        let x = stack_batch(&inputs[i..i + fb].iter().collect::<Vec<_>>());
        let pred = predict_batch(rt, info, &rep.params, &rep.running, x)?;
        for j in 0..fb {
            let mut r = [0.0f64; 4];
            for k in 0..4 {
                r[k] = (pred.data()[j * 4 + k] - targets[i + j].data()[k]) as f64;
            }
            residuals.push(r);
        }
        i += fb;
    }
    let mut out = [0.0f32; 4];
    for k in 0..4 {
        let xs: Vec<f64> = residuals.iter().map(|r| r[k]).collect();
        out[k] = hydra3d::util::stats::stddev(&xs) as f32;
    }
    Ok(out)
}
