//! Paper-scale scaling study on the simulated Lassen cluster: regenerates
//! the Fig. 4/5/6/7/8 series, then sweeps full 3D (D, H, W) spatial grids
//! — the §III-A multi-axis decomposition — and optionally writes the CI
//! bench artifact.
//!
//!     cargo run --release --example strong_scaling_sim
//!     cargo run --release --example strong_scaling_sim -- --quick --json bench_sim.json
//!
//! `--quick` skips the figure series and runs only the grid sweep (the CI
//! bench-artifact job's configuration). `--json PATH` writes the sweep as
//! `{"schema": 1, "kind": "sim", "metrics": {...}}` for `ci/bench_gate.py`:
//! per grid, the modeled step time, the exposed allreduce tail, the
//! per-sample halo volume and the per-rank redistribution volume
//! (deterministic — the regression gate's anchors).
//!
//! `--io {inmem,store,store-async}` selects the modeled ingestion pipeline
//! (the same matrix the functional `hydra3d train --io` runs): `inmem`
//! prices the conventional sample-parallel cached reader, `store` the
//! spatially-parallel store with blocking staging, `store-async` (default)
//! the paper's overlapped pipeline.

use hydra3d::config::ClusterConfig;
use hydra3d::coordinator;
use hydra3d::iosim::pipeline::{spatial_redist_bytes, IoStrategy};
use hydra3d::models::cosmoflow_paper;
use hydra3d::perfmodel::scaling::strong_scaling_grids;
use hydra3d::util::json::write_bench_json;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    // --io maps the functional pipeline modes onto the analytic strategies
    let io_name = args
        .iter()
        .position(|a| a == "--io")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("store-async");
    let io = match io_name {
        "inmem" => IoStrategy::SampleParallelCached,
        "store" => IoStrategy::SpatialParallelBlocking,
        "store-async" => IoStrategy::SpatialParallel,
        other => {
            eprintln!("unknown --io {other:?} (inmem|store|store-async)");
            std::process::exit(2);
        }
    };

    let cl = ClusterConfig::default();
    if !quick {
        std::fs::create_dir_all("runs").ok();
        print!("{}", coordinator::table1());
        println!();
        print!("{}", coordinator::table2(&cl));
        println!();
        print!("{}", coordinator::fig4(&cl));
        println!();
        print!("{}", coordinator::fig5(&cl));
        println!();
        print!("{}", coordinator::fig6(&cl, Some(std::path::Path::new("runs"))));
        println!();
        print!("{}", coordinator::fig7(&cl));
        println!();
        print!("{}", coordinator::fig8(&cl));
        println!();
    }

    // ---- 3D grid sweep: same GPU budget, different partition axes ------
    let n = 4;
    let grids: [(usize, usize, usize); 6] =
        [(8, 1, 1), (4, 2, 1), (2, 2, 2), (16, 1, 1), (4, 2, 2), (4, 4, 2)];
    let m = cosmoflow_paper(512, false);
    let sample_bytes = 4.0 * 4.0 * 512f64.powi(3); // f32 x 4ch x 512^3
    // redistribution only exists for the store-backed (spatial) pipelines
    let spatial = matches!(io, IoStrategy::SpatialParallel
                               | IoStrategy::SpatialParallelBlocking);
    let pts = strong_scaling_grids(&m, &cl, n, &grids, io);
    println!("3D spatial grid sweep: CosmoFlow 512^3, N = {n}, io = {io_name}");
    println!("  grid      GPUs   step[ms]  exposed AR[ms]  halo[MiB/sample]  \
              redist[MiB/rank]");
    for p in &pts {
        let redist = if spatial {
            format!("{:>8.2}",
                    spatial_redist_bytes(sample_bytes, p.ways)
                        / (1u64 << 20) as f64)
        } else {
            format!("{:>8}", "-")
        };
        println!(
            "  {:<9} {:>4}   {:>8.1}        {:>8.2}          {:>8.2}      \
             {}{}",
            format!("{}x{}x{}", p.grid.0, p.grid.1, p.grid.2),
            p.gpus,
            p.model_iter_s * 1e3,
            p.exposed_ar_s * 1e3,
            p.halo_bytes / (1u64 << 20) as f64,
            redist,
            if p.feasible { "" } else { "  (OOM)" },
        );
    }
    println!(
        "  (note the 8-rank grids: 2x2x2 and 4x2x1 move less halo than \
         8x1x1 — the multi-axis claim)"
    );

    if let Some(path) = json_path {
        let mut metrics: Vec<(String, f64)> = Vec::new();
        for p in &pts {
            let key = format!("sim.cf512_n{}_g{}x{}x{}", p.n, p.grid.0, p.grid.1,
                              p.grid.2);
            metrics.push((format!("{key}_step_ms"), p.model_iter_s * 1e3));
            metrics.push((format!("{key}_exposed_ar_ms"), p.exposed_ar_s * 1e3));
            metrics.push((format!("{key}_halo_bytes"), p.halo_bytes));
            if spatial {
                // per-rank, per-iteration store staging volume —
                // deterministic, exact-match-gated like the halo metrics
                metrics.push((format!("{key}_redist_bytes"),
                              spatial_redist_bytes(sample_bytes, p.ways)));
            }
        }
        write_bench_json(&path, "sim", &metrics).expect("write bench json");
        println!("wrote {path}");
    }
}
