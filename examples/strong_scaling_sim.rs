//! Paper-scale scaling study on the simulated Lassen cluster: regenerates
//! the Fig. 4/5/6/7/8 series in one run and writes Chrome traces.
//!
//!     cargo run --release --example strong_scaling_sim

use hydra3d::config::ClusterConfig;
use hydra3d::coordinator;

fn main() {
    let cl = ClusterConfig::default();
    std::fs::create_dir_all("runs").ok();
    print!("{}", coordinator::table1());
    println!();
    print!("{}", coordinator::table2(&cl));
    println!();
    print!("{}", coordinator::fig4(&cl));
    println!();
    print!("{}", coordinator::fig5(&cl));
    println!();
    print!("{}", coordinator::fig6(&cl, Some(std::path::Path::new("runs"))));
    println!();
    print!("{}", coordinator::fig7(&cl));
    println!();
    print!("{}", coordinator::fig8(&cl));
}
