//! Quickstart: load the AOT artifacts, train a tiny CosmoFlow hybrid-
//! parallel (2-way depth partitioning x 1 group) on the *traced*
//! communicator backend, evaluate, and replay the recorded communication
//! against the §III-C performance model.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use hydra3d::comm::{CommBackend, GradReduce, TraceCollector};
use hydra3d::config::ClusterConfig;
use hydra3d::data::grf::{GrfConfig, GrfDataset};
use hydra3d::engine::dataparallel::eval_mse;
use hydra3d::engine::hybrid::{train_hybrid_with, HybridOpts, InMemorySource};
use hydra3d::engine::LrSchedule;
use hydra3d::partition::SpatialGrid;
use hydra3d::perfmodel::trace::replay;
use hydra3d::perfmodel::{Link, SrModel};
use hydra3d::runtime::RuntimeHandle;
use std::sync::Arc;

fn main() -> Result<()> {
    // CI runs every example from a clean checkout; the runtime path needs
    // the AOT artifacts, so degrade to a skip instead of an error.
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("quickstart: artifacts/ not built (run `make artifacts`); \
                  skipping the runtime demo");
        return Ok(());
    }
    // 1. the PJRT runtime service: loads artifacts/manifest.json, compiles
    //    HLO-text executables lazily on first call.
    let rt = RuntimeHandle::start(std::path::Path::new("artifacts"))?;
    let info = rt.manifest().model("cf-nano")?.clone();
    println!("model cf-nano: {} params, input {}^3", info.param_count(),
             info.input_size);

    // 2. a tiny synthetic universe dataset (Gaussian random fields whose
    //    spectra encode 4 latent "cosmological parameters").
    let ds = GrfDataset::generate(&GrfConfig { size: info.input_size, seed: 1 }, 12);
    let source = Arc::new(InMemorySource {
        inputs: ds.inputs.clone(),
        targets: ds.targets.clone(),
    });

    // 3. hybrid-parallel training: 2 ranks split each sample's depth in
    //    half, halo-exchange conv boundaries, and allreduce gradients in
    //    buckets overlapped with backward. The traced backend records
    //    every message on the wire.
    let steps = 30;
    let opts = HybridOpts {
        model: "cf-nano".into(),
        grid: SpatialGrid::depth(2),
        groups: 1,
        batch_global: 2,
        steps,
        seed: 7,
        schedule: LrSchedule { lr0: 3e-3, floor_frac: 0.1, total_steps: steps },
        log_every: 10,
        ckpt: None,
    };
    let trace = Arc::new(TraceCollector::new());
    let rep = train_hybrid_with(&rt, &opts, source,
                                &CommBackend::Traced(trace.clone()),
                                GradReduce::default())?;
    println!(
        "loss {:.4} -> {:.4} over {steps} steps ({} comm bytes, \
         allreduce {:.3}s exposed / {:.3}s overlapped)",
        rep.records[0].loss,
        rep.final_loss(),
        rep.comm_bytes,
        rep.phases.allreduce,
        rep.phases.allreduce_overlapped,
    );

    // 4. replay the recorded communication against the §III-C link model:
    //    what would this exact message stream cost on Lassen's NVLink?
    let link = SrModel::from_cluster(&ClusterConfig::default(), Link::NvLink);
    let r = replay(&trace, opts.groups * opts.grid.ways(), &link);
    println!(
        "trace: {} messages / {} bytes / {} collectives -> p2p critical \
         {:.3} ms, closed-form allreduce {:.3} ms",
        r.messages,
        r.bytes,
        r.collectives,
        r.p2p_critical_secs * 1e3,
        r.allreduce_model_secs * 1e3,
    );

    // 5. evaluate with the fused predict executable.
    let mse = eval_mse(&rt, &info, &rep.params, &rep.running, &ds.inputs, &ds.targets)?;
    println!("train-set parameter MSE: {mse:.4}");
    Ok(())
}
