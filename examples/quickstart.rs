//! Quickstart: load the AOT artifacts, train a tiny CosmoFlow hybrid-
//! parallel (2-way depth partitioning x 1 group), and evaluate.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use hydra3d::data::grf::{GrfConfig, GrfDataset};
use hydra3d::engine::dataparallel::eval_mse;
use hydra3d::engine::hybrid::{train_hybrid, HybridOpts, InMemorySource};
use hydra3d::engine::LrSchedule;
use hydra3d::runtime::RuntimeHandle;
use std::sync::Arc;

fn main() -> Result<()> {
    // 1. the PJRT runtime service: loads artifacts/manifest.json, compiles
    //    HLO-text executables lazily on first call.
    let rt = RuntimeHandle::start(std::path::Path::new("artifacts"))?;
    let info = rt.manifest().model("cf-nano")?.clone();
    println!("model cf-nano: {} params, input {}^3", info.param_count(),
             info.input_size);

    // 2. a tiny synthetic universe dataset (Gaussian random fields whose
    //    spectra encode 4 latent "cosmological parameters").
    let ds = GrfDataset::generate(&GrfConfig { size: info.input_size, seed: 1 }, 12);
    let source = Arc::new(InMemorySource {
        inputs: ds.inputs.clone(),
        targets: ds.targets.clone(),
    });

    // 3. hybrid-parallel training: 2 ranks split each sample's depth in
    //    half, halo-exchange conv boundaries, and allreduce gradients.
    let steps = 30;
    let opts = HybridOpts {
        model: "cf-nano".into(),
        ways: 2,
        groups: 1,
        batch_global: 2,
        steps,
        seed: 7,
        schedule: LrSchedule { lr0: 3e-3, floor_frac: 0.1, total_steps: steps },
        log_every: 10,
    };
    let rep = train_hybrid(&rt, &opts, source)?;
    println!(
        "loss {:.4} -> {:.4} over {steps} steps ({} comm bytes)",
        rep.records[0].loss,
        rep.final_loss(),
        rep.comm_bytes
    );

    // 4. evaluate with the fused predict executable.
    let mse = eval_mse(&rt, &info, &rep.params, &rep.running, &ds.inputs, &ds.targets)?;
    println!("train-set parameter MSE: {mse:.4}");
    Ok(())
}
