//! 3D U-Net segmentation on synthetic CT volumes — the LiTS-analogue
//! workload (paper §II-C / Fig. 7's network), trained hybrid-parallel with
//! spatially partitioned labels, evaluated with per-voxel accuracy + Dice.
//!
//!     cargo run --release --example unet_segmentation [-- --io store-async]
//!
//! `--io {inmem,store,store-async}` selects the sample source: the store
//! modes write the scans to a scratch container (the "PFS") and train
//! through the §III-B pipeline — per-rank hyperslab ingestion at epoch 0
//! (the one-hot ground truth spatially distributed exactly like the input),
//! then per-step shard redistribution, optionally double-buffered behind
//! compute. The trajectory is bit-identical to the in-memory source.

use anyhow::Result;
use hydra3d::comm::{CommBackend, GradReduce};
use hydra3d::data::container::{write_label_dataset, Container};
use hydra3d::data::ct::ct_dataset;
use hydra3d::engine::dataparallel::predict_batch;
use hydra3d::engine::hybrid::{train_hybrid, train_hybrid_store, HybridOpts,
                              InMemorySource, IoMode};
use hydra3d::engine::LrSchedule;
use hydra3d::partition::SpatialGrid;
use hydra3d::runtime::RuntimeHandle;
use hydra3d::tensor::Tensor;
use std::sync::Arc;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let io = args
        .iter()
        .position(|a| a == "--io")
        .and_then(|i| args.get(i + 1))
        .map(|s| IoMode::parse(s))
        .transpose()?
        .unwrap_or(IoMode::InMem);
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("unet_segmentation: artifacts/ not built (run `make \
                  artifacts`); skipping the runtime demo");
        return Ok(());
    }
    let rt = RuntimeHandle::start(std::path::Path::new("artifacts"))?;
    let info = rt.manifest().model("unet16")?.clone();
    let size = info.input_size;
    let k = info.n_classes;
    println!("3D U-Net {size}^3, {k} classes, {} params", info.param_count());

    let (inputs, labels) = ct_dataset(size, k, 10, 99);
    let (test_in, test_lb) = ct_dataset(size, k, 4, 1234);
    let source = Arc::new(InMemorySource {
        inputs: inputs.clone(),
        targets: labels.clone(),
    });

    // hybrid-parallel: 2-way depth split (pass a 3D grid, e.g.
    // SpatialGrid::new(2, 2, 2), once the grid shard set is built); the
    // one-hot ground truth is spatially partitioned exactly like the input
    // (paper §III-B: "we also spatially distribute the ground-truth
    // segmentation").
    let steps = 40;
    let opts = HybridOpts {
        model: "unet16".into(),
        grid: SpatialGrid::depth(2),
        groups: 1,
        batch_global: 2,
        steps,
        seed: 5,
        schedule: LrSchedule { lr0: 2e-3, floor_frac: 0.1, total_steps: steps },
        log_every: 10,
        ckpt: None,
    };
    let rep = match io {
        IoMode::InMem => train_hybrid(&rt, &opts, source)?,
        IoMode::Store | IoMode::StoreAsync => {
            let mut path = std::env::temp_dir();
            path.push(format!("hydra3d-unet-io-{}", std::process::id()));
            write_label_dataset(&path, &inputs, &labels)?;
            let container = Arc::new(Container::open(&path)?);
            let rep = train_hybrid_store(&rt, &opts, container, io,
                                         &CommBackend::Channel,
                                         GradReduce::default());
            std::fs::remove_file(&path).ok();
            let rep = rep?;
            println!(
                "io [{}]: ingest {:.0} KiB, redist {:.0} KiB, exposed {:.3}s \
                 / overlapped {:.3}s",
                io.name(),
                rep.ingest_bytes as f64 / 1024.0,
                rep.redist_bytes as f64 / 1024.0,
                rep.io_exposed,
                rep.io_overlapped,
            );
            rep
        }
    };
    println!("loss {:.4} -> {:.4}", rep.records[0].loss, rep.final_loss());

    // evaluate: per-voxel accuracy and mean Dice over the test scans
    let fb = info.fused.batch;
    let vol = size * size * size;
    let (mut correct, mut total) = (0usize, 0usize);
    let mut dice_acc = 0.0f64;
    let mut i = 0;
    while i + fb <= test_in.len() {
        let x = hydra3d::engine::dataparallel::stack_batch(
            &test_in[i..i + fb].iter().collect::<Vec<_>>(),
        );
        let logits = predict_batch(&rt, &info, &rep.params, &rep.running, x)?;
        for j in 0..fb {
            let truth = argmax_labels(&test_lb[i + j], k, vol);
            let pred = argmax_logits(&logits, j, k, vol);
            let mut inter = vec![0usize; k];
            let mut pc = vec![0usize; k];
            let mut tc = vec![0usize; k];
            for v in 0..vol {
                if pred[v] == truth[v] {
                    correct += 1;
                    inter[pred[v]] += 1;
                }
                pc[pred[v]] += 1;
                tc[truth[v]] += 1;
                total += 1;
            }
            let dice: f64 = (0..k)
                .map(|c| {
                    let den = pc[c] + tc[c];
                    if den == 0 { 1.0 } else { 2.0 * inter[c] as f64 / den as f64 }
                })
                .sum::<f64>()
                / k as f64;
            dice_acc += dice;
        }
        i += fb;
    }
    let n_eval = i;
    println!(
        "test voxel accuracy {:.1}%  mean Dice {:.3} over {} scans",
        100.0 * correct as f64 / total as f64,
        dice_acc / n_eval as f64,
        n_eval
    );
    Ok(())
}

fn argmax_labels(onehot: &Tensor, k: usize, vol: usize) -> Vec<usize> {
    (0..vol)
        .map(|v| (0..k).max_by(|&a, &b| {
            onehot.data()[a * vol + v]
                .partial_cmp(&onehot.data()[b * vol + v])
                .unwrap()
        }).unwrap())
        .collect()
}

fn argmax_logits(logits: &Tensor, j: usize, k: usize, vol: usize) -> Vec<usize> {
    let base = j * k * vol;
    (0..vol)
        .map(|v| (0..k).max_by(|&a, &b| {
            logits.data()[base + a * vol + v]
                .partial_cmp(&logits.data()[base + b * vol + v])
                .unwrap()
        }).unwrap())
        .collect()
}
