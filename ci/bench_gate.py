#!/usr/bin/env python3
"""Merge bench JSON dumps into BENCH_PR.json and gate against a baseline.

Usage:
    bench_gate.py --out BENCH_PR.json --baseline BENCH_baseline.json \
        [--tolerance 0.15] input1.json [input2.json ...]

Every input is `{"schema": 1, "kind": ..., "metrics": {name: number}}`
(written by `cargo bench --bench micro -- --json` and
`examples/strong_scaling_sim --json`; metric names are already namespaced
`micro.*` / `sim.*`). The merged metrics are written to --out, which CI
uploads as a workflow artifact on every PR.

Gate rules, per metric present in BOTH the PR run and the baseline:

* `*_bytes` / `*_count` metrics are deterministic (model-derived halo
  volumes, store ingest/redistribution volumes, message counts, pool-miss
  counts): any difference fails — a structural change must update the
  baseline intentionally.
* `*_per_sec` / `*_x` metrics are throughputs / speedup ratios
  (higher is better): fail when PR < baseline * (1 - tol).
* other numeric metrics are timings: fail when PR > baseline * (1 + tol).
  Improvements and metrics missing from the baseline are reported only, so
  freshly added benches don't gate until the baseline is refreshed (copy a
  BENCH_PR.json from a quiet machine over BENCH_baseline.json).

With `--strict-bytes`, a deterministic (`*_bytes` / `*_count`) metric
present on only ONE side also fails — a new counter must land together
with its baseline value, and a counter a bench stops emitting must be
removed from the baseline — so byte counters can never silently skip the
exact-match gate in either direction.

Exit status 1 on any gate failure. Stdlib only.

`bench_gate.py --self-test` runs an offline fixture suite over the gate
rules themselves (exact-match bytes, throughput floors, timing ceilings,
strict-bytes in both directions, record-only fallbacks) so CI proves the
gate still fires before trusting a green gate run.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile


def load_metrics(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != 1:
        raise SystemExit(f"{path}: unsupported schema {doc.get('schema')!r}")
    metrics = doc.get("metrics", {})
    bad = [k for k, v in metrics.items() if not isinstance(v, (int, float))]
    if bad:
        raise SystemExit(f"{path}: non-numeric metrics {bad}")
    return metrics


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", required=True, help="merged BENCH_PR.json path")
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="allowed relative timing regression (default 0.15)")
    ap.add_argument("--strict-bytes", action="store_true",
                    help="fail on deterministic (*_bytes/*_count) PR metrics "
                         "that have no baseline entry")
    ap.add_argument("inputs", nargs="+", help="bench JSON dumps to merge")
    args = ap.parse_args()

    merged: dict = {}
    for path in args.inputs:
        for key, val in load_metrics(path).items():
            if key in merged:
                raise SystemExit(f"duplicate metric {key!r} (from {path})")
            merged[key] = val
    with open(args.out, "w") as f:
        json.dump({"schema": 1, "kind": "pr", "metrics": merged}, f,
                  indent=1, sort_keys=True)
    print(f"wrote {args.out} ({len(merged)} metrics)")

    try:
        with open(args.baseline) as f:
            base_doc = json.load(f)
    except FileNotFoundError:
        print(f"NOTE: no baseline at {args.baseline}; gate is record-only")
        return 0
    base = base_doc.get("metrics", {})

    failures = []
    gated = 0
    for key in sorted(merged):
        exact = key.endswith("_bytes") or key.endswith("_count")
        if key not in base:
            if exact and args.strict_bytes:
                failures.append(
                    f"{key}: deterministic metric has no baseline entry "
                    f"(add its exact value to {args.baseline})")
                print(f"  [FAIL] {key} = {merged[key]:g} (no baseline entry)")
            else:
                print(f"  (new)    {key} = {merged[key]:g}")
            continue
        pr, bl = merged[key], base[key]
        gated += 1
        higher_better = key.endswith("_per_sec") or key.endswith("_x")
        if exact:
            status = "ok" if pr == bl else "FAIL"
            if pr != bl:
                failures.append(
                    f"{key}: {pr:g} != baseline {bl:g} (deterministic metric "
                    f"changed — update BENCH_baseline.json if intentional)")
        elif higher_better:
            floor = bl * (1.0 - args.tolerance)
            status = "ok" if pr >= floor else "FAIL"
            if pr < floor:
                failures.append(
                    f"{key}: {pr:g} < baseline {bl:g} "
                    f"(-{(1.0 - pr / bl) * 100.0:.1f}% below the "
                    f"{args.tolerance * 100.0:.0f}% budget; higher is better)")
        else:
            limit = bl * (1.0 + args.tolerance)
            status = "ok" if pr <= limit else "FAIL"
            if pr > limit:
                failures.append(
                    f"{key}: {pr:g} > baseline {bl:g} "
                    f"(+{(pr / bl - 1.0) * 100.0:.1f}% > "
                    f"{args.tolerance * 100.0:.0f}% budget)")
        print(f"  [{status:>4}] {key}: pr {pr:g} vs baseline {bl:g}")
    for key in sorted(set(base) - set(merged)):
        if (key.endswith("_bytes") or key.endswith("_count")) and args.strict_bytes:
            failures.append(
                f"{key}: deterministic baseline metric missing from the PR run "
                f"(bench stopped emitting it — remove it from {args.baseline} "
                f"if intentional)")
            print(f"  [FAIL] {key} only in baseline")
        else:
            print(f"  (gone)   {key} only in baseline")

    print(f"gated {gated} metrics against {args.baseline}")
    if failures:
        print("\nbench regression gate FAILED:", file=sys.stderr)
        for f_ in failures:
            print(f"  - {f_}", file=sys.stderr)
        return 1
    return 0


def _run(argv: list) -> int:
    """Invoke main() with a substitute argv, mapping SystemExit to a code."""
    saved = sys.argv
    sys.argv = ["bench_gate.py"] + argv
    try:
        return main()
    except SystemExit as e:  # load_metrics rejects bad inputs this way
        return 1 if isinstance(e.code, str) else int(e.code or 0)
    finally:
        sys.argv = saved


def self_test() -> int:
    """Fixture suite: every gate rule must fire (and only when it should)."""

    def dump(d: str, name: str, metrics: dict, schema: int = 1) -> str:
        path = os.path.join(d, name)
        with open(path, "w") as f:
            json.dump({"schema": schema, "kind": "t", "metrics": metrics}, f)
        return path

    cases = []  # (label, expected_exit, actual_exit)
    with tempfile.TemporaryDirectory(prefix="bench_gate_selftest.") as d:
        out = os.path.join(d, "PR.json")

        def gate(metrics, baseline, *extra) -> int:
            inp = dump(d, "in.json", metrics)
            base = dump(d, "base.json", baseline)
            return _run(["--out", out, "--baseline", base, *extra, inp])

        cases.append(("identical metrics pass",
                      0, gate({"a.step_time_us": 10.0}, {"a.step_time_us": 10.0})))
        cases.append(("timing within budget passes",
                      0, gate({"a_us": 11.0}, {"a_us": 10.0})))
        cases.append(("timing regression fails",
                      1, gate({"a_us": 12.0}, {"a_us": 10.0})))
        cases.append(("timing improvement passes",
                      0, gate({"a_us": 5.0}, {"a_us": 10.0})))
        cases.append(("throughput drop fails (higher is better)",
                      1, gate({"a_per_sec": 8.0}, {"a_per_sec": 10.0})))
        cases.append(("throughput gain passes",
                      0, gate({"a_per_sec": 20.0}, {"a_per_sec": 10.0})))
        cases.append(("deterministic bytes off-by-one fails",
                      1, gate({"a_bytes": 101.0}, {"a_bytes": 100.0})))
        cases.append(("deterministic count must match exactly",
                      1, gate({"a_count": 3}, {"a_count": 2})))
        cases.append(("new timing metric is record-only",
                      0, gate({"a_us": 9.0, "b_us": 1.0}, {"a_us": 9.0})))
        cases.append(("new bytes metric passes without --strict-bytes",
                      0, gate({"b_bytes": 7.0}, {})))
        cases.append(("new bytes metric fails under --strict-bytes",
                      1, gate({"b_bytes": 7.0}, {}, "--strict-bytes")))
        cases.append(("vanished baseline bytes fails under --strict-bytes",
                      1, gate({}, {"b_bytes": 7.0}, "--strict-bytes")))
        cases.append(("vanished baseline timing is report-only",
                      0, gate({}, {"b_us": 7.0}, "--strict-bytes")))

        inp = dump(d, "in.json", {"a_us": 1.0})
        cases.append(("missing baseline file is record-only", 0, _run(
            ["--out", out, "--baseline", os.path.join(d, "nope.json"), inp])))

        dup1 = dump(d, "dup1.json", {"a_us": 1.0})
        dup2 = dump(d, "dup2.json", {"a_us": 2.0})
        base = dump(d, "base.json", {})
        cases.append(("duplicate metric across inputs is rejected", 1, _run(
            ["--out", out, "--baseline", base, dup1, dup2])))

        bad = dump(d, "bad.json", {"a_us": 1.0}, schema=2)
        cases.append(("unsupported schema is rejected", 1, _run(
            ["--out", out, "--baseline", base, bad])))

        nonnum = os.path.join(d, "nonnum.json")
        with open(nonnum, "w") as f:
            json.dump({"schema": 1, "metrics": {"a_us": "fast"}}, f)
        cases.append(("non-numeric metric is rejected", 1, _run(
            ["--out", out, "--baseline", base, nonnum])))

    bad_cases = [(label, want, got) for label, want, got in cases if want != got]
    print(f"\nbench_gate --self-test: {len(cases) - len(bad_cases)}/{len(cases)} "
          f"cases behaved as expected")
    for label, want, got in bad_cases:
        print(f"  SELF-TEST FAIL: {label}: expected exit {want}, got {got}",
              file=sys.stderr)
    return 1 if bad_cases else 0


if __name__ == "__main__":
    if "--self-test" in sys.argv[1:]:
        sys.exit(self_test())
    sys.exit(main())
