#!/usr/bin/env python3
"""Forbid allocating tensor-op forms inside the engines' step loops.

The training hot path (PR "zero-alloc hot path") routes every per-step
tensor movement through the buffer pool: `slice_ax_into`, `pad_ax_into`
and `block3_into` write into pooled storage instead of allocating. The
allocating originals (`slice_ax`, `pad_ax`, `block3`) are still the right
call at setup time, but inside `run_rank` / `run_group` step loops they
reintroduce a per-step allocation that the steady-state pool-miss bench
gate was built to keep at zero.

This lint brace-matches the bodies of `fn run_rank` and `fn run_group` in
rust/src/engine/*.rs, then the `for step in ...` / `for _step in ...`
loops inside them, and fails the build if an allocating form with a
pooled `_into` variant appears there. Suppress a deliberate use with a
`// hot-path-lint: allow` comment on the same line.

Usage: python3 ci/hot_path_lint.py [engine_dir]

`hot_path_lint.py --self-test` lints a synthetic engine fixture with known
violations, suppressions and decoys, and fails unless the lint flags
exactly the planted lines — so CI proves the lint still fires before
trusting a clean run over the real engines.
"""

import re
import sys
import tempfile
from pathlib import Path

# Allocating forms that have a pooled `_into` counterpart in tensor/.
# (`crop_ax` has no `_into` variant yet, so it is not banned.)
BANNED = ["slice_ax", "pad_ax", "block3"]
HOT_FNS = ["run_rank", "run_group"]
SUPPRESS = "hot-path-lint: allow"


def strip_noncode(line: str) -> str:
    """Drop line comments and string literals so the patterns only match
    code. (Block comments in these files are line-leading `//!`/`///`;
    this is a lint, not a parser.)"""
    line = re.sub(r'"(?:[^"\\]|\\.)*"', '""', line)
    return line.split("//")[0]


def match_block(text: str, open_idx: int) -> int:
    """Index one past the `}` matching the `{` at open_idx."""
    depth = 0
    for i in range(open_idx, len(text)):
        c = text[i]
        if c == "{":
            depth += 1
        elif c == "}":
            depth -= 1
            if depth == 0:
                return i + 1
    raise ValueError(f"unbalanced braces from offset {open_idx}")


def fn_body_span(text: str, name: str):
    """(start, end) offsets of `fn <name>`'s body, or None."""
    m = re.search(rf"\bfn\s+{name}\b", text)
    if not m:
        return None
    open_idx = text.index("{", m.end())
    return open_idx, match_block(text, open_idx)


def step_loop_spans(text: str, lo: int, hi: int):
    """Spans of `for step in ...` / `for _step in ...` bodies in [lo, hi)."""
    spans = []
    for m in re.finditer(r"\bfor\s+_?step\b[^{]*", text[lo:hi]):
        open_idx = text.index("{", lo + m.end() - 1)
        spans.append((open_idx, match_block(text, open_idx)))
    return spans


def lint_file(path: Path):
    text = path.read_text()
    violations = []
    for fn in HOT_FNS:
        span = fn_body_span(text, fn)
        if span is None:
            continue
        for lo, hi in step_loop_spans(text, *span):
            body = text[lo:hi]
            base_line = text[:lo].count("\n") + 1
            for off, raw in enumerate(body.splitlines()):
                if SUPPRESS in raw:
                    continue
                code = strip_noncode(raw)
                for op in BANNED:
                    if re.search(rf"\.{op}\(", code):
                        violations.append(
                            (path, base_line + off, fn, op, raw.strip())
                        )
    return violations


def main() -> int:
    engine_dir = Path(sys.argv[1] if len(sys.argv) > 1 else "rust/src/engine")
    files = sorted(engine_dir.glob("*.rs"))
    if not files:
        print(f"hot_path_lint: no .rs files under {engine_dir}", file=sys.stderr)
        return 2
    violations = []
    for f in files:
        violations.extend(lint_file(f))
    if violations:
        print("hot_path_lint: allocating tensor ops inside step loops:")
        for path, line, fn, op, snippet in violations:
            print(
                f"  {path}:{line}: `.{op}(` in {fn}'s step loop — use "
                f"`{op}_into` with a pooled buffer ({snippet})"
            )
        print(
            f"\n{len(violations)} violation(s). If the allocation is "
            f"deliberate, mark the line with `// {SUPPRESS}`."
        )
        return 1
    checked = ", ".join(f.name for f in files)
    print(f"hot_path_lint: ok ({checked})")
    return 0


FIXTURE = """\
fn setup() {
    let a = x.slice_ax(0, 1, 2); // allocating at setup time is fine
}

fn run_rank() {
    let warm = x.pad_ax(0, 1, 1); // outside the step loop: fine
    for step in start..steps {
        let bad1 = x.slice_ax(0, 1, 2);
        let ok1 = x.slice_ax_into(&mut buf, 0, 1, 2);
        let ok2 = x.pad_ax(0, 1, 1); // hot-path-lint: allow
        // commented: x.block3(2) should not fire
        let s = "call .block3( inside a string";
        if deep {
            let bad2 = y.block3(2);
        }
    }
}

fn run_group() {
    for _step in 0..n {
        let bad3 = z.pad_ax(1, 2, 2);
    }
}
"""

# (line, fn, op) triples the fixture plants; the lint must find these and
# nothing else. Lines are 1-based within FIXTURE.
PLANTED = [(8, "run_rank", "slice_ax"), (14, "run_rank", "block3"),
           (21, "run_group", "pad_ax")]


def self_test() -> int:
    with tempfile.TemporaryDirectory(prefix="hot_path_lint_selftest.") as d:
        f = Path(d) / "fake_engine.rs"
        f.write_text(FIXTURE)
        got = [(line, fn, op) for _, line, fn, op, _ in lint_file(f)]
    if sorted(got) == sorted(PLANTED):
        print(f"hot_path_lint --self-test: ok "
              f"({len(PLANTED)} planted violations flagged, decoys ignored)")
        return 0
    print("hot_path_lint --self-test FAILED:", file=sys.stderr)
    print(f"  expected {sorted(PLANTED)}", file=sys.stderr)
    print(f"  got      {sorted(got)}", file=sys.stderr)
    return 1


if __name__ == "__main__":
    if "--self-test" in sys.argv[1:]:
        sys.exit(self_test())
    sys.exit(main())
