//! `cargo bench --bench fig7_unet_strong` — 3D U-Net 256^3 strong scaling
//! (paper Fig. 7).
use hydra3d::config::ClusterConfig;
use hydra3d::coordinator::fig7;
use hydra3d::util::bench::banner;

fn main() {
    banner("Fig. 7 — 3D U-Net strong scaling");
    print!("{}", fig7(&ClusterConfig::default()));
}
