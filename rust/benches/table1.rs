//! `cargo bench --bench table1` — regenerate paper Table I.
use hydra3d::coordinator::table1;
use hydra3d::util::bench::{banner, Bench};

fn main() {
    banner("Table I — CosmoFlow architecture analytics");
    print!("{}", table1());
    let mut b = Bench::quick();
    b.run("table1 generation", || {
        std::hint::black_box(table1());
    });
}
