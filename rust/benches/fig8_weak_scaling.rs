//! `cargo bench --bench fig8_weak_scaling` — weak scaling of both networks
//! (paper Fig. 8).
use hydra3d::config::ClusterConfig;
use hydra3d::coordinator::fig8;
use hydra3d::util::bench::banner;

fn main() {
    banner("Fig. 8 — weak scaling");
    print!("{}", fig8(&ClusterConfig::default()));
}
