//! `cargo bench --bench table2` — regenerate paper Table II (achieved conv
//! performance vs cuDNN kernel peak).
use hydra3d::config::ClusterConfig;
use hydra3d::coordinator::table2;
use hydra3d::util::bench::{banner, Bench};

fn main() {
    let cl = ClusterConfig::default();
    banner("Table II — distributed conv vs kernel-only peak");
    print!("{}", table2(&cl));
    let mut b = Bench::quick();
    b.run("table2 generation", || {
        std::hint::black_box(table2(&cl));
    });
}
