//! `cargo bench --bench fig5_io_ablation` — strong scaling without
//! spatially-parallel I/O (paper Fig. 5).
use hydra3d::config::ClusterConfig;
use hydra3d::coordinator::fig5;
use hydra3d::util::bench::banner;

fn main() {
    banner("Fig. 5 — I/O ablation");
    print!("{}", fig5(&ClusterConfig::default()));
}
