//! `cargo bench --bench micro` — hot-path micro-benchmarks for the L3
//! performance pass (DESIGN.md §7): halo pack/unpack bandwidth, 3D grid
//! halo exchange, ring allreduce throughput, bucketed-overlap exposed
//! time, container hyperslab reads, and PJRT call overhead. Before/after
//! numbers are recorded in EXPERIMENTS.md §Perf.
//!
//! Pass `--quick` (or set `HYDRA3D_BENCH_QUICK=1`) for the CI smoke mode:
//! same code paths, much shorter measurement windows. Pass `--json PATH`
//! to dump every measurement (plus the exposed-allreduce numbers) as
//! `{"schema": 1, "kind": "micro", "metrics": {...}}` for the CI
//! bench-artifact gate (`ci/bench_gate.py`).

use hydra3d::comm::{
    allreduce_sum_hier, halo, socket_world, world, BucketPlan, Communicator,
    OverlapAllreduce,
};
use hydra3d::data::container::{write_dataset, Container};
use hydra3d::iosim::store::{assignments_of, AsyncStaging, DataStore};
use hydra3d::partition::{GridTopology, SpatialGrid};
use hydra3d::runtime::RuntimeHandle;
use hydra3d::tensor::pool::BufferPool;
use hydra3d::tensor::Tensor;
use hydra3d::util::bench::{banner, Bench};
use hydra3d::util::json::write_bench_json;
use hydra3d::util::rng::Pcg;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick")
        || std::env::var("HYDRA3D_BENCH_QUICK")
            .is_ok_and(|v| !v.is_empty() && v != "0");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let mut b = if quick { Bench::quick() } else { Bench::default() };
    if quick {
        println!("(quick mode: short measurement windows)");
    }
    let pack_us = halo_pack(&mut b);
    let grid_halo_bytes = halo_grid(&mut b, quick);
    let stp = step_throughput(&mut b, quick);
    allreduce(&mut b, quick);
    let (mono_us, buck_us) = overlap(&mut b, quick);
    let (ring_frame_bytes, hier_frame_bytes) = socket_frames(&mut b, quick);
    let stg = staging(&mut b, quick);
    container_reads(&mut b);
    pjrt_overhead(&mut b);

    if let Some(path) = json_path {
        let mut metrics: Vec<(String, f64)> = b
            .results()
            .iter()
            .map(|m| (format!("micro.{}_s", slug(&m.name)), m.median))
            .collect();
        metrics.push(("micro.exposed_allreduce_mono_us".into(), mono_us));
        metrics.push(("micro.exposed_allreduce_bucketed_us".into(), buck_us));
        metrics.push(("micro.staging_blocking_us".into(), stg.blocking_us));
        metrics.push(("micro.staging_async_exposed_us".into(), stg.exposed_us));
        metrics.push(("micro.halo_pack_us".into(), pack_us));
        metrics.push(("micro.step_fresh_time_us".into(), stp.fresh_us));
        metrics.push(("micro.step_time_us".into(), stp.pooled_us));
        metrics.push(("micro.step_samples_per_sec".into(), stp.samples_per_sec));
        // `_x` suffix: ci/bench_gate.py gates ratio metrics as
        // higher-is-better (floor at baseline * (1 - tol)). Measuring both
        // lanes in one process makes the ratio robust to machine speed.
        metrics.push(("micro.step_pooled_speedup_x".into(), stp.speedup_x));
        // `_bytes` / `_count` suffixes: ci/bench_gate.py gates deterministic
        // metrics with exact equality, not the 15% timing budget.
        metrics.push(("micro.grid_halo_round_bytes".into(),
                      grid_halo_bytes as f64));
        metrics.push(("micro.step_halo_bytes".into(),
                      stp.halo_step_bytes as f64));
        metrics.push(("micro.step_steady_pool_miss_count".into(),
                      stp.steady_misses as f64));
        metrics.push(("micro.store_redist_step_bytes".into(),
                      stg.redist_step_bytes as f64));
        metrics.push(("micro.store_ingest_bytes".into(),
                      stg.ingest_bytes as f64));
        metrics.push(("micro.socket_ring_frame_bytes".into(),
                      ring_frame_bytes as f64));
        metrics.push(("micro.socket_hier_frame_bytes".into(),
                      hier_frame_bytes as f64));
        write_bench_json(&path, "micro", &metrics).expect("write bench json");
        println!("\nwrote {path}");
    }
}

/// Lowercase, alphanumeric + underscores — stable JSON metric keys.
fn slug(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    let mut last_us = false;
    for c in name.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c.to_ascii_lowercase());
            last_us = false;
        } else if !last_us && !out.is_empty() {
            out.push('_');
            last_us = true;
        }
    }
    out.trim_end_matches('_').to_string()
}

/// Halo pack/unpack = depth-slab copies into preallocated buffers (the
/// paper's optimized CUDA packing kernels; ours must stay memcpy-bound and,
/// post-pool, allocation-free). Returns the pack median in microseconds.
fn halo_pack(b: &mut Bench) -> f64 {
    banner("halo pack/unpack (slab copies, preallocated buffers)");
    // conv2-of-cf64-like shard: 32 ch x 16 planes x 64 x 64
    let t = Tensor::zeros(&[1, 32, 16, 64, 64]);
    let halo_bytes = (32 * 64 * 64 * 4) as f64;
    let mut face = vec![0.0f32; 32 * 64 * 64];
    let m = b.run("slice_ax_into 1-plane halo (32x64x64)", || {
        t.slice_ax_into(2, 0, 1, std::hint::black_box(&mut face));
    });
    let pack_us = m.median * 1e6;
    println!("   -> pack bandwidth {:.2} GB/s", halo_bytes / m.median / 1e9);
    let mut padded = t.pad_ax(2, 1, 1);
    let m = b.run("set_slice_ax_from 1-plane halo", || {
        padded.set_slice_ax_from(2, 0, 1, std::hint::black_box(&face));
    });
    println!("   -> unpack bandwidth {:.2} GB/s", halo_bytes / m.median / 1e9);
    let mut pad_out = Tensor::zeros(&[1, 32, 18, 64, 64]);
    let m = b.run("pad_ax_into full shard (+2 planes)", || {
        t.pad_ax_into(2, 1, 1, std::hint::black_box(&mut pad_out));
    });
    println!("   -> pad bandwidth {:.2} GB/s", (t.numel() * 4) as f64 / m.median / 1e9);
    let mut acc = t.clone();
    b.run("add_slice_ax_from (reverse-halo accumulate)", || {
        acc.add_slice_ax_from(2, 0, 1, std::hint::black_box(&face));
    });
    pack_us
}

/// Full 3D halo exchange (2x2x2 grid, 8 thread-ranks): one forward +
/// backward round per iteration, sequential per-axis faces. Returns the
/// world-wide halo bytes of one forward+backward round (deterministic).
fn halo_grid(b: &mut Bench, quick: bool) -> u64 {
    banner("3D grid halo exchange (2x2x2, 8 thread-ranks)");
    let grid = SpatialGrid::new(2, 2, 2);
    let topo = GridTopology::new(1, grid);
    let shard = Tensor::zeros(&[1, 8, 8, 8, 8]);
    let iters = if quick { 3 } else { 10 };
    let eps0 = world(grid.ways());
    let counters = eps0[0].counters().clone();
    let m = b.run_once("grid halo fwd+bwd (8ch 8^3 shards)", || {
        std::thread::scope(|s| {
            for (r, ep) in eps0.into_iter().enumerate() {
                let nbrs = topo.neighbors(r);
                let shard = shard.clone();
                s.spawn(move || {
                    for _ in 0..iters {
                        let p = halo::exchange_forward_grid(&ep, &shard, 1, &nbrs,
                                                            [true, true, true],
                                                            None)
                            .unwrap();
                        halo::exchange_backward_grid(&ep, p, 1, &nbrs,
                                                     [true, true, true], None)
                            .unwrap();
                    }
                });
            }
        });
    });
    let bytes = counters.halo_bytes_axes();
    let per_round: u64 = bytes.iter().sum::<u64>() / iters as u64;
    println!(
        "   -> {:.1} us/round, {} halo B/round (D/H/W {}/{}/{})",
        m.median / iters as f64 * 1e6,
        per_round,
        bytes[0] / iters as u64,
        bytes[1] / iters as u64,
        bytes[2] / iters as u64,
    );
    per_round
}

struct StepNumbers {
    /// Per-step wall time of the fresh-allocation lane (sequential
    /// per-axis exchange, allocating element-wise ops, per-step gradient
    /// buffers), microseconds.
    fresh_us: f64,
    /// Per-step wall time of the pooled lane (fused grid exchange, pooled
    /// `_into` ops, hoisted gradient buffers), microseconds.
    pooled_us: f64,
    /// Samples/sec of the pooled lane (the 8-rank group advances one
    /// sample per step).
    samples_per_sec: f64,
    /// fresh_us / pooled_us — both lanes run in the same process on the
    /// same machine, so this ratio is robust to absolute machine speed.
    speedup_x: f64,
    /// World-wide halo bytes of one pooled step (deterministic:
    /// 3 layers x fwd+bwd x 8 ranks x one face per axis each).
    halo_step_bytes: u64,
    /// Pool misses summed over ranks after the warm-up step — steady-state
    /// steps must run entirely from recycled buffers, i.e. exactly 0.
    steady_misses: u64,
}

/// Training-step skeleton on the hybrid 2x2x2 grid (8 thread-ranks,
/// (1,8,32,32,32) shards, halo 1, 3 conv-like layers fwd+bwd): the
/// pre-pool idiom (per-axis exchange composition + fresh allocations every
/// step) vs the pooled hot path the engine now runs (fused grid exchange +
/// per-rank `BufferPool` + hoisted gradient buffers). Gates the PR's
/// zero-alloc claim: steady-state pool misses must be 0 and the speedup
/// ratio must clear the baseline floor.
fn step_throughput(b: &mut Bench, quick: bool) -> StepNumbers {
    banner("hybrid step skeleton: fresh allocations vs pooled (2x2x2)");
    let grid = SpatialGrid::new(2, 2, 2);
    let topo = GridTopology::new(1, grid);
    let shard_shape = [1usize, 8, 32, 32, 32];
    let layers = 3usize;
    let n_params = 4usize;
    let param_len = 1usize << 15;
    // +1 warm-up step in both lanes (the pooled lane's pool fills there).
    let steps = 1 + if quick { 3 } else { 8 };
    let axes = [true, true, true];

    // ---- fresh lane: the pre-pool idiom ---------------------------------
    let mut fresh_secs = 0.0f64;
    let eps_f = world(grid.ways());
    b.run_once("step fresh (per-axis halo + per-step allocs)", || {
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for (r, ep) in eps_f.into_iter().enumerate() {
                let nbrs = topo.neighbors(r);
                s.spawn(move || {
                    let mut x = Tensor::zeros(&shard_shape);
                    for _ in 0..steps {
                        for _ in 0..layers {
                            let p = halo::exchange_forward_axis(
                                &ep, &x, 2, 1, nbrs.lo[0], nbrs.hi[0]).unwrap();
                            let p = halo::exchange_forward_axis(
                                &ep, &p, 3, 1, nbrs.lo[1], nbrs.hi[1]).unwrap();
                            let p = halo::exchange_forward_axis(
                                &ep, &p, 4, 1, nbrs.lo[2], nbrs.hi[2]).unwrap();
                            let act = p.leaky_relu(0.01);
                            let d = halo::exchange_backward_axis(
                                &ep, &act, 4, 1, nbrs.lo[2], nbrs.hi[2]).unwrap();
                            let d = halo::exchange_backward_axis(
                                &ep, &d, 3, 1, nbrs.lo[1], nbrs.hi[1]).unwrap();
                            x = halo::exchange_backward_axis(
                                &ep, &d, 2, 1, nbrs.lo[0], nbrs.hi[0]).unwrap();
                        }
                        let grads: Vec<Tensor> = (0..n_params)
                            .map(|_| Tensor::zeros(&[param_len]))
                            .collect();
                        std::hint::black_box(&grads);
                    }
                    std::hint::black_box(x.numel());
                });
            }
        });
        fresh_secs = t0.elapsed().as_secs_f64();
    });
    let fresh_us = fresh_secs / steps as f64 * 1e6;

    // ---- pooled lane: the engine's zero-alloc hot path ------------------
    let mut pooled_secs = 0.0f64;
    let mut steady_misses = 0u64;
    let eps_p = world(grid.ways());
    let counters = eps_p[0].counters().clone();
    b.run_once("step pooled (fused halo + buffer pool)", || {
        let t0 = Instant::now();
        let misses: Vec<u64> = std::thread::scope(|s| {
            let hs: Vec<_> = eps_p
                .into_iter()
                .enumerate()
                .map(|(r, ep)| {
                    let nbrs = topo.neighbors(r);
                    s.spawn(move || {
                        let pool = BufferPool::new();
                        let mut grads: Vec<Tensor> = (0..n_params)
                            .map(|_| Tensor::zeros(&[param_len]))
                            .collect();
                        let mut x = Tensor::zeros(&shard_shape);
                        for step in 0..steps {
                            if step == 1 {
                                // warm-up over: every class is now pooled
                                pool.reset_counters();
                            }
                            for _ in 0..layers {
                                let p = halo::exchange_forward_grid(
                                    &ep, &x, 1, &nbrs, axes, Some(&pool))
                                    .unwrap();
                                pool.recycle(x);
                                let mut act = pool.take_tensor(p.shape());
                                p.leaky_relu_into(0.01, &mut act);
                                pool.recycle(p);
                                x = halo::exchange_backward_grid(
                                    &ep, act, 1, &nbrs, axes, Some(&pool))
                                    .unwrap();
                            }
                            for g in grads.iter_mut() {
                                g.data_mut().fill(0.0);
                            }
                            std::hint::black_box(&grads);
                        }
                        std::hint::black_box(x.numel());
                        pool.misses()
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        pooled_secs = t0.elapsed().as_secs_f64();
        steady_misses = misses.iter().sum();
    });
    let pooled_us = pooled_secs / steps as f64 * 1e6;
    let halo_step_bytes =
        counters.halo_bytes_axes().iter().sum::<u64>() / steps as u64;
    let samples_per_sec = 1e6 / pooled_us;
    let speedup_x = fresh_us / pooled_us;
    println!(
        "   -> {:.1} us/step fresh vs {:.1} us/step pooled ({:.2}x, \
         {:.2} samples/s, {} halo B/step, {} steady-state pool misses)",
        fresh_us, pooled_us, speedup_x, samples_per_sec, halo_step_bytes,
        steady_misses,
    );
    StepNumbers {
        fresh_us,
        pooled_us,
        samples_per_sec,
        speedup_x,
        halo_step_bytes,
        steady_misses,
    }
}

/// Ring allreduce over thread-ranks: should be within a small factor of the
/// memcpy roofline at MiB sizes.
fn allreduce(b: &mut Bench, quick: bool) {
    banner("ring allreduce (4 thread-ranks)");
    let sizes: &[usize] = if quick { &[1 << 10, 1 << 16] } else { &[1 << 10, 1 << 16, 1 << 20] };
    let iters = if quick { 5 } else { 20 };
    for &len in sizes {
        let name = format!("allreduce_sum {} f32 x4 ranks", len);
        let m = b.run_once(&name, || {
            let eps = world(4);
            std::thread::scope(|s| {
                for ep in eps {
                    s.spawn(move || {
                        let group: Vec<usize> = (0..4).collect();
                        let mut buf = vec![1.0f32; len];
                        for _ in 0..iters {
                            ep.allreduce_sum(&mut buf, &group).unwrap();
                        }
                    });
                }
            });
        });
        let per_iter = m.median / iters as f64;
        println!("   -> {:.2} MB buffers, {:.1} us/allreduce, {:.2} GB/s reduced",
                 len as f64 * 4.0 / 1e6,
                 per_iter * 1e6,
                 (len * 4) as f64 / per_iter / 1e9);
    }
}

/// Exposed (non-overlapped) gradient allreduce time: monolithic blocking
/// allreduce after backward vs the bucketed path that launches each
/// bucket's allreduce as its layer's backward completes. "Backward
/// compute" is simulated with sleeps (accelerator compute does not occupy
/// the host CPU), so the bucketed worker genuinely overlaps.
fn overlap(b: &mut Bench, quick: bool) -> (f64, f64) {
    banner("gradient allreduce overlap (4 thread-ranks)");
    let mut mono_us = 0.0f64;
    let mut buck_us = 0.0f64;
    let ranks = 4usize;
    let layers = 12usize;
    let per_layer = if quick { 1 << 13 } else { 1 << 15 }; // f32 elems
    let compute = Duration::from_micros(if quick { 100 } else { 300 });
    let sizes = vec![per_layer; layers];

    // monolithic: full backward, then one blocking allreduce
    let mono = b.run_once("monolithic allreduce after backward", || {
        let eps = world(ranks);
        let exposed: Vec<f64> = std::thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .map(|ep| {
                    s.spawn(move || {
                        let group: Vec<usize> = (0..ranks).collect();
                        for _ in 0..layers {
                            std::thread::sleep(compute);
                        }
                        let mut flat = vec![1.0f32; layers * per_layer];
                        let t0 = Instant::now();
                        ep.allreduce_sum(&mut flat, &group).unwrap();
                        t0.elapsed().as_secs_f64()
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let worst = exposed.iter().copied().fold(0.0, f64::max);
        mono_us = worst * 1e6;
        println!("   -> exposed allreduce: {:.1} us (worst rank)", worst * 1e6);
    });

    // bucketed: one bucket per layer, launched as each "backward" finishes
    let sizes_ref = &sizes;
    let buck = b.run_once("bucketed overlap (1 bucket/layer)", || {
        let grad_world = world(ranks);
        let exposed: Vec<f64> = std::thread::scope(|s| {
            let hs: Vec<_> = grad_world
                .into_iter()
                .map(|ep| {
                    s.spawn(move || {
                        let group: Vec<usize> = (0..ranks).collect();
                        let plan = BucketPlan::new(sizes_ref, per_layer);
                        let mut ov =
                            OverlapAllreduce::start(Box::new(ep), group, plan);
                        let mut grads: Vec<Tensor> = sizes_ref
                            .iter()
                            .map(|&sz| Tensor::from_vec(&[sz], vec![1.0; sz]))
                            .collect();
                        for pi in (0..layers).rev() {
                            std::thread::sleep(compute); // this layer's backward
                            ov.param_ready(pi, grads[pi].data());
                        }
                        let rep = ov.finish(&mut grads).unwrap();
                        ov.shutdown().unwrap();
                        rep.exposed_secs
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let worst = exposed.iter().copied().fold(0.0, f64::max);
        buck_us = worst * 1e6;
        println!("   -> exposed allreduce: {:.1} us (worst rank)", worst * 1e6);
    });
    println!(
        "   -> end-to-end {:.2} ms monolithic vs {:.2} ms bucketed \
         ({:.2}x, {} x {} f32 grads)",
        mono.median * 1e3,
        buck.median * 1e3,
        mono.median / buck.median,
        layers,
        per_layer,
    );
    (mono_us, buck_us)
}

/// Socket transport: flat ring vs hierarchical allreduce over the same
/// 1024-f32 payload on a 4-rank world packed 2 ranks per simulated node.
/// Only inter-node hops travel the framed socket link (12 B header +
/// payload per frame), so the two `_frame_bytes` returns are the wire
/// totals the two algorithms put on the slow links — deterministic, and
/// gated exactly by `ci/bench_gate.py`: flat ring 12 frames x 256 f32
/// (12432 B), hierarchical 4 frames x 512 f32 (8240 B).
fn socket_frames(b: &mut Bench, quick: bool) -> (u64, u64) {
    banner("socket transport framing: flat ring vs hier (4 ranks, 2/node)");
    let len = 1024usize;
    let iters = if quick { 3 } else { 10 };
    let group: Vec<usize> = (0..4).collect();

    // separate worlds for the two lanes so the frame counters don't mix
    let eps_ring = socket_world(4, 2).expect("socket world");
    let ring_counters = eps_ring[0].counters().clone();
    let group_r = group.clone();
    let m = b.run_once("socket flat ring allreduce 1024 f32 x4 ranks", || {
        std::thread::scope(|s| {
            for ep in eps_ring {
                let group = group_r.clone();
                s.spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    for _ in 0..iters {
                        ep.allreduce_sum(&mut buf, &group).unwrap();
                    }
                });
            }
        });
    });
    let ring_frame_bytes = ring_counters.socket_frame_bytes() / iters as u64;
    println!(
        "   -> {:.1} us/allreduce, {} inter-node frame B/allreduce",
        m.median / iters as f64 * 1e6,
        ring_frame_bytes,
    );

    let eps_hier = socket_world(4, 2).expect("socket world");
    let hier_counters = eps_hier[0].counters().clone();
    let m = b.run_once("socket hier allreduce 1024 f32 x4 ranks (2/node)", || {
        std::thread::scope(|s| {
            for ep in eps_hier {
                let group = group.clone();
                s.spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    for _ in 0..iters {
                        allreduce_sum_hier(&ep, &mut buf, &group, 2).unwrap();
                    }
                });
            }
        });
    });
    let hier_frame_bytes = hier_counters.socket_frame_bytes() / iters as u64;
    println!(
        "   -> {:.1} us/allreduce, {} inter-node frame B/allreduce",
        m.median / iters as f64 * 1e6,
        hier_frame_bytes,
    );
    (ring_frame_bytes, hier_frame_bytes)
}

struct StagingNumbers {
    /// Mean per-step exposed time of the *blocking* store redistribution
    /// (worst rank), microseconds.
    blocking_us: f64,
    /// Mean per-step exposed wait of the *async double-buffered* staging
    /// (worst rank), microseconds — should sit well below `blocking_us`
    /// whenever compute is long enough to hide the exchange.
    exposed_us: f64,
    /// Redistribution payload per step, summed over ranks (deterministic).
    redist_step_bytes: u64,
    /// Epoch-0 ingestion bytes, summed over ranks (deterministic: each
    /// input voxel read exactly once + one target per shard position).
    ingest_bytes: u64,
}

/// Store staging: blocking per-step redistribution vs the async
/// double-buffered prefetch worker (§III-B / Fig. 5). "Compute" is a sleep
/// (accelerator compute does not occupy the host CPU), so the async
/// worker's exchange genuinely overlaps and only the residual wait shows.
fn staging(b: &mut Bench, quick: bool) -> StagingNumbers {
    banner("store staging: blocking redistribution vs async double-buffer");
    // 2 groups x 2-way depth split of 4 samples of 1x8^3 (+4-f32 targets);
    // owner = sample % 2, and the schedule always consumes cross-group, so
    // every step moves 4 shards of 256 f32 + 4 targets = 4160 B.
    let (size, n_samples, groups) = (8usize, 4usize, 2usize);
    let topo = GridTopology::new(groups, SpatialGrid::depth(2));
    let steps = if quick { 8 } else { 32 };
    let compute = Duration::from_micros(if quick { 150 } else { 400 });
    let mut rng = Pcg::new(6, 6);
    let inputs: Vec<Tensor> = (0..n_samples)
        .map(|_| {
            let mut t = Tensor::zeros(&[1, 1, size, size, size]);
            rng.fill_normal(t.data_mut(), 1.0);
            t
        })
        .collect();
    let targets: Vec<Tensor> =
        (0..n_samples).map(|_| Tensor::zeros(&[1, 4])).collect();
    let mut path = std::env::temp_dir();
    path.push(format!("hydra3d-bench-staging-{}", std::process::id()));
    write_dataset(&path, &inputs, &targets, None).unwrap();
    let c = Arc::new(Container::open(&path).unwrap());
    // schedule rows (group-major): each group trains on a sample the other
    // group owns, alternating pairs across steps
    let sched: Arc<Vec<Vec<usize>>> = Arc::new(
        (0..steps).map(|s| if s % 2 == 0 { vec![1, 0] } else { vec![3, 2] })
            .collect(),
    );

    // ---- blocking: redistribute on the compute thread every step ---------
    let mut stores: Vec<DataStore> = (0..topo.world_size())
        .map(|r| DataStore::ingest(&c, topo, r, false).unwrap())
        .collect();
    let ingest_bytes: u64 = stores.iter().map(|s| s.ingest_bytes).sum();
    let mut blocking_us = 0.0f64;
    b.run_once("blocking store redistribution (4 ranks)", || {
        let eps = world(topo.world_size());
        let exposed: Vec<f64> = std::thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .zip(stores.iter_mut())
                .map(|(ep, st)| {
                    let sched = sched.clone();
                    s.spawn(move || {
                        let mut total = 0.0f64;
                        for row in sched.iter() {
                            std::thread::sleep(compute); // the step's compute
                            let assigns = assignments_of(row, groups);
                            let t0 = Instant::now();
                            st.redistribute(&ep, &assigns).unwrap();
                            total += t0.elapsed().as_secs_f64();
                        }
                        total
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let worst = exposed.iter().copied().fold(0.0, f64::max);
        blocking_us = worst / steps as f64 * 1e6;
        println!("   -> exposed staging: {:.1} us/step (worst rank)", blocking_us);
    });
    let redist_step_bytes: u64 =
        stores.iter().map(|s| s.redist_bytes).sum::<u64>() / steps as u64;

    // ---- async: the prefetch worker stages step s+1 behind step s --------
    let mut exposed_us = 0.0f64;
    b.run_once("async double-buffered staging (4 ranks)", || {
        let io_eps = world(topo.world_size());
        let exposed: Vec<f64> = std::thread::scope(|s| {
            let hs: Vec<_> = io_eps
                .into_iter()
                .enumerate()
                .map(|(r, ep)| {
                    let c = c.clone();
                    let sched = sched.clone();
                    s.spawn(move || {
                        let mut stg = AsyncStaging::start(
                            c, topo, r, false, Box::new(ep), sched.clone(),
                            groups, 0,
                        );
                        let mut total = 0.0f64;
                        for _ in 0..steps {
                            std::thread::sleep(compute); // the step's compute
                            total += stg.begin_step().unwrap();
                        }
                        stg.shutdown().unwrap();
                        total
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let worst = exposed.iter().copied().fold(0.0, f64::max);
        exposed_us = worst / steps as f64 * 1e6;
        println!("   -> exposed staging: {:.1} us/step (worst rank)", exposed_us);
    });
    println!(
        "   -> {:.1} us/step blocking vs {:.1} us/step async-exposed \
         ({} redist B/step, {} ingest B)",
        blocking_us, exposed_us, redist_step_bytes, ingest_bytes,
    );
    std::fs::remove_file(&path).ok();
    StagingNumbers { blocking_us, exposed_us, redist_step_bytes, ingest_bytes }
}

/// Container hyperslab read throughput (the PFS-facing path).
fn container_reads(b: &mut Bench) {
    banner("container hyperslab reads");
    let mut rng = Pcg::new(5, 5);
    let mut t = Tensor::zeros(&[1, 1, 32, 32, 32]);
    rng.fill_normal(t.data_mut(), 1.0);
    let inputs = vec![t; 4];
    let targets = vec![Tensor::zeros(&[1, 4]); 4];
    let mut path = std::env::temp_dir();
    path.push(format!("hydra3d-bench-{}", std::process::id()));
    write_dataset(&path, &inputs, &targets, None).unwrap();
    let c = Container::open(&path).unwrap();
    let m = b.run("read_input_shard 8 planes of 32^3", || {
        std::hint::black_box(c.read_input_shard(0, 8, 8).unwrap());
    });
    println!("   -> {:.2} GB/s", (8 * 32 * 32 * 4) as f64 / m.median / 1e9);
    std::fs::remove_file(&path).ok();
}

/// PJRT dispatch overhead: a minimal executable round-trip bounds the
/// per-layer-call tax of the hybrid engine.
fn pjrt_overhead(b: &mut Bench) {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("(artifacts not built; skipping PJRT overhead bench)");
        return;
    }
    banner("PJRT call overhead (runtime service round-trip)");
    let rt = RuntimeHandle::start(&dir).unwrap();
    let man = rt.manifest();
    let m = man.model("cf-nano").unwrap();
    let plan = &m.hybrid[&1];
    if let hydra3d::runtime::LayerDesc::Conv { fwd, .. } = &plan[0] {
        let e = man.entry(fwd.as_ref().unwrap()).unwrap().clone();
        let x = Tensor::zeros(&e.inputs[0]);
        let w = Tensor::zeros(&e.inputs[1]);
        let name = fwd.clone().unwrap();
        rt.warm(&name).unwrap();
        b.run("conv_fwd cf-nano shard (incl. marshaling)", || {
            std::hint::black_box(rt.call(&name, vec![x.clone(), w.clone()]).unwrap());
        });
    }
}
