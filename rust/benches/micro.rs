//! `cargo bench --bench micro` — hot-path micro-benchmarks for the L3
//! performance pass (DESIGN.md §7): halo pack/unpack bandwidth, ring
//! allreduce throughput, container hyperslab reads, and PJRT call overhead.
//! Before/after numbers are recorded in EXPERIMENTS.md §Perf.

use hydra3d::comm::world;
use hydra3d::data::container::{write_dataset, Container};
use hydra3d::runtime::RuntimeHandle;
use hydra3d::tensor::Tensor;
use hydra3d::util::bench::{banner, Bench};
use hydra3d::util::rng::Pcg;
use std::path::PathBuf;

fn main() {
    let mut b = Bench::default();
    halo_pack(&mut b);
    allreduce(&mut b);
    container_reads(&mut b);
    pjrt_overhead(&mut b);
}

/// Halo pack/unpack = depth-slab copies (the paper's optimized CUDA packing
/// kernels; ours must stay memcpy-bound).
fn halo_pack(b: &mut Bench) {
    banner("halo pack/unpack (slab copies)");
    // conv2-of-cf64-like shard: 32 ch x 16 planes x 64 x 64
    let t = Tensor::zeros(&[1, 32, 16, 64, 64]);
    let halo_bytes = (32 * 1 * 64 * 64 * 4) as f64;
    let m = b.run("slice_d 1-plane halo (32x64x64)", || {
        std::hint::black_box(t.slice_d(0, 1));
    });
    println!("   -> pack bandwidth {:.2} GB/s", halo_bytes / m.median / 1e9);
    let mut padded = t.pad_d(1, 1);
    let slab = t.slice_d(0, 1);
    let m = b.run("set_slice_d 1-plane halo", || {
        padded.set_slice_d(0, std::hint::black_box(&slab));
    });
    println!("   -> unpack bandwidth {:.2} GB/s", halo_bytes / m.median / 1e9);
    let m = b.run("pad_d full shard (+2 planes)", || {
        std::hint::black_box(t.pad_d(1, 1));
    });
    println!("   -> pad bandwidth {:.2} GB/s", (t.numel() * 4) as f64 / m.median / 1e9);
    let mut acc = t.clone();
    b.run("add_slice_d (reverse-halo accumulate)", || {
        acc.add_slice_d(0, std::hint::black_box(&slab));
    });
}

/// Ring allreduce over thread-ranks: should be within a small factor of the
/// memcpy roofline at MiB sizes.
fn allreduce(b: &mut Bench) {
    banner("ring allreduce (4 thread-ranks)");
    for len in [1usize << 10, 1 << 16, 1 << 20] {
        let name = format!("allreduce_sum {} f32 x4 ranks", len);
        let m = b.run_once(&name, || {
            let eps = world(4);
            std::thread::scope(|s| {
                for ep in eps {
                    s.spawn(move || {
                        let group: Vec<usize> = (0..4).collect();
                        let mut buf = vec![1.0f32; len];
                        for _ in 0..20 {
                            ep.allreduce_sum(&mut buf, &group).unwrap();
                        }
                    });
                }
            });
        });
        let per_iter = m.median / 20.0;
        println!("   -> {:.2} MB buffers, {:.1} us/allreduce, {:.2} GB/s reduced",
                 len as f64 * 4.0 / 1e6,
                 per_iter * 1e6,
                 (len * 4) as f64 / per_iter / 1e9);
    }
}

/// Container hyperslab read throughput (the PFS-facing path).
fn container_reads(b: &mut Bench) {
    banner("container hyperslab reads");
    let mut rng = Pcg::new(5, 5);
    let mut t = Tensor::zeros(&[1, 1, 32, 32, 32]);
    rng.fill_normal(t.data_mut(), 1.0);
    let inputs = vec![t; 4];
    let targets = vec![Tensor::zeros(&[1, 4]); 4];
    let mut path = std::env::temp_dir();
    path.push(format!("hydra3d-bench-{}", std::process::id()));
    write_dataset(&path, &inputs, &targets, None).unwrap();
    let c = Container::open(&path).unwrap();
    let m = b.run("read_input_shard 8 planes of 32^3", || {
        std::hint::black_box(c.read_input_shard(0, 8, 8).unwrap());
    });
    println!("   -> {:.2} GB/s", (8 * 32 * 32 * 4) as f64 / m.median / 1e9);
    std::fs::remove_file(&path).ok();
}

/// PJRT dispatch overhead: a minimal executable round-trip bounds the
/// per-layer-call tax of the hybrid engine.
fn pjrt_overhead(b: &mut Bench) {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("(artifacts not built; skipping PJRT overhead bench)");
        return;
    }
    banner("PJRT call overhead (runtime service round-trip)");
    let rt = RuntimeHandle::start(&dir).unwrap();
    let man = rt.manifest();
    let m = man.model("cf-nano").unwrap();
    let plan = &m.hybrid[&1];
    if let hydra3d::runtime::LayerDesc::Conv { fwd, .. } = &plan[0] {
        let e = man.entry(fwd.as_ref().unwrap()).unwrap().clone();
        let x = Tensor::zeros(&e.inputs[0]);
        let w = Tensor::zeros(&e.inputs[1]);
        let name = fwd.clone().unwrap();
        rt.warm(&name).unwrap();
        b.run("conv_fwd cf-nano shard (incl. marshaling)", || {
            std::hint::black_box(rt.call(&name, vec![x.clone(), w.clone()]).unwrap());
        });
    }
}
