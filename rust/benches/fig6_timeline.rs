//! `cargo bench --bench fig6_timeline` — per-GPU stream timelines, 8 vs 16
//! GPUs/sample (paper Fig. 6). Emits Chrome traces into runs/.
use hydra3d::config::ClusterConfig;
use hydra3d::coordinator::fig6;
use hydra3d::util::bench::banner;

fn main() {
    std::fs::create_dir_all("runs").ok();
    banner("Fig. 6 — execution timelines");
    print!("{}", fig6(&ClusterConfig::default(), Some(std::path::Path::new("runs"))));
}
