//! `cargo bench --bench fig4_strong_scaling` — CosmoFlow 512^3 strong
//! scaling across mini-batch sizes (paper Fig. 4).
use hydra3d::config::ClusterConfig;
use hydra3d::coordinator::fig4;
use hydra3d::util::bench::banner;

fn main() {
    banner("Fig. 4 — CosmoFlow 512^3 strong scaling");
    print!("{}", fig4(&ClusterConfig::default()));
}
