//! The §III-C performance model.
//!
//! Predicts per-iteration training time for a (model, partitioning,
//! mini-batch, cluster) configuration:
//!
//! ```text
//! FP_l  = max{ Comp_l(D_main), Σ_d 2 SR(D_halo_d) } + Comp_l(D_halo)
//! Cost  = Σ_l FP_l + max{ Σ_l (BD_l + BF_l), Σ_l AR(θ_l) }
//! ```
//!
//! `Comp_l` comes from a calibrated V100/cuDNN kernel cost model
//! ([`KernelModel`] — the paper benchmarks cuDNN directly; we encode the
//! same efficiency structure: peak fraction degraded by narrow channels,
//! small extents, and thin non-cube shards, the effect the paper blames
//! for the 1.66x speedup at 2x GPUs in §V-B). `SR` is a linear latency +
//! bandwidth link model fitted the way the paper fits Aluminum ping-pong
//! benchmarks; `AR` is the standard ring-allreduce model over the
//! bottleneck link.

pub mod scaling;
pub mod trace;

use crate::config::ClusterConfig;
use crate::models::{AnalyticLayer, AnalyticModel, LayerKind};
use crate::partition::Grid4;
use crate::util::stats::linreg;

/// Link kinds on the simulated cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Link {
    NvLink,
    InfiniBand,
}

/// Linear point-to-point model t(bytes) = alpha + bytes / bw.
#[derive(Clone, Copy, Debug)]
pub struct SrModel {
    pub alpha_s: f64,
    pub bytes_per_s: f64,
}

impl SrModel {
    pub fn from_cluster(cluster: &ClusterConfig, link: Link) -> SrModel {
        match link {
            Link::NvLink => SrModel {
                alpha_s: cluster.nvlink_latency_us * 1e-6,
                bytes_per_s: cluster.nvlink_gbps * 1e9,
            },
            Link::InfiniBand => SrModel {
                alpha_s: cluster.ib_latency_us * 1e-6,
                bytes_per_s: cluster.ib_gbps * 1e9,
            },
        }
    }

    pub fn time(&self, bytes: f64) -> f64 {
        if bytes <= 0.0 {
            0.0
        } else {
            self.alpha_s + bytes / self.bytes_per_s
        }
    }

    /// Fit from (bytes, seconds) measurements — the paper's methodology
    /// (linear regression over Aluminum ping-pong data).
    pub fn fit(bytes: &[f64], secs: &[f64]) -> SrModel {
        let (a, b, _r2) = linreg(bytes, secs);
        SrModel { alpha_s: a.max(0.0), bytes_per_s: if b > 0.0 { 1.0 / b } else { f64::MAX } }
    }
}

/// NCCL-style allreduce over `n` ranks: hierarchical/tree latency
/// (O(log n) startup, as the paper's log-transformed regression captures)
/// plus the ring bandwidth term 2(n-1)/n * bytes / bw.
pub fn allreduce_time(bytes: f64, n: usize, link: &SrModel) -> f64 {
    if n <= 1 || bytes <= 0.0 {
        return 0.0;
    }
    let lat = 2.0 * (n as f64).log2().ceil() * link.alpha_s;
    let bw = 2.0 * (n as f64 - 1.0) / n as f64 * bytes / link.bytes_per_s;
    lat + bw
}

/// Calibrated per-GPU kernel cost model (V100, cuDNN-like efficiency).
#[derive(Clone, Copy, Debug)]
pub struct KernelModel {
    pub peak_flops: f64,
    /// HBM stream bandwidth for pointwise/pooling layers
    pub mem_bps: f64,
    /// base fraction of peak dense conv achieves
    pub conv_eff: f64,
}

impl KernelModel {
    pub fn v100(cluster: &ClusterConfig) -> KernelModel {
        KernelModel {
            peak_flops: cluster.gpu_tflops * 1e12,
            mem_bps: 900e9 * 0.75,
            conv_eff: 0.30,
        }
    }

    /// Effective conv efficiency for a shard of `cin` input channels, local
    /// depth extent `dsh` (output planes this GPU computes) and full
    /// H-extent `ext`. Encodes the paper's observations: narrow channels
    /// (conv1) and thin non-cube domains under-utilize cuDNN kernels.
    fn conv_shard_eff(&self, cin: usize, dsh: usize, ext: usize) -> f64 {
        let f_cin = (cin as f64 / (cin as f64 + 2.0)).powf(0.35);
        // thin-slab penalty: cuDNN's 3D kernels lose efficiency as the
        // local depth extent shrinks below a few tens of planes (the paper
        // blames exactly this for the 1.66x speedup at 2x GPUs, §V-B).
        let f_thin = dsh as f64 / (dsh as f64 + 10.0);
        let f_small = if ext < 8 { 0.4 } else { 1.0 };
        self.conv_eff * f_cin * f_thin * f_small
    }

    /// Forward-pass compute time of layer `l` on one GPU holding a
    /// `1/grid.spatial_ways()` shard (no communication). The thin-shard
    /// penalties apply per axis: depth splits shrink the local depth
    /// extent, H/W splits shrink the in-plane extent cuDNN tiles over.
    pub fn comp_fwd(&self, l: &AnalyticLayer, grid: Grid4) -> f64 {
        let frac = 1.0 / grid.spatial_ways() as f64;
        match l.kind {
            LayerKind::Conv | LayerKind::Deconv => {
                let dsh = (l.d_out / grid.d).max(1);
                let ext = (l.d_out / grid.h.max(grid.w)).max(1);
                l.fwd_flops() * frac
                    / (self.peak_flops * self.conv_shard_eff(l.cin, dsh, ext))
            }
            LayerKind::Pool | LayerKind::BatchNorm => {
                // bandwidth-bound: read + write the shard
                let bytes = 8.0 * l.out_elems() * frac;
                bytes / self.mem_bps
            }
            LayerKind::Fc => l.fwd_flops() / (self.peak_flops * 0.10),
        }
    }
}

/// The full §III-C model for one configuration.
#[derive(Clone, Debug)]
pub struct PerfModel {
    pub kernel: KernelModel,
    pub nvlink: SrModel,
    pub ib: SrModel,
    pub gpus_per_node: usize,
}

/// Per-layer predicted times (seconds).
#[derive(Clone, Debug, Default)]
pub struct LayerCost {
    pub name: String,
    pub fp: f64,
    pub bd: f64,
    pub bf: f64,
    pub halo: f64,
    pub comp_fwd: f64,
}

/// End-to-end prediction for one iteration.
#[derive(Clone, Debug)]
pub struct IterCost {
    pub layers: Vec<LayerCost>,
    pub fwd: f64,
    pub bwd: f64,
    pub allreduce: f64,
    /// allreduce overlaps backward (paper Fig. 6): iteration = fwd +
    /// max(bwd, allreduce)
    pub total: f64,
    /// kernel-only (communication-free) total — Table II's "Peak"
    pub kernel_only: f64,
    pub samples_per_s: f64,
    pub feasible: bool,
}

impl PerfModel {
    pub fn new(cluster: &ClusterConfig) -> PerfModel {
        PerfModel {
            kernel: KernelModel::v100(cluster),
            nvlink: SrModel::from_cluster(cluster, Link::NvLink),
            ib: SrModel::from_cluster(cluster, Link::InfiniBand),
            gpus_per_node: cluster.gpus_per_node,
        }
    }

    /// Halo link for a `ways`-way depth split: shards are packed onto
    /// nodes in depth order (paper Fig. 2), so splits within a node ride
    /// NVLink; wider splits bottleneck on InfiniBand.
    fn halo_link(&self, ways: usize) -> &SrModel {
        if ways <= self.gpus_per_node {
            &self.nvlink
        } else {
            &self.ib
        }
    }

    /// One training iteration of `model` under `grid` with global
    /// mini-batch `n` on a `gpu_mem_gib`-limited device.
    pub fn iteration(&self, model: &AnalyticModel, grid: Grid4, n: usize,
                     gpu_mem_gib: f64) -> IterCost {
        let ways = grid.spatial_ways();
        let groups = grid.n.max(1);
        let world = grid.world_size();
        let samples_per_group = (n as f64 / groups as f64).max(1.0);
        let mem_per_gpu = model.activation_gib() / ways as f64;
        let feasible = mem_per_gpu <= gpu_mem_gib * 0.95;

        let mut layers = Vec::new();
        let (mut fwd, mut bwd, mut kernel_only) = (0.0f64, 0.0f64, 0.0f64);
        let mut ar_total = 0.0f64;
        for l in &model.layers {
            let comp = self.kernel.comp_fwd(l, grid);
            // halo: one face each side per partitioned axis, exchanged
            // sequentially (§III-A), overlapped with main compute — so the
            // exposed term is Σ_axis 2 SR(face_axis)
            let link = self.halo_link(ways);
            let sr: f64 = (0..3)
                .map(|a| link.time(l.halo_face_bytes_axis(grid, a)))
                .sum();
            // extra boundary output recomputed from the halo region,
            // accumulated over the partitioned axes
            let halo_frac = if l.kind == LayerKind::Conv && l.k > 1 {
                [grid.d, grid.h, grid.w]
                    .iter()
                    .filter(|&&wy| wy > 1)
                    .map(|&wy| {
                        (l.k - 1) as f64
                            / (l.d_in as f64 / wy as f64 + (l.k - 1) as f64)
                    })
                    .sum()
            } else {
                0.0
            };
            let comp_halo = comp * halo_frac;
            let fp = comp.max(2.0 * sr) + comp_halo;
            // backward-data and backward-filter each cost ~one forward conv
            let (bd, bf) = match l.kind {
                LayerKind::Conv | LayerKind::Deconv | LayerKind::Fc => {
                    (comp.max(2.0 * sr) + comp_halo, comp)
                }
                _ => (comp, 0.0),
            };
            // parameter-gradient allreduce over all GPUs (ring on the
            // bottleneck link once the job spans nodes)
            let link = if world <= self.gpus_per_node { &self.nvlink } else { &self.ib };
            ar_total += allreduce_time(4.0 * l.param_count() as f64, world, link);
            fwd += fp * samples_per_group;
            bwd += (bd + bf) * samples_per_group;
            kernel_only += (comp + bd.min(comp + comp_halo) + bf) * samples_per_group;
            layers.push(LayerCost {
                name: l.name.clone(),
                fp: fp * samples_per_group,
                bd: bd * samples_per_group,
                bf: bf * samples_per_group,
                halo: 2.0 * sr * samples_per_group,
                comp_fwd: comp * samples_per_group,
            });
        }
        let total = fwd + bwd.max(ar_total);
        IterCost {
            layers,
            fwd,
            bwd,
            allreduce: ar_total,
            total,
            kernel_only,
            samples_per_s: n as f64 / total,
            feasible,
        }
    }

    /// Conv-layers-only achieved-vs-peak ratio (Table II's "Rel" column):
    /// kernel-only conv time / conv time including halo overheads.
    pub fn conv_rel_to_peak(&self, model: &AnalyticModel, grid: Grid4, n: usize,
                            conv_name: Option<&str>) -> f64 {
        let it = self.iteration(model, grid, n, f64::MAX);
        let sel = |lc: &&LayerCost| {
            lc.name.starts_with("conv")
                && conv_name.map(|c| lc.name == c).unwrap_or(true)
        };
        let with: f64 = it.layers.iter().filter(sel).map(|l| l.fp + l.bd + l.bf).sum();
        let kernel: f64 = it
            .layers
            .iter()
            .filter(sel)
            .map(|l| 3.0 * l.comp_fwd)
            .sum();
        kernel / with
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::cosmoflow_paper;

    fn pm() -> PerfModel {
        PerfModel::new(&ClusterConfig::default())
    }

    #[test]
    fn sr_fit_recovers_line() {
        let truth = SrModel { alpha_s: 3e-6, bytes_per_s: 50e9 };
        let bytes: Vec<f64> = (1..20).map(|i| i as f64 * 1e6).collect();
        let secs: Vec<f64> = bytes.iter().map(|&b| truth.time(b)).collect();
        let fit = SrModel::fit(&bytes, &secs);
        assert!((fit.alpha_s - truth.alpha_s).abs() < 1e-7);
        assert!((fit.bytes_per_s - truth.bytes_per_s).abs() / truth.bytes_per_s < 0.01);
    }

    #[test]
    fn allreduce_scales_with_ranks_and_bytes() {
        let link = SrModel { alpha_s: 4e-6, bytes_per_s: 21e9 };
        let t1 = allreduce_time(37.8e6, 512, &link);
        let t2 = allreduce_time(37.8e6, 2048, &link);
        assert!(t2 > t1); // latency term grows
        assert!(allreduce_time(0.0, 512, &link) == 0.0);
        assert!(allreduce_time(1e6, 1, &link) == 0.0);
    }

    /// Strong scaling of the 512^3 model, N=64: going 512 -> 2048 GPUs
    /// (8-way -> 32-way) must land near the paper's 1.77x.
    #[test]
    fn fig4_headline_speedup() {
        let m = cosmoflow_paper(512, false);
        let p = pm();
        let t8 = p.iteration(&m, Grid4::depth_only(64, 8), 64, 16.0);
        let t32 = p.iteration(&m, Grid4::depth_only(64, 32), 64, 16.0);
        let speedup = t8.total / t32.total;
        assert!(
            (1.4..2.6).contains(&speedup),
            "512->2048 GPU speedup {speedup:.2} (paper: 1.77x)"
        );
        assert!(t8.feasible && t32.feasible);
    }

    /// N=16: 128 -> 512 GPUs speedup near the paper's 1.98x.
    #[test]
    fn fig4_n16_speedup() {
        let m = cosmoflow_paper(512, false);
        let p = pm();
        let a = p.iteration(&m, Grid4::depth_only(16, 8), 16, 16.0);
        let b = p.iteration(&m, Grid4::depth_only(16, 32), 16, 16.0);
        let s = a.total / b.total;
        assert!((1.4..2.9).contains(&s), "{s:.2} (paper: 1.98x)");
    }

    /// Table II structure: achieved/peak ratio decreases with more ways,
    /// conv1 (narrow channels) scales worse than the full network.
    #[test]
    fn table2_rel_to_peak_structure() {
        let m = cosmoflow_paper(512, false);
        let p = pm();
        let all8 = p.conv_rel_to_peak(&m, Grid4::depth_only(64, 8), 64, None);
        let all32 = p.conv_rel_to_peak(&m, Grid4::depth_only(64, 32), 64, None);
        let c1_8 = p.conv_rel_to_peak(&m, Grid4::depth_only(64, 8), 64, Some("conv1"));
        let c1_32 = p.conv_rel_to_peak(&m, Grid4::depth_only(64, 32), 64, Some("conv1"));
        assert!(all8 > 0.88 && all8 <= 1.0, "8-way rel {all8} (paper 95.6%)");
        assert!(all32 < all8, "rel must drop with ways: {all8} -> {all32}");
        assert!((0.55..0.95).contains(&all32), "32-way rel {all32} (paper 82.4%)");
        assert!(c1_32 < c1_8, "conv1 rel: {c1_8} -> {c1_32} (paper 93.8 -> 64.7)");
    }

    /// A 2x2x2 spatial grid exchanges less halo volume than the 8-way
    /// depth split of the same 8 GPUs (the multi-axis decomposition claim;
    /// Dryden et al.), and the model prices it accordingly.
    #[test]
    fn grid_3d_halo_below_depth_only() {
        let m = cosmoflow_paper(512, false);
        let p = pm();
        let depth = p.iteration(&m, Grid4::depth_only(8, 8), 8, 16.0);
        let grid = p.iteration(&m, Grid4 { n: 8, d: 2, h: 2, w: 2 }, 8, 16.0);
        let halo_depth: f64 = depth.layers.iter().map(|l| l.halo).sum();
        let halo_grid: f64 = grid.layers.iter().map(|l| l.halo).sum();
        assert!(halo_grid < halo_depth,
                "3D halo {halo_grid} must be below depth-only {halo_depth}");
        // both are feasible, finite predictions
        assert!(grid.total > 0.0 && grid.total.is_finite());
        assert!(grid.feasible && depth.feasible);
    }

    /// Memory feasibility drives the minimum ways (Fig. 4 has no 4-way
    /// bars for 512^3 + BN).
    #[test]
    fn infeasible_configs_flagged() {
        let m = cosmoflow_paper(512, true); // with BN: x2 memory modeled via bn layers
        let p = pm();
        let it = p.iteration(&m, Grid4::depth_only(1, 4), 1, 16.0);
        assert!(!it.feasible, "512^3+BN on 4 GPUs must be infeasible");
        let it8 = p.iteration(&m, Grid4::depth_only(1, 8), 1, 16.0);
        assert!(it8.feasible);
    }

    /// conv1 dominates runtime (§V-B: "conv1 accounts for almost half").
    #[test]
    fn conv1_dominates() {
        let m = cosmoflow_paper(512, false);
        let p = pm();
        let it = p.iteration(&m, Grid4::depth_only(64, 8), 64, 16.0);
        let conv1 = it.layers.iter().find(|l| l.name == "conv1").unwrap();
        let conv_total: f64 = it
            .layers
            .iter()
            .filter(|l| l.name.starts_with("conv"))
            .map(|l| l.fp + l.bd + l.bf)
            .sum();
        let frac = (conv1.fp + conv1.bd + conv1.bf) / conv_total;
        assert!((0.3..0.7).contains(&frac), "conv1 fraction {frac}");
    }
}
