//! Trace ingestion: replay a [`TraceCollector`] recording against the
//! §III-C link model.
//!
//! The traced communicator backend records every point-to-point message a
//! run actually sent (the collectives decompose into sends, so the ring /
//! recursive-doubling / halo structure is all there) plus one event per
//! logical collective. This module prices that recording with the fitted
//! [`SrModel`] link:
//!
//! * **p2p replay** — every recorded message costs `alpha + bytes/bw`;
//!   messages sent by one rank serialize (a rank has one injection port),
//!   so the critical path is the busiest rank's total. This is the
//!   measured-structure prediction.
//! * **collective closed forms** — the same logical collectives priced
//!   with the §III-C formulas ([`allreduce_time`] for allreduces). Tests
//!   assert the two views agree, which is exactly the validation the paper
//!   performs between measured Aluminum traces and its model.

use super::{allreduce_time, SrModel};
use crate::comm::traced::TraceCollector;
use crate::comm::Collective;

/// Priced replay of one trace.
#[derive(Clone, Debug, Default)]
pub struct TraceReplay {
    /// Point-to-point messages recorded.
    pub messages: usize,
    /// Total payload bytes recorded.
    pub bytes: u64,
    /// Per-rank serialized send time (seconds) under the link model.
    pub per_rank_secs: Vec<f64>,
    /// Busiest-rank send time — the p2p critical-path estimate.
    pub p2p_critical_secs: f64,
    /// The same run's logical allreduces priced with the closed-form
    /// §III-C model (latency tree + ring bandwidth term).
    pub allreduce_model_secs: f64,
    /// Logical collectives recorded (allreduces, gathers, barriers, ...).
    pub collectives: usize,
    /// Halo-face payload bytes per spatial axis (D, H, W), from the axis
    /// tags the halo exchange attaches to its sends — the per-dimension
    /// halo volumes the §III-A cost model sums over.
    pub halo_bytes_axis: [u64; 3],
    /// Data-store redistribution payload bytes (`MsgTag::Redist`) — the
    /// §III-B staging volume; `iosim::pipeline::io_time_from_redist_trace`
    /// prices it against the analytic spatial-parallel I/O term.
    pub redist_bytes: u64,
}

/// Replay `trace` (from a world of `world` ranks) against `link`.
pub fn replay(trace: &TraceCollector, world: usize, link: &SrModel) -> TraceReplay {
    let msgs = trace.messages();
    let mut per_rank_secs = vec![0.0f64; world];
    let mut bytes = 0u64;
    for m in &msgs {
        bytes += m.bytes;
        if m.from < world {
            per_rank_secs[m.from] += link.time(m.bytes as f64);
        }
    }
    let p2p_critical_secs = per_rank_secs.iter().copied().fold(0.0, f64::max);
    let colls = trace.collectives();
    let allreduce_model_secs = colls
        .iter()
        .filter(|c| matches!(c.op, Collective::AllreduceRing | Collective::AllreduceRd))
        .map(|c| allreduce_time(4.0 * c.elems as f64, c.group_len, link))
        .sum();
    TraceReplay {
        messages: msgs.len(),
        bytes,
        per_rank_secs,
        p2p_critical_secs,
        allreduce_model_secs,
        collectives: colls.len(),
        halo_bytes_axis: trace.halo_bytes_per_axis(),
        redist_bytes: trace.redist_bytes(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{world, Communicator, Traced};
    use std::sync::Arc;
    use std::thread;

    fn run_traced_allreduce(n: usize, len: usize) -> Arc<TraceCollector> {
        let tc = Arc::new(TraceCollector::new());
        let eps: Vec<_> = world(n)
            .into_iter()
            .map(|e| Traced::new(e, tc.clone()))
            .collect();
        thread::scope(|s| {
            for ep in eps {
                s.spawn(move || {
                    let group: Vec<usize> = (0..n).collect();
                    let mut buf = vec![1.0f32; len];
                    ep.allreduce_sum(&mut buf, &group).unwrap();
                });
            }
        });
        tc
    }

    /// Ring allreduce over g ranks moves exactly 2(g-1) chunks per rank and
    /// 2(g-1) * len elements in total — the structure §III-C assumes.
    #[test]
    fn ring_trace_matches_theory() {
        let (n, len) = (4usize, 1000usize);
        let tc = run_traced_allreduce(n, len);
        assert_eq!(tc.message_count(), 2 * (n - 1) * n);
        assert_eq!(tc.total_bytes(), (2 * (n - 1) * len * 4) as u64);
        let per_rank = tc.per_rank_bytes(n);
        for (r, &b) in per_rank.iter().enumerate() {
            assert_eq!(b, (2 * (n - 1) * len * 4) as u64 / n as u64, "rank {r}");
        }
        assert_eq!(tc.collectives().len(), 1, "one logical allreduce");
    }

    /// The p2p replay of a ring allreduce agrees with the closed-form
    /// allreduce model: identical bandwidth term, latency within the
    /// per-message vs log-tree modeling difference.
    #[test]
    fn replay_agrees_with_closed_form() {
        let (n, len) = (4usize, 1 << 16);
        let tc = run_traced_allreduce(n, len);
        let link = SrModel { alpha_s: 2e-6, bytes_per_s: 50e9 };
        let rep = replay(&tc, n, &link);
        assert_eq!(rep.messages, 2 * (n - 1) * n);
        assert_eq!(rep.collectives, 1);
        assert!(rep.p2p_critical_secs > 0.0);
        let ratio = rep.p2p_critical_secs / rep.allreduce_model_secs;
        assert!(
            (0.5..2.0).contains(&ratio),
            "p2p replay {:.2e}s vs model {:.2e}s (ratio {ratio:.2})",
            rep.p2p_critical_secs,
            rep.allreduce_model_secs,
        );
    }

    /// Halo sends carry their axis tag through to the replay report.
    #[test]
    fn replay_accounts_halo_axes() {
        use crate::comm::halo;
        use crate::partition::{GridTopology, SpatialGrid};
        use crate::tensor::Tensor;
        let grid = SpatialGrid::new(2, 1, 2);
        let topo = GridTopology::new(1, grid);
        let tc = Arc::new(TraceCollector::new());
        let eps: Vec<_> = world(grid.ways())
            .into_iter()
            .map(|e| Traced::new(e, tc.clone()))
            .collect();
        thread::scope(|s| {
            for (r, ep) in eps.into_iter().enumerate() {
                let nbrs = topo.neighbors(r);
                s.spawn(move || {
                    let shard = Tensor::zeros(&[1, 1, 2, 2, 2]);
                    halo::exchange_forward_grid(&ep, &shard, 1, &nbrs,
                                                [true, true, true], None)
                        .unwrap();
                });
            }
        });
        let link = SrModel { alpha_s: 1e-6, bytes_per_s: 10e9 };
        let rep = replay(&tc, 4, &link);
        // D faces: 4 sends of a (1,1,1,2,2) face = 16 B; H is unsplit
        // (zero-pad only); W faces go out after the D+H pads: 4 sends of
        // (1,1,4,4,1) = 64 B.
        assert_eq!(rep.halo_bytes_axis, [4 * 4 * 4, 0, 4 * 16 * 4]);
        assert_eq!(rep.bytes, (4 * 4 * 4 + 4 * 16 * 4) as u64);
    }

    /// Store-redistribution sends carry `MsgTag::Redist` into the replay,
    /// separately from halo and generic traffic.
    #[test]
    fn replay_accounts_redistribution_bytes() {
        use crate::comm::MsgTag;
        let tc = Arc::new(TraceCollector::new());
        let eps: Vec<_> = world(2)
            .into_iter()
            .map(|e| Traced::new(e, tc.clone()))
            .collect();
        thread::scope(|s| {
            for ep in eps {
                s.spawn(move || {
                    let peer = 1 - ep.rank();
                    ep.send_tagged(peer, vec![0.0; 50], MsgTag::Redist);
                    ep.send(peer, vec![0.0; 7]); // generic: not redist
                    ep.recv(peer).unwrap();
                    ep.recv(peer).unwrap();
                });
            }
        });
        let link = SrModel { alpha_s: 1e-6, bytes_per_s: 10e9 };
        let rep = replay(&tc, 2, &link);
        assert_eq!(rep.redist_bytes, 2 * 50 * 4);
        assert_eq!(rep.bytes, (2 * 50 * 4 + 2 * 7 * 4) as u64);
        assert_eq!(rep.halo_bytes_axis, [0; 3]);
    }

    /// Per-rank send loads in a ring are balanced.
    #[test]
    fn ring_loads_are_balanced() {
        let tc = run_traced_allreduce(5, 500);
        let link = SrModel { alpha_s: 1e-6, bytes_per_s: 10e9 };
        let rep = replay(&tc, 5, &link);
        let min = rep.per_rank_secs.iter().copied().fold(f64::MAX, f64::min);
        assert!(rep.p2p_critical_secs <= min * 1.25, "{:?}", rep.per_rank_secs);
    }
}
