//! Trace ingestion: replay a [`TraceCollector`] recording against the
//! §III-C link model.
//!
//! The traced communicator backend records every point-to-point message a
//! run actually sent (the collectives decompose into sends, so the ring /
//! recursive-doubling / halo structure is all there) plus one event per
//! logical collective. This module prices that recording with the fitted
//! [`SrModel`] link:
//!
//! * **p2p replay** — every recorded message costs `alpha + bytes/bw`;
//!   messages sent by one rank serialize (a rank has one injection port),
//!   so the critical path is the busiest rank's total. This is the
//!   measured-structure prediction.
//! * **collective closed forms** — the same logical collectives priced
//!   with the §III-C formulas ([`allreduce_time`] for allreduces). Tests
//!   assert the two views agree, which is exactly the validation the paper
//!   performs between measured Aluminum traces and its model.

use super::{allreduce_time, SrModel};
use crate::comm::traced::TraceCollector;
use crate::comm::Collective;

/// Priced replay of one trace.
#[derive(Clone, Debug, Default)]
pub struct TraceReplay {
    /// Point-to-point messages recorded.
    pub messages: usize,
    /// Total payload bytes recorded.
    pub bytes: u64,
    /// Per-rank serialized send time (seconds) under the link model.
    pub per_rank_secs: Vec<f64>,
    /// Busiest-rank send time — the p2p critical-path estimate.
    pub p2p_critical_secs: f64,
    /// The same run's logical allreduces priced with the closed-form
    /// §III-C model (latency tree + ring bandwidth term).
    pub allreduce_model_secs: f64,
    /// Logical collectives recorded (allreduces, gathers, barriers, ...).
    pub collectives: usize,
    /// Halo-face payload bytes per spatial axis (D, H, W), from the axis
    /// tags the halo exchange attaches to its sends — the per-dimension
    /// halo volumes the §III-A cost model sums over.
    pub halo_bytes_axis: [u64; 3],
    /// Data-store redistribution payload bytes (`MsgTag::Redist`) — the
    /// §III-B staging volume; `iosim::pipeline::io_time_from_redist_trace`
    /// prices it against the analytic spatial-parallel I/O term.
    pub redist_bytes: u64,
}

/// Replay `trace` (from a world of `world` ranks) against `link`.
pub fn replay(trace: &TraceCollector, world: usize, link: &SrModel) -> TraceReplay {
    let msgs = trace.messages();
    let mut per_rank_secs = vec![0.0f64; world];
    let mut bytes = 0u64;
    for m in &msgs {
        bytes += m.bytes;
        if m.from < world {
            per_rank_secs[m.from] += link.time(m.bytes as f64);
        }
    }
    let p2p_critical_secs = per_rank_secs.iter().copied().fold(0.0, f64::max);
    let colls = trace.collectives();
    let allreduce_model_secs = colls
        .iter()
        .filter(|c| matches!(c.op, Collective::AllreduceRing | Collective::AllreduceRd))
        .map(|c| allreduce_time(4.0 * c.elems as f64, c.group_len, link))
        .sum();
    TraceReplay {
        messages: msgs.len(),
        bytes,
        per_rank_secs,
        p2p_critical_secs,
        allreduce_model_secs,
        collectives: colls.len(),
        halo_bytes_axis: trace.halo_bytes_per_axis(),
        redist_bytes: trace.redist_bytes(),
    }
}

/// Node-aware priced replay: the same p2p recording, split into intra-node
/// and inter-node hops and priced with **two** link models.
///
/// This is the multi-process view of the §III-C validation: under the
/// socket backend a world of `world` ranks packs `ranks_per_node`
/// consecutive ranks per node ([`node_of`](crate::comm::socket::node_of)),
/// so a message is an intra-node hop (shared memory / Unix socket —
/// `intra` link) exactly when sender and receiver share a node, and an
/// inter-node hop (TCP — `inter` link) otherwise. Pricing the two classes
/// separately is what makes the hierarchical allreduce
/// ([`allreduce_sum_hier`](crate::comm::allreduce_sum_hier)) show its
/// advantage: it moves the same payload but shifts hops from the `inter`
/// column into the `intra` column.
#[derive(Clone, Debug, Default)]
pub struct HierReplay {
    /// Messages whose endpoints share a node.
    pub intra_messages: usize,
    /// Messages crossing a node boundary.
    pub inter_messages: usize,
    /// Payload bytes on intra-node hops.
    pub intra_bytes: u64,
    /// Payload bytes on inter-node hops.
    pub inter_bytes: u64,
    /// Per-rank serialized send time (seconds) under the two-link model.
    pub per_rank_secs: Vec<f64>,
    /// Busiest-rank send time — the node-aware critical-path estimate.
    pub p2p_critical_secs: f64,
}

/// Replay `trace` (from a world of `world` ranks, `ranks_per_node` ranks
/// packed per node) pricing intra-node hops with `intra` and inter-node
/// hops with `inter`. With `ranks_per_node == 1` every hop is inter-node
/// and this degenerates to [`replay`] over the `inter` link.
pub fn replay_hier(
    trace: &TraceCollector,
    world: usize,
    ranks_per_node: usize,
    intra: &SrModel,
    inter: &SrModel,
) -> HierReplay {
    use crate::comm::socket::node_of;
    let mut out = HierReplay {
        per_rank_secs: vec![0.0f64; world],
        ..HierReplay::default()
    };
    for m in &trace.messages() {
        let same_node =
            node_of(m.from, ranks_per_node) == node_of(m.to, ranks_per_node);
        let link = if same_node { intra } else { inter };
        if same_node {
            out.intra_messages += 1;
            out.intra_bytes += m.bytes;
        } else {
            out.inter_messages += 1;
            out.inter_bytes += m.bytes;
        }
        if m.from < world {
            out.per_rank_secs[m.from] += link.time(m.bytes as f64);
        }
    }
    out.p2p_critical_secs = out.per_rank_secs.iter().copied().fold(0.0, f64::max);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{world, Communicator, Traced};
    use std::sync::Arc;
    use std::thread;

    fn run_traced_allreduce(n: usize, len: usize) -> Arc<TraceCollector> {
        let tc = Arc::new(TraceCollector::new());
        let eps: Vec<_> = world(n)
            .into_iter()
            .map(|e| Traced::new(e, tc.clone()))
            .collect();
        thread::scope(|s| {
            for ep in eps {
                s.spawn(move || {
                    let group: Vec<usize> = (0..n).collect();
                    let mut buf = vec![1.0f32; len];
                    ep.allreduce_sum(&mut buf, &group).unwrap();
                });
            }
        });
        tc
    }

    /// Ring allreduce over g ranks moves exactly 2(g-1) chunks per rank and
    /// 2(g-1) * len elements in total — the structure §III-C assumes.
    #[test]
    fn ring_trace_matches_theory() {
        let (n, len) = (4usize, 1000usize);
        let tc = run_traced_allreduce(n, len);
        assert_eq!(tc.message_count(), 2 * (n - 1) * n);
        assert_eq!(tc.total_bytes(), (2 * (n - 1) * len * 4) as u64);
        let per_rank = tc.per_rank_bytes(n);
        for (r, &b) in per_rank.iter().enumerate() {
            assert_eq!(b, (2 * (n - 1) * len * 4) as u64 / n as u64, "rank {r}");
        }
        assert_eq!(tc.collectives().len(), 1, "one logical allreduce");
    }

    /// The p2p replay of a ring allreduce agrees with the closed-form
    /// allreduce model: identical bandwidth term, latency within the
    /// per-message vs log-tree modeling difference.
    #[test]
    fn replay_agrees_with_closed_form() {
        let (n, len) = (4usize, 1 << 16);
        let tc = run_traced_allreduce(n, len);
        let link = SrModel { alpha_s: 2e-6, bytes_per_s: 50e9 };
        let rep = replay(&tc, n, &link);
        assert_eq!(rep.messages, 2 * (n - 1) * n);
        assert_eq!(rep.collectives, 1);
        assert!(rep.p2p_critical_secs > 0.0);
        let ratio = rep.p2p_critical_secs / rep.allreduce_model_secs;
        assert!(
            (0.5..2.0).contains(&ratio),
            "p2p replay {:.2e}s vs model {:.2e}s (ratio {ratio:.2})",
            rep.p2p_critical_secs,
            rep.allreduce_model_secs,
        );
    }

    /// Halo sends carry their axis tag through to the replay report.
    #[test]
    fn replay_accounts_halo_axes() {
        use crate::comm::halo;
        use crate::partition::{GridTopology, SpatialGrid};
        use crate::tensor::Tensor;
        let grid = SpatialGrid::new(2, 1, 2);
        let topo = GridTopology::new(1, grid);
        let tc = Arc::new(TraceCollector::new());
        let eps: Vec<_> = world(grid.ways())
            .into_iter()
            .map(|e| Traced::new(e, tc.clone()))
            .collect();
        thread::scope(|s| {
            for (r, ep) in eps.into_iter().enumerate() {
                let nbrs = topo.neighbors(r);
                s.spawn(move || {
                    let shard = Tensor::zeros(&[1, 1, 2, 2, 2]);
                    halo::exchange_forward_grid(&ep, &shard, 1, &nbrs,
                                                [true, true, true], None)
                        .unwrap();
                });
            }
        });
        let link = SrModel { alpha_s: 1e-6, bytes_per_s: 10e9 };
        let rep = replay(&tc, 4, &link);
        // D faces: 4 sends of a (1,1,1,2,2) face = 16 B; H is unsplit
        // (zero-pad only); W faces go out after the D+H pads: 4 sends of
        // (1,1,4,4,1) = 64 B.
        assert_eq!(rep.halo_bytes_axis, [4 * 4 * 4, 0, 4 * 16 * 4]);
        assert_eq!(rep.bytes, (4 * 4 * 4 + 4 * 16 * 4) as u64);
    }

    /// Store-redistribution sends carry `MsgTag::Redist` into the replay,
    /// separately from halo and generic traffic.
    #[test]
    fn replay_accounts_redistribution_bytes() {
        use crate::comm::MsgTag;
        let tc = Arc::new(TraceCollector::new());
        let eps: Vec<_> = world(2)
            .into_iter()
            .map(|e| Traced::new(e, tc.clone()))
            .collect();
        thread::scope(|s| {
            for ep in eps {
                s.spawn(move || {
                    let peer = 1 - ep.rank();
                    ep.send_tagged(peer, vec![0.0; 50], MsgTag::Redist);
                    ep.send(peer, vec![0.0; 7]); // generic: not redist
                    ep.recv(peer).unwrap();
                    ep.recv(peer).unwrap();
                });
            }
        });
        let link = SrModel { alpha_s: 1e-6, bytes_per_s: 10e9 };
        let rep = replay(&tc, 2, &link);
        assert_eq!(rep.redist_bytes, 2 * 50 * 4);
        assert_eq!(rep.bytes, (2 * 50 * 4 + 2 * 7 * 4) as u64);
        assert_eq!(rep.halo_bytes_axis, [0; 3]);
    }

    fn run_traced_hier(n: usize, rpn: usize, len: usize) -> Arc<TraceCollector> {
        let tc = Arc::new(TraceCollector::new());
        let eps: Vec<_> = world(n)
            .into_iter()
            .map(|e| Traced::new(e, tc.clone()))
            .collect();
        thread::scope(|s| {
            for ep in eps {
                s.spawn(move || {
                    let group: Vec<usize> = (0..n).collect();
                    let mut buf = vec![1.0f32; len];
                    crate::comm::allreduce_sum_hier(&ep, &mut buf, &group, rpn)
                        .unwrap();
                });
            }
        });
        tc
    }

    /// The hierarchical allreduce's hop split: the member legs stay
    /// on-node, only the leader ring crosses nodes.
    #[test]
    fn hier_replay_splits_hops() {
        let (n, rpn, len) = (4usize, 2usize, 1000usize);
        let tc = run_traced_hier(n, rpn, len);
        let intra = SrModel { alpha_s: 1e-7, bytes_per_s: 200e9 };
        let inter = SrModel { alpha_s: 2e-6, bytes_per_s: 12e9 };
        let rep = replay_hier(&tc, n, rpn, &intra, &inter);
        // member -> leader (Hier(0)) and leader -> member (Hier(1)), one
        // full buffer each way on both nodes
        assert_eq!(rep.intra_messages, 4);
        assert_eq!(rep.intra_bytes, 4 * (len * 4) as u64);
        // leader ring over 2 leaders: each sends one reduce-scatter chunk
        // and one allgather chunk of len/2 elements
        assert_eq!(rep.inter_messages, 4);
        assert_eq!(rep.inter_bytes, (2 * len * 4) as u64);
        // only the leaders (ranks 0 and 2) touch the slow link, so the
        // critical path is a leader's and members are strictly cheaper
        assert!(rep.per_rank_secs[0] > rep.per_rank_secs[1]);
        let leader_max = rep.per_rank_secs[0].max(rep.per_rank_secs[2]);
        assert_eq!(rep.p2p_critical_secs, leader_max);
    }

    /// ranks_per_node 1 puts every hop on the inter link: identical to the
    /// flat replay over that link.
    #[test]
    fn hier_replay_degenerates_to_flat() {
        let tc = run_traced_allreduce(4, 512);
        let intra = SrModel { alpha_s: 1e-7, bytes_per_s: 200e9 };
        let inter = SrModel { alpha_s: 2e-6, bytes_per_s: 12e9 };
        let flat = replay(&tc, 4, &inter);
        let hier = replay_hier(&tc, 4, 1, &intra, &inter);
        assert_eq!(hier.intra_messages, 0);
        assert_eq!(hier.intra_bytes, 0);
        assert_eq!(hier.inter_bytes, flat.bytes);
        assert_eq!(hier.per_rank_secs, flat.per_rank_secs);
    }

    /// The two-level allreduce moves fewer inter-node bytes than the flat
    /// ring for the same payload — the HyPar-Flow argument, in bytes. With
    /// 4 ranks at 2 per node the flat ring crosses nodes on 2 of its 4
    /// directed edges (3072 B/sender here), the hier leader ring on all of
    /// its 2 edges but only len/2-chunks (2048 B/sender).
    #[test]
    fn hier_moves_fewer_inter_node_bytes_than_flat() {
        let (n, rpn, len) = (4usize, 2usize, 1024usize);
        let link = SrModel { alpha_s: 1e-6, bytes_per_s: 10e9 };
        let flat = replay_hier(&run_traced_allreduce(n, len), n, rpn, &link, &link);
        let hier = replay_hier(&run_traced_hier(n, rpn, len), n, rpn, &link, &link);
        assert!(
            hier.inter_bytes < flat.inter_bytes,
            "hier {} vs flat {} inter-node bytes",
            hier.inter_bytes,
            flat.inter_bytes
        );
        assert_eq!(flat.inter_bytes, (2 * 6 * (len / 4) * 4) as u64);
        assert_eq!(hier.inter_bytes, (2 * len * 4) as u64);
    }

    /// Per-rank send loads in a ring are balanced.
    #[test]
    fn ring_loads_are_balanced() {
        let tc = run_traced_allreduce(5, 500);
        let link = SrModel { alpha_s: 1e-6, bytes_per_s: 10e9 };
        let rep = replay(&tc, 5, &link);
        let min = rep.per_rank_secs.iter().copied().fold(f64::MAX, f64::min);
        assert!(rep.p2p_critical_secs <= min * 1.25, "{:?}", rep.per_rank_secs);
    }
}
