//! Scaling sweeps: the data series behind Figs. 4, 5, 7 and 8.

use super::PerfModel;
use crate::config::ClusterConfig;
use crate::iosim::pfs::Pfs;
use crate::iosim::pipeline::{io_time_per_iter, iteration_time, overlaps, IoStrategy};
use crate::models::AnalyticModel;
use crate::partition::Grid4;

/// One point of a scaling curve.
#[derive(Clone, Debug)]
pub struct ScalePoint {
    pub gpus: usize,
    pub ways: usize,
    /// Spatial split (D, H, W) behind `ways` (`(ways, 1, 1)` for the
    /// depth-only sweeps).
    pub grid: (usize, usize, usize),
    pub n: usize,
    pub iter_s: f64,
    pub model_iter_s: f64, // the §III-C prediction (shaded bars in Fig. 4)
    pub samples_per_s: f64,
    pub fwd_s: f64,
    pub bwd_s: f64,
    /// Exposed (non-overlapped) gradient-allreduce tail, seconds.
    pub exposed_ar_s: f64,
    /// Per-sample halo volume (one face per partitioned axis per conv
    /// layer), bytes — the BENCH artifact's deterministic metric.
    pub halo_bytes: f64,
    pub io_s: f64,
    pub feasible: bool,
}

/// Strong scaling (Fig. 4 / Fig. 7): fixed global mini-batch `n`, growing
/// spatial ways. `io` selects the ingestion strategy (Fig. 5 uses
/// `SampleParallelCached`).
pub fn strong_scaling(
    model: &AnalyticModel,
    cluster: &ClusterConfig,
    n: usize,
    ways_list: &[usize],
    io: IoStrategy,
) -> Vec<ScalePoint> {
    let grids: Vec<(usize, usize, usize)> =
        ways_list.iter().map(|&w| (w, 1, 1)).collect();
    strong_scaling_grids(model, cluster, n, &grids, io)
}

/// Strong scaling over explicit (D, H, W) spatial splits — the §III-A
/// multi-axis sweep `examples/strong_scaling_sim` and the bench artifact
/// run. Depth-only entries reproduce [`strong_scaling`] exactly.
pub fn strong_scaling_grids(
    model: &AnalyticModel,
    cluster: &ClusterConfig,
    n: usize,
    grids: &[(usize, usize, usize)],
    io: IoStrategy,
) -> Vec<ScalePoint> {
    let pm = PerfModel::new(cluster);
    let pfs = Pfs::default();
    let sample_bytes = 4.0 * model.in_channels as f64
        * (model.input_size as f64).powi(3);
    grids
        .iter()
        .map(|&(d, h, w)| {
            let grid = Grid4 { n, d, h, w };
            let ways = grid.spatial_ways();
            let it = pm.iteration(model, grid, n, cluster.gpu_mem_gib);
            let io_s = io_time_per_iter(io, &pfs, cluster, sample_bytes, n, ways);
            let iter_s = iteration_time(it.total, io_s, overlaps(io));
            ScalePoint {
                gpus: grid.world_size(),
                ways,
                grid: (d, h, w),
                n,
                iter_s,
                model_iter_s: it.total,
                samples_per_s: n as f64 / iter_s,
                fwd_s: it.fwd,
                bwd_s: it.bwd.max(it.allreduce),
                exposed_ar_s: (it.allreduce - it.bwd).max(0.0),
                halo_bytes: grid_halo_bytes(model, grid),
                io_s,
                feasible: it.feasible,
            }
        })
        .collect()
}

/// Weak scaling (Fig. 8): fixed per-group batch, growing group count at a
/// fixed spatial partitioning.
pub fn weak_scaling(
    model: &AnalyticModel,
    cluster: &ClusterConfig,
    ways: usize,
    groups_list: &[usize],
    per_group_batch: usize,
) -> Vec<ScalePoint> {
    let pm = PerfModel::new(cluster);
    groups_list
        .iter()
        .map(|&groups| {
            let n = groups * per_group_batch;
            let grid = Grid4 { n: groups, d: ways, h: 1, w: 1 };
            let it = pm.iteration(model, grid, n, cluster.gpu_mem_gib);
            ScalePoint {
                gpus: grid.world_size(),
                ways,
                grid: (ways, 1, 1),
                n,
                iter_s: it.total,
                model_iter_s: it.total,
                samples_per_s: it.samples_per_s,
                fwd_s: it.fwd,
                bwd_s: it.bwd.max(it.allreduce),
                exposed_ar_s: (it.allreduce - it.bwd).max(0.0),
                halo_bytes: grid_halo_bytes(model, grid),
                io_s: 0.0,
                feasible: it.feasible,
            }
        })
        .collect()
}

/// Throughput speedup of the last point relative to the first.
pub fn speedup(points: &[ScalePoint]) -> f64 {
    points.last().unwrap().samples_per_s / points[0].samples_per_s
}

/// Per-sample halo volume of `model` under `grid`: one face per
/// partitioned axis per conv layer, f32 bytes — independent of the rank
/// count along an axis (faces shrink as the *other* axes split).
pub fn grid_halo_bytes(model: &AnalyticModel, grid: Grid4) -> f64 {
    model
        .layers
        .iter()
        .map(|l| (0..3).map(|a| l.halo_face_bytes_axis(grid, a)).sum::<f64>())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::cosmoflow_paper;

    /// Fig. 5: with sample-parallel I/O, strong scaling stalls — iteration
    /// time barely improves with 4x GPUs; with spatially-parallel I/O it
    /// keeps scaling.
    #[test]
    fn fig5_io_ablation() {
        let m = cosmoflow_paper(512, false);
        let cl = ClusterConfig::default();
        let bad = strong_scaling(&m, &cl, 64, &[8, 16, 32], IoStrategy::SampleParallelCached);
        let good = strong_scaling(&m, &cl, 64, &[8, 16, 32], IoStrategy::SpatialParallel);
        let bad_speedup = speedup(&bad);
        let good_speedup = speedup(&good);
        assert!(good_speedup > 1.9, "spatial-parallel speedup {good_speedup}");
        assert!(
            bad_speedup < 0.75 * good_speedup,
            "sample-parallel I/O should stall scaling: {bad_speedup} vs {good_speedup}"
        );
        // I/O is fully overlapped in the good pipeline at this scale
        for p in &good {
            assert!(p.io_s < p.model_iter_s, "io visible at {} ways", p.ways);
        }
    }

    /// Fig. 8 (128^3, pure data parallel): near-linear weak scaling —
    /// paper reports 65.4x on 512 GPUs over 4.
    #[test]
    fn fig8_weak_scaling_dataparallel() {
        let m = cosmoflow_paper(128, false);
        let cl = ClusterConfig::default();
        let pts = weak_scaling(&m, &cl, 1, &[4, 16, 64, 128, 512], 8);
        let s = speedup(&pts);
        assert!((50.0..129.0).contains(&s), "weak scaling 4->512 GPUs: {s:.1}x");
        // hybrid configs trade throughput for memory (paper: "increasing
        // spatial parallelism results in lower throughput")
        let hybrid = weak_scaling(&m, &cl, 4, &[1, 4, 16, 32, 128], 8);
        assert!(hybrid[4].samples_per_s < pts[4].samples_per_s);
    }

    /// Fig. 8 (512^3): weak scaling at 8/16/32-way to 2048 GPUs — the
    /// paper reports 147x / 71x / 37x over the 1-group baselines.
    #[test]
    fn fig8_weak_scaling_512() {
        let m = cosmoflow_paper(512, false);
        let cl = ClusterConfig::default();
        for (ways, max_groups, paper) in [(8usize, 256usize, 147.3), (16, 128, 71.3), (32, 64, 37.2)] {
            let pts = weak_scaling(&m, &cl, ways, &[1, max_groups], 1);
            let s = speedup(&pts);
            // qualitative: close to linear in groups, within a wide band of
            // the paper's number
            assert!(
                s > 0.35 * max_groups as f64 && s <= 1.02 * max_groups as f64,
                "{ways}-way: {s:.1}x vs paper {paper}"
            );
        }
    }

    /// 3D grid sweeps: depth-only entries reproduce `strong_scaling`, and
    /// 3D splits of the same GPU count carry less halo volume.
    #[test]
    fn grid_sweep_consistent_with_depth_only() {
        let m = cosmoflow_paper(512, false);
        let cl = ClusterConfig::default();
        let a = strong_scaling(&m, &cl, 4, &[8], IoStrategy::SpatialParallel);
        let b = strong_scaling_grids(&m, &cl, 4, &[(8, 1, 1), (2, 2, 2)],
                                     IoStrategy::SpatialParallel);
        assert_eq!(a[0].iter_s, b[0].iter_s);
        assert_eq!(a[0].grid, (8, 1, 1));
        assert_eq!(b[0].gpus, b[1].gpus);
        assert!(b[1].halo_bytes < b[0].halo_bytes,
                "2x2x2 halo {} must be below 8x1x1 {}", b[1].halo_bytes,
                b[0].halo_bytes);
        // the committed BENCH_baseline.json values
        assert_eq!(b[0].halo_bytes, 11_747_328.0);
        assert_eq!(b[1].halo_bytes, 8_810_496.0);
    }

    #[test]
    fn strong_scaling_monotone_until_overdecomposed() {
        let m = cosmoflow_paper(512, false);
        let cl = ClusterConfig::default();
        let pts = strong_scaling(&m, &cl, 4, &[4, 8, 16, 32, 64], IoStrategy::SpatialParallel);
        // throughput improves early, then flattens/drops when shards get thin
        assert!(pts[1].samples_per_s > pts[0].samples_per_s);
        let gain_late = pts[4].samples_per_s / pts[3].samples_per_s;
        let gain_early = pts[1].samples_per_s / pts[0].samples_per_s;
        assert!(gain_late < gain_early, "over-decomposition must bite: {gain_early} vs {gain_late}");
    }
}
