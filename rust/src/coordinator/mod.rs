//! The experiment coordinator: regenerates every table and figure of the
//! paper's evaluation (§V) from the analytic models, the §III-C
//! performance model, the cluster simulator and the I/O pipeline model.
//! Shared by the `hydra3d` CLI and the `cargo bench` harnesses.

use crate::config::ClusterConfig;
use crate::iosim::pipeline::IoStrategy;
use crate::models::{cosmoflow_paper, unet3d_paper};
use crate::partition::Grid4;
use crate::perfmodel::scaling::{speedup, strong_scaling, weak_scaling};
use crate::perfmodel::PerfModel;
use crate::sim::simulate_iteration;

/// Table I: CosmoFlow architecture + analytic cost columns.
pub fn table1() -> String {
    let mut out = String::from(
        "Table I: CosmoFlow network analytics (paper values in parentheses)\n\
         ------------------------------------------------------------------\n\
         W_i    conv GFlop (paper)   fwd GFlop (paper)   mem GiB (paper)   params\n",
    );
    let paper = [(128usize, 55.55, 18.52, 0.824), (256, 443.8, 147.9, 6.59),
                 (512, 3550.0, 1183.0, 52.7)];
    for (wi, pt, pf, pm) in paper {
        let m = cosmoflow_paper(wi, false);
        out.push_str(&format!(
            "{:<6} {:>8.2} ({:>7.2})   {:>8.2} ({:>6.1})   {:>6.2} ({:>5.3})   {:.2}M\n",
            wi,
            m.conv_total_gflops(),
            pt,
            m.conv_fwd_gflops(),
            pf,
            m.activation_gib(),
            pm,
            m.param_count() as f64 / 1e6,
        ));
    }
    out.push_str(&format!(
        "min GPUs/sample @16GiB: 512^3 = {} (paper: 4), +BN = {} (paper: 8)\n",
        cosmoflow_paper(512, false).min_gpus_per_sample(16.0, false),
        cosmoflow_paper(512, false).min_gpus_per_sample(16.0, true),
    ));
    out
}

/// Table II: achieved conv performance relative to the cuDNN kernel peak.
pub fn table2(cluster: &ClusterConfig) -> String {
    let m = cosmoflow_paper(512, false);
    let pm = PerfModel::new(cluster);
    let mut out = String::from(
        "Table II: distributed conv vs kernel-only peak, 512^3, N=64\n\
         Depth    Layer   Rel [%]   (paper)\n",
    );
    for (ways, layer, paper) in [
        (8usize, None, 95.6),
        (32, None, 82.4),
        (8, Some("conv1"), 93.8),
        (32, Some("conv1"), 64.7),
    ] {
        let rel = pm.conv_rel_to_peak(&m, Grid4::depth_only(64, ways), 64, layer);
        out.push_str(&format!(
            "{:>2}-way   {:<6}  {:>6.1}    ({:.1})\n",
            ways,
            layer.unwrap_or("All"),
            rel * 100.0,
            paper,
        ));
    }
    out
}

fn render_points(points: &[crate::perfmodel::scaling::ScalePoint], label: &str)
                 -> String {
    let mut out = format!("{label}\n  GPUs   ways     N   iter[ms]  model[ms]  samples/s  io[ms]\n");
    for p in points {
        out.push_str(&format!(
            "{:>6} {:>6} {:>5}   {:>8.1}   {:>8.1}   {:>8.2}  {:>6.1}{}\n",
            p.gpus,
            p.ways,
            p.n,
            p.iter_s * 1e3,
            p.model_iter_s * 1e3,
            p.samples_per_s,
            p.io_s * 1e3,
            if p.feasible { "" } else { "  (OOM)" },
        ));
    }
    out.push_str(&format!("  speedup (last/first): {:.2}x\n", speedup(points)));
    out
}

/// Fig. 4: strong scaling of CosmoFlow 512^3 across mini-batch sizes.
pub fn fig4(cluster: &ClusterConfig) -> String {
    let m = cosmoflow_paper(512, false);
    let mut out = String::from(
        "Fig. 4: CosmoFlow 512^3 strong scaling (spatially-parallel I/O)\n",
    );
    for n in [1usize, 2, 4, 16, 64] {
        let ways: Vec<usize> = [8usize, 16, 32, 64]
            .iter()
            .copied()
            .filter(|w| n * w <= 2048)
            .collect();
        let pts = strong_scaling(&m, cluster, n, &ways, IoStrategy::SpatialParallel);
        out.push_str(&render_points(&pts, &format!("-- N = {n}")));
    }
    out.push_str("paper headlines: 1.98x @ 512/128 GPUs (N=16), 1.77x @ 2048/512 (N=64)\n");
    out
}

/// Fig. 5: the same sweep without spatially-parallel I/O.
pub fn fig5(cluster: &ClusterConfig) -> String {
    let m = cosmoflow_paper(512, false);
    let mut out = String::from(
        "Fig. 5: CosmoFlow 512^3 strong scaling WITHOUT spatially-parallel I/O\n\
         (distributed caching only; single reader per sample + scatter)\n",
    );
    let pts = strong_scaling(&m, cluster, 64, &[8, 16, 32],
                             IoStrategy::SampleParallelCached);
    out.push_str(&render_points(&pts, "-- N = 64, sample-parallel I/O"));
    let good = strong_scaling(&m, cluster, 64, &[8, 16, 32],
                              IoStrategy::SpatialParallel);
    out.push_str(&render_points(&good, "-- N = 64, spatially-parallel I/O (ref)"));
    out
}

/// Fig. 6: single-GPU execution timelines, 8 vs 16 GPUs/sample, N=4.
pub fn fig6(cluster: &ClusterConfig, emit_trace: Option<&std::path::Path>) -> String {
    let m = cosmoflow_paper(512, false);
    let mut out = String::from("Fig. 6: execution timelines (512^3, N=4)\n");
    for ways in [8usize, 16] {
        let t = simulate_iteration(&m, cluster, Grid4::depth_only(4, ways), 4);
        out.push_str(&format!(
            "\n-- {} GPUs/sample ({} total), iteration {:.1} ms, main occupancy {:.1}%\n{}",
            ways,
            4 * ways,
            t.iter_s * 1e3,
            t.main_occupancy() * 100.0,
            t.ascii(96),
        ));
        if let Some(dir) = emit_trace {
            let path = dir.join(format!("fig6_timeline_{ways}way.trace.json"));
            let _ = std::fs::write(&path, t.chrome_trace());
            out.push_str(&format!("   chrome trace -> {}\n", path.display()));
        }
    }
    let s = simulate_iteration(&m, cluster, Grid4::depth_only(4, 8), 4).iter_s
        / simulate_iteration(&m, cluster, Grid4::depth_only(4, 16), 4).iter_s;
    out.push_str(&format!("\n8->16 way speedup: {s:.2}x (paper: ~1.66x)\n"));
    out
}

/// Fig. 7: 3D U-Net 256^3 strong scaling.
pub fn fig7(cluster: &ClusterConfig) -> String {
    let m = unet3d_paper(256, 3);
    let mut out = String::from("Fig. 7: 3D U-Net 256^3 strong scaling\n");
    for n in [1usize, 4, 16] {
        let ways: Vec<usize> = [16usize, 32, 64]
            .iter()
            .copied()
            .filter(|w| n * w <= 2048)
            .collect();
        let pts = strong_scaling(&m, cluster, n, &ways, IoStrategy::SpatialParallel);
        out.push_str(&render_points(&pts, &format!("-- N = {n}")));
    }
    out.push_str("paper headline: 1.42x @ 512/256 GPUs (N=16)\n");
    out
}

/// Fig. 8: weak scaling of CosmoFlow (128^3 and 512^3) and the U-Net.
pub fn fig8(cluster: &ClusterConfig) -> String {
    let mut out = String::from("Fig. 8: weak scaling (per-group batch fixed)\n");
    let cf128 = cosmoflow_paper(128, false);
    for (label, ways) in [("data-parallel", 1usize), ("4-way", 4), ("8-way", 8)] {
        let groups: Vec<usize> = [1usize, 4, 16, 64, 128, 512]
            .iter()
            .copied()
            .filter(|g| g * ways <= 2048)
            .collect();
        let pts = weak_scaling(&cf128, cluster, ways, &groups, 8);
        out.push_str(&render_points(&pts, &format!("-- CosmoFlow 128^3, {label}")));
    }
    let cf512 = cosmoflow_paper(512, false);
    for (ways, paper) in [(8usize, 147.3), (16, 71.3), (32, 37.2)] {
        let groups: Vec<usize> = [1usize, 2, 8, 32, 2048 / ways].to_vec();
        let pts = weak_scaling(&cf512, cluster, ways, &groups, 1);
        out.push_str(&render_points(
            &pts,
            &format!("-- CosmoFlow 512^3, {ways}-way (paper: {paper}x @2048)"),
        ));
    }
    let unet = unet3d_paper(256, 3);
    let pts = weak_scaling(&unet, cluster, 32, &[1, 2, 8, 32], 1);
    out.push_str(&render_points(&pts, "-- 3D U-Net 256^3, 32-way (paper: 28.4x @1024)"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reports_render() {
        let cl = ClusterConfig::default();
        for s in [
            table1(),
            table2(&cl),
            fig4(&cl),
            fig5(&cl),
            fig6(&cl, None),
            fig7(&cl),
            fig8(&cl),
        ] {
            assert!(s.len() > 100, "report too short:\n{s}");
        }
    }

    #[test]
    fn table1_mentions_paper_values() {
        let t = table1();
        assert!(t.contains("3550"));
        assert!(t.contains("52.7"));
    }
}
