//! # HYDRA-3D
//!
//! Reproduction of *"The Case for Strong Scaling in Deep Learning: Training
//! Large 3D CNNs with Hybrid Parallelism"* (Oyama et al., 2020) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! This crate is **Layer 3**: the distributed coordinator. It owns
//!
//! * the process topology and the multi-rank communicator — in-process
//!   channel worlds for tests plus a real multi-process socket backend
//!   (Unix-domain/TCP transport, rank launcher, hierarchical two-level
//!   collectives) behind the same trait ([`comm`], [`partition`]),
//! * the hybrid-parallel training engine — full D×H×W spatial partitioning
//!   with per-axis face halo exchange, distributed batch-norm,
//!   data-parallel gradient allreduce ([`engine`]),
//! * the spatially-parallel I/O pipeline: hyperslab readers and the
//!   distributed in-memory data store ([`data`], [`iosim`]),
//! * the paper's §III-C performance model and a discrete-event cluster
//!   simulator used to regenerate the paper-scale figures ([`perfmodel`],
//!   [`sim`]),
//! * the PJRT runtime that loads and executes the AOT-compiled JAX/Pallas
//!   artifacts ([`runtime`]); Python never runs at training time,
//! * the `hydra3d verify` static analysis: dry-run extraction of any
//!   configuration's communication schedule and checks for send/recv
//!   matching, collective agreement, tag discipline, deadlock freedom and
//!   buffer-pool discipline ([`analysis`]).
//!
//! See `DESIGN.md` for the full system inventory and the experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod analysis;
pub mod util;
pub mod tensor;
pub mod partition;
pub mod comm;
pub mod config;
pub mod runtime;
pub mod models;
pub mod engine;
pub mod data;
pub mod iosim;
pub mod perfmodel;
pub mod sim;
pub mod coordinator;
