//! Packed f32 n-d tensors (NCDHW convention for activations).
//!
//! This is the host-side tensor the coordinator shuffles between the PJRT
//! executables, the communicator, and the data pipeline. It deliberately
//! supports exactly what the engine hot path needs — depth-slab views
//! (hyperslabs), halo padding, per-channel reductions for distributed
//! batch-norm, and the elementwise tails (activations/dropout) the engine
//! keeps on the Rust side. Heavy lifting (conv/pool/fc) happens inside the
//! AOT executables.
//!
//! Depth slabs of an NCDHW tensor are contiguous per (n, c) pair, so every
//! slab copy below is a strided sequence of `copy_from_slice` memcpys —
//! this is the same insight behind the paper's optimized halo pack/unpack
//! CUDA kernels (§III-A), and it is benchmarked in `benches/micro.rs`.

use crate::util::par;
use anyhow::{bail, Result};

pub mod pool;

/// A dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} != data len {}", data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on non-scalar {:?}", self.shape);
        self.data[0]
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    // ---- NCDHW geometry ---------------------------------------------------

    fn dims5(&self) -> (usize, usize, usize, usize, usize) {
        assert_eq!(self.shape.len(), 5, "expected 5-d NCDHW, got {:?}", self.shape);
        (self.shape[0], self.shape[1], self.shape[2], self.shape[3], self.shape[4])
    }

    // ---- axis-parameterized spatial slabs (axis 2=D, 3=H, 4=W) ------------

    /// (outer, axis_len, inner) strides of spatial `axis` of an NCDHW
    /// tensor: a slab `[i0, i0+len)` along the axis is `outer` contiguous
    /// runs of `len * inner` elements, so every slab op below is a strided
    /// sequence of `copy_from_slice` memcpys regardless of the axis.
    fn axis_geom(&self, axis: usize) -> (usize, usize, usize) {
        assert_eq!(self.shape.len(), 5, "expected 5-d NCDHW, got {:?}", self.shape);
        assert!((2..=4).contains(&axis), "spatial axis {axis} not in 2..=4");
        let outer: usize = self.shape[..axis].iter().product();
        let inner: usize = self.shape[axis + 1..].iter().product();
        (outer, self.shape[axis], inner)
    }

    /// Copy out the slab `[i0, i0+len)` along spatial `axis`.
    pub fn slice_ax(&self, axis: usize, i0: usize, len: usize) -> Tensor {
        let mut shape = self.shape.clone();
        shape[axis] = len;
        let mut out = Tensor::zeros(&shape);
        self.slice_ax_into(axis, i0, len, &mut out.data);
        out
    }

    /// Copy the slab `[i0, i0+len)` along spatial `axis` into the flat
    /// buffer `out` (length `outer * len * inner`) — the zero-alloc pack
    /// primitive behind pooled halo sends.
    pub fn slice_ax_into(&self, axis: usize, i0: usize, len: usize, out: &mut [f32]) {
        let (outer, alen, inner) = self.axis_geom(axis);
        assert!(i0 + len <= alen,
                "slab [{i0}, {}) out of axis {axis} extent {alen}", i0 + len);
        let run = len * inner;
        assert_eq!(out.len(), outer * run, "slice_ax_into buffer size");
        for o in 0..outer {
            let src = (o * alen + i0) * inner;
            let dst = o * run;
            out[dst..dst + run].copy_from_slice(&self.data[src..src + run]);
        }
    }

    /// Write `slab` into offset `i0` along spatial `axis` of self.
    pub fn set_slice_ax(&mut self, axis: usize, i0: usize, slab: &Tensor) {
        let (outer, _, inner) = self.axis_geom(axis);
        let (souter, slen, sinner) = slab.axis_geom(axis);
        assert!((souter, sinner) == (outer, inner),
                "slab {:?} @{i0} (axis {axis}) into {:?}", slab.shape, self.shape);
        self.set_slice_ax_from(axis, i0, slen, &slab.data);
    }

    /// Write the flat buffer `src` (slab layout, `outer * len * inner`) into
    /// offset `i0` along spatial `axis` — the zero-alloc unpack primitive
    /// behind pooled halo receives.
    pub fn set_slice_ax_from(&mut self, axis: usize, i0: usize, len: usize, src: &[f32]) {
        let (outer, alen, inner) = self.axis_geom(axis);
        let run = len * inner;
        assert!(i0 + len <= alen && src.len() == outer * run,
                "slab [{i0}, {}) (axis {axis}) into {:?}", i0 + len, self.shape);
        for o in 0..outer {
            let dst = (o * alen + i0) * inner;
            let s = o * run;
            self.data[dst..dst + run].copy_from_slice(&src[s..s + run]);
        }
    }

    /// Accumulate (`+=`) `slab` into offset `i0` along spatial `axis` — the
    /// reverse halo exchange (gradients of shared faces are summed into the
    /// owner).
    pub fn add_slice_ax(&mut self, axis: usize, i0: usize, slab: &Tensor) {
        let (outer, _, inner) = self.axis_geom(axis);
        let (souter, slen, sinner) = slab.axis_geom(axis);
        assert!((souter, sinner) == (outer, inner),
                "slab {:?} @{i0} (axis {axis}) into {:?}", slab.shape, self.shape);
        self.add_slice_ax_from(axis, i0, slen, &slab.data);
    }

    /// Accumulate the flat buffer `src` (slab layout) into offset `i0`
    /// along spatial `axis` (flat-buffer variant of [`Tensor::add_slice_ax`]).
    pub fn add_slice_ax_from(&mut self, axis: usize, i0: usize, len: usize, src: &[f32]) {
        let (outer, alen, inner) = self.axis_geom(axis);
        let run = len * inner;
        assert!(i0 + len <= alen && src.len() == outer * run,
                "slab [{i0}, {}) (axis {axis}) into {:?}", i0 + len, self.shape);
        for o in 0..outer {
            let dst = (o * alen + i0) * inner;
            let s = o * run;
            for i in 0..run {
                self.data[dst + i] += src[s + i];
            }
        }
    }

    /// New tensor with `lo` zero faces before and `hi` after along `axis`.
    ///
    /// Single-pass construction (zero-fill and copy interleaved per outer
    /// block) — this runs once per conv layer per sample per partitioned
    /// axis in the halo exchange, and the two-pass zeros+copy version cost
    /// ~1.7x as much memory traffic (EXPERIMENTS.md §Perf).
    pub fn pad_ax(&self, axis: usize, lo: usize, hi: usize) -> Tensor {
        let (outer, alen, inner) = self.axis_geom(axis);
        let mut shape = self.shape.clone();
        shape[axis] = alen + lo + hi;
        let mut data = Vec::with_capacity(outer * (alen + lo + hi) * inner);
        for o in 0..outer {
            data.resize(data.len() + lo * inner, 0.0);
            let src = o * alen * inner;
            data.extend_from_slice(&self.data[src..src + alen * inner]);
            data.resize(data.len() + hi * inner, 0.0);
        }
        Tensor { shape, data }
    }

    /// [`Tensor::pad_ax`] into a caller-provided (typically pooled) tensor
    /// of the padded shape: zero faces + interior copy, no allocation.
    pub fn pad_ax_into(&self, axis: usize, lo: usize, hi: usize, out: &mut Tensor) {
        let (outer, alen, inner) = self.axis_geom(axis);
        let (oo, olen, oi) = out.axis_geom(axis);
        assert!((oo, olen, oi) == (outer, alen + lo + hi, inner),
                "pad_ax_into {:?} +({lo},{hi}) axis {axis} into {:?}",
                self.shape, out.shape);
        for o in 0..outer {
            let dst = o * olen * inner;
            out.data[dst..dst + lo * inner].fill(0.0);
            let src = o * alen * inner;
            out.data[dst + lo * inner..dst + (lo + alen) * inner]
                .copy_from_slice(&self.data[src..src + alen * inner]);
            out.data[dst + (lo + alen) * inner..dst + olen * inner].fill(0.0);
        }
    }

    /// Drop `lo` faces from the front and `hi` from the back along `axis`.
    pub fn crop_ax(&self, axis: usize, lo: usize, hi: usize) -> Tensor {
        let (_, alen, _) = self.axis_geom(axis);
        self.slice_ax(axis, lo, alen - lo - hi)
    }

    /// Copy out the (D, H, W) sub-cuboid at `off` of extents `len` — the
    /// general hyperslab read behind the 3D-grid flatten scatter.
    pub fn block3(&self, off: [usize; 3], len: [usize; 3]) -> Tensor {
        let (n, c, _, _, _) = self.dims5();
        let mut out = Tensor::zeros(&[n, c, len[0], len[1], len[2]]);
        self.block3_into(off, len, &mut out.data);
        out
    }

    /// Copy the sub-cuboid at `off`/`len` into the flat buffer `out`
    /// (block layout, `n*c*len[0]*len[1]*len[2]` elements) — the fused
    /// halo-pack primitive: faces go straight into pooled send buffers.
    pub fn block3_into(&self, off: [usize; 3], len: [usize; 3], out: &mut [f32]) {
        let (n, c, d, h, w) = self.dims5();
        assert!(off[0] + len[0] <= d && off[1] + len[1] <= h && off[2] + len[2] <= w,
                "block @{off:?}+{len:?} out of {:?}", self.shape);
        assert_eq!(out.len(), n * c * len[0] * len[1] * len[2], "block3_into buffer");
        for nc in 0..n * c {
            for dd in 0..len[0] {
                for hh in 0..len[1] {
                    let src = ((nc * d + off[0] + dd) * h + off[1] + hh) * w + off[2];
                    let dst = ((nc * len[0] + dd) * len[1] + hh) * len[2];
                    out[dst..dst + len[2]].copy_from_slice(&self.data[src..src + len[2]]);
                }
            }
        }
    }

    /// Write `block` into the sub-cuboid at `off` (inverse of [`block3`]) —
    /// the 3D-grid flatten gather's reassembly step.
    pub fn set_block3(&mut self, off: [usize; 3], block: &Tensor) {
        let (n, c, _, _, _) = self.dims5();
        let (bn, bc, bd, bh, bw) = block.dims5();
        assert!((bn, bc) == (n, c), "block {:?} into {:?}", block.shape, self.shape);
        self.set_block3_from(off, [bd, bh, bw], &block.data);
    }

    /// Write the flat buffer `src` (block layout) into the sub-cuboid at
    /// `off`/`len` — the fused halo-unpack primitive: received bytes land
    /// directly in the padded tensor.
    pub fn set_block3_from(&mut self, off: [usize; 3], len: [usize; 3], src: &[f32]) {
        let (n, c, d, h, w) = self.dims5();
        assert!(off[0] + len[0] <= d && off[1] + len[1] <= h && off[2] + len[2] <= w,
                "block @{off:?}+{len:?} into {:?}", self.shape);
        assert_eq!(src.len(), n * c * len[0] * len[1] * len[2], "set_block3_from buffer");
        for nc in 0..n * c {
            for dd in 0..len[0] {
                for hh in 0..len[1] {
                    let dst = ((nc * d + off[0] + dd) * h + off[1] + hh) * w + off[2];
                    let s = ((nc * len[0] + dd) * len[1] + hh) * len[2];
                    self.data[dst..dst + len[2]].copy_from_slice(&src[s..s + len[2]]);
                }
            }
        }
    }

    /// Accumulate (`+=`) the flat buffer `src` (block layout) into the
    /// sub-cuboid at `off`/`len` — the fused *backward* halo-unpack:
    /// gradients of shared faces are summed into the owner in place.
    pub fn add_block3_from(&mut self, off: [usize; 3], len: [usize; 3], src: &[f32]) {
        let (n, c, d, h, w) = self.dims5();
        assert!(off[0] + len[0] <= d && off[1] + len[1] <= h && off[2] + len[2] <= w,
                "block @{off:?}+{len:?} into {:?}", self.shape);
        assert_eq!(src.len(), n * c * len[0] * len[1] * len[2], "add_block3_from buffer");
        for nc in 0..n * c {
            for dd in 0..len[0] {
                for hh in 0..len[1] {
                    let dst = ((nc * d + off[0] + dd) * h + off[1] + hh) * w + off[2];
                    let s = ((nc * len[0] + dd) * len[1] + hh) * len[2];
                    for i in 0..len[2] {
                        self.data[dst + i] += src[s + i];
                    }
                }
            }
        }
    }

    /// Concatenate along spatial `axis` (2=D, 3=H, 4=W).
    pub fn concat_ax(axis: usize, parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let mut shape = parts[0].shape.clone();
        shape[axis] = parts.iter().map(|p| p.shape[axis]).sum();
        let mut out = Tensor::zeros(&shape);
        let mut i0 = 0;
        for p in parts {
            out.set_slice_ax(axis, i0, p);
            i0 += p.shape[axis];
        }
        out
    }

    /// Concatenate along channels (axis 1) — U-Net skip connections.
    pub fn concat_c(a: &Tensor, b: &Tensor) -> Tensor {
        let (n, ca, d, h, w) = a.dims5();
        let (nb, cb, db, hb, wb) = b.dims5();
        assert!((n, d, h, w) == (nb, db, hb, wb));
        let mut out = Tensor::zeros(&[n, ca + cb, d, h, w]);
        let block = d * h * w;
        for i in 0..n {
            let dst = i * (ca + cb) * block;
            out.data[dst..dst + ca * block]
                .copy_from_slice(&a.data[i * ca * block..(i + 1) * ca * block]);
            out.data[dst + ca * block..dst + (ca + cb) * block]
                .copy_from_slice(&b.data[i * cb * block..(i + 1) * cb * block]);
        }
        out
    }

    /// Split channels (inverse of [`concat_c`]): returns (first `ca`, rest).
    pub fn split_c(&self, ca: usize) -> (Tensor, Tensor) {
        let (n, c, d, h, w) = self.dims5();
        assert!(ca < c);
        let cb = c - ca;
        let block = d * h * w;
        let mut a = Tensor::zeros(&[n, ca, d, h, w]);
        let mut b = Tensor::zeros(&[n, cb, d, h, w]);
        for i in 0..n {
            let src = i * c * block;
            a.data[i * ca * block..(i + 1) * ca * block]
                .copy_from_slice(&self.data[src..src + ca * block]);
            b.data[i * cb * block..(i + 1) * cb * block]
                .copy_from_slice(&self.data[src + ca * block..src + c * block]);
        }
        (a, b)
    }

    // ---- per-channel reductions (distributed batch-norm) ------------------

    /// (sum, sum of squares) per channel over (n, d, h, w).
    ///
    /// Channels are distributed over worker threads; each channel's
    /// accumulation runs on one thread in ascending-sample order, exactly
    /// as the serial loop would, so results are bit-identical for any
    /// thread count (see `util::par`'s determinism contract).
    pub fn channel_stats(&self) -> (Vec<f32>, Vec<f32>) {
        let (n, c, d, h, w) = self.dims5();
        let block = d * h * w;
        let stats = par::map_indexed(c, n * block, |ch| {
            let (mut c1, mut c2) = (0.0f32, 0.0f32);
            for i in 0..n {
                let off = (i * c + ch) * block;
                let (mut a, mut b) = (0.0f64, 0.0f64);
                for &v in &self.data[off..off + block] {
                    a += v as f64;
                    b += (v as f64) * (v as f64);
                }
                c1 += a as f32;
                c2 += b as f32;
            }
            (c1, c2)
        });
        stats.into_iter().unzip()
    }

    /// Elements per channel (n*d*h*w) — the BN `count` term.
    pub fn per_channel_count(&self) -> usize {
        let (n, _, d, h, w) = self.dims5();
        n * d * h * w
    }

    // ---- elementwise -----------------------------------------------------

    pub fn leaky_relu(&self, slope: f32) -> Tensor {
        let mut out = Tensor::zeros(&self.shape);
        self.leaky_relu_into(slope, &mut out);
        out
    }

    /// [`Tensor::leaky_relu`] into a caller-provided (typically pooled)
    /// tensor of the same shape.
    pub fn leaky_relu_into(&self, slope: f32, out: &mut Tensor) {
        assert_eq!(self.shape, out.shape);
        par::zip_mut(&mut out.data, &self.data, |d, s| {
            for (y, &x) in d.iter_mut().zip(s) {
                *y = if x >= 0.0 { x } else { slope * x };
            }
        });
    }

    /// dL/dx of leaky-ReLU given the *pre-activation* input.
    pub fn leaky_relu_bwd(&self, dy: &Tensor, slope: f32) -> Tensor {
        let mut dx = dy.clone();
        self.leaky_relu_bwd_inplace(&mut dx, slope);
        dx
    }

    /// In-place [`Tensor::leaky_relu_bwd`]: `dy` (dL/dy) becomes dL/dx,
    /// with `self` the saved pre-activation input.
    pub fn leaky_relu_bwd_inplace(&self, dy: &mut Tensor, slope: f32) {
        assert_eq!(self.shape, dy.shape);
        par::zip_mut(&mut dy.data, &self.data, |d, s| {
            for (g, &x) in d.iter_mut().zip(s) {
                if x < 0.0 {
                    *g *= slope;
                }
            }
        });
    }

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        par::zip_mut(&mut self.data, &other.data, |d, s| {
            for (a, b) in d.iter_mut().zip(s) {
                *a += b;
            }
        });
    }

    pub fn scale(&mut self, s: f32) {
        par::chunks_mut(&mut self.data, |c| {
            for v in c.iter_mut() {
                *v *= s;
            }
        });
    }

    pub fn mul_elem(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let mut out = self.clone();
        out.mul_assign_slice(&other.data);
        out
    }

    /// Elementwise `self[i] *= other[i]` against a flat buffer — the
    /// in-place dropout-mask apply (no mask `Tensor` materialized).
    pub fn mul_assign_slice(&mut self, other: &[f32]) {
        assert_eq!(self.data.len(), other.len(), "mul_assign_slice length");
        par::zip_mut(&mut self.data, other, |d, s| {
            for (a, b) in d.iter_mut().zip(s) {
                *a *= b;
            }
        });
    }

    /// Max |a - b| — for tests and equivalence checks.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative L2 difference ||a-b|| / (||b|| + eps).
    pub fn rel_l2_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for (a, b) in self.data.iter().zip(&other.data) {
            num += ((a - b) * (a - b)) as f64;
            den += (b * b) as f64;
        }
        (num.sqrt() / (den.sqrt() + 1e-12)) as f32
    }

    pub fn assert_close(&self, other: &Tensor, tol: f32, what: &str) -> Result<()> {
        let d = self.max_abs_diff(other);
        if d > tol {
            bail!("{what}: max abs diff {d} > tol {tol}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|i| i as f32).collect())
    }

    #[test]
    fn slab_roundtrip() {
        let t = seq(&[2, 3, 8, 2, 2]);
        let slab = t.slice_ax(2, 2, 4);
        assert_eq!(slab.shape(), &[2, 3, 4, 2, 2]);
        let mut t2 = Tensor::zeros(t.shape());
        t2.set_slice_ax(2, 2, &slab);
        let back = t2.slice_ax(2, 2, 4);
        assert_eq!(back, slab);
    }

    #[test]
    fn slab_values_match_manual_index() {
        let t = seq(&[1, 2, 4, 2, 2]);
        let slab = t.slice_ax(2, 1, 2);
        // element (n=0, c=1, d=1(global d=2), h=1, w=0):
        let manual = t.data()[((0 * 2 + 1) * 4 + 2) * 4 + 2];
        let got = slab.data()[((0 * 2 + 1) * 2 + 1) * 4 + 2];
        assert_eq!(manual, got);
    }

    #[test]
    fn pad_crop_inverse() {
        let t = seq(&[1, 2, 4, 3, 3]);
        let p = t.pad_ax(2, 1, 2);
        assert_eq!(p.shape(), &[1, 2, 7, 3, 3]);
        assert_eq!(p.crop_ax(2, 1, 2), t);
        // padding planes are zero
        assert!(p.slice_ax(2, 0, 1).data().iter().all(|&x| x == 0.0));
        assert!(p.slice_ax(2, 5, 2).data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn add_slice_accumulates() {
        let mut t = Tensor::zeros(&[1, 1, 4, 2, 2]);
        let ones = Tensor::from_vec(&[1, 1, 2, 2, 2], vec![1.0; 8]);
        t.add_slice_ax(2, 1, &ones);
        t.add_slice_ax(2, 2, &ones);
        let expect = [0.0, 1.0, 2.0, 1.0];
        for d in 0..4 {
            assert!(t.slice_ax(2, d, 1).data().iter().all(|&x| x == expect[d]));
        }
    }

    #[test]
    fn axis_slabs_match_manual_index() {
        // slice along H and W must agree with direct index arithmetic
        let t = seq(&[2, 2, 3, 4, 5]);
        let sh = t.slice_ax(3, 1, 2);
        assert_eq!(sh.shape(), &[2, 2, 3, 2, 5]);
        // element (n=1, c=0, d=2, h=1(global 2), w=3)
        let manual = t.data()[(((1 * 2) * 3 + 2) * 4 + 2) * 5 + 3];
        assert_eq!(sh.data()[(((1 * 2) * 3 + 2) * 2 + 1) * 5 + 3], manual);
        let sw = t.slice_ax(4, 2, 2);
        assert_eq!(sw.shape(), &[2, 2, 3, 4, 2]);
        let manual = t.data()[(((1 * 2 + 1) * 3 + 1) * 4 + 3) * 5 + 2];
        assert_eq!(sw.data()[(((1 * 2 + 1) * 3 + 1) * 4 + 3) * 2], manual);
    }

    #[test]
    fn axis_ops_roundtrip_all_axes() {
        let t = seq(&[2, 3, 4, 5, 6]);
        for axis in 2..=4 {
            let ext = t.shape()[axis];
            let slab = t.slice_ax(axis, 1, ext - 2);
            let mut back = Tensor::zeros(t.shape());
            back.set_slice_ax(axis, 1, &slab);
            assert_eq!(back.slice_ax(axis, 1, ext - 2), slab, "axis {axis}");
            // pad/crop inverse with zero faces
            let p = t.pad_ax(axis, 1, 2);
            assert_eq!(p.shape()[axis], ext + 3);
            assert_eq!(p.crop_ax(axis, 1, 2), t, "axis {axis}");
            assert!(p.slice_ax(axis, 0, 1).data().iter().all(|&x| x == 0.0));
            assert!(p.slice_ax(axis, ext + 1, 2).data().iter().all(|&x| x == 0.0));
            // accumulate adds
            let mut acc = t.clone();
            acc.add_slice_ax(axis, 1, &slab);
            let twice = acc.slice_ax(axis, 1, ext - 2);
            for (a, b) in twice.data().iter().zip(slab.data()) {
                assert_eq!(*a, 2.0 * b);
            }
        }
    }

    #[test]
    fn into_variants_match_allocating_ops() {
        let t = seq(&[2, 3, 4, 5, 6]);
        for axis in 2..=4 {
            let ext = t.shape()[axis];
            // slice_ax_into == slice_ax
            let slab = t.slice_ax(axis, 1, ext - 2);
            let mut flat = vec![-1.0; slab.numel()];
            t.slice_ax_into(axis, 1, ext - 2, &mut flat);
            assert_eq!(flat, slab.data(), "slice axis {axis}");
            // set/add _from == Tensor variants
            let mut a = Tensor::zeros(t.shape());
            let mut b = Tensor::zeros(t.shape());
            a.set_slice_ax(axis, 1, &slab);
            b.set_slice_ax_from(axis, 1, ext - 2, &flat);
            assert_eq!(a, b, "set axis {axis}");
            a.add_slice_ax(axis, 1, &slab);
            b.add_slice_ax_from(axis, 1, ext - 2, &flat);
            assert_eq!(a, b, "add axis {axis}");
            // pad_ax_into (over stale contents) == pad_ax
            let p = t.pad_ax(axis, 1, 2);
            let mut shape = t.shape().to_vec();
            shape[axis] += 3;
            let mut q = Tensor::from_vec(&shape, vec![9.0; p.numel()]);
            t.pad_ax_into(axis, 1, 2, &mut q);
            assert_eq!(q, p, "pad axis {axis}");
        }
    }

    #[test]
    fn block3_from_variants_match() {
        let t = seq(&[2, 2, 4, 4, 4]);
        let (off, len) = ([1, 0, 2], [2, 3, 2]);
        let b = t.block3(off, len);
        let mut flat = vec![-1.0; b.numel()];
        t.block3_into(off, len, &mut flat);
        assert_eq!(flat, b.data());
        let mut x = Tensor::zeros(t.shape());
        let mut y = Tensor::zeros(t.shape());
        x.set_block3(off, &b);
        y.set_block3_from(off, len, &flat);
        assert_eq!(x, y);
        y.add_block3_from(off, len, &flat);
        let twice = y.block3(off, len);
        for (a, b) in twice.data().iter().zip(b.data()) {
            assert_eq!(*a, 2.0 * b);
        }
    }

    #[test]
    fn inplace_elementwise_matches() {
        let t = seq(&[1, 1, 2, 3, 4]);
        let pre = Tensor::from_vec(t.shape(), t.data().iter().map(|&x| x - 10.0).collect());
        let mut dy = seq(&[1, 1, 2, 3, 4]);
        let dx = pre.leaky_relu_bwd(&dy, 0.1);
        pre.leaky_relu_bwd_inplace(&mut dy, 0.1);
        assert_eq!(dy, dx);
        let mut out = Tensor::zeros(t.shape());
        pre.leaky_relu_into(0.1, &mut out);
        assert_eq!(out, pre.leaky_relu(0.1));
        let mask: Vec<f32> = (0..t.numel()).map(|i| (i % 2) as f32).collect();
        let mut m = t.clone();
        m.mul_assign_slice(&mask);
        assert_eq!(m, t.mul_elem(&Tensor::from_vec(t.shape(), mask)));
    }

    #[test]
    fn block3_roundtrip_and_values() {
        let t = seq(&[1, 2, 4, 4, 4]);
        let b = t.block3([1, 2, 0], [2, 2, 3]);
        assert_eq!(b.shape(), &[1, 2, 2, 2, 3]);
        // element (c=1, d=0(global 1), h=1(global 3), w=2)
        let manual = t.data()[((1 * 4 + 1) * 4 + 3) * 4 + 2];
        assert_eq!(b.data()[((1 * 2) * 2 + 1) * 3 + 2], manual);
        let mut back = Tensor::zeros(t.shape());
        back.set_block3([1, 2, 0], &b);
        assert_eq!(back.block3([1, 2, 0], [2, 2, 3]), b);
        // reassembling all 8 octants reproduces the original
        let mut whole = Tensor::zeros(t.shape());
        for d0 in [0, 2] {
            for h0 in [0, 2] {
                for w0 in [0, 2] {
                    whole.set_block3([d0, h0, w0],
                                     &t.block3([d0, h0, w0], [2, 2, 2]));
                }
            }
        }
        assert_eq!(whole, t);
    }

    #[test]
    fn concat_split_roundtrip() {
        let a = seq(&[2, 3, 2, 2, 2]);
        let b = seq(&[2, 5, 2, 2, 2]);
        let c = Tensor::concat_c(&a, &b);
        assert_eq!(c.shape(), &[2, 8, 2, 2, 2]);
        let (a2, b2) = c.split_c(3);
        assert_eq!(a2, a);
        assert_eq!(b2, b);

        let parts = [a.slice_ax(2, 0, 1), a.slice_ax(2, 1, 1)];
        let whole = Tensor::concat_ax(2, &[&parts[0], &parts[1]]);
        assert_eq!(whole, a);
    }

    #[test]
    fn channel_stats_match_naive() {
        let t = seq(&[2, 2, 2, 2, 2]);
        let (s1, s2) = t.channel_stats();
        // naive per channel
        for c in 0..2 {
            let (mut a, mut b) = (0.0, 0.0);
            for n in 0..2 {
                for i in 0..8 {
                    let v = t.data()[(n * 2 + c) * 8 + i];
                    a += v;
                    b += v * v;
                }
            }
            assert!((s1[c] - a).abs() < 1e-3);
            assert!((s2[c] - b).abs() < 1e-1);
        }
        assert_eq!(t.per_channel_count(), 16);
    }

    #[test]
    fn leaky_and_bwd() {
        let t = Tensor::from_vec(&[2, 2], vec![-2.0, -0.5, 0.5, 2.0]);
        let y = t.leaky_relu(0.1);
        assert_eq!(y.data(), &[-0.2, -0.05, 0.5, 2.0]);
        let dy = Tensor::from_vec(&[2, 2], vec![1.0; 4]);
        let dx = t.leaky_relu_bwd(&dy, 0.1);
        assert_eq!(dx.data(), &[0.1, 0.1, 1.0, 1.0]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![0.0; 3]);
    }
}
