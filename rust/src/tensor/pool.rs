//! Per-rank buffer pool: steady-state steps reuse every hot-path buffer.
//!
//! The hot path (halo exchange, activation saves, bucket staging, I/O
//! staging) used to allocate fresh `Vec<f32>` storage on every step.
//! [`BufferPool`] keeps free lists keyed by *exact* element count, so
//! after a warm-up step every `take` is a free-list pop and the step
//! performs zero heap allocations in the tensor/halo/bucket path — the
//! property asserted by the pool-miss counter test and gated in CI via
//! `micro.step_steady_pool_miss_count`.
//!
//! The pool is deliberately single-threaded (one pool per rank, ranks
//! are threads/processes that never share one): `RefCell`/`Cell` keep
//! it out of every atomic-ops fast path. Buffers returned by
//! [`BufferPool::take`] contain stale data on a hit; callers that need
//! zeros must use [`BufferPool::take_zeroed`] or overwrite fully.

use super::Tensor;
use std::cell::{Cell, RefCell};
use std::collections::HashMap;

/// Most buffers kept per exact size class. Producer/consumer imbalances
/// (e.g. a fresh runtime output recycled every step whose consumer hands
/// its storage to the runtime) would otherwise grow a free list without
/// bound; steady-state cycles need only a handful of buffers per class.
const MAX_PER_CLASS: usize = 8;

/// Exact-size free lists of `f32` buffers plus hit/miss counters.
#[derive(Default)]
pub struct BufferPool {
    free: RefCell<HashMap<usize, Vec<Vec<f32>>>>,
    hits: Cell<u64>,
    misses: Cell<u64>,
}

/// A [`Tensor`] checked out of a [`BufferPool`]. Thin alias used at API
/// boundaries to document ownership: the callee is expected to
/// [`BufferPool::recycle`] it (or hand it onward) rather than drop it.
pub type PooledTensor = Tensor;

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Check out a buffer of exactly `len` elements. Contents are
    /// *unspecified* on a pool hit (stale data from the previous user);
    /// a miss allocates zeroed storage.
    pub fn take(&self, len: usize) -> Vec<f32> {
        if let Some(buf) = self.free.borrow_mut().get_mut(&len).and_then(|l| l.pop()) {
            self.hits.set(self.hits.get() + 1);
            buf
        } else {
            self.misses.set(self.misses.get() + 1);
            vec![0.0; len]
        }
    }

    /// Check out a buffer of `len` elements, zero-filled.
    pub fn take_zeroed(&self, len: usize) -> Vec<f32> {
        let mut buf = self.take(len);
        buf.fill(0.0);
        buf
    }

    /// Return a buffer to the free list for its exact size (dropped if the
    /// size class is already full — see [`MAX_PER_CLASS`]).
    pub fn put(&self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut free = self.free.borrow_mut();
        let list = free.entry(buf.len()).or_default();
        if list.len() < MAX_PER_CLASS {
            list.push(buf);
        }
    }

    /// Check out a tensor of `shape` with *unspecified* contents.
    pub fn take_tensor(&self, shape: &[usize]) -> PooledTensor {
        let len = shape.iter().product();
        Tensor::from_vec(shape, self.take(len))
    }

    /// Check out a tensor of `shape`, zero-filled.
    pub fn take_tensor_zeroed(&self, shape: &[usize]) -> PooledTensor {
        let len = shape.iter().product();
        Tensor::from_vec(shape, self.take_zeroed(len))
    }

    /// Check out a bitwise copy of `src`.
    pub fn take_clone(&self, src: &Tensor) -> PooledTensor {
        let mut buf = self.take(src.numel());
        buf.copy_from_slice(src.data());
        Tensor::from_vec(src.shape(), buf)
    }

    /// Return a tensor's storage to the pool.
    pub fn recycle(&self, t: Tensor) {
        self.put(t.into_vec());
    }

    /// Free-list pops since construction / [`BufferPool::reset_counters`].
    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    /// Fresh allocations since construction / [`BufferPool::reset_counters`].
    pub fn misses(&self) -> u64 {
        self.misses.get()
    }

    pub fn reset_counters(&self) {
        self.hits.set(0);
        self.misses.set(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_reuses_exact_sizes() {
        let pool = BufferPool::new();
        let a = pool.take(16);
        let b = pool.take(32);
        assert_eq!((pool.hits(), pool.misses()), (0, 2));
        pool.put(a);
        pool.put(b);
        let a2 = pool.take(16);
        assert_eq!(a2.len(), 16);
        assert_eq!((pool.hits(), pool.misses()), (1, 2));
        let _c = pool.take(17); // different size: miss
        assert_eq!(pool.misses(), 3);
    }

    #[test]
    fn take_zeroed_clears_stale_data() {
        let pool = BufferPool::new();
        let mut a = pool.take(8);
        a.fill(7.0);
        pool.put(a);
        let b = pool.take_zeroed(8);
        assert!(b.iter().all(|&x| x == 0.0));
        assert_eq!(pool.hits(), 1);
    }

    #[test]
    fn tensor_roundtrip_and_clone() {
        let pool = BufferPool::new();
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect());
        let c = pool.take_clone(&t);
        assert_eq!(c.data(), t.data());
        assert_eq!(c.shape(), t.shape());
        pool.recycle(c);
        let z = pool.take_tensor_zeroed(&[3, 2]);
        assert!(z.data().iter().all(|&x| x == 0.0));
        assert_eq!(pool.hits(), 1);
        assert_eq!(pool.misses(), 1);
    }
}
