//! Per-rank buffer pool: steady-state steps reuse every hot-path buffer.
//!
//! The hot path (halo exchange, activation saves, bucket staging, I/O
//! staging) used to allocate fresh `Vec<f32>` storage on every step.
//! [`BufferPool`] keeps free lists keyed by *exact* element count, so
//! after a warm-up step every `take` is a free-list pop and the step
//! performs zero heap allocations in the tensor/halo/bucket path — the
//! property asserted by the pool-miss counter test and gated in CI via
//! `micro.step_steady_pool_miss_count`.
//!
//! The pool is deliberately single-threaded (one pool per rank, ranks
//! are threads/processes that never share one): `RefCell`/`Cell` keep
//! it out of every atomic-ops fast path. Buffers returned by
//! [`BufferPool::take`] contain stale data on a hit; callers that need
//! zeros must use [`BufferPool::take_zeroed`] or overwrite fully.

use super::Tensor;
use std::cell::{Cell, RefCell};
use std::collections::HashMap;

/// Most buffers kept per exact size class. Producer/consumer imbalances
/// (e.g. a fresh runtime output recycled every step whose consumer hands
/// its storage to the runtime) would otherwise grow a free list without
/// bound; steady-state cycles need only a handful of buffers per class.
const MAX_PER_CLASS: usize = 8;

/// One pool-discipline event, recorded (behind the opt-in runtime flag
/// [`BufferPool::enable_log`]) for `analysis::checks`' use-after-return /
/// double-return verification. `ptr` is the buffer's storage address —
/// stable while a live allocation sits in the free list, which is exactly
/// the window the checks care about.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolEvent {
    /// Buffer checked out (free-list pop or fresh allocation).
    Take { ptr: usize, len: usize },
    /// Buffer returned to the free list.
    Put { ptr: usize, len: usize },
    /// Buffer dropped on return (size class full): its address may be
    /// reused by a later unrelated allocation, so the checker must retire
    /// the pointer state here.
    Evict { ptr: usize, len: usize },
    /// Caller touched the buffer (hook for callers / the mutation
    /// harness; a `Use` of a pointer currently in the free list is a
    /// use-after-return).
    Use { ptr: usize, len: usize },
}

/// Exact-size free lists of `f32` buffers plus hit/miss counters.
///
/// The steady-state contract — after warm-up, every `take` is a free-list
/// hit:
///
/// ```
/// use hydra3d::tensor::pool::BufferPool;
///
/// let pool = BufferPool::new();
/// let buf = pool.take(1024);          // cold: allocates (a miss)
/// assert_eq!(pool.misses(), 1);
/// pool.put(buf);
///
/// pool.reset_counters();              // warm-up over
/// let buf = pool.take(1024);          // same size class: free-list pop
/// assert_eq!((pool.hits(), pool.misses()), (1, 0));
/// pool.put(buf);
///
/// // tensors check out of the same per-size free lists
/// let t = pool.take_tensor_zeroed(&[2, 8, 8, 8]);
/// pool.recycle(t);
/// ```
#[derive(Default)]
pub struct BufferPool {
    free: RefCell<HashMap<usize, Vec<Vec<f32>>>>,
    hits: Cell<u64>,
    misses: Cell<u64>,
    /// `Some` once [`BufferPool::enable_log`] is called; `None` (the
    /// default) keeps the hot path to a single branch.
    log: RefCell<Option<Vec<PoolEvent>>>,
}

/// A [`Tensor`] checked out of a [`BufferPool`]. Thin alias used at API
/// boundaries to document ownership: the callee is expected to
/// [`BufferPool::recycle`] it (or hand it onward) rather than drop it.
pub type PooledTensor = Tensor;

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start recording [`PoolEvent`]s (idempotent; off by default).
    pub fn enable_log(&self) {
        let mut log = self.log.borrow_mut();
        if log.is_none() {
            *log = Some(Vec::new());
        }
    }

    /// Drain the recorded events (empty when logging was never enabled).
    pub fn take_log(&self) -> Vec<PoolEvent> {
        self.log.borrow_mut().as_mut().map(std::mem::take).unwrap_or_default()
    }

    fn record(&self, ev: PoolEvent) {
        if let Some(log) = self.log.borrow_mut().as_mut() {
            log.push(ev);
        }
    }

    /// Whether a live buffer with this storage address is currently in a
    /// free list. Because the free lists own their buffers, a `true` here
    /// during [`BufferPool::put`] can only mean the same logical buffer is
    /// being returned twice — the double-return guard's predicate.
    pub fn contains(&self, ptr: *const f32) -> bool {
        self.free
            .borrow()
            .values()
            .any(|list| list.iter().any(|b| b.as_ptr() == ptr))
    }

    /// Check out a buffer of exactly `len` elements. Contents are
    /// *unspecified* on a pool hit (stale data from the previous user);
    /// a miss allocates zeroed storage.
    pub fn take(&self, len: usize) -> Vec<f32> {
        let buf = if let Some(buf) =
            self.free.borrow_mut().get_mut(&len).and_then(|l| l.pop())
        {
            self.hits.set(self.hits.get() + 1);
            buf
        } else {
            self.misses.set(self.misses.get() + 1);
            vec![0.0; len]
        };
        self.record(PoolEvent::Take { ptr: buf.as_ptr() as usize, len });
        buf
    }

    /// Check out a buffer of `len` elements, zero-filled.
    pub fn take_zeroed(&self, len: usize) -> Vec<f32> {
        let mut buf = self.take(len);
        buf.fill(0.0);
        buf
    }

    /// Return a buffer to the free list for its exact size (dropped if the
    /// size class is already full — see [`MAX_PER_CLASS`]).
    ///
    /// Debug builds assert the buffer isn't already in a free list: free
    /// lists hold live allocations, so an address match means the same
    /// buffer returned twice, which would hand the storage out to two
    /// users and corrupt both silently.
    pub fn put(&self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        debug_assert!(
            !self.contains(buf.as_ptr()),
            "BufferPool::put: double return of a {}-element buffer",
            buf.len()
        );
        let ptr = buf.as_ptr() as usize;
        let len = buf.len();
        let mut free = self.free.borrow_mut();
        let list = free.entry(len).or_default();
        if list.len() < MAX_PER_CLASS {
            list.push(buf);
            drop(free);
            self.record(PoolEvent::Put { ptr, len });
        } else {
            drop(free);
            self.record(PoolEvent::Evict { ptr, len });
        }
    }

    /// Note a read/write of `buf` in the event log (no-op unless logging
    /// is enabled). Call sites are opt-in — the discipline check flags a
    /// `Use` whose pointer currently sits in a free list.
    pub fn note_use(&self, buf: &[f32]) {
        self.record(PoolEvent::Use { ptr: buf.as_ptr() as usize, len: buf.len() });
    }

    /// Check out a tensor of `shape` with *unspecified* contents.
    pub fn take_tensor(&self, shape: &[usize]) -> PooledTensor {
        let len = shape.iter().product();
        Tensor::from_vec(shape, self.take(len))
    }

    /// Check out a tensor of `shape`, zero-filled.
    pub fn take_tensor_zeroed(&self, shape: &[usize]) -> PooledTensor {
        let len = shape.iter().product();
        Tensor::from_vec(shape, self.take_zeroed(len))
    }

    /// Check out a bitwise copy of `src`.
    pub fn take_clone(&self, src: &Tensor) -> PooledTensor {
        let mut buf = self.take(src.numel());
        buf.copy_from_slice(src.data());
        Tensor::from_vec(src.shape(), buf)
    }

    /// Return a tensor's storage to the pool.
    pub fn recycle(&self, t: Tensor) {
        self.put(t.into_vec());
    }

    /// Free-list pops since construction / [`BufferPool::reset_counters`].
    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    /// Fresh allocations since construction / [`BufferPool::reset_counters`].
    pub fn misses(&self) -> u64 {
        self.misses.get()
    }

    pub fn reset_counters(&self) {
        self.hits.set(0);
        self.misses.set(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_reuses_exact_sizes() {
        let pool = BufferPool::new();
        let a = pool.take(16);
        let b = pool.take(32);
        assert_eq!((pool.hits(), pool.misses()), (0, 2));
        pool.put(a);
        pool.put(b);
        let a2 = pool.take(16);
        assert_eq!(a2.len(), 16);
        assert_eq!((pool.hits(), pool.misses()), (1, 2));
        let _c = pool.take(17); // different size: miss
        assert_eq!(pool.misses(), 3);
    }

    #[test]
    fn take_zeroed_clears_stale_data() {
        let pool = BufferPool::new();
        let mut a = pool.take(8);
        a.fill(7.0);
        pool.put(a);
        let b = pool.take_zeroed(8);
        assert!(b.iter().all(|&x| x == 0.0));
        assert_eq!(pool.hits(), 1);
    }

    #[test]
    fn double_return_guard_predicate() {
        // `contains` is the predicate behind the debug_assert in `put`: a
        // buffer's address is in a free list exactly between its return
        // and its next checkout, so a second `put` of the same buffer in
        // that window is what the guard fires on.
        let pool = BufferPool::new();
        let buf = pool.take(8);
        let ptr = buf.as_ptr();
        assert!(!pool.contains(ptr), "checked-out buffer is not pooled");
        pool.put(buf);
        assert!(pool.contains(ptr), "returned buffer sits in the free list");
        let again = pool.take(8);
        assert_eq!(again.as_ptr(), ptr, "free lists are LIFO per class");
        assert!(!pool.contains(ptr));
        pool.put(again);
    }

    #[test]
    fn event_log_records_discipline() {
        let pool = BufferPool::new();
        pool.enable_log();
        let buf = pool.take(4);
        let ptr = buf.as_ptr() as usize;
        pool.note_use(&buf);
        pool.put(buf);
        assert_eq!(
            pool.take_log(),
            vec![
                PoolEvent::Take { ptr, len: 4 },
                PoolEvent::Use { ptr, len: 4 },
                PoolEvent::Put { ptr, len: 4 },
            ]
        );
        // overflow beyond MAX_PER_CLASS logs an Evict (the checker retires
        // the address there — it may be reused by a later allocation)
        let bufs: Vec<_> = (0..MAX_PER_CLASS + 1).map(|_| pool.take(2)).collect();
        for b in bufs {
            pool.put(b);
        }
        let log = pool.take_log();
        let evicts =
            log.iter().filter(|e| matches!(e, PoolEvent::Evict { .. })).count();
        assert_eq!(evicts, 1);
    }

    #[test]
    fn tensor_roundtrip_and_clone() {
        let pool = BufferPool::new();
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect());
        let c = pool.take_clone(&t);
        assert_eq!(c.data(), t.data());
        assert_eq!(c.shape(), t.shape());
        pool.recycle(c);
        let z = pool.take_tensor_zeroed(&[3, 2]);
        assert!(z.data().iter().all(|&x| x == 0.0));
        assert_eq!(pool.hits(), 1);
        assert_eq!(pool.misses(), 1);
    }
}
