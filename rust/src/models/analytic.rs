//! Paper-scale network analytics: Table I regenerated from first
//! principles.
//!
//! The extended CosmoFlow model (§IV, Table I): 7 convolutions of 3^3
//! filters over a 4-channel input (the "2019_05_4parE" dataset stores 4
//! redshifts per universe), `c4` with stride 2, pooling inserted so the
//! final spatial extent is 2^3 at every input size, and fc layers
//! 2048-256-4. Verified invariants (tests below):
//!
//! * 9.44 M parameters at every input size,
//! * 55.55 / 443.8 / 3550 GFlop of conv work per sample (fwd+bwd),
//! * 18.52 / 147.9 / 1183 GFlop forward-only,
//! * 0.824 / 6.59 / 52.7 GiB activation memory per sample (±10 %).

/// Layer kinds that matter for cost accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Deconv,
    Pool,
    BatchNorm,
    Fc,
}

/// One layer of a paper-scale model.
#[derive(Clone, Debug)]
pub struct AnalyticLayer {
    pub name: String,
    pub kind: LayerKind,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    /// input spatial extent (cubic)
    pub d_in: usize,
    /// output spatial extent (cubic)
    pub d_out: usize,
}

impl AnalyticLayer {
    /// Forward FLOPs per sample (multiply-add = 2 flops).
    pub fn fwd_flops(&self) -> f64 {
        let vox_out = (self.d_out as f64).powi(3);
        match self.kind {
            LayerKind::Conv => {
                2.0 * (self.k as f64).powi(3) * self.cin as f64 * self.cout as f64
                    * vox_out
            }
            LayerKind::Deconv => {
                // transposed conv: every input voxel scatters k^3*cout MACs
                let vox_in = (self.d_in as f64).powi(3);
                2.0 * (self.k as f64).powi(3) * self.cin as f64 * self.cout as f64
                    * vox_in
            }
            LayerKind::Pool => (self.cin as f64) * vox_out * 8.0,
            LayerKind::BatchNorm => 4.0 * self.cout as f64 * vox_out,
            LayerKind::Fc => 2.0 * self.cin as f64 * self.cout as f64,
        }
    }

    /// fwd + bwd-data + bwd-filter (the paper's "# conv ops" counts 3x fwd).
    pub fn total_flops(&self) -> f64 {
        match self.kind {
            LayerKind::Conv | LayerKind::Deconv | LayerKind::Fc => 3.0 * self.fwd_flops(),
            _ => 2.0 * self.fwd_flops(),
        }
    }

    /// Output activation elements per sample.
    pub fn out_elems(&self) -> f64 {
        match self.kind {
            LayerKind::Fc => self.cout as f64,
            _ => self.cout as f64 * (self.d_out as f64).powi(3),
        }
    }

    pub fn param_count(&self) -> usize {
        match self.kind {
            LayerKind::Conv | LayerKind::Deconv => {
                self.cin * self.cout * self.k * self.k * self.k
            }
            LayerKind::BatchNorm => 2 * self.cout,
            LayerKind::Fc => self.cin * self.cout + self.cout,
            LayerKind::Pool => 0,
        }
    }

    /// Bytes of one depth-halo face under `ways`-way depth partitioning
    /// (f32; zero if the layer exchanges no halo).
    pub fn halo_face_bytes(&self, ways: usize) -> f64 {
        self.halo_face_bytes_axis(
            crate::partition::Grid4 { n: 1, d: ways, h: 1, w: 1 }, 0)
    }

    /// Bytes of one halo face along spatial `axis` (0=D, 1=H, 2=W) under a
    /// `grid` spatial split: `cin * halo * (face area)` f32, where the face
    /// area is the product of the *other* two axes' shard extents (layers
    /// are cubic). Zero for unpartitioned axes and non-conv layers — the
    /// per-dimension halo regions the §III-C model sums over.
    pub fn halo_face_bytes_axis(&self, grid: crate::partition::Grid4, axis: usize)
                                -> f64 {
        let dims = [grid.d, grid.h, grid.w];
        if dims[axis] <= 1 || self.kind != LayerKind::Conv || self.k <= 1 {
            return 0.0;
        }
        let halo = (self.k - 1) / 2;
        let area: f64 = (0..3)
            .filter(|&a| a != axis)
            .map(|a| (self.d_in as f64 / dims[a] as f64).max(1.0))
            .product();
        4.0 * self.cin as f64 * halo as f64 * area
    }
}

/// A full analytic model.
#[derive(Clone, Debug)]
pub struct AnalyticModel {
    pub name: String,
    pub input_size: usize,
    pub in_channels: usize,
    pub layers: Vec<AnalyticLayer>,
}

impl AnalyticModel {
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    pub fn conv_total_gflops(&self) -> f64 {
        self.layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv | LayerKind::Deconv))
            .map(|l| l.total_flops())
            .sum::<f64>()
            / 1e9
    }

    pub fn conv_fwd_gflops(&self) -> f64 {
        self.layers
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv | LayerKind::Deconv))
            .map(|l| l.fwd_flops())
            .sum::<f64>()
            / 1e9
    }

    /// Activation-memory estimate per sample, bytes: every inter-layer
    /// tensor is stored once as an activation and once as a gradient, and
    /// each is registered both as "output of layer i" and "input of layer
    /// i+1" in the framework's buffer accounting — 4 bytes * 4 *
    /// sum(out_elems), plus the input tensor itself. Matches Table I
    /// within ~10 %.
    pub fn activation_bytes(&self) -> f64 {
        let input = self.in_channels as f64 * (self.input_size as f64).powi(3);
        let acts: f64 = self.layers.iter().map(|l| l.out_elems()).sum();
        4.0 * (input + 4.0 * acts)
    }

    pub fn activation_gib(&self) -> f64 {
        self.activation_bytes() / (1u64 << 30) as f64
    }

    /// Minimum GPUs per sample given a memory capacity (the paper's
    /// feasibility argument: 512^3 + BN needs >= 8 V100s).
    pub fn min_gpus_per_sample(&self, gpu_mem_gib: f64, with_bn: bool) -> usize {
        let need = self.activation_gib() * if with_bn { 2.0 } else { 1.0 };
        // power-of-two partitioning as in the paper's ways
        let mut g = 1;
        while (need / g as f64) > gpu_mem_gib * 0.9 {
            g *= 2;
        }
        g
    }
}

/// CosmoFlow at input size `wi` (128 / 256 / 512), per Table I.
/// `use_bn` appends a batch-norm after every conv (the §IV extension).
pub fn cosmoflow_paper(wi: usize, use_bn: bool) -> AnalyticModel {
    let channels = [16usize, 32, 64, 128, 256, 256, 256];
    // pooling layout per Table I: p_i follows c_i while spatial > 2
    let mut layers = Vec::new();
    let mut s = wi;
    let mut cin = 4; // 4 redshift channels
    for (i, &c) in channels.iter().enumerate() {
        let stride = if i == 3 { 2 } else { 1 }; // c4 has stride 2
        let conv_out = s / stride;
        layers.push(AnalyticLayer {
            name: format!("conv{}", i + 1),
            kind: LayerKind::Conv,
            cin,
            cout: c,
            k: 3,
            stride,
            d_in: s,
            d_out: conv_out,
        });
        if use_bn {
            layers.push(AnalyticLayer {
                name: format!("bn{}", i + 1),
                kind: LayerKind::BatchNorm,
                cin: c,
                cout: c,
                k: 0,
                stride: 1,
                d_in: conv_out,
                d_out: conv_out,
            });
        }
        s = conv_out;
        if s > 2 {
            layers.push(AnalyticLayer {
                name: format!("pool{}", i + 1),
                kind: LayerKind::Pool,
                cin: c,
                cout: c,
                k: 2,
                stride: 2,
                d_in: s,
                d_out: s / 2,
            });
            s /= 2;
        }
        cin = c;
    }
    assert_eq!(s, 2, "CosmoFlow must flatten at 2^3 (wi={wi})");
    let flat = cin * s * s * s;
    for (j, &f) in [2048usize, 256, 4].iter().enumerate() {
        layers.push(AnalyticLayer {
            name: format!("fc{}", j + 1),
            kind: LayerKind::Fc,
            cin: if j == 0 { flat } else { layers.last().unwrap().cout },
            cout: f,
            k: 0,
            stride: 1,
            d_in: 1,
            d_out: 1,
        });
    }
    AnalyticModel {
        name: format!("cosmoflow-{wi}{}", if use_bn { "-bn" } else { "" }),
        input_size: wi,
        in_channels: 4,
        layers,
    }
}

/// The original 3D U-Net (Çiçek et al. 2016) at cubic input `wi`
/// (paper §V uses 256^3, 1 input channel, 3 output classes for LiTS).
pub fn unet3d_paper(wi: usize, n_classes: usize) -> AnalyticModel {
    let mut layers = Vec::new();
    let mut s = wi;
    fn push_conv(layers: &mut Vec<AnalyticLayer>, name: String, cin: usize,
                 cout: usize, s: usize) {
        layers.push(AnalyticLayer {
            name,
            kind: LayerKind::Conv,
            cin,
            cout,
            k: 3,
            stride: 1,
            d_in: s,
            d_out: s,
        });
        layers.push(AnalyticLayer {
            name: "bn".into(),
            kind: LayerKind::BatchNorm,
            cin: cout,
            cout,
            k: 0,
            stride: 1,
            d_in: s,
            d_out: s,
        });
    }
    // analysis path: (32,64) (64,128) (128,256)
    let downs = [(1usize, 32usize, 64usize), (64, 64, 128), (128, 128, 256)];
    for (i, &(cin, ca, cb)) in downs.iter().enumerate() {
        push_conv(&mut layers, format!("down{}a", i), cin, ca, s);
        push_conv(&mut layers, format!("down{}b", i), ca, cb, s);
        layers.push(AnalyticLayer {
            name: format!("pool{}", i),
            kind: LayerKind::Pool,
            cin: cb,
            cout: cb,
            k: 2,
            stride: 2,
            d_in: s,
            d_out: s / 2,
        });
        s /= 2;
    }
    push_conv(&mut layers, "bottom_a".into(), 256, 256, s);
    push_conv(&mut layers, "bottom_b".into(), 256, 512, s);
    // synthesis path
    let ups = [(512usize, 512usize, 256usize, 256usize), (256, 256, 128, 128),
               (128, 128, 64, 64)];
    for (i, &(cin, cskip_plus, ca, cb)) in ups.iter().enumerate() {
        layers.push(AnalyticLayer {
            name: format!("up{}deconv", i),
            kind: LayerKind::Deconv,
            cin,
            cout: cin,
            k: 2,
            stride: 2,
            d_in: s,
            d_out: s * 2,
        });
        s *= 2;
        push_conv(&mut layers, format!("up{}a", i), cin + cskip_plus / 2, ca, s);
        push_conv(&mut layers, format!("up{}b", i), ca, cb, s);
    }
    layers.push(AnalyticLayer {
        name: "head".into(),
        kind: LayerKind::Conv,
        cin: 64,
        cout: n_classes,
        k: 1,
        stride: 1,
        d_in: s,
        d_out: s,
    });
    AnalyticModel { name: format!("unet3d-{wi}"), input_size: wi, in_channels: 1, layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn within(x: f64, want: f64, tol: f64) -> bool {
        (x - want).abs() / want <= tol
    }

    #[test]
    fn table1_output_widths() {
        for (wi, wants) in [
            (128usize, vec![(128, 64), (64, 32), (32, 16), (8, 4), (4, 2), (2, 2), (2, 2)]),
            (256, vec![(256, 128), (128, 64), (64, 32), (16, 8), (8, 4), (4, 2), (2, 2)]),
            (512, vec![(512, 256), (256, 128), (128, 64), (32, 16), (16, 8), (8, 4), (4, 2)]),
        ] {
            let m = cosmoflow_paper(wi, false);
            let convs: Vec<&AnalyticLayer> =
                m.layers.iter().filter(|l| l.kind == LayerKind::Conv).collect();
            for (i, (conv_out, after_pool)) in wants.iter().enumerate() {
                assert_eq!(convs[i].d_out, *conv_out, "wi={wi} c{}", i + 1);
                // after-pool width = next conv's input (or flatten extent)
                let next_in = convs.get(i + 1).map(|c| c.d_in).unwrap_or(2);
                assert_eq!(next_in, *after_pool, "wi={wi} p{}", i + 1);
            }
        }
    }

    #[test]
    fn table1_param_count() {
        for wi in [128, 256, 512] {
            let m = cosmoflow_paper(wi, false);
            let p = m.param_count() as f64 / 1e6;
            assert!(within(p, 9.44, 0.005), "wi={wi}: {p} M params");
        }
    }

    #[test]
    fn table1_conv_gflops() {
        let want_total = [(128, 55.55), (256, 443.8), (512, 3550.0)];
        let want_fwd = [(128, 18.52), (256, 147.9), (512, 1183.0)];
        for ((wi, t), (_, f)) in want_total.iter().zip(&want_fwd) {
            let m = cosmoflow_paper(*wi, false);
            assert!(within(m.conv_total_gflops(), *t, 0.01),
                    "wi={wi} total {} vs {t}", m.conv_total_gflops());
            assert!(within(m.conv_fwd_gflops(), *f, 0.01),
                    "wi={wi} fwd {} vs {f}", m.conv_fwd_gflops());
        }
    }

    #[test]
    fn table1_memory_estimate() {
        for (wi, want) in [(128usize, 0.824f64), (256, 6.59), (512, 52.7)] {
            let m = cosmoflow_paper(wi, false);
            let got = m.activation_gib();
            assert!(within(got, want, 0.10), "wi={wi}: {got} GiB vs {want}");
        }
    }

    #[test]
    fn memory_feasibility_matches_paper() {
        // §IV: 512^3 needs 4 GPUs; with BN memory doubles -> at least 8.
        let m = cosmoflow_paper(512, false);
        assert_eq!(m.min_gpus_per_sample(16.0, false), 4);
        assert_eq!(m.min_gpus_per_sample(16.0, true), 8);
        // 128^3 fits on one GPU
        assert_eq!(cosmoflow_paper(128, false).min_gpus_per_sample(16.0, false), 1);
    }

    #[test]
    fn bn_variant_adds_only_bn_params() {
        let a = cosmoflow_paper(512, false).param_count();
        let b = cosmoflow_paper(512, true).param_count();
        assert_eq!(b - a, 2 * (16 + 32 + 64 + 128 + 256 + 256 + 256));
    }

    #[test]
    fn unet_structure() {
        let m = unet3d_paper(256, 3);
        // U-Net memory at 256^3 exceeds CosmoFlow at 256^3 by a lot (§II-C)
        let cf = cosmoflow_paper(256, false);
        assert!(m.activation_gib() > 3.0 * cf.activation_gib(),
                "unet {} vs cf {}", m.activation_gib(), cf.activation_gib());
        // symmetric: ends at full resolution
        assert_eq!(m.layers.last().unwrap().d_out, 256);
        assert_eq!(m.layers.last().unwrap().cout, 3);
        // §V-B: 256^3 U-Net needs at least 16 GPUs per sample
        assert!(m.min_gpus_per_sample(16.0, false) >= 16,
                "min gpus {}", m.min_gpus_per_sample(16.0, false));
    }

    #[test]
    fn halo_bytes_sane() {
        let m = cosmoflow_paper(512, false);
        let c1 = &m.layers[0];
        // conv1 halo face: 4 ch * 1 plane * 512^2 * 4 B = 4 MiB
        assert_eq!(c1.halo_face_bytes(8), 4.0 * 512.0 * 512.0 * 4.0);
        assert_eq!(c1.halo_face_bytes(1), 0.0);
    }

    #[test]
    fn halo_bytes_per_axis_sublinear_in_3d() {
        use crate::partition::Grid4;
        let m = cosmoflow_paper(512, false);
        let c1 = &m.layers[0];
        let g222 = Grid4 { n: 1, d: 2, h: 2, w: 2 };
        // D face under 2x2x2: 4 ch * (512/2)^2 * 4 B, same on every axis
        let want = 4.0 * 256.0 * 256.0 * 4.0;
        for axis in 0..3 {
            assert_eq!(c1.halo_face_bytes_axis(g222, axis), want, "axis {axis}");
        }
        // unpartitioned axes exchange nothing
        let g811 = Grid4 { n: 1, d: 8, h: 1, w: 1 };
        assert_eq!(c1.halo_face_bytes_axis(g811, 1), 0.0);
        assert_eq!(c1.halo_face_bytes_axis(g811, 0), c1.halo_face_bytes(8));
        // the paper's multi-axis claim: total halo volume of an 8-rank 3D
        // grid is below the 8-way depth split's
        let total_3d: f64 = m.layers.iter()
            .map(|l| (0..3).map(|a| l.halo_face_bytes_axis(g222, a)).sum::<f64>())
            .sum();
        let total_1d: f64 = m.layers.iter()
            .map(|l| l.halo_face_bytes(8))
            .sum();
        assert!(total_3d < total_1d, "3D {total_3d} vs 1D {total_1d}");
        // exact values (also the committed BENCH_baseline.json gate)
        assert_eq!(total_1d, 11_747_328.0);
        assert_eq!(total_3d, 8_810_496.0);
    }
}
