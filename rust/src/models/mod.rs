//! Network definitions and analytics.
//!
//! [`analytic`] holds the *paper-scale* architectures (CosmoFlow at
//! 128^3/256^3/512^3 exactly as Table I, and the original 3D U-Net at
//! 256^3) with per-layer FLOP, activation-memory and halo-volume
//! accounting. These drive Table I/II and feed the §III-C performance
//! model; they are never compiled to HLO.
//!
//! The miniaturized *functional* models executed by the engine are defined
//! once in `python/compile/model.py` and arrive here through the AOT
//! manifest ([`crate::runtime::ModelInfo`]).

pub mod analytic;

pub use analytic::{cosmoflow_paper, unet3d_paper, AnalyticLayer, AnalyticModel, LayerKind};
