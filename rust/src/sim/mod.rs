//! Stream-level execution timeline (the paper's Fig. 6): per-GPU "Main",
//! "Halo xchg" and "Allreduce" streams for one training iteration, derived
//! from the §III-C per-layer costs.
//!
//! Semantics match the paper's measured behaviour: halo exchanges run on an
//! async stream overlapped with main compute (`FP = max(comp, 2 SR) +
//! comp_halo`); NCCL gradient allreduces start as each layer's backward
//! filter pass completes and overlap the remaining backward work.

use crate::config::ClusterConfig;
use crate::models::AnalyticModel;
use crate::partition::Grid4;
use crate::perfmodel::{allreduce_time, PerfModel, SrModel};
use crate::util::json::{obj, Json};

/// One timeline event.
#[derive(Clone, Debug)]
pub struct Event {
    pub stream: Stream,
    pub name: String,
    pub start_s: f64,
    pub end_s: f64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stream {
    Main,
    Halo,
    Allreduce,
}

impl Stream {
    pub fn label(&self) -> &'static str {
        match self {
            Stream::Main => "Main",
            Stream::Halo => "Halo xchg",
            Stream::Allreduce => "Allreduce",
        }
    }
}

/// A simulated single-GPU timeline for one iteration.
#[derive(Clone, Debug)]
pub struct Timeline {
    pub events: Vec<Event>,
    pub iter_s: f64,
    pub main_busy_s: f64,
}

/// Build the root GPU's timeline for one iteration.
pub fn simulate_iteration(
    model: &AnalyticModel,
    cluster: &ClusterConfig,
    grid: Grid4,
    n: usize,
) -> Timeline {
    let pm = PerfModel::new(cluster);
    let cost = pm.iteration(model, grid, n, f64::MAX);
    let world = grid.world_size();
    let ar_link = if world <= cluster.gpus_per_node {
        SrModel::from_cluster(cluster, crate::perfmodel::Link::NvLink)
    } else {
        SrModel::from_cluster(cluster, crate::perfmodel::Link::InfiniBand)
    };

    let mut events = Vec::new();
    let mut t = 0.0f64;
    let mut main_busy = 0.0f64;
    // ---- forward ----
    for lc in &cost.layers {
        if lc.halo > 0.0 {
            events.push(Event {
                stream: Stream::Halo,
                name: format!("{} halo", lc.name),
                start_s: t,
                end_s: t + lc.halo,
            });
        }
        let comp_end = t + lc.fp.max(lc.halo);
        events.push(Event {
            stream: Stream::Main,
            name: format!("{} FP", lc.name),
            start_s: t,
            end_s: comp_end,
        });
        main_busy += lc.fp;
        t = comp_end;
    }
    // ---- backward (reverse order); allreduce issued as BF completes ----
    let mut ar_t = t;
    let params: Vec<(String, f64)> = model
        .layers
        .iter()
        .map(|l| (l.name.clone(), 4.0 * l.param_count() as f64))
        .collect();
    for (i, lc) in cost.layers.iter().enumerate().rev() {
        if lc.halo > 0.0 {
            events.push(Event {
                stream: Stream::Halo,
                name: format!("{} halo (bwd)", lc.name),
                start_s: t,
                end_s: t + lc.halo,
            });
        }
        let end = t + lc.bd + lc.bf;
        events.push(Event {
            stream: Stream::Main,
            name: format!("{} BD+BF", lc.name),
            start_s: t,
            end_s: end,
        });
        main_busy += lc.bd + lc.bf;
        t = end;
        // async allreduce of this layer's gradients
        let bytes = params[i].1;
        if bytes > 0.0 && world > 1 {
            let ar = allreduce_time(bytes, world, &ar_link);
            let start = ar_t.max(t);
            events.push(Event {
                stream: Stream::Allreduce,
                name: format!("{} AR", params[i].0),
                start_s: start,
                end_s: start + ar,
            });
            ar_t = start + ar;
        }
    }
    let iter_s = t.max(ar_t);
    Timeline { events, iter_s, main_busy_s: main_busy }
}

impl Timeline {
    /// Main-stream occupancy (the paper: "the main streams are nearly
    /// fully packed").
    pub fn main_occupancy(&self) -> f64 {
        self.main_busy_s / self.iter_s
    }

    /// Chrome trace JSON (`chrome://tracing` / Perfetto).
    pub fn chrome_trace(&self) -> String {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                obj(vec![
                    ("name", e.name.as_str().into()),
                    ("ph", "X".into()),
                    ("ts", (e.start_s * 1e6).into()),
                    ("dur", ((e.end_s - e.start_s) * 1e6).into()),
                    ("pid", 0usize.into()),
                    (
                        "tid",
                        match e.stream {
                            Stream::Main => 0usize,
                            Stream::Halo => 1,
                            Stream::Allreduce => 2,
                        }
                        .into(),
                    ),
                ])
            })
            .collect();
        Json::Arr(events).to_string()
    }

    /// ASCII rendering (one row per stream), `width` characters wide.
    pub fn ascii(&self, width: usize) -> String {
        let scale = width as f64 / self.iter_s;
        let mut rows = String::new();
        for stream in [Stream::Main, Stream::Halo, Stream::Allreduce] {
            let mut row = vec![b' '; width];
            for e in self.events.iter().filter(|e| e.stream == stream) {
                let a = (e.start_s * scale) as usize;
                let b = ((e.end_s * scale) as usize).min(width).max(a + 1);
                let ch = match stream {
                    Stream::Main => b'#',
                    Stream::Halo => b'~',
                    Stream::Allreduce => b'=',
                };
                for c in row.iter_mut().take(b.min(width)).skip(a) {
                    *c = ch;
                }
            }
            rows.push_str(&format!(
                "{:<10} |{}|\n",
                stream.label(),
                String::from_utf8(row).unwrap()
            ));
        }
        rows.push_str(&format!("iteration: {:.1} ms\n", self.iter_s * 1e3));
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::cosmoflow_paper;

    fn tl(ways: usize) -> Timeline {
        let m = cosmoflow_paper(512, false);
        let cl = ClusterConfig::default();
        simulate_iteration(&m, &cl, Grid4::depth_only(4, ways), 4)
    }

    /// Fig. 6's headline: 8 -> 16 GPUs/sample gives ~1.66x.
    #[test]
    fn fig6_speedup_in_paper_range() {
        let s = tl(8).iter_s / tl(16).iter_s;
        assert!((1.3..2.0).contains(&s), "8->16 way speedup {s:.2} (paper 1.66x)");
    }

    /// The main stream is nearly fully packed; halo cost is almost
    /// negligible (both observations of §V-B).
    #[test]
    fn main_stream_packed_halo_negligible() {
        let t = tl(8);
        assert!(t.main_occupancy() > 0.9, "occupancy {}", t.main_occupancy());
        let halo: f64 = t
            .events
            .iter()
            .filter(|e| e.stream == Stream::Halo)
            .map(|e| e.end_s - e.start_s)
            .sum();
        assert!(halo < 0.15 * t.iter_s, "halo {halo} vs iter {}", t.iter_s);
    }

    /// Allreduce overlaps backward: it never extends the iteration by more
    /// than a small tail.
    #[test]
    fn allreduce_overlapped() {
        let t = tl(8);
        let main_end = t
            .events
            .iter()
            .filter(|e| e.stream == Stream::Main)
            .map(|e| e.end_s)
            .fold(0.0f64, f64::max);
        assert!(t.iter_s <= main_end * 1.15, "AR tail too long");
    }

    #[test]
    fn trace_formats_render() {
        let t = tl(8);
        let json = t.chrome_trace();
        assert!(json.starts_with('[') && json.contains("\"ph\":\"X\""));
        crate::util::json::Json::parse(&json).unwrap();
        let art = t.ascii(72);
        assert!(art.contains("Main") && art.contains('#'));
    }
}
