//! The I/O pipeline: the grid-aware distributed data store that feeds the
//! hybrid engine, and the PFS performance model (paper §III-B, Figs. 3 & 5).
//!
//! * [`store`] — the functional data store, keyed by the engine's D×H×W
//!   process grid: epoch-0 hyperslab ingestion where each rank reads only
//!   its (D, H, W) block of its owned samples (native container block
//!   reads), a global owner map, per-step group-to-group redistribution
//!   over the communicator (tagged `MsgTag::Redist`), and two training
//!   front-ends — [`store::StoreSource`] (blocking staging) and
//!   [`store::AsyncStaging`] (a prefetch worker that double-buffers the
//!   next step's exchange behind compute). `engine::hybrid` consumes these
//!   through `train_hybrid_store`, so the §III-B pipeline is part of the
//!   functional training path, not just a cost model.
//! * [`pfs`] — the parallel-file-system bandwidth model (240 GB/s aggregate
//!   on Lassen) used by the Fig. 5 ablation.
//! * [`pipeline`] — iteration-time composition: sample-parallel I/O
//!   (baseline, does not strong-scale) vs spatially-parallel I/O with
//!   caching and overlap (the paper's approach), plus calibration of the
//!   spatial-parallel term against traced redistribution bytes.

pub mod pfs;
pub mod pipeline;
pub mod store;

pub use pfs::Pfs;
pub use store::{AsyncStaging, DataStore, StoreSource};
