//! The I/O pipeline: distributed in-memory data store (functional) and the
//! PFS performance model (paper §III-B, Figs. 3 & 5).
//!
//! * [`store`] — the functional data store: epoch-0 hyperslab ingestion
//!   where each rank reads only its slab of its owned samples, a global
//!   owner map, and per-step redistribution over the communicator.
//! * [`pfs`] — the parallel-file-system bandwidth model (240 GB/s aggregate
//!   on Lassen) used by the Fig. 5 ablation.
//! * [`pipeline`] — iteration-time composition: sample-parallel I/O
//!   (baseline, does not strong-scale) vs spatially-parallel I/O with
//!   caching and overlap (the paper's approach).

pub mod pfs;
pub mod pipeline;
pub mod store;

pub use pfs::Pfs;
pub use store::DataStore;
