//! The distributed in-memory data store (paper §III-B, Fig. 3), keyed by
//! the engine's D×H×W process grid.
//!
//! Epoch 0: every rank ingests *only its own (D, H, W) hyperslabs* of the
//! samples its group owns (spatially-parallel ingestion — each rank reads
//! the grid block matching its shard coordinates via the container's
//! native `read_input_block3` path, never a slab-then-crop). The aggregate
//! of all ranks' caches is the full dataset, so the PFS is never touched
//! again.
//!
//! Epoch 1+: before each step, the store redistributes cached hyperslabs so
//! the ranks about to train on a sample hold its shards — peer-to-peer
//! exchanges over the (fast) interconnect instead of PFS reads, tagged
//! [`MsgTag::Redist`] so the traced backend and the calibrated §III-C I/O
//! model can audit the staging volume.
//!
//! The owner map distributes samples round-robin over groups; because every
//! member of a group holds the shard at *its own grid position*, a rank
//! only ever caches hyperslabs of its own (D, H, W) block, and
//! redistribution is a pure position-to-position, group-to-group transfer —
//! never a re-slicing. Shard geometry is [`SpatialGrid::shard_of`]
//! (floor-even, last shard takes the remainder), identical to the engine's
//! even split whenever extents divide — the "aligns the spatially parallel
//! I/O, training, and data caching" property of §III-B.
//!
//! Two functional front-ends wire the store into the training loop:
//!
//! * [`StoreSource`] — a [`SampleSource`] whose per-step shards come from a
//!   blocking [`DataStore::redistribute`] at the top of each step.
//! * [`AsyncStaging`] — a per-rank prefetch worker (the same worker-thread
//!   pattern as `comm::bucket`'s gradient worker, on a second world) that
//!   double-buffers the *next* step's shard exchange behind the current
//!   step's compute, leaving only the residual wait exposed (Fig. 5's
//!   overlapped I/O).

use crate::comm::{Communicator, Counters, MsgTag};
use crate::data::container::Container;
use crate::engine::hybrid::SampleSource;
use crate::partition::GridTopology;
use crate::tensor::pool::BufferPool;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Global owner map: which *group* caches each sample (every member of the
/// group holds the shard at its own grid position).
#[derive(Clone, Debug)]
pub struct OwnerMap {
    pub n_samples: usize,
    pub groups: usize,
}

impl OwnerMap {
    pub fn owner_group(&self, sample: usize) -> usize {
        sample % self.groups
    }

    /// Samples owned by `group`.
    pub fn samples_of(&self, group: usize) -> Vec<usize> {
        (0..self.n_samples).filter(|s| self.owner_group(*s) == group).collect()
    }
}

/// Split one schedule row (`batch_global` sample slots, group-major) into
/// the per-group consumption lists [`DataStore::redistribute`] expects.
pub fn assignments_of(row: &[usize], groups: usize) -> Vec<Vec<usize>> {
    assert!(groups > 0 && row.len() % groups == 0,
            "schedule row of {} slots not divisible by {groups} groups",
            row.len());
    let bpg = row.len() / groups;
    (0..groups).map(|g| row[g * bpg..(g + 1) * bpg].to_vec()).collect()
}

/// One rank's shard cache + redistribution logic.
pub struct DataStore {
    pub topo: GridTopology,
    pub rank: usize,
    pub owner: OwnerMap,
    /// (D, H, W) offset of this rank's hyperslab in the global volume.
    pub shard_off: [usize; 3],
    /// (D, H, W) extents of this rank's hyperslab.
    pub shard_len: [usize; 3],
    /// sample -> cached (input shard, target) — this rank's block only
    cache: HashMap<usize, (Tensor, Tensor)>,
    /// per-step staging of shards fetched from owners
    staged: HashMap<usize, (Tensor, Tensor)>,
    /// recycles last step's staged shards into this step's send copies and
    /// own-sample stages, so steady-state redistribution stops allocating
    pool: BufferPool,
    /// shard tensor shapes (known even when this rank owns no samples)
    x_shape: Vec<usize>,
    t_shape: Vec<usize>,
    pub ingest_bytes: u64,
    pub redist_bytes: u64,
    label_mode: bool,
}

impl DataStore {
    /// Epoch-0 ingestion: read this rank's (D, H, W) hyperslab of every
    /// owned sample through the container's native block path. `label_mode`
    /// caches spatial label shards (U-Net) instead of flat targets
    /// (CosmoFlow).
    pub fn ingest(
        container: &Container,
        topo: GridTopology,
        rank: usize,
        label_mode: bool,
    ) -> Result<DataStore> {
        let (group, pos) = topo.coords_of(rank);
        let (shard_off, shard_len) = topo.grid.shard_of(container.meta.size, pos);
        let owner =
            OwnerMap { n_samples: container.meta.n_samples, groups: topo.groups };
        let shard_vox = (shard_len[0] * shard_len[1] * shard_len[2]) as u64;
        let x_shape =
            vec![1, container.meta.channels, shard_len[0], shard_len[1], shard_len[2]];
        let (t_shape, t_bytes) = if label_mode {
            if container.meta.label_channels == 0 {
                bail!("label-mode store on a container without labels");
            }
            (vec![1, container.meta.label_channels, shard_len[0], shard_len[1],
                  shard_len[2]],
             4 * container.meta.label_channels as u64 * shard_vox)
        } else {
            (vec![1, container.meta.target_len], 4 * container.meta.target_len as u64)
        };
        let mut cache = HashMap::new();
        // Count ingestion from the shard geometry, not the (shared)
        // container byte counter: ranks ingest concurrently under the
        // async staging path, so counter deltas would mix ranks' reads.
        let mut ingest_bytes = 0u64;
        for s in owner.samples_of(group) {
            let x = container.read_input_block3(s, shard_off, shard_len)?;
            let t = if label_mode {
                container.read_label_block3(s, shard_off, shard_len)?
            } else {
                container.read_target(s)?
            };
            ingest_bytes += 4 * container.meta.channels as u64 * shard_vox + t_bytes;
            cache.insert(s, (x, t));
        }
        Ok(DataStore {
            topo,
            rank,
            owner,
            shard_off,
            shard_len,
            cache,
            staged: HashMap::new(),
            pool: BufferPool::new(),
            x_shape,
            t_shape,
            ingest_bytes,
            redist_bytes: 0,
            label_mode,
        })
    }

    /// Container-free construction for `hydra3d verify`'s dry runs: the
    /// cache holds zero-filled shard tensors of the exact shapes an
    /// ingested container of this geometry would produce, so
    /// [`DataStore::redistribute`] issues a byte-identical communication
    /// schedule without a dataset (or a filesystem) in the loop.
    #[allow(clippy::too_many_arguments)]
    pub fn synthetic(
        topo: GridTopology,
        rank: usize,
        n_samples: usize,
        size: usize,
        channels: usize,
        target_len: usize,
        label_channels: usize,
        label_mode: bool,
    ) -> Result<DataStore> {
        let (group, pos) = topo.coords_of(rank);
        let (shard_off, shard_len) = topo.grid.shard_of(size, pos);
        let owner = OwnerMap { n_samples, groups: topo.groups };
        let x_shape = vec![1, channels, shard_len[0], shard_len[1], shard_len[2]];
        let t_shape = if label_mode {
            if label_channels == 0 {
                bail!("label-mode synthetic store needs label_channels > 0");
            }
            vec![1, label_channels, shard_len[0], shard_len[1], shard_len[2]]
        } else {
            vec![1, target_len]
        };
        let mut cache = HashMap::new();
        for s in owner.samples_of(group) {
            cache.insert(s, (Tensor::zeros(&x_shape), Tensor::zeros(&t_shape)));
        }
        Ok(DataStore {
            topo,
            rank,
            owner,
            shard_off,
            shard_len,
            cache,
            staged: HashMap::new(),
            pool: BufferPool::new(),
            x_shape,
            t_shape,
            ingest_bytes: 0,
            redist_bytes: 0,
            label_mode,
        })
    }

    /// Number of cached samples (diagnostics).
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// Inspect a cached entry (diagnostics / tests).
    pub fn cache_entry(&self, sample: usize) -> Option<&(Tensor, Tensor)> {
        self.cache.get(&sample)
    }

    /// Redistribute shards for one step: `assignments[g]` is the list of
    /// samples group `g` will train on. Each rank exchanges with the rank
    /// at the *same grid position* in the owning/consuming group, so every
    /// transfer stays within one (D, H, W) block. Collective: every rank
    /// calls this with identical `assignments`.
    pub fn redistribute(&mut self, ep: &dyn Communicator, assignments: &[Vec<usize>])
                        -> Result<()> {
        assert_eq!(assignments.len(), self.topo.groups,
                   "assignments per group mismatch");
        let (my_group, pos) = self.topo.coords_of(self.rank);
        // retire last step's staging into the pool: those buffers become
        // this step's send copies and own-sample stages
        for (_, (x, t)) in self.staged.drain() {
            self.pool.recycle(x);
            self.pool.recycle(t);
        }
        // send phase: for every sample I own that another group needs
        for (g, samples) in assignments.iter().enumerate() {
            for &s in samples {
                if self.owner.owner_group(s) == my_group && g != my_group {
                    let (x, t) = self
                        .cache
                        .get(&s)
                        .ok_or_else(|| anyhow!("rank {}: sample {s} not cached",
                                               self.rank))?;
                    let dst = self.topo.rank_of(g, pos);
                    let bytes = 4 * (x.numel() + t.numel()) as u64;
                    ep.counters().add_redist_bytes(bytes);
                    let mut xb = self.pool.take(x.numel());
                    xb.copy_from_slice(x.data());
                    let mut tb = self.pool.take(t.numel());
                    tb.copy_from_slice(t.data());
                    ep.send_tagged(dst, xb, MsgTag::Redist);
                    ep.send_tagged(dst, tb, MsgTag::Redist);
                    self.redist_bytes += bytes;
                }
            }
        }
        // receive phase: samples I need but don't own
        for &s in &assignments[my_group] {
            let og = self.owner.owner_group(s);
            if og == my_group {
                let (x, t) = self
                    .cache
                    .get(&s)
                    .ok_or_else(|| anyhow!("rank {}: sample {s} not cached",
                                           self.rank))?;
                self.staged.insert(s, (self.pool.take_clone(x),
                                       self.pool.take_clone(t)));
            } else {
                let src = self.topo.rank_of(og, pos);
                let xbuf = ep.recv_tagged(src, MsgTag::Redist)?;
                let tbuf = ep.recv_tagged(src, MsgTag::Redist)?;
                self.staged.insert(
                    s,
                    (Tensor::from_vec(&self.x_shape, xbuf),
                     Tensor::from_vec(&self.t_shape, tbuf)),
                );
            }
        }
        Ok(())
    }

    /// Fetch a staged shard (after [`DataStore::redistribute`]).
    pub fn staged_shard(&self, sample: usize) -> Result<&(Tensor, Tensor)> {
        self.staged
            .get(&sample)
            .ok_or_else(|| anyhow!("sample {sample} not staged on rank {}", self.rank))
    }

    /// Move the staged map out (the async worker ships it to the compute
    /// thread and immediately starts staging the next step).
    pub fn take_staged(&mut self) -> HashMap<usize, (Tensor, Tensor)> {
        std::mem::take(&mut self.staged)
    }

    pub fn label_mode(&self) -> bool {
        self.label_mode
    }
}

/// Serve one staged (input, target) entry through the [`SampleSource`]
/// geometry checks shared by [`StoreSource`] and [`AsyncStaging`].
fn serve_input(
    staged: &HashMap<usize, (Tensor, Tensor)>,
    sample: usize,
    off: [usize; 3],
    len: [usize; 3],
    shard_off: [usize; 3],
    shard_len: [usize; 3],
) -> Result<Tensor> {
    if off != shard_off || len != shard_len {
        bail!("store shard is {shard_off:?}+{shard_len:?}, engine asked for \
               {off:?}+{len:?} (grid mismatch)");
    }
    staged
        .get(&sample)
        .map(|(x, _)| x.clone())
        .ok_or_else(|| anyhow!("sample {sample} not staged for this step"))
}

fn serve_target(
    staged: &HashMap<usize, (Tensor, Tensor)>,
    sample: usize,
) -> Result<Tensor> {
    staged
        .get(&sample)
        .map(|(_, t)| t.clone())
        .ok_or_else(|| anyhow!("target {sample} not staged for this step"))
}

/// A [`SampleSource`] over the data store with *blocking* per-step
/// redistribution: [`StoreSource::begin_step`] runs the group-to-group
/// exchange on the calling (compute) thread, so the staging cost is fully
/// exposed — the overlap ablation's baseline.
pub struct StoreSource {
    pub store: DataStore,
}

impl StoreSource {
    pub fn new(store: DataStore) -> StoreSource {
        StoreSource { store }
    }

    /// Stage this step's shards (collective over all ranks; `row` is the
    /// step's schedule row, identical everywhere).
    pub fn begin_step(&mut self, ep: &dyn Communicator, row: &[usize]) -> Result<()> {
        let assigns = assignments_of(row, self.store.topo.groups);
        self.store.redistribute(ep, &assigns)
    }
}

impl SampleSource for StoreSource {
    fn len(&self) -> usize {
        self.store.owner.n_samples
    }

    /// Depth-slab view — valid only for depth-only grids (the store shard
    /// is then a full H×W slab).
    fn input_shard(&self, sample: usize, d0: usize, len: usize) -> Result<Tensor> {
        if !self.store.topo.grid.is_depth_only()
            || d0 != self.store.shard_off[0]
            || len != self.store.shard_len[0]
        {
            bail!("store shard is D{}+{} of a {} grid, engine asked for depth \
                   slab [{d0}, {})",
                  self.store.shard_off[0], self.store.shard_len[0],
                  self.store.topo.grid, d0 + len);
        }
        serve_input(&self.store.staged, sample, self.store.shard_off,
                    self.store.shard_len, self.store.shard_off,
                    self.store.shard_len)
    }

    fn target_full(&self, sample: usize) -> Result<Tensor> {
        if self.store.label_mode {
            bail!("label-mode store has no flat targets");
        }
        serve_target(&self.store.staged, sample)
    }

    fn target_shard(&self, sample: usize, d0: usize, len: usize) -> Result<Tensor> {
        self.target_shard3(sample, [d0, 0, 0],
                           [len, self.store.shard_len[1], self.store.shard_len[2]])
    }

    fn input_shard3(&self, sample: usize, off: [usize; 3], len: [usize; 3])
                    -> Result<Tensor> {
        serve_input(&self.store.staged, sample, off, len, self.store.shard_off,
                    self.store.shard_len)
    }

    fn target_shard3(&self, sample: usize, off: [usize; 3], len: [usize; 3])
                     -> Result<Tensor> {
        if !self.store.label_mode {
            bail!("target_shard3 on a store without spatial labels");
        }
        if off != self.store.shard_off || len != self.store.shard_len {
            bail!("label shard is {:?}+{:?}, engine asked for {off:?}+{len:?}",
                  self.store.shard_off, self.store.shard_len);
        }
        serve_target(&self.store.staged, sample)
    }
}

/// Ingestion + redistribution totals of one staging worker.
#[derive(Clone, Copy, Debug, Default)]
pub struct IoWorkerStats {
    pub ingest_bytes: u64,
    pub redist_bytes: u64,
    /// Worker-side seconds spent inside redistributions (hidden behind
    /// compute when the double buffer keeps up; not wall-clock additive).
    pub redist_secs: f64,
}

/// Asynchronous double-buffered staging: a per-rank worker thread owns the
/// store and a *second-world* communicator endpoint (the same isolation
/// pattern as `comm::bucket`'s gradient worker, so staging traffic never
/// interleaves with compute-world halo/BN messages). The worker ingests at
/// start-up, then stages step `s + 1`'s shard exchange while the compute
/// thread trains on step `s`; the bounded channel (capacity 1) caps the
/// run-ahead at one step — a classic double buffer.
pub struct AsyncStaging {
    rx: Receiver<HashMap<usize, (Tensor, Tensor)>>,
    worker: Option<JoinHandle<Result<IoWorkerStats>>>,
    current: HashMap<usize, (Tensor, Tensor)>,
    counters: Arc<Counters>,
    shard_off: [usize; 3],
    shard_len: [usize; 3],
    n_samples: usize,
    label_mode: bool,
    depth_only: bool,
}

impl AsyncStaging {
    /// Spawn the staging worker for `rank`. `ep` must be this rank's
    /// endpoint into a world dedicated to staging traffic; `sched` is the
    /// global sample schedule (one row per step, identical on every rank).
    /// `start_step` skips the schedule prefix a resumed run already
    /// consumed, so the prefetcher and the compute ranks stay in lockstep.
    #[allow(clippy::too_many_arguments)]
    pub fn start(
        container: Arc<Container>,
        topo: GridTopology,
        rank: usize,
        label_mode: bool,
        ep: Box<dyn Communicator>,
        sched: Arc<Vec<Vec<usize>>>,
        groups: usize,
        start_step: usize,
    ) -> AsyncStaging {
        let (_, pos) = topo.coords_of(rank);
        let (shard_off, shard_len) = topo.grid.shard_of(container.meta.size, pos);
        let n_samples = container.meta.n_samples;
        let depth_only = topo.grid.is_depth_only();
        let counters = ep.counters().clone();
        let (tx, rx) = sync_channel::<HashMap<usize, (Tensor, Tensor)>>(1);
        let worker = std::thread::Builder::new()
            .name(format!("io-staging-{rank}"))
            .spawn(move || staging_worker(container, topo, rank, label_mode, ep,
                                          sched, groups, start_step, tx))
            .expect("spawn staging worker");
        AsyncStaging {
            rx,
            worker: Some(worker),
            current: HashMap::new(),
            counters,
            shard_off,
            shard_len,
            n_samples,
            label_mode,
            depth_only,
        }
    }

    /// Shared traffic counters of the staging world (for
    /// `TrainReport::comm_bytes`, like the gradient world's).
    pub fn counters(&self) -> &Arc<Counters> {
        &self.counters
    }

    /// Swap in the next step's staged shards. Returns the exposed wait:
    /// ~zero when the worker kept ahead of compute, the residual staging
    /// time otherwise.
    pub fn begin_step(&mut self) -> Result<f64> {
        let t0 = Instant::now();
        self.current = self.rx.recv().map_err(|_| {
            anyhow!("I/O staging worker terminated early (see join error)")
        })?;
        Ok(t0.elapsed().as_secs_f64())
    }

    pub fn len(&self) -> usize {
        self.n_samples
    }

    pub fn input_shard(&self, sample: usize, d0: usize, len: usize) -> Result<Tensor> {
        if !self.depth_only || d0 != self.shard_off[0] || len != self.shard_len[0] {
            bail!("staged shard is D{}+{}, engine asked for depth slab [{d0}, {})",
                  self.shard_off[0], self.shard_len[0], d0 + len);
        }
        serve_input(&self.current, sample, self.shard_off, self.shard_len,
                    self.shard_off, self.shard_len)
    }

    pub fn input_shard3(&self, sample: usize, off: [usize; 3], len: [usize; 3])
                        -> Result<Tensor> {
        serve_input(&self.current, sample, off, len, self.shard_off, self.shard_len)
    }

    pub fn target_full(&self, sample: usize) -> Result<Tensor> {
        if self.label_mode {
            bail!("label-mode staging has no flat targets");
        }
        serve_target(&self.current, sample)
    }

    pub fn target_shard3(&self, sample: usize, off: [usize; 3], len: [usize; 3])
                         -> Result<Tensor> {
        if !self.label_mode {
            bail!("target_shard3 on a staging source without spatial labels");
        }
        if off != self.shard_off || len != self.shard_len {
            bail!("label shard is {:?}+{:?}, engine asked for {off:?}+{len:?}",
                  self.shard_off, self.shard_len);
        }
        serve_target(&self.current, sample)
    }

    /// Stop the worker and collect its ingestion/redistribution totals.
    pub fn shutdown(mut self) -> Result<IoWorkerStats> {
        drop(self.rx); // unblocks a worker parked on a full double buffer
        match self.worker.take() {
            Some(h) => h.join().map_err(|_| anyhow!("staging worker panicked"))?,
            None => Ok(IoWorkerStats::default()),
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn staging_worker(
    container: Arc<Container>,
    topo: GridTopology,
    rank: usize,
    label_mode: bool,
    ep: Box<dyn Communicator>,
    sched: Arc<Vec<Vec<usize>>>,
    groups: usize,
    start_step: usize,
    tx: SyncSender<HashMap<usize, (Tensor, Tensor)>>,
) -> Result<IoWorkerStats> {
    let mut store = DataStore::ingest(&container, topo, rank, label_mode)?;
    let mut redist_secs = 0.0;
    for row in sched.iter().skip(start_step) {
        let assigns = assignments_of(row, groups);
        let t0 = Instant::now();
        store.redistribute(ep.as_ref(), &assigns)?;
        redist_secs += t0.elapsed().as_secs_f64();
        if tx.send(store.take_staged()).is_err() {
            break; // consumer gone (error or early exit): stop staging
        }
    }
    Ok(IoWorkerStats {
        ingest_bytes: store.ingest_bytes,
        redist_bytes: store.redist_bytes,
        redist_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owner_map_partitions_samples() {
        let om = OwnerMap { n_samples: 10, groups: 3 };
        let mut seen = vec![false; 10];
        for g in 0..3 {
            for s in om.samples_of(g) {
                assert!(!seen[s]);
                seen[s] = true;
                assert_eq!(om.owner_group(s), g);
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn assignments_split_schedule_rows_group_major() {
        let row = [5usize, 1, 4, 2, 0, 3];
        assert_eq!(assignments_of(&row, 3),
                   vec![vec![5, 1], vec![4, 2], vec![0, 3]]);
        assert_eq!(assignments_of(&row, 1), vec![row.to_vec()]);
    }
}
