//! The distributed in-memory data store (paper §III-B, Fig. 3).
//!
//! Epoch 0: every rank ingests *only its own hyperslabs* of the samples it
//! owns (spatially-parallel ingestion — each rank reads the depth range
//! matching its shard position, for the subset of samples assigned to it
//! by the owner map). The aggregate of all ranks' caches is the full
//! dataset, so the PFS is never touched again.
//!
//! Epoch 1+: before each step, the store redistributes cached hyperslabs so
//! the ranks about to train on a sample hold its shards — peer-to-peer
//! exchanges over the (fast) interconnect instead of PFS reads.
//!
//! The owner map distributes samples round-robin over *positions within
//! groups*, so a rank only ever caches hyperslabs of its own depth range:
//! redistribution is a pure group-to-group transfer, never a re-slicing —
//! the "aligns the spatially parallel I/O, training, and data caching"
//! property of §III-B.

use crate::comm::Communicator;
use crate::data::container::Container;
use crate::engine::hybrid::SampleSource;
use crate::partition::{DepthPartition, Topology};
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Global owner map: which *group* caches each sample (every member of the
/// group holds its own depth shard of it).
#[derive(Clone, Debug)]
pub struct OwnerMap {
    pub n_samples: usize,
    pub groups: usize,
}

impl OwnerMap {
    pub fn owner_group(&self, sample: usize) -> usize {
        sample % self.groups
    }

    /// Samples owned by `group`.
    pub fn samples_of(&self, group: usize) -> Vec<usize> {
        (0..self.n_samples).filter(|s| self.owner_group(*s) == group).collect()
    }
}

/// One rank's shard cache + redistribution logic.
pub struct DataStore {
    pub topo: Topology,
    pub rank: usize,
    pub owner: OwnerMap,
    pub part: DepthPartition,
    /// sample -> cached (input shard, target) — this rank's depth range only
    cache: HashMap<usize, (Tensor, Tensor)>,
    /// per-step staging of shards fetched from owners
    staged: HashMap<usize, (Tensor, Tensor)>,
    pub ingest_bytes: u64,
    pub redist_bytes: u64,
    label_mode: bool,
}

impl DataStore {
    /// Epoch-0 ingestion: read this rank's hyperslab of every owned sample.
    /// `label_mode` caches spatial label shards (U-Net) instead of flat
    /// targets (CosmoFlow).
    pub fn ingest(
        container: &Container,
        topo: Topology,
        rank: usize,
        label_mode: bool,
    ) -> Result<DataStore> {
        let (group, pos) = topo.coords_of(rank);
        let part = DepthPartition::new_even(container.meta.size, topo.d_ways)?;
        let owner = OwnerMap { n_samples: container.meta.n_samples, groups: topo.groups };
        let (d0, dlen) = (part.shard_start(pos), part.shard_len());
        let mut cache = HashMap::new();
        let before = container.bytes_read.load(std::sync::atomic::Ordering::Relaxed);
        for s in owner.samples_of(group) {
            let x = container.read_input_shard(s, d0, dlen)?;
            let t = if label_mode {
                container.read_label_shard(s, d0, dlen)?
            } else {
                container.read_target(s)?
            };
            cache.insert(s, (x, t));
        }
        let after = container.bytes_read.load(std::sync::atomic::Ordering::Relaxed);
        Ok(DataStore {
            topo,
            rank,
            owner,
            part,
            cache,
            staged: HashMap::new(),
            ingest_bytes: after - before,
            redist_bytes: 0,
            label_mode,
        })
    }

    /// Number of cached samples (diagnostics).
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// Inspect a cached entry (diagnostics / tests).
    pub fn cache_entry(&self, sample: usize) -> Option<&(Tensor, Tensor)> {
        self.cache.get(&sample)
    }

    /// Redistribute shards for one step: `assignments[g]` is the list of
    /// samples group `g` will train on. Each rank exchanges with the rank
    /// at the *same shard position* in the owning/consuming group, so every
    /// transfer stays within one depth range. Collective: every rank calls
    /// this with identical `assignments`.
    pub fn redistribute(&mut self, ep: &dyn Communicator, assignments: &[Vec<usize>])
                        -> Result<()> {
        let (my_group, pos) = self.topo.coords_of(self.rank);
        self.staged.clear();
        // send phase: for every sample I own that another group needs
        for (g, samples) in assignments.iter().enumerate() {
            for &s in samples {
                if self.owner.owner_group(s) == my_group && g != my_group {
                    let (x, t) = self
                        .cache
                        .get(&s)
                        .ok_or_else(|| anyhow!("rank {}: sample {s} not cached",
                                               self.rank))?;
                    let dst = self.topo.rank_of(g, pos);
                    ep.send(dst, x.data().to_vec());
                    ep.send(dst, t.data().to_vec());
                    self.redist_bytes += 4 * (x.numel() + t.numel()) as u64;
                }
            }
        }
        // receive phase: samples I need but don't own
        for &s in &assignments[my_group] {
            let og = self.owner.owner_group(s);
            if og == my_group {
                let (x, t) = self.cache.get(&s).unwrap();
                self.staged.insert(s, (x.clone(), t.clone()));
            } else {
                let src = self.topo.rank_of(og, pos);
                let xbuf = ep.recv(src)?;
                let tbuf = ep.recv(src)?;
                let (xs, ts) = self.shard_shapes()?;
                self.staged.insert(
                    s,
                    (Tensor::from_vec(&xs, xbuf), Tensor::from_vec(&ts, tbuf)),
                );
            }
        }
        Ok(())
    }

    fn shard_shapes(&self) -> Result<(Vec<usize>, Vec<usize>)> {
        let (x, t) = self
            .cache
            .values()
            .next()
            .ok_or_else(|| anyhow!("empty cache on rank {}", self.rank))?;
        Ok((x.shape().to_vec(), t.shape().to_vec()))
    }

    /// Fetch a staged shard (after [`redistribute`]).
    pub fn staged_shard(&self, sample: usize) -> Result<&(Tensor, Tensor)> {
        self.staged
            .get(&sample)
            .ok_or_else(|| anyhow!("sample {sample} not staged on rank {}", self.rank))
    }

    pub fn label_mode(&self) -> bool {
        self.label_mode
    }
}

/// A [`SampleSource`] over a store that has been fully pre-staged for the
/// samples a rank will consume (used by the store-backed training path).
pub struct StagedSource {
    pub shards: HashMap<(usize, usize, usize), Tensor>, // (sample, d0, len)
    pub targets: HashMap<usize, Tensor>,
    pub n: usize,
}

impl SampleSource for StagedSource {
    fn len(&self) -> usize {
        self.n
    }
    fn input_shard(&self, sample: usize, d0: usize, len: usize) -> Result<Tensor> {
        self.shards
            .get(&(sample, d0, len))
            .cloned()
            .ok_or_else(|| anyhow!("shard ({sample},{d0},{len}) not staged"))
    }
    fn target_full(&self, sample: usize) -> Result<Tensor> {
        self.targets
            .get(&sample)
            .cloned()
            .ok_or_else(|| anyhow!("target {sample} not staged"))
    }
    fn target_shard(&self, sample: usize, d0: usize, len: usize) -> Result<Tensor> {
        let t = self.target_full(sample)?;
        Ok(t.slice_d(d0, len))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owner_map_partitions_samples() {
        let om = OwnerMap { n_samples: 10, groups: 3 };
        let mut seen = vec![false; 10];
        for g in 0..3 {
            for s in om.samples_of(g) {
                assert!(!seen[s]);
                seen[s] = true;
                assert_eq!(om.owner_group(s), g);
            }
        }
        assert!(seen.iter().all(|&x| x));
    }
}
