//! Iteration-time composition of compute and I/O — the model behind the
//! paper's Fig. 5 ablation ("without spatial-parallel I/O, iteration time
//! does not scale at all").
//!
//! Three ingestion strategies:
//!
//! * **SampleParallelPfs** — the conventional reader: one rank per sample
//!   streams whole samples from the PFS every step. Reader parallelism is
//!   capped by the mini-batch size N, so PFS bandwidth stops scaling with
//!   GPUs; and because the sample must then be scattered to its group, a
//!   redistribution cost grows with `ways`.
//! * **SampleParallelCached** — Fig. 5's configuration: the dataset is
//!   cached in host memory (Conduit-style) but each sample is still read
//!   and scattered by a single rank — the scatter and the single-reader
//!   memory bandwidth still bound the pipeline.
//! * **SpatialParallel** — the paper's pipeline: every rank ingests /
//!   receives only its hyperslab (store + owner map); steady-state I/O is a
//!   group-to-group shard copy that shrinks 1/ways and overlaps with
//!   compute, so it vanishes from the critical path.

use super::pfs::Pfs;
use crate::config::ClusterConfig;

/// Ingestion strategies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoStrategy {
    SampleParallelPfs,
    SampleParallelCached,
    /// The paper's pipeline: per-rank hyperslab staging, prefetched behind
    /// compute (the functional `--io store-async` path).
    SpatialParallel,
    /// Spatially-parallel staging *without* the prefetch overlap — the
    /// functional `--io store` path; same volume, fully exposed.
    SpatialParallelBlocking,
}

/// Per-iteration I/O time for a mini-batch of `n` samples of `sample_bytes`
/// each, trained by `n * ways` GPUs.
pub fn io_time_per_iter(
    strategy: IoStrategy,
    pfs: &Pfs,
    cluster: &ClusterConfig,
    sample_bytes: f64,
    n: usize,
    ways: usize,
) -> f64 {
    let host_bw = 16e9; // host memcpy stream bandwidth, bytes/s
    let link_bw = cluster.ib_gbps * 1e9;
    match strategy {
        IoStrategy::SampleParallelPfs => {
            // N concurrent whole-sample readers + scatter to `ways` peers
            let read = pfs.read_time(sample_bytes * n as f64, n);
            let scatter = scatter_time(sample_bytes, ways, link_bw);
            read + scatter
        }
        IoStrategy::SampleParallelCached => {
            // cached in host memory, still single-reader per sample
            let read = sample_bytes / host_bw;
            let scatter = scatter_time(sample_bytes, ways, link_bw);
            read + scatter
        }
        IoStrategy::SpatialParallel | IoStrategy::SpatialParallelBlocking => {
            // every rank moves only its hyperslab, group-to-group, all
            // pairs concurrently; in the async variant the copy is fully
            // overlapped with the previous iteration's compute, but we
            // report its raw cost either way.
            (sample_bytes / ways as f64) / link_bw
        }
    }
}

/// Per-rank, per-iteration redistribution volume of the spatially-parallel
/// store (bytes): the deterministic quantity the functional store's
/// `MsgTag::Redist` counters measure, so the model and a traced run gate
/// against the same number.
pub fn spatial_redist_bytes(sample_bytes: f64, ways: usize) -> f64 {
    sample_bytes / ways.max(1) as f64
}

/// Calibrate the spatially-parallel I/O term against a *traced* run: price
/// the measured per-rank, per-iteration redistribution bytes (the sum of
/// `MsgTag::Redist` payloads divided by ranks × steps) with the cluster
/// link, instead of the analytic `sample_bytes / ways` estimate. When the
/// trace matches the model's volume the two agree exactly — the same
/// measured-vs-closed-form validation `perfmodel::trace` performs for
/// collectives.
pub fn io_time_from_redist_trace(redist_bytes_per_rank_iter: f64,
                                 cluster: &ClusterConfig) -> f64 {
    redist_bytes_per_rank_iter / (cluster.ib_gbps * 1e9)
}

fn scatter_time(sample_bytes: f64, ways: usize, link_bw: f64) -> f64 {
    if ways <= 1 {
        0.0
    } else {
        // the reader sends (ways-1)/ways of the sample out over one link
        sample_bytes * (ways - 1) as f64 / ways as f64 / link_bw
    }
}

/// Whether the strategy's I/O overlaps with compute (the paper's pipeline
/// prefetches the next mini-batch during the current iteration; the
/// blocking store variant moves the same bytes but stays on the critical
/// path).
pub fn overlaps(strategy: IoStrategy) -> bool {
    matches!(strategy, IoStrategy::SpatialParallel)
}

/// Compose iteration time from compute and I/O.
pub fn iteration_time(compute_s: f64, io_s: f64, overlapped: bool) -> f64 {
    if overlapped {
        compute_s.max(io_s)
    } else {
        compute_s + io_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Pfs, ClusterConfig) {
        (Pfs::default(), ClusterConfig::default())
    }

    /// The Fig. 5 phenomenon: with sample-parallel I/O the per-iteration
    /// I/O time is *independent of the GPU count* (fixed N), so strong
    /// scaling stalls; spatial-parallel I/O shrinks 1/ways.
    #[test]
    fn sample_parallel_does_not_strong_scale() {
        let (pfs, cl) = setup();
        let gib = (1u64 << 30) as f64; // one 512^3 x 4ch sample
        let n = 64;
        let t8 = io_time_per_iter(IoStrategy::SampleParallelCached, &pfs, &cl, gib, n, 8);
        let t32 = io_time_per_iter(IoStrategy::SampleParallelCached, &pfs, &cl, gib, n, 32);
        assert!(t32 >= t8 * 0.95, "sample-parallel should not improve: {t8} vs {t32}");

        let s8 = io_time_per_iter(IoStrategy::SpatialParallel, &pfs, &cl, gib, n, 8);
        let s32 = io_time_per_iter(IoStrategy::SpatialParallel, &pfs, &cl, gib, n, 32);
        assert!(s32 < s8 / 3.5, "spatial-parallel must scale: {s8} vs {s32}");
        assert!(s8 < t8, "spatial beats sample-parallel at 8 ways");
    }

    #[test]
    fn pfs_reads_dominate_uncached() {
        let (pfs, cl) = setup();
        let gib = (1u64 << 30) as f64;
        let t = io_time_per_iter(IoStrategy::SampleParallelPfs, &pfs, &cl, gib, 64, 8);
        // 64 GiB over min(64 x 1 GB/s, 240 GB/s) = 64 GB/s -> ~1 s
        assert!(t > 0.5, "{t}");
    }

    #[test]
    fn overlap_composition() {
        assert_eq!(iteration_time(0.2, 0.05, true), 0.2);
        assert_eq!(iteration_time(0.2, 0.5, true), 0.5);
        assert_eq!(iteration_time(0.2, 0.05, false), 0.25);
        assert!(overlaps(IoStrategy::SpatialParallel));
        assert!(!overlaps(IoStrategy::SampleParallelCached));
        assert!(!overlaps(IoStrategy::SpatialParallelBlocking));
    }

    /// The blocking store variant moves the same volume as the overlapped
    /// one, and the trace-calibrated price agrees with the analytic model
    /// when the traced volume matches `sample_bytes / ways`.
    #[test]
    fn calibration_matches_analytic_model() {
        let (pfs, cl) = setup();
        let gib = (1u64 << 30) as f64;
        for ways in [8usize, 32] {
            let a = io_time_per_iter(IoStrategy::SpatialParallel, &pfs, &cl, gib,
                                     16, ways);
            let b = io_time_per_iter(IoStrategy::SpatialParallelBlocking, &pfs,
                                     &cl, gib, 16, ways);
            assert_eq!(a, b, "same volume at {ways} ways");
            let cal = io_time_from_redist_trace(spatial_redist_bytes(gib, ways),
                                                &cl);
            assert!((cal - a).abs() < 1e-12 * a.max(1.0),
                    "calibrated {cal} vs analytic {a}");
        }
    }
}
