//! Parallel-file-system bandwidth model.
//!
//! Lassen's PFS sustains ~240 GB/s in aggregate (§III-B); a single client
//! stream is capped far lower. Read time for a set of concurrent readers is
//! governed by whichever saturates first:
//!
//! `t = bytes_total / min(aggregate_bw, readers * per_reader_bw)` + latency.

/// PFS model parameters (defaults from the paper's system description).
#[derive(Clone, Copy, Debug)]
pub struct Pfs {
    /// aggregate bandwidth, bytes/s
    pub aggregate_bps: f64,
    /// per-reader (per-process) streaming bandwidth, bytes/s
    pub per_reader_bps: f64,
    /// per-request latency, seconds
    pub latency_s: f64,
}

impl Default for Pfs {
    fn default() -> Self {
        Pfs {
            aggregate_bps: 240e9,
            per_reader_bps: 1.0e9, // a single POSIX stream on Lassen's PFS
            latency_s: 1e-3,
        }
    }
}

impl Pfs {
    /// Time for `readers` concurrent processes to collectively read
    /// `bytes_total`, split evenly.
    pub fn read_time(&self, bytes_total: f64, readers: usize) -> f64 {
        if bytes_total <= 0.0 {
            return 0.0;
        }
        let readers = readers.max(1) as f64;
        let bw = (readers * self.per_reader_bps).min(self.aggregate_bps);
        self.latency_s + bytes_total / bw
    }

    /// Effective utilized bandwidth for a reader count.
    pub fn effective_bw(&self, readers: usize) -> f64 {
        (readers.max(1) as f64 * self.per_reader_bps).min(self.aggregate_bps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_scales_with_readers_until_saturation() {
        let pfs = Pfs::default();
        let one = pfs.read_time(64e9, 1);
        let many = pfs.read_time(64e9, 64);
        assert!(many < one / 30.0, "{one} vs {many}");
        // beyond saturation more readers don't help
        let sat = pfs.read_time(64e9, 240);
        let sat2 = pfs.read_time(64e9, 2048);
        assert!((sat - sat2).abs() / sat < 1e-9);
        assert!((pfs.effective_bw(2048) - 240e9).abs() < 1.0);
    }

    #[test]
    fn paper_example_minibatch_load_time() {
        // §III-B: "loading each mini-batch [64 x 1 GiB] requires at least
        // 256 ms" at 240 GB/s.
        let pfs = Pfs { latency_s: 0.0, ..Default::default() };
        let t = pfs.read_time(64.0 * (1u64 << 30) as f64, 100_000);
        assert!((t - 0.286).abs() < 0.03, "{t}");
    }
}
