//! Gaussian-random-field universe synthesis.
//!
//! Each "universe" is a density cube drawn from a Gaussian random field
//! whose isotropic power spectrum depends on four latent parameters
//! (normalized to [-1, 1], like the paper's Ω_M, σ_8, n_s, H_0):
//!
//! * `amp`   — overall fluctuation amplitude (σ_8 analogue),
//! * `tilt`  — spectral index of P(k) ∝ k^n (n_s analogue),
//! * `large` — extra power in the lowest-k modes (H_0 / large-scale
//!   expansion analogue — the paper observes H_0 benefits most from
//!   full-resolution training, Fig. 10),
//! * `cut`   — small-scale exponential cutoff (matter-density analogue).
//!
//! `large` lives *only* in modes with wavelength comparable to the full
//! box: splitting a cube into 8 or 64 sub-volumes discards those modes, so
//! models trained on sub-volumes hit an accuracy floor — the mechanism
//! behind the paper's order-of-magnitude MSE improvement at 512^3.

use crate::tensor::Tensor;
use crate::util::fft::fft3d;
use crate::util::rng::Pcg;

/// Latent parameters, each in [-1, 1].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Universe {
    pub amp: f32,
    pub tilt: f32,
    pub large: f32,
    pub cut: f32,
}

impl Universe {
    pub fn to_target(&self) -> Tensor {
        Tensor::from_vec(&[1, 4], vec![self.amp, self.tilt, self.large, self.cut])
    }

    pub fn sample(rng: &mut Pcg) -> Universe {
        Universe {
            amp: rng.uniform_in(-1.0, 1.0),
            tilt: rng.uniform_in(-1.0, 1.0),
            large: rng.uniform_in(-1.0, 1.0),
            cut: rng.uniform_in(-1.0, 1.0),
        }
    }
}

/// Synthesis configuration.
#[derive(Clone, Copy, Debug)]
pub struct GrfConfig {
    pub size: usize,
    pub seed: u64,
}

/// Power spectrum P(k) for normalized wavenumber k (in units of the
/// fundamental mode 2π/L, i.e. k=1 is one wavelength per box).
fn power(k: f64, u: &Universe) -> f64 {
    if k <= 0.0 {
        return 0.0;
    }
    let amp = (1.0 + 0.45 * u.amp as f64).powi(2);
    let n = -1.2 + 0.8 * u.tilt as f64;
    // `large` modulates ONLY k <= ~2.5 (the full-box modes); exponential
    // form keeps P(k) strictly positive for all parameter values.
    let large = (2.0 * u.large as f64 * (-((k / 2.5) * (k / 2.5))).exp()).exp();
    let kcut = 6.0 * (1.5f64).powf(u.cut as f64);
    amp * k.powf(n) * large * (-(k / kcut)).exp()
}

/// Synthesize one universe: N(0,1) white noise shaped by sqrt(P(k)) in
/// Fourier space, inverse-transformed, then passed through a mild
/// exponential nonlinearity (log-normal-ish density) and standardized.
pub fn synthesize(cfg: &GrfConfig, index: u64, u: &Universe) -> Tensor {
    let n = cfg.size;
    assert!(n.is_power_of_two(), "grf size must be 2^k");
    let mut rng = Pcg::new(cfg.seed ^ 0x6f2_u64, index);
    let vol = n * n * n;
    let mut re = vec![0.0f64; vol];
    let mut im = vec![0.0f64; vol];
    // white noise in real space -> FFT -> shape -> inverse FFT guarantees a
    // real field without hermitian bookkeeping.
    for v in re.iter_mut() {
        *v = rng.normal();
    }
    fft3d(&mut re, &mut im, n, false);
    let half = n / 2;
    for d in 0..n {
        for h in 0..n {
            for w in 0..n {
                let kd = if d <= half { d } else { n - d } as f64;
                let kh = if h <= half { h } else { n - h } as f64;
                let kw = if w <= half { w } else { n - w } as f64;
                let k = (kd * kd + kh * kh + kw * kw).sqrt();
                let s = power(k, u).sqrt();
                let idx = (d * n + h) * n + w;
                re[idx] *= s;
                im[idx] *= s;
            }
        }
    }
    fft3d(&mut re, &mut im, n, true);
    // Normalize by the *reference* field std (parameters all zero) so the
    // amplitude parameter survives — per-field standardization would wash
    // a pure spectral scale out of the data entirely.
    let uref = Universe { amp: 0.0, tilt: 0.0, large: 0.0, cut: 0.0 };
    let mut ref_power = 0.0f64;
    for d in 0..n {
        for h in 0..n {
            for w in 0..n {
                let kd = if d <= half { d } else { n - d } as f64;
                let kh = if h <= half { h } else { n - h } as f64;
                let kw = if w <= half { w } else { n - w } as f64;
                ref_power += power((kd * kd + kh * kh + kw * kw).sqrt(), &uref);
            }
        }
    }
    let ref_std = (ref_power / vol as f64).sqrt().max(1e-12);
    let mean: f64 = re.iter().sum::<f64>() / vol as f64;
    let data: Vec<f32> = re
        .iter()
        .map(|&x| {
            let z = ((x - mean) / ref_std).clamp(-8.0, 8.0);
            // mild nonlinearity: keeps densities positive-skewed without
            // coupling the large-scale modes into local statistics so hard
            // that sub-volumes could recover them
            ((0.35 * z).exp() - 1.063) as f32
        })
        .collect();
    Tensor::from_vec(&[1, 1, n, n, n], data)
}

/// A generated dataset: full cubes or sub-volume splits of the same cubes.
pub struct GrfDataset {
    pub inputs: Vec<Tensor>,
    pub targets: Vec<Tensor>,
    pub params: Vec<Universe>,
}

impl GrfDataset {
    /// `n_universes` full cubes of `size`^3.
    pub fn generate(cfg: &GrfConfig, n_universes: usize) -> GrfDataset {
        let mut rng = Pcg::new(cfg.seed, 0x0111);
        let mut inputs = Vec::with_capacity(n_universes);
        let mut targets = Vec::with_capacity(n_universes);
        let mut params = Vec::with_capacity(n_universes);
        for i in 0..n_universes {
            let u = Universe::sample(&mut rng);
            inputs.push(synthesize(cfg, i as u64, &u));
            targets.push(u.to_target());
            params.push(u);
        }
        GrfDataset { inputs, targets, params }
    }

    /// Split every cube into (size/sub)^3 sub-volumes, each inheriting the
    /// parent's parameters — the paper's 128^3 sub-volume regime (§II-B).
    pub fn split(&self, sub: usize) -> GrfDataset {
        let mut inputs = Vec::new();
        let mut targets = Vec::new();
        let mut params = Vec::new();
        for (x, (t, u)) in self.inputs.iter().zip(self.targets.iter().zip(&self.params)) {
            let n = x.shape()[2];
            assert!(n % sub == 0);
            let per = n / sub;
            for di in 0..per {
                // extract (sub)^3 blocks; reuse slice_ax for depth and
                // manual gather for h/w
                let slab = x.slice_ax(2, di * sub, sub);
                for hi in 0..per {
                    for wi in 0..per {
                        let mut block = Tensor::zeros(&[1, 1, sub, sub, sub]);
                        for d in 0..sub {
                            for h in 0..sub {
                                let src = (d * n + hi * sub + h) * n + wi * sub;
                                let dst = (d * sub + h) * sub;
                                block.data_mut()[dst..dst + sub]
                                    .copy_from_slice(&slab.data()[src..src + sub]);
                            }
                        }
                        inputs.push(block);
                        targets.push(t.clone());
                        params.push(*u);
                    }
                }
            }
        }
        GrfDataset { inputs, targets, params }
    }

    pub fn len(&self) -> usize {
        self.inputs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inputs.is_empty()
    }
}

/// Measured radially-binned power spectrum of a cube (diagnostics/tests).
pub fn measured_spectrum(x: &Tensor) -> Vec<f64> {
    let n = x.shape()[2];
    let vol = n * n * n;
    let mut re: Vec<f64> = x.data().iter().map(|&v| v as f64).collect();
    let mut im = vec![0.0f64; vol];
    fft3d(&mut re, &mut im, n, false);
    let half = n / 2;
    let mut pow = vec![0.0f64; half + 1];
    let mut cnt = vec![0usize; half + 1];
    for d in 0..n {
        for h in 0..n {
            for w in 0..n {
                let kd = if d <= half { d } else { n - d } as f64;
                let kh = if h <= half { h } else { n - h } as f64;
                let kw = if w <= half { w } else { n - w } as f64;
                let k = (kd * kd + kh * kh + kw * kw).sqrt().round() as usize;
                if k <= half {
                    let idx = (d * n + h) * n + w;
                    pow[k] += (re[idx] * re[idx] + im[idx] * im[idx]) / vol as f64;
                    cnt[k] += 1;
                }
            }
        }
    }
    pow.iter().zip(&cnt).map(|(p, &c)| if c > 0 { p / c as f64 } else { 0.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GrfConfig {
        GrfConfig { size: 16, seed: 7 }
    }

    #[test]
    fn deterministic_generation() {
        let u = Universe { amp: 0.2, tilt: -0.3, large: 0.5, cut: 0.0 };
        let a = synthesize(&cfg(), 3, &u);
        let b = synthesize(&cfg(), 3, &u);
        assert_eq!(a.max_abs_diff(&b), 0.0);
        let c = synthesize(&cfg(), 4, &u);
        assert!(a.max_abs_diff(&c) > 0.1, "different index, different field");
    }

    #[test]
    fn amplitude_parameter_scales_power() {
        let lo = Universe { amp: -1.0, tilt: 0.0, large: 0.0, cut: 0.0 };
        let hi = Universe { amp: 1.0, tilt: 0.0, large: 0.0, cut: 0.0 };
        let a = synthesize(&cfg(), 0, &lo);
        let b = synthesize(&cfg(), 0, &hi);
        let va: f64 = a.data().iter().map(|&x| (x as f64).powi(2)).sum();
        let vb: f64 = b.data().iter().map(|&x| (x as f64).powi(2)).sum();
        assert!(vb > 1.5 * va, "amp must raise variance: {va} vs {vb}");
    }

    #[test]
    fn large_scale_parameter_lives_at_low_k() {
        let lo = Universe { amp: 0.0, tilt: 0.0, large: -1.0, cut: 0.0 };
        let hi = Universe { amp: 0.0, tilt: 0.0, large: 1.0, cut: 0.0 };
        let a = measured_spectrum(&synthesize(&cfg(), 1, &lo));
        let b = measured_spectrum(&synthesize(&cfg(), 1, &hi));
        // low-k power differs strongly...
        let low_ratio = b[1] / a[1].max(1e-12);
        assert!(low_ratio > 3.0, "low-k ratio {low_ratio}");
        // ...and much more than high-k power (the exp nonlinearity couples
        // modes, so high-k shifts a little; the *separation* is what makes
        // `large` unlearnable from sub-volumes).
        let hi_ratio = b[6] / a[6].max(1e-12);
        assert!(hi_ratio < low_ratio / 2.5,
                "separation too weak: low {low_ratio} vs high {hi_ratio}");
    }

    #[test]
    fn dataset_and_split_geometry() {
        let ds = GrfDataset::generate(&cfg(), 2);
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.inputs[0].shape(), &[1, 1, 16, 16, 16]);
        let sub = ds.split(8);
        assert_eq!(sub.len(), 2 * 8);
        assert_eq!(sub.inputs[0].shape(), &[1, 1, 8, 8, 8]);
        // sub-volume targets inherit the parent's parameters
        for i in 0..8 {
            assert_eq!(sub.targets[i].data(), ds.targets[0].data());
        }
        // first sub-volume equals the corner block of the parent
        let parent = &ds.inputs[0];
        let block = &sub.inputs[0];
        for d in 0..8 {
            for h in 0..8 {
                for w in 0..8 {
                    let pv = parent.data()[(d * 16 + h) * 16 + w];
                    let bv = block.data()[(d * 8 + h) * 8 + w];
                    assert_eq!(pv, bv);
                }
            }
        }
    }
}
