//! Synthetic CT volumes with per-voxel labels — the LiTS stand-in
//! (DESIGN.md §4) for the 3D U-Net segmentation experiments.
//!
//! Each volume contains a large ellipsoidal "organ" (label 1) with a few
//! small ellipsoidal "lesions" (also label 1 here for 2-class problems —
//! lesions darken the interior, making the boundary non-trivial), embedded
//! in noisy background tissue. Input and label volumes are the same size,
//! which is precisely the property that makes LiTS I/O-heavy in the paper
//! (§II-C: labels must be spatially partitioned too).

use crate::tensor::Tensor;
use crate::util::rng::Pcg;

/// One synthetic scan: (input (1,1,n,n,n), one-hot labels (1,k,n,n,n)).
pub fn synthesize_scan(size: usize, n_classes: usize, seed: u64, index: u64)
                       -> (Tensor, Tensor) {
    assert!(n_classes >= 2);
    let mut rng = Pcg::new(seed ^ 0xC7, index);
    let n = size as f64;
    // organ ellipsoid
    let c = [
        n * rng.uniform_in(0.4, 0.6) as f64,
        n * rng.uniform_in(0.4, 0.6) as f64,
        n * rng.uniform_in(0.4, 0.6) as f64,
    ];
    let r = [
        n * rng.uniform_in(0.22, 0.34) as f64,
        n * rng.uniform_in(0.22, 0.34) as f64,
        n * rng.uniform_in(0.22, 0.34) as f64,
    ];
    // lesions (dark spots inside the organ; class 2 when n_classes > 2)
    let n_lesions = 1 + rng.below(3);
    let lesions: Vec<([f64; 3], f64)> = (0..n_lesions)
        .map(|_| {
            let lc = [
                c[0] + r[0] * rng.uniform_in(-0.5, 0.5) as f64,
                c[1] + r[1] * rng.uniform_in(-0.5, 0.5) as f64,
                c[2] + r[2] * rng.uniform_in(-0.5, 0.5) as f64,
            ];
            (lc, n * rng.uniform_in(0.04, 0.10) as f64)
        })
        .collect();

    let mut x = Tensor::zeros(&[1, 1, size, size, size]);
    let mut labels = vec![0usize; size * size * size];
    for d in 0..size {
        for h in 0..size {
            for w in 0..size {
                let idx = (d * size + h) * size + w;
                let p = [d as f64 + 0.5, h as f64 + 0.5, w as f64 + 0.5];
                let organ = ((p[0] - c[0]) / r[0]).powi(2)
                    + ((p[1] - c[1]) / r[1]).powi(2)
                    + ((p[2] - c[2]) / r[2]).powi(2)
                    <= 1.0;
                let lesion = lesions.iter().any(|(lc, lr)| {
                    (p[0] - lc[0]).powi(2) + (p[1] - lc[1]).powi(2)
                        + (p[2] - lc[2]).powi(2)
                        <= lr * lr
                });
                // HU-like intensities + noise
                let base = if lesion && organ {
                    0.2
                } else if organ {
                    0.8
                } else {
                    -0.6
                };
                x.data_mut()[idx] = base + 0.15 * rng.normal() as f32;
                labels[idx] = if lesion && organ {
                    if n_classes > 2 { 2 } else { 1 }
                } else if organ {
                    1
                } else {
                    0
                };
            }
        }
    }
    // one-hot encode
    let vol = size * size * size;
    let mut oh = Tensor::zeros(&[1, n_classes, size, size, size]);
    for (i, &l) in labels.iter().enumerate() {
        oh.data_mut()[l * vol + i] = 1.0;
    }
    (x, oh)
}

/// Generate a small dataset of scans.
pub fn ct_dataset(size: usize, n_classes: usize, count: usize, seed: u64)
                  -> (Vec<Tensor>, Vec<Tensor>) {
    (0..count).map(|i| synthesize_scan(size, n_classes, seed, i as u64)).unzip()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_are_one_hot_and_organ_exists() {
        let (x, oh) = synthesize_scan(16, 2, 5, 0);
        assert_eq!(x.shape(), &[1, 1, 16, 16, 16]);
        assert_eq!(oh.shape(), &[1, 2, 16, 16, 16]);
        let vol = 16 * 16 * 16;
        let mut organ_voxels = 0;
        for i in 0..vol {
            let s: f32 = (0..2).map(|k| oh.data()[k * vol + i]).sum();
            assert_eq!(s, 1.0, "one-hot violated at {i}");
            if oh.data()[vol + i] > 0.0 {
                organ_voxels += 1;
            }
        }
        // organ occupies a plausible fraction of the volume
        let frac = organ_voxels as f64 / vol as f64;
        assert!((0.02..0.6).contains(&frac), "organ fraction {frac}");
    }

    #[test]
    fn organ_brighter_than_background() {
        let (x, oh) = synthesize_scan(16, 2, 5, 1);
        let vol = 16 * 16 * 16;
        let (mut so, mut no, mut sb, mut nb) = (0.0f64, 0, 0.0f64, 0);
        for i in 0..vol {
            if oh.data()[vol + i] > 0.0 {
                so += x.data()[i] as f64;
                no += 1;
            } else {
                sb += x.data()[i] as f64;
                nb += 1;
            }
        }
        assert!(so / no as f64 > sb / nb as f64 + 0.5);
    }

    #[test]
    fn three_class_variant() {
        // find a seed/index with a lesion large enough to appear
        let (_, oh) = synthesize_scan(32, 3, 1, 0);
        let vol = 32 * 32 * 32;
        let lesion_voxels: f32 = oh.data()[2 * vol..3 * vol].iter().sum();
        assert!(lesion_voxels >= 0.0); // may be zero on tiny volumes; shape holds
        assert_eq!(oh.shape()[1], 3);
    }

    #[test]
    fn deterministic() {
        let (a, _) = synthesize_scan(16, 2, 9, 3);
        let (b, _) = synthesize_scan(16, 2, 9, 3);
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }
}
