//! Datasets: synthesis, storage, hyperslab access.
//!
//! * [`grf`] — Gaussian-random-field "universes" with parameter-dependent
//!   power spectra: the stand-in for the CosmoFlow N-body dataset
//!   (DESIGN.md §4). The 4 latent parameters are only fully recoverable
//!   from full cubes (large-scale modes), reproducing the paper's science
//!   claim that sub-volume training caps accuracy.
//! * [`ct`] — synthetic CT volumes with organ/lesion labels: the LiTS
//!   stand-in for the 3D U-Net (equal-size input and label volumes).
//! * [`container`] — a depth-chunked binary volume container (the HDF5
//!   stand-in): hyperslab reads are contiguous chunk reads, which is the
//!   property parallel HDF5 gives the paper's spatially-parallel reader.

pub mod container;
pub mod ct;
pub mod grf;

pub use container::{Container, ContainerWriter};
pub use grf::{GrfConfig, Universe};
