//! Depth-chunked binary volume container — the HDF5 stand-in.
//!
//! Layout (little-endian f32 payloads):
//!
//! ```text
//! magic "H3D1" | u32 header_len | header JSON
//! targets:  n_samples * target_len          (regression targets)
//! inputs:   n_samples * C * D * H * W       (depth-major per channel)
//! labels:   n_samples * K * D * H * W       (optional one-hot volumes)
//! ```
//!
//! Because each (sample, channel) is depth-contiguous, a depth hyperslab
//! read is one contiguous `pread` per channel — the access pattern parallel
//! HDF5 gives the paper's spatially-parallel reader (§III-B). All reads go
//! through `read_exact_at`, so a single [`Container`] serves every rank
//! thread concurrently, and a byte counter feeds the I/O accounting.

use crate::engine::hybrid::SampleSource;
use crate::tensor::Tensor;
use crate::util::json::{obj, Json};
use anyhow::{anyhow, bail, Result};
use std::fs::File;
use std::io::Write;
use std::os::unix::fs::FileExt;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

const MAGIC: &[u8; 4] = b"H3D1";

/// Container metadata.
#[derive(Clone, Debug)]
pub struct Meta {
    pub n_samples: usize,
    pub channels: usize,
    pub size: usize,
    pub target_len: usize,
    pub label_channels: usize, // 0 = no label volumes
}

/// Streaming writer.
pub struct ContainerWriter {
    file: File,
    meta: Meta,
    written_targets: usize,
    written_inputs: usize,
    written_labels: usize,
}

impl ContainerWriter {
    pub fn create(path: &Path, meta: Meta) -> Result<ContainerWriter> {
        let mut file = File::create(path)?;
        let hdr = obj(vec![
            ("n_samples", meta.n_samples.into()),
            ("channels", meta.channels.into()),
            ("size", meta.size.into()),
            ("target_len", meta.target_len.into()),
            ("label_channels", meta.label_channels.into()),
        ])
        .to_string();
        file.write_all(MAGIC)?;
        file.write_all(&(hdr.len() as u32).to_le_bytes())?;
        file.write_all(hdr.as_bytes())?;
        Ok(ContainerWriter {
            file,
            meta,
            written_targets: 0,
            written_inputs: 0,
            written_labels: 0,
        })
    }

    /// Targets must be written first, then inputs, then labels (layout
    /// order). Enforced by counters.
    pub fn write_target(&mut self, t: &Tensor) -> Result<()> {
        if t.numel() != self.meta.target_len {
            bail!("target len {} != {}", t.numel(), self.meta.target_len);
        }
        if self.written_inputs > 0 {
            bail!("targets must precede inputs");
        }
        write_f32s(&mut self.file, t.data())?;
        self.written_targets += 1;
        Ok(())
    }

    pub fn write_input(&mut self, x: &Tensor) -> Result<()> {
        let m = &self.meta;
        let want = [1, m.channels, m.size, m.size, m.size];
        if x.shape() != want {
            bail!("input shape {:?} != {:?}", x.shape(), want);
        }
        if self.written_targets != m.n_samples {
            bail!("write all {} targets before inputs", m.n_samples);
        }
        write_f32s(&mut self.file, x.data())?;
        self.written_inputs += 1;
        Ok(())
    }

    pub fn write_label(&mut self, l: &Tensor) -> Result<()> {
        let m = &self.meta;
        let want = [1, m.label_channels, m.size, m.size, m.size];
        if l.shape() != want {
            bail!("label shape {:?} != {:?}", l.shape(), want);
        }
        if self.written_inputs != m.n_samples {
            bail!("write all inputs before labels");
        }
        write_f32s(&mut self.file, l.data())?;
        self.written_labels += 1;
        Ok(())
    }

    pub fn finish(self) -> Result<()> {
        let m = &self.meta;
        if self.written_inputs != m.n_samples
            || (m.label_channels > 0 && self.written_labels != m.n_samples)
        {
            bail!("incomplete container");
        }
        self.file.sync_all()?;
        Ok(())
    }
}

fn write_f32s(f: &mut File, data: &[f32]) -> Result<()> {
    // safe little-endian serialization
    let mut buf = Vec::with_capacity(data.len() * 4);
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Random-access reader (thread-safe: positioned reads only).
pub struct Container {
    file: File,
    pub meta: Meta,
    targets_off: u64,
    inputs_off: u64,
    labels_off: u64,
    pub bytes_read: AtomicU64,
    pub reads: AtomicU64,
}

impl Container {
    pub fn open(path: &Path) -> Result<Container> {
        let file = File::open(path).map_err(|e| anyhow!("open {path:?}: {e}"))?;
        let mut head = [0u8; 8];
        file.read_exact_at(&mut head, 0)?;
        if &head[..4] != MAGIC {
            bail!("{path:?}: not an H3D1 container");
        }
        let hdr_len = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
        let mut hdr = vec![0u8; hdr_len];
        file.read_exact_at(&mut hdr, 8)?;
        let v = Json::parse(std::str::from_utf8(&hdr)?)?;
        let meta = Meta {
            n_samples: v.req("n_samples")?.as_usize()?,
            channels: v.req("channels")?.as_usize()?,
            size: v.req("size")?.as_usize()?,
            target_len: v.req("target_len")?.as_usize()?,
            label_channels: v.req("label_channels")?.as_usize()?,
        };
        let targets_off = 8 + hdr_len as u64;
        let vol = (meta.size * meta.size * meta.size) as u64;
        let inputs_off = targets_off + (meta.n_samples * meta.target_len) as u64 * 4;
        let labels_off = inputs_off + meta.n_samples as u64 * meta.channels as u64 * vol * 4;
        Ok(Container {
            file,
            meta,
            targets_off,
            inputs_off,
            labels_off,
            bytes_read: AtomicU64::new(0),
            reads: AtomicU64::new(0),
        })
    }

    fn read_f32s(&self, off: u64, count: usize) -> Result<Vec<f32>> {
        let mut buf = vec![0u8; count * 4];
        self.file.read_exact_at(&mut buf, off)?;
        self.bytes_read.fetch_add(buf.len() as u64, Ordering::Relaxed);
        self.reads.fetch_add(1, Ordering::Relaxed);
        Ok(buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn read_target(&self, sample: usize) -> Result<Tensor> {
        let tl = self.meta.target_len;
        let v = self.read_f32s(self.targets_off + (sample * tl) as u64 * 4, tl)?;
        Ok(Tensor::from_vec(&[1, tl], v))
    }

    /// Depth hyperslab of the input volume: one contiguous read per channel.
    pub fn read_input_shard(&self, sample: usize, d0: usize, len: usize) -> Result<Tensor> {
        self.read_shard(self.inputs_off, self.meta.channels, sample, d0, len)
    }

    /// Depth hyperslab of the one-hot label volume.
    pub fn read_label_shard(&self, sample: usize, d0: usize, len: usize) -> Result<Tensor> {
        if self.meta.label_channels == 0 {
            bail!("container has no labels");
        }
        self.read_shard(self.labels_off, self.meta.label_channels, sample, d0, len)
    }

    fn read_shard(&self, base: u64, channels: usize, sample: usize, d0: usize,
                  len: usize) -> Result<Tensor> {
        let s = self.meta.size;
        if d0 + len > s {
            bail!("hyperslab [{d0}, {}) out of depth {s}", d0 + len);
        }
        let plane = s * s;
        let vol = (s * plane) as u64;
        let mut data = Vec::with_capacity(channels * len * plane);
        for c in 0..channels {
            let off = base
                + ((sample * channels + c) as u64 * vol + (d0 * plane) as u64) * 4;
            data.extend(self.read_f32s(off, len * plane)?);
        }
        Ok(Tensor::from_vec(&[1, channels, len, s, s], data))
    }

    /// Native (D, H, W) hyperslab of the input volume: reads exactly the
    /// block's bytes — one contiguous run per channel for full-H×W depth
    /// slabs, per (c, d) plane for full-W slabs, per (c, d, h) row
    /// otherwise. No read-slab-then-crop: this is the access pattern
    /// parallel HDF5 hyperslab selection gives the paper's grid reader.
    pub fn read_input_block3(&self, sample: usize, off: [usize; 3],
                             len: [usize; 3]) -> Result<Tensor> {
        self.read_block3(self.inputs_off, self.meta.channels, sample, off, len)
    }

    /// Native (D, H, W) hyperslab of the one-hot label volume.
    pub fn read_label_block3(&self, sample: usize, off: [usize; 3],
                             len: [usize; 3]) -> Result<Tensor> {
        if self.meta.label_channels == 0 {
            bail!("container has no labels");
        }
        self.read_block3(self.labels_off, self.meta.label_channels, sample, off, len)
    }

    fn read_block3(&self, base: u64, channels: usize, sample: usize,
                   off: [usize; 3], len: [usize; 3]) -> Result<Tensor> {
        let s = self.meta.size;
        for a in 0..3 {
            if off[a] + len[a] > s || len[a] == 0 {
                bail!("hyperslab [{}, {}) out of axis {a} extent {s}",
                      off[a], off[a] + len[a]);
            }
        }
        let plane = s * s;
        let vol = (s * plane) as u64;
        let mut data = Vec::with_capacity(channels * len[0] * len[1] * len[2]);
        for c in 0..channels {
            let cbase = base + (sample * channels + c) as u64 * vol * 4;
            if len[1] == s && len[2] == s {
                // full-plane depth slab: one contiguous read per channel
                data.extend(self.read_f32s(cbase + (off[0] * plane) as u64 * 4,
                                           len[0] * plane)?);
            } else if len[2] == s {
                // full-W rows: one contiguous read per (c, d) plane
                for d in 0..len[0] {
                    let o = ((off[0] + d) * plane + off[1] * s) as u64;
                    data.extend(self.read_f32s(cbase + o * 4, len[1] * s)?);
                }
            } else {
                // general block: one read per (c, d, h) row
                for d in 0..len[0] {
                    for h in 0..len[1] {
                        let o = ((off[0] + d) * plane + (off[1] + h) * s + off[2])
                            as u64;
                        data.extend(self.read_f32s(cbase + o * 4, len[2])?);
                    }
                }
            }
        }
        Ok(Tensor::from_vec(&[1, channels, len[0], len[1], len[2]], data))
    }
}

/// Direct-from-file shard source: every rank reads only its hyperslab —
/// the paper's epoch-0 ingestion path.
impl SampleSource for Container {
    fn len(&self) -> usize {
        self.meta.n_samples
    }
    fn input_shard(&self, sample: usize, d0: usize, len: usize) -> Result<Tensor> {
        self.read_input_shard(sample, d0, len)
    }
    fn target_full(&self, sample: usize) -> Result<Tensor> {
        self.read_target(sample)
    }
    fn target_shard(&self, sample: usize, d0: usize, len: usize) -> Result<Tensor> {
        self.read_label_shard(sample, d0, len)
    }
    fn input_shard3(&self, sample: usize, off: [usize; 3], len: [usize; 3])
                    -> Result<Tensor> {
        self.read_input_block3(sample, off, len)
    }
    fn target_shard3(&self, sample: usize, off: [usize; 3], len: [usize; 3])
                     -> Result<Tensor> {
        self.read_label_block3(sample, off, len)
    }
}

/// Write a whole in-memory dataset into a container file.
pub fn write_dataset(
    path: &Path,
    inputs: &[Tensor],
    targets: &[Tensor],
    labels: Option<&[Tensor]>,
) -> Result<()> {
    assert!(!inputs.is_empty());
    let shape = inputs[0].shape();
    let meta = Meta {
        n_samples: inputs.len(),
        channels: shape[1],
        size: shape[2],
        target_len: targets[0].numel(),
        label_channels: labels.map(|l| l[0].shape()[1]).unwrap_or(0),
    };
    let mut w = ContainerWriter::create(path, meta)?;
    for t in targets {
        w.write_target(t)?;
    }
    for x in inputs {
        w.write_input(x)?;
    }
    if let Some(ls) = labels {
        for l in ls {
            w.write_label(l)?;
        }
    }
    w.finish()
}

/// Write a segmentation dataset (inputs + one-hot label volumes) into a
/// container. Spatial-label tasks never read the flat target slot, but the
/// layout requires one target per sample, so a minimal placeholder is
/// written — the one idiom every store-backed U-Net caller needs.
pub fn write_label_dataset(
    path: &Path,
    inputs: &[Tensor],
    labels: &[Tensor],
) -> Result<()> {
    let dummy: Vec<Tensor> =
        (0..inputs.len()).map(|_| Tensor::zeros(&[1, 1])).collect();
    write_dataset(path, inputs, &dummy, Some(labels))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("hydra3d-test-{name}-{}", std::process::id()));
        p
    }

    fn rand_tensor(rng: &mut Pcg, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn roundtrip_and_hyperslabs() {
        let mut rng = Pcg::new(1, 1);
        // container volumes are cubic (meta.size^3)
        let inputs: Vec<Tensor> =
            (0..3).map(|_| rand_tensor(&mut rng, &[1, 2, 8, 8, 8])).collect();
        let targets: Vec<Tensor> =
            (0..3).map(|_| rand_tensor(&mut rng, &[1, 4])).collect();
        let path = tmpfile("roundtrip");
        write_dataset(&path, &inputs, &targets, None).unwrap();

        let c = Container::open(&path).unwrap();
        assert_eq!(c.meta.n_samples, 3);
        for s in 0..3 {
            assert_eq!(c.read_target(s).unwrap(), targets[s]);
            // full read == original
            let full = c.read_input_shard(s, 0, 8).unwrap();
            assert_eq!(full, inputs[s]);
            // hyperslab == slice
            let shard = c.read_input_shard(s, 2, 4).unwrap();
            assert_eq!(shard, inputs[s].slice_ax(2, 2, 4));
        }
        // hyperslab reads touch only the bytes they need (per channel read)
        c.bytes_read.store(0, Ordering::Relaxed);
        let _ = c.read_input_shard(0, 0, 2).unwrap();
        assert_eq!(c.bytes_read.load(Ordering::Relaxed), 2 * 2 * 64 * 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn block3_reads_match_memory_and_touch_exact_bytes() {
        let mut rng = Pcg::new(3, 1);
        let inputs: Vec<Tensor> =
            (0..2).map(|_| rand_tensor(&mut rng, &[1, 2, 8, 8, 8])).collect();
        let targets: Vec<Tensor> =
            (0..2).map(|_| rand_tensor(&mut rng, &[1, 4])).collect();
        let path = tmpfile("block3");
        write_dataset(&path, &inputs, &targets, None).unwrap();
        let c = Container::open(&path).unwrap();
        for (off, len) in [
            ([0usize, 0, 0], [8usize, 8, 8]), // whole volume
            ([2, 0, 0], [4, 8, 8]),           // depth slab fast path
            ([2, 4, 0], [4, 4, 8]),           // full-W rows path
            ([1, 2, 3], [3, 4, 5]),           // general block
        ] {
            c.bytes_read.store(0, Ordering::Relaxed);
            let got = c.read_input_block3(1, off, len).unwrap();
            assert_eq!(got, inputs[1].block3(off, len), "off {off:?} len {len:?}");
            // exactly the block's bytes were read, never a superset
            assert_eq!(c.bytes_read.load(Ordering::Relaxed),
                       (2 * len[0] * len[1] * len[2] * 4) as u64,
                       "off {off:?} len {len:?}");
        }
        assert!(c.read_input_block3(0, [6, 0, 0], [4, 8, 8]).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn labels_roundtrip() {
        let mut rng = Pcg::new(2, 1);
        let inputs: Vec<Tensor> =
            (0..2).map(|_| rand_tensor(&mut rng, &[1, 1, 4, 4, 4])).collect();
        let targets: Vec<Tensor> = (0..2).map(|_| Tensor::zeros(&[1, 1])).collect();
        let labels: Vec<Tensor> =
            (0..2).map(|_| rand_tensor(&mut rng, &[1, 3, 4, 4, 4])).collect();
        let path = tmpfile("labels");
        write_dataset(&path, &inputs, &targets, Some(&labels)).unwrap();
        let c = Container::open(&path).unwrap();
        assert_eq!(c.read_label_shard(1, 1, 2).unwrap(), labels[1].slice_ax(2, 1, 2));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn writer_enforces_order_and_shapes() {
        let path = tmpfile("order");
        let meta = Meta { n_samples: 1, channels: 1, size: 4, target_len: 2,
                          label_channels: 0 };
        let mut w = ContainerWriter::create(&path, meta).unwrap();
        assert!(w.write_input(&Tensor::zeros(&[1, 1, 4, 4, 4])).is_err());
        w.write_target(&Tensor::zeros(&[1, 2])).unwrap();
        assert!(w.write_input(&Tensor::zeros(&[1, 1, 2, 4, 4])).is_err());
        w.write_input(&Tensor::zeros(&[1, 1, 4, 4, 4])).unwrap();
        w.finish().unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("magic");
        std::fs::write(&path, b"NOTAHDF5FILE....").unwrap();
        assert!(Container::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
