//! Halo exchange for spatially partitioned activations (§III-A of the
//! paper), generalized from depth slabs to face exchanges along any subset
//! of the (D, H, W) axes.
//!
//! Forward: each rank contributes its boundary faces to its neighbours and
//! receives theirs, building a halo-padded shard the conv executable can
//! consume with a convolution that is `valid` along every padded axis.
//! Boundary ranks get zero faces on the outer side (the global "same"
//! padding).
//!
//! A 3D grid exchange is **per partitioned axis, sequentially** (D, then
//! H, then W). Because each axis exchange sends the full, already-padded
//! boundary face, corner and edge regions propagate through the
//! neighbours' previous exchanges — after the last axis the shard is
//! *exactly* the halo-padded hyperslab of the globally padded volume (the
//! reassembly test below asserts bitwise equality), which is the paper's
//! per-dimension halo-region scheme and is exact for separable "same"
//! padding.
//!
//! The grid entry points ([`exchange_forward_grid`] /
//! [`exchange_backward_grid`]) implement that sequential algorithm
//! **fused**: one padded buffer of the final shape is built up front and
//! every per-axis face is packed/unpacked as a `block3` hyperslab of that
//! buffer, with send/recv storage drawn from an optional per-rank
//! [`BufferPool`]. No intermediate repadded/cropped tensors exist — the
//! per-axis composition used to move the whole (growing) shard through a
//! fresh allocation per axis, which dominated step time. Face extents per
//! axis are identical to the sequential composition (already-exchanged
//! axes contribute their full padded extent, later axes only their
//! interior), so byte counters and results are bit-identical; the
//! composition test below asserts this against the per-axis functions.
//!
//! Backward: `conv_bwd_data` produces gradients for the *padded* input;
//! the halo-face gradients belong to the neighbours' interiors, so they
//! are sent back and **accumulated**. The 3D backward walks the axes in
//! reverse (W, then H, then D) — the exact adjoint of the forward
//! composition, verified by the adjoint property test. The fused backward
//! mutates the padded gradient in place and extracts the interior once at
//! the end.
//!
//! Pack/unpack are contiguous-slab copies (see [`crate::tensor`]); the
//! paper's equivalent is its suite of optimized CUDA packing kernels. Every
//! face send is tagged with its axis ([`MsgTag::Halo`]) and counted in the
//! world's per-axis halo byte counters, so both the engine report and the
//! traced backend can audit the §III-A halo volume per dimension.

use super::{Communicator, MsgTag};
use crate::partition::GridNeighbors;
use crate::tensor::pool::BufferPool;
use crate::tensor::Tensor;
use anyhow::Result;

fn take_buf(pool: Option<&BufferPool>, len: usize) -> Vec<f32> {
    match pool {
        Some(p) => p.take(len),
        None => vec![0.0; len],
    }
}

fn put_buf(pool: Option<&BufferPool>, buf: Vec<f32>) {
    if let Some(p) = pool {
        p.put(buf);
    }
}

/// Forward face exchange along one spatial `axis` (2=D, 3=H, 4=W): returns
/// the shard padded with `halo` faces on each side of that axis (neighbour
/// data or zeros at the global boundary).
///
/// `lo` is the rank holding the previous shard along the axis, `hi` the
/// next. All ranks of a sample group must call this collectively, in the
/// same per-axis order. Works with any [`Communicator`] backend (the
/// send-then-receive protocol only requires non-blocking sends).
pub fn exchange_forward_axis(
    ep: &dyn Communicator,
    shard: &Tensor,
    axis: usize,
    halo: usize,
    lo: Option<usize>,
    hi: Option<usize>,
) -> Result<Tensor> {
    if halo == 0 || (lo.is_none() && hi.is_none()) {
        return Ok(shard.pad_ax(axis, halo, halo));
    }
    let len = shard.shape()[axis];
    assert!(len >= halo,
            "shard axis {axis} extent {len} < halo {halo} (over-decomposed)");
    let ax = (axis - 2) as u8;
    let felems = shard.numel() / len * halo;
    // post sends first (non-blocking), then receive — no deadlock with
    // buffered channels.
    if let Some(u) = lo {
        let mut face = vec![0.0f32; felems];
        shard.slice_ax_into(axis, 0, halo, &mut face);
        ep.counters().add_halo_bytes(ax as usize, (felems * 4) as u64);
        ep.send_tagged(u, face, MsgTag::Halo(ax));
    }
    if let Some(d) = hi {
        let mut face = vec![0.0f32; felems];
        shard.slice_ax_into(axis, len - halo, halo, &mut face);
        ep.counters().add_halo_bytes(ax as usize, (felems * 4) as u64);
        ep.send_tagged(d, face, MsgTag::Halo(ax));
    }
    let mut padded = shard.pad_ax(axis, halo, halo);
    if let Some(u) = lo {
        let buf = ep.recv_tagged(u, MsgTag::Halo(ax))?;
        padded.set_slice_ax_from(axis, 0, halo, &buf);
    }
    if let Some(d) = hi {
        let buf = ep.recv_tagged(d, MsgTag::Halo(ax))?;
        padded.set_slice_ax_from(axis, halo + len, halo, &buf);
    }
    Ok(padded)
}

/// Backward (transpose) face exchange along one spatial `axis`: crop the
/// padded-input gradient to the shard and accumulate the halo-face
/// gradients received from the neighbours into the shard's boundary faces.
pub fn exchange_backward_axis(
    ep: &dyn Communicator,
    dx_padded: &Tensor,
    axis: usize,
    halo: usize,
    lo: Option<usize>,
    hi: Option<usize>,
) -> Result<Tensor> {
    if halo == 0 || (lo.is_none() && hi.is_none()) {
        return Ok(dx_padded.crop_ax(axis, halo, halo));
    }
    let lp = dx_padded.shape()[axis];
    let len = lp - 2 * halo;
    let ax = (axis - 2) as u8;
    let felems = dx_padded.numel() / lp * halo;
    // grads that live in my padding belong to the neighbours' interiors
    if let Some(u) = lo {
        let mut face = vec![0.0f32; felems];
        dx_padded.slice_ax_into(axis, 0, halo, &mut face);
        ep.counters().add_halo_bytes(ax as usize, (felems * 4) as u64);
        ep.send_tagged(u, face, MsgTag::Halo(ax));
    }
    if let Some(d) = hi {
        let mut face = vec![0.0f32; felems];
        dx_padded.slice_ax_into(axis, halo + len, halo, &mut face);
        ep.counters().add_halo_bytes(ax as usize, (felems * 4) as u64);
        ep.send_tagged(d, face, MsgTag::Halo(ax));
    }
    let mut dx = dx_padded.crop_ax(axis, halo, halo);
    // … and the neighbours' padding grads accumulate into my boundary.
    if let Some(u) = lo {
        // lo neighbour's *far* padding overlaps my first `halo` faces
        let buf = ep.recv_tagged(u, MsgTag::Halo(ax))?;
        dx.add_slice_ax_from(axis, 0, halo, &buf);
    }
    if let Some(d) = hi {
        let buf = ep.recv_tagged(d, MsgTag::Halo(ax))?;
        dx.add_slice_ax_from(axis, len - halo, halo, &buf);
    }
    Ok(dx)
}

/// Face-block geometry for the fused exchange of axis `a`: per-axis
/// `(off, len)` of the hyperslab orthogonal to `a` inside the fully
/// padded buffer, matching what the sequential per-axis composition would
/// send at that point — axes exchanged *before* `a` contribute their full
/// padded extent, axes exchanged *after* only their interior. Entries for
/// axis `a` itself are placeholders `(0, halo)`; callers set `off[a]`.
fn face_box(s: &[usize], halo: usize, pad_axes: [bool; 3], a: usize)
            -> ([usize; 3], [usize; 3]) {
    let mut off = [0usize; 3];
    let mut len = [0usize; 3];
    for j in 0..3 {
        (off[j], len[j]) = if j == a {
            (0, halo)
        } else if !pad_axes[j] {
            (0, s[2 + j])
        } else if j < a {
            (0, s[2 + j] + 2 * halo)
        } else {
            (halo, s[2 + j])
        };
    }
    (off, len)
}

/// Forward halo exchange over a 3D process grid: the sequential per-axis
/// exchange (D, then H, then W over the axes with `pad_axes[a]` set),
/// fused into one padded buffer. Axes the plan's executables pad
/// internally keep `pad_axes[a] = false`; the depth-only engine is
/// `[true, false, false]`, grid plans are all-true.
///
/// With `pool` set, the padded result and all transient send buffers come
/// from / return to the per-rank [`BufferPool`]; the caller owns the
/// returned tensor and should recycle it when done.
pub fn exchange_forward_grid(
    ep: &dyn Communicator,
    shard: &Tensor,
    halo: usize,
    nbrs: &GridNeighbors,
    pad_axes: [bool; 3],
    pool: Option<&BufferPool>,
) -> Result<Tensor> {
    let h = halo;
    let s = shard.shape().to_vec();
    if h == 0 || !pad_axes.iter().any(|&p| p) {
        return Ok(match pool {
            Some(p) => p.take_clone(shard),
            None => shard.clone(),
        });
    }
    let mut pshape = s.clone();
    for a in 0..3 {
        if pad_axes[a] {
            assert!(s[2 + a] >= h,
                    "shard axis {} extent {} < halo {h} (over-decomposed)",
                    2 + a, s[2 + a]);
            pshape[2 + a] += 2 * h;
        }
    }
    // One zero-filled buffer of the final shape; boundary faces that no
    // exchange below writes stay zero — the global "same" padding.
    let mut padded = match pool {
        Some(p) => p.take_tensor_zeroed(&pshape),
        None => Tensor::zeros(&pshape),
    };
    let int_off = [0, 1, 2].map(|a| if pad_axes[a] { h } else { 0 });
    padded.set_block3_from(int_off, [s[2], s[3], s[4]], shard.data());

    for a in 0..3 {
        if !pad_axes[a] || (nbrs.lo[a].is_none() && nbrs.hi[a].is_none()) {
            continue;
        }
        let (base, len) = face_box(&s, h, pad_axes, a);
        let elems = s[0] * s[1] * len[0] * len[1] * len[2];
        let sa = s[2 + a];
        // pack + send my boundary interior faces (non-blocking) …
        if let Some(u) = nbrs.lo[a] {
            let mut off = base;
            off[a] = h;
            let mut buf = take_buf(pool, elems);
            padded.block3_into(off, len, &mut buf);
            ep.counters().add_halo_bytes(a, (elems * 4) as u64);
            ep.send_tagged(u, buf, MsgTag::Halo(a as u8));
        }
        if let Some(d) = nbrs.hi[a] {
            let mut off = base;
            off[a] = sa;
            let mut buf = take_buf(pool, elems);
            padded.block3_into(off, len, &mut buf);
            ep.counters().add_halo_bytes(a, (elems * 4) as u64);
            ep.send_tagged(d, buf, MsgTag::Halo(a as u8));
        }
        // … then unpack the neighbours' faces straight into my halo slots.
        if let Some(u) = nbrs.lo[a] {
            let buf = ep.recv_tagged(u, MsgTag::Halo(a as u8))?;
            let mut off = base;
            off[a] = 0;
            padded.set_block3_from(off, len, &buf);
            put_buf(pool, buf);
        }
        if let Some(d) = nbrs.hi[a] {
            let buf = ep.recv_tagged(d, MsgTag::Halo(a as u8))?;
            let mut off = base;
            off[a] = h + sa;
            padded.set_block3_from(off, len, &buf);
            put_buf(pool, buf);
        }
    }
    Ok(padded)
}

/// Backward (transpose) halo exchange over a 3D process grid: the exact
/// adjoint of [`exchange_forward_grid`], so the axes run in reverse order
/// (W, then H, then D). Takes the padded gradient *by value* — faces are
/// packed from and accumulated into it in place, and the interior is
/// extracted once at the end (its storage is recycled into `pool` when
/// one is provided).
pub fn exchange_backward_grid(
    ep: &dyn Communicator,
    dx_padded: Tensor,
    halo: usize,
    nbrs: &GridNeighbors,
    pad_axes: [bool; 3],
    pool: Option<&BufferPool>,
) -> Result<Tensor> {
    let h = halo;
    if h == 0 || !pad_axes.iter().any(|&p| p) {
        return Ok(dx_padded);
    }
    let mut s = dx_padded.shape().to_vec();
    for a in 0..3 {
        if pad_axes[a] {
            s[2 + a] -= 2 * h;
        }
    }
    let mut g = dx_padded;
    for a in (0..3).rev() {
        if !pad_axes[a] || (nbrs.lo[a].is_none() && nbrs.hi[a].is_none()) {
            continue;
        }
        let (base, len) = face_box(&s, h, pad_axes, a);
        let elems = s[0] * s[1] * len[0] * len[1] * len[2];
        let sa = s[2 + a];
        // grads in my padding belong to the neighbours' interiors …
        if let Some(u) = nbrs.lo[a] {
            let mut off = base;
            off[a] = 0;
            let mut buf = take_buf(pool, elems);
            g.block3_into(off, len, &mut buf);
            ep.counters().add_halo_bytes(a, (elems * 4) as u64);
            ep.send_tagged(u, buf, MsgTag::Halo(a as u8));
        }
        if let Some(d) = nbrs.hi[a] {
            let mut off = base;
            off[a] = h + sa;
            let mut buf = take_buf(pool, elems);
            g.block3_into(off, len, &mut buf);
            ep.counters().add_halo_bytes(a, (elems * 4) as u64);
            ep.send_tagged(d, buf, MsgTag::Halo(a as u8));
        }
        // … and the neighbours' padding grads accumulate into my boundary.
        if let Some(u) = nbrs.lo[a] {
            let buf = ep.recv_tagged(u, MsgTag::Halo(a as u8))?;
            let mut off = base;
            off[a] = h;
            g.add_block3_from(off, len, &buf);
            put_buf(pool, buf);
        }
        if let Some(d) = nbrs.hi[a] {
            let buf = ep.recv_tagged(d, MsgTag::Halo(a as u8))?;
            let mut off = base;
            off[a] = sa;
            g.add_block3_from(off, len, &buf);
            put_buf(pool, buf);
        }
    }
    let int_off = [0, 1, 2].map(|a| if pad_axes[a] { h } else { 0 });
    let mut dx = match pool {
        Some(p) => p.take_tensor(&s),
        None => Tensor::zeros(&s),
    };
    g.block3_into(int_off, [s[2], s[3], s[4]], dx.data_mut());
    if let Some(p) = pool {
        p.recycle(g);
    }
    Ok(dx)
}

/// Depth-only forward exchange (axis 2) — the 1D special case.
pub fn exchange_forward(
    ep: &dyn Communicator,
    shard: &Tensor,
    halo: usize,
    up: Option<usize>,
    down: Option<usize>,
) -> Result<Tensor> {
    exchange_forward_axis(ep, shard, 2, halo, up, down)
}

/// Depth-only backward (transpose) exchange (axis 2).
pub fn exchange_backward(
    ep: &dyn Communicator,
    dx_padded: &Tensor,
    halo: usize,
    up: Option<usize>,
    down: Option<usize>,
) -> Result<Tensor> {
    exchange_backward_axis(ep, dx_padded, 2, halo, up, down)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{world, Loopback};
    use crate::partition::{GridTopology, SpatialGrid, Topology};
    use crate::util::prop;
    use crate::util::rng::Pcg;
    use std::thread;

    /// Distributed forward exchange over W ranks == local padding of the
    /// gathered tensor.
    #[test]
    fn forward_reassembles_global_padding() {
        for ways in [2usize, 4] {
            let d = 8;
            let sh = d / ways; // even depth split, as the engine requires
            let topo = Topology::new(1, ways);
            let mut rng = Pcg::new(1, 0);
            let mut data = vec![0.0f32; 2 * 3 * d * 2 * 2];
            rng.fill_normal(&mut data, 1.0);
            let global = Tensor::from_vec(&[2, 3, d, 2, 2], data);
            let global_padded = global.pad_ax(2, 1, 1);

            let eps = world(ways);
            let padded: Vec<Tensor> = thread::scope(|s| {
                let hs: Vec<_> = eps
                    .into_iter()
                    .enumerate()
                    .map(|(r, ep)| {
                        let shard = global.slice_ax(2, r * sh, sh);
                        let (up, down) = (topo.up(r), topo.down(r));
                        s.spawn(move || {
                            exchange_forward(&ep, &shard, 1, up, down).unwrap()
                        })
                    })
                    .collect();
                hs.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (r, p) in padded.iter().enumerate() {
                let want = global_padded.slice_ax(2, r * sh, sh + 2);
                assert_eq!(p, &want, "ways={ways} rank={r}");
            }
        }
    }

    /// Run the 3D grid exchange over a thread world and return each rank's
    /// padded shard (grid given as its SpatialGrid + global shard extents).
    fn run_grid_forward(global: &Tensor, grid: SpatialGrid, halo: usize)
                        -> Vec<Tensor> {
        let topo = GridTopology::new(1, grid);
        let (d, h, w) = (global.shape()[2], global.shape()[3], global.shape()[4]);
        let sh = [d / grid.d, h / grid.h, w / grid.w];
        let eps = world(grid.ways());
        thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(r, ep)| {
                    let c = grid.coords(r);
                    let shard = global.block3([c[0] * sh[0], c[1] * sh[1], c[2] * sh[2]], sh);
                    let nbrs = topo.neighbors(r);
                    s.spawn(move || {
                        exchange_forward_grid(&ep, &shard, halo, &nbrs,
                                              [true, true, true], None)
                        .unwrap()
                    })
                })
                .collect();
            hs.into_iter().map(|x| x.join().unwrap()).collect()
        })
    }

    /// The fused per-axis exchange reproduces the globally padded volume
    /// *exactly* — corners and edges included — on true 3D grids.
    #[test]
    fn grid_forward_reassembles_global_padding() {
        let mut rng = Pcg::new(3, 0);
        for (gd, gh, gw) in [(2usize, 2usize, 1usize), (2, 1, 2), (2, 2, 2), (1, 3, 2)] {
            let grid = SpatialGrid::new(gd, gh, gw);
            let (d, h, w) = (6usize, 6usize, 6usize); // divisible by 1, 2, 3
            let mut data = vec![0.0f32; 2 * d * h * w];
            rng.fill_normal(&mut data, 1.0);
            let global = Tensor::from_vec(&[1, 2, d, h, w], data);
            let gp = global.pad_ax(2, 1, 1).pad_ax(3, 1, 1).pad_ax(4, 1, 1);
            let sh = [d / gd, h / gh, w / gw];
            let padded = run_grid_forward(&global, grid, 1);
            for (r, p) in padded.iter().enumerate() {
                let c = grid.coords(r);
                let want = gp.block3([c[0] * sh[0], c[1] * sh[1], c[2] * sh[2]],
                                     [sh[0] + 2, sh[1] + 2, sh[2] + 2]);
                assert_eq!(p, &want, "grid {grid} rank {r}");
            }
        }
    }

    /// Backward exchange is the exact transpose of forward:
    /// <forward(x), y_padded> == <x, backward(y_padded)> for all x, y.
    #[test]
    fn backward_is_adjoint_of_forward() {
        let ways = 4;
        let d = 8;
        let sh = d / ways;
        let topo = Topology::new(1, ways);
        let mut rng = Pcg::new(2, 0);
        let shape = [1usize, 2, d, 2, 2];
        let n_elem: usize = shape.iter().product();
        let mut xv = vec![0.0f32; n_elem];
        rng.fill_normal(&mut xv, 1.0);
        let x = Tensor::from_vec(&shape, xv);
        // y lives in padded space per shard
        let mut ys: Vec<Tensor> = Vec::new();
        for _ in 0..ways {
            let mut yv = vec![0.0f32; 2 * (d / ways + 2) * 2 * 2];
            rng.fill_normal(&mut yv, 1.0);
            ys.push(Tensor::from_vec(&[1, 2, d / ways + 2, 2, 2], yv));
        }

        let eps = world(ways);
        let (fwd, bwd): (Vec<Tensor>, Vec<Tensor>) = thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(r, ep)| {
                    let shard = x.slice_ax(2, r * sh, sh);
                    let y = ys[r].clone();
                    let (up, down) = (topo.up(r), topo.down(r));
                    s.spawn(move || {
                        let f = exchange_forward(&ep, &shard, 1, up, down).unwrap();
                        let b = exchange_backward(&ep, &y, 1, up, down).unwrap();
                        (f, b)
                    })
                })
                .collect();
            let pairs: Vec<_> = hs.into_iter().map(|h| h.join().unwrap()).collect();
            pairs.into_iter().unzip()
        });

        let lhs: f64 = fwd
            .iter()
            .zip(&ys)
            .map(|(f, y)| {
                f.data().iter().zip(y.data()).map(|(a, b)| (a * b) as f64).sum::<f64>()
            })
            .sum();
        let rhs: f64 = bwd
            .iter()
            .enumerate()
            .map(|(r, b)| {
                let shard = x.slice_ax(2, r * sh, sh);
                b.data()
                    .iter()
                    .zip(shard.data())
                    .map(|(a, c)| (a * c) as f64)
                    .sum::<f64>()
            })
            .sum();
        assert!((lhs - rhs).abs() < 1e-3, "adjoint mismatch: {lhs} vs {rhs}");
    }

    /// The 3D grid forward/backward pair is an exact adjoint on random
    /// grids and shard extents — the algebraic identity that makes grid-
    /// partitioned backprop compute the same gradients as a single rank.
    #[test]
    fn prop_grid_halo_adjoint() {
        prop::check("grid-halo-adjoint", 12, |g| {
            let grid = SpatialGrid::new(g.usize_in(1, 2), g.usize_in(1, 2),
                                        g.usize_in(1, 2));
            let sh = [g.usize_in(1, 3), g.usize_in(1, 3), g.usize_in(1, 3)];
            let dims = [grid.d * sh[0], grid.h * sh[1], grid.w * sh[2]];
            let c = g.usize_in(1, 2);
            let global = Tensor::from_vec(
                &[1, c, dims[0], dims[1], dims[2]],
                g.vec_f32(c * dims[0] * dims[1] * dims[2], 1.0),
            );
            let topo = GridTopology::new(1, grid);
            let ys: Vec<Tensor> = (0..grid.ways())
                .map(|_| {
                    let ps = [sh[0] + 2, sh[1] + 2, sh[2] + 2];
                    Tensor::from_vec(&[1, c, ps[0], ps[1], ps[2]],
                                     g.vec_f32(c * ps[0] * ps[1] * ps[2], 1.0))
                })
                .collect();
            let eps = world(grid.ways());
            let (fwd, bwd): (Vec<Tensor>, Vec<Tensor>) = thread::scope(|s| {
                let hs: Vec<_> = eps
                    .into_iter()
                    .enumerate()
                    .map(|(r, ep)| {
                        let cc = grid.coords(r);
                        let shard = global.block3(
                            [cc[0] * sh[0], cc[1] * sh[1], cc[2] * sh[2]], sh);
                        let y = ys[r].clone();
                        let nbrs = topo.neighbors(r);
                        s.spawn(move || {
                            let f = exchange_forward_grid(&ep, &shard, 1, &nbrs,
                                                          [true, true, true], None)
                                .unwrap();
                            let b = exchange_backward_grid(&ep, y, 1, &nbrs,
                                                           [true, true, true], None)
                                .unwrap();
                            (f, b)
                        })
                    })
                    .collect();
                let pairs: Vec<_> = hs.into_iter().map(|h| h.join().unwrap()).collect();
                pairs.into_iter().unzip()
            });
            let lhs: f64 = fwd
                .iter()
                .zip(&ys)
                .map(|(f, y)| {
                    f.data().iter().zip(y.data())
                        .map(|(a, b)| (a * b) as f64).sum::<f64>()
                })
                .sum();
            let rhs: f64 = bwd
                .iter()
                .enumerate()
                .map(|(r, b)| {
                    let cc = grid.coords(r);
                    let shard = global.block3(
                        [cc[0] * sh[0], cc[1] * sh[1], cc[2] * sh[2]], sh);
                    b.data().iter().zip(shard.data())
                        .map(|(a, x)| (a * x) as f64).sum::<f64>()
                })
                .sum();
            if (lhs - rhs).abs() > 1e-3 * lhs.abs().max(1.0) {
                return Err(format!("grid {grid}: <Fx,y>={lhs} vs <x,F'y>={rhs}"));
            }
            Ok(())
        });
    }

    /// The fused grid exchange is bit-identical to composing the per-axis
    /// functions sequentially (D,H,W forward; W,H,D backward), pooled or
    /// not — the invariant that keeps every `*_bytes` counter and every
    /// training trajectory unchanged by the fused rewrite.
    #[test]
    fn prop_fused_matches_sequential_composition() {
        prop::check("fused-vs-sequential", 10, |g| {
            let grid = SpatialGrid::new(g.usize_in(1, 2), g.usize_in(1, 2),
                                        g.usize_in(1, 2));
            let sh = [g.usize_in(1, 3), g.usize_in(1, 3), g.usize_in(2, 3)];
            let c = g.usize_in(1, 2);
            let topo = GridTopology::new(1, grid);
            let shards: Vec<Tensor> = (0..grid.ways())
                .map(|_| {
                    Tensor::from_vec(&[1, c, sh[0], sh[1], sh[2]],
                                     g.vec_f32(c * sh[0] * sh[1] * sh[2], 1.0))
                })
                .collect();
            let ys: Vec<Tensor> = (0..grid.ways())
                .map(|_| {
                    let ps = [sh[0] + 2, sh[1] + 2, sh[2] + 2];
                    Tensor::from_vec(&[1, c, ps[0], ps[1], ps[2]],
                                     g.vec_f32(c * ps[0] * ps[1] * ps[2], 1.0))
                })
                .collect();
            let run = |fused: bool, pooled: bool| -> (Vec<Tensor>, Vec<Tensor>) {
                let eps = world(grid.ways());
                thread::scope(|s| {
                    let hs: Vec<_> = eps
                        .into_iter()
                        .enumerate()
                        .map(|(r, ep)| {
                            let shard = shards[r].clone();
                            let y = ys[r].clone();
                            let nbrs = topo.neighbors(r);
                            s.spawn(move || {
                                if fused {
                                    let pool = BufferPool::new();
                                    let pl = pooled.then_some(&pool);
                                    let f = exchange_forward_grid(
                                        &ep, &shard, 1, &nbrs, [true, true, true],
                                        pl).unwrap();
                                    let b = exchange_backward_grid(
                                        &ep, y, 1, &nbrs, [true, true, true],
                                        pl).unwrap();
                                    (f, b)
                                } else {
                                    let mut f = shard;
                                    for a in 0..3 {
                                        f = exchange_forward_axis(
                                            &ep, &f, 2 + a, 1,
                                            nbrs.lo[a], nbrs.hi[a]).unwrap();
                                    }
                                    let mut b = y;
                                    for a in (0..3).rev() {
                                        b = exchange_backward_axis(
                                            &ep, &b, 2 + a, 1,
                                            nbrs.lo[a], nbrs.hi[a]).unwrap();
                                    }
                                    (f, b)
                                }
                            })
                        })
                        .collect();
                    let pairs: Vec<_> =
                        hs.into_iter().map(|h| h.join().unwrap()).collect();
                    pairs.into_iter().unzip()
                })
            };
            let (f_seq, b_seq) = run(false, false);
            for pooled in [false, true] {
                let (f_fused, b_fused) = run(true, pooled);
                for r in 0..grid.ways() {
                    if f_fused[r] != f_seq[r] {
                        return Err(format!("fwd mismatch rank {r} (pooled={pooled})"));
                    }
                    if b_fused[r] != b_seq[r] {
                        return Err(format!("bwd mismatch rank {r} (pooled={pooled})"));
                    }
                }
            }
            Ok(())
        });
    }

    /// After one warm-up round-trip, pooled exchanges run entirely off the
    /// free lists: zero pool misses — the zero-alloc steady-state claim.
    #[test]
    fn pooled_exchange_zero_misses_after_warmup() {
        let grid = SpatialGrid::new(2, 2, 2);
        let topo = GridTopology::new(1, grid);
        let mut rng = Pcg::new(11, 0);
        let shards: Vec<Tensor> = (0..8)
            .map(|_| {
                let mut v = vec![0.0f32; 2 * 3 * 3 * 3];
                rng.fill_normal(&mut v, 1.0);
                Tensor::from_vec(&[1, 2, 3, 3, 3], v)
            })
            .collect();
        let eps = world(8);
        let misses: Vec<u64> = thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(r, ep)| {
                    let shard = shards[r].clone();
                    let nbrs = topo.neighbors(r);
                    s.spawn(move || {
                        let pool = BufferPool::new();
                        for round in 0..3 {
                            if round == 1 {
                                pool.reset_counters();
                            }
                            let f = exchange_forward_grid(
                                &ep, &shard, 1, &nbrs, [true, true, true],
                                Some(&pool)).unwrap();
                            let dx = exchange_backward_grid(
                                &ep, f, 1, &nbrs, [true, true, true],
                                Some(&pool)).unwrap();
                            pool.recycle(dx);
                        }
                        pool.misses()
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(misses, vec![0; 8], "steady-state pool misses");
    }

    /// Per-axis halo byte counters see exactly the face volume sent.
    #[test]
    fn halo_byte_counters_per_axis() {
        let grid = SpatialGrid::new(2, 2, 1);
        let mut rng = Pcg::new(8, 0);
        let mut data = vec![0.0f32; 2 * 4 * 4 * 4];
        rng.fill_normal(&mut data, 1.0);
        let global = Tensor::from_vec(&[1, 2, 4, 4, 4], data);
        // counters are shared by all endpoints of one world
        let eps = world(grid.ways());
        let counters = eps[0].counters().clone();
        let topo = GridTopology::new(1, grid);
        thread::scope(|s| {
            for (r, ep) in eps.into_iter().enumerate() {
                let c = grid.coords(r);
                let shard = global.block3([c[0] * 2, c[1] * 2, 0], [2, 2, 4]);
                let nbrs = topo.neighbors(r);
                s.spawn(move || {
                    exchange_forward_grid(&ep, &shard, 1, &nbrs,
                                          [true, true, true], None)
                        .unwrap();
                });
            }
        });
        let bytes = counters.halo_bytes_axes();
        // D faces: 4 sends of a (1,2,1,2,4) face = 16 f32 = 64 B each;
        // H faces go out after the D pad: 4 sends of (1,2,4,1,4) = 32 f32
        // = 128 B each; W is unsplit.
        assert_eq!(bytes[0], 4 * 16 * 4);
        assert_eq!(bytes[1], 4 * 32 * 4);
        assert_eq!(bytes[2], 0);
    }

    #[test]
    fn single_rank_is_zero_padding() {
        let x = Tensor::from_vec(&[1, 1, 2, 1, 1], vec![1.0, 2.0]);
        let eps = world(1);
        let p = exchange_forward(&eps[0], &x, 1, None, None).unwrap();
        assert_eq!(p.data(), &[0.0, 1.0, 2.0, 0.0]);
        let dx = exchange_backward(&eps[0], &p, 1, None, None).unwrap();
        assert_eq!(dx.data(), &[1.0, 2.0]);
    }

    /// The loopback backend behaves identically for boundary-only ranks.
    #[test]
    fn loopback_backend_single_rank() {
        let lb = Loopback::new();
        let x = Tensor::from_vec(&[1, 1, 2, 1, 1], vec![3.0, 4.0]);
        let p = exchange_forward(&lb, &x, 1, None, None).unwrap();
        assert_eq!(p.data(), &[0.0, 3.0, 4.0, 0.0]);
        let dx = exchange_backward(&lb, &p, 1, None, None).unwrap();
        assert_eq!(dx.data(), &[3.0, 4.0]);
    }
}
