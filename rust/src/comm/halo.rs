//! Halo exchange for spatially partitioned activations (§III-A of the
//! paper), generalized from depth slabs to face exchanges along any subset
//! of the (D, H, W) axes.
//!
//! Forward: each rank contributes its boundary faces to its neighbours and
//! receives theirs, building a halo-padded shard the conv executable can
//! consume with a convolution that is `valid` along every padded axis.
//! Boundary ranks get zero faces on the outer side (the global "same"
//! padding).
//!
//! A 3D grid runs one face exchange **per partitioned axis, sequentially**
//! (D, then H, then W). Because each axis exchange sends the full,
//! already-padded boundary face, corner and edge regions propagate through
//! the neighbours' previous exchanges — after the last axis the shard is
//! *exactly* the halo-padded hyperslab of the globally padded volume (the
//! reassembly test below asserts bitwise equality), which is the paper's
//! per-dimension halo-region scheme and is exact for separable "same"
//! padding.
//!
//! Backward: `conv_bwd_data` produces gradients for the *padded* input; the
//! halo-face gradients belong to the neighbours' interiors, so they are
//! sent back and **accumulated**. The 3D backward walks the axes in
//! reverse (W, then H, then D) — the exact adjoint of the forward
//! composition, verified by the adjoint property test.
//!
//! Pack/unpack are contiguous-slab copies (see [`crate::tensor`]); the
//! paper's equivalent is its suite of optimized CUDA packing kernels. Every
//! face send is tagged with its axis ([`MsgTag::Halo`]) and counted in the
//! world's per-axis halo byte counters, so both the engine report and the
//! traced backend can audit the §III-A halo volume per dimension.

use super::{Communicator, MsgTag};
use crate::partition::GridNeighbors;
use crate::tensor::Tensor;
use anyhow::Result;

/// Forward face exchange along one spatial `axis` (2=D, 3=H, 4=W): returns
/// the shard padded with `halo` faces on each side of that axis (neighbour
/// data or zeros at the global boundary).
///
/// `lo` is the rank holding the previous shard along the axis, `hi` the
/// next. All ranks of a sample group must call this collectively, in the
/// same per-axis order. Works with any [`Communicator`] backend (the
/// send-then-receive protocol only requires non-blocking sends).
pub fn exchange_forward_axis(
    ep: &dyn Communicator,
    shard: &Tensor,
    axis: usize,
    halo: usize,
    lo: Option<usize>,
    hi: Option<usize>,
) -> Result<Tensor> {
    if halo == 0 || (lo.is_none() && hi.is_none()) {
        return Ok(shard.pad_ax(axis, halo, halo));
    }
    let len = shard.shape()[axis];
    assert!(len >= halo,
            "shard axis {axis} extent {len} < halo {halo} (over-decomposed)");
    let ax = (axis - 2) as u8;
    // post sends first (non-blocking), then receive — no deadlock with
    // buffered channels.
    if let Some(u) = lo {
        let face = shard.slice_ax(axis, 0, halo);
        ep.counters().add_halo_bytes(ax as usize, (face.numel() * 4) as u64);
        ep.send_tagged(u, face.into_vec(), MsgTag::Halo(ax));
    }
    if let Some(d) = hi {
        let face = shard.slice_ax(axis, len - halo, halo);
        ep.counters().add_halo_bytes(ax as usize, (face.numel() * 4) as u64);
        ep.send_tagged(d, face.into_vec(), MsgTag::Halo(ax));
    }
    let mut padded = shard.pad_ax(axis, halo, halo);
    let mut fshape = shard.shape().to_vec();
    fshape[axis] = halo;
    if let Some(u) = lo {
        let buf = ep.recv(u)?;
        padded.set_slice_ax(axis, 0, &Tensor::from_vec(&fshape, buf));
    }
    if let Some(d) = hi {
        let buf = ep.recv(d)?;
        padded.set_slice_ax(axis, halo + len, &Tensor::from_vec(&fshape, buf));
    }
    Ok(padded)
}

/// Backward (transpose) face exchange along one spatial `axis`: crop the
/// padded-input gradient to the shard and accumulate the halo-face
/// gradients received from the neighbours into the shard's boundary faces.
pub fn exchange_backward_axis(
    ep: &dyn Communicator,
    dx_padded: &Tensor,
    axis: usize,
    halo: usize,
    lo: Option<usize>,
    hi: Option<usize>,
) -> Result<Tensor> {
    if halo == 0 || (lo.is_none() && hi.is_none()) {
        return Ok(dx_padded.crop_ax(axis, halo, halo));
    }
    let lp = dx_padded.shape()[axis];
    let len = lp - 2 * halo;
    let ax = (axis - 2) as u8;
    // grads that live in my padding belong to the neighbours' interiors
    if let Some(u) = lo {
        let face = dx_padded.slice_ax(axis, 0, halo);
        ep.counters().add_halo_bytes(ax as usize, (face.numel() * 4) as u64);
        ep.send_tagged(u, face.into_vec(), MsgTag::Halo(ax));
    }
    if let Some(d) = hi {
        let face = dx_padded.slice_ax(axis, halo + len, halo);
        ep.counters().add_halo_bytes(ax as usize, (face.numel() * 4) as u64);
        ep.send_tagged(d, face.into_vec(), MsgTag::Halo(ax));
    }
    let mut dx = dx_padded.crop_ax(axis, halo, halo);
    let mut fshape = dx.shape().to_vec();
    fshape[axis] = halo;
    // … and the neighbours' padding grads accumulate into my boundary.
    if let Some(u) = lo {
        // lo neighbour's *far* padding overlaps my first `halo` faces
        let buf = ep.recv(u)?;
        dx.add_slice_ax(axis, 0, &Tensor::from_vec(&fshape, buf));
    }
    if let Some(d) = hi {
        let buf = ep.recv(d)?;
        dx.add_slice_ax(axis, len - halo, &Tensor::from_vec(&fshape, buf));
    }
    Ok(dx)
}

/// Forward halo exchange over a 3D process grid: one sequential face
/// exchange per axis with `pad_axes[a]` set (D, then H, then W). Axes the
/// plan's executables pad internally keep `pad_axes[a] = false`; the
/// depth-only engine is `[true, false, false]`, grid plans are all-true.
pub fn exchange_forward_grid(
    ep: &dyn Communicator,
    shard: &Tensor,
    halo: usize,
    nbrs: &GridNeighbors,
    pad_axes: [bool; 3],
) -> Result<Tensor> {
    let mut out: Option<Tensor> = None;
    for a in 0..3 {
        if pad_axes[a] {
            let src = out.as_ref().unwrap_or(shard);
            out = Some(exchange_forward_axis(ep, src, 2 + a, halo,
                                             nbrs.lo[a], nbrs.hi[a])?);
        }
    }
    Ok(out.unwrap_or_else(|| shard.clone()))
}

/// Backward (transpose) halo exchange over a 3D process grid: the exact
/// adjoint of [`exchange_forward_grid`], so the axes run in reverse order
/// (W, then H, then D).
pub fn exchange_backward_grid(
    ep: &dyn Communicator,
    dx_padded: &Tensor,
    halo: usize,
    nbrs: &GridNeighbors,
    pad_axes: [bool; 3],
) -> Result<Tensor> {
    let mut out: Option<Tensor> = None;
    for a in (0..3).rev() {
        if pad_axes[a] {
            let src = out.as_ref().unwrap_or(dx_padded);
            out = Some(exchange_backward_axis(ep, src, 2 + a, halo,
                                              nbrs.lo[a], nbrs.hi[a])?);
        }
    }
    Ok(out.unwrap_or_else(|| dx_padded.clone()))
}

/// Depth-only forward exchange (axis 2) — the 1D special case.
pub fn exchange_forward(
    ep: &dyn Communicator,
    shard: &Tensor,
    halo: usize,
    up: Option<usize>,
    down: Option<usize>,
) -> Result<Tensor> {
    exchange_forward_axis(ep, shard, 2, halo, up, down)
}

/// Depth-only backward (transpose) exchange (axis 2).
pub fn exchange_backward(
    ep: &dyn Communicator,
    dx_padded: &Tensor,
    halo: usize,
    up: Option<usize>,
    down: Option<usize>,
) -> Result<Tensor> {
    exchange_backward_axis(ep, dx_padded, 2, halo, up, down)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{world, Loopback};
    use crate::partition::{GridTopology, SpatialGrid, Topology};
    use crate::util::prop;
    use crate::util::rng::Pcg;
    use std::thread;

    /// Distributed forward exchange over W ranks == local padding of the
    /// gathered tensor.
    #[test]
    fn forward_reassembles_global_padding() {
        for ways in [2usize, 4] {
            let d = 8;
            let sh = d / ways; // even depth split, as the engine requires
            let topo = Topology::new(1, ways);
            let mut rng = Pcg::new(1, 0);
            let mut data = vec![0.0f32; 2 * 3 * d * 2 * 2];
            rng.fill_normal(&mut data, 1.0);
            let global = Tensor::from_vec(&[2, 3, d, 2, 2], data);
            let global_padded = global.pad_d(1, 1);

            let eps = world(ways);
            let padded: Vec<Tensor> = thread::scope(|s| {
                let hs: Vec<_> = eps
                    .into_iter()
                    .enumerate()
                    .map(|(r, ep)| {
                        let shard = global.slice_d(r * sh, sh);
                        let (up, down) = (topo.up(r), topo.down(r));
                        s.spawn(move || {
                            exchange_forward(&ep, &shard, 1, up, down).unwrap()
                        })
                    })
                    .collect();
                hs.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (r, p) in padded.iter().enumerate() {
                let want = global_padded.slice_d(r * sh, sh + 2);
                assert_eq!(p, &want, "ways={ways} rank={r}");
            }
        }
    }

    /// Run the 3D grid exchange over a thread world and return each rank's
    /// padded shard (grid given as its SpatialGrid + global shard extents).
    fn run_grid_forward(global: &Tensor, grid: SpatialGrid, halo: usize)
                        -> Vec<Tensor> {
        let topo = GridTopology::new(1, grid);
        let (d, h, w) = (global.shape()[2], global.shape()[3], global.shape()[4]);
        let sh = [d / grid.d, h / grid.h, w / grid.w];
        let eps = world(grid.ways());
        thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(r, ep)| {
                    let c = grid.coords(r);
                    let shard = global.block3([c[0] * sh[0], c[1] * sh[1], c[2] * sh[2]], sh);
                    let nbrs = topo.neighbors(r);
                    s.spawn(move || {
                        exchange_forward_grid(&ep, &shard, halo, &nbrs,
                                              [true, true, true])
                        .unwrap()
                    })
                })
                .collect();
            hs.into_iter().map(|x| x.join().unwrap()).collect()
        })
    }

    /// The sequential per-axis exchange reproduces the globally padded
    /// volume *exactly* — corners and edges included — on true 3D grids.
    #[test]
    fn grid_forward_reassembles_global_padding() {
        let mut rng = Pcg::new(3, 0);
        for (gd, gh, gw) in [(2usize, 2usize, 1usize), (2, 1, 2), (2, 2, 2), (1, 3, 2)] {
            let grid = SpatialGrid::new(gd, gh, gw);
            let (d, h, w) = (6usize, 6usize, 6usize); // divisible by 1, 2, 3
            let mut data = vec![0.0f32; 2 * d * h * w];
            rng.fill_normal(&mut data, 1.0);
            let global = Tensor::from_vec(&[1, 2, d, h, w], data);
            let gp = global.pad_ax(2, 1, 1).pad_ax(3, 1, 1).pad_ax(4, 1, 1);
            let sh = [d / gd, h / gh, w / gw];
            let padded = run_grid_forward(&global, grid, 1);
            for (r, p) in padded.iter().enumerate() {
                let c = grid.coords(r);
                let want = gp.block3([c[0] * sh[0], c[1] * sh[1], c[2] * sh[2]],
                                     [sh[0] + 2, sh[1] + 2, sh[2] + 2]);
                assert_eq!(p, &want, "grid {grid} rank {r}");
            }
        }
    }

    /// Backward exchange is the exact transpose of forward:
    /// <forward(x), y_padded> == <x, backward(y_padded)> for all x, y.
    #[test]
    fn backward_is_adjoint_of_forward() {
        let ways = 4;
        let d = 8;
        let sh = d / ways;
        let topo = Topology::new(1, ways);
        let mut rng = Pcg::new(2, 0);
        let shape = [1usize, 2, d, 2, 2];
        let n_elem: usize = shape.iter().product();
        let mut xv = vec![0.0f32; n_elem];
        rng.fill_normal(&mut xv, 1.0);
        let x = Tensor::from_vec(&shape, xv);
        // y lives in padded space per shard
        let mut ys: Vec<Tensor> = Vec::new();
        for _ in 0..ways {
            let mut yv = vec![0.0f32; 2 * (d / ways + 2) * 2 * 2];
            rng.fill_normal(&mut yv, 1.0);
            ys.push(Tensor::from_vec(&[1, 2, d / ways + 2, 2, 2], yv));
        }

        let eps = world(ways);
        let (fwd, bwd): (Vec<Tensor>, Vec<Tensor>) = thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(r, ep)| {
                    let shard = x.slice_d(r * sh, sh);
                    let y = ys[r].clone();
                    let (up, down) = (topo.up(r), topo.down(r));
                    s.spawn(move || {
                        let f = exchange_forward(&ep, &shard, 1, up, down).unwrap();
                        let b = exchange_backward(&ep, &y, 1, up, down).unwrap();
                        (f, b)
                    })
                })
                .collect();
            let pairs: Vec<_> = hs.into_iter().map(|h| h.join().unwrap()).collect();
            pairs.into_iter().unzip()
        });

        let lhs: f64 = fwd
            .iter()
            .zip(&ys)
            .map(|(f, y)| {
                f.data().iter().zip(y.data()).map(|(a, b)| (a * b) as f64).sum::<f64>()
            })
            .sum();
        let rhs: f64 = bwd
            .iter()
            .enumerate()
            .map(|(r, b)| {
                let shard = x.slice_d(r * sh, sh);
                b.data()
                    .iter()
                    .zip(shard.data())
                    .map(|(a, c)| (a * c) as f64)
                    .sum::<f64>()
            })
            .sum();
        assert!((lhs - rhs).abs() < 1e-3, "adjoint mismatch: {lhs} vs {rhs}");
    }

    /// The 3D grid forward/backward pair is an exact adjoint on random
    /// grids and shard extents — the algebraic identity that makes grid-
    /// partitioned backprop compute the same gradients as a single rank.
    #[test]
    fn prop_grid_halo_adjoint() {
        prop::check("grid-halo-adjoint", 12, |g| {
            let grid = SpatialGrid::new(g.usize_in(1, 2), g.usize_in(1, 2),
                                        g.usize_in(1, 2));
            let sh = [g.usize_in(1, 3), g.usize_in(1, 3), g.usize_in(1, 3)];
            let dims = [grid.d * sh[0], grid.h * sh[1], grid.w * sh[2]];
            let c = g.usize_in(1, 2);
            let global = Tensor::from_vec(
                &[1, c, dims[0], dims[1], dims[2]],
                g.vec_f32(c * dims[0] * dims[1] * dims[2], 1.0),
            );
            let topo = GridTopology::new(1, grid);
            let ys: Vec<Tensor> = (0..grid.ways())
                .map(|_| {
                    let ps = [sh[0] + 2, sh[1] + 2, sh[2] + 2];
                    Tensor::from_vec(&[1, c, ps[0], ps[1], ps[2]],
                                     g.vec_f32(c * ps[0] * ps[1] * ps[2], 1.0))
                })
                .collect();
            let eps = world(grid.ways());
            let (fwd, bwd): (Vec<Tensor>, Vec<Tensor>) = thread::scope(|s| {
                let hs: Vec<_> = eps
                    .into_iter()
                    .enumerate()
                    .map(|(r, ep)| {
                        let cc = grid.coords(r);
                        let shard = global.block3(
                            [cc[0] * sh[0], cc[1] * sh[1], cc[2] * sh[2]], sh);
                        let y = ys[r].clone();
                        let nbrs = topo.neighbors(r);
                        s.spawn(move || {
                            let f = exchange_forward_grid(&ep, &shard, 1, &nbrs,
                                                          [true, true, true])
                                .unwrap();
                            let b = exchange_backward_grid(&ep, &y, 1, &nbrs,
                                                           [true, true, true])
                                .unwrap();
                            (f, b)
                        })
                    })
                    .collect();
                let pairs: Vec<_> = hs.into_iter().map(|h| h.join().unwrap()).collect();
                pairs.into_iter().unzip()
            });
            let lhs: f64 = fwd
                .iter()
                .zip(&ys)
                .map(|(f, y)| {
                    f.data().iter().zip(y.data())
                        .map(|(a, b)| (a * b) as f64).sum::<f64>()
                })
                .sum();
            let rhs: f64 = bwd
                .iter()
                .enumerate()
                .map(|(r, b)| {
                    let cc = grid.coords(r);
                    let shard = global.block3(
                        [cc[0] * sh[0], cc[1] * sh[1], cc[2] * sh[2]], sh);
                    b.data().iter().zip(shard.data())
                        .map(|(a, x)| (a * x) as f64).sum::<f64>()
                })
                .sum();
            if (lhs - rhs).abs() > 1e-3 * lhs.abs().max(1.0) {
                return Err(format!("grid {grid}: <Fx,y>={lhs} vs <x,F'y>={rhs}"));
            }
            Ok(())
        });
    }

    /// Per-axis halo byte counters see exactly the face volume sent.
    #[test]
    fn halo_byte_counters_per_axis() {
        let grid = SpatialGrid::new(2, 2, 1);
        let mut rng = Pcg::new(8, 0);
        let mut data = vec![0.0f32; 2 * 4 * 4 * 4];
        rng.fill_normal(&mut data, 1.0);
        let global = Tensor::from_vec(&[1, 2, 4, 4, 4], data);
        // counters are shared by all endpoints of one world
        let eps = world(grid.ways());
        let counters = eps[0].counters().clone();
        let topo = GridTopology::new(1, grid);
        thread::scope(|s| {
            for (r, ep) in eps.into_iter().enumerate() {
                let c = grid.coords(r);
                let shard = global.block3([c[0] * 2, c[1] * 2, 0], [2, 2, 4]);
                let nbrs = topo.neighbors(r);
                s.spawn(move || {
                    exchange_forward_grid(&ep, &shard, 1, &nbrs, [true, true, true])
                        .unwrap();
                });
            }
        });
        let bytes = counters.halo_bytes_axes();
        // D faces: 4 sends of a (1,2,1,2,4) face = 16 f32 = 64 B each;
        // H faces go out after the D pad: 4 sends of (1,2,4,1,4) = 32 f32
        // = 128 B each; W is unsplit.
        assert_eq!(bytes[0], 4 * 16 * 4);
        assert_eq!(bytes[1], 4 * 32 * 4);
        assert_eq!(bytes[2], 0);
    }

    #[test]
    fn single_rank_is_zero_padding() {
        let x = Tensor::from_vec(&[1, 1, 2, 1, 1], vec![1.0, 2.0]);
        let eps = world(1);
        let p = exchange_forward(&eps[0], &x, 1, None, None).unwrap();
        assert_eq!(p.data(), &[0.0, 1.0, 2.0, 0.0]);
        let dx = exchange_backward(&eps[0], &p, 1, None, None).unwrap();
        assert_eq!(dx.data(), &[1.0, 2.0]);
    }

    /// The loopback backend behaves identically for boundary-only ranks.
    #[test]
    fn loopback_backend_single_rank() {
        let lb = Loopback::new();
        let x = Tensor::from_vec(&[1, 1, 2, 1, 1], vec![3.0, 4.0]);
        let p = exchange_forward(&lb, &x, 1, None, None).unwrap();
        assert_eq!(p.data(), &[0.0, 3.0, 4.0, 0.0]);
        let dx = exchange_backward(&lb, &p, 1, None, None).unwrap();
        assert_eq!(dx.data(), &[3.0, 4.0]);
    }
}
