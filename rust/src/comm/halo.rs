//! Halo exchange for depth-partitioned activations (§III-A of the paper).
//!
//! Forward: each rank contributes its boundary planes to its neighbours and
//! receives theirs, building a halo-padded shard the conv executable can
//! consume with a depth-`valid` convolution. Boundary ranks get zero planes
//! on the outer side (the global "same" padding).
//!
//! Backward: `conv_bwd_data` produces gradients for the *padded* input; the
//! halo-plane gradients belong to the neighbours' interiors, so they are
//! sent back and **accumulated** (transpose of the forward exchange).
//!
//! Pack/unpack are contiguous-slab copies (see [`crate::tensor`]); the
//! paper's equivalent is its suite of optimized CUDA packing kernels.

use super::Communicator;
use crate::tensor::Tensor;
use anyhow::Result;

/// Forward halo exchange: returns the shard padded with `halo` planes on
/// each depth side (neighbour data or zeros at the global boundary).
///
/// `up` is the rank holding the previous depth shard, `down` the next.
/// All ranks of a sample group must call this collectively. Works with
/// any [`Communicator`] backend (the send-then-receive protocol only
/// requires non-blocking sends).
pub fn exchange_forward(
    ep: &dyn Communicator,
    shard: &Tensor,
    halo: usize,
    up: Option<usize>,
    down: Option<usize>,
) -> Result<Tensor> {
    if halo == 0 || (up.is_none() && down.is_none()) {
        return Ok(shard.pad_d(halo, halo));
    }
    let d = shard.shape()[2];
    assert!(d >= halo, "shard depth {d} < halo {halo} (over-decomposed)");
    // post sends first (non-blocking), then receive — no deadlock with
    // buffered channels.
    if let Some(u) = up {
        ep.send(u, shard.slice_d(0, halo).into_vec());
    }
    if let Some(dn) = down {
        ep.send(dn, shard.slice_d(d - halo, halo).into_vec());
    }
    let mut padded = shard.pad_d(halo, halo);
    let (n, c, _, h, w) = dims5(shard);
    if let Some(u) = up {
        let buf = ep.recv(u)?;
        padded.set_slice_d(0, &Tensor::from_vec(&[n, c, halo, h, w], buf));
    }
    if let Some(dn) = down {
        let buf = ep.recv(dn)?;
        padded.set_slice_d(halo + d, &Tensor::from_vec(&[n, c, halo, h, w], buf));
    }
    Ok(padded)
}

/// Backward (transpose) halo exchange: crop the padded-input gradient to
/// the shard and accumulate the halo-plane gradients received from the
/// neighbours into the shard's boundary planes.
pub fn exchange_backward(
    ep: &dyn Communicator,
    dx_padded: &Tensor,
    halo: usize,
    up: Option<usize>,
    down: Option<usize>,
) -> Result<Tensor> {
    if halo == 0 || (up.is_none() && down.is_none()) {
        return Ok(dx_padded.crop_d(halo, halo));
    }
    let dp = dx_padded.shape()[2];
    let d = dp - 2 * halo;
    // grads that live in my padding belong to the neighbours' interiors
    if let Some(u) = up {
        ep.send(u, dx_padded.slice_d(0, halo).into_vec());
    }
    if let Some(dn) = down {
        ep.send(dn, dx_padded.slice_d(halo + d, halo).into_vec());
    }
    let mut dx = dx_padded.crop_d(halo, halo);
    let (n, c, _, h, w) = dims5(&dx);
    // … and the neighbours' padding grads accumulate into my boundary.
    if let Some(u) = up {
        // up neighbour's *bottom* padding overlaps my first `halo` planes
        let buf = ep.recv(u)?;
        dx.add_slice_d(0, &Tensor::from_vec(&[n, c, halo, h, w], buf));
    }
    if let Some(dn) = down {
        let buf = ep.recv(dn)?;
        dx.add_slice_d(d - halo, &Tensor::from_vec(&[n, c, halo, h, w], buf));
    }
    Ok(dx)
}

fn dims5(t: &Tensor) -> (usize, usize, usize, usize, usize) {
    let s = t.shape();
    (s[0], s[1], s[2], s[3], s[4])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{world, Loopback};
    use crate::partition::{DepthPartition, Topology};
    use crate::util::rng::Pcg;
    use std::thread;

    /// Distributed forward exchange over W ranks == local padding of the
    /// gathered tensor.
    #[test]
    fn forward_reassembles_global_padding() {
        for ways in [2usize, 4] {
            let d = 8;
            let part = DepthPartition::new_even(d, ways).unwrap();
            let topo = Topology::new(1, ways);
            let mut rng = Pcg::new(1, 0);
            let mut data = vec![0.0f32; 2 * 3 * d * 2 * 2];
            rng.fill_normal(&mut data, 1.0);
            let global = Tensor::from_vec(&[2, 3, d, 2, 2], data);
            let global_padded = global.pad_d(1, 1);

            let eps = world(ways);
            let padded: Vec<Tensor> = thread::scope(|s| {
                let hs: Vec<_> = eps
                    .into_iter()
                    .enumerate()
                    .map(|(r, ep)| {
                        let shard = global.slice_d(part.shard_start(r), part.shard_len());
                        let (up, down) = (topo.up(r), topo.down(r));
                        s.spawn(move || {
                            exchange_forward(&ep, &shard, 1, up, down).unwrap()
                        })
                    })
                    .collect();
                hs.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (r, p) in padded.iter().enumerate() {
                let want = global_padded.slice_d(part.shard_start(r), part.shard_len() + 2);
                assert_eq!(p, &want, "ways={ways} rank={r}");
            }
        }
    }

    /// Backward exchange is the exact transpose of forward:
    /// <forward(x), y_padded> == <x, backward(y_padded)> for all x, y.
    #[test]
    fn backward_is_adjoint_of_forward() {
        let ways = 4;
        let d = 8;
        let part = DepthPartition::new_even(d, ways).unwrap();
        let topo = Topology::new(1, ways);
        let mut rng = Pcg::new(2, 0);
        let shape = [1usize, 2, d, 2, 2];
        let n_elem: usize = shape.iter().product();
        let mut xv = vec![0.0f32; n_elem];
        rng.fill_normal(&mut xv, 1.0);
        let x = Tensor::from_vec(&shape, xv);
        // y lives in padded space per shard
        let mut ys: Vec<Tensor> = Vec::new();
        for _ in 0..ways {
            let mut yv = vec![0.0f32; 1 * 2 * (d / ways + 2) * 2 * 2];
            rng.fill_normal(&mut yv, 1.0);
            ys.push(Tensor::from_vec(&[1, 2, d / ways + 2, 2, 2], yv));
        }

        let eps = world(ways);
        let (fwd, bwd): (Vec<Tensor>, Vec<Tensor>) = thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(r, ep)| {
                    let shard = x.slice_d(part.shard_start(r), part.shard_len());
                    let y = ys[r].clone();
                    let (up, down) = (topo.up(r), topo.down(r));
                    s.spawn(move || {
                        let f = exchange_forward(&ep, &shard, 1, up, down).unwrap();
                        let b = exchange_backward(&ep, &y, 1, up, down).unwrap();
                        (f, b)
                    })
                })
                .collect();
            let pairs: Vec<_> = hs.into_iter().map(|h| h.join().unwrap()).collect();
            pairs.into_iter().unzip()
        });

        let lhs: f64 = fwd
            .iter()
            .zip(&ys)
            .map(|(f, y)| {
                f.data().iter().zip(y.data()).map(|(a, b)| (a * b) as f64).sum::<f64>()
            })
            .sum();
        let rhs: f64 = bwd
            .iter()
            .enumerate()
            .map(|(r, b)| {
                let shard = x.slice_d(part.shard_start(r), part.shard_len());
                b.data()
                    .iter()
                    .zip(shard.data())
                    .map(|(a, c)| (a * c) as f64)
                    .sum::<f64>()
            })
            .sum();
        assert!((lhs - rhs).abs() < 1e-3, "adjoint mismatch: {lhs} vs {rhs}");
    }

    #[test]
    fn single_rank_is_zero_padding() {
        let x = Tensor::from_vec(&[1, 1, 2, 1, 1], vec![1.0, 2.0]);
        let eps = world(1);
        let p = exchange_forward(&eps[0], &x, 1, None, None).unwrap();
        assert_eq!(p.data(), &[0.0, 1.0, 2.0, 0.0]);
        let dx = exchange_backward(&eps[0], &p, 1, None, None).unwrap();
        assert_eq!(dx.data(), &[1.0, 2.0]);
    }

    /// The loopback backend behaves identically for boundary-only ranks.
    #[test]
    fn loopback_backend_single_rank() {
        let lb = Loopback::new();
        let x = Tensor::from_vec(&[1, 1, 2, 1, 1], vec![3.0, 4.0]);
        let p = exchange_forward(&lb, &x, 1, None, None).unwrap();
        assert_eq!(p.data(), &[0.0, 3.0, 4.0, 0.0]);
        let dx = exchange_backward(&lb, &p, 1, None, None).unwrap();
        assert_eq!(dx.data(), &[3.0, 4.0]);
    }
}
