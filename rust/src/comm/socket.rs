//! The multi-process socket backend.
//!
//! Ranks are grouped onto **nodes** of `ranks_per_node` consecutive ranks
//! (`node_of(r) = r / ranks_per_node`, the launcher's packing order).
//! Intra-node links are the same unbounded `std::sync::mpsc` channels the
//! channel backend uses; inter-node links are stream sockets carrying
//! length-prefixed frames. Per inter-node link each process runs one
//! **writer pump** (drains an unbounded frame queue into the socket — so
//! [`Communicator::send`] never blocks, preserving the engine's
//! send-then-receive halo protocol) and one **reader pump** (demultiplexes
//! incoming frames to the destination rank's delivery channel).
//!
//! # Wire format
//!
//! One frame per message: a 12-byte little-endian header
//! `[src: u32][dst: u32][elems: u32]` followed by `elems` f32 payload
//! words, also little-endian. f32 payloads cross the wire bit-exactly
//! (`to_le_bytes`/`from_le_bytes` round-trip every bit pattern), and the
//! collectives are the shared trait defaults, so a socket world computes
//! **bit-identical** results to a channel world of the same size — the
//! property `tests/socket_backend.rs` gates. Frame wire volume
//! (header + payload) is counted into [`Counters::socket_frame_bytes`] at
//! enqueue time on the sending side, which makes it deterministic for a
//! fixed configuration and exactly gateable in CI.
//!
//! # Entry points
//!
//! * [`socket_world`] — the whole world in one process, nodes simulated by
//!   `UnixStream::pair` socketpairs. Every inter-node byte crosses a real
//!   kernel socket; used by `CommBackend::Socket`, the equivalence tests
//!   and the bench `socket_smoke` lane.
//! * [`connect_node`] — one process per node: binds this node's listener,
//!   dials every lower node (with retry until `HYDRA3D_CONNECT_TIMEOUT_MS`,
//!   default 30000), accepts every higher node, then runs a
//!   barrier-on-connect handshake through node 0 before any engine traffic
//!   starts. Rendezvous is Unix-domain sockets under
//!   [`Rendezvous::sock_dir`] or, when [`Rendezvous::hosts`] is set, TCP —
//!   the multi-host path. `comm::launch` forks the node processes and
//!   writes the manifest this consumes.
//!
//! # Teardown
//!
//! Dropping a node's endpoints disconnects its frame queues; each writer
//! pump drains, shuts down its write half and exits; the peer's reader
//! pump sees EOF and drops its delivery senders; pending receives fail
//! with the same "peer disconnected" error the channel backend produces.
//! No thread joins anything — teardown is a pure EOF cascade.

use super::{Collective, Communicator, Counters};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

type Msg = Vec<f32>;
/// (src, dst, payload) — one queued inter-node message.
type Frame = (u32, u32, Vec<f32>);

/// Frame header wire size: `[src: u32][dst: u32][elems: u32]`, LE.
pub const FRAME_HEADER_BYTES: u64 = 12;

/// Wire bytes of one inter-node frame carrying `elems` f32s.
pub fn frame_wire_bytes(elems: usize) -> u64 {
    FRAME_HEADER_BYTES + 4 * elems as u64
}

/// Node hosting rank `rank` under the launcher's consecutive packing.
pub fn node_of(rank: usize, ranks_per_node: usize) -> usize {
    rank / ranks_per_node.max(1)
}

/// Number of nodes hosting a world of `world` ranks.
pub fn node_count(world: usize, ranks_per_node: usize) -> usize {
    world.div_ceil(ranks_per_node.max(1))
}

/// The global rank range hosted by `node` (the last node takes the
/// remainder when `world` is not divisible).
pub fn node_ranks(node: usize, world: usize, ranks_per_node: usize) -> std::ops::Range<usize> {
    let rpn = ranks_per_node.max(1);
    (node * rpn).min(world)..((node + 1) * rpn).min(world)
}

/// An established inter-node stream: Unix-domain locally, TCP multi-host.
enum NodeStream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl NodeStream {
    fn try_clone(&self) -> std::io::Result<NodeStream> {
        match self {
            NodeStream::Unix(s) => s.try_clone().map(NodeStream::Unix),
            NodeStream::Tcp(s) => s.try_clone().map(NodeStream::Tcp),
        }
    }

    /// Close the write half only: the peer's reader sees EOF while our own
    /// reader keeps draining whatever the peer still sends.
    fn shutdown_write(&self) {
        let _ = match self {
            NodeStream::Unix(s) => s.shutdown(Shutdown::Write),
            NodeStream::Tcp(s) => s.shutdown(Shutdown::Write),
        };
    }
}

impl Read for NodeStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            NodeStream::Unix(s) => s.read(buf),
            NodeStream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for NodeStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            NodeStream::Unix(s) => s.write(buf),
            NodeStream::Tcp(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            NodeStream::Unix(s) => s.flush(),
            NodeStream::Tcp(s) => s.flush(),
        }
    }
}

/// Serialize one frame into `scratch` and write it out in a single call.
fn write_frame<W: Write>(
    w: &mut W,
    src: u32,
    dst: u32,
    data: &[f32],
    scratch: &mut Vec<u8>,
) -> std::io::Result<()> {
    scratch.clear();
    scratch.reserve(FRAME_HEADER_BYTES as usize + 4 * data.len());
    scratch.extend_from_slice(&src.to_le_bytes());
    scratch.extend_from_slice(&dst.to_le_bytes());
    scratch.extend_from_slice(&(data.len() as u32).to_le_bytes());
    for v in data {
        scratch.extend_from_slice(&v.to_le_bytes());
    }
    w.write_all(scratch)
}

/// Read one frame; `Ok(None)` on clean EOF at a frame boundary.
fn read_frame<R: Read>(r: &mut R) -> std::io::Result<Option<(usize, usize, Vec<f32>)>> {
    let mut hdr = [0u8; FRAME_HEADER_BYTES as usize];
    match r.read_exact(&mut hdr) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let src = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
    let dst = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
    let elems = u32::from_le_bytes(hdr[8..12].try_into().unwrap()) as usize;
    let mut raw = vec![0u8; 4 * elems];
    r.read_exact(&mut raw)?;
    let data = raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Some((src, dst, data)))
}

/// Writer pump for one inter-node link: drain the frame queue into the
/// stream, then shut the write half so the peer's reader sees EOF. A write
/// error (peer died) exits the pump; the sender-side error then surfaces
/// as "peer node link closed" on the next enqueue.
fn spawn_writer(stream: NodeStream, rx: Receiver<Frame>) {
    std::thread::Builder::new()
        .name("socket-writer".into())
        .spawn(move || {
            let Ok(inner) = stream.try_clone() else { return };
            let mut w = BufWriter::new(inner);
            let mut scratch = Vec::new();
            while let Ok((src, dst, data)) = rx.recv() {
                if write_frame(&mut w, src, dst, &data, &mut scratch).is_err()
                    || w.flush().is_err()
                {
                    break;
                }
            }
            drop(w);
            stream.shutdown_write();
        })
        .expect("spawn socket writer");
}

/// Reader pump for one inter-node link: demultiplex incoming frames into
/// the destination ranks' delivery channels until EOF. A frame for an
/// already-dropped endpoint is discarded (teardown is EOF-driven).
fn spawn_reader(stream: NodeStream, deliver: HashMap<(usize, usize), Sender<Msg>>) {
    std::thread::Builder::new()
        .name("socket-reader".into())
        .spawn(move || {
            let mut r = BufReader::new(stream);
            while let Ok(Some((src, dst, data))) = read_frame(&mut r) {
                if let Some(tx) = deliver.get(&(dst, src)) {
                    let _ = tx.send(data);
                }
            }
        })
        .expect("spawn socket reader");
}

/// A rank's link to one destination rank.
enum Link {
    /// Same node: straight into the destination's delivery channel.
    Local(Sender<Msg>),
    /// Other node: enqueue a frame on the writer pump of that node's link.
    Remote(Sender<Frame>),
}

/// One rank's endpoint into a socket world — same ordering and collective
/// semantics as the channel backend's `Endpoint`.
pub struct SocketEndpoint {
    rank: usize,
    world: usize,
    node: usize,
    /// Indexed by destination rank.
    links: Vec<Link>,
    /// Indexed by source rank (FIFO per sender, like the channel world).
    rxs: Vec<Receiver<Msg>>,
    counters: Arc<Counters>,
}

impl SocketEndpoint {
    /// The node hosting this rank.
    pub fn node(&self) -> usize {
        self.node
    }
}

impl Communicator for SocketEndpoint {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world_size(&self) -> usize {
        self.world
    }

    /// Asynchronous send (never blocks): local messages go straight into
    /// the peer's delivery channel, remote ones onto the unbounded frame
    /// queue of the inter-node writer pump.
    fn send(&self, to: usize, data: Vec<f32>) {
        self.counters
            .bytes
            .fetch_add((data.len() * 4) as u64, Ordering::Relaxed);
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        match &self.links[to] {
            Link::Local(tx) => tx.send(data).expect("peer endpoint dropped"),
            Link::Remote(tx) => {
                self.counters.add_socket_frame_bytes(frame_wire_bytes(data.len()));
                tx.send((self.rank as u32, to as u32, data))
                    .expect("peer node link closed");
            }
        }
    }

    fn recv(&self, from: usize) -> Result<Vec<f32>> {
        self.rxs[from]
            .recv()
            .map_err(|_| anyhow!("rank {}: peer {from} disconnected", self.rank))
    }

    fn counters(&self) -> &Arc<Counters> {
        &self.counters
    }

    fn on_collective(&self, op: Collective, _elems: usize, _group: &[usize]) {
        if matches!(
            op,
            Collective::AllreduceRing | Collective::AllreduceRd | Collective::AllreduceHier
        ) {
            self.counters.allreduces.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Build an `n`-rank socket world **in one process**, packing ranks onto
/// simulated nodes of `ranks_per_node` connected by `UnixStream::pair`
/// socketpairs. All endpoints share one [`Counters`], so world-total
/// counters aggregate exactly as in a channel world (plus
/// [`Counters::socket_frame_bytes`] for the inter-node wire volume).
pub fn socket_world(n: usize, ranks_per_node: usize) -> Result<Vec<SocketEndpoint>> {
    if n == 0 {
        bail!("socket world needs at least one rank");
    }
    if ranks_per_node == 0 {
        bail!("ranks-per-node must be >= 1");
    }
    let rpn = ranks_per_node;
    let nodes = node_count(n, rpn);
    let counters = Arc::new(Counters::default());

    // delivery channels: deliver_tx[dst][src] feeds rxs[dst][src]
    let mut deliver_tx: Vec<Vec<Sender<Msg>>> = Vec::with_capacity(n);
    let mut deliver_rx: Vec<Vec<Receiver<Msg>>> = Vec::with_capacity(n);
    for _dst in 0..n {
        let (mut txs, mut rxs) = (Vec::with_capacity(n), Vec::with_capacity(n));
        for _src in 0..n {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(rx);
        }
        deliver_tx.push(txs);
        deliver_rx.push(rxs);
    }

    // one socketpair per unordered node pair; one frame queue + writer +
    // reader pump per direction
    let mut queue_tx: HashMap<(usize, usize), Sender<Frame>> = HashMap::new();
    for a in 0..nodes {
        for b in a + 1..nodes {
            let (sa, sb) = UnixStream::pair().context("node socketpair")?;
            for (local, peer, s) in [(a, b, sa), (b, a, sb)] {
                let stream = NodeStream::Unix(s);
                let (tx, rx) = channel::<Frame>();
                queue_tx.insert((local, peer), tx);
                spawn_writer(stream.try_clone().context("clone node stream")?, rx);
                let mut deliver = HashMap::new();
                for dst in node_ranks(local, n, rpn) {
                    for src in node_ranks(peer, n, rpn) {
                        deliver.insert((dst, src), deliver_tx[dst][src].clone());
                    }
                }
                spawn_reader(stream, deliver);
            }
        }
    }

    let mut eps = Vec::with_capacity(n);
    for (rank, rx_row) in deliver_rx.into_iter().enumerate() {
        let node = node_of(rank, rpn);
        let links = (0..n)
            .map(|dst| {
                let dnode = node_of(dst, rpn);
                if dnode == node {
                    Link::Local(deliver_tx[dst][rank].clone())
                } else {
                    Link::Remote(queue_tx[&(node, dnode)].clone())
                }
            })
            .collect();
        eps.push(SocketEndpoint {
            rank,
            world: n,
            node,
            links,
            rxs: rx_row,
            counters: counters.clone(),
        });
    }
    Ok(eps)
}

/// Rendezvous description for a multi-process world. `comm::launch` writes
/// it as the manifest file the `hydra3d worker` subcommand reads.
#[derive(Clone, Debug)]
pub struct Rendezvous {
    pub world: usize,
    pub ranks_per_node: usize,
    /// Directory of the per-node Unix-domain listener sockets
    /// (`<sock_dir>/<label>-<node>.sock`); used when `hosts` is empty.
    pub sock_dir: PathBuf,
    /// Label distinguishing concurrent worlds in one `sock_dir`.
    pub label: String,
    /// `host:port` per node — when non-empty, rendezvous is TCP (the
    /// multi-host path) and `sock_dir` is ignored.
    pub hosts: Vec<String>,
}

impl Rendezvous {
    pub fn nodes(&self) -> usize {
        node_count(self.world, self.ranks_per_node)
    }

    fn sock_path(&self, node: usize) -> PathBuf {
        self.sock_dir.join(format!("{}-{node}.sock", self.label))
    }
}

/// Connect-phase timeout: `HYDRA3D_CONNECT_TIMEOUT_MS`, default 30000.
fn connect_timeout() -> Duration {
    let ms = std::env::var("HYDRA3D_CONNECT_TIMEOUT_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(30_000);
    Duration::from_millis(ms)
}

/// The listener half of the rendezvous (higher nodes dial into it).
enum NodeListener {
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl NodeListener {
    fn set_nonblocking(&self) -> std::io::Result<()> {
        match self {
            NodeListener::Unix(l) => l.set_nonblocking(true),
            NodeListener::Tcp(l) => l.set_nonblocking(true),
        }
    }

    fn accept(&self) -> std::io::Result<NodeStream> {
        match self {
            NodeListener::Unix(l) => l.accept().map(|(s, _)| NodeStream::Unix(s)),
            NodeListener::Tcp(l) => l.accept().map(|(s, _)| {
                let _ = s.set_nodelay(true);
                NodeStream::Tcp(s)
            }),
        }
    }
}

/// Dial node `peer`'s listener, retrying until the deadline (its process
/// may not have bound yet).
fn dial(rv: &Rendezvous, peer: usize, deadline: Instant) -> Result<NodeStream> {
    loop {
        let attempt = if rv.hosts.is_empty() {
            UnixStream::connect(rv.sock_path(peer)).map(NodeStream::Unix)
        } else {
            TcpStream::connect(&rv.hosts[peer]).map(|s| {
                let _ = s.set_nodelay(true);
                NodeStream::Tcp(s)
            })
        };
        match attempt {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    bail!(
                        "rendezvous timeout dialing node {peer} \
                         (HYDRA3D_CONNECT_TIMEOUT_MS): {e}"
                    );
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Establish this node's links to every peer node and run the
/// barrier-on-connect handshake; returns the endpoints of the ranks this
/// node hosts ([`node_ranks`]). Connection topology: every node *dials*
/// all lower-numbered nodes and *accepts* all higher-numbered ones, each
/// dialer identifying itself with a 4-byte hello. After all links stand,
/// every node reports readiness to node 0 and blocks until node 0 releases
/// the world, so no engine traffic races the rendezvous.
pub fn connect_node(rv: &Rendezvous, node: usize) -> Result<Vec<SocketEndpoint>> {
    if rv.world == 0 {
        bail!("socket world needs at least one rank");
    }
    if rv.ranks_per_node == 0 {
        bail!("ranks-per-node must be >= 1");
    }
    let nodes = rv.nodes();
    if node >= nodes {
        bail!("node {node} out of range ({nodes} node(s) for world {})", rv.world);
    }
    if !rv.hosts.is_empty() && rv.hosts.len() != nodes {
        bail!("rendezvous lists {} host(s) for {nodes} node(s)", rv.hosts.len());
    }
    let deadline = Instant::now() + connect_timeout();

    // bind our listener first so lower-numbered dialers can retry into it
    let listener = if nodes > 1 && node < nodes - 1 {
        let l = if rv.hosts.is_empty() {
            let path = rv.sock_path(node);
            let _ = std::fs::remove_file(&path);
            NodeListener::Unix(
                UnixListener::bind(&path)
                    .with_context(|| format!("bind {}", path.display()))?,
            )
        } else {
            NodeListener::Tcp(
                TcpListener::bind(&rv.hosts[node])
                    .with_context(|| format!("bind {}", rv.hosts[node]))?,
            )
        };
        l.set_nonblocking().context("listener nonblocking")?;
        Some(l)
    } else {
        None
    };

    // dial every lower node, identifying ourselves with a hello frame
    let mut streams: HashMap<usize, NodeStream> = HashMap::new();
    for peer in 0..node {
        let mut s = dial(rv, peer, deadline)?;
        s.write_all(&(node as u32).to_le_bytes()).context("send hello")?;
        s.flush().context("flush hello")?;
        streams.insert(peer, s);
    }

    // accept every higher node (they may dial in any order)
    if let Some(l) = &listener {
        while streams.len() < nodes - 1 {
            match l.accept() {
                Ok(mut s) => {
                    let peer = read_u32(&mut s).context("read hello")? as usize;
                    if peer <= node || peer >= nodes {
                        bail!("unexpected hello from node {peer}");
                    }
                    streams.insert(peer, s);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        let missing: Vec<usize> = (node + 1..nodes)
                            .filter(|p| !streams.contains_key(p))
                            .collect();
                        bail!(
                            "rendezvous timeout waiting for node(s) {missing:?} \
                             (HYDRA3D_CONNECT_TIMEOUT_MS)"
                        );
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e).context("accept"),
            }
        }
    }
    if rv.hosts.is_empty() {
        let _ = std::fs::remove_file(rv.sock_path(node));
    }

    // barrier-on-connect: everyone reports 'R'eady to node 0 and blocks on
    // its 'G'o, so no frame traffic races a still-connecting node
    if nodes > 1 {
        if node == 0 {
            for peer in 1..nodes {
                let s = streams.get_mut(&peer).expect("link");
                let mut b = [0u8; 1];
                s.read_exact(&mut b).context("barrier ready")?;
                if b != *b"R" {
                    bail!("bad barrier byte from node {peer}");
                }
            }
            for peer in 1..nodes {
                let s = streams.get_mut(&peer).expect("link");
                s.write_all(b"G").and_then(|_| s.flush()).context("barrier go")?;
            }
        } else {
            let s = streams.get_mut(&0).expect("link to node 0");
            s.write_all(b"R").and_then(|_| s.flush()).context("barrier ready")?;
            let mut b = [0u8; 1];
            s.read_exact(&mut b).context("barrier go")?;
            if b != *b"G" {
                bail!("bad barrier byte from node 0");
            }
        }
    }

    // local delivery channels: deliver[(dst, src)] for our hosted ranks
    let counters = Arc::new(Counters::default());
    let local = node_ranks(node, rv.world, rv.ranks_per_node);
    let mut deliver_tx: HashMap<(usize, usize), Sender<Msg>> = HashMap::new();
    let mut deliver_rx: HashMap<(usize, usize), Receiver<Msg>> = HashMap::new();
    for dst in local.clone() {
        for src in 0..rv.world {
            let (tx, rx) = channel();
            deliver_tx.insert((dst, src), tx);
            deliver_rx.insert((dst, src), rx);
        }
    }

    // frame queue + pumps per established link
    let mut queue_tx: HashMap<usize, Sender<Frame>> = HashMap::new();
    for (peer, stream) in streams {
        let (tx, rx) = channel::<Frame>();
        queue_tx.insert(peer, tx);
        spawn_writer(stream.try_clone().context("clone node stream")?, rx);
        let mut deliver = HashMap::new();
        for dst in local.clone() {
            for src in node_ranks(peer, rv.world, rv.ranks_per_node) {
                deliver.insert((dst, src), deliver_tx[&(dst, src)].clone());
            }
        }
        spawn_reader(stream, deliver);
    }

    let mut eps = Vec::with_capacity(local.len());
    for rank in local.clone() {
        let links = (0..rv.world)
            .map(|dst| {
                let dnode = node_of(dst, rv.ranks_per_node);
                if dnode == node {
                    Link::Local(deliver_tx[&(dst, rank)].clone())
                } else {
                    Link::Remote(queue_tx[&dnode].clone())
                }
            })
            .collect();
        let rxs = (0..rv.world)
            .map(|src| deliver_rx.remove(&(rank, src)).expect("delivery channel"))
            .collect();
        eps.push(SocketEndpoint {
            rank,
            world: rv.world,
            node,
            links,
            rxs,
            counters: counters.clone(),
        });
    }
    Ok(eps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run_world<F>(n: usize, rpn: usize, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(&SocketEndpoint) -> Vec<f32> + Send + Sync + Copy,
    {
        let eps = socket_world(n, rpn).unwrap();
        thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .map(|ep| s.spawn(move || f(&ep)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn p2p_ordering_across_nodes() {
        // ranks 0 and 1 live on different nodes: FIFO must hold over the wire
        let out = run_world(2, 1, |ep| {
            if ep.rank() == 0 {
                ep.send(1, vec![1.0]);
                ep.send(1, vec![2.0]);
                vec![]
            } else {
                let a = ep.recv(0).unwrap();
                let b = ep.recv(0).unwrap();
                vec![a[0], b[0]]
            }
        });
        assert_eq!(out[1], vec![1.0, 2.0]);
    }

    #[test]
    fn allreduce_matches_channel_bitwise() {
        // adversarial floats over a 2-node split: socket collectives must be
        // bit-identical to the channel world's (shared trait defaults +
        // bit-exact LE framing)
        let mk_buf = |rank: usize| -> Vec<f32> {
            (0..33)
                .map(|i| ((rank + 1) as f32 * 1e-3).powi((i % 7) as i32 + 1))
                .collect()
        };
        let sock = run_world(4, 2, move |ep| {
            let mut buf = mk_buf(ep.rank());
            ep.allreduce_sum(&mut buf, &[0, 1, 2, 3]).unwrap();
            buf
        });
        let eps = super::super::world(4);
        let chan: Vec<Vec<f32>> = thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .map(|ep| {
                    s.spawn(move || {
                        let mut buf = mk_buf(ep.rank());
                        ep.allreduce_sum(&mut buf, &[0, 1, 2, 3]).unwrap();
                        buf
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(sock, chan);
    }

    #[test]
    fn frame_bytes_count_inter_node_only() {
        let eps = socket_world(4, 2).unwrap();
        let counters = eps[0].counters().clone();
        thread::scope(|s| {
            for ep in eps {
                s.spawn(move || {
                    let r = ep.rank();
                    // intra-node pair exchange: 0<->1 and 2<->3
                    let buddy = r ^ 1;
                    ep.send(buddy, vec![0.0; 10]);
                    ep.recv(buddy).unwrap();
                    // inter-node pair exchange: 0<->2 and 1<->3
                    let far = (r + 2) % 4;
                    ep.send(far, vec![0.0; 10]);
                    ep.recv(far).unwrap();
                });
            }
        });
        // 4 inter-node messages of 10 f32 -> 4 * (12 + 40) frame bytes;
        // payload counters cover all 8 messages like the channel backend
        assert_eq!(counters.socket_frame_bytes(), 4 * frame_wire_bytes(10));
        assert_eq!(counters.bytes(), 8 * 40);
        assert_eq!(counters.messages(), 8);
    }

    #[test]
    fn node_math() {
        assert_eq!(node_count(4, 2), 2);
        assert_eq!(node_count(5, 2), 3);
        assert_eq!(node_ranks(2, 5, 2), 4..5);
        assert_eq!(node_of(3, 2), 1);
        assert!(socket_world(2, 0).is_err());
    }
}
