//! The traced backend: records message sizes/orders and logical
//! collectives for the §III-C performance model.
//!
//! [`Traced`] wraps any [`Communicator`] and appends one [`MessageEvent`]
//! per point-to-point send and one [`CollectiveEvent`] per *logical*
//! collective (recorded by the group root `group[0]`, so a g-rank
//! allreduce yields one event, not g) to a shared [`TraceCollector`].
//! Because the trait's collectives decompose into `send`/`recv`, the
//! message stream captures the actual wire structure of ring allreduce,
//! recursive doubling, halo exchange and the flatten gather — exactly what
//! `perfmodel::trace` replays against the fitted link model.
//!
//! The collector keeps every event in memory (~40 bytes per message), so
//! it is sized for diagnostic runs of bounded step count; for long traced
//! runs, drain with [`TraceCollector::clear`] between steps or phases.

use super::{Collective, Communicator, Counters, MsgTag};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One recorded point-to-point message.
#[derive(Clone, Copy, Debug)]
pub struct MessageEvent {
    /// Global submission order across all ranks.
    pub seq: u64,
    pub from: usize,
    pub to: usize,
    pub bytes: u64,
    /// Traffic class ([`MsgTag::Halo`] carries the spatial axis, so the
    /// per-dimension halo volume of §III-A can be audited from a trace).
    pub tag: MsgTag,
}

/// One recorded logical collective (one event per group-wide call).
#[derive(Clone, Copy, Debug)]
pub struct CollectiveEvent {
    pub seq: u64,
    /// Group root (`group[0]`, the recording rank).
    pub root: usize,
    pub op: Collective,
    /// Per-rank buffer length in f32 elements.
    pub elems: usize,
    pub group_len: usize,
}

/// Shared trace sink for a (pair of) traced world(s).
#[derive(Default)]
pub struct TraceCollector {
    seq: AtomicU64,
    messages: Mutex<Vec<MessageEvent>>,
    collectives: Mutex<Vec<CollectiveEvent>>,
}

impl TraceCollector {
    pub fn new() -> TraceCollector {
        TraceCollector::default()
    }

    fn next_seq(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed)
    }

    fn record_message(&self, from: usize, to: usize, bytes: u64, tag: MsgTag) {
        let ev = MessageEvent { seq: self.next_seq(), from, to, bytes, tag };
        self.messages.lock().expect("trace poisoned").push(ev);
    }

    fn record_collective(&self, root: usize, op: Collective, elems: usize, group_len: usize) {
        let ev = CollectiveEvent { seq: self.next_seq(), root, op, elems, group_len };
        self.collectives.lock().expect("trace poisoned").push(ev);
    }

    /// Snapshot of all recorded messages (submission order).
    pub fn messages(&self) -> Vec<MessageEvent> {
        let mut v = self.messages.lock().expect("trace poisoned").clone();
        v.sort_by_key(|e| e.seq);
        v
    }

    /// Snapshot of all recorded logical collectives (submission order).
    pub fn collectives(&self) -> Vec<CollectiveEvent> {
        let mut v = self.collectives.lock().expect("trace poisoned").clone();
        v.sort_by_key(|e| e.seq);
        v
    }

    pub fn message_count(&self) -> usize {
        self.messages.lock().expect("trace poisoned").len()
    }

    pub fn total_bytes(&self) -> u64 {
        self.messages.lock().expect("trace poisoned").iter().map(|e| e.bytes).sum()
    }

    /// Bytes sent per rank, for worlds of size `world`.
    pub fn per_rank_bytes(&self, world: usize) -> Vec<u64> {
        let mut out = vec![0u64; world];
        for e in self.messages.lock().expect("trace poisoned").iter() {
            if e.from < world {
                out[e.from] += e.bytes;
            }
        }
        out
    }

    /// Total halo-face payload bytes per spatial axis (D, H, W), from the
    /// axis tags of the recorded sends.
    pub fn halo_bytes_per_axis(&self) -> [u64; 3] {
        let mut out = [0u64; 3];
        for e in self.messages.lock().expect("trace poisoned").iter() {
            if let MsgTag::Halo(a) = e.tag {
                out[a as usize] += e.bytes;
            }
        }
        out
    }

    /// Total data-store redistribution payload bytes ([`MsgTag::Redist`])
    /// — the §III-B staging volume the calibrated I/O model prices.
    pub fn redist_bytes(&self) -> u64 {
        self.messages
            .lock()
            .expect("trace poisoned")
            .iter()
            .filter(|e| e.tag == MsgTag::Redist)
            .map(|e| e.bytes)
            .sum()
    }

    /// Forget everything recorded so far (between steps/phases).
    pub fn clear(&self) {
        self.messages.lock().expect("trace poisoned").clear();
        self.collectives.lock().expect("trace poisoned").clear();
    }
}

/// A [`Communicator`] wrapper that traces all traffic of `inner`.
pub struct Traced<C: Communicator> {
    inner: C,
    trace: Arc<TraceCollector>,
}

impl<C: Communicator> Traced<C> {
    pub fn new(inner: C, trace: Arc<TraceCollector>) -> Traced<C> {
        Traced { inner, trace }
    }

    pub fn trace(&self) -> &Arc<TraceCollector> {
        &self.trace
    }
}

impl<C: Communicator> Communicator for Traced<C> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn world_size(&self) -> usize {
        self.inner.world_size()
    }

    fn send(&self, to: usize, data: Vec<f32>) {
        self.trace
            .record_message(self.inner.rank(), to, (data.len() * 4) as u64,
                            MsgTag::Generic);
        self.inner.send(to, data);
    }

    fn send_tagged(&self, to: usize, data: Vec<f32>, tag: MsgTag) {
        self.trace
            .record_message(self.inner.rank(), to, (data.len() * 4) as u64, tag);
        self.inner.send(to, data);
    }

    fn recv(&self, from: usize) -> Result<Vec<f32>> {
        self.inner.recv(from)
    }

    fn counters(&self) -> &Arc<Counters> {
        self.inner.counters()
    }

    fn on_collective(&self, op: Collective, elems: usize, group: &[usize]) {
        // Record on the group root (`group[0]`): unique per call, and for
        // rooted collectives (gather/broadcast) the only rank whose buffer
        // length is meaningful. The minimum rank would record elems=0 for
        // a broadcast from a permuted group's root.
        if group.first() == Some(&self.inner.rank()) {
            self.trace
                .record_collective(self.inner.rank(), op, elems, group.len());
        }
        self.inner.on_collective(op, elems, group);
    }
}
