//! The traced backend: records message sizes/orders and logical
//! collectives for the §III-C performance model.
//!
//! [`Traced`] wraps any [`Communicator`] and appends one [`MessageEvent`]
//! per point-to-point send and one [`CollectiveEvent`] per *logical*
//! collective (recorded by the group root `group[0]`, so a g-rank
//! allreduce yields one event, not g) to a shared [`TraceCollector`].
//! Because the trait's collectives decompose into `send`/`recv`, the
//! message stream captures the actual wire structure of ring allreduce,
//! recursive doubling, halo exchange and the flatten gather — exactly what
//! `perfmodel::trace` replays against the fitted link model.
//!
//! The collector keeps every event in memory (~40 bytes per message), so
//! it is sized for diagnostic runs of bounded step count; for long traced
//! runs, drain with [`TraceCollector::clear`] between steps or phases.

use super::{Collective, Communicator, Counters, MsgTag, ScheduleOp};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One recorded point-to-point message.
#[derive(Clone, Copy, Debug)]
pub struct MessageEvent {
    /// Global submission order across all ranks.
    pub seq: u64,
    pub from: usize,
    pub to: usize,
    pub bytes: u64,
    /// Traffic class ([`MsgTag::Halo`] carries the spatial axis, so the
    /// per-dimension halo volume of §III-A can be audited from a trace).
    pub tag: MsgTag,
}

/// One recorded logical collective (one event per group-wide call).
#[derive(Clone, Copy, Debug)]
pub struct CollectiveEvent {
    pub seq: u64,
    /// Group root (`group[0]`, the recording rank).
    pub root: usize,
    pub op: Collective,
    /// Per-rank buffer length in f32 elements.
    pub elems: usize,
    pub group_len: usize,
}

/// Shared trace sink for a (pair of) traced world(s).
///
/// Besides the flat [`MessageEvent`]/[`CollectiveEvent`] records (the
/// §III-C replay input), the collector keeps one ordered [`ScheduleOp`]
/// stream *per wrapped endpoint* — the per-rank program-order schedule
/// that `analysis::checks` verifies. Endpoints register at construction
/// ([`Traced::new`]), so stream index = construction order; when one
/// collector traces several worlds (compute, then grad), each world's
/// ranks occupy a contiguous id range.
#[derive(Default)]
pub struct TraceCollector {
    seq: AtomicU64,
    messages: Mutex<Vec<MessageEvent>>,
    collectives: Mutex<Vec<CollectiveEvent>>,
    ops: Mutex<Vec<Vec<ScheduleOp>>>,
}

impl TraceCollector {
    pub fn new() -> TraceCollector {
        TraceCollector::default()
    }

    fn next_seq(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Allocate a per-endpoint [`ScheduleOp`] stream; returns its index.
    fn register_endpoint(&self) -> usize {
        let mut ops = self.ops.lock().expect("trace poisoned");
        ops.push(Vec::new());
        ops.len() - 1
    }

    fn record_op(&self, ep_id: usize, op: ScheduleOp) {
        self.ops.lock().expect("trace poisoned")[ep_id].push(op);
    }

    /// Per-endpoint schedules, indexed by endpoint construction order
    /// (rank order within each `build_world` call).
    pub fn op_streams(&self) -> Vec<Vec<ScheduleOp>> {
        self.ops.lock().expect("trace poisoned").clone()
    }

    fn record_message(&self, from: usize, to: usize, bytes: u64, tag: MsgTag) {
        let ev = MessageEvent { seq: self.next_seq(), from, to, bytes, tag };
        self.messages.lock().expect("trace poisoned").push(ev);
    }

    fn record_collective(&self, root: usize, op: Collective, elems: usize, group_len: usize) {
        let ev = CollectiveEvent { seq: self.next_seq(), root, op, elems, group_len };
        self.collectives.lock().expect("trace poisoned").push(ev);
    }

    /// Snapshot of all recorded messages (submission order).
    pub fn messages(&self) -> Vec<MessageEvent> {
        let mut v = self.messages.lock().expect("trace poisoned").clone();
        v.sort_by_key(|e| e.seq);
        v
    }

    /// Snapshot of all recorded logical collectives (submission order).
    pub fn collectives(&self) -> Vec<CollectiveEvent> {
        let mut v = self.collectives.lock().expect("trace poisoned").clone();
        v.sort_by_key(|e| e.seq);
        v
    }

    pub fn message_count(&self) -> usize {
        self.messages.lock().expect("trace poisoned").len()
    }

    pub fn total_bytes(&self) -> u64 {
        self.messages.lock().expect("trace poisoned").iter().map(|e| e.bytes).sum()
    }

    /// Bytes sent per rank, for worlds of size `world`.
    pub fn per_rank_bytes(&self, world: usize) -> Vec<u64> {
        let mut out = vec![0u64; world];
        for e in self.messages.lock().expect("trace poisoned").iter() {
            if e.from < world {
                out[e.from] += e.bytes;
            }
        }
        out
    }

    /// Total halo-face payload bytes per spatial axis (D, H, W), from the
    /// axis tags of the recorded sends.
    pub fn halo_bytes_per_axis(&self) -> [u64; 3] {
        let mut out = [0u64; 3];
        for e in self.messages.lock().expect("trace poisoned").iter() {
            if let MsgTag::Halo(a) = e.tag {
                out[a as usize] += e.bytes;
            }
        }
        out
    }

    /// Total data-store redistribution payload bytes ([`MsgTag::Redist`])
    /// — the §III-B staging volume the calibrated I/O model prices.
    pub fn redist_bytes(&self) -> u64 {
        self.messages
            .lock()
            .expect("trace poisoned")
            .iter()
            .filter(|e| e.tag == MsgTag::Redist)
            .map(|e| e.bytes)
            .sum()
    }

    /// Forget everything recorded so far (between steps/phases).
    /// Endpoint streams keep their slots (ids stay valid) but are emptied.
    pub fn clear(&self) {
        self.messages.lock().expect("trace poisoned").clear();
        self.collectives.lock().expect("trace poisoned").clear();
        for s in self.ops.lock().expect("trace poisoned").iter_mut() {
            s.clear();
        }
    }
}

/// A [`Communicator`] wrapper that traces all traffic of `inner`.
pub struct Traced<C: Communicator> {
    inner: C,
    trace: Arc<TraceCollector>,
    /// Index of this endpoint's [`ScheduleOp`] stream in the collector.
    ep_id: usize,
}

impl<C: Communicator> Traced<C> {
    pub fn new(inner: C, trace: Arc<TraceCollector>) -> Traced<C> {
        let ep_id = trace.register_endpoint();
        Traced { inner, trace, ep_id }
    }

    pub fn trace(&self) -> &Arc<TraceCollector> {
        &self.trace
    }
}

impl<C: Communicator> Communicator for Traced<C> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn world_size(&self) -> usize {
        self.inner.world_size()
    }

    fn send(&self, to: usize, data: Vec<f32>) {
        self.trace
            .record_message(self.inner.rank(), to, (data.len() * 4) as u64,
                            MsgTag::Generic);
        self.trace.record_op(
            self.ep_id,
            ScheduleOp::Send { to, elems: data.len(), tag: MsgTag::Generic },
        );
        self.inner.send(to, data);
    }

    fn send_tagged(&self, to: usize, data: Vec<f32>, tag: MsgTag) {
        self.trace
            .record_message(self.inner.rank(), to, (data.len() * 4) as u64, tag);
        self.trace
            .record_op(self.ep_id, ScheduleOp::Send { to, elems: data.len(), tag });
        self.inner.send(to, data);
    }

    fn recv(&self, from: usize) -> Result<Vec<f32>> {
        // Recorded after completion (the length isn't known before), which
        // preserves per-stream program order: the thread can't issue its
        // next op until this blocking receive returns.
        let data = self.inner.recv(from)?;
        self.trace.record_op(
            self.ep_id,
            ScheduleOp::Recv { from, elems: data.len(), tag: MsgTag::Generic },
        );
        Ok(data)
    }

    fn recv_tagged(&self, from: usize, tag: MsgTag) -> Result<Vec<f32>> {
        let data = self.inner.recv(from)?;
        self.trace
            .record_op(self.ep_id, ScheduleOp::Recv { from, elems: data.len(), tag });
        Ok(data)
    }

    fn counters(&self) -> &Arc<Counters> {
        self.inner.counters()
    }

    fn on_collective(&self, op: Collective, elems: usize, group: &[usize]) {
        // Record on the group root (`group[0]`): unique per call, and for
        // rooted collectives (gather/broadcast) the only rank whose buffer
        // length is meaningful. The minimum rank would record elems=0 for
        // a broadcast from a permuted group's root.
        if group.first() == Some(&self.inner.rank()) {
            self.trace
                .record_collective(self.inner.rank(), op, elems, group.len());
        }
        // Every participant also gets a marker in its own schedule stream:
        // check (b) compares these per-group marker subsequences across
        // member ranks for order/size agreement.
        self.trace.record_op(
            self.ep_id,
            ScheduleOp::Collective { op, elems, group: group.to_vec() },
        );
        self.inner.on_collective(op, elems, group);
    }
}
