//! Deterministic single-process loopback backend.
//!
//! A world of exactly one rank: self-sends go through an in-object FIFO
//! queue, group-of-one collectives are no-ops (by the trait's early
//! returns). No threads, no channels between ranks — ideal for fast unit
//! tests and for single-rank engine runs that still need a
//! [`Communicator`].

use super::{Communicator, Counters};
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

/// The single-rank backend.
#[derive(Default)]
pub struct Loopback {
    queue: Mutex<VecDeque<Vec<f32>>>,
    counters: Arc<Counters>,
}

impl Loopback {
    pub fn new() -> Loopback {
        Loopback::default()
    }
}

impl Communicator for Loopback {
    fn rank(&self) -> usize {
        0
    }

    fn world_size(&self) -> usize {
        1
    }

    fn send(&self, to: usize, data: Vec<f32>) {
        assert_eq!(to, 0, "loopback world has a single rank");
        self.counters
            .bytes
            .fetch_add((data.len() * 4) as u64, Ordering::Relaxed);
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        self.queue.lock().expect("loopback queue poisoned").push_back(data);
    }

    fn recv(&self, from: usize) -> Result<Vec<f32>> {
        assert_eq!(from, 0, "loopback world has a single rank");
        self.queue
            .lock()
            .expect("loopback queue poisoned")
            .pop_front()
            .ok_or_else(|| anyhow!("loopback recv with no pending self-message"))
    }

    fn counters(&self) -> &Arc<Counters> {
        &self.counters
    }
}
