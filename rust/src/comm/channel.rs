//! The fully-connected channel-thread backend (the default world).
//!
//! Ranks are threads, links are unbounded `std::sync::mpsc` channels, so
//! sends never block and the engine's send-then-receive halo protocol
//! cannot deadlock; numerics are exactly what a real MPI/NCCL deployment
//! computes (same reduction orders via the shared trait collectives).

use super::{Collective, Communicator, Counters};
use anyhow::{anyhow, Result};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

type Msg = Vec<f32>;

/// One rank's endpoint into a fully-connected channel world.
pub struct Endpoint {
    pub rank: usize,
    pub world: usize,
    txs: Vec<Sender<Msg>>,
    rxs: Vec<Receiver<Msg>>,
    pub counters: Arc<Counters>,
}

/// Build a fully-connected world of `n` endpoints.
pub fn world(n: usize) -> Vec<Endpoint> {
    let counters = Arc::new(Counters::default());
    // txs[src][dst], rxs[dst][src]
    let mut txs: Vec<Vec<Option<Sender<Msg>>>> = (0..n)
        .map(|_| (0..n).map(|_| None).collect())
        .collect();
    let mut rxs: Vec<Vec<Option<Receiver<Msg>>>> = (0..n)
        .map(|_| (0..n).map(|_| None).collect())
        .collect();
    for src in 0..n {
        for dst in 0..n {
            let (tx, rx) = channel();
            txs[src][dst] = Some(tx);
            rxs[dst][src] = Some(rx);
        }
    }
    txs.into_iter()
        .zip(rxs)
        .enumerate()
        .map(|(rank, (tx_row, rx_row))| Endpoint {
            rank,
            world: n,
            txs: tx_row.into_iter().map(Option::unwrap).collect(),
            rxs: rx_row.into_iter().map(Option::unwrap).collect(),
            counters: counters.clone(),
        })
        .collect()
}

impl Communicator for Endpoint {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world_size(&self) -> usize {
        self.world
    }

    /// Asynchronous send (never blocks — unbounded channel).
    fn send(&self, to: usize, data: Vec<f32>) {
        self.counters
            .bytes
            .fetch_add((data.len() * 4) as u64, Ordering::Relaxed);
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        self.txs[to].send(data).expect("peer endpoint dropped");
    }

    fn recv(&self, from: usize) -> Result<Vec<f32>> {
        self.rxs[from]
            .recv()
            .map_err(|_| anyhow!("rank {}: peer {from} disconnected", self.rank))
    }

    fn counters(&self) -> &Arc<Counters> {
        &self.counters
    }

    fn on_collective(&self, op: Collective, _elems: usize, _group: &[usize]) {
        if matches!(
            op,
            Collective::AllreduceRing | Collective::AllreduceRd | Collective::AllreduceHier
        ) {
            self.counters.allreduces.fetch_add(1, Ordering::Relaxed);
        }
    }
}
