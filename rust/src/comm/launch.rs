//! Rank bootstrap for the multi-process socket backend.
//!
//! `hydra3d train --backend socket` does not run ranks itself: it writes a
//! **rendezvous manifest**, fork/execs one `hydra3d worker` process per
//! node ([`launch`]), and supervises them. Each worker reads the manifest
//! ([`read_manifest`]), connects its node into the world
//! ([`socket::connect_node`](super::socket::connect_node) — which includes
//! the barrier-on-connect handshake), runs the task document, writes its
//! result to `<results_dir>/node-<i>.json` and exits 0.
//!
//! Supervision is fail-fast: the launcher polls all children, and the
//! first non-zero exit (or launch timeout, `HYDRA3D_LAUNCH_TIMEOUT_MS`,
//! default 300000) kills the remaining workers and surfaces a clean error
//! instead of hanging on a world that can never complete its collectives —
//! the property `tests/socket_backend.rs` exercises by killing a worker.
//!
//! The manifest is a single JSON file:
//!
//! ```json
//! {
//!   "schema": 1,
//!   "world": 4,
//!   "ranks_per_node": 2,
//!   "label": "w1234",
//!   "sock_dir": "/tmp/hydra3d-launch-1234/sock",
//!   "results_dir": "/tmp/hydra3d-launch-1234/results",
//!   "hosts": [],
//!   "task": { "...": "opaque to this module" }
//! }
//! ```
//!
//! `hosts` non-empty switches rendezvous from Unix-domain sockets to TCP
//! (one `host:port` per node) — the multi-host path, where the same
//! manifest file is distributed to every host and each runs its own
//! `hydra3d worker --manifest ... --node <i>`.

use super::socket::{node_count, Rendezvous};
use crate::util::json::{obj, Json};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

/// Manifest file name inside the launch scratch directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// Everything needed to start one multi-process world.
#[derive(Clone, Debug)]
pub struct LaunchSpec {
    pub world: usize,
    pub ranks_per_node: usize,
    /// `host:port` per node for TCP rendezvous; empty = Unix-domain
    /// sockets under the scratch directory.
    pub hosts: Vec<String>,
    /// Opaque task document passed through to every worker.
    pub task: Json,
}

/// Worker-side view of the manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub rendezvous: Rendezvous,
    pub results_dir: PathBuf,
    pub task: Json,
}

/// Where node `node` writes its result document.
pub fn result_path(results_dir: &Path, node: usize) -> PathBuf {
    results_dir.join(format!("node-{node}.json"))
}

/// Create the scratch layout (`sock/`, `results/`) and write the manifest;
/// returns the manifest path.
pub fn write_manifest(scratch: &Path, spec: &LaunchSpec) -> Result<PathBuf> {
    if spec.world == 0 {
        bail!("socket world needs at least one rank");
    }
    if spec.ranks_per_node == 0 {
        bail!("ranks-per-node must be >= 1");
    }
    let nodes = node_count(spec.world, spec.ranks_per_node);
    if !spec.hosts.is_empty() && spec.hosts.len() != nodes {
        bail!("{} host(s) listed for {nodes} node(s)", spec.hosts.len());
    }
    let sock_dir = scratch.join("sock");
    let results_dir = scratch.join("results");
    std::fs::create_dir_all(&sock_dir)
        .with_context(|| format!("create {}", sock_dir.display()))?;
    std::fs::create_dir_all(&results_dir)
        .with_context(|| format!("create {}", results_dir.display()))?;
    let doc = obj(vec![
        ("schema", 1usize.into()),
        ("world", spec.world.into()),
        ("ranks_per_node", spec.ranks_per_node.into()),
        ("label", format!("w{}", std::process::id()).into()),
        ("sock_dir", sock_dir.to_string_lossy().into_owned().into()),
        ("results_dir", results_dir.to_string_lossy().into_owned().into()),
        ("hosts", spec.hosts.clone().into()),
        ("task", spec.task.clone()),
    ]);
    let path = scratch.join(MANIFEST_FILE);
    std::fs::write(&path, doc.to_string())
        .with_context(|| format!("write {}", path.display()))?;
    Ok(path)
}

/// Parse a manifest file into the worker's view.
pub fn read_manifest(path: &Path) -> Result<Manifest> {
    let doc = Json::parse_file(path)?;
    let hosts = doc
        .req("hosts")?
        .as_arr()?
        .iter()
        .map(|h| Ok(h.as_str()?.to_string()))
        .collect::<Result<Vec<String>>>()?;
    Ok(Manifest {
        rendezvous: Rendezvous {
            world: doc.req("world")?.as_usize()?,
            ranks_per_node: doc.req("ranks_per_node")?.as_usize()?,
            sock_dir: PathBuf::from(doc.req("sock_dir")?.as_str()?),
            label: doc.req("label")?.as_str()?.to_string(),
            hosts,
        },
        results_dir: PathBuf::from(doc.req("results_dir")?.as_str()?),
        task: doc.req("task")?.clone(),
    })
}

/// Overall supervision timeout: `HYDRA3D_LAUNCH_TIMEOUT_MS`, default
/// 300000 (5 minutes — must cover the whole worker run, not just the
/// rendezvous, which has its own `HYDRA3D_CONNECT_TIMEOUT_MS`).
fn launch_timeout() -> Duration {
    let ms = std::env::var("HYDRA3D_LAUNCH_TIMEOUT_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(300_000);
    Duration::from_millis(ms)
}

/// Last lines of a worker's captured stderr, for failure diagnostics.
fn log_tail(path: &Path, lines: usize) -> Option<String> {
    let text = std::fs::read_to_string(path).ok()?;
    let all: Vec<&str> = text.lines().collect();
    let start = all.len().saturating_sub(lines);
    let tail = all[start..].join("\n");
    (!tail.is_empty()).then_some(tail)
}

/// Fork/exec one `exe worker --manifest M --node I` per node, supervise
/// them fail-fast, and return the per-node result documents (node order).
pub fn launch(exe: &Path, spec: &LaunchSpec, scratch: &Path) -> Result<Vec<Json>> {
    launch_attempt(exe, spec, scratch, false)
}

/// One supervised launch attempt. Worker stderr is captured to
/// `<scratch>/logs/node-<i>.stderr.log` (uploaded by CI when a socket or
/// fault lane fails); on a node failure the tail of that node's log is
/// echoed to the launcher's stderr. `suppress_fault_injection` strips the
/// `HYDRA3D_TEST_DIE_*` hooks from the workers' environment — restarted
/// attempts must not re-inject the failure they are recovering from.
fn launch_attempt(
    exe: &Path,
    spec: &LaunchSpec,
    scratch: &Path,
    suppress_fault_injection: bool,
) -> Result<Vec<Json>> {
    let manifest = write_manifest(scratch, spec)?;
    let results_dir = scratch.join("results");
    let logs_dir = scratch.join("logs");
    std::fs::create_dir_all(&logs_dir)
        .with_context(|| format!("create {}", logs_dir.display()))?;
    let nodes = node_count(spec.world, spec.ranks_per_node);
    let log_path =
        |node: usize| logs_dir.join(format!("node-{node}.stderr.log"));
    let mut children: Vec<(usize, Child)> = Vec::with_capacity(nodes);
    for node in 0..nodes {
        let log = std::fs::File::create(log_path(node))
            .with_context(|| format!("create worker log for node {node}"))?;
        let mut cmd = Command::new(exe);
        cmd.arg("worker")
            .arg("--manifest")
            .arg(&manifest)
            .arg("--node")
            .arg(node.to_string())
            .stdin(Stdio::null())
            .stderr(Stdio::from(log));
        if suppress_fault_injection {
            cmd.env_remove("HYDRA3D_TEST_DIE_NODE")
                .env_remove("HYDRA3D_TEST_DIE_AT_STEP");
        }
        let child = cmd
            .spawn()
            .with_context(|| format!("spawn worker for node {node}"))?;
        children.push((node, child));
    }

    let deadline = Instant::now() + launch_timeout();
    let mut exited = vec![false; nodes];
    let mut failure: Option<String> = None;
    loop {
        let mut all_done = true;
        for (node, child) in children.iter_mut() {
            if exited[*node] {
                continue;
            }
            match child.try_wait() {
                Ok(Some(status)) => {
                    exited[*node] = true;
                    if !status.success() && failure.is_none() {
                        failure =
                            Some(format!("worker for node {node} failed: {status}"));
                    }
                }
                Ok(None) => all_done = false,
                Err(e) => {
                    exited[*node] = true;
                    if failure.is_none() {
                        failure = Some(format!("worker for node {node}: {e}"));
                    }
                }
            }
        }
        if failure.is_some() || all_done {
            break;
        }
        if Instant::now() >= deadline {
            failure = Some(format!(
                "launch timeout after {}ms (HYDRA3D_LAUNCH_TIMEOUT_MS)",
                launch_timeout().as_millis()
            ));
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }

    if let Some(msg) = failure {
        // fail-fast: a dead node means the world's collectives can never
        // complete, so kill the survivors instead of hanging on them
        for (node, child) in children.iter_mut() {
            if !exited[*node] {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
        for node in 0..nodes {
            if let Some(tail) = log_tail(&log_path(node), 10) {
                eprintln!("--- node {node} stderr (tail) ---\n{tail}");
            }
        }
        bail!("{msg}");
    }

    (0..nodes)
        .map(|node| {
            let p = result_path(&results_dir, node);
            Json::parse_file(&p).with_context(|| {
                format!("worker for node {node} exited 0 but wrote no result")
            })
        })
        .collect()
}

/// [`launch`] with checkpoint-based recovery: when an attempt fails (a
/// worker died or the launch timed out), re-launch the world up to
/// `max_restarts` times. Each attempt runs under its own
/// `<scratch>/attempt-<n>/` scratch (fresh sockets, results and logs);
/// attempts after the first run with fault injection suppressed and with
/// `resume_task` applied to the task document (the caller flips its
/// `resume` key on, so the restarted world loads the newest committed
/// snapshot). Returns the final attempt's results plus the number of
/// restarts performed.
pub fn launch_with_recovery(
    exe: &Path,
    spec: &LaunchSpec,
    scratch: &Path,
    max_restarts: usize,
    mut resume_task: impl FnMut(&Json) -> Json,
) -> Result<(Vec<Json>, usize)> {
    let mut spec = spec.clone();
    let mut restarts = 0usize;
    loop {
        let attempt_scratch = scratch.join(format!("attempt-{restarts}"));
        let r = launch_attempt(exe, &spec, &attempt_scratch, restarts > 0);
        match r {
            Ok(results) => return Ok((results, restarts)),
            Err(e) if restarts < max_restarts => {
                restarts += 1;
                eprintln!(
                    "[fault-recovery] attempt failed ({e:#}); restarting world \
                     from latest checkpoint (restart {restarts}/{max_restarts})"
                );
                spec.task = resume_task(&spec.task);
            }
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("hydra3d-manifest-test-{}", std::process::id()));
        let spec = LaunchSpec {
            world: 5,
            ranks_per_node: 2,
            hosts: vec![],
            task: obj(vec![("model", "cf-nano".into()), ("steps", 3usize.into())]),
        };
        let path = write_manifest(&dir, &spec).unwrap();
        let m = read_manifest(&path).unwrap();
        assert_eq!(m.rendezvous.world, 5);
        assert_eq!(m.rendezvous.ranks_per_node, 2);
        assert_eq!(m.rendezvous.nodes(), 3);
        assert!(m.rendezvous.hosts.is_empty());
        assert_eq!(m.task.req("model").unwrap().as_str().unwrap(), "cf-nano");
        assert_eq!(result_path(&m.results_dir, 2).file_name().unwrap(),
                   "node-2.json");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_rejects_bad_specs() {
        let dir = std::env::temp_dir()
            .join(format!("hydra3d-manifest-bad-{}", std::process::id()));
        let bad_rpn = LaunchSpec {
            world: 4,
            ranks_per_node: 0,
            hosts: vec![],
            task: Json::Null,
        };
        assert!(write_manifest(&dir, &bad_rpn).is_err());
        let bad_hosts = LaunchSpec {
            world: 4,
            ranks_per_node: 2,
            hosts: vec!["127.0.0.1:4440".into()],
            task: Json::Null,
        };
        let err = write_manifest(&dir, &bad_hosts).unwrap_err().to_string();
        assert!(err.contains("1 host(s) listed for 2 node(s)"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
