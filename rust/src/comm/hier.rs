//! Hierarchical (two-level) collectives — the HyPar-Flow pattern
//! (arXiv:1911.05146) for node-grouped worlds.
//!
//! A flat ring allreduce sends every byte `2(g-1)/g` times over whichever
//! link happens to be next in the ring — including the slow inter-node
//! links. [`allreduce_sum_hier`] instead reduces within each node onto a
//! **node leader** (cheap intra-node channel hops, [`MsgTag::Hier`]\(0\)),
//! runs the ring only over the leaders (the inter-node socket hops, with
//! full payload but `nodes` instead of `g` participants), then broadcasts
//! the result back within each node ([`MsgTag::Hier`]\(1\)).
//!
//! Determinism: intra-node accumulation follows group order, the leader
//! ring is the shared trait ring, and members copy their leader's buffer
//! verbatim — so all group members finish with **bit-identical** results,
//! on every backend. The reduction *order* differs from the flat ring,
//! though, so hier results are not bitwise comparable to flat ones; that
//! is why the engines only use this under the opt-in
//! [`GradReduce::Hier`](super::GradReduce::Hier) and never silently.
//!
//! Schedule shape (what `hydra3d verify` sees): one
//! [`Collective::AllreduceHier`] marker on every participant with the full
//! group, the member/leader legs as `Hier(0)`/`Hier(1)` tagged p2p
//! messages, and the leader ring's own [`Collective::AllreduceRing`]
//! marker on the leader subgroup.

use super::{socket, Collective, Communicator, MsgTag};
use anyhow::Result;

/// In-place two-level sum-allreduce over `group`, with node membership
/// derived from `ranks_per_node` (the launcher's consecutive packing,
/// [`socket::node_of`]). Every member must call with an equal-length
/// buffer. Falls back to the flat ring when the hierarchy is degenerate
/// (`ranks_per_node <= 1`, or every member alone on its node).
pub fn allreduce_sum_hier<C: Communicator + ?Sized>(
    ep: &C,
    buf: &mut [f32],
    group: &[usize],
    ranks_per_node: usize,
) -> Result<()> {
    let g = group.len();
    if g == 1 {
        return Ok(());
    }
    if ranks_per_node <= 1 {
        return ep.allreduce_sum(buf, group);
    }
    // bucket members by hosting node, preserving group order (all ranks
    // derive the identical bucketing, so the schedule cannot diverge)
    let mut nodes: Vec<(usize, Vec<usize>)> = Vec::new();
    for &r in group {
        let nd = socket::node_of(r, ranks_per_node);
        match nodes.iter_mut().find(|(n, _)| *n == nd) {
            Some((_, members)) => members.push(r),
            None => nodes.push((nd, vec![r])),
        }
    }
    if nodes.len() == g {
        // every member alone on its node: the hierarchy adds nothing
        return ep.allreduce_sum(buf, group);
    }
    ep.on_collective(Collective::AllreduceHier, buf.len(), group);
    let leaders: Vec<usize> = nodes.iter().map(|(_, m)| m[0]).collect();
    let me = ep.rank();
    let bucket = nodes
        .iter()
        .map(|(_, m)| m)
        .find(|m| m.contains(&me))
        .expect("rank not in group");
    let leader = bucket[0];
    if me == leader {
        // level 1: reduce the node's members onto the leader, group order
        for &m in &bucket[1..] {
            let incoming = ep.recv_tagged(m, MsgTag::Hier(0))?;
            assert_eq!(incoming.len(), buf.len(), "hier schedule out of sync");
            crate::util::par::zip_mut(buf, &incoming, |d, s| {
                for (dst, src) in d.iter_mut().zip(s) {
                    *dst += src;
                }
            });
        }
        // level 2: ring over the leaders (the only inter-node traffic)
        ep.allreduce_sum(buf, &leaders)?;
        // level 3: broadcast the reduced buffer back within the node
        for &m in &bucket[1..] {
            ep.send_tagged(m, buf.to_vec(), MsgTag::Hier(1));
        }
    } else {
        ep.send_tagged(leader, buf.to_vec(), MsgTag::Hier(0));
        let reduced = ep.recv_tagged(leader, MsgTag::Hier(1))?;
        assert_eq!(reduced.len(), buf.len(), "hier schedule out of sync");
        buf.copy_from_slice(&reduced);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::{socket_world, world, Communicator};
    use super::*;
    use std::thread;

    fn mk_buf(rank: usize, len: usize) -> Vec<f32> {
        (0..len)
            .map(|i| ((rank + 1) as f32 * 1e-3).powi((i % 5) as i32 + 1))
            .collect()
    }

    fn run_hier<E: Communicator + Send>(
        eps: Vec<E>,
        rpn: usize,
        len: usize,
    ) -> Vec<Vec<f32>> {
        let n = eps.len();
        thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .map(|ep| {
                    let group: Vec<usize> = (0..n).collect();
                    s.spawn(move || {
                        let mut buf = mk_buf(ep.rank(), len);
                        allreduce_sum_hier(&ep, &mut buf, &group, rpn).unwrap();
                        buf
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn hier_sums_and_is_rank_identical() {
        for (n, rpn) in [(4, 2), (6, 2), (8, 4), (5, 2), (4, 4)] {
            let out = run_hier(world(n), rpn, 17);
            let expect: Vec<f32> = (0..17)
                .map(|i| (0..n).map(|r| mk_buf(r, 17)[i]).sum::<f32>())
                .collect();
            for (r, o) in out.iter().enumerate() {
                assert_eq!(o, &out[0], "rank {r} diverged (n={n} rpn={rpn})");
                for i in 0..17 {
                    assert!(
                        (o[i] - expect[i]).abs() <= 1e-5 * expect[i].abs().max(1.0),
                        "n={n} rpn={rpn} rank {r} elt {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn hier_bitwise_identical_channel_vs_socket() {
        let chan = run_hier(world(4), 2, 33);
        let sock = run_hier(socket_world(4, 2).unwrap(), 2, 33);
        assert_eq!(chan, sock);
    }

    #[test]
    fn degenerate_hierarchy_falls_back_to_ring() {
        // rpn 1: flat ring, bitwise equal to allreduce_sum
        let hier = run_hier(world(3), 1, 9);
        let eps = world(3);
        let flat: Vec<Vec<f32>> = thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .map(|ep| {
                    s.spawn(move || {
                        let mut buf = mk_buf(ep.rank(), 9);
                        ep.allreduce_sum(&mut buf, &[0, 1, 2]).unwrap();
                        buf
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(hier, flat);
    }

    #[test]
    fn hier_inter_node_frame_bytes() {
        // world 4, rpn 2, 1024 f32: only the leader ring (ranks 0 and 2,
        // one 512-elem reduce-scatter step + one allgather step each)
        // crosses nodes -> 4 frames of 512 elems
        let eps = socket_world(4, 2).unwrap();
        let counters = eps[0].counters().clone();
        let out = run_hier(eps, 2, 1024);
        assert_eq!(out.len(), 4);
        assert_eq!(
            counters.socket_frame_bytes(),
            4 * socket::frame_wire_bytes(512)
        );
    }
}
