//! The communication layer: a [`Communicator`] trait with pluggable
//! backends, collectives layered over point-to-point, and bucketed
//! compute-overlapped gradient allreduce.
//!
//! # Layering
//!
//! Collectives are *provided methods* of the trait, implemented over the
//! backend's `send`/`recv` — ring allreduce (reduce-scatter + allgather,
//! the NCCL algorithm the paper leans on), recursive doubling, allgather,
//! gather/broadcast and barrier — so their communication structure is
//! identical on every backend and can be counted, benchmarked
//! (`benches/micro.rs`) and fed to the §III-C performance model. Reduction
//! orders are deterministic and identical on every rank, which is what the
//! engine hybrid-vs-single-rank equivalence tests validate.
//!
//! # Backends — which one to use
//!
//! * [`Endpoint`] (module [`channel`], built with [`world`]) — the
//!   fully-connected channel-thread world: every rank is a thread, links
//!   are unbounded `std::sync::mpsc` channels, so sends never block and
//!   the engine's send-then-receive halo protocol cannot deadlock. This is
//!   the default backend for multi-rank training and the numerical
//!   reference (same reduction orders as a real MPI/NCCL deployment).
//! * [`Loopback`] — a deterministic single-process, single-rank backend:
//!   self-sends go through an in-object queue, group-of-one collectives
//!   are no-ops. Use it in unit tests and single-rank runs that need a
//!   `Communicator` without spawning a thread world.
//! * [`Traced`] — wraps any other backend and records every message
//!   (source, destination, bytes, sequence) and every logical collective
//!   into a shared [`TraceCollector`]. Because collectives decompose into
//!   `send`/`recv`, the trace captures the *actual* wire structure;
//!   `perfmodel::trace` replays it against the §III-C link model to
//!   predict communication time for a measured run. Use it to validate
//!   the performance model or to audit communication volume.
//! * [`SocketEndpoint`] (module [`socket`], built with [`socket_world`] or
//!   [`socket::connect_node`]) — the multi-process backend: ranks are
//!   grouped onto *nodes* (`--ranks-per-node`), intra-node links stay
//!   lock-free channels, inter-node links are length-prefixed frames over
//!   Unix-domain or TCP sockets. [`socket_world`] builds the whole world in
//!   one process over socketpairs (every inter-node byte crosses a real
//!   socket — the CI smoke path); [`socket::connect_node`] is the
//!   per-process entry used by `hydra3d worker` after [`launch`] forks the
//!   node processes and performs the barrier-on-connect handshake.
//!
//! Backends are selected with [`CommBackend`]; the engines accept any of
//! them and must produce identical training trajectories.
//!
//! # Hierarchical collectives
//!
//! [`hier::allreduce_sum_hier`] is the HyPar-Flow-style two-level
//! allreduce: intra-node reduce onto a node leader ([`MsgTag::Hier`]\(0\)
//! traffic), flat ring over the leaders (inter-node), intra-node broadcast
//! back ([`MsgTag::Hier`]\(1\)). It is deterministic and rank-identical
//! like every other collective here, but its reduction *order* differs
//! from the flat ring, so it is opt-in via [`GradReduce::Hier`] rather
//! than silently swapped in.
//!
//! # Overlap
//!
//! [`bucket`] implements the paper's backprop/allreduce overlap (Fig. 6):
//! gradients are partitioned into fixed-size buckets and each bucket's
//! ring allreduce is launched on a per-rank worker thread as soon as the
//! owning layers' backward passes complete, instead of one blocking
//! allreduce at the end of the step.

pub mod bucket;
mod channel;
pub mod halo;
pub mod hier;
pub mod launch;
pub mod loopback;
pub mod socket;
pub mod traced;

pub use bucket::{BucketPlan, GradReduce, OverlapAllreduce, OverlapReport, DEFAULT_BUCKET_ELEMS};
pub use channel::{world, Endpoint};
pub use hier::allreduce_sum_hier;
pub use loopback::Loopback;
pub use socket::{socket_world, SocketEndpoint};
pub use traced::{CollectiveEvent, MessageEvent, TraceCollector, Traced};

use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Global communication counters (shared by all endpoints of a world).
#[derive(Default, Debug)]
pub struct Counters {
    pub bytes: AtomicU64,
    pub messages: AtomicU64,
    pub allreduces: AtomicU64,
    /// Halo-face payload bytes per partitioned spatial axis (D, H, W),
    /// recorded by `comm::halo` on the sending side — the §III-A
    /// per-dimension halo-region volumes.
    pub halo_axis_bytes: [AtomicU64; 3],
    /// Data-store redistribution payload bytes (the §III-B group-to-group
    /// shard staging), recorded by `iosim::store` on the sending side.
    pub redist_bytes: AtomicU64,
    /// Wire bytes of inter-node socket frames (12-byte header + payload),
    /// recorded by the socket backend at enqueue time on the sending side.
    /// Zero on every other backend and for intra-node traffic; fully
    /// deterministic for a fixed config, so CI gates it exactly.
    pub socket_frame_bytes: AtomicU64,
}

impl Counters {
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }
    pub fn allreduces(&self) -> u64 {
        self.allreduces.load(Ordering::Relaxed)
    }
    /// (D, H, W) halo bytes sent so far.
    pub fn halo_bytes_axes(&self) -> [u64; 3] {
        [
            self.halo_axis_bytes[0].load(Ordering::Relaxed),
            self.halo_axis_bytes[1].load(Ordering::Relaxed),
            self.halo_axis_bytes[2].load(Ordering::Relaxed),
        ]
    }
    pub(crate) fn add_halo_bytes(&self, axis: usize, bytes: u64) {
        self.halo_axis_bytes[axis].fetch_add(bytes, Ordering::Relaxed);
    }
    /// Store-redistribution bytes sent so far over this world.
    pub fn redist_bytes(&self) -> u64 {
        self.redist_bytes.load(Ordering::Relaxed)
    }
    pub(crate) fn add_redist_bytes(&self, bytes: u64) {
        self.redist_bytes.fetch_add(bytes, Ordering::Relaxed);
    }
    /// Inter-node socket frame bytes (header + payload) sent so far.
    pub fn socket_frame_bytes(&self) -> u64 {
        self.socket_frame_bytes.load(Ordering::Relaxed)
    }
    pub(crate) fn add_socket_frame_bytes(&self, bytes: u64) {
        self.socket_frame_bytes.fetch_add(bytes, Ordering::Relaxed);
    }
}

/// Traffic class of a point-to-point message, for per-class accounting
/// ([`Communicator::send_tagged`]; the traced backend records the tag).
///
/// Receivers declare the tag they expect via
/// [`Communicator::recv_tagged`]; `analysis::checks` pairs each send with
/// its receive and flags tag mismatches and cross-class aliasing.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MsgTag {
    Generic,
    /// Halo face along spatial axis 0=D, 1=H, 2=W.
    Halo(u8),
    /// Data-store shard redistribution (§III-B group-to-group staging).
    Redist,
    /// Flatten-boundary scatter of the root's backward activation shards.
    Scatter,
    /// Hierarchical-allreduce leg: 0 = member-to-leader reduce,
    /// 1 = leader-to-member broadcast (`comm::hier`).
    Hier(u8),
}

impl MsgTag {
    /// Coarse traffic class, for aliasing checks: two tags of different
    /// classes must never meet on the same (sender, receiver) pairing.
    /// `Generic` covers collective-internal and control traffic.
    pub fn class(&self) -> &'static str {
        match self {
            MsgTag::Generic => "generic",
            MsgTag::Halo(_) => "halo",
            MsgTag::Redist => "redist",
            MsgTag::Scatter => "scatter",
            MsgTag::Hier(_) => "hier",
        }
    }
}

impl std::fmt::Display for MsgTag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MsgTag::Generic => write!(f, "generic"),
            MsgTag::Halo(a) => write!(f, "halo({a})"),
            MsgTag::Redist => write!(f, "redist"),
            MsgTag::Scatter => write!(f, "scatter"),
            MsgTag::Hier(leg) => write!(f, "hier({leg})"),
        }
    }
}

/// One intended communication operation of one rank, in program order —
/// the unit of the schedule that `hydra3d verify` analyzes. Recorded by
/// the traced backend into per-endpoint streams: `Send`/`Recv` capture the
/// actual wire traffic (collectives decompose into them), while
/// `Collective` is a non-blocking marker recorded on *every* participant
/// when a logical collective starts, so rank-order agreement can be
/// checked without reverse-engineering the p2p pattern.
#[derive(Clone, Debug, PartialEq)]
pub enum ScheduleOp {
    /// Point-to-point send of `elems` f32s to `to`.
    Send { to: usize, elems: usize, tag: MsgTag },
    /// Blocking receive from `from`; `tag` is the tag the receiver
    /// *expects* (`Generic` for untagged/collective-internal receives),
    /// `elems` the length actually delivered.
    Recv { from: usize, elems: usize, tag: MsgTag },
    /// Logical collective entry on this rank (marker, not wire traffic).
    Collective { op: Collective, elems: usize, group: Vec<usize> },
}

/// Collective operations, for the [`Communicator::on_collective`] hook and
/// the traced backend's records.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Collective {
    AllreduceRing,
    AllreduceRd,
    /// Two-level intra-node/inter-node allreduce (`comm::hier`); recorded
    /// on every participant with the *full* group. The inter-node leg
    /// additionally records its own [`Collective::AllreduceRing`] on the
    /// leader subgroup.
    AllreduceHier,
    ReduceScatter,
    Allgather,
    GatherToRoot,
    Broadcast,
    Barrier,
}

/// Position of `rank` within `group` (collectives address group members by
/// index; `group` may be any permutation of any subset of the world).
fn index_in(group: &[usize], rank: usize) -> usize {
    group
        .iter()
        .position(|&r| r == rank)
        .expect("rank not in group")
}

/// A rank's endpoint into a communication world.
///
/// Backends implement the five required methods; every collective is a
/// provided method layered over `send`/`recv`, so all backends share one
/// (deterministic, rank-identical) collective implementation.
///
/// Endpoints are owned values, moved into their rank's thread (or
/// process); the usual driving pattern is a scoped thread per rank:
///
/// ```
/// use hydra3d::comm::{world, Communicator};
///
/// let eps = world(2); // fully-connected channel world of 2 ranks
/// let outs: Vec<Vec<f32>> = std::thread::scope(|s| {
///     let hs: Vec<_> = eps
///         .into_iter()
///         .map(|ep| {
///             s.spawn(move || {
///                 let mut buf = vec![ep.rank() as f32 + 1.0];
///                 ep.allreduce_sum(&mut buf, &[0, 1]).unwrap();
///                 buf
///             })
///         })
///         .collect();
///     hs.into_iter().map(|h| h.join().unwrap()).collect()
/// });
/// // 1.0 + 2.0, bit-identical on every rank
/// assert_eq!(outs, vec![vec![3.0], vec![3.0]]);
/// ```
pub trait Communicator: Send {
    /// This rank's id in the world.
    fn rank(&self) -> usize;

    /// Number of ranks in the world.
    fn world_size(&self) -> usize;

    /// Asynchronous point-to-point send (must never block).
    fn send(&self, to: usize, data: Vec<f32>);

    /// [`Communicator::send`] with a traffic-class tag. Backends that do
    /// per-class accounting (the traced backend) override this; the default
    /// drops the tag.
    fn send_tagged(&self, to: usize, data: Vec<f32>, _tag: MsgTag) {
        self.send(to, data);
    }

    /// Blocking receive of the next message from `from` (program order).
    fn recv(&self, from: usize) -> Result<Vec<f32>>;

    /// [`Communicator::recv`] declaring the traffic class the caller
    /// expects. Channels are FIFO per sender and carry no tag on the wire,
    /// so the default ignores `tag`; the traced backend overrides this to
    /// record the expectation so `analysis::checks` can pair it against
    /// the sender's [`MsgTag`].
    fn recv_tagged(&self, from: usize, _tag: MsgTag) -> Result<Vec<f32>> {
        self.recv(from)
    }

    /// Shared traffic counters of this rank's world.
    fn counters(&self) -> &Arc<Counters>;

    /// Hook fired when a collective with more than one participant starts
    /// on this rank. Backends use it for accounting (channel world) or
    /// trace recording (traced backend).
    fn on_collective(&self, _op: Collective, _elems: usize, _group: &[usize]) {}

    /// In-place sum-allreduce over `group` using the ring algorithm
    /// (reduce-scatter then allgather), 2(g-1) steps. Works for any group
    /// size; every member must call with an equal-length buffer.
    ///
    /// Reduction order is identical on every rank (chunk r is always
    /// accumulated in ring order starting at rank r+1), so all members end
    /// with bit-identical results — required for the equivalence tests.
    fn allreduce_sum(&self, buf: &mut [f32], group: &[usize]) -> Result<()> {
        let g = group.len();
        if g == 1 {
            return Ok(());
        }
        self.on_collective(Collective::AllreduceRing, buf.len(), group);
        let me = index_in(group, self.rank());
        let next = group[(me + 1) % g];
        let prev = group[(me + g - 1) % g];
        let bounds: Vec<(usize, usize)> = (0..g).map(|i| chunk_bounds(buf.len(), g, i)).collect();
        ring_reduce_scatter(self, buf, group, &bounds)?;
        // allgather the reduced chunks around the ring.
        for s in 0..g - 1 {
            let send_c = (me + 1 + g - s) % g;
            let recv_c = (me + g - s) % g;
            let (lo, hi) = bounds[send_c];
            self.send(next, buf[lo..hi].to_vec());
            let incoming = self.recv(prev)?;
            let (lo, hi) = bounds[recv_c];
            buf[lo..hi].copy_from_slice(&incoming);
        }
        Ok(())
    }

    /// Ring reduce-scatter: after the call, this rank's *owned chunk* —
    /// returned as `[lo, hi)` bounds into `buf` — holds the full sum over
    /// the group; the rest of `buf` holds partial sums. The owned chunk of
    /// group index `me` is chunk `(me + 1) % g`, matching the first phase
    /// of [`Communicator::allreduce_sum`].
    fn reduce_scatter_sum(&self, buf: &mut [f32], group: &[usize]) -> Result<(usize, usize)> {
        let g = group.len();
        if g == 1 {
            return Ok((0, buf.len()));
        }
        self.on_collective(Collective::ReduceScatter, buf.len(), group);
        let bounds: Vec<(usize, usize)> = (0..g).map(|i| chunk_bounds(buf.len(), g, i)).collect();
        ring_reduce_scatter(self, buf, group, &bounds)?;
        Ok(bounds[(index_in(group, self.rank()) + 1) % g])
    }

    /// Recursive-doubling allreduce (power-of-two groups): log2(g) steps of
    /// pairwise exchange+add. Higher bandwidth cost than ring for large
    /// buffers but lower latency for small ones — the engine uses it for
    /// the per-channel BN statistics.
    fn allreduce_sum_rd(&self, buf: &mut [f32], group: &[usize]) -> Result<()> {
        let g = group.len();
        if g == 1 {
            return Ok(());
        }
        assert!(g.is_power_of_two(), "recursive doubling needs 2^k ranks");
        self.on_collective(Collective::AllreduceRd, buf.len(), group);
        let me = index_in(group, self.rank());
        let mut dist = 1;
        while dist < g {
            let peer = group[me ^ dist];
            self.send(peer, buf.to_vec());
            let incoming = self.recv(peer)?;
            assert_eq!(incoming.len(), buf.len(), "rd schedule out of sync");
            for (dst, src) in buf.iter_mut().zip(&incoming) {
                *dst += src;
            }
            dist <<= 1;
        }
        Ok(())
    }

    /// Gather equal-length contributions from all of `group` onto every
    /// member (flat exchange; used for small control data).
    fn allgather(&self, mine: &[f32], group: &[usize]) -> Result<Vec<Vec<f32>>> {
        let me = index_in(group, self.rank());
        if group.len() > 1 {
            self.on_collective(Collective::Allgather, mine.len(), group);
        }
        for (i, &r) in group.iter().enumerate() {
            if i != me {
                self.send(r, mine.to_vec());
            }
        }
        let mut out = Vec::with_capacity(group.len());
        for (i, &r) in group.iter().enumerate() {
            if i == me {
                out.push(mine.to_vec());
            } else {
                out.push(self.recv(r)?);
            }
        }
        Ok(out)
    }

    /// Gather variable-length f32 buffers to `group[0]`; returns Some(parts)
    /// on the root (in group order), None elsewhere.
    fn gather_to_root(&self, mine: &[f32], group: &[usize]) -> Result<Option<Vec<Vec<f32>>>> {
        self.gather_to_root_vec(mine.to_vec(), group)
    }

    /// [`Communicator::gather_to_root`] taking the contribution by value —
    /// non-roots hand their (possibly pooled) buffer straight to `send`
    /// with no defensive copy.
    fn gather_to_root_vec(&self, mine: Vec<f32>, group: &[usize]) -> Result<Option<Vec<Vec<f32>>>> {
        let me = index_in(group, self.rank());
        if group.len() > 1 {
            self.on_collective(Collective::GatherToRoot, mine.len(), group);
        }
        if me == 0 {
            let mut parts = Vec::with_capacity(group.len());
            parts.push(mine);
            for &r in &group[1..] {
                parts.push(self.recv(r)?);
            }
            Ok(Some(parts))
        } else {
            self.send(group[0], mine);
            Ok(None)
        }
    }

    /// Broadcast from `group[0]` to the rest; non-roots pass an empty vec.
    fn broadcast(&self, mine: Vec<f32>, group: &[usize]) -> Result<Vec<f32>> {
        let me = index_in(group, self.rank());
        if group.len() > 1 {
            self.on_collective(Collective::Broadcast, mine.len(), group);
        }
        if me == 0 {
            for &r in &group[1..] {
                self.send(r, mine.clone());
            }
            Ok(mine)
        } else {
            self.recv(group[0])
        }
    }

    /// Synchronization barrier over `group` (gather of empties to the
    /// group root, then a broadcast of empties back).
    fn barrier(&self, group: &[usize]) -> Result<()> {
        let g = group.len();
        if g == 1 {
            return Ok(());
        }
        self.on_collective(Collective::Barrier, 0, group);
        let me = index_in(group, self.rank());
        if me == 0 {
            for &r in &group[1..] {
                self.recv(r)?;
            }
            for &r in &group[1..] {
                self.send(r, Vec::new());
            }
        } else {
            self.send(group[0], Vec::new());
            self.recv(group[0])?;
        }
        Ok(())
    }
}

/// Backend selector for the training engines: every variant produces a
/// world of [`Communicator`]s with identical collective semantics.
#[derive(Clone)]
pub enum CommBackend {
    /// Fully-connected channel-thread world (the default).
    Channel,
    /// Deterministic single-process backend; only world size 1.
    Loopback,
    /// Channel world wrapped in message/collective tracing.
    Traced(Arc<TraceCollector>),
    /// In-process socket world: ranks are packed onto simulated nodes of
    /// `ranks_per_node` and every inter-node message crosses a real
    /// Unix socketpair as a length-prefixed frame ([`socket_world`]).
    /// Same collective semantics and counter totals as [`CommBackend::Channel`]
    /// (plus [`Counters::socket_frame_bytes`]).
    Socket { ranks_per_node: usize },
}

impl CommBackend {
    /// Build a world of `n` communicators.
    pub fn build_world(&self, n: usize) -> Result<Vec<Box<dyn Communicator>>> {
        match self {
            CommBackend::Channel => Ok(world(n)
                .into_iter()
                .map(|e| Box::new(e) as Box<dyn Communicator>)
                .collect()),
            CommBackend::Loopback => {
                if n != 1 {
                    bail!("loopback backend is single-rank only (asked for {n} ranks)");
                }
                Ok(vec![Box::new(Loopback::new()) as Box<dyn Communicator>])
            }
            CommBackend::Traced(tc) => Ok(world(n)
                .into_iter()
                .map(|e| Box::new(Traced::new(e, tc.clone())) as Box<dyn Communicator>)
                .collect()),
            CommBackend::Socket { ranks_per_node } => {
                Ok(socket_world(n, *ranks_per_node)?
                    .into_iter()
                    .map(|e| Box::new(e) as Box<dyn Communicator>)
                    .collect())
            }
        }
    }

    /// Human-readable backend name (CLI/report labels).
    pub fn name(&self) -> &'static str {
        match self {
            CommBackend::Channel => "channel",
            CommBackend::Loopback => "loopback",
            CommBackend::Traced(_) => "traced",
            CommBackend::Socket { .. } => "socket",
        }
    }
}

/// The ring reduce-scatter schedule shared by [`Communicator::allreduce_sum`]
/// and [`Communicator::reduce_scatter_sum`]: after g-1 steps, group index
/// `me` owns the full sum of chunk `(me + 1) % g` within `bounds`; the rest
/// of `buf` holds partial sums. Callers handle the g == 1 early return and
/// the [`Communicator::on_collective`] accounting.
fn ring_reduce_scatter<C: Communicator + ?Sized>(
    ep: &C,
    buf: &mut [f32],
    group: &[usize],
    bounds: &[(usize, usize)],
) -> Result<()> {
    let g = group.len();
    let me = index_in(group, ep.rank());
    let next = group[(me + 1) % g];
    let prev = group[(me + g - 1) % g];
    for s in 0..g - 1 {
        let send_c = (me + g - s) % g;
        let recv_c = (me + g - s - 1) % g;
        let (lo, hi) = bounds[send_c];
        ep.send(next, buf[lo..hi].to_vec());
        let incoming = ep.recv(prev)?;
        let (lo, hi) = bounds[recv_c];
        // A length mismatch means the ranks' collective schedules diverged
        // (e.g. buckets launched in different orders); the zip below would
        // silently truncate, so fail loudly instead — a hard assert, since
        // release builds are exactly where silent corruption would hide.
        assert_eq!(incoming.len(), hi - lo, "ring schedule out of sync");
        // per-element adds are independent, so threading keeps the result
        // bit-identical (see util::par's determinism contract)
        crate::util::par::zip_mut(&mut buf[lo..hi], &incoming, |d, s| {
            for (dst, src) in d.iter_mut().zip(s) {
                *dst += src;
            }
        });
    }
    Ok(())
}

/// Even-ish chunking of `len` into `parts` (first `len % parts` chunks get
/// one extra element).
fn chunk_bounds(len: usize, parts: usize, idx: usize) -> (usize, usize) {
    let base = len / parts;
    let extra = len % parts;
    let lo = idx * base + idx.min(extra);
    let hi = lo + base + usize::from(idx < extra);
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::thread;

    /// Endpoints are *moved into* their threads (Receiver is Send, not
    /// Sync) — the same ownership pattern the engine uses.
    fn run_world<F>(n: usize, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(&Endpoint) -> Vec<f32> + Send + Sync + Copy,
    {
        let eps = world(n);
        thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .map(|ep| s.spawn(move || f(&ep)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn p2p_ordering() {
        let out = run_world(2, |ep| {
            if ep.rank == 0 {
                ep.send(1, vec![1.0]);
                ep.send(1, vec![2.0]);
                vec![]
            } else {
                let a = ep.recv(0).unwrap();
                let b = ep.recv(0).unwrap();
                vec![a[0], b[0]]
            }
        });
        assert_eq!(out[1], vec![1.0, 2.0]);
    }

    #[test]
    fn ring_allreduce_matches_sum() {
        for n in [2usize, 3, 4, 5, 8] {
            let out = run_world(n, move |ep| {
                let group: Vec<usize> = (0..ep.world).collect();
                let mut buf: Vec<f32> =
                    (0..10).map(|i| (ep.rank * 10 + i) as f32).collect();
                ep.allreduce_sum(&mut buf, &group).unwrap();
                buf
            });
            let expect: Vec<f32> = (0..10)
                .map(|i| (0..n).map(|r| (r * 10 + i) as f32).sum())
                .collect();
            for r in 0..n {
                assert_eq!(out[r], expect, "rank {r} of {n}");
            }
        }
    }

    #[test]
    fn ring_allreduce_bitwise_identical_across_ranks() {
        // adversarial floats: results must still be *identical* on all ranks
        let out = run_world(4, |ep| {
            let group: Vec<usize> = (0..4).collect();
            let mut buf: Vec<f32> = (0..33)
                .map(|i| ((ep.rank + 1) as f32 * 1e-3).powi((i % 7) as i32 + 1))
                .collect();
            ep.allreduce_sum(&mut buf, &group).unwrap();
            buf
        });
        for r in 1..4 {
            assert_eq!(out[0], out[r]);
        }
    }

    #[test]
    fn reduce_scatter_owns_full_sum() {
        let n = 4;
        let len = 13;
        let outs = run_world(n, move |ep| {
            let group: Vec<usize> = (0..n).collect();
            let mut buf: Vec<f32> = (0..len).map(|i| (ep.rank * len + i) as f32).collect();
            let (lo, hi) = ep.reduce_scatter_sum(&mut buf, &group).unwrap();
            let mut out = vec![lo as f32, hi as f32];
            out.extend_from_slice(&buf[lo..hi]);
            out
        });
        let expect: Vec<f32> = (0..len)
            .map(|i| (0..n).map(|r| (r * len + i) as f32).sum())
            .collect();
        for (me, o) in outs.iter().enumerate() {
            let (lo, hi) = (o[0] as usize, o[1] as usize);
            let owned_chunk = (me + 1) % n;
            assert_eq!((lo, hi), chunk_bounds(len, n, owned_chunk), "rank {me}");
            assert_eq!(&o[2..], &expect[lo..hi], "rank {me}");
        }
    }

    #[test]
    fn rd_allreduce_matches_ring() {
        let out = run_world(4, |ep| {
            let group: Vec<usize> = (0..4).collect();
            let mut a: Vec<f32> = (0..8).map(|i| (ep.rank + i) as f32).collect();
            let mut b = a.clone();
            ep.allreduce_sum(&mut a, &group).unwrap();
            ep.allreduce_sum_rd(&mut b, &group).unwrap();
            a.extend(b);
            a
        });
        for o in &out {
            let (a, b) = o.split_at(8);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn subgroup_allreduce() {
        // two disjoint groups reduce independently
        let out = run_world(4, |ep| {
            let group: Vec<usize> = if ep.rank < 2 { vec![0, 1] } else { vec![2, 3] };
            let mut buf = vec![ep.rank as f32];
            ep.allreduce_sum(&mut buf, &group).unwrap();
            buf
        });
        assert_eq!(out, vec![vec![1.0], vec![1.0], vec![5.0], vec![5.0]]);
    }

    #[test]
    fn gather_broadcast_barrier() {
        let out = run_world(3, |ep| {
            let group: Vec<usize> = (0..3).collect();
            let gathered = ep.gather_to_root(&[ep.rank as f32], &group).unwrap();
            let val = if let Some(parts) = gathered {
                parts.iter().map(|p| p[0]).sum::<f32>()
            } else {
                0.0
            };
            let out = ep.broadcast(vec![val], &group).unwrap();
            ep.barrier(&group).unwrap();
            out
        });
        assert_eq!(out, vec![vec![3.0]; 3]);
    }

    #[test]
    fn allgather_order() {
        let out = run_world(3, |ep| {
            let group = [2usize, 0, 1]; // deliberately permuted group order
            let parts = ep.allgather(&[ep.rank as f32 * 2.0], &group).unwrap();
            parts.into_iter().flatten().collect()
        });
        for o in out {
            assert_eq!(o, vec![4.0, 0.0, 2.0]);
        }
    }

    #[test]
    fn counters_accumulate() {
        let mut eps = world(2);
        let c = eps[0].counters().clone();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        thread::scope(|s| {
            s.spawn(move || e0.send(1, vec![0.0; 100]));
            s.spawn(move || {
                e1.recv(0).unwrap();
            });
        });
        assert_eq!(c.bytes(), 400);
        assert_eq!(c.messages(), 1);
    }

    #[test]
    fn loopback_backend_is_single_rank_world() {
        let comms = CommBackend::Loopback.build_world(1).unwrap();
        let ep = &comms[0];
        assert_eq!((ep.rank(), ep.world_size()), (0, 1));
        // group-of-one collectives are no-ops with correct results
        let mut buf = vec![3.0, -1.0];
        ep.allreduce_sum(&mut buf, &[0]).unwrap();
        assert_eq!(buf, vec![3.0, -1.0]);
        assert_eq!(ep.allgather(&[2.0], &[0]).unwrap(), vec![vec![2.0]]);
        ep.barrier(&[0]).unwrap();
        // self-messaging is FIFO
        ep.send(0, vec![1.0]);
        ep.send(0, vec![2.0]);
        assert_eq!(ep.recv(0).unwrap(), vec![1.0]);
        assert_eq!(ep.recv(0).unwrap(), vec![2.0]);
        assert!(CommBackend::Loopback.build_world(2).is_err());
    }

    #[test]
    fn traced_backend_matches_channel_numerics() {
        let tc = Arc::new(TraceCollector::new());
        let comms = CommBackend::Traced(tc.clone()).build_world(3).unwrap();
        let outs: Vec<Vec<f32>> = thread::scope(|s| {
            let hs: Vec<_> = comms
                .into_iter()
                .map(|ep| {
                    s.spawn(move || {
                        let group: Vec<usize> = (0..3).collect();
                        let mut buf = vec![ep.rank() as f32; 5];
                        ep.allreduce_sum(&mut buf, &group).unwrap();
                        buf
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for o in &outs {
            assert_eq!(o, &vec![3.0; 5]);
        }
        // ring over g ranks moves exactly 2(g-1) * len elements in total
        assert_eq!(tc.message_count(), 2 * 2 * 3);
        assert_eq!(tc.total_bytes(), (2 * 2 * 5 * 4) as u64);
        assert_eq!(tc.collectives().len(), 1, "one logical collective");
    }

    #[test]
    fn prop_chunk_bounds_cover() {
        prop::check("chunk-cover", 200, |g| {
            let len = g.usize_in(0, 200);
            let parts = g.usize_in(1, 17);
            let mut end = 0;
            for i in 0..parts {
                let (lo, hi) = chunk_bounds(len, parts, i);
                if lo != end || hi < lo {
                    return Err(format!("gap at chunk {i}: ({lo},{hi}) end={end}"));
                }
                end = hi;
            }
            if end != len {
                return Err(format!("cover ended at {end} != {len}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_ring_allreduce_random_groups() {
        prop::check("ring-random", 12, |g| {
            let n = g.usize_in(2, 6);
            let len = g.usize_in(1, 40);
            let vals: Vec<Vec<f32>> =
                (0..n).map(|_| g.vec_f32(len, 1.0)).collect();
            let expect: Vec<f32> = (0..len)
                .map(|i| vals.iter().map(|v| v[i]).sum())
                .collect();
            let eps = world(n);
            let out: Vec<Vec<f32>> = thread::scope(|s| {
                let hs: Vec<_> = eps
                    .into_iter()
                    .zip(&vals)
                    .map(|(ep, v)| {
                        let group: Vec<usize> = (0..n).collect();
                        let mut buf = v.clone();
                        s.spawn(move || {
                            ep.allreduce_sum(&mut buf, &group).unwrap();
                            buf
                        })
                    })
                    .collect();
                hs.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (r, o) in out.iter().enumerate() {
                for i in 0..len {
                    if (o[i] - expect[i]).abs() > 1e-4 * expect[i].abs().max(1.0) {
                        return Err(format!("rank {r} elt {i}: {} != {}", o[i],
                                           expect[i]));
                    }
                }
            }
            Ok(())
        });
    }
}
