//! Simulated multi-rank communicator: ranks are threads, links are
//! channels.
//!
//! The functional engine runs every GPU of the paper's cluster as a thread
//! holding an [`Endpoint`]. Message passing is `std::sync::mpsc` with
//! unbounded buffering, so sends never block and the engine's
//! send-then-receive halo protocol cannot deadlock; numerics are exactly
//! what a real MPI/NCCL deployment computes (same reduction orders), which
//! is what the hybrid-vs-single-rank equivalence tests validate.
//!
//! Collectives are implemented *over* point-to-point — ring allreduce
//! (reduce-scatter + allgather, the NCCL algorithm the paper leans on) and
//! recursive doubling — so their communication structure can be counted,
//! benchmarked (`benches/micro.rs`) and fed to the §III-C performance
//! model.

pub mod halo;

use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// Global communication counters (shared by all endpoints).
#[derive(Default, Debug)]
pub struct Counters {
    pub bytes: AtomicU64,
    pub messages: AtomicU64,
    pub allreduces: AtomicU64,
}

impl Counters {
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }
}

type Msg = Vec<f32>;

/// One rank's endpoint into the world.
pub struct Endpoint {
    pub rank: usize,
    pub world: usize,
    txs: Vec<Sender<Msg>>,
    rxs: Vec<Receiver<Msg>>,
    pub counters: Arc<Counters>,
}

/// Build a fully-connected world of `n` endpoints.
pub fn world(n: usize) -> Vec<Endpoint> {
    let counters = Arc::new(Counters::default());
    // txs[src][dst], rxs[dst][src]
    let mut txs: Vec<Vec<Option<Sender<Msg>>>> = (0..n)
        .map(|_| (0..n).map(|_| None).collect())
        .collect();
    let mut rxs: Vec<Vec<Option<Receiver<Msg>>>> = (0..n)
        .map(|_| (0..n).map(|_| None).collect())
        .collect();
    for src in 0..n {
        for dst in 0..n {
            let (tx, rx) = channel();
            txs[src][dst] = Some(tx);
            rxs[dst][src] = Some(rx);
        }
    }
    txs.into_iter()
        .zip(rxs)
        .enumerate()
        .map(|(rank, (tx_row, rx_row))| Endpoint {
            rank,
            world: n,
            txs: tx_row.into_iter().map(Option::unwrap).collect(),
            rxs: rx_row.into_iter().map(Option::unwrap).collect(),
            counters: counters.clone(),
        })
        .collect()
}

impl Endpoint {
    /// Asynchronous send (never blocks — unbounded channel).
    pub fn send(&self, to: usize, data: Vec<f32>) {
        self.counters
            .bytes
            .fetch_add((data.len() * 4) as u64, Ordering::Relaxed);
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        self.txs[to].send(data).expect("peer endpoint dropped");
    }

    /// Blocking receive of the next message from `from` (program order).
    pub fn recv(&self, from: usize) -> Result<Vec<f32>> {
        self.rxs[from]
            .recv()
            .map_err(|_| anyhow!("rank {}: peer {from} disconnected", self.rank))
    }

    fn me_in(&self, group: &[usize]) -> usize {
        group
            .iter()
            .position(|&r| r == self.rank)
            .expect("rank not in group")
    }

    /// In-place sum-allreduce over `group` using the ring algorithm
    /// (reduce-scatter then allgather), 2(g-1) steps. Works for any group
    /// size; every member must call with an equal-length buffer.
    ///
    /// Reduction order is identical on every rank (chunk r is always
    /// accumulated in ring order starting at rank r+1), so all members end
    /// with bit-identical results — required for the equivalence tests.
    pub fn allreduce_sum(&self, buf: &mut [f32], group: &[usize]) -> Result<()> {
        let g = group.len();
        if g == 1 {
            return Ok(());
        }
        self.counters.allreduces.fetch_add(1, Ordering::Relaxed);
        let me = self.me_in(group);
        let next = group[(me + 1) % g];
        let prev = group[(me + g - 1) % g];
        let bounds: Vec<(usize, usize)> = (0..g).map(|i| chunk_bounds(buf.len(), g, i)).collect();

        // reduce-scatter: after step s, rank owns the full sum of chunk
        // (me+1) after g-1 steps.
        for s in 0..g - 1 {
            let send_c = (me + g - s) % g;
            let recv_c = (me + g - s - 1) % g;
            let (lo, hi) = bounds[send_c];
            self.send(next, buf[lo..hi].to_vec());
            let incoming = self.recv(prev)?;
            let (lo, hi) = bounds[recv_c];
            for (dst, src) in buf[lo..hi].iter_mut().zip(&incoming) {
                *dst += src;
            }
        }
        // allgather the reduced chunks around the ring.
        for s in 0..g - 1 {
            let send_c = (me + 1 + g - s) % g;
            let recv_c = (me + g - s) % g;
            let (lo, hi) = bounds[send_c];
            self.send(next, buf[lo..hi].to_vec());
            let incoming = self.recv(prev)?;
            let (lo, hi) = bounds[recv_c];
            buf[lo..hi].copy_from_slice(&incoming);
        }
        Ok(())
    }

    /// Recursive-doubling allreduce (power-of-two groups): log2(g) steps of
    /// pairwise exchange+add. Higher bandwidth cost than ring for large
    /// buffers but lower latency for small ones — the engine uses it for
    /// the per-channel BN statistics.
    pub fn allreduce_sum_rd(&self, buf: &mut [f32], group: &[usize]) -> Result<()> {
        let g = group.len();
        if g == 1 {
            return Ok(());
        }
        assert!(g.is_power_of_two(), "recursive doubling needs 2^k ranks");
        self.counters.allreduces.fetch_add(1, Ordering::Relaxed);
        let me = self.me_in(group);
        let mut dist = 1;
        while dist < g {
            let peer = group[me ^ dist];
            self.send(peer, buf.to_vec());
            let incoming = self.recv(peer)?;
            for (dst, src) in buf.iter_mut().zip(&incoming) {
                *dst += src;
            }
            dist <<= 1;
        }
        Ok(())
    }

    /// Gather equal-length contributions from all of `group` onto every
    /// member (flat exchange; used for small control data).
    pub fn allgather(&self, mine: &[f32], group: &[usize]) -> Result<Vec<Vec<f32>>> {
        let me = self.me_in(group);
        for (i, &r) in group.iter().enumerate() {
            if i != me {
                self.send(r, mine.to_vec());
            }
        }
        let mut out = Vec::with_capacity(group.len());
        for (i, &r) in group.iter().enumerate() {
            if i == me {
                out.push(mine.to_vec());
            } else {
                out.push(self.recv(r)?);
            }
        }
        Ok(out)
    }

    /// Gather variable-length f32 buffers to `group[0]`; returns Some(parts)
    /// on the root (in group order), None elsewhere.
    pub fn gather_to_root(&self, mine: &[f32], group: &[usize])
                          -> Result<Option<Vec<Vec<f32>>>> {
        let me = self.me_in(group);
        if me == 0 {
            let mut parts = Vec::with_capacity(group.len());
            parts.push(mine.to_vec());
            for &r in &group[1..] {
                parts.push(self.recv(r)?);
            }
            Ok(Some(parts))
        } else {
            self.send(group[0], mine.to_vec());
            Ok(None)
        }
    }

    /// Broadcast from `group[0]` to the rest; non-roots pass an empty vec.
    pub fn broadcast(&self, mine: Vec<f32>, group: &[usize]) -> Result<Vec<f32>> {
        let me = self.me_in(group);
        if me == 0 {
            for &r in &group[1..] {
                self.send(r, mine.clone());
            }
            Ok(mine)
        } else {
            self.recv(group[0])
        }
    }

    /// Synchronization barrier over `group`.
    pub fn barrier(&self, group: &[usize]) -> Result<()> {
        self.gather_to_root(&[], group)?;
        self.broadcast(vec![], group)?;
        Ok(())
    }
}

/// Even-ish chunking of `len` into `parts` (first `len % parts` chunks get
/// one extra element).
fn chunk_bounds(len: usize, parts: usize, idx: usize) -> (usize, usize) {
    let base = len / parts;
    let extra = len % parts;
    let lo = idx * base + idx.min(extra);
    let hi = lo + base + usize::from(idx < extra);
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::thread;

    /// Endpoints are *moved into* their threads (Receiver is Send, not
    /// Sync) — the same ownership pattern the engine uses.
    fn run_world<F>(n: usize, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(&Endpoint) -> Vec<f32> + Send + Sync + Copy,
    {
        let eps = world(n);
        thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .map(|ep| s.spawn(move || f(&ep)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn p2p_ordering() {
        let out = run_world(2, |ep| {
            if ep.rank == 0 {
                ep.send(1, vec![1.0]);
                ep.send(1, vec![2.0]);
                vec![]
            } else {
                let a = ep.recv(0).unwrap();
                let b = ep.recv(0).unwrap();
                vec![a[0], b[0]]
            }
        });
        assert_eq!(out[1], vec![1.0, 2.0]);
    }

    #[test]
    fn ring_allreduce_matches_sum() {
        for n in [2usize, 3, 4, 5, 8] {
            let out = run_world(n, move |ep| {
                let group: Vec<usize> = (0..ep.world).collect();
                let mut buf: Vec<f32> =
                    (0..10).map(|i| (ep.rank * 10 + i) as f32).collect();
                ep.allreduce_sum(&mut buf, &group).unwrap();
                buf
            });
            let expect: Vec<f32> = (0..10)
                .map(|i| (0..n).map(|r| (r * 10 + i) as f32).sum())
                .collect();
            for r in 0..n {
                assert_eq!(out[r], expect, "rank {r} of {n}");
            }
        }
    }

    #[test]
    fn ring_allreduce_bitwise_identical_across_ranks() {
        // adversarial floats: results must still be *identical* on all ranks
        let out = run_world(4, |ep| {
            let group: Vec<usize> = (0..4).collect();
            let mut buf: Vec<f32> = (0..33)
                .map(|i| ((ep.rank + 1) as f32 * 1e-3).powi((i % 7) as i32 + 1))
                .collect();
            ep.allreduce_sum(&mut buf, &group).unwrap();
            buf
        });
        for r in 1..4 {
            assert_eq!(out[0], out[r]);
        }
    }

    #[test]
    fn rd_allreduce_matches_ring() {
        let out = run_world(4, |ep| {
            let group: Vec<usize> = (0..4).collect();
            let mut a: Vec<f32> = (0..8).map(|i| (ep.rank + i) as f32).collect();
            let mut b = a.clone();
            ep.allreduce_sum(&mut a, &group).unwrap();
            ep.allreduce_sum_rd(&mut b, &group).unwrap();
            a.extend(b);
            a
        });
        for o in &out {
            let (a, b) = o.split_at(8);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn subgroup_allreduce() {
        // two disjoint groups reduce independently
        let out = run_world(4, |ep| {
            let group: Vec<usize> = if ep.rank < 2 { vec![0, 1] } else { vec![2, 3] };
            let mut buf = vec![ep.rank as f32];
            ep.allreduce_sum(&mut buf, &group).unwrap();
            buf
        });
        assert_eq!(out, vec![vec![1.0], vec![1.0], vec![5.0], vec![5.0]]);
    }

    #[test]
    fn gather_broadcast_barrier() {
        let out = run_world(3, |ep| {
            let group: Vec<usize> = (0..3).collect();
            let gathered = ep.gather_to_root(&[ep.rank as f32], &group).unwrap();
            let val = if let Some(parts) = gathered {
                parts.iter().map(|p| p[0]).sum::<f32>()
            } else {
                0.0
            };
            let out = ep.broadcast(vec![val], &group).unwrap();
            ep.barrier(&group).unwrap();
            out
        });
        assert_eq!(out, vec![vec![3.0]; 3]);
    }

    #[test]
    fn allgather_order() {
        let out = run_world(3, |ep| {
            let group = [2usize, 0, 1]; // deliberately permuted group order
            let parts = ep.allgather(&[ep.rank as f32 * 2.0], &group).unwrap();
            parts.into_iter().flatten().collect()
        });
        for o in out {
            assert_eq!(o, vec![4.0, 0.0, 2.0]);
        }
    }

    #[test]
    fn counters_accumulate() {
        let mut eps = world(2);
        let c = eps[0].counters.clone();
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        thread::scope(|s| {
            s.spawn(move || e0.send(1, vec![0.0; 100]));
            s.spawn(move || {
                e1.recv(0).unwrap();
            });
        });
        assert_eq!(c.bytes(), 400);
        assert_eq!(c.messages(), 1);
    }

    #[test]
    fn prop_chunk_bounds_cover() {
        prop::check("chunk-cover", 200, |g| {
            let len = g.usize_in(0, 200);
            let parts = g.usize_in(1, 17);
            let mut end = 0;
            for i in 0..parts {
                let (lo, hi) = chunk_bounds(len, parts, i);
                if lo != end || hi < lo {
                    return Err(format!("gap at chunk {i}: ({lo},{hi}) end={end}"));
                }
                end = hi;
            }
            if end != len {
                return Err(format!("cover ended at {end} != {len}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_ring_allreduce_random_groups() {
        prop::check("ring-random", 12, |g| {
            let n = g.usize_in(2, 6);
            let len = g.usize_in(1, 40);
            let vals: Vec<Vec<f32>> =
                (0..n).map(|_| g.vec_f32(len, 1.0)).collect();
            let expect: Vec<f32> = (0..len)
                .map(|i| vals.iter().map(|v| v[i]).sum())
                .collect();
            let eps = world(n);
            let out: Vec<Vec<f32>> = thread::scope(|s| {
                let hs: Vec<_> = eps
                    .into_iter()
                    .zip(&vals)
                    .map(|(ep, v)| {
                        let group: Vec<usize> = (0..n).collect();
                        let mut buf = v.clone();
                        s.spawn(move || {
                            ep.allreduce_sum(&mut buf, &group).unwrap();
                            buf
                        })
                    })
                    .collect();
                hs.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (r, o) in out.iter().enumerate() {
                for i in 0..len {
                    if (o[i] - expect[i]).abs() > 1e-4 * expect[i].abs().max(1.0) {
                        return Err(format!("rank {r} elt {i}: {} != {}", o[i],
                                           expect[i]));
                    }
                }
            }
            Ok(())
        });
    }
}
