//! Bucketed gradient allreduce with backprop overlap.
//!
//! The paper hides the data-parallel gradient allreduce behind the
//! backward pass (Fig. 6: the "Allreduce" stream starts as each layer's
//! backward-filter kernel completes). This module is the functional
//! analogue: parameter gradients are partitioned into fixed-size
//! [`BucketPlan`] buckets **in reverse parameter order** (backward
//! produces the last layers' gradients first), and each bucket's ring
//! allreduce is launched on a dedicated per-rank worker thread the moment
//! its last parameter's backward contribution lands — instead of one
//! blocking allreduce over the whole flattened gradient at the end of the
//! step.
//!
//! The worker owns a second [`Communicator`] world (the analogue of a
//! dedicated NCCL stream/communicator), so gradient traffic never
//! interleaves with the compute world's halo/BN messages. Bucket launch
//! order is a deterministic function of the (identical) plan walk, so the
//! ring collectives line up across ranks, and each bucket's result is
//! bit-identical on every rank.

use super::{CommBackend, Communicator};
use crate::comm::Counters;
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Default bucket capacity: 64 Ki f32 elements (256 KiB), roughly the
/// paper's per-layer gradient granularity for the miniaturized models.
pub const DEFAULT_BUCKET_ELEMS: usize = 1 << 16;

/// Gradient aggregation strategy of the training engines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradReduce {
    /// One blocking ring allreduce over the flattened gradients after the
    /// whole backward pass (the pre-overlap baseline).
    Monolithic,
    /// Bucketed allreduce overlapped with backward on a worker thread.
    Bucketed { bucket_elems: usize },
    /// Bucketed overlap whose per-bucket allreduce is the two-level
    /// intra-node/inter-node [`allreduce_sum_hier`](super::hier::allreduce_sum_hier)
    /// (`--ranks-per-node` > 1 on the socket backend). Deterministic and
    /// rank-identical like [`GradReduce::Bucketed`], but with a different
    /// reduction *order*, so trajectories are not bitwise comparable to
    /// the flat-ring strategies — which is why it is opt-in.
    Hier { bucket_elems: usize, ranks_per_node: usize },
}

impl Default for GradReduce {
    fn default() -> Self {
        GradReduce::Bucketed { bucket_elems: DEFAULT_BUCKET_ELEMS }
    }
}

impl GradReduce {
    /// Build the per-rank gradient-world endpoints this strategy needs: a
    /// dedicated world (the analogue of a separate NCCL communicator, so
    /// gradient traffic never interleaves with compute-world messages) for
    /// the bucketed path, all `None` for the monolithic path.
    pub fn build_grad_world(
        &self,
        backend: &CommBackend,
        n: usize,
    ) -> Result<Vec<Option<Box<dyn Communicator>>>> {
        match self {
            GradReduce::Bucketed { .. } | GradReduce::Hier { .. } => {
                Ok(backend.build_world(n)?.into_iter().map(Some).collect())
            }
            GradReduce::Monolithic => Ok((0..n).map(|_| None).collect()),
        }
    }
}

/// One gradient bucket: a set of parameters packed into one flat buffer.
#[derive(Clone, Debug)]
pub struct Bucket {
    /// Parameter indices, in pack order.
    pub params: Vec<usize>,
    /// Offset of each parameter inside the bucket buffer.
    pub offsets: Vec<usize>,
    /// Total f32 elements in the bucket.
    pub elems: usize,
}

/// Partition of the parameter list into buckets.
#[derive(Clone, Debug)]
pub struct BucketPlan {
    pub buckets: Vec<Bucket>,
    /// param index -> (bucket index, offset in bucket)
    locations: Vec<(usize, usize)>,
    param_sizes: Vec<usize>,
}

impl BucketPlan {
    /// Greedy fixed-capacity packing of `param_sizes` (f32 elements per
    /// parameter) in **reverse** parameter order, so bucket 0 fills first
    /// during a reverse-plan backward walk. A parameter larger than
    /// `bucket_elems` gets a bucket of its own; every bucket holds at
    /// least one parameter.
    pub fn new(param_sizes: &[usize], bucket_elems: usize) -> BucketPlan {
        let cap = bucket_elems.max(1);
        let mut buckets: Vec<Bucket> = Vec::new();
        let mut cur = Bucket { params: Vec::new(), offsets: Vec::new(), elems: 0 };
        for pi in (0..param_sizes.len()).rev() {
            let sz = param_sizes[pi];
            if !cur.params.is_empty() && cur.elems + sz > cap {
                buckets.push(std::mem::replace(
                    &mut cur,
                    Bucket { params: Vec::new(), offsets: Vec::new(), elems: 0 },
                ));
            }
            cur.offsets.push(cur.elems);
            cur.params.push(pi);
            cur.elems += sz;
        }
        if !cur.params.is_empty() {
            buckets.push(cur);
        }
        let mut locations = vec![(0usize, 0usize); param_sizes.len()];
        for (bi, b) in buckets.iter().enumerate() {
            for (k, &pi) in b.params.iter().enumerate() {
                locations[pi] = (bi, b.offsets[k]);
            }
        }
        BucketPlan { buckets, locations, param_sizes: param_sizes.to_vec() }
    }

    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }

    pub fn n_params(&self) -> usize {
        self.locations.len()
    }

    /// (bucket index, offset) of a parameter.
    pub fn locate(&self, param: usize) -> (usize, usize) {
        self.locations[param]
    }
}

/// What the per-step drain observed.
#[derive(Clone, Copy, Debug, Default)]
pub struct OverlapReport {
    /// Wall-clock seconds the calling (compute) thread spent blocked in
    /// [`OverlapAllreduce::finish`] waiting for bucket results — the
    /// *exposed* (non-overlapped) allreduce time.
    pub exposed_secs: f64,
    /// Worker-side seconds spent inside bucket allreduces this step
    /// (mostly hidden behind backward compute; not wall-clock additive).
    pub worker_secs: f64,
    /// Buckets reduced this step.
    pub buckets: usize,
}

type BucketResult = (usize, Result<Vec<f32>>, f64);

/// Per-rank overlapped gradient allreducer.
///
/// Created once per rank (spawning the worker thread that owns the
/// gradient-world [`Communicator`]), then reused every step:
/// [`param_ready`](OverlapAllreduce::param_ready) during the last
/// micro-batch's backward walk, [`finish`](OverlapAllreduce::finish)
/// after it (which also flushes any parameters the walk never marked, so
/// correctness never depends on complete marking), and
/// [`shutdown`](OverlapAllreduce::shutdown) at the end of training.
pub struct OverlapAllreduce {
    plan: BucketPlan,
    staging: Vec<Option<Vec<f32>>>,
    marked: Vec<bool>,
    launched: Vec<bool>,
    n_launched: usize,
    to_worker: Option<Sender<(usize, Vec<f32>)>>,
    from_worker: Receiver<BucketResult>,
    worker: Option<JoinHandle<()>>,
    counters: Arc<Counters>,
}

impl OverlapAllreduce {
    /// Spawn the worker thread. `comm` is this rank's endpoint into the
    /// dedicated gradient world; `group` is the set of ranks reducing
    /// together (every member must build the same `plan`).
    pub fn start(comm: Box<dyn Communicator>, group: Vec<usize>, plan: BucketPlan)
                 -> OverlapAllreduce {
        OverlapAllreduce::start_with(comm, group, plan, 1)
    }

    /// [`OverlapAllreduce::start`] whose worker reduces each bucket with
    /// the two-level [`allreduce_sum_hier`](super::hier::allreduce_sum_hier)
    /// instead of the flat ring — the [`GradReduce::Hier`] path.
    pub fn start_hier(
        comm: Box<dyn Communicator>,
        group: Vec<usize>,
        plan: BucketPlan,
        ranks_per_node: usize,
    ) -> OverlapAllreduce {
        OverlapAllreduce::start_with(comm, group, plan, ranks_per_node)
    }

    fn start_with(
        comm: Box<dyn Communicator>,
        group: Vec<usize>,
        plan: BucketPlan,
        ranks_per_node: usize,
    ) -> OverlapAllreduce {
        let counters = comm.counters().clone();
        let (to_worker, work_rx) = channel::<(usize, Vec<f32>)>();
        let (res_tx, from_worker) = channel::<BucketResult>();
        let worker = std::thread::Builder::new()
            .name("grad-allreduce".into())
            .spawn(move || {
                while let Ok((b, mut buf)) = work_rx.recv() {
                    let t0 = Instant::now();
                    let res = if ranks_per_node > 1 {
                        crate::comm::hier::allreduce_sum_hier(
                            comm.as_ref(),
                            &mut buf,
                            &group,
                            ranks_per_node,
                        )
                    } else {
                        comm.allreduce_sum(&mut buf, &group)
                    };
                    let dt = t0.elapsed().as_secs_f64();
                    let msg = match res {
                        Ok(()) => (b, Ok(buf), dt),
                        Err(e) => (b, Err(e), dt),
                    };
                    if res_tx.send(msg).is_err() {
                        return; // owner dropped mid-step
                    }
                }
            })
            .expect("spawn gradient allreduce worker");
        let n = plan.n_buckets();
        let n_params = plan.n_params();
        OverlapAllreduce {
            plan,
            staging: (0..n).map(|_| None).collect(),
            marked: vec![false; n_params],
            launched: vec![false; n],
            n_launched: 0,
            to_worker: Some(to_worker),
            from_worker,
            worker: Some(worker),
            counters,
        }
    }

    /// Per-rank entry point for the engines: start the overlap worker when
    /// the strategy is bucketed and [`GradReduce::build_grad_world`] built
    /// this rank a gradient-world endpoint, `None` otherwise.
    pub fn for_rank(
        reduce: GradReduce,
        grad_ep: Option<Box<dyn Communicator>>,
        group: Vec<usize>,
        param_sizes: &[usize],
    ) -> Option<OverlapAllreduce> {
        match (reduce, grad_ep) {
            (GradReduce::Bucketed { bucket_elems }, Some(ep)) => {
                let plan = BucketPlan::new(param_sizes, bucket_elems);
                Some(OverlapAllreduce::start(ep, group, plan))
            }
            (GradReduce::Hier { bucket_elems, ranks_per_node }, Some(ep)) => {
                let plan = BucketPlan::new(param_sizes, bucket_elems);
                Some(OverlapAllreduce::start_hier(ep, group, plan, ranks_per_node))
            }
            _ => None,
        }
    }

    pub fn plan(&self) -> &BucketPlan {
        &self.plan
    }

    /// Gradient-world traffic counters (for `TrainReport::comm_bytes`).
    pub fn counters(&self) -> &Arc<Counters> {
        &self.counters
    }

    /// Mark a parameter's gradient as final and copy it into its bucket;
    /// launches the bucket's allreduce once all member parameters are in.
    /// Must be called in the same order on every rank of the group.
    pub fn param_ready(&mut self, param: usize, grad: &[f32]) {
        if self.marked[param] {
            return;
        }
        let (b, off) = self.plan.locate(param);
        assert!(
            !self.launched[b],
            "param {param} marked ready after bucket {b} launched"
        );
        debug_assert_eq!(grad.len(), self.plan.param_sizes[param]);
        let elems = self.plan.buckets[b].elems;
        let buf = self.staging[b].get_or_insert_with(|| vec![0.0; elems]);
        buf[off..off + grad.len()].copy_from_slice(grad);
        self.marked[param] = true;
        let bucket = &self.plan.buckets[b];
        if bucket.params.iter().all(|&pi| self.marked[pi]) {
            self.launch(b);
        }
    }

    fn launch(&mut self, b: usize) {
        let buf = self.staging[b].take().expect("bucket staging missing");
        self.launched[b] = true;
        self.n_launched += 1;
        if let Some(tx) = &self.to_worker {
            // A send failure means the worker died; finish() will surface it.
            let _ = tx.send((b, buf));
        }
    }

    /// Flush unmarked parameters from `grads`, drain all bucket results
    /// back into `grads`, and reset for the next step.
    pub fn finish(&mut self, grads: &mut [Tensor]) -> Result<OverlapReport> {
        for pi in 0..self.plan.n_params() {
            if !self.marked[pi] {
                self.param_ready(pi, grads[pi].data());
            }
        }
        let t0 = Instant::now();
        let mut worker_secs = 0.0;
        let mut completed = 0;
        while completed < self.n_launched {
            let (b, res, secs) = self.from_worker.recv().map_err(|_| {
                anyhow!("gradient allreduce worker terminated unexpectedly")
            })?;
            let buf = res?;
            worker_secs += secs;
            let bucket = &self.plan.buckets[b];
            for (k, &pi) in bucket.params.iter().enumerate() {
                let off = bucket.offsets[k];
                let n = grads[pi].numel();
                grads[pi].data_mut().copy_from_slice(&buf[off..off + n]);
            }
            // Keep the bucket buffer staged for the next step: offsets
            // cover it contiguously and every member param is re-copied
            // before launch, so reuse is safe and steady-state steps
            // allocate no staging storage.
            self.staging[b] = Some(buf);
            completed += 1;
        }
        let report = OverlapReport {
            exposed_secs: t0.elapsed().as_secs_f64(),
            worker_secs,
            buckets: completed,
        };
        self.marked.fill(false);
        self.launched.fill(false);
        self.n_launched = 0;
        Ok(report)
    }

    /// Stop and join the worker thread.
    pub fn shutdown(mut self) -> Result<()> {
        drop(self.to_worker.take());
        if let Some(h) = self.worker.take() {
            h.join()
                .map_err(|_| anyhow!("gradient allreduce worker panicked"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::world;
    use std::thread;

    #[test]
    fn bucket_plan_covers_params_in_reverse_order() {
        let sizes = [10usize, 200, 3, 50, 50];
        let plan = BucketPlan::new(&sizes, 64);
        // every param in exactly one bucket, offsets consistent
        let mut seen = vec![0usize; sizes.len()];
        for (bi, b) in plan.buckets.iter().enumerate() {
            assert!(!b.params.is_empty());
            let mut off = 0;
            for (k, &pi) in b.params.iter().enumerate() {
                seen[pi] += 1;
                assert_eq!(b.offsets[k], off);
                assert_eq!(plan.locate(pi), (bi, off));
                off += sizes[pi];
            }
            assert_eq!(off, b.elems);
        }
        assert!(seen.iter().all(|&c| c == 1));
        // reverse order: bucket 0 starts with the last parameter
        assert_eq!(plan.buckets[0].params[0], sizes.len() - 1);
        // oversized param 1 (200 > 64) sits alone in its bucket
        let (b1, _) = plan.locate(1);
        assert_eq!(plan.buckets[b1].params, vec![1]);
    }

    #[test]
    fn single_param_single_bucket() {
        let plan = BucketPlan::new(&[7], 4);
        assert_eq!(plan.n_buckets(), 1);
        assert_eq!(plan.locate(0), (0, 0));
    }

    /// Bucketed allreduce over 3 ranks: results match the direct sum and
    /// are bit-identical across ranks.
    #[test]
    fn overlapped_allreduce_matches_sum() {
        let n = 3;
        let sizes = vec![5usize, 17, 2, 9];
        let plan = BucketPlan::new(&sizes, 16);
        let grad_world = world(n);
        let outs: Vec<Vec<Vec<f32>>> = thread::scope(|s| {
            let hs: Vec<_> = grad_world
                .into_iter()
                .enumerate()
                .map(|(r, ep)| {
                    let plan = plan.clone();
                    let sizes = sizes.clone();
                    s.spawn(move || {
                        let group: Vec<usize> = (0..n).collect();
                        let mut ov =
                            OverlapAllreduce::start(Box::new(ep), group, plan);
                        let mut grads: Vec<Tensor> = sizes
                            .iter()
                            .enumerate()
                            .map(|(pi, &sz)| {
                                Tensor::from_vec(
                                    &[sz],
                                    (0..sz)
                                        .map(|i| (r * 100 + pi * 10 + i) as f32)
                                        .collect(),
                                )
                            })
                            .collect();
                        // mark in reverse order, like a backward walk
                        for pi in (0..sizes.len()).rev() {
                            let data = grads[pi].data().to_vec();
                            ov.param_ready(pi, &data);
                        }
                        let rep = ov.finish(&mut grads).unwrap();
                        assert_eq!(rep.buckets, ov.plan().n_buckets());
                        ov.shutdown().unwrap();
                        grads.into_iter().map(Tensor::into_vec).collect::<Vec<_>>()
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (pi, &sz) in sizes.iter().enumerate() {
            for i in 0..sz {
                let want: f32 =
                    (0..n).map(|r| (r * 100 + pi * 10 + i) as f32).sum();
                assert_eq!(outs[0][pi][i], want, "param {pi} elt {i}");
            }
        }
        for r in 1..n {
            assert_eq!(outs[0], outs[r], "rank {r} diverged bitwise");
        }
    }

    /// finish() without any param_ready call degrades to a correct
    /// (pipelined) bucketed allreduce.
    #[test]
    fn finish_flushes_unmarked_params() {
        let n = 2;
        let plan = BucketPlan::new(&[4, 4], 4);
        let grad_world = world(n);
        let outs: Vec<Vec<f32>> = thread::scope(|s| {
            let hs: Vec<_> = grad_world
                .into_iter()
                .enumerate()
                .map(|(r, ep)| {
                    let plan = plan.clone();
                    s.spawn(move || {
                        let group: Vec<usize> = (0..n).collect();
                        let mut ov =
                            OverlapAllreduce::start(Box::new(ep), group, plan);
                        let mut grads =
                            vec![Tensor::from_vec(&[4], vec![r as f32 + 1.0; 4]); 2];
                        ov.finish(&mut grads).unwrap();
                        // reusable across steps: run a second step
                        let mut grads2 =
                            vec![Tensor::from_vec(&[4], vec![2.0 * r as f32; 4]); 2];
                        ov.finish(&mut grads2).unwrap();
                        ov.shutdown().unwrap();
                        let mut out = grads[0].data().to_vec();
                        out.extend_from_slice(grads2[0].data());
                        out
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for o in &outs {
            assert_eq!(&o[..4], &[3.0; 4]); // 1 + 2
            assert_eq!(&o[4..], &[2.0; 4]); // 0 + 2
        }
    }
}
