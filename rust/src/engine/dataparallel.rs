//! The fused data-parallel engine — the baseline regime the paper scales
//! beyond (§II-A1).
//!
//! Each rank executes the whole-model `train_step` AOT executable
//! (`jax.value_and_grad` over the fused graph) on its local micro-batch and
//! allreduces gradients over all ranks. Also hosts [`predict_batch`], the
//! shared evaluation path for both engines.

use super::optim::Adam;
use super::{
    dropout_mask, init_params, sample_schedule_epochs, LrSchedule, PhaseTimes,
    StepRecord, TrainReport, BN_MOMENTUM,
};
use crate::comm::{CommBackend, Communicator, GradReduce, OverlapAllreduce};
use crate::runtime::checkpoint::{self, CheckpointCfg};
use crate::runtime::{ModelInfo, RuntimeHandle};
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::sync::Arc;
use std::time::Instant;

/// Options for a fused data-parallel run.
#[derive(Clone, Debug)]
pub struct FusedOpts {
    pub model: String,
    pub groups: usize,
    pub batch_global: usize,
    pub steps: usize,
    pub seed: u64,
    pub schedule: LrSchedule,
    pub log_every: usize,
    /// Checkpoint/restart configuration; `None` trains without snapshots.
    pub ckpt: Option<CheckpointCfg>,
}

/// The fused engine's checkpoint fingerprint: no spatial grid, world ==
/// groups (one rank per group).
fn ckpt_fingerprint(opts: &FusedOpts) -> checkpoint::Fingerprint {
    checkpoint::Fingerprint {
        model: opts.model.clone(),
        grid: "1x1x1".to_string(),
        groups: opts.groups,
        batch_global: opts.batch_global,
        steps: opts.steps,
        seed: opts.seed,
        world: opts.groups,
    }
}

/// Full-sample source for the fused path (inputs NCDHW, targets (1, n) or
/// one-hot (1, K, D, H, W)).
pub struct FullSource {
    pub inputs: Vec<Tensor>,
    pub targets: Vec<Tensor>,
}

/// Train with `groups` fused data-parallel ranks on the default channel
/// backend with bucketed gradient allreduce.
pub fn train_fused(
    rt: &RuntimeHandle,
    opts: &FusedOpts,
    source: Arc<FullSource>,
) -> Result<TrainReport> {
    train_fused_with(rt, opts, source, &CommBackend::Channel, GradReduce::default())
}

/// [`train_fused`] with an explicit communicator backend and gradient
/// aggregation strategy.
pub fn train_fused_with(
    rt: &RuntimeHandle,
    opts: &FusedOpts,
    source: Arc<FullSource>,
    backend: &CommBackend,
    reduce: GradReduce,
) -> Result<TrainReport> {
    let info = Arc::new(rt.manifest().model(&opts.model)?.clone());
    if opts.batch_global % opts.groups != 0 {
        bail!("batch {} not divisible by {} groups", opts.batch_global, opts.groups);
    }
    let bpg = opts.batch_global / opts.groups;
    if bpg % info.fused.batch != 0 {
        bail!("per-rank batch {bpg} must be a multiple of the fused batch {}",
              info.fused.batch);
    }
    let sched = Arc::new(sample_schedule_epochs(opts.seed, source.inputs.len(),
                                                opts.batch_global, opts.steps));
    // resolved once, before any rank thread spawns, so all groups agree
    let start_step = match &opts.ckpt {
        Some(c) if c.resume => {
            checkpoint::resolve_resume(&c.dir, &ckpt_fingerprint(opts))?
                .unwrap_or(0)
        }
        _ => 0,
    };
    let endpoints = backend.build_world(opts.groups)?;
    let grad_eps = reduce.build_grad_world(backend, opts.groups)?;
    // world-shared counters: read only after every rank joins (a rank
    // reading them at its own finish races its peers' final sends)
    let comm_counters = endpoints[0].counters().clone();
    let grad_counters =
        grad_eps.iter().flatten().next().map(|ep| ep.counters().clone());

    let reports: Vec<Result<TrainReport>> = std::thread::scope(|s| {
        endpoints
            .into_iter()
            .zip(grad_eps)
            .enumerate()
            .map(|(g, (ep, grad_ep))| {
                let rt = rt.clone();
                let info = info.clone();
                let source = source.clone();
                let sched = sched.clone();
                let opts = opts.clone();
                s.spawn(move || -> Result<TrainReport> {
                    run_group(g, ep, grad_ep, reduce, rt, info, source, sched,
                              opts, start_step)
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().expect("rank panicked"))
            .collect()
    });
    let mut out = None;
    for (g, rep) in reports.into_iter().enumerate() {
        let rep = rep.with_context(|| format!("group {g}"))?;
        if g == 0 {
            out = Some(rep);
        }
    }
    let mut out = out.unwrap();
    out.comm_bytes = comm_counters.bytes()
        + grad_counters.as_ref().map(|c| c.bytes()).unwrap_or(0);
    out.socket_frame_bytes = comm_counters.socket_frame_bytes()
        + grad_counters.map(|c| c.socket_frame_bytes()).unwrap_or(0);
    Ok(out)
}

#[allow(clippy::too_many_arguments)]
fn run_group(
    group: usize,
    ep: Box<dyn Communicator>,
    grad_ep: Option<Box<dyn Communicator>>,
    reduce: GradReduce,
    rt: RuntimeHandle,
    info: Arc<ModelInfo>,
    source: Arc<FullSource>,
    sched: Arc<Vec<Vec<usize>>>,
    opts: FusedOpts,
    start_step: usize,
) -> Result<TrainReport> {
    let world_group: Vec<usize> = (0..opts.groups).collect();
    let bpg = opts.batch_global / opts.groups;
    let fb = info.fused.batch;
    let n_params = info.params.len();
    let n_bn = info.fused.n_bn;
    let bn_chans = info.bn_channels();

    let mut params = init_params(&info, opts.seed);
    let mut adam = Adam::for_params(&params);
    let mut run_mean: Vec<Tensor> = bn_chans.iter().map(|&c| Tensor::zeros(&[c])).collect();
    let mut run_var: Vec<Tensor> =
        bn_chans.iter().map(|&c| Tensor::from_vec(&[c], vec![1.0; c])).collect();
    let mut records = Vec::new();
    let mut phases = PhaseTimes::default();

    // ---- checkpoint/restart ----------------------------------------------
    // One rank per group and no spatial partitioning: the shard geometry is
    // trivial (coords/offsets zero), but the same keyed format and commit
    // protocol as the hybrid engine apply.
    let ckpt_geom = checkpoint::ShardGeom {
        rank: group,
        world: opts.groups,
        group,
        coords: [0; 3],
        shard_off: [0; 3],
        shard_len: [0; 3],
    };
    let ckpt_fp = ckpt_fingerprint(&opts);
    if start_step > 0 {
        let c = opts.ckpt.as_ref().ok_or_else(|| {
            anyhow!("resume step {start_step} without a checkpoint config")
        })?;
        let st = checkpoint::load_shard(&c.dir, start_step, &ckpt_geom)
            .with_context(|| format!("group {group} resume"))?;
        checkpoint::check_shapes(&st, &params, &run_mean)?;
        adam.load_state(st.adam_m, st.adam_v, st.adam_t)?;
        params = st.params;
        run_mean = st.run_mean;
        run_var = st.run_var;
        records = st.records;
    }

    // Bucketed gradient allreduce on a worker thread: in the fused engine
    // the whole backward runs inside one opaque executable, so gradients
    // become final per-parameter only as they are extracted from the last
    // micro-batch's outputs — buckets launch during that extraction and
    // pipeline with the remaining unpacking/EMA work.
    let sizes: Vec<usize> = info.params.iter().map(|(_, s)| s.iter().product()).collect();
    let mut overlap = OverlapAllreduce::for_rank(reduce, grad_ep, world_group.clone(), &sizes);

    // gradient accumulators and the monolithic-allreduce flatten buffer are
    // hoisted out of the step loop: steady-state steps reuse them in place
    let mut grads: Vec<Tensor> =
        info.params.iter().map(|(_, s)| Tensor::zeros(s)).collect();
    let mut flat_scratch: Vec<f32> = Vec::new();

    for step in start_step..opts.steps {
        let lr = opts.schedule.at(step);
        for g in grads.iter_mut() {
            g.data_mut().fill(0.0);
        }
        let mut loss_acc = 0.0f32;

        // micro-batches of the fused executable's lowered batch size
        for mb in 0..bpg / fb {
            let t0 = Instant::now();
            let slots: Vec<usize> =
                (0..fb).map(|i| group * bpg + mb * fb + i).collect();
            let samples: Vec<usize> = slots.iter().map(|&s| sched[step][s]).collect();
            let x = stack_batch(&samples.iter().map(|&s| &source.inputs[s])
                                .collect::<Vec<_>>());
            let tgt = stack_batch(&samples.iter().map(|&s| &source.targets[s])
                                  .collect::<Vec<_>>());
            phases.io += t0.elapsed().as_secs_f64();

            let mut inputs = vec![x, tgt];
            // dropout masks, one row per sample instance
            let fc_widths = mask_widths(&info);
            for layer in 0..info.fused.n_masks {
                let mut rows = Vec::with_capacity(fb * fc_widths[layer]);
                for (i, &slot) in slots.iter().enumerate() {
                    let _ = i;
                    let instance = (step * opts.batch_global + slot) as u64;
                    rows.extend(dropout_mask(opts.seed, instance, layer as u64,
                                             fc_widths[layer],
                                             info.dropout_keep as f32));
                }
                inputs.push(Tensor::from_vec(&[fb, fc_widths[layer]], rows));
            }
            inputs.extend(params.iter().cloned());

            let t = Instant::now();
            let mut out = rt.call(&info.fused.train_step, inputs)?;
            phases.fwd_compute += t.elapsed().as_secs_f64();

            // outputs: loss, grads..., bn means..., bn vars...
            let loss = out.remove(0).item();
            loss_acc += loss / (bpg / fb) as f32;
            let last_mb = mb + 1 == bpg / fb;
            for (gi, g) in out.drain(..n_params).enumerate() {
                let mut g = g;
                g.scale(1.0 / (bpg / fb) as f32); // average micro-batches
                grads[gi].add_assign(&g);
                if last_mb {
                    if let Some(ov) = overlap.as_mut() {
                        ov.param_ready(gi, grads[gi].data());
                    }
                }
            }
            for k in 0..n_bn {
                ema(&mut run_mean[k], &out[k], BN_MOMENTUM);
                ema(&mut run_var[k], &out[n_bn + k], BN_MOMENTUM);
            }
        }

        // average over groups: allreduce (shared epilogue) then scale; the
        // scalar loss rides its own tiny allreduce in both strategies.
        let inv_g = 1.0 / opts.groups as f32;
        super::reduce_grads(ep.as_ref(), overlap.as_mut(), &mut grads,
                            &world_group, &mut phases, &mut flat_scratch)?;
        for g in grads.iter_mut() {
            g.scale(inv_g);
        }
        let t = Instant::now();
        let mut lbuf = vec![loss_acc];
        ep.allreduce_sum(&mut lbuf, &world_group)?;
        phases.allreduce += t.elapsed().as_secs_f64();
        let loss_global = lbuf[0] * inv_g;

        let t = Instant::now();
        adam.step(&mut params, &grads, lr);
        phases.optimizer += t.elapsed().as_secs_f64();

        if group == 0 && opts.log_every > 0
            && (step % opts.log_every == 0 || step + 1 == opts.steps)
        {
            eprintln!("[fused x{} {}] step {:>4} loss {:.6} lr {:.2e}",
                      opts.groups, opts.model, step, loss_global, lr);
        }
        records.push(StepRecord { step, loss: loss_global, lr, io_wait: 0.0 });

        // ---- checkpoint save (same commit protocol as the hybrid engine) -
        if let Some(c) = opts.ckpt.as_ref() {
            if checkpoint::due_after(c, step, opts.steps) {
                let t = Instant::now();
                let (adam_m, adam_v, adam_t) = adam.state();
                checkpoint::save_rank(c, &ckpt_fp, &ckpt_geom,
                    &checkpoint::SaveState {
                        next_step: step + 1,
                        adam_t,
                        records: &records,
                        params: &params,
                        adam_m,
                        adam_v,
                        run_mean: &run_mean,
                        run_var: &run_var,
                    })?;
                ep.barrier(&world_group)?;
                if group == 0 {
                    checkpoint::commit(&c.dir, step + 1)?;
                }
                phases.io += t.elapsed().as_secs_f64();
            }
        }
    }

    if let Some(ov) = overlap.take() {
        ov.shutdown()?;
    }
    Ok(TrainReport {
        records,
        params,
        running: (run_mean, run_var),
        phases,
        // world totals are filled in by `train_fused_with` post-join — the
        // counters are world-shared and racy to read per-rank
        comm_bytes: 0,
        halo_bytes: [0; 3],
        io_exposed: 0.0,
        io_overlapped: 0.0,
        ingest_bytes: 0,
        redist_bytes: 0,
        socket_frame_bytes: 0,
    })
}

// ---------------------------------------------------------------------------
// Dry-run schedule extraction (`hydra3d verify`)
// ---------------------------------------------------------------------------

/// Extract the fused data-parallel engine's communication schedule: one
/// rank per group, gradients allreduced via the configured strategy, the
/// scalar loss on its own ring. The fused engine has no spatial
/// partitioning and no store, so the config must be in-memory with a
/// trivial grid.
pub fn dry_run_fused(
    spec: &crate::analysis::ModelSpec,
    cfg: &crate::analysis::VerifyCfg,
) -> Result<crate::analysis::Schedule> {
    use crate::analysis::{Schedule, WorldOps};
    use crate::comm::TraceCollector;
    use crate::engine::hybrid::IoMode;

    if cfg.io != IoMode::InMem {
        bail!("verify: the fused engine is in-memory only (got {:?})", cfg.io);
    }
    if cfg.grid.ways() != 1 {
        bail!("verify: the fused engine has no spatial grid (got {})", cfg.grid);
    }
    if cfg.groups == 0 || cfg.batch_global % cfg.groups != 0 {
        bail!(
            "verify: global batch {} not divisible by {} group(s)",
            cfg.batch_global,
            cfg.groups
        );
    }
    if cfg.steps == 0 || cfg.samples == 0 {
        bail!("verify: steps and samples must be positive");
    }
    let n = cfg.groups;

    let tc_compute = Arc::new(TraceCollector::new());
    let eps = CommBackend::Traced(tc_compute.clone()).build_world(n)?;
    let tc_grad = Arc::new(TraceCollector::new());
    let grad_eps =
        cfg.reduce.build_grad_world(&CommBackend::Traced(tc_grad.clone()), n)?;

    let sizes: Vec<usize> =
        spec.params.iter().map(|(_, s)| s.iter().product()).collect();
    let world_group: Vec<usize> = (0..n).collect();

    std::thread::scope(|s| -> Result<()> {
        let handles: Vec<_> = eps
            .into_iter()
            .zip(grad_eps)
            .map(|(ep, grad_ep)| {
                let sizes = &sizes;
                let world_group = &world_group;
                s.spawn(move || -> Result<()> {
                    let mut overlap = OverlapAllreduce::for_rank(
                        cfg.reduce,
                        grad_ep,
                        world_group.clone(),
                        sizes,
                    );
                    let mut grads: Vec<Tensor> = spec
                        .params
                        .iter()
                        .map(|(_, sh)| Tensor::zeros(sh))
                        .collect();
                    let mut flat_scratch: Vec<f32> = Vec::new();
                    let mut phases = PhaseTimes::default();
                    for _step in 0..cfg.steps {
                        // gradients become final per-parameter as the last
                        // micro-batch's outputs are extracted, in forward
                        // (output) order — mirror run_group's drain loop
                        if let Some(ov) = overlap.as_mut() {
                            for (gi, g) in grads.iter().enumerate() {
                                ov.param_ready(gi, g.data());
                            }
                        }
                        super::reduce_grads(
                            ep.as_ref(),
                            overlap.as_mut(),
                            &mut grads,
                            world_group,
                            &mut phases,
                            &mut flat_scratch,
                        )?;
                        let mut lbuf = vec![0.0f32];
                        ep.allreduce_sum(&mut lbuf, world_group)?;
                    }
                    if let Some(ov) = overlap.take() {
                        ov.shutdown()?;
                    }
                    Ok(())
                })
            })
            .collect();
        for h in handles {
            h.join().map_err(|_| anyhow::anyhow!("dry-run rank panicked"))??;
        }
        Ok(())
    })?;

    let mut worlds = vec![WorldOps {
        name: "compute".to_string(),
        size: n,
        ranks: tc_compute.op_streams(),
    }];
    if !matches!(cfg.reduce, GradReduce::Monolithic) {
        worlds.push(WorldOps {
            name: "grad".to_string(),
            size: n,
            ranks: tc_grad.op_streams(),
        });
    }
    Ok(Schedule { worlds, pool_logs: Vec::new() })
}

/// Stack single-sample tensors (leading dim 1) into a batch.
pub fn stack_batch(parts: &[&Tensor]) -> Tensor {
    assert!(!parts.is_empty());
    let one = parts[0].shape();
    assert_eq!(one[0], 1, "stack_batch expects leading dim 1");
    let mut shape = one.to_vec();
    shape[0] = parts.len();
    let mut data = Vec::with_capacity(shape.iter().product());
    for p in parts {
        assert_eq!(p.shape(), one);
        data.extend_from_slice(p.data());
    }
    Tensor::from_vec(&shape, data)
}

fn mask_widths(info: &ModelInfo) -> Vec<usize> {
    // widths of the dropout-carrying fc layers, in forward order
    info.plan
        .iter()
        .filter_map(|l| match l {
            crate::runtime::LayerDesc::Fc { fout, dropout: true, .. } => Some(*fout),
            _ => None,
        })
        .collect()
}

fn ema(acc: &mut Tensor, x: &Tensor, momentum: f32) {
    for (a, &b) in acc.data_mut().iter_mut().zip(x.data()) {
        *a = momentum * *a + (1.0 - momentum) * b;
    }
}

/// Evaluate the fused `predict` executable on a batch (must match the
/// lowered fused batch size; callers loop over the eval set).
pub fn predict_batch(
    rt: &RuntimeHandle,
    info: &ModelInfo,
    params: &[Tensor],
    running: &(Vec<Tensor>, Vec<Tensor>),
    x: Tensor,
) -> Result<Tensor> {
    let mut inputs = vec![x];
    inputs.extend(params.iter().cloned());
    inputs.extend(running.0.iter().cloned());
    inputs.extend(running.1.iter().cloned());
    Ok(rt.call(&info.fused.predict, inputs)?.remove(0))
}

/// Mean loss of `predict` outputs vs targets (MSE over all elements) — the
/// evaluation metric of Fig. 9.
pub fn eval_mse(
    rt: &RuntimeHandle,
    info: &ModelInfo,
    params: &[Tensor],
    running: &(Vec<Tensor>, Vec<Tensor>),
    inputs: &[Tensor],
    targets: &[Tensor],
) -> Result<f32> {
    let fb = info.fused.batch;
    let mut se = 0.0f64;
    let mut n = 0usize;
    let mut i = 0;
    while i + fb <= inputs.len() {
        let x = stack_batch(&inputs[i..i + fb].iter().collect::<Vec<_>>());
        let pred = predict_batch(rt, info, params, running, x)?;
        for (j, t) in targets[i..i + fb].iter().enumerate() {
            for (k, &tv) in t.data().iter().enumerate() {
                let pv = pred.data()[j * t.numel() + k];
                se += ((pv - tv) as f64).powi(2);
                n += 1;
            }
        }
        i += fb;
    }
    Ok((se / n.max(1) as f64) as f32)
}
