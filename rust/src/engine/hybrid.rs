//! The hybrid-parallel training engine (the paper's §III-A, functional).
//!
//! Every rank is a thread owning one [`Communicator`] endpoint and a clone
//! of the PJRT [`RuntimeHandle`]. Ranks form `groups x grid.ways()` (data
//! x spatial): each sample group holds one sample partitioned over a full
//! `D×H×W` process grid ([`SpatialGrid`]; `d×1×1` is the classic depth
//! split) and walks the per-layer shard executables of the AOT manifest in
//! lockstep, with
//!
//! * **halo exchanges** around every conv — a fused pack/exchange/unpack
//!   over all partitioned axes into one pooled padded buffer, bit-identical
//!   to the sequential per-axis composition (exact for separable "same"
//!   padding, [`crate::comm::halo`]),
//! * **distributed batch-norm**: (sum, sumsq, count) partials allreduced
//!   over all ranks of the instant batch before `bn_apply`, and the
//!   matching (g1, g2) allreduce in backward,
//! * **gather/scatter at the flatten boundary**: the non-spatial tail (fc,
//!   loss) runs on the group root, exactly like the paper's treatment of
//!   CosmoFlow's fully-connected head ("we ignore the cost of the non-3D
//!   part", §III-C — here it is merely centralized, not ignored),
//! * **gradient allreduce** over the whole world (standard data-parallel
//!   aggregation of the parameter gradients, §III-A) — by default
//!   *bucketed and overlapped with backward*: each bucket's ring allreduce
//!   launches on a per-rank worker thread as soon as its layers' backward
//!   passes complete (the paper's Fig. 6 "Allreduce" stream), leaving only
//!   the tail exposed. `GradReduce::Monolithic` restores the blocking
//!   end-of-step allreduce for comparison.
//!
//! All ranks hold replicated parameters and run the optimizer on the
//! (bit-identical) allreduced gradients, so parameters never diverge.

use super::optim::Adam;
use super::{
    dropout_mask, init_params, sample_schedule_epochs, LrSchedule, PhaseTimes,
    StepRecord, TrainReport, BN_EPS, BN_MOMENTUM, LEAKY_SLOPE,
};
use crate::comm::{
    halo, CommBackend, Communicator, Counters, GradReduce, MsgTag, OverlapAllreduce,
};
use crate::data::container::Container;
use crate::iosim::store::{AsyncStaging, DataStore, StoreSource};
use crate::partition::{GridNeighbors, GridTopology, SpatialGrid};
use crate::runtime::checkpoint::{self, CheckpointCfg};
use crate::runtime::{LayerDesc, ModelInfo, RuntimeHandle};
use crate::tensor::pool::BufferPool;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Fault-injection hook (`HYDRA3D_TEST_DIE_AT_STEP`): step index at which
/// this process aborts abruptly, `usize::MAX` when disarmed. Process-global
/// because the injected failure models a *node* dying, not a rank thread.
static DIE_AT_STEP: AtomicUsize = AtomicUsize::new(usize::MAX);

/// Arm the current process to exit(101) at the top of training step `step`
/// — an abrupt node death for the fault-injection lane (`hydra3d worker`
/// arms this when `HYDRA3D_TEST_DIE_AT_STEP` is set). Steps are absolute,
/// so a resumed world that starts past `step` never re-fires.
pub fn arm_test_die_at_step(step: usize) {
    DIE_AT_STEP.store(step, Ordering::SeqCst);
}

fn maybe_die_at(step: usize, rank: usize) {
    if DIE_AT_STEP.load(Ordering::Relaxed) == step {
        eprintln!("[fault-injection] rank {rank} aborting process at step {step}");
        std::process::exit(101);
    }
}

/// Where a rank's shards come from. The in-memory implementation slices
/// full samples; the I/O pipeline provides a store-backed implementation
/// that reads only the hyperslab (spatially-parallel I/O, §III-B).
///
/// The required methods are depth slabs (the container's contiguous access
/// pattern); the provided `*_shard3` methods serve the 3D-grid engine by
/// reading the depth slab and cropping H/W in memory — sources with
/// finer-grained native access can override them.
pub trait SampleSource: Send + Sync {
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Depth hyperslab `[d0, d0+len)` of the input volume, as (1,C,len,H,W).
    fn input_shard(&self, sample: usize, d0: usize, len: usize) -> Result<Tensor>;
    /// Non-spatial target (CosmoFlow's 4 parameters), as (1, n).
    fn target_full(&self, sample: usize) -> Result<Tensor>;
    /// Depth hyperslab of a spatial one-hot target (U-Net), (1,K,len,H,W).
    fn target_shard(&self, sample: usize, d0: usize, len: usize) -> Result<Tensor>;

    /// (D, H, W) hyperslab of the input volume at `off` with extents
    /// `len`, as (1, C, len[0], len[1], len[2]).
    fn input_shard3(&self, sample: usize, off: [usize; 3], len: [usize; 3])
                    -> Result<Tensor> {
        Ok(crop_hw(self.input_shard(sample, off[0], len[0])?, off, len))
    }

    /// (D, H, W) hyperslab of a spatial one-hot target (U-Net).
    fn target_shard3(&self, sample: usize, off: [usize; 3], len: [usize; 3])
                     -> Result<Tensor> {
        Ok(crop_hw(self.target_shard(sample, off[0], len[0])?, off, len))
    }
}

/// Crop a depth slab's H/W extents to the (D, H, W) hyperslab in one pass
/// (no copy when the slab already matches — the depth-only fast path).
fn crop_hw(slab: Tensor, off: [usize; 3], len: [usize; 3]) -> Tensor {
    let s = slab.shape();
    if off[1] == 0 && len[1] == s[3] && off[2] == 0 && len[2] == s[4] {
        slab
    } else {
        slab.block3([0, off[1], off[2]], len)
    }
}

/// Simple source over fully materialized samples.
pub struct InMemorySource {
    pub inputs: Vec<Tensor>,
    /// (1, n) for CosmoFlow; (1, K, D, H, W) one-hot for U-Net
    pub targets: Vec<Tensor>,
}

impl SampleSource for InMemorySource {
    fn len(&self) -> usize {
        self.inputs.len()
    }
    fn input_shard(&self, sample: usize, d0: usize, len: usize) -> Result<Tensor> {
        Ok(self.inputs[sample].slice_ax(2, d0, len))
    }
    fn target_full(&self, sample: usize) -> Result<Tensor> {
        Ok(self.targets[sample].clone())
    }
    fn target_shard(&self, sample: usize, d0: usize, len: usize) -> Result<Tensor> {
        Ok(self.targets[sample].slice_ax(2, d0, len))
    }
}

/// Options for a hybrid run.
#[derive(Clone, Debug)]
pub struct HybridOpts {
    pub model: String,
    /// Spatial process grid (ways along D, H, W); `SpatialGrid::depth(w)`
    /// is the 1D depth partitioning.
    pub grid: SpatialGrid,
    pub groups: usize,
    pub batch_global: usize,
    pub steps: usize,
    pub seed: u64,
    pub schedule: LrSchedule,
    pub log_every: usize,
    /// Checkpoint/restart configuration (`--checkpoint-every/--checkpoint-dir/
    /// --resume`); `None` trains without snapshots, bit-identical to before
    /// the feature existed (the checkpoint barrier only runs when set).
    pub ckpt: Option<CheckpointCfg>,
}

/// The run-configuration fingerprint a checkpoint of this run carries —
/// everything that pins the deterministic trajectory.
pub(crate) fn ckpt_fingerprint(opts: &HybridOpts, world: usize)
                               -> checkpoint::Fingerprint {
    checkpoint::Fingerprint {
        model: opts.model.clone(),
        grid: opts.grid.to_string(),
        groups: opts.groups,
        batch_global: opts.batch_global,
        steps: opts.steps,
        seed: opts.seed,
        world,
    }
}

/// Resolve the step a (possibly resuming) world starts at. Called once per
/// process *before* any rank thread or staging worker spawns, so every
/// rank — and every node of a socket world — agrees on the same step.
fn resolve_start_step(opts: &HybridOpts, world: usize) -> Result<usize> {
    let Some(c) = &opts.ckpt else { return Ok(0) };
    if !c.resume {
        return Ok(0);
    }
    let fp = ckpt_fingerprint(opts, world);
    Ok(checkpoint::resolve_resume(&c.dir, &fp)?.unwrap_or(0))
}

/// Where a rank's per-step shards come from — the functional realization
/// of the paper's Fig. 5 I/O matrix (`--io` on the CLI).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoMode {
    /// In-memory / direct source: shards sliced per step, no store.
    InMem,
    /// §III-B data store with *blocking* per-step redistribution on the
    /// compute thread (staging cost fully exposed).
    Store,
    /// §III-B data store with asynchronous double-buffered staging: a
    /// per-rank prefetch worker on a second world stages step `s + 1`
    /// behind step `s`'s compute (only the residual wait is exposed).
    StoreAsync,
}

impl IoMode {
    /// Parse the CLI spelling: `inmem` | `store` | `store-async`.
    pub fn parse(s: &str) -> Result<IoMode> {
        match s {
            "inmem" => Ok(IoMode::InMem),
            "store" => Ok(IoMode::Store),
            "store-async" => Ok(IoMode::StoreAsync),
            other => bail!("unknown --io mode {other:?} (inmem|store|store-async)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            IoMode::InMem => "inmem",
            IoMode::Store => "store",
            IoMode::StoreAsync => "store-async",
        }
    }
}

/// Per-rank I/O driver: serves the step's shards and, for store-backed
/// modes, runs (or awaits) the per-step redistribution.
enum RankIo {
    Shared(Arc<dyn SampleSource>),
    Store(StoreSource),
    StoreAsync(AsyncStaging),
}

/// Ingestion/redistribution totals of one rank's I/O driver.
#[derive(Clone, Copy, Debug, Default)]
struct RankIoStats {
    ingest_bytes: u64,
    redist_bytes: u64,
    overlapped_secs: f64,
}

impl RankIo {
    /// Make this step's shards available. Returns the exposed wall-clock
    /// wait on the compute thread (zero for shared sources).
    fn begin_step(&mut self, ep: &dyn Communicator, row: &[usize]) -> Result<f64> {
        match self {
            RankIo::Shared(_) => Ok(0.0),
            RankIo::Store(src) => {
                let t0 = Instant::now();
                src.begin_step(ep, row)?;
                Ok(t0.elapsed().as_secs_f64())
            }
            RankIo::StoreAsync(a) => a.begin_step(),
        }
    }

    fn input_shard3(&self, sample: usize, off: [usize; 3], len: [usize; 3])
                    -> Result<Tensor> {
        match self {
            RankIo::Shared(s) => s.input_shard3(sample, off, len),
            RankIo::Store(s) => s.input_shard3(sample, off, len),
            RankIo::StoreAsync(a) => a.input_shard3(sample, off, len),
        }
    }

    fn target_full(&self, sample: usize) -> Result<Tensor> {
        match self {
            RankIo::Shared(s) => s.target_full(sample),
            RankIo::Store(s) => s.target_full(sample),
            RankIo::StoreAsync(a) => a.target_full(sample),
        }
    }

    fn target_shard3(&self, sample: usize, off: [usize; 3], len: [usize; 3])
                     -> Result<Tensor> {
        match self {
            RankIo::Shared(s) => s.target_shard3(sample, off, len),
            RankIo::Store(s) => s.target_shard3(sample, off, len),
            RankIo::StoreAsync(a) => a.target_shard3(sample, off, len),
        }
    }

    /// Tear down (joining the staging worker if any) and report totals.
    fn finish(self) -> Result<RankIoStats> {
        match self {
            RankIo::Shared(_) => Ok(RankIoStats::default()),
            RankIo::Store(s) => Ok(RankIoStats {
                ingest_bytes: s.store.ingest_bytes,
                redist_bytes: s.store.redist_bytes,
                overlapped_secs: 0.0,
            }),
            RankIo::StoreAsync(a) => {
                let st = a.shutdown()?;
                Ok(RankIoStats {
                    ingest_bytes: st.ingest_bytes,
                    redist_bytes: st.redist_bytes,
                    overlapped_secs: st.redist_secs,
                })
            }
        }
    }

    /// Counter handle of this driver's staging world, if it runs one (the
    /// async prefetch worker's second world — its traffic is not visible
    /// in the compute world's counters). The handle is world-shared:
    /// totals are only deterministic once every rank has joined, which is
    /// why [`run_world`] reads it, not the ranks themselves.
    fn staging_counters(&self) -> Option<Arc<Counters>> {
        match self {
            RankIo::StoreAsync(a) => Some(a.counters().clone()),
            _ => None,
        }
    }
}

/// Train `opts.model` with `groups x grid.ways()` hybrid parallelism on
/// the default channel backend with bucketed, backprop-overlapped gradient
/// allreduce. Returns rank 0's view (parameters are replicated and
/// identical).
pub fn train_hybrid(
    rt: &RuntimeHandle,
    opts: &HybridOpts,
    source: Arc<dyn SampleSource>,
) -> Result<TrainReport> {
    train_hybrid_with(rt, opts, source, &CommBackend::Channel, GradReduce::default())
}

/// [`train_hybrid`] with an explicit communicator backend and gradient
/// aggregation strategy. All backends and both strategies produce the same
/// training trajectory (up to float reduction-order noise).
pub fn train_hybrid_with(
    rt: &RuntimeHandle,
    opts: &HybridOpts,
    source: Arc<dyn SampleSource>,
    backend: &CommBackend,
    reduce: GradReduce,
) -> Result<TrainReport> {
    let topo = GridTopology::new(opts.groups, opts.grid);
    let sched = Arc::new(sample_schedule_epochs(opts.seed, source.len(),
                                                opts.batch_global, opts.steps));
    let ios: Vec<RankIo> = (0..topo.world_size())
        .map(|_| RankIo::Shared(source.clone()))
        .collect();
    let start_step = resolve_start_step(opts, topo.world_size())?;
    run_world(rt, opts, backend, reduce, sched, ios, start_step)
}

/// Train from a container file through the §III-B store pipeline: each
/// rank ingests only its grid block of its owned samples at epoch 0, then
/// every step's shards come from group-to-group redistribution — blocking
/// ([`IoMode::Store`]) or double-buffered behind compute
/// ([`IoMode::StoreAsync`]). Bit-identical to [`train_hybrid_with`] over an
/// in-memory copy of the same dataset: the store moves bytes, never values.
pub fn train_hybrid_store(
    rt: &RuntimeHandle,
    opts: &HybridOpts,
    container: Arc<Container>,
    mode: IoMode,
    backend: &CommBackend,
    reduce: GradReduce,
) -> Result<TrainReport> {
    let topo = GridTopology::new(opts.groups, opts.grid);
    // validate before ingesting a single byte or spawning a staging worker
    // (run_world re-checks, but by then workers would already be running)
    if opts.batch_global % opts.groups != 0 {
        bail!("batch {} not divisible by {} groups", opts.batch_global, opts.groups);
    }
    let n_samples = container.meta.n_samples;
    let sched = Arc::new(sample_schedule_epochs(opts.seed, n_samples,
                                                opts.batch_global, opts.steps));
    // U-Net-style plans end in a spatially partitioned loss: the store must
    // cache label shards instead of flat targets.
    let info = rt.manifest().model(&opts.model)?;
    let (plan, _) = info.hybrid_plan(&opts.grid)?;
    let label_mode = plan.iter().any(|l| matches!(l, LayerDesc::Xent { .. }));
    // resolve the resume point before any staging worker spawns: the async
    // prefetchers iterate the schedule themselves and must start at the
    // same absolute step as the compute ranks
    let start_step = resolve_start_step(opts, topo.world_size())?;
    let ios: Vec<RankIo> = match mode {
        IoMode::InMem => bail!("IoMode::InMem has no store; use train_hybrid_with \
                                (the container itself is a SampleSource)"),
        IoMode::Store => (0..topo.world_size())
            .map(|r| {
                let store = DataStore::ingest(&container, topo, r, label_mode)?;
                Ok(RankIo::Store(StoreSource::new(store)))
            })
            .collect::<Result<Vec<_>>>()?,
        IoMode::StoreAsync => {
            // staging worker world: the analogue of a dedicated comm stream,
            // so staging traffic never interleaves with halo/BN messages
            let io_eps = backend.build_world(topo.world_size())?;
            io_eps
                .into_iter()
                .enumerate()
                .map(|(r, ep)| {
                    RankIo::StoreAsync(AsyncStaging::start(
                        container.clone(), topo, r, label_mode, ep,
                        sched.clone(), opts.groups, start_step,
                    ))
                })
                .collect()
        }
    };
    run_world(rt, opts, backend, reduce, sched, ios, start_step)
}

/// Shared multi-rank driver: spawn one thread per rank over the chosen
/// backend and aggregate the per-rank reports (rank 0's parameters, plus
/// world-summed I/O byte counters and worst-rank staging times).
fn run_world(
    rt: &RuntimeHandle,
    opts: &HybridOpts,
    backend: &CommBackend,
    reduce: GradReduce,
    sched: Arc<Vec<Vec<usize>>>,
    ios: Vec<RankIo>,
    start_step: usize,
) -> Result<TrainReport> {
    let info = Arc::new(rt.manifest().model(&opts.model)?.clone());
    let (plan, pad_axes) = {
        let (p, axes) = info.hybrid_plan(&opts.grid)?;
        (Arc::new(p.clone()), axes)
    };
    if opts.batch_global % opts.groups != 0 {
        bail!("batch {} not divisible by {} groups", opts.batch_global, opts.groups);
    }
    let topo = GridTopology::new(opts.groups, opts.grid);
    assert_eq!(ios.len(), topo.world_size());
    let endpoints = backend.build_world(topo.world_size())?;
    let grad_eps = reduce.build_grad_world(backend, topo.world_size())?;
    // snapshot the world-shared counter handles now and read them only
    // after every rank thread has joined — the one point where the totals
    // are deterministic (a rank reading them during its own teardown races
    // whatever its peers are still sending)
    let comm_counters = endpoints[0].counters().clone();
    let grad_counters =
        grad_eps.iter().flatten().next().map(|ep| ep.counters().clone());
    let staging_counters = ios.iter().find_map(RankIo::staging_counters);

    let reports: Vec<Result<TrainReport>> = std::thread::scope(|s| {
        let handles: Vec<_> = endpoints
            .into_iter()
            .zip(grad_eps)
            .zip(ios)
            .map(|((ep, grad_ep), io)| {
                let rt = rt.clone();
                let info = info.clone();
                let plan = plan.clone();
                let sched = sched.clone();
                let opts = opts.clone();
                s.spawn(move || {
                    run_rank(RankCtx {
                        ep,
                        grad_ep,
                        reduce,
                        topo,
                        pad_axes,
                        rt,
                        info,
                        plan,
                        io,
                        sched,
                        opts,
                        start_step,
                    })
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank panicked")).collect()
    });
    let mut out: Option<TrainReport> = None;
    let (mut ingest, mut redist) = (0u64, 0u64);
    let (mut exposed, mut overlapped) = (0.0f64, 0.0f64);
    for (r, rep) in reports.into_iter().enumerate() {
        let rep = rep.with_context(|| format!("rank {r}"))?;
        ingest += rep.ingest_bytes;
        redist += rep.redist_bytes;
        exposed = exposed.max(rep.io_exposed);
        overlapped = overlapped.max(rep.io_overlapped);
        if r == 0 {
            out = Some(rep);
        }
    }
    let mut out = out.unwrap();
    out.ingest_bytes = ingest;
    out.redist_bytes = redist;
    out.io_exposed = exposed;
    out.io_overlapped = overlapped;
    out.comm_bytes = comm_counters.bytes()
        + grad_counters.as_ref().map(|c| c.bytes()).unwrap_or(0)
        + staging_counters.as_ref().map(|c| c.bytes()).unwrap_or(0);
    out.halo_bytes = comm_counters.halo_bytes_axes();
    out.socket_frame_bytes = comm_counters.socket_frame_bytes()
        + grad_counters.map(|c| c.socket_frame_bytes()).unwrap_or(0)
        + staging_counters.map(|c| c.socket_frame_bytes()).unwrap_or(0);
    Ok(out)
}

/// One node's share of a multi-process `--backend socket` run — what
/// `hydra3d worker` executes after
/// [`connect_node`](crate::comm::socket::connect_node).
///
/// All counters are send-side, so the per-node totals are disjoint: the
/// launcher sums them over nodes and recovers the single-process world
/// totals bit-for-bit (the backend-equivalence gate in
/// `tests/socket_backend.rs`).
pub struct NodeReport {
    /// Rank 0's training view — `Some` only on the node hosting rank 0.
    /// Its byte counters stay zero (this process cannot see remote ranks'
    /// counters); use the node totals below.
    pub report: Option<TrainReport>,
    /// Bytes sent by this node's ranks on the compute + gradient worlds.
    pub comm_bytes: u64,
    /// Halo bytes sent by this node's ranks, per spatial axis.
    pub halo_bytes: [u64; 3],
    /// Inter-node wire bytes framed by this node's ranks
    /// ([`Counters::socket_frame_bytes`]).
    pub socket_frame_bytes: u64,
}

/// Drive [`run_rank`] for one node's local ranks over pre-connected
/// endpoints (multi-process analogue of [`run_world`], in-memory I/O only
/// — every worker regenerates the dataset from the seed, so samples never
/// cross process boundaries outside the engine's own schedule).
///
/// `endpoints` and `grad_eps` are this node's consecutive ranks in world
/// order; `grad_eps[i]` must be `None` exactly when `reduce` is
/// [`GradReduce::Monolithic`] (mirroring
/// [`GradReduce::build_grad_world`]).
pub fn train_hybrid_node(
    rt: &RuntimeHandle,
    opts: &HybridOpts,
    source: Arc<dyn SampleSource>,
    reduce: GradReduce,
    endpoints: Vec<Box<dyn Communicator>>,
    grad_eps: Vec<Option<Box<dyn Communicator>>>,
) -> Result<NodeReport> {
    let info = Arc::new(rt.manifest().model(&opts.model)?.clone());
    let (plan, pad_axes) = {
        let (p, axes) = info.hybrid_plan(&opts.grid)?;
        (Arc::new(p.clone()), axes)
    };
    if opts.batch_global % opts.groups != 0 {
        bail!("batch {} not divisible by {} groups", opts.batch_global, opts.groups);
    }
    let topo = GridTopology::new(opts.groups, opts.grid);
    if endpoints.is_empty() {
        bail!("node hosts no ranks");
    }
    if endpoints.len() != grad_eps.len() {
        bail!("{} endpoints but {} grad endpoints", endpoints.len(), grad_eps.len());
    }
    let sched = Arc::new(sample_schedule_epochs(opts.seed, source.len(),
                                                opts.batch_global, opts.steps));
    // each worker process resolves the resume step independently; the scan
    // is deterministic over a quiescent checkpoint dir, so all nodes of the
    // (re)launched world agree without extra coordination
    let start_step = resolve_start_step(opts, topo.world_size())?;
    // per-process counters: they only ever see this node's ranks, so the
    // post-join read is both deterministic and exactly this node's share
    let comm_counters = endpoints[0].counters().clone();
    let grad_counters =
        grad_eps.iter().flatten().next().map(|ep| ep.counters().clone());

    let reports: Vec<(usize, Result<TrainReport>)> = std::thread::scope(|s| {
        let handles: Vec<_> = endpoints
            .into_iter()
            .zip(grad_eps)
            .map(|(ep, grad_ep)| {
                let rank = ep.rank();
                let rt = rt.clone();
                let info = info.clone();
                let plan = plan.clone();
                let sched = sched.clone();
                let opts = opts.clone();
                let io = RankIo::Shared(source.clone());
                let h = s.spawn(move || {
                    run_rank(RankCtx {
                        ep,
                        grad_ep,
                        reduce,
                        topo,
                        pad_axes,
                        rt,
                        info,
                        plan,
                        io,
                        sched,
                        opts,
                        start_step,
                    })
                });
                (rank, h)
            })
            .collect();
        handles
            .into_iter()
            .map(|(rank, h)| (rank, h.join().expect("rank panicked")))
            .collect()
    });
    let mut report = None;
    for (rank, rep) in reports {
        let rep = rep.with_context(|| format!("rank {rank}"))?;
        if rank == 0 {
            report = Some(rep);
        }
    }
    let comm_bytes = comm_counters.bytes()
        + grad_counters.as_ref().map(|c| c.bytes()).unwrap_or(0);
    let socket_frame_bytes = comm_counters.socket_frame_bytes()
        + grad_counters
            .as_ref()
            .map(|c| c.socket_frame_bytes())
            .unwrap_or(0);
    Ok(NodeReport {
        report,
        comm_bytes,
        halo_bytes: comm_counters.halo_bytes_axes(),
        socket_frame_bytes,
    })
}

struct RankCtx {
    ep: Box<dyn Communicator>,
    grad_ep: Option<Box<dyn Communicator>>,
    reduce: GradReduce,
    topo: GridTopology,
    /// Axes the plan's executables expect halo padding on (depth plans pad
    /// D only; grid plans pad all three).
    pad_axes: [bool; 3],
    rt: RuntimeHandle,
    info: Arc<ModelInfo>,
    plan: Arc<Vec<LayerDesc>>,
    io: RankIo,
    sched: Arc<Vec<Vec<usize>>>,
    opts: HybridOpts,
    /// First step this world executes (0 for fresh runs; the resolved
    /// snapshot step when resuming).
    start_step: usize,
}

/// Parameter indices owned by one plan layer (gradients become final on a
/// rank as soon as this layer's backward pass for the last local sample
/// completes — the bucket-overlap readiness signal). Takes the bare
/// `(name, shape)` parameter table so the dry-run schedule walkers share
/// the exact readiness order the live engine uses.
pub(crate) fn layer_param_indices(
    params: &[(String, Vec<usize>)],
    layer: &LayerDesc,
) -> Vec<usize> {
    let names: Vec<String> = match layer {
        LayerDesc::Conv { tag, .. } | LayerDesc::Deconv { tag, .. } => {
            vec![format!("{tag}.w")]
        }
        LayerDesc::Bn { tag, .. } => {
            vec![format!("{tag}.gamma"), format!("{tag}.beta")]
        }
        LayerDesc::Fc { tag, .. } => vec![format!("{tag}.w"), format!("{tag}.b")],
        _ => Vec::new(),
    };
    names
        .iter()
        .filter_map(|n| params.iter().position(|(p, _)| p == n))
        .collect()
}

/// Per-layer saved forward state for the backward pass.
enum Saved {
    Conv { padded: Tensor },
    Deconv { x: Tensor },
    Pool { x: Tensor, y: Option<Tensor> },
    Bn { x: Tensor, mean: Tensor, var: Tensor, cnt: f32 },
    Act { pre: Tensor },
    Flatten { shard_shape: Vec<usize> },
    Fc { x: Option<Tensor>, pre: Option<Tensor>, mask: Option<Vec<f32>> },
    Skip,
    Concat { c_skip: usize },
    Loss,
}

fn run_rank(mut cx: RankCtx) -> Result<TrainReport> {
    let rank = cx.ep.rank();
    let (group, pos) = cx.topo.coords_of(rank);
    let world_group: Vec<usize> = (0..cx.topo.world_size()).collect();
    let group_ranks = cx.topo.group_ranks(group);
    let nbrs: GridNeighbors = cx.topo.neighbors(rank);
    let grid = cx.opts.grid;
    let ways = grid.ways();
    let is_root = pos == 0;
    let bpg = cx.opts.batch_global / cx.opts.groups;

    // Bucketed overlap: partition the parameter gradients into fixed-size
    // buckets (reverse parameter order == backward completion order) and
    // hand this rank's gradient-world endpoint to a worker thread.
    let sizes: Vec<usize> =
        cx.info.params.iter().map(|(_, s)| s.iter().product()).collect();
    let mut overlap =
        OverlapAllreduce::for_rank(cx.reduce, cx.grad_ep.take(), world_group.clone(), &sizes);

    let mut params = init_params(&cx.info, cx.opts.seed);
    let mut adam = Adam::for_params(&params);
    let bn_chans = cx.info.bn_channels();
    let mut run_mean: Vec<Tensor> =
        bn_chans.iter().map(|&c| Tensor::zeros(&[c])).collect();
    let mut run_var: Vec<Tensor> =
        bn_chans.iter().map(|&c| {
            Tensor::from_vec(&[c], vec![1.0; c])
        }).collect();

    // Even per-axis split of the (cubic) input volume: the AOT shard
    // executables are lowered at a single shard shape, so every axis
    // extent must divide (the simulator's last-shard-takes-remainder
    // geometry does not apply here).
    let size = cx.info.input_size;
    let gdims = grid.dims();
    for (a, &g) in gdims.iter().enumerate() {
        if g == 0 || size % g != 0 {
            bail!("input {size}^3 not divisible by grid {grid} along axis {a} \
                   (the AOT shard executables need even shards)");
        }
    }
    let shard_len = [size / gdims[0], size / gdims[1], size / gdims[2]];
    let gc = grid.coords(pos);
    let shard_off =
        [gc[0] * shard_len[0], gc[1] * shard_len[1], gc[2] * shard_len[2]];
    let mut records = Vec::new();
    let mut phases = PhaseTimes::default();

    // ---- checkpoint/restart ----------------------------------------------
    // Shards are keyed by the rank's grid geometry (the same hyperslab the
    // store uses), and the resume step was resolved once per process, so a
    // mismatched or torn snapshot fails loudly here instead of diverging.
    let ckpt_geom = checkpoint::ShardGeom {
        rank,
        world: cx.topo.world_size(),
        group,
        coords: gc,
        shard_off,
        shard_len,
    };
    let ckpt_fp = ckpt_fingerprint(&cx.opts, cx.topo.world_size());
    if cx.start_step > 0 {
        let c = cx.opts.ckpt.as_ref().ok_or_else(|| {
            anyhow!("resume step {} without a checkpoint config", cx.start_step)
        })?;
        let st = checkpoint::load_shard(&c.dir, cx.start_step, &ckpt_geom)
            .with_context(|| format!("rank {rank} resume"))?;
        checkpoint::check_shapes(&st, &params, &run_mean)?;
        adam.load_state(st.adam_m, st.adam_v, st.adam_t)?;
        params = st.params;
        run_mean = st.run_mean;
        run_var = st.run_var;
        records = st.records;
        if rank == 0 && cx.opts.log_every > 0 {
            eprintln!("[hybrid {}x{} {}] resumed from checkpoint step {}",
                      cx.opts.groups, grid, cx.opts.model, cx.start_step);
        }
    }

    // Per-rank buffer pool: halo faces, padded activations, saved
    // pre-activations and gather/scatter staging all cycle through it, so
    // steady-state steps stop allocating on the hot path. Gradient
    // accumulators are hoisted out of the step loop for the same reason.
    let pool = BufferPool::new();
    let mut grads: Vec<Tensor> =
        cx.info.params.iter().map(|(_, s)| Tensor::zeros(s)).collect();
    let mut flat_scratch: Vec<f32> = Vec::new();

    let mut io_exposed_total = 0.0f64;
    for step in cx.start_step..cx.opts.steps {
        maybe_die_at(step, rank);
        let lr = cx.opts.schedule.at(step);
        for g in grads.iter_mut() {
            g.data_mut().fill(0.0);
        }
        let mut loss_local = 0.0f32;

        // ---- staging: make this step's shards available ------------------
        // (collective for the blocking store; a double-buffer swap for the
        // async store; free for shared sources)
        let io_wait = cx.io.begin_step(cx.ep.as_ref(), &cx.sched[step])?;
        phases.io += io_wait;
        io_exposed_total += io_wait;

        for j in 0..bpg {
            let slot = group * bpg + j;
            let sample = cx.sched[step][slot];
            let instance = (step * cx.opts.batch_global + slot) as u64;

            // ---- I/O: fetch only this rank's hyperslab -------------------
            let t0 = Instant::now();
            let x_shard = cx.io.input_shard3(sample, shard_off, shard_len)?;
            phases.io += t0.elapsed().as_secs_f64();

            // ---- forward -------------------------------------------------
            let mut saved: Vec<Saved> = Vec::with_capacity(cx.plan.len());
            let mut skips: HashMap<usize, Tensor> = HashMap::new();
            let mut h = Some(x_shard);
            let mut loss_scale = 1.0f32;
            for layer in cx.plan.iter() {
                match layer {
                    LayerDesc::Conv { tag, halo: hl, fwd, .. } => {
                        let x = h.take().unwrap();
                        let t = Instant::now();
                        let padded = halo::exchange_forward_grid(
                            &cx.ep, &x, *hl, &nbrs, cx.pad_axes, Some(&pool))?;
                        phases.halo += t.elapsed().as_secs_f64();
                        pool.recycle(x);
                        let wi = cx.info.param_index(&format!("{tag}.w"))
                            .ok_or_else(|| anyhow!("no param {tag}.w"))?;
                        let t = Instant::now();
                        let y = cx.rt.call(fwd.as_ref().unwrap(),
                                           vec![padded.clone(), params[wi].clone()])?
                            .remove(0);
                        phases.fwd_compute += t.elapsed().as_secs_f64();
                        saved.push(Saved::Conv { padded });
                        h = Some(y);
                    }
                    LayerDesc::Deconv { tag, fwd, .. } => {
                        let x = h.take().unwrap();
                        let wi = cx.info.param_index(&format!("{tag}.w")).unwrap();
                        let t = Instant::now();
                        let y = cx.rt.call(fwd.as_ref().unwrap(),
                                           vec![x.clone(), params[wi].clone()])?
                            .remove(0);
                        phases.fwd_compute += t.elapsed().as_secs_f64();
                        saved.push(Saved::Deconv { x });
                        h = Some(y);
                    }
                    LayerDesc::Pool { op, fwd, .. } => {
                        let x = h.take().unwrap();
                        let t = Instant::now();
                        let y = cx.rt.call(fwd.as_ref().unwrap(), vec![x.clone()])?
                            .remove(0);
                        phases.fwd_compute += t.elapsed().as_secs_f64();
                        h = Some(y.clone());
                        saved.push(Saved::Pool {
                            x,
                            y: (op == "max").then_some(y),
                        });
                    }
                    LayerDesc::Bn { tag, c, apply, .. } => {
                        let x = h.take().unwrap();
                        // distributed BN: allreduce (s1, s2, cnt) over the
                        // instant batch = every rank in the world.
                        let (s1, s2) = x.channel_stats();
                        let mut buf = Vec::with_capacity(2 * c + 1);
                        buf.extend_from_slice(&s1);
                        buf.extend_from_slice(&s2);
                        buf.push(x.per_channel_count() as f32);
                        let t = Instant::now();
                        cx.ep.allreduce_sum_rd(&mut buf, &world_group)?;
                        phases.allreduce += t.elapsed().as_secs_f64();
                        let cnt = buf[2 * c];
                        let mean: Vec<f32> = buf[..*c].iter().map(|v| v / cnt).collect();
                        let var: Vec<f32> = buf[*c..2 * c]
                            .iter()
                            .zip(&mean)
                            .map(|(s2, m)| s2 / cnt - m * m)
                            .collect();
                        let mean = Tensor::from_vec(&[*c], mean);
                        let var = Tensor::from_vec(&[*c], var);
                        let gi = cx.info.param_index(&format!("{tag}.gamma")).unwrap();
                        let bi = cx.info.param_index(&format!("{tag}.beta")).unwrap();
                        let t = Instant::now();
                        let y = cx.rt.call(apply.as_ref().unwrap(), vec![
                            x.clone(), mean.clone(), var.clone(),
                            params[gi].clone(), params[bi].clone(),
                        ])?.remove(0);
                        phases.fwd_compute += t.elapsed().as_secs_f64();
                        // running stats EMA (identical on every rank)
                        let k = bn_index(&cx.info, tag);
                        ema(&mut run_mean[k], &mean, BN_MOMENTUM);
                        ema(&mut run_var[k], &var, BN_MOMENTUM);
                        saved.push(Saved::Bn { x, mean, var, cnt });
                        h = Some(y);
                    }
                    LayerDesc::Act { .. } => {
                        let x = h.take().unwrap();
                        let mut y = pool.take_tensor(x.shape());
                        x.leaky_relu_into(LEAKY_SLOPE, &mut y);
                        h = Some(y);
                        saved.push(Saved::Act { pre: x });
                    }
                    LayerDesc::SaveSkip { slot, .. } => {
                        skips.insert(*slot, pool.take_clone(h.as_ref().unwrap()));
                        saved.push(Saved::Skip);
                    }
                    LayerDesc::ConcatSkip { slot, c_skip, .. } => {
                        let up_act = h.take().unwrap();
                        let skip = skips.remove(slot)
                            .ok_or_else(|| anyhow!("missing skip {slot}"))?;
                        h = Some(Tensor::concat_c(&skip, &up_act));
                        pool.recycle(skip);
                        pool.recycle(up_act);
                        saved.push(Saved::Concat { c_skip: *c_skip });
                    }
                    LayerDesc::Flatten { .. } => {
                        let x = h.take().unwrap();
                        let shard_shape = x.shape().to_vec();
                        let t = Instant::now();
                        let gathered =
                            cx.ep.gather_to_root_vec(x.into_vec(), &group_ranks)?;
                        phases.halo += t.elapsed().as_secs_f64();
                        // reassemble the (D, H, W) block grid on the root;
                        // the received part buffers feed the pool that the
                        // backward scatter draws its send blocks from
                        h = gathered.map(|parts| {
                            let (c, sd, sh, sw) = (shard_shape[1], shard_shape[2],
                                                   shard_shape[3], shard_shape[4]);
                            let mut full = Tensor::zeros(&[
                                1, c, sd * gdims[0], sh * gdims[1], sw * gdims[2],
                            ]);
                            for (p, part) in parts.into_iter().enumerate() {
                                let pc = grid.coords(p);
                                full.set_block3_from(
                                    [pc[0] * sd, pc[1] * sh, pc[2] * sw],
                                    [sd, sh, sw], &part);
                                pool.put(part);
                            }
                            let flat = full.numel();
                            full.reshape(&[1, flat])
                        });
                        saved.push(Saved::Flatten { shard_shape });
                    }
                    LayerDesc::Fc { tag, fout, act, dropout, fwd, .. } => {
                        if let Some(x) = h.take() {
                            let wi = cx.info.param_index(&format!("{tag}.w")).unwrap();
                            let bi = cx.info.param_index(&format!("{tag}.b")).unwrap();
                            let t = Instant::now();
                            let mut y = cx.rt.call(fwd.as_ref().unwrap(), vec![
                                x.clone(), params[wi].clone(), params[bi].clone(),
                            ])?.remove(0);
                            phases.fwd_compute += t.elapsed().as_secs_f64();
                            let mut pre = None;
                            let mut mask = None;
                            if *act {
                                let mut a = pool.take_tensor(y.shape());
                                y.leaky_relu_into(LEAKY_SLOPE, &mut a);
                                pre = Some(y);
                                y = a;
                            }
                            if *dropout {
                                let layer_id = fc_index(&cx.info, tag) as u64;
                                let m = dropout_mask(cx.opts.seed, instance, layer_id,
                                                     *fout,
                                                     cx.info.dropout_keep as f32);
                                y.mul_assign_slice(&m);
                                mask = Some(m);
                            }
                            saved.push(Saved::Fc { x: Some(x), pre, mask });
                            h = Some(y);
                        } else {
                            saved.push(Saved::Fc { x: None, pre: None, mask: None });
                        }
                    }
                    LayerDesc::Mse { n, fwd_bwd } => {
                        if let Some(pred) = h.take() {
                            let tgt = cx.io.target_full(sample)?;
                            let t = Instant::now();
                            let mut out = cx.rt.call(fwd_bwd.as_ref().unwrap(),
                                                     vec![pred, tgt])?;
                            phases.fwd_compute += t.elapsed().as_secs_f64();
                            let dpred = out.remove(1);
                            let sse = out.remove(0).item();
                            loss_scale =
                                1.0 / (cx.opts.batch_global * n) as f32;
                            loss_local += sse * loss_scale;
                            let mut g = dpred;
                            g.scale(loss_scale);
                            h = Some(g);
                        }
                        saved.push(Saved::Loss);
                    }
                    LayerDesc::Xent { d, h: hh, w, fwd_bwd, .. } => {
                        let logits = h.take().unwrap();
                        let t0 = Instant::now();
                        let tgt =
                            cx.io.target_shard3(sample, shard_off, shard_len)?;
                        phases.io += t0.elapsed().as_secs_f64();
                        let t = Instant::now();
                        let mut out = cx.rt.call(fwd_bwd.as_ref().unwrap(),
                                                 vec![logits, tgt])?;
                        phases.fwd_compute += t.elapsed().as_secs_f64();
                        let dlogits = out.remove(1);
                        let lsum = out.remove(0).item();
                        loss_scale =
                            1.0 / (cx.opts.batch_global * d * hh * w) as f32;
                        loss_local += lsum * loss_scale;
                        let mut g = dlogits;
                        g.scale(loss_scale);
                        h = Some(g);
                        saved.push(Saved::Loss);
                    }
                }
            }

            // ---- backward (reverse plan walk; saved state is consumed so
            // its buffers return to the pool as soon as a layer is done) ----
            let mut dy = h; // gradient w.r.t. the loss input, from above
            let mut dskips: HashMap<usize, Tensor> = HashMap::new();
            for (layer, sv) in cx.plan.iter().zip(saved).rev() {
                match (layer, sv) {
                    (LayerDesc::Mse { .. }, _) | (LayerDesc::Xent { .. }, _) => {}
                    (LayerDesc::Fc { tag, bwd, act, .. },
                     Saved::Fc { x, pre, mask }) => {
                        if let Some(x) = x {
                            let mut g = dy.take().unwrap();
                            if let Some(m) = &mask {
                                g.mul_assign_slice(m);
                            }
                            if *act {
                                let pre = pre.unwrap();
                                pre.leaky_relu_bwd_inplace(&mut g, LEAKY_SLOPE);
                                pool.recycle(pre);
                            }
                            let wi = cx.info.param_index(&format!("{tag}.w")).unwrap();
                            let bi = cx.info.param_index(&format!("{tag}.b")).unwrap();
                            let t = Instant::now();
                            let mut out = cx.rt.call(bwd.as_ref().unwrap(), vec![
                                x, params[wi].clone(), g,
                            ])?;
                            phases.bwd_compute += t.elapsed().as_secs_f64();
                            let db = out.remove(2);
                            let dw = out.remove(1);
                            let dx = out.remove(0);
                            grads[wi].add_assign(&dw);
                            grads[bi].add_assign(&db);
                            dy = Some(dx);
                        }
                    }
                    (LayerDesc::Flatten { .. }, Saved::Flatten { shard_shape }) => {
                        // scatter the flat gradient back to the grid shards;
                        // send blocks come from the pool (fed by the forward
                        // gather), so the root stays allocation-free
                        let t = Instant::now();
                        if is_root {
                            let g = dy.take().unwrap();
                            let (c, sd, sh, sw) = (shard_shape[1], shard_shape[2],
                                                   shard_shape[3], shard_shape[4]);
                            let dfull = g.reshape(&[
                                1, c, sd * gdims[0], sh * gdims[1], sw * gdims[2],
                            ]);
                            let blk = c * sd * sh * sw;
                            for p in (1..ways).rev() {
                                let pc = grid.coords(p);
                                let mut buf = pool.take(blk);
                                dfull.block3_into(
                                    [pc[0] * sd, pc[1] * sh, pc[2] * sw],
                                    [sd, sh, sw], &mut buf);
                                cx.ep.send_tagged(group_ranks[p], buf,
                                                  MsgTag::Scatter);
                            }
                            let mut mine = pool.take_tensor(&shard_shape);
                            dfull.block3_into([0, 0, 0], [sd, sh, sw],
                                              mine.data_mut());
                            pool.recycle(dfull);
                            dy = Some(mine);
                        } else {
                            let buf =
                                cx.ep.recv_tagged(group_ranks[0], MsgTag::Scatter)?;
                            dy = Some(Tensor::from_vec(&shard_shape, buf));
                        }
                        phases.halo += t.elapsed().as_secs_f64();
                    }
                    (LayerDesc::ConcatSkip { slot, .. }, Saved::Concat { c_skip }) => {
                        let g = dy.take().unwrap();
                        let (dskip, dup) = g.split_c(c_skip);
                        pool.recycle(g);
                        dskips.insert(*slot, dskip);
                        dy = Some(dup);
                    }
                    (LayerDesc::SaveSkip { slot, .. }, Saved::Skip) => {
                        let mut g = dy.take().unwrap();
                        if let Some(ds) = dskips.remove(slot) {
                            g.add_assign(&ds);
                            pool.recycle(ds);
                        }
                        dy = Some(g);
                    }
                    (LayerDesc::Act { .. }, Saved::Act { pre }) => {
                        let mut g = dy.take().unwrap();
                        pre.leaky_relu_bwd_inplace(&mut g, LEAKY_SLOPE);
                        pool.recycle(pre);
                        dy = Some(g);
                    }
                    (LayerDesc::Bn { tag, c, bwd_partials, bwd_apply, .. },
                     Saved::Bn { x, mean, var, cnt }) => {
                        let g = dy.take().unwrap();
                        let gi = cx.info.param_index(&format!("{tag}.gamma")).unwrap();
                        let bi = cx.info.param_index(&format!("{tag}.beta")).unwrap();
                        let t = Instant::now();
                        let parts = cx.rt.call(bwd_partials.as_ref().unwrap(), vec![
                            x.clone(), g.clone(), mean.clone(), var.clone(),
                            params[gi].clone(), params[bi].clone(),
                        ])?;
                        phases.bwd_compute += t.elapsed().as_secs_f64();
                        let mut buf = Vec::with_capacity(2 * c);
                        buf.extend_from_slice(parts[0].data());
                        buf.extend_from_slice(parts[1].data());
                        let t = Instant::now();
                        cx.ep.allreduce_sum_rd(&mut buf, &world_group)?;
                        phases.allreduce += t.elapsed().as_secs_f64();
                        let g1 = Tensor::from_vec(&[*c], buf[..*c].to_vec());
                        let g2 = Tensor::from_vec(&[*c], buf[*c..].to_vec());
                        // dgamma/dbeta are already global sums: accumulate
                        // them on world rank 0 only so the final gradient
                        // allreduce does not multiply them by the world size.
                        if rank == 0 {
                            grads[gi].add_assign(&g1);
                            grads[bi].add_assign(&g2);
                        }
                        let t = Instant::now();
                        let dx = cx.rt.call(bwd_apply.as_ref().unwrap(), vec![
                            x, g, mean, var,
                            params[gi].clone(), params[bi].clone(),
                            g1, g2, Tensor::scalar(cnt),
                        ])?.remove(0);
                        phases.bwd_compute += t.elapsed().as_secs_f64();
                        dy = Some(dx);
                    }
                    (LayerDesc::Pool { op, bwd, .. }, Saved::Pool { x, y }) => {
                        let g = dy.take().unwrap();
                        let t = Instant::now();
                        let dx = if op == "max" {
                            cx.rt.call(bwd.as_ref().unwrap(), vec![
                                x, y.unwrap(), g,
                            ])?.remove(0)
                        } else {
                            pool.recycle(x);
                            cx.rt.call(bwd.as_ref().unwrap(), vec![g])?.remove(0)
                        };
                        phases.bwd_compute += t.elapsed().as_secs_f64();
                        dy = Some(dx);
                    }
                    (LayerDesc::Deconv { tag, bwd_data, bwd_filter, .. },
                     Saved::Deconv { x }) => {
                        let g = dy.take().unwrap();
                        let wi = cx.info.param_index(&format!("{tag}.w")).unwrap();
                        let t = Instant::now();
                        let dw = cx.rt.call(bwd_filter.as_ref().unwrap(), vec![
                            x, g.clone(),
                        ])?.remove(0);
                        let dx = cx.rt.call(bwd_data.as_ref().unwrap(), vec![
                            g, params[wi].clone(),
                        ])?.remove(0);
                        phases.bwd_compute += t.elapsed().as_secs_f64();
                        grads[wi].add_assign(&dw);
                        dy = Some(dx);
                    }
                    (LayerDesc::Conv { tag, halo: hl, bwd_data, bwd_filter, .. },
                     Saved::Conv { padded }) => {
                        let g = dy.take().unwrap();
                        let wi = cx.info.param_index(&format!("{tag}.w")).unwrap();
                        let t = Instant::now();
                        let dw = cx.rt.call(bwd_filter.as_ref().unwrap(), vec![
                            padded, g.clone(),
                        ])?.remove(0);
                        grads[wi].add_assign(&dw);
                        let dxp = cx.rt.call(bwd_data.as_ref().unwrap(), vec![
                            g, params[wi].clone(),
                        ])?.remove(0);
                        phases.bwd_compute += t.elapsed().as_secs_f64();
                        let t = Instant::now();
                        let dx = halo::exchange_backward_grid(
                            &cx.ep, dxp, *hl, &nbrs, cx.pad_axes, Some(&pool))?;
                        phases.halo += t.elapsed().as_secs_f64();
                        dy = Some(dx);
                    }
                    _ => bail!("plan/saved mismatch in backward"),
                }
                // bucket-overlap readiness: after the last local sample's
                // backward pass of a layer, its parameter gradients are
                // final — stage them and launch full buckets.
                if j + 1 == bpg {
                    if let Some(ov) = overlap.as_mut() {
                        for pi in layer_param_indices(&cx.info.params, layer) {
                            ov.param_ready(pi, grads[pi].data());
                        }
                    }
                }
            }
            // the input gradient closes the pool cycle: next sample's
            // backward draws its interior buffer from here
            if let Some(d) = dy {
                pool.recycle(d);
            }
            let _ = loss_scale;
        }

        // ---- gradient allreduce over the whole world (ring) --------------
        super::reduce_grads(cx.ep.as_ref(), overlap.as_mut(), &mut grads,
                            &world_group, &mut phases, &mut flat_scratch)?;

        // ---- optimizer (replicated, identical on every rank) -------------
        let t = Instant::now();
        adam.step(&mut params, &grads, lr);
        phases.optimizer += t.elapsed().as_secs_f64();

        // ---- loss for reporting ------------------------------------------
        let mut lbuf = vec![loss_local];
        cx.ep.allreduce_sum(&mut lbuf, &world_group)?;
        if rank == 0 && cx.opts.log_every > 0
            && (step % cx.opts.log_every == 0 || step + 1 == cx.opts.steps)
        {
            eprintln!("[hybrid {}x{} {}] step {:>4} loss {:.6} lr {:.2e}",
                      cx.opts.groups, grid, cx.opts.model, step, lbuf[0], lr);
        }
        records.push(StepRecord { step, loss: lbuf[0], lr, io_wait });

        // ---- checkpoint save (cadence keyed on the absolute step, so an
        // interrupted and a resumed run snapshot — and barrier — at
        // identical points) ------------------------------------------------
        if let Some(c) = cx.opts.ckpt.as_ref() {
            if checkpoint::due_after(c, step, cx.opts.steps) {
                let t = Instant::now();
                let (adam_m, adam_v, adam_t) = adam.state();
                checkpoint::save_rank(c, &ckpt_fp, &ckpt_geom,
                    &checkpoint::SaveState {
                        next_step: step + 1,
                        adam_t,
                        records: &records,
                        params: &params,
                        adam_m,
                        adam_v,
                        run_mean: &run_mean,
                        run_var: &run_var,
                    })?;
                // all shards durable before rank 0 publishes the snapshot
                cx.ep.barrier(&world_group)?;
                if rank == 0 {
                    checkpoint::commit(&c.dir, step + 1)?;
                }
                phases.io += t.elapsed().as_secs_f64();
            }
        }
    }

    if let Some(ov) = overlap.take() {
        ov.shutdown()?;
    }
    let iostats = cx.io.finish()?;
    // byte totals stay zero here: the counters are world-shared, so the
    // caller ([`run_world`] / [`train_hybrid_node`]) fills them in after
    // every rank has joined — the only deterministic read point
    Ok(TrainReport {
        records,
        params,
        running: (run_mean, run_var),
        phases,
        comm_bytes: 0,
        halo_bytes: [0; 3],
        io_exposed: io_exposed_total,
        io_overlapped: iostats.overlapped_secs,
        ingest_bytes: iostats.ingest_bytes,
        redist_bytes: iostats.redist_bytes,
        socket_frame_bytes: 0,
    })
}

fn bn_index(info: &ModelInfo, tag: &str) -> usize {
    info.bn_layers.iter().position(|l| l == tag).expect("unknown bn layer")
}

fn fc_index(_info: &ModelInfo, tag: &str) -> usize {
    // fc layer ordinal from its tag ("fc0", "fc1", ...)
    tag.trim_start_matches("fc").parse().unwrap_or(0)
}

fn ema(acc: &mut Tensor, x: &Tensor, momentum: f32) {
    for (a, &b) in acc.data_mut().iter_mut().zip(x.data()) {
        *a = momentum * *a + (1.0 - momentum) * b;
    }
}

/// Forward-only evaluation under hybrid partitioning is intentionally not
/// implemented separately: evaluation reuses the fused `predict` executable
/// with the hybrid-trained parameters and running statistics (identical
/// semantics; see `dataparallel::predict_batch`).
pub use super::dataparallel::predict_batch;

/// Mean of the BN epsilon/momentum constants is fixed at compile time; keep
/// them consistent with the Python side.
const _: () = {
    assert!(BN_EPS == 1e-5);
};

// ---------------------------------------------------------------------------
// Dry-run schedule extraction (`hydra3d verify`)
// ---------------------------------------------------------------------------

use crate::analysis::{ModelSpec, Schedule, VerifyCfg, WorldOps};
use crate::comm::TraceCollector;
use crate::iosim::store::assignments_of;
use crate::tensor::pool::PoolEvent;

/// Reject configurations [`run_rank`] itself would reject (assertion or
/// bail), with a message naming the offending geometry — the dry run
/// spawns a whole world of threads, and a mid-flight failure on one rank
/// would leave its peers blocked in a receive.
fn dry_validate(spec: &ModelSpec, cfg: &VerifyCfg) -> Result<()> {
    if cfg.groups == 0 {
        bail!("verify: groups must be positive");
    }
    if cfg.batch_global == 0 || cfg.batch_global % cfg.groups != 0 {
        bail!(
            "verify: global batch {} not divisible by {} group(s)",
            cfg.batch_global,
            cfg.groups
        );
    }
    if cfg.steps == 0 {
        bail!("verify: steps must be positive");
    }
    if cfg.samples == 0 {
        bail!("verify: samples must be positive");
    }
    let world = cfg.groups * cfg.grid.ways();
    if spec.has_bn() && world > 1 && !world.is_power_of_two() {
        bail!(
            "verify: BN statistics allreduce (recursive doubling) needs a \
             power-of-two world, got {world}"
        );
    }
    let gd = cfg.grid.dims();
    let pad_axes = if cfg.grid.is_depth_only() {
        [true, false, false]
    } else {
        [true, true, true]
    };
    for (a, &g) in gd.iter().enumerate() {
        if spec.input_size % g != 0 {
            bail!(
                "verify: input extent {} not divisible by grid dim {} on \
                 axis {a}",
                spec.input_size,
                g
            );
        }
    }
    for layer in &spec.plan {
        let (dims, halo) = match layer {
            LayerDesc::Conv { d, h, w, halo, .. } => ([*d, *h, *w], *halo),
            LayerDesc::Flatten { d, h, w, .. } => ([*d, *h, *w], 0),
            _ => continue,
        };
        for a in 0..3 {
            if dims[a] % gd[a] != 0 {
                bail!(
                    "verify: layer extent {} not divisible by grid dim {} \
                     on axis {a}",
                    dims[a],
                    gd[a]
                );
            }
            if pad_axes[a] && dims[a] / gd[a] < halo {
                bail!(
                    "verify: shard extent {} < halo {halo} on axis {a}",
                    dims[a] / gd[a]
                );
            }
        }
    }
    Ok(())
}

/// Extract the hybrid engine's communication schedule for one
/// configuration by dry-running its comm path: real traced channel
/// worlds, real halo/collective/store code, zero-filled buffers of the
/// true shapes — no runtime, no artifacts, no dataset.
///
/// Three worlds may be built, mirroring production exactly: the compute
/// world (halo, BN statistics, flatten gather/scatter, loss, blocking
/// store redistribution), the gradient world (bucketed-overlap
/// allreduces; absent under `GradReduce::Monolithic`, whose single ring
/// runs on the compute world), and the staging world (`StoreAsync`
/// redistribution; the prefetch worker's traffic, run inline here — each
/// schedule row is redistributed exactly once either way, and the checks
/// compare per-endpoint streams, not cross-rank interleavings).
pub fn dry_run_hybrid(spec: &ModelSpec, cfg: &VerifyCfg) -> Result<Schedule> {
    dry_validate(spec, cfg)?;
    let topo = GridTopology::new(cfg.groups, cfg.grid);
    let n = topo.world_size();
    let sched =
        sample_schedule_epochs(cfg.seed, cfg.samples, cfg.batch_global, cfg.steps);

    let tc_compute = Arc::new(TraceCollector::new());
    let eps = CommBackend::Traced(tc_compute.clone()).build_world(n)?;
    let tc_grad = Arc::new(TraceCollector::new());
    let grad_eps =
        cfg.reduce.build_grad_world(&CommBackend::Traced(tc_grad.clone()), n)?;
    let tc_staging = Arc::new(TraceCollector::new());
    let staging_eps: Vec<Option<Box<dyn Communicator>>> =
        if cfg.io == IoMode::StoreAsync {
            CommBackend::Traced(tc_staging.clone())
                .build_world(n)?
                .into_iter()
                .map(Some)
                .collect()
        } else {
            (0..n).map(|_| None).collect()
        };

    let pool_logs = std::thread::scope(|s| -> Result<Vec<Vec<PoolEvent>>> {
        let sched = &sched;
        let handles: Vec<_> = eps
            .into_iter()
            .zip(grad_eps)
            .zip(staging_eps)
            .map(|((ep, gep), sep)| {
                s.spawn(move || dry_rank(spec, cfg, topo, ep, gep, sep, sched))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| anyhow!("dry-run rank panicked"))?)
            .collect()
    })?;

    let mut worlds = vec![WorldOps {
        name: "compute".to_string(),
        size: n,
        ranks: tc_compute.op_streams(),
    }];
    if !matches!(cfg.reduce, GradReduce::Monolithic) {
        worlds.push(WorldOps {
            name: "grad".to_string(),
            size: n,
            ranks: tc_grad.op_streams(),
        });
    }
    if cfg.io == IoMode::StoreAsync {
        worlds.push(WorldOps {
            name: "staging".to_string(),
            size: n,
            ranks: tc_staging.op_streams(),
        });
    }
    Ok(Schedule { worlds, pool_logs })
}

/// One rank of the dry run: every communication op of [`run_rank`], in
/// program order, with the compute elided. Any drift between this walker
/// and `run_rank`'s comm sequence is caught by the artifact-gated parity
/// test in `tests/verify_suite.rs`, which diffs the two traced streams.
fn dry_rank(
    spec: &ModelSpec,
    cfg: &VerifyCfg,
    topo: GridTopology,
    ep: Box<dyn Communicator>,
    grad_ep: Option<Box<dyn Communicator>>,
    staging_ep: Option<Box<dyn Communicator>>,
    sched: &[Vec<usize>],
) -> Result<Vec<PoolEvent>> {
    let rank = ep.rank();
    let n = topo.world_size();
    let (group, pos) = topo.coords_of(rank);
    let world_group: Vec<usize> = (0..n).collect();
    let group_ranks = topo.group_ranks(group);
    let nbrs = topo.neighbors(rank);
    let gd = topo.grid.dims();
    let ways = topo.grid.ways();
    let is_root = pos == 0;
    let bpg = cfg.batch_global / topo.groups;
    let pad_axes = if topo.grid.is_depth_only() {
        [true, false, false]
    } else {
        [true, true, true]
    };

    let sizes: Vec<usize> =
        spec.params.iter().map(|(_, s)| s.iter().product()).collect();
    let mut overlap = OverlapAllreduce::for_rank(
        cfg.reduce,
        grad_ep,
        world_group.clone(),
        &sizes,
    );
    let mut grads: Vec<Tensor> =
        spec.params.iter().map(|(_, s)| Tensor::zeros(s)).collect();
    let mut flat_scratch: Vec<f32> = Vec::new();
    let mut phases = PhaseTimes::default();
    let pool = BufferPool::new();
    pool.enable_log();

    let mut store = match cfg.io {
        IoMode::InMem => None,
        IoMode::Store | IoMode::StoreAsync => Some(DataStore::synthetic(
            topo,
            rank,
            cfg.samples,
            spec.input_size,
            spec.in_channels,
            spec.target_len,
            spec.label_channels,
            spec.label_mode(),
        )?),
    };

    for row in sched.iter() {
        // ---- I/O staging: the store modes' per-step redistribution ------
        if let Some(st) = store.as_mut() {
            let assigns = assignments_of(row, topo.groups);
            match cfg.io {
                IoMode::Store => st.redistribute(ep.as_ref(), &assigns)?,
                IoMode::StoreAsync => {
                    // the async worker's traffic, on its dedicated world
                    let sep = staging_ep.as_ref().expect("staging endpoint");
                    st.redistribute(sep.as_ref(), &assigns)?;
                    let _ = st.take_staged();
                }
                IoMode::InMem => unreachable!(),
            }
        }

        for j in 0..bpg {
            // ---- forward ------------------------------------------------
            for layer in &spec.plan {
                match layer {
                    LayerDesc::Conv { cin, d, h, w, halo, .. } => {
                        let s = [d / gd[0], h / gd[1], w / gd[2]];
                        let x = pool
                            .take_tensor_zeroed(&[1, *cin, s[0], s[1], s[2]]);
                        let padded = halo::exchange_forward_grid(
                            ep.as_ref(),
                            &x,
                            *halo,
                            &nbrs,
                            pad_axes,
                            Some(&pool),
                        )?;
                        pool.recycle(x);
                        pool.recycle(padded);
                    }
                    LayerDesc::Bn { c, .. } => {
                        // (sum, sumsq, count) partials
                        let mut buf = vec![0.0f32; 2 * c + 1];
                        ep.allreduce_sum_rd(&mut buf, &world_group)?;
                    }
                    LayerDesc::Flatten { c, d, h, w } => {
                        let elems =
                            c * (d / gd[0]) * (h / gd[1]) * (w / gd[2]);
                        let mine = pool.take_zeroed(elems);
                        if let Some(parts) =
                            ep.gather_to_root_vec(mine, &group_ranks)?
                        {
                            for part in parts {
                                pool.put(part);
                            }
                        }
                    }
                    _ => {}
                }
            }

            // ---- backward -----------------------------------------------
            for layer in spec.plan.iter().rev() {
                match layer {
                    LayerDesc::Flatten { c, d, h, w } => {
                        let blk =
                            c * (d / gd[0]) * (h / gd[1]) * (w / gd[2]);
                        if is_root {
                            for p in (1..ways).rev() {
                                let buf = pool.take(blk);
                                ep.send_tagged(
                                    group_ranks[p],
                                    buf,
                                    MsgTag::Scatter,
                                );
                            }
                        } else {
                            let buf = ep
                                .recv_tagged(group_ranks[0], MsgTag::Scatter)?;
                            pool.put(buf);
                        }
                    }
                    LayerDesc::Bn { c, .. } => {
                        // (dgamma, dbeta) partials
                        let mut buf = vec![0.0f32; 2 * c];
                        ep.allreduce_sum_rd(&mut buf, &world_group)?;
                    }
                    LayerDesc::Conv { cin, d, h, w, halo, .. } => {
                        let mut pshape =
                            vec![1, *cin, d / gd[0], h / gd[1], w / gd[2]];
                        for a in 0..3 {
                            if pad_axes[a] {
                                pshape[2 + a] += 2 * halo;
                            }
                        }
                        let dxp = pool.take_tensor_zeroed(&pshape);
                        let dx = halo::exchange_backward_grid(
                            ep.as_ref(),
                            dxp,
                            *halo,
                            &nbrs,
                            pad_axes,
                            Some(&pool),
                        )?;
                        pool.recycle(dx);
                    }
                    _ => {}
                }
                // bucket-overlap readiness, exactly as in run_rank
                if j + 1 == bpg {
                    if let Some(ov) = overlap.as_mut() {
                        for pi in layer_param_indices(&spec.params, layer) {
                            ov.param_ready(pi, grads[pi].data());
                        }
                    }
                }
            }
        }

        // ---- gradient allreduce + loss report ---------------------------
        super::reduce_grads(
            ep.as_ref(),
            overlap.as_mut(),
            &mut grads,
            &world_group,
            &mut phases,
            &mut flat_scratch,
        )?;
        let mut lbuf = vec![0.0f32];
        ep.allreduce_sum(&mut lbuf, &world_group)?;
    }

    if let Some(ov) = overlap.take() {
        ov.shutdown()?;
    }
    Ok(pool.take_log())
}
