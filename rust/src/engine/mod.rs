//! The training engines.
//!
//! Two engines share parameter init, optimizer, dropout-mask derivation and
//! LR schedule, so their trajectories are directly comparable:
//!
//! * [`dataparallel`] — the fused path: each rank executes the whole-model
//!   `train_step` AOT executable on its local batch and allreduces
//!   gradients. This is the classic regime the paper scales *beyond*.
//! * [`hybrid`] — the paper's contribution: every sample is spatially
//!   partitioned over a *sample group* on a D×H×W process grid (depth-only
//!   is the `d×1×1` case); convolutions run on halo-exchanged shards (one
//!   face exchange per partitioned axis) through per-layer AOT
//!   executables, batch-norm statistics are allreduced across the whole
//!   instant batch, the non-spatial tail (fc layers) runs on the group
//!   root, and weight gradients are allreduced across all ranks (the green
//!   arrows of the paper's Fig. 2).
//!
//! The core correctness invariant — hybrid(W ways) ≡ hybrid(1 way) ≡ fused
//! for identical seeds — is enforced in `rust/tests/engine_equivalence.rs`.

pub mod dataparallel;
pub mod hybrid;
pub mod optim;

use crate::comm::{Communicator, OverlapAllreduce};
use crate::runtime::ModelInfo;
use crate::tensor::Tensor;
use crate::util::rng::Pcg;
use anyhow::Result;
use std::time::Instant;

/// Leaky-ReLU slope used across both engines (must match kernels/ref.py).
pub const LEAKY_SLOPE: f32 = 0.01;
/// Running-statistics momentum for batch-norm EMA.
pub const BN_MOMENTUM: f32 = 0.9;
/// Batch-norm epsilon (must match kernels/ref.py BN_EPS).
pub const BN_EPS: f32 = 1e-5;

/// Aggregate parameter gradients over `group` (shared by both engines):
/// drain the bucketed-overlap worker when present — only the tail not
/// hidden behind backward is exposed — otherwise run one blocking ring
/// allreduce over the flattened gradients. Either way `grads` ends holding
/// the group-wide sums and `phases` gets the allreduce attribution.
/// `scratch` is the monolithic path's flatten buffer; callers hoist it out
/// of the step loop so steady-state steps reuse one allocation.
pub(crate) fn reduce_grads(
    ep: &dyn Communicator,
    overlap: Option<&mut OverlapAllreduce>,
    grads: &mut [Tensor],
    group: &[usize],
    phases: &mut PhaseTimes,
    scratch: &mut Vec<f32>,
) -> Result<()> {
    match overlap {
        Some(ov) => {
            let rep = ov.finish(grads)?;
            phases.allreduce += rep.exposed_secs;
            phases.allreduce_overlapped += rep.worker_secs;
        }
        None => {
            scratch.clear();
            scratch.reserve(grads.iter().map(|g| g.numel()).sum());
            for g in grads.iter() {
                scratch.extend_from_slice(g.data());
            }
            let t = Instant::now();
            ep.allreduce_sum(scratch, group)?;
            phases.allreduce += t.elapsed().as_secs_f64();
            let mut off = 0;
            for g in grads.iter_mut() {
                let n = g.numel();
                g.data_mut().copy_from_slice(&scratch[off..off + n]);
                off += n;
            }
        }
    }
    Ok(())
}

/// Deterministic parameter initialization from the manifest param table:
/// He-style normals for weights (stream per parameter index), ones for BN
/// gamma, zeros for biases/betas. Identical on every rank by construction.
pub fn init_params(info: &ModelInfo, seed: u64) -> Vec<Tensor> {
    info.params
        .iter()
        .enumerate()
        .map(|(i, (name, shape))| {
            let mut t = Tensor::zeros(shape);
            if name.ends_with(".gamma") {
                t.data_mut().fill(1.0);
            } else if name.ends_with(".w") {
                let fan_in: usize = shape[1..].iter().product::<usize>().max(1);
                let sigma = (1.0 / fan_in as f32).sqrt();
                let mut rng = Pcg::new(seed ^ 0x9a17_u64, i as u64);
                rng.fill_normal(t.data_mut(), sigma);
            } // .b / .beta stay zero
            t
        })
        .collect()
}

/// Deterministic dropout mask for one sample row: values are 0 or 1/keep
/// (pre-scaled, matching the fused graph's mask semantics). Depends only on
/// (seed, sample instance, layer), *not* on rank or partitioning, so every
/// engine configuration draws identical masks.
pub fn dropout_mask(seed: u64, sample_instance: u64, layer: u64, width: usize,
                    keep: f32) -> Vec<f32> {
    let mut rng = Pcg::new(seed ^ 0xD80u64, sample_instance * 97 + layer);
    (0..width)
        .map(|_| if rng.bernoulli(keep as f64) { 1.0 / keep } else { 0.0 })
        .collect()
}

/// Linear learning-rate decay: lr0 at step 0 down to `lr0 * floor_frac` at
/// `total` (the paper's schedule reaches 0.01x at 100 epochs).
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub lr0: f64,
    pub floor_frac: f64,
    pub total_steps: usize,
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f64 {
        if self.total_steps == 0 {
            return self.lr0;
        }
        let p = (step as f64 / self.total_steps as f64).min(1.0);
        self.lr0 * (1.0 - (1.0 - self.floor_frac) * p)
    }
}

/// Epoch-shuffled sample schedule: the sequence of dataset indices consumed
/// by successive steps, identical on every rank (derived from the seed, as
/// the paper's data store computes a global schedule before each epoch).
pub fn sample_schedule(seed: u64, n_samples: usize, batch: usize, steps: usize)
                       -> Vec<Vec<usize>> {
    let mut rng = Pcg::new(seed ^ 0x5C0Fu64, 11);
    let mut order: Vec<usize> = (0..n_samples).collect();
    rng.shuffle(&mut order);
    let mut cursor = 0;
    let mut out = Vec::with_capacity(steps);
    for _ in 0..steps {
        let mut b = Vec::with_capacity(batch);
        for _ in 0..batch {
            if cursor == n_samples {
                rng.shuffle(&mut order);
                cursor = 0;
            }
            b.push(order[cursor]);
            cursor += 1;
        }
        out.push(b);
    }
    out
}

/// Sample order of one epoch: a permutation of `0..n_samples` that depends
/// only on `(seed, epoch)` — any pipeline component (either engine, the
/// data store, the async staging worker) can reproduce an epoch's order
/// independently, which is how the paper's store computes a global shuffle
/// before each epoch (§III-B) without a coordination broadcast.
pub fn epoch_order(seed: u64, epoch: u64, n_samples: usize) -> Vec<usize> {
    let mut rng = Pcg::new(seed ^ 0x5C0Fu64, 0xE90C ^ epoch);
    let mut order: Vec<usize> = (0..n_samples).collect();
    rng.shuffle(&mut order);
    order
}

/// [`sample_schedule`] with per-epoch seeding ([`epoch_order`]): both
/// engines and the store-backed I/O pipeline consume this variant, so the
/// compute schedule and the store's redistribution schedule are one object.
pub fn sample_schedule_epochs(seed: u64, n_samples: usize, batch: usize,
                              steps: usize) -> Vec<Vec<usize>> {
    let mut epoch = 0u64;
    let mut order = epoch_order(seed, epoch, n_samples);
    let mut cursor = 0;
    let mut out = Vec::with_capacity(steps);
    for _ in 0..steps {
        let mut b = Vec::with_capacity(batch);
        for _ in 0..batch {
            if cursor == n_samples {
                epoch += 1;
                order = epoch_order(seed, epoch, n_samples);
                cursor = 0;
            }
            b.push(order[cursor]);
            cursor += 1;
        }
        out.push(b);
    }
    out
}

/// Per-step training record.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub lr: f64,
    /// Exposed (non-overlapped) I/O staging wait this step, seconds: zero
    /// for in-memory sources, the blocking redistribution time for the
    /// synchronous store, the residual double-buffer wait for async
    /// staging (the paper's "I/O waits" stream in Fig. 6).
    pub io_wait: f64,
}

/// Wall-clock breakdown of one engine run (the functional analogue of the
/// paper's Fig. 6 streams).
#[derive(Clone, Debug, Default)]
pub struct PhaseTimes {
    pub fwd_compute: f64,
    pub bwd_compute: f64,
    pub halo: f64,
    /// Wall-clock allreduce time on the compute thread: BN statistics plus
    /// the *exposed* (non-overlapped) part of the gradient allreduce.
    pub allreduce: f64,
    /// Worker-side gradient allreduce seconds hidden behind backward
    /// compute by the bucketed-overlap path (not wall-clock additive, so
    /// excluded from [`PhaseTimes::total`]).
    pub allreduce_overlapped: f64,
    pub io: f64,
    pub optimizer: f64,
}

impl PhaseTimes {
    pub fn total(&self) -> f64 {
        self.fwd_compute + self.bwd_compute + self.halo + self.allreduce + self.io
            + self.optimizer
    }

    pub fn merge_max(&mut self, o: &PhaseTimes) {
        self.fwd_compute = self.fwd_compute.max(o.fwd_compute);
        self.bwd_compute = self.bwd_compute.max(o.bwd_compute);
        self.halo = self.halo.max(o.halo);
        self.allreduce = self.allreduce.max(o.allreduce);
        self.allreduce_overlapped = self.allreduce_overlapped.max(o.allreduce_overlapped);
        self.io = self.io.max(o.io);
        self.optimizer = self.optimizer.max(o.optimizer);
    }
}

/// Outcome of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub records: Vec<StepRecord>,
    pub params: Vec<Tensor>,
    /// running BN statistics (means, vars) per BN layer, for eval
    pub running: (Vec<Tensor>, Vec<Tensor>),
    pub phases: PhaseTimes,
    pub comm_bytes: u64,
    /// Halo-face bytes sent per spatial axis (D, H, W) — zero for the
    /// fused engine, the §III-A per-dimension halo volume for hybrid runs.
    pub halo_bytes: [u64; 3],
    /// Exposed (compute-thread wall-clock) I/O staging seconds, worst rank
    /// — what the step time actually pays for data movement.
    pub io_exposed: f64,
    /// Staging seconds hidden behind compute by the async prefetch worker,
    /// worst rank (not wall-clock additive) — Fig. 5's overlapped I/O.
    pub io_overlapped: f64,
    /// Epoch-0 container ("PFS") ingestion bytes, summed over all ranks:
    /// exactly one copy of the dataset plus one target read per shard
    /// position for store-backed runs, zero for in-memory sources.
    pub ingest_bytes: u64,
    /// Store redistribution bytes, summed over all ranks — the §III-B
    /// group-to-group staging volume (deterministic given seed/topology).
    pub redist_bytes: u64,
    /// Inter-node wire bytes framed by the socket transport (12-byte
    /// header + payload per frame) — zero for every other backend, and
    /// for socket worlds where all traffic stays intra-node.
    pub socket_frame_bytes: u64,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.records.last().map(|r| r.loss).unwrap_or(f32::NAN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_endpoints() {
        let s = LrSchedule { lr0: 1e-3, floor_frac: 0.01, total_steps: 100 };
        assert_eq!(s.at(0), 1e-3);
        assert!((s.at(100) - 1e-5).abs() < 1e-12);
        assert!((s.at(50) - 0.505e-3).abs() < 1e-9);
        assert!((s.at(200) - 1e-5).abs() < 1e-12); // clamped
    }

    #[test]
    fn dropout_mask_deterministic_and_scaled() {
        let a = dropout_mask(1, 5, 0, 1000, 0.8);
        let b = dropout_mask(1, 5, 0, 1000, 0.8);
        assert_eq!(a, b);
        let c = dropout_mask(1, 5, 1, 1000, 0.8);
        assert_ne!(a, c);
        let kept = a.iter().filter(|&&x| x > 0.0).count();
        assert!((kept as f64 / 1000.0 - 0.8).abs() < 0.06, "kept={kept}");
        for &x in &a {
            assert!(x == 0.0 || (x - 1.25).abs() < 1e-6);
        }
    }

    #[test]
    fn schedule_covers_epochs_fairly() {
        let sched = sample_schedule(3, 10, 4, 10); // 40 draws over 10 samples
        let mut counts = [0usize; 10];
        for b in &sched {
            assert_eq!(b.len(), 4);
            for &i in b {
                counts[i] += 1;
            }
        }
        // 4 full epochs: every sample seen exactly 4 times
        assert!(counts.iter().all(|&c| c == 4), "{counts:?}");
    }

    #[test]
    fn epoch_schedule_is_fair_and_independently_seeded() {
        let sched = sample_schedule_epochs(3, 10, 4, 10); // 4 epochs of 10
        let mut counts = [0usize; 10];
        for b in &sched {
            assert_eq!(b.len(), 4);
            for &i in b {
                counts[i] += 1;
            }
        }
        assert!(counts.iter().all(|&c| c == 4), "{counts:?}");
        // the flattened schedule is exactly the concatenated epoch orders,
        // so a detached component can reproduce any epoch on its own
        let flat: Vec<usize> = sched.iter().flatten().copied().collect();
        for e in 0..4u64 {
            assert_eq!(&flat[(e as usize) * 10..(e as usize + 1) * 10],
                       &epoch_order(3, e, 10)[..], "epoch {e}");
        }
        // epochs genuinely reshuffle
        assert_ne!(epoch_order(3, 0, 10), epoch_order(3, 1, 10));
        // and the order depends only on (seed, epoch)
        assert_eq!(epoch_order(3, 2, 10), epoch_order(3, 2, 10));
    }
}
