//! Optimizers. The paper trains with Adam (β1=0.9, β2=0.999, ε=1e-8, §IV).
//!
//! The optimizer runs on the Rust side (replicated on every rank over
//! already-allreduced gradients), mirroring how the paper's framework
//! separates cuDNN compute from framework-side parameter updates.

use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Adam with bias correction.
#[derive(Clone, Debug)]
pub struct Adam {
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    t: u64,
}

impl Adam {
    pub fn new(shapes: &[Vec<usize>], beta1: f64, beta2: f64, eps: f64) -> Adam {
        Adam {
            beta1,
            beta2,
            eps,
            m: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            v: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            t: 0,
        }
    }

    pub fn for_params(params: &[Tensor]) -> Adam {
        let shapes: Vec<Vec<usize>> = params.iter().map(|p| p.shape().to_vec()).collect();
        Adam::new(&shapes, 0.9, 0.999, 1e-8)
    }

    /// One update step: `p -= lr * m_hat / (sqrt(v_hat) + eps)`.
    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f64) {
        assert_eq!(params.len(), grads.len());
        self.t += 1;
        let b1 = self.beta1 as f32;
        let b2 = self.beta2 as f32;
        let bc1 = 1.0 - (self.beta1).powi(self.t as i32);
        let bc2 = 1.0 - (self.beta2).powi(self.t as i32);
        let step_scale = (lr / bc1) as f32;
        let vbc = bc2 as f32;
        let eps = self.eps as f32;
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            let pd = p.data_mut();
            let gd = g.data();
            let md = m.data_mut();
            let vd = v.data_mut();
            for i in 0..pd.len() {
                md[i] = b1 * md[i] + (1.0 - b1) * gd[i];
                vd[i] = b2 * vd[i] + (1.0 - b2) * gd[i] * gd[i];
                let vhat = vd[i] / vbc;
                pd[i] -= step_scale * md[i] / (vhat.sqrt() + eps);
            }
        }
    }

    pub fn steps_taken(&self) -> u64 {
        self.t
    }

    /// Checkpoint view of the full optimizer state: (m, v, t).
    pub fn state(&self) -> (&[Tensor], &[Tensor], u64) {
        (&self.m, &self.v, self.t)
    }

    /// Restore a checkpointed (m, v, t), shape-validated against the
    /// moments this optimizer was built for.
    pub fn load_state(&mut self, m: Vec<Tensor>, v: Vec<Tensor>, t: u64) -> Result<()> {
        if m.len() != self.m.len() || v.len() != self.v.len() {
            bail!("optimizer state has {}/{} moment tensors, expected {}",
                  m.len(), v.len(), self.m.len());
        }
        for (i, (new, cur)) in m.iter().zip(&self.m).enumerate() {
            if new.shape() != cur.shape() {
                bail!("restored m[{i}] shape {:?} != expected {:?}",
                      new.shape(), cur.shape());
            }
        }
        for (i, (new, cur)) in v.iter().zip(&self.v).enumerate() {
            if new.shape() != cur.shape() {
                bail!("restored v[{i}] shape {:?} != expected {:?}",
                      new.shape(), cur.shape());
            }
        }
        self.m = m;
        self.v = v;
        self.t = t;
        Ok(())
    }
}

/// Plain SGD (for ablations).
#[derive(Clone, Debug, Default)]
pub struct Sgd {
    pub momentum: f64,
    velocity: Vec<Tensor>,
}

impl Sgd {
    pub fn new(momentum: f64) -> Sgd {
        Sgd { momentum, velocity: Vec::new() }
    }

    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f64) {
        if self.velocity.is_empty() {
            self.velocity = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        }
        let mu = self.momentum as f32;
        for ((p, g), v) in params.iter_mut().zip(grads).zip(self.velocity.iter_mut()) {
            let pd = p.data_mut();
            let gd = g.data();
            let vd = v.data_mut();
            for i in 0..pd.len() {
                vd[i] = mu * vd[i] + gd[i];
                pd[i] -= (lr as f32) * vd[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adam on f(x) = x^2 converges toward 0.
    #[test]
    fn adam_minimizes_quadratic() {
        let mut p = vec![Tensor::from_vec(&[1], vec![5.0])];
        let mut opt = Adam::for_params(&p);
        for _ in 0..500 {
            let g = vec![Tensor::from_vec(&[1], vec![2.0 * p[0].data()[0]])];
            opt.step(&mut p, &g, 0.05);
        }
        assert!(p[0].data()[0].abs() < 0.05, "{}", p[0].data()[0]);
    }

    /// First Adam step size equals lr regardless of gradient scale
    /// (bias-corrected signSGD-like behaviour).
    #[test]
    fn adam_first_step_is_lr_sized() {
        for g0 in [1e-4f32, 1.0, 1e4] {
            let mut p = vec![Tensor::from_vec(&[1], vec![0.0])];
            let mut opt = Adam::for_params(&p);
            opt.step(&mut p, &[Tensor::from_vec(&[1], vec![g0])], 0.01);
            assert!((p[0].data()[0] + 0.01).abs() < 1e-4, "g0={g0}: {}", p[0].data()[0]);
        }
    }

    #[test]
    fn adam_deterministic() {
        let run = || {
            let mut p = vec![Tensor::from_vec(&[2], vec![1.0, -2.0])];
            let mut opt = Adam::for_params(&p);
            for i in 0..10 {
                let g = vec![Tensor::from_vec(&[2], vec![0.1 * i as f32, -0.2])];
                opt.step(&mut p, &g, 1e-2);
            }
            p[0].data().to_vec()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn sgd_with_momentum_accelerates() {
        let mut p = vec![Tensor::from_vec(&[1], vec![1.0])];
        let mut opt = Sgd::new(0.9);
        let g = vec![Tensor::from_vec(&[1], vec![1.0])];
        opt.step(&mut p, &g, 0.1);
        let d1 = 1.0 - p[0].data()[0];
        opt.step(&mut p, &g, 0.1);
        let d2 = 1.0 - d1 - p[0].data()[0];
        assert!(d2 > d1, "momentum should grow the step: {d1} then {d2}");
    }
}
