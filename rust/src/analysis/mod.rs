//! Static analysis of communication schedules — the `hydra3d verify`
//! subsystem.
//!
//! Training correctness here rests on every rank of a world agreeing on
//! one wire protocol: each send paired with exactly one receive of the
//! same tag and byte count, collectives issued in the same order with the
//! same reduce sizes everywhere, and no blocking receive that can wait on
//! a message nobody will send. Those properties are invisible to the
//! numeric tests (a run that deadlocks never returns a wrong number — it
//! never returns), so this module checks them *statically*, against the
//! extracted schedule rather than a wall-clock run.
//!
//! Extraction ([`extract`]) is a **dry run through the real comm layer**:
//! it builds genuine channel-thread worlds wrapped in the traced backend
//! and drives them with walkers that mirror the engines' per-step
//! communication (halo exchange, BN statistics, flatten gather/scatter,
//! bucketed or monolithic gradient allreduce, store redistribution) using
//! zero-filled buffers of the true shapes — no kernels, no AOT artifacts,
//! no dataset. Because the walkers call the *same* `comm::halo`,
//! `comm::bucket` and `iosim::store` code the engines call, the recorded
//! wire structure cannot drift from production.
//!
//! [`checks::check_schedule`] then verifies five properties (send/recv
//! matching, collective agreement, tag discipline, deadlock freedom,
//! buffer-pool discipline), and [`mutate`] seeds deliberate schedule
//! defects to prove each property is actually enforced.

pub mod checks;
pub mod model;
pub mod mutate;

pub use checks::{check_schedule, Defect, DefectKind};
pub use model::ModelSpec;
pub use mutate::{MutationKind, MutationOutcome};

use crate::comm::{GradReduce, ScheduleOp, DEFAULT_BUCKET_ELEMS};
use crate::engine::hybrid::IoMode;
use crate::partition::SpatialGrid;
use crate::tensor::pool::PoolEvent;
use anyhow::Result;

/// Which engine's schedule to extract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Hybrid spatial × data parallelism (`engine::hybrid`).
    Hybrid,
    /// Pure data parallelism over fused executables
    /// (`engine::dataparallel`); in-memory I/O only.
    Fused,
}

/// One configuration to extract and check.
#[derive(Clone, Copy, Debug)]
pub struct VerifyCfg {
    pub grid: SpatialGrid,
    pub groups: usize,
    pub batch_global: usize,
    pub steps: usize,
    /// Dataset size for the store modes' sample schedule.
    pub samples: usize,
    pub seed: u64,
    pub io: IoMode,
    pub reduce: GradReduce,
    pub engine: EngineKind,
}

impl VerifyCfg {
    /// A human-readable one-liner for reports and defect context.
    pub fn describe(&self) -> String {
        format!(
            "grid {} x {} group(s), batch {}, {} step(s), io {:?}, {:?}, {:?}",
            self.grid,
            self.groups,
            self.batch_global,
            self.steps,
            self.io,
            self.reduce,
            self.engine
        )
    }

    /// The mutation harness baseline: a world of 4 (2 groups × 2-way depth
    /// grid) with BN, blocking store staging and bucketed overlap — every
    /// traffic class (halo, scatter, redist, bucket collectives) present.
    pub fn mutation_baseline() -> (ModelSpec, VerifyCfg) {
        let spec = ModelSpec::builtin("cf-sim-bn").expect("builtin");
        let cfg = VerifyCfg {
            grid: SpatialGrid::new(2, 1, 1),
            groups: 2,
            batch_global: 4,
            steps: 1,
            samples: 8,
            seed: 7,
            io: IoMode::Store,
            reduce: GradReduce::default(),
            engine: EngineKind::Hybrid,
        };
        (spec, cfg)
    }
}

/// Per-rank op streams of one communicator world.
#[derive(Clone, Debug)]
pub struct WorldOps {
    /// "compute", "grad" or "staging".
    pub name: String,
    pub size: usize,
    /// `ranks[r]` is rank `r`'s ops in program order.
    pub ranks: Vec<Vec<ScheduleOp>>,
}

/// The full extracted schedule of one configuration: every world's
/// per-rank op streams plus each rank's buffer-pool event log.
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    pub worlds: Vec<WorldOps>,
    /// One log per compute rank (empty for engines without a pool).
    pub pool_logs: Vec<Vec<PoolEvent>>,
}

impl Schedule {
    pub fn world(&self, name: &str) -> Option<&WorldOps> {
        self.worlds.iter().find(|w| w.name == name)
    }

    pub fn world_mut(&mut self, name: &str) -> Option<&mut WorldOps> {
        self.worlds.iter_mut().find(|w| w.name == name)
    }

    /// Total ops across all worlds (a quick sanity figure for reports).
    pub fn total_ops(&self) -> usize {
        self.worlds
            .iter()
            .map(|w| w.ranks.iter().map(Vec::len).sum::<usize>())
            .sum()
    }
}

/// Extract the communication schedule of `cfg` by dry-running the
/// configured engine's comm path over real traced worlds.
pub fn extract(spec: &ModelSpec, cfg: &VerifyCfg) -> Result<Schedule> {
    match cfg.engine {
        EngineKind::Hybrid => crate::engine::hybrid::dry_run_hybrid(spec, cfg),
        EngineKind::Fused => crate::engine::dataparallel::dry_run_fused(spec, cfg),
    }
}

/// Extract and check one configuration; empty result = clean.
pub fn verify(spec: &ModelSpec, cfg: &VerifyCfg) -> Result<Vec<Defect>> {
    Ok(check_schedule(&extract(spec, cfg)?))
}

/// The CI configuration matrix: every built-in model over the grids,
/// group counts and I/O modes the integration tests exercise. BN models
/// are constrained to power-of-two worlds (the recursive-doubling
/// statistics allreduce requires it), exactly as in production.
pub fn matrix() -> Vec<(ModelSpec, VerifyCfg)> {
    let grids = [
        SpatialGrid::new(1, 1, 1),
        SpatialGrid::new(2, 1, 1),
        SpatialGrid::new(1, 2, 1),
        SpatialGrid::new(3, 1, 1),
        SpatialGrid::new(2, 2, 1),
        SpatialGrid::new(2, 2, 2),
    ];
    let ios = [IoMode::InMem, IoMode::Store, IoMode::StoreAsync];
    let mut out = Vec::new();
    for name in ModelSpec::builtin_names() {
        let spec = ModelSpec::builtin(name).expect("builtin");
        for grid in grids {
            for groups in [1usize, 2] {
                let world = groups * grid.ways();
                if spec.has_bn() && world > 1 && !world.is_power_of_two() {
                    continue;
                }
                for io in ios {
                    let mut reduces = vec![GradReduce::default()];
                    // monolithic variant on a representative subset: it
                    // only changes the gradient reduction, which the grid
                    // and io axes don't interact with
                    if grid.ways() == 2 && io == IoMode::Store {
                        reduces.push(GradReduce::Monolithic);
                    }
                    // hierarchical variant wherever a 2-rank node grouping
                    // is non-degenerate (world >= 4 members span >= 2 nodes
                    // with at least one multi-member node), so the checker
                    // covers the Hier(0)/Hier(1) tag classes and the
                    // leader-subgroup ring
                    if world >= 4 && io == IoMode::InMem {
                        reduces.push(GradReduce::Hier {
                            bucket_elems: DEFAULT_BUCKET_ELEMS,
                            ranks_per_node: 2,
                        });
                    }
                    for reduce in reduces {
                        out.push((
                            spec.clone(),
                            VerifyCfg {
                                grid,
                                groups,
                                batch_global: 2 * groups,
                                steps: 2,
                                samples: 4 * groups,
                                seed: 11,
                                io,
                                reduce,
                                engine: EngineKind::Hybrid,
                            },
                        ));
                    }
                }
            }
        }
        // fused data-parallel schedules: in-memory only, both reductions
        for groups in [1usize, 2, 4] {
            for reduce in [GradReduce::default(), GradReduce::Monolithic] {
                out.push((
                    spec.clone(),
                    VerifyCfg {
                        grid: SpatialGrid::new(1, 1, 1),
                        groups,
                        batch_global: 2 * groups,
                        steps: 2,
                        samples: 4 * groups,
                        seed: 11,
                        io: IoMode::InMem,
                        reduce,
                        engine: EngineKind::Fused,
                    },
                ));
            }
        }
    }
    out
}

/// Run every mutation class against the baseline schedule `rounds` times
/// with distinct seeds; each outcome says whether the checker caught the
/// seeded defect with the expected diagnostic kind.
pub fn run_mutation_suite(seed: u64, rounds: usize) -> Result<Vec<MutationOutcome>> {
    let (spec, cfg) = VerifyCfg::mutation_baseline();
    let baseline = extract(&spec, &cfg)?;
    let clean = check_schedule(&baseline);
    if !clean.is_empty() {
        anyhow::bail!(
            "mutation baseline is not clean: {} defect(s), first: {}",
            clean.len(),
            clean[0]
        );
    }
    let mut out = Vec::new();
    for kind in MutationKind::ALL {
        for round in 0..rounds.max(1) {
            let mut mutated = baseline.clone();
            let desc = mutate::apply(&mut mutated, kind, seed + round as u64)?;
            let defects = check_schedule(&mutated);
            let hit = defects.iter().find(|d| d.kind == kind.expected()).cloned();
            out.push(MutationOutcome {
                kind,
                seed: seed + round as u64,
                desc,
                caught: hit.is_some(),
                defect: hit,
            });
        }
    }
    Ok(out)
}
