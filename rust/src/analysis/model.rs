//! Synthetic model descriptions for schedule extraction.
//!
//! The dry-run walkers need only what determines *communication*: layer
//! kinds with their global input geometry, halo widths, BN channel counts
//! and the ordered parameter table (bucket layout). [`ModelSpec`] carries
//! exactly that, so `hydra3d verify` can check every grid/group/io
//! combination without AOT artifacts or a dataset on disk. The built-in
//! specs mirror the real CosmoFlow / UNet plan shapes (conv → bn → pool
//! pyramid with a flatten/fc head, and an encoder–decoder with skip
//! connections) at toy extents divisible by every CI grid dimension.
//!
//! Spatial entries use the same convention as the AOT manifests
//! (`python/compile/model.py::layer_plan`): `d/h/w` are the layer's
//! *global input* activation extents; ranks derive their shard as
//! `dim / grid_dim`. That is what lets [`ModelSpec::from_model_info`]
//! reuse a real manifest's plan verbatim.

use crate::runtime::{LayerDesc, ModelInfo};
use anyhow::{bail, Result};

/// Communication-relevant description of one model.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    /// Global cubic input extent (the store serves `size^3` volumes).
    pub input_size: usize,
    pub in_channels: usize,
    /// Layer plan; spatial dims are global *input* extents per layer.
    pub plan: Vec<LayerDesc>,
    /// Ordered `(name, shape)` parameter table (gradient/bucket order).
    pub params: Vec<(String, Vec<usize>)>,
    /// Flat regression-target length (MSE models).
    pub target_len: usize,
    /// Per-voxel label channels (segmentation models).
    pub label_channels: usize,
}

impl ModelSpec {
    /// Look up a built-in spec by name.
    pub fn builtin(name: &str) -> Result<ModelSpec> {
        match name {
            "cf-sim" => Ok(cf_sim(false)),
            "cf-sim-bn" => Ok(cf_sim(true)),
            "unet-sim" => Ok(unet_sim()),
            _ => bail!(
                "unknown built-in model '{name}' (have: cf-sim, cf-sim-bn, \
                 unet-sim)"
            ),
        }
    }

    /// Names of every built-in spec (the CI matrix iterates these).
    pub fn builtin_names() -> [&'static str; 3] {
        ["cf-sim", "cf-sim-bn", "unet-sim"]
    }

    /// Build a spec from a real AOT manifest entry, so `verify` can check
    /// the exact production plans when artifacts are present.
    pub fn from_model_info(info: &ModelInfo) -> ModelSpec {
        ModelSpec {
            name: info.name.clone(),
            input_size: info.input_size,
            in_channels: info.in_channels,
            plan: info.plan.clone(),
            params: info.params.clone(),
            target_len: info.n_targets,
            label_channels: info.n_classes,
        }
    }

    /// Segmentation models (per-voxel targets) end in cross-entropy.
    pub fn label_mode(&self) -> bool {
        self.plan.iter().any(|l| matches!(l, LayerDesc::Xent { .. }))
    }

    /// Whether the plan carries batch-norm layers (whose statistics
    /// allreduce constrains the world size to powers of two).
    pub fn has_bn(&self) -> bool {
        self.plan.iter().any(|l| matches!(l, LayerDesc::Bn { .. }))
    }

    /// Total parameter elements (the monolithic allreduce payload).
    pub fn param_elems(&self) -> usize {
        self.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

/// `s` is the global input extent along each spatial axis.
fn conv(tag: &str, cin: usize, cout: usize, k: usize, s: usize, halo: usize)
        -> LayerDesc {
    LayerDesc::Conv {
        tag: tag.to_string(),
        cin,
        cout,
        k,
        stride: 1,
        d: s,
        h: s,
        w: s,
        halo,
        fwd: None,
        bwd_data: None,
        bwd_filter: None,
    }
}

fn bn(tag: &str, c: usize, s: usize) -> LayerDesc {
    LayerDesc::Bn {
        tag: tag.to_string(),
        c,
        d: s,
        h: s,
        w: s,
        apply: None,
        bwd_partials: None,
        bwd_apply: None,
    }
}

fn act(c: usize, s: usize) -> LayerDesc {
    LayerDesc::Act { c, d: s, h: s, w: s }
}

fn pool(c: usize, s: usize) -> LayerDesc {
    LayerDesc::Pool {
        op: "max".to_string(),
        c,
        d: s,
        h: s,
        w: s,
        fwd: None,
        bwd: None,
    }
}

fn fc(tag: &str, fin: usize, fout: usize, act: bool) -> LayerDesc {
    LayerDesc::Fc {
        tag: tag.to_string(),
        fin,
        fout,
        act,
        dropout: false,
        fwd: None,
        bwd: None,
    }
}

/// Two-stage CosmoFlow-shaped pyramid: 12³ input, two conv(+BN) blocks
/// with a 2× pool between them, flatten to an fc head, MSE targets.
/// Extents 12 and 6 are divisible by grid dims 1, 2 and 3.
fn cf_sim(use_bn: bool) -> ModelSpec {
    let mut plan = vec![conv("conv0", 1, 4, 3, 12, 1)];
    if use_bn {
        plan.push(bn("conv0", 4, 12));
    }
    plan.push(act(4, 12));
    plan.push(pool(4, 12)); // 12 -> 6
    plan.push(conv("conv1", 4, 8, 3, 6, 1));
    if use_bn {
        plan.push(bn("conv1", 8, 6));
    }
    plan.push(act(8, 6));
    plan.push(LayerDesc::Flatten { c: 8, d: 6, h: 6, w: 6 });
    let fin = 8 * 6 * 6 * 6;
    plan.push(fc("fc0", fin, 16, true));
    plan.push(fc("fc1", 16, 4, false));
    plan.push(LayerDesc::Mse { n: 4, fwd_bwd: None });

    let mut params = vec![("conv0.w".to_string(), vec![4, 1, 3, 3, 3])];
    if use_bn {
        params.push(("conv0.gamma".to_string(), vec![4]));
        params.push(("conv0.beta".to_string(), vec![4]));
    }
    params.push(("conv1.w".to_string(), vec![8, 4, 3, 3, 3]));
    if use_bn {
        params.push(("conv1.gamma".to_string(), vec![8]));
        params.push(("conv1.beta".to_string(), vec![8]));
    }
    params.push(("fc0.w".to_string(), vec![16, fin]));
    params.push(("fc0.b".to_string(), vec![16]));
    params.push(("fc1.w".to_string(), vec![4, 16]));
    params.push(("fc1.b".to_string(), vec![4]));

    ModelSpec {
        name: if use_bn { "cf-sim-bn" } else { "cf-sim" }.to_string(),
        input_size: 12,
        in_channels: 1,
        plan,
        params,
        target_len: 4,
        label_channels: 0,
    }
}

/// One-level UNet-shaped encoder–decoder: skip save at full resolution,
/// pooled bottom convs, a 2× deconv back up, skip concat, 1×1 head conv
/// and per-voxel cross-entropy (label mode).
fn unet_sim() -> ModelSpec {
    let plan = vec![
        conv("down0", 1, 4, 3, 12, 1),
        act(4, 12),
        LayerDesc::SaveSkip { slot: 0, c: 4, d: 12, h: 12, w: 12 },
        pool(4, 12), // 12 -> 6
        conv("bottom", 4, 8, 3, 6, 1),
        act(8, 6),
        LayerDesc::Deconv {
            tag: "up0".to_string(),
            cin: 8,
            cout: 4,
            d: 6, // input extent; deconv doubles it back to 12
            h: 6,
            w: 6,
            fwd: None,
            bwd_data: None,
            bwd_filter: None,
        },
        LayerDesc::ConcatSkip { slot: 0, c_skip: 4, c_up: 4, d: 12, h: 12, w: 12 },
        conv("head", 8, 3, 1, 12, 0),
        LayerDesc::Xent { n_classes: 3, d: 12, h: 12, w: 12, fwd_bwd: None },
    ];
    let params = vec![
        ("down0.w".to_string(), vec![4, 1, 3, 3, 3]),
        ("bottom.w".to_string(), vec![8, 4, 3, 3, 3]),
        ("up0.w".to_string(), vec![4, 8, 2, 2, 2]),
        ("head.w".to_string(), vec![3, 8, 1, 1, 1]),
    ];
    ModelSpec {
        name: "unet-sim".to_string(),
        input_size: 12,
        in_channels: 1,
        plan,
        params,
        target_len: 0,
        label_channels: 3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve_and_are_consistent() {
        for name in ModelSpec::builtin_names() {
            let spec = ModelSpec::builtin(name).unwrap();
            assert_eq!(spec.name, name);
            assert!(!spec.plan.is_empty());
            assert!(!spec.params.is_empty());
            assert!(spec.param_elems() > 0);
            // every 12-extent layer dim is divisible by grids up to 3
            assert_eq!(spec.input_size % 3, 0);
            assert_eq!(spec.input_size % 2, 0);
        }
        assert!(ModelSpec::builtin("nope").is_err());
    }

    #[test]
    fn bn_and_label_flags() {
        assert!(!ModelSpec::builtin("cf-sim").unwrap().has_bn());
        assert!(ModelSpec::builtin("cf-sim-bn").unwrap().has_bn());
        assert!(!ModelSpec::builtin("cf-sim").unwrap().label_mode());
        assert!(ModelSpec::builtin("unet-sim").unwrap().label_mode());
    }

    #[test]
    fn param_names_match_plan_tags() {
        // layer_param_indices keys params by "<tag>.w" etc. — the specs
        // must keep the two tables consistent or overlap marking silently
        // degrades to flush-at-finish.
        let spec = ModelSpec::builtin("cf-sim-bn").unwrap();
        for layer in &spec.plan {
            let idx = crate::engine::hybrid::layer_param_indices(&spec.params, layer);
            match layer {
                LayerDesc::Conv { .. } => assert_eq!(idx.len(), 1),
                LayerDesc::Bn { .. } => assert_eq!(idx.len(), 2),
                LayerDesc::Fc { .. } => assert_eq!(idx.len(), 2),
                _ => assert!(idx.is_empty()),
            }
        }
    }
}
