//! Seeded schedule mutations: deliberate wire-protocol defects used to
//! prove the checker enforces what it claims.
//!
//! Each [`MutationKind`] perturbs an extracted [`Schedule`] the way a
//! real engine bug would — a receive that was never posted, a tag typo'd
//! across subsystems, a rank that reorders its collectives, a buffer
//! returned twice — and maps to the [`DefectKind`] the checker must
//! report for it. `hydra3d verify --mutations` and the negative test
//! suite assert every class is caught with rank/tag/op context.

use super::checks::{Defect, DefectKind};
use super::Schedule;
use crate::comm::{MsgTag, ScheduleOp};
use crate::tensor::pool::PoolEvent;
use crate::util::rng::Pcg;
use anyhow::{bail, Result};

/// One class of seeded schedule defect.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MutationKind {
    /// Delete a receive: its sender's message is never consumed.
    DropRecv,
    /// Delete a send: its receiver waits for a message nobody sends.
    DropSend,
    /// Retag a halo send to a *different axis* (same traffic class).
    SwapTag,
    /// Retag a halo send as redistribution traffic (class aliasing).
    AliasTag,
    /// Grow a send's element count so it no longer matches the receive.
    SkewBytes,
    /// Swap two same-group, different-op collectives on one rank.
    ReorderCollectives,
    /// Bump one rank's reduce size for one collective.
    SkewCollectiveElems,
    /// Move one channel pair's first receives ahead of their first sends
    /// on both endpoints — the classic mutual-wait protocol inversion.
    RecvBeforeSend,
    /// Duplicate a pool return.
    PoolDoubleReturn,
    /// Touch a buffer right after returning it to the pool.
    PoolUseAfterReturn,
}

impl MutationKind {
    pub const ALL: [MutationKind; 10] = [
        MutationKind::DropRecv,
        MutationKind::DropSend,
        MutationKind::SwapTag,
        MutationKind::AliasTag,
        MutationKind::SkewBytes,
        MutationKind::ReorderCollectives,
        MutationKind::SkewCollectiveElems,
        MutationKind::RecvBeforeSend,
        MutationKind::PoolDoubleReturn,
        MutationKind::PoolUseAfterReturn,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            MutationKind::DropRecv => "drop-recv",
            MutationKind::DropSend => "drop-send",
            MutationKind::SwapTag => "swap-tag",
            MutationKind::AliasTag => "alias-tag",
            MutationKind::SkewBytes => "skew-bytes",
            MutationKind::ReorderCollectives => "reorder-collectives",
            MutationKind::SkewCollectiveElems => "skew-collective-elems",
            MutationKind::RecvBeforeSend => "recv-before-send",
            MutationKind::PoolDoubleReturn => "pool-double-return",
            MutationKind::PoolUseAfterReturn => "pool-use-after-return",
        }
    }

    /// The defect class the checker must report for this mutation.
    pub fn expected(&self) -> DefectKind {
        match self {
            MutationKind::DropRecv => DefectKind::UnmatchedSend,
            MutationKind::DropSend => DefectKind::UnmatchedRecv,
            MutationKind::SwapTag => DefectKind::TagMismatch,
            MutationKind::AliasTag => DefectKind::TagAliasing,
            MutationKind::SkewBytes => DefectKind::ByteMismatch,
            MutationKind::ReorderCollectives => DefectKind::CollectiveOrder,
            MutationKind::SkewCollectiveElems => DefectKind::CollectiveSize,
            MutationKind::RecvBeforeSend => DefectKind::Deadlock,
            MutationKind::PoolDoubleReturn => DefectKind::PoolDoubleReturn,
            MutationKind::PoolUseAfterReturn => DefectKind::PoolUseAfterReturn,
        }
    }
}

/// Outcome of one seeded mutation round.
#[derive(Clone, Debug)]
pub struct MutationOutcome {
    pub kind: MutationKind,
    pub seed: u64,
    /// What was perturbed, for the report.
    pub desc: String,
    /// Whether a defect of the expected kind was reported.
    pub caught: bool,
    pub defect: Option<Defect>,
}

/// Ops matching `pred` across all worlds, as `(world, rank, index)`.
fn op_sites(
    sched: &Schedule,
    pred: impl Fn(&ScheduleOp) -> bool,
) -> Vec<(usize, usize, usize)> {
    let mut sites = Vec::new();
    for (wi, w) in sched.worlds.iter().enumerate() {
        for (r, stream) in w.ranks.iter().enumerate() {
            for (i, op) in stream.iter().enumerate() {
                if pred(op) {
                    sites.push((wi, r, i));
                }
            }
        }
    }
    sites
}

fn pick<T: Copy>(rng: &mut Pcg, xs: &[T]) -> T {
    xs[rng.below(xs.len())]
}

fn is_halo_send(op: &ScheduleOp) -> bool {
    matches!(op, ScheduleOp::Send { tag: MsgTag::Halo(_), .. })
}

/// Apply one seeded mutation in place; returns a description of the
/// perturbation. Fails if the schedule has no applicable site (the
/// mutation baseline is chosen so every class has one).
pub fn apply(sched: &mut Schedule, kind: MutationKind, seed: u64) -> Result<String> {
    let mut rng = Pcg::new(seed, 0xa11a);
    match kind {
        MutationKind::DropRecv => {
            let tagged = op_sites(sched, |op| {
                matches!(op, ScheduleOp::Recv { tag, .. } if *tag != MsgTag::Generic)
            });
            let sites = if tagged.is_empty() {
                op_sites(sched, |op| matches!(op, ScheduleOp::Recv { .. }))
            } else {
                tagged
            };
            if sites.is_empty() {
                bail!("no receive to drop");
            }
            let (wi, r, i) = pick(&mut rng, &sites);
            let op = sched.worlds[wi].ranks[r].remove(i);
            Ok(format!(
                "dropped {op:?} at rank {r} of world {}",
                sched.worlds[wi].name
            ))
        }
        MutationKind::DropSend => {
            let tagged = op_sites(sched, |op| {
                matches!(op, ScheduleOp::Send { tag, .. } if *tag != MsgTag::Generic)
            });
            let sites = if tagged.is_empty() {
                op_sites(sched, |op| matches!(op, ScheduleOp::Send { .. }))
            } else {
                tagged
            };
            if sites.is_empty() {
                bail!("no send to drop");
            }
            let (wi, r, i) = pick(&mut rng, &sites);
            let op = sched.worlds[wi].ranks[r].remove(i);
            Ok(format!(
                "dropped {op:?} at rank {r} of world {}",
                sched.worlds[wi].name
            ))
        }
        MutationKind::SwapTag | MutationKind::AliasTag => {
            let sites = op_sites(sched, is_halo_send);
            if sites.is_empty() {
                bail!("no halo send to retag");
            }
            let (wi, r, i) = pick(&mut rng, &sites);
            let stream = &mut sched.worlds[wi].ranks[r];
            let old = match &stream[i] {
                ScheduleOp::Send { tag: MsgTag::Halo(a), .. } => MsgTag::Halo(*a),
                _ => unreachable!("site filter"),
            };
            let new_tag = match (kind, old) {
                (MutationKind::SwapTag, MsgTag::Halo(a)) => {
                    MsgTag::Halo((a + 1) % 3)
                }
                _ => MsgTag::Redist,
            };
            if let ScheduleOp::Send { tag, .. } = &mut stream[i] {
                *tag = new_tag;
            }
            Ok(format!(
                "retagged send #{i} at rank {r} of world {} from {old} to \
                 {new_tag}",
                sched.worlds[wi].name
            ))
        }
        MutationKind::SkewBytes => {
            let sites =
                op_sites(sched, |op| matches!(op, ScheduleOp::Send { .. }));
            if sites.is_empty() {
                bail!("no send to skew");
            }
            let (wi, r, i) = pick(&mut rng, &sites);
            if let ScheduleOp::Send { elems, .. } =
                &mut sched.worlds[wi].ranks[r][i]
            {
                *elems += 1;
            }
            Ok(format!(
                "grew send #{i} at rank {r} of world {} by one element",
                sched.worlds[wi].name
            ))
        }
        MutationKind::ReorderCollectives => {
            // two consecutive markers of the *same group* with *different
            // ops* on one rank — swapping same-op markers would show up as
            // a size divergence instead of an order divergence
            let mut pairs = Vec::new();
            for (wi, w) in sched.worlds.iter().enumerate() {
                for (r, stream) in w.ranks.iter().enumerate() {
                    let marks: Vec<usize> = (0..stream.len())
                        .filter(|&i| {
                            matches!(stream[i], ScheduleOp::Collective { .. })
                        })
                        .collect();
                    for k in 1..marks.len() {
                        let (i, j) = (marks[k - 1], marks[k]);
                        if let (
                            ScheduleOp::Collective { op: a, group: ga, .. },
                            ScheduleOp::Collective { op: b, group: gb, .. },
                        ) = (&stream[i], &stream[j])
                        {
                            if ga == gb && a != b {
                                pairs.push((wi, r, i, j));
                            }
                        }
                    }
                }
            }
            if pairs.is_empty() {
                bail!("no adjacent same-group different-op collectives");
            }
            let (wi, r, i, j) = pick(&mut rng, &pairs);
            sched.worlds[wi].ranks[r].swap(i, j);
            Ok(format!(
                "swapped collectives #{i} and #{j} at rank {r} of world {}",
                sched.worlds[wi].name
            ))
        }
        MutationKind::SkewCollectiveElems => {
            let sites = op_sites(sched, |op| {
                matches!(op, ScheduleOp::Collective { group, .. } if group.len() > 1)
            });
            if sites.is_empty() {
                bail!("no multi-rank collective to skew");
            }
            let (wi, r, i) = pick(&mut rng, &sites);
            if let ScheduleOp::Collective { elems, .. } =
                &mut sched.worlds[wi].ranks[r][i]
            {
                *elems += 1;
            }
            Ok(format!(
                "grew collective #{i} reduce size at rank {r} of world {}",
                sched.worlds[wi].name
            ))
        }
        MutationKind::RecvBeforeSend => {
            // channel pairs (a, b) where both endpoints send before they
            // receive — invert both so each blocks on the other first
            let mut cands = Vec::new();
            for (wi, w) in sched.worlds.iter().enumerate() {
                let n = w.ranks.len();
                let pos_send = |r: usize, peer: usize| {
                    w.ranks[r].iter().position(|op| {
                        matches!(op, ScheduleOp::Send { to, .. } if *to == peer)
                    })
                };
                let pos_recv = |r: usize, peer: usize| {
                    w.ranks[r].iter().position(|op| {
                        matches!(op, ScheduleOp::Recv { from, .. } if *from == peer)
                    })
                };
                for a in 0..n {
                    for b in (a + 1)..n {
                        if let (Some(sa), Some(ra), Some(sb), Some(rb)) = (
                            pos_send(a, b),
                            pos_recv(a, b),
                            pos_send(b, a),
                            pos_recv(b, a),
                        ) {
                            if ra > sa && rb > sb {
                                cands.push((wi, a, b, sa, ra, sb, rb));
                            }
                        }
                    }
                }
            }
            if cands.is_empty() {
                bail!("no send-then-recv channel pair to invert");
            }
            let (wi, a, b, sa, ra, sb, rb) = pick(&mut rng, &cands);
            let name = sched.worlds[wi].name.clone();
            let sa_stream = &mut sched.worlds[wi].ranks[a];
            let op = sa_stream.remove(ra);
            sa_stream.insert(sa, op);
            let sb_stream = &mut sched.worlds[wi].ranks[b];
            let op = sb_stream.remove(rb);
            sb_stream.insert(sb, op);
            Ok(format!(
                "moved first receives of channel pair ({a}, {b}) ahead of \
                 their first sends on world {name}"
            ))
        }
        MutationKind::PoolDoubleReturn | MutationKind::PoolUseAfterReturn => {
            let mut sites = Vec::new();
            for (r, log) in sched.pool_logs.iter().enumerate() {
                for (i, ev) in log.iter().enumerate() {
                    if let PoolEvent::Put { ptr, len } = *ev {
                        sites.push((r, i, ptr, len));
                    }
                }
            }
            if sites.is_empty() {
                bail!("no pool return to perturb");
            }
            let (r, i, ptr, len) = pick(&mut rng, &sites);
            let ev = if kind == MutationKind::PoolDoubleReturn {
                PoolEvent::Put { ptr, len }
            } else {
                PoolEvent::Use { ptr, len }
            };
            sched.pool_logs[r].insert(i + 1, ev);
            Ok(format!(
                "inserted {ev:?} after return #{i} in rank {r}'s pool log"
            ))
        }
    }
}
