//! Schedule checks: the five static properties `hydra3d verify` enforces.
//!
//! All checks run over an extracted [`Schedule`](super::Schedule) — pure
//! data, no live communicators — so a failed check names the exact rank,
//! peer, tag and op instead of a hung process:
//!
//! 1. **Send/recv matching** — on every directed channel of a world, the
//!    k-th send pairs with the k-th receive (FIFO, which is the channel
//!    backend's delivery order). Pairs must agree on byte count and tag;
//!    leftovers on either side are unmatched traffic.
//! 2. **Collective agreement** — all member ranks of a group must issue
//!    the group's collectives in identical order with identical reduce
//!    sizes; a rank that skips, reorders or resizes one desynchronizes
//!    the ring/recursive-doubling step loops.
//! 3. **Tag discipline** — a send whose tag *class* (halo / redist /
//!    scatter / generic) differs from what the paired receive expects is
//!    traffic aliasing between subsystems on one world.
//! 4. **Deadlock freedom** — executing the schedule abstractly
//!    (non-blocking sends, blocking FIFO receives) must drain every
//!    rank; stuck ranks are reported with their wait-for cycle.
//! 5. **Pool discipline** — per-rank buffer-pool event logs must never
//!    return one buffer twice nor touch a buffer that sits in a free
//!    list (the runtime `debug_assert` catches the former only on the
//!    step that trips it; the log check covers the whole schedule).

use super::{Schedule, WorldOps};
use crate::comm::{MsgTag, ScheduleOp};
use crate::tensor::pool::PoolEvent;
use std::collections::HashMap;
use std::fmt;

/// Classes of schedule defects, one per enforced property violation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DefectKind {
    /// A send with no receive to pair with.
    UnmatchedSend,
    /// A receive with no send to pair with.
    UnmatchedRecv,
    /// Paired send/recv disagree on element count.
    ByteMismatch,
    /// Paired send/recv carry different tags of the same class.
    TagMismatch,
    /// Paired send/recv carry tags of *different* classes — one
    /// subsystem's traffic delivered to another's receive.
    TagAliasing,
    /// Group members disagree on collective order (or count).
    CollectiveOrder,
    /// Group members agree on order but disagree on a reduce size.
    CollectiveSize,
    /// The schedule cannot drain: a blocking receive waits forever.
    Deadlock,
    /// A pool buffer returned to a free list twice.
    PoolDoubleReturn,
    /// A pool buffer used while sitting in a free list.
    PoolUseAfterReturn,
}

impl DefectKind {
    pub fn name(&self) -> &'static str {
        match self {
            DefectKind::UnmatchedSend => "unmatched-send",
            DefectKind::UnmatchedRecv => "unmatched-recv",
            DefectKind::ByteMismatch => "byte-mismatch",
            DefectKind::TagMismatch => "tag-mismatch",
            DefectKind::TagAliasing => "tag-aliasing",
            DefectKind::CollectiveOrder => "collective-order",
            DefectKind::CollectiveSize => "collective-size",
            DefectKind::Deadlock => "deadlock",
            DefectKind::PoolDoubleReturn => "pool-double-return",
            DefectKind::PoolUseAfterReturn => "pool-use-after-return",
        }
    }
}

/// One detected schedule defect, with enough context to locate it: the
/// world and rank it anchors to, the peer/tag of the offending op where
/// applicable, the op rendered as text, and a free-form detail line.
#[derive(Clone, Debug)]
pub struct Defect {
    pub kind: DefectKind,
    pub world: String,
    pub rank: usize,
    pub peer: Option<usize>,
    pub tag: Option<MsgTag>,
    pub op: String,
    pub detail: String,
}

impl fmt::Display for Defect {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] world {} rank {}", self.kind.name(), self.world, self.rank)?;
        if let Some(p) = self.peer {
            write!(f, " peer {p}")?;
        }
        if let Some(t) = self.tag {
            write!(f, " tag {t}")?;
        }
        write!(f, ": {} — {}", self.op, self.detail)
    }
}

fn op_text(op: &ScheduleOp) -> String {
    match op {
        ScheduleOp::Send { to, elems, tag } => {
            format!("send {elems} f32 [{tag}] -> rank {to}")
        }
        ScheduleOp::Recv { from, elems, tag } => {
            format!("recv {elems} f32 [{tag}] <- rank {from}")
        }
        ScheduleOp::Collective { op, elems, group } => {
            format!("{op:?}({elems}) over {} rank(s)", group.len())
        }
    }
}

/// Run every check over every world (and the pool logs) of a schedule.
pub fn check_schedule(s: &Schedule) -> Vec<Defect> {
    let mut out = Vec::new();
    for w in &s.worlds {
        check_p2p_pairing(w, &mut out);
        check_collectives(w, &mut out);
        check_deadlock(w, &mut out);
    }
    for (rank, log) in s.pool_logs.iter().enumerate() {
        check_pool(rank, log, &mut out);
    }
    out
}

/// Check 1 + 3: pair the k-th send on each directed channel with the
/// k-th receive and compare element counts and tags.
fn check_p2p_pairing(w: &WorldOps, out: &mut Vec<Defect>) {
    let n = w.ranks.len();
    for from in 0..n {
        for to in 0..n {
            if from == to {
                continue;
            }
            let sends: Vec<(usize, MsgTag)> = w.ranks[from]
                .iter()
                .filter_map(|op| match op {
                    ScheduleOp::Send { to: t, elems, tag } if *t == to => {
                        Some((*elems, *tag))
                    }
                    _ => None,
                })
                .collect();
            let recvs: Vec<(usize, MsgTag)> = w.ranks[to]
                .iter()
                .filter_map(|op| match op {
                    ScheduleOp::Recv { from: f, elems, tag } if *f == from => {
                        Some((*elems, *tag))
                    }
                    _ => None,
                })
                .collect();
            let paired = sends.len().min(recvs.len());
            for k in 0..paired {
                let (se, st) = sends[k];
                let (re, rt) = recvs[k];
                if st != rt {
                    let kind = if st.class() != rt.class() {
                        DefectKind::TagAliasing
                    } else {
                        DefectKind::TagMismatch
                    };
                    out.push(Defect {
                        kind,
                        world: w.name.clone(),
                        rank: from,
                        peer: Some(to),
                        tag: Some(st),
                        op: format!("send #{k} {se} f32 [{st}] -> rank {to}"),
                        detail: format!(
                            "rank {to} expects [{rt}] on its matching receive"
                        ),
                    });
                } else if se != re {
                    out.push(Defect {
                        kind: DefectKind::ByteMismatch,
                        world: w.name.clone(),
                        rank: from,
                        peer: Some(to),
                        tag: Some(st),
                        op: format!("send #{k} {se} f32 [{st}] -> rank {to}"),
                        detail: format!("rank {to} receives {re} f32 instead"),
                    });
                }
            }
            for (k, &(se, st)) in sends.iter().enumerate().skip(paired) {
                out.push(Defect {
                    kind: DefectKind::UnmatchedSend,
                    world: w.name.clone(),
                    rank: from,
                    peer: Some(to),
                    tag: Some(st),
                    op: format!("send #{k} {se} f32 [{st}] -> rank {to}"),
                    detail: format!(
                        "rank {to} posts only {} receive(s) on this channel",
                        recvs.len()
                    ),
                });
            }
            for (k, &(re, rt)) in recvs.iter().enumerate().skip(paired) {
                out.push(Defect {
                    kind: DefectKind::UnmatchedRecv,
                    world: w.name.clone(),
                    rank: to,
                    peer: Some(from),
                    tag: Some(rt),
                    op: format!("recv #{k} {re} f32 [{rt}] <- rank {from}"),
                    detail: format!(
                        "rank {from} posts only {} send(s) on this channel",
                        sends.len()
                    ),
                });
            }
        }
    }
}

/// Check 2: every member of a collective group must issue the group's
/// collectives in the same order with the same sizes.
fn check_collectives(w: &WorldOps, out: &mut Vec<Defect>) {
    type Seq = Vec<(crate::comm::Collective, usize)>;
    let mut by_group: HashMap<Vec<usize>, HashMap<usize, Seq>> = HashMap::new();
    for (r, stream) in w.ranks.iter().enumerate() {
        for op in stream {
            if let ScheduleOp::Collective { op: c, elems, group } = op {
                if !group.contains(&r) {
                    out.push(Defect {
                        kind: DefectKind::CollectiveOrder,
                        world: w.name.clone(),
                        rank: r,
                        peer: None,
                        tag: None,
                        op: op_text(op),
                        detail: format!(
                            "rank {r} issued a collective for a group it is \
                             not a member of ({group:?})"
                        ),
                    });
                    continue;
                }
                by_group
                    .entry(group.clone())
                    .or_default()
                    .entry(r)
                    .or_default()
                    .push((*c, *elems));
            }
        }
    }
    for (group, members) in &by_group {
        let empty: Seq = Vec::new();
        let reference = members.get(&group[0]).unwrap_or(&empty);
        for &m in group {
            let seq = members.get(&m).unwrap_or(&empty);
            if m == group[0] {
                continue;
            }
            let shared = reference.len().min(seq.len());
            let mut diverged = false;
            for k in 0..shared {
                let (rop, relems) = reference[k];
                let (sop, selems) = seq[k];
                if rop != sop {
                    out.push(Defect {
                        kind: DefectKind::CollectiveOrder,
                        world: w.name.clone(),
                        rank: m,
                        peer: Some(group[0]),
                        tag: None,
                        op: format!("collective #{k}: {sop:?}({selems})"),
                        detail: format!(
                            "rank {} issues {rop:?}({relems}) at the same \
                             position on group {group:?}",
                            group[0]
                        ),
                    });
                    diverged = true;
                    break;
                }
                if relems != selems {
                    out.push(Defect {
                        kind: DefectKind::CollectiveSize,
                        world: w.name.clone(),
                        rank: m,
                        peer: Some(group[0]),
                        tag: None,
                        op: format!("collective #{k}: {sop:?}({selems})"),
                        detail: format!(
                            "rank {} reduces {relems} f32 at the same \
                             position on group {group:?}",
                            group[0]
                        ),
                    });
                    diverged = true;
                    break;
                }
            }
            if !diverged && reference.len() != seq.len() {
                out.push(Defect {
                    kind: DefectKind::CollectiveOrder,
                    world: w.name.clone(),
                    rank: m,
                    peer: Some(group[0]),
                    tag: None,
                    op: format!("{} collective(s) on group {group:?}", seq.len()),
                    detail: format!(
                        "rank {} issues {} collective(s) on the same group",
                        group[0],
                        reference.len()
                    ),
                });
            }
        }
    }
}

/// Check 4: abstract execution — sends never block, receives block on an
/// empty per-channel FIFO, collective markers are free (the real
/// collectives are already decomposed into the surrounding sends/recvs).
/// If the system stops progressing before every stream drains, report
/// the wait-for cycles / starvations among the stuck ranks.
fn check_deadlock(w: &WorldOps, out: &mut Vec<Defect>) {
    let n = w.ranks.len();
    let mut pc = vec![0usize; n];
    let mut queued: HashMap<(usize, usize), usize> = HashMap::new();
    let mut progress = true;
    while progress {
        progress = false;
        for r in 0..n {
            while let Some(op) = w.ranks[r].get(pc[r]) {
                match op {
                    ScheduleOp::Send { to, .. } => {
                        *queued.entry((r, *to)).or_insert(0) += 1;
                    }
                    ScheduleOp::Recv { from, .. } => {
                        let slot = queued.entry((*from, r)).or_insert(0);
                        if *slot == 0 {
                            break; // blocked: nothing queued on this channel
                        }
                        *slot -= 1;
                    }
                    ScheduleOp::Collective { .. } => {}
                }
                pc[r] += 1;
                progress = true;
            }
        }
    }

    let stuck: Vec<usize> = (0..n).filter(|&r| pc[r] < w.ranks[r].len()).collect();
    if stuck.is_empty() {
        return;
    }
    // Each stuck rank blocks on exactly one receive; follow the wait-for
    // edges to classify starvation (peer finished) vs genuine cycles.
    let wait_on = |r: usize| -> (usize, MsgTag, String) {
        match &w.ranks[r][pc[r]] {
            ScheduleOp::Recv { from, elems, tag } => {
                (*from, *tag, format!("recv {elems} f32 [{tag}] <- rank {from}"))
            }
            op => unreachable!("stuck on non-blocking op {op:?}"),
        }
    };
    let is_stuck = |r: usize| pc[r] < w.ranks[r].len();
    for &r in &stuck {
        let (from, tag, op) = wait_on(r);
        if !is_stuck(from) {
            out.push(Defect {
                kind: DefectKind::Deadlock,
                world: w.name.clone(),
                rank: r,
                peer: Some(from),
                tag: Some(tag),
                op,
                detail: format!(
                    "rank {r} blocks forever: rank {from} completed its \
                     schedule without sending the awaited message"
                ),
            });
        }
    }
    // cycle detection on the out-degree-1 wait graph restricted to stuck
    // ranks; report each cycle once, anchored at its smallest rank
    let mut color = vec![0u8; n]; // 0 = unvisited, 1 = on path, 2 = done
    for &start in &stuck {
        if color[start] != 0 {
            continue;
        }
        let mut path = Vec::new();
        let mut cur = start;
        while is_stuck(cur) && color[cur] == 0 {
            color[cur] = 1;
            path.push(cur);
            cur = wait_on(cur).0;
        }
        if is_stuck(cur) && color[cur] == 1 {
            let at = path.iter().position(|&x| x == cur).unwrap();
            let cycle = &path[at..];
            let anchor = *cycle.iter().min().unwrap();
            let (peer, tag, op) = wait_on(anchor);
            let chain: Vec<String> =
                cycle.iter().map(|r| format!("rank {r}")).collect();
            out.push(Defect {
                kind: DefectKind::Deadlock,
                world: w.name.clone(),
                rank: anchor,
                peer: Some(peer),
                tag: Some(tag),
                op,
                detail: format!(
                    "wait-for cycle: {} -> {}",
                    chain.join(" -> "),
                    chain[0]
                ),
            });
        }
        for &r in &path {
            color[r] = 2;
        }
    }
}

/// Check 5: replay one rank's pool event log through the free-list state
/// machine. `Put` of a pointer already free = double return; `Use` of a
/// pointer currently free = use-after-return. `Evict` retires an address
/// (the allocator may reuse it), and a `Put` of an unknown pointer is a
/// legal first return of a buffer the pool never vended.
fn check_pool(rank: usize, log: &[PoolEvent], out: &mut Vec<Defect>) {
    #[derive(PartialEq)]
    enum St {
        Free,
        Out,
    }
    let mut state: HashMap<usize, St> = HashMap::new();
    for (i, ev) in log.iter().enumerate() {
        match *ev {
            PoolEvent::Take { ptr, .. } => {
                state.insert(ptr, St::Out);
            }
            PoolEvent::Put { ptr, len } => {
                if state.get(&ptr) == Some(&St::Free) {
                    out.push(Defect {
                        kind: DefectKind::PoolDoubleReturn,
                        world: "pool".to_string(),
                        rank,
                        peer: None,
                        tag: None,
                        op: format!("put #{i}: {len} f32 @ {ptr:#x}"),
                        detail: "buffer returned while already in a free list"
                            .to_string(),
                    });
                }
                state.insert(ptr, St::Free);
            }
            PoolEvent::Evict { ptr, .. } => {
                state.remove(&ptr);
            }
            PoolEvent::Use { ptr, len } => {
                if state.get(&ptr) == Some(&St::Free) {
                    out.push(Defect {
                        kind: DefectKind::PoolUseAfterReturn,
                        world: "pool".to_string(),
                        rank,
                        peer: None,
                        tag: None,
                        op: format!("use #{i}: {len} f32 @ {ptr:#x}"),
                        detail: "buffer touched while sitting in a free list"
                            .to_string(),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Collective;

    fn world(ranks: Vec<Vec<ScheduleOp>>) -> WorldOps {
        WorldOps { name: "test".to_string(), size: ranks.len(), ranks }
    }

    fn send(to: usize, elems: usize, tag: MsgTag) -> ScheduleOp {
        ScheduleOp::Send { to, elems, tag }
    }

    fn recv(from: usize, elems: usize, tag: MsgTag) -> ScheduleOp {
        ScheduleOp::Recv { from, elems, tag }
    }

    fn check(w: WorldOps) -> Vec<Defect> {
        check_schedule(&Schedule { worlds: vec![w], pool_logs: vec![] })
    }

    #[test]
    fn clean_pingpong_has_no_defects() {
        let h = MsgTag::Halo(0);
        let w = world(vec![
            vec![send(1, 8, h), recv(1, 8, h)],
            vec![send(0, 8, h), recv(0, 8, h)],
        ]);
        assert!(check(w).is_empty());
    }

    #[test]
    fn missing_recv_is_unmatched_send() {
        let h = MsgTag::Halo(0);
        let w = world(vec![vec![send(1, 8, h)], vec![]]);
        let d = check(w);
        assert!(d.iter().any(|x| x.kind == DefectKind::UnmatchedSend
            && x.rank == 0
            && x.peer == Some(1)));
    }

    #[test]
    fn tag_class_mismatch_is_aliasing() {
        let w = world(vec![
            vec![send(1, 8, MsgTag::Redist)],
            vec![recv(0, 8, MsgTag::Halo(1))],
        ]);
        let d = check(w);
        assert!(d.iter().any(|x| x.kind == DefectKind::TagAliasing));
        let w = world(vec![
            vec![send(1, 8, MsgTag::Halo(0))],
            vec![recv(0, 8, MsgTag::Halo(1))],
        ]);
        let d = check(w);
        assert!(d.iter().any(|x| x.kind == DefectKind::TagMismatch));
    }

    #[test]
    fn mutual_recv_first_is_a_cycle() {
        let g = MsgTag::Generic;
        let w = world(vec![
            vec![recv(1, 4, g), send(1, 4, g)],
            vec![recv(0, 4, g), send(0, 4, g)],
        ]);
        let d = check(w);
        let dl: Vec<_> =
            d.iter().filter(|x| x.kind == DefectKind::Deadlock).collect();
        assert_eq!(dl.len(), 1, "one cycle reported once: {d:?}");
        assert!(dl[0].detail.contains("cycle"));
    }

    #[test]
    fn collective_divergence_kinds() {
        let grp = vec![0usize, 1];
        let c = |op, elems| ScheduleOp::Collective { op, elems, group: grp.clone() };
        // order divergence
        let w = world(vec![
            vec![c(Collective::AllreduceRd, 9), c(Collective::AllreduceRing, 1)],
            vec![c(Collective::AllreduceRing, 1), c(Collective::AllreduceRd, 9)],
        ]);
        assert!(check(w).iter().any(|x| x.kind == DefectKind::CollectiveOrder));
        // size divergence
        let w = world(vec![
            vec![c(Collective::AllreduceRd, 9)],
            vec![c(Collective::AllreduceRd, 10)],
        ]);
        assert!(check(w).iter().any(|x| x.kind == DefectKind::CollectiveSize));
    }

    #[test]
    fn pool_discipline_violations() {
        let logs = vec![vec![
            PoolEvent::Take { ptr: 0x10, len: 4 },
            PoolEvent::Put { ptr: 0x10, len: 4 },
            PoolEvent::Use { ptr: 0x10, len: 4 },
            PoolEvent::Put { ptr: 0x10, len: 4 },
        ]];
        let d = check_schedule(&Schedule { worlds: vec![], pool_logs: logs });
        assert!(d.iter().any(|x| x.kind == DefectKind::PoolUseAfterReturn));
        assert!(d.iter().any(|x| x.kind == DefectKind::PoolDoubleReturn));
        // evict retires the address: a fresh Take/Put at the same ptr is fine
        let logs = vec![vec![
            PoolEvent::Take { ptr: 0x20, len: 4 },
            PoolEvent::Evict { ptr: 0x20, len: 4 },
            PoolEvent::Take { ptr: 0x20, len: 8 },
            PoolEvent::Put { ptr: 0x20, len: 8 },
        ]];
        let d = check_schedule(&Schedule { worlds: vec![], pool_logs: logs });
        assert!(d.is_empty(), "{d:?}");
    }
}
