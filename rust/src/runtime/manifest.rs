//! Typed view of `artifacts/manifest.json` — the single source of truth
//! shared between the Python AOT compiler and the Rust engine.

use crate::partition::SpatialGrid;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One AOT executable: HLO file + its signature.
#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// Fused whole-model executables of one model.
#[derive(Clone, Debug)]
pub struct FusedInfo {
    pub train_step: String,
    pub predict: String,
    /// per-call batch the fused graphs were lowered at
    pub batch: usize,
    pub n_masks: usize,
    pub n_bn: usize,
}

/// A layer of the (hybrid) execution plan. Entry-name fields are `None` in
/// the generic plan and populated in per-ways hybrid plans.
#[derive(Clone, Debug)]
pub enum LayerDesc {
    Conv {
        tag: String,
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
        d: usize,
        h: usize,
        w: usize,
        halo: usize,
        fwd: Option<String>,
        bwd_data: Option<String>,
        bwd_filter: Option<String>,
    },
    Deconv {
        tag: String,
        cin: usize,
        cout: usize,
        d: usize,
        h: usize,
        w: usize,
        fwd: Option<String>,
        bwd_data: Option<String>,
        bwd_filter: Option<String>,
    },
    Pool {
        op: String,
        c: usize,
        d: usize,
        h: usize,
        w: usize,
        fwd: Option<String>,
        bwd: Option<String>,
    },
    Bn {
        tag: String,
        c: usize,
        d: usize,
        h: usize,
        w: usize,
        apply: Option<String>,
        bwd_partials: Option<String>,
        bwd_apply: Option<String>,
    },
    Act { c: usize, d: usize, h: usize, w: usize },
    Flatten { c: usize, d: usize, h: usize, w: usize },
    SaveSkip { slot: usize, c: usize, d: usize, h: usize, w: usize },
    ConcatSkip { slot: usize, c_skip: usize, c_up: usize, d: usize, h: usize, w: usize },
    Fc {
        tag: String,
        fin: usize,
        fout: usize,
        act: bool,
        dropout: bool,
        fwd: Option<String>,
        bwd: Option<String>,
    },
    Mse { n: usize, fwd_bwd: Option<String> },
    Xent { n_classes: usize, d: usize, h: usize, w: usize, fwd_bwd: Option<String> },
}

/// One model's metadata.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub kind: String, // "cosmoflow" | "unet"
    pub input_size: usize,
    pub in_channels: usize,
    pub use_bn: bool,
    /// ordered (name, shape) — grads in train_step mirror this order
    pub params: Vec<(String, Vec<usize>)>,
    pub bn_layers: Vec<String>,
    pub plan: Vec<LayerDesc>,
    pub fused: FusedInfo,
    /// ways -> depth-partitioned plan with executable entry names
    pub hybrid: HashMap<usize, Vec<LayerDesc>>,
    /// "dxhxw" -> 3D-grid plan (executables halo-padded on all three
    /// axes); keys with an `x` in the manifest's `hybrid` table land here
    pub hybrid_grid: HashMap<String, Vec<LayerDesc>>,
    pub n_targets: usize,
    pub n_classes: usize,
    pub dropout_keep: f64,
}

impl ModelInfo {
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|(n, _)| n == name)
    }

    /// Execution plan + halo-padded axes for a spatial `grid`: depth-only
    /// grids use the per-ways depth plans (executables pad D only, H/W
    /// "same"-padded inside the kernels); true 3D grids use the
    /// `dxhxw`-keyed grid plans (valid convs, halo-padded on all axes).
    pub fn hybrid_plan(&self, grid: &SpatialGrid)
                       -> Result<(&Vec<LayerDesc>, [bool; 3])> {
        if grid.is_depth_only() {
            self.hybrid
                .get(&grid.d)
                .map(|p| (p, [true, false, false]))
                .ok_or_else(|| {
                    anyhow!("model {} has no {}-way depth shard set (rebuild \
                             artifacts)", self.name, grid.d)
                })
        } else {
            self.hybrid_grid
                .get(&grid.key())
                .map(|p| (p, [true, true, true]))
                .ok_or_else(|| {
                    anyhow!("model {} has no {} grid shard set (rebuild \
                             artifacts with this grid in aot.py GRID_SETS)",
                            self.name, grid.key())
                })
        }
    }

    /// BN channel widths in forward order.
    pub fn bn_channels(&self) -> Vec<usize> {
        self.bn_layers
            .iter()
            .map(|l| {
                self.params
                    .iter()
                    .find(|(n, _)| *n == format!("{l}.gamma"))
                    .map(|(_, s)| s[0])
                    .expect("bn layer without gamma")
            })
            .collect()
    }
}

/// The whole manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: HashMap<String, Entry>,
    pub models: HashMap<String, ModelInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let v = Json::parse_file(&dir.join("manifest.json"))?;
        if v.req("version")?.as_usize()? != 1 {
            bail!("unsupported manifest version");
        }
        let mut entries = HashMap::new();
        for (name, e) in v.req("entries")?.as_obj()? {
            let inputs = e.req("inputs")?.as_arr()?.iter()
                .map(|s| s.as_shape()).collect::<Result<Vec<_>>>()?;
            let outputs = e.req("outputs")?.as_arr()?.iter()
                .map(|s| s.as_shape()).collect::<Result<Vec<_>>>()?;
            entries.insert(name.clone(), Entry {
                name: name.clone(),
                file: dir.join(e.req("file")?.as_str()?),
                inputs,
                outputs,
            });
        }
        let mut models = HashMap::new();
        for (name, m) in v.req("models")?.as_obj()? {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries, models })
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries.get(name).ok_or_else(|| anyhow!("no entry {name:?}"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).ok_or_else(|| anyhow!("no model {name:?}"))
    }
}

fn parse_model(name: &str, m: &Json) -> Result<ModelInfo> {
    let params = m.req("params")?.as_arr()?.iter()
        .map(|p| {
            let p = p.as_arr()?;
            Ok((p[0].as_str()?.to_string(), p[1].as_shape()?))
        })
        .collect::<Result<Vec<_>>>()?;
    let bn_layers = m.req("bn_layers")?.as_arr()?.iter()
        .map(|s| Ok(s.as_str()?.to_string()))
        .collect::<Result<Vec<_>>>()?;
    let f = m.req("fused")?;
    let fused = FusedInfo {
        train_step: f.req("train_step")?.as_str()?.to_string(),
        predict: f.req("predict")?.as_str()?.to_string(),
        batch: f.req("batch")?.as_usize()?,
        n_masks: f.req("n_masks")?.as_usize()?,
        n_bn: f.req("n_bn")?.as_usize()?,
    };
    let plan = m.req("plan")?.as_arr()?.iter()
        .map(parse_layer)
        .collect::<Result<Vec<_>>>()?;
    let mut hybrid = HashMap::new();
    let mut hybrid_grid = HashMap::new();
    for (key, p) in m.req("hybrid")?.as_obj()? {
        let plan = p.as_arr()?.iter().map(parse_layer).collect::<Result<Vec<_>>>()?;
        match key.parse::<usize>() {
            Ok(w) => {
                hybrid.insert(w, plan);
            }
            Err(_) => {
                // validate the dxhxw key eagerly so a malformed manifest
                // fails at load, not at plan lookup
                let grid = SpatialGrid::parse(key)
                    .map_err(|e| anyhow!("model {name}: hybrid key {key:?}: {e}"))?;
                hybrid_grid.insert(grid.key(), plan);
            }
        }
    }
    Ok(ModelInfo {
        name: name.to_string(),
        kind: m.req("kind")?.as_str()?.to_string(),
        input_size: m.req("input_size")?.as_usize()?,
        in_channels: m.req("in_channels")?.as_usize()?,
        use_bn: m.req("use_bn")?.as_bool()?,
        params,
        bn_layers,
        plan,
        fused,
        hybrid,
        hybrid_grid,
        n_targets: m.get("n_targets").map(|v| v.as_usize()).transpose()?.unwrap_or(0),
        n_classes: m.get("n_classes").map(|v| v.as_usize()).transpose()?.unwrap_or(0),
        dropout_keep: m.get("dropout_keep").map(|v| v.as_f64()).transpose()?
            .unwrap_or(1.0),
    })
}

fn parse_layer(l: &Json) -> Result<LayerDesc> {
    let kind = l.req("kind")?.as_str()?;
    let u = |k: &str| -> Result<usize> { l.req(k)?.as_usize() };
    let opt = |k: &str| -> Option<String> {
        l.get(k).and_then(|v| v.as_str().ok()).map(str::to_string)
    };
    let tag = || opt("tag").unwrap_or_default();
    Ok(match kind {
        "conv" => LayerDesc::Conv {
            tag: tag(),
            cin: u("cin")?,
            cout: u("cout")?,
            k: u("k")?,
            stride: u("stride")?,
            d: u("d")?,
            h: u("h")?,
            w: u("w")?,
            halo: l.get("halo").map(|v| v.as_usize()).transpose()?
                .unwrap_or((u("k")? - 1) / 2),
            fwd: opt("fwd"),
            bwd_data: opt("bwd_data"),
            bwd_filter: opt("bwd_filter"),
        },
        "deconv" => LayerDesc::Deconv {
            tag: tag(),
            cin: u("cin")?,
            cout: u("cout")?,
            d: u("d")?,
            h: u("h")?,
            w: u("w")?,
            fwd: opt("fwd"),
            bwd_data: opt("bwd_data"),
            bwd_filter: opt("bwd_filter"),
        },
        "pool" => LayerDesc::Pool {
            op: l.req("op")?.as_str()?.to_string(),
            c: u("c")?,
            d: u("d")?,
            h: u("h")?,
            w: u("w")?,
            fwd: opt("fwd"),
            bwd: opt("bwd"),
        },
        "bn" => LayerDesc::Bn {
            tag: tag(),
            c: u("c")?,
            d: u("d")?,
            h: u("h")?,
            w: u("w")?,
            apply: opt("apply"),
            bwd_partials: opt("bwd_partials"),
            bwd_apply: opt("bwd_apply"),
        },
        "act" => LayerDesc::Act { c: u("c")?, d: u("d")?, h: u("h")?, w: u("w")? },
        "flatten" => LayerDesc::Flatten { c: u("c")?, d: u("d")?, h: u("h")?, w: u("w")? },
        "save_skip" => LayerDesc::SaveSkip {
            slot: u("slot")?, c: u("c")?, d: u("d")?, h: u("h")?, w: u("w")?,
        },
        "concat_skip" => LayerDesc::ConcatSkip {
            slot: u("slot")?,
            c_skip: u("c_skip")?,
            c_up: u("c_up")?,
            d: u("d")?,
            h: u("h")?,
            w: u("w")?,
        },
        "fc" => LayerDesc::Fc {
            tag: tag(),
            fin: u("fin")?,
            fout: u("fout")?,
            act: l.req("act")?.as_bool()?,
            dropout: l.req("dropout")?.as_bool()?,
            fwd: opt("fwd"),
            bwd: opt("bwd"),
        },
        "mse" => LayerDesc::Mse { n: u("n")?, fwd_bwd: opt("fwd_bwd") },
        "xent" => LayerDesc::Xent {
            n_classes: u("n_classes")?,
            d: u("d")?,
            h: u("h")?,
            w: u("w")?,
            fwd_bwd: opt("fwd_bwd"),
        },
        other => bail!("unknown layer kind {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn loads_repo_manifest() {
        let Some(dir) = repo_artifacts() else { return };
        let man = Manifest::load(&dir).unwrap();
        assert!(man.entries.len() > 100, "{}", man.entries.len());
        let m = man.model("cf16").unwrap();
        assert_eq!(m.kind, "cosmoflow");
        assert_eq!(m.input_size, 16);
        assert_eq!(m.n_targets, 4);
        assert!(!m.use_bn);
        assert!(m.hybrid.contains_key(&2));
        // every referenced entry file exists
        for e in man.entries.values() {
            assert!(e.file.exists(), "{:?}", e.file);
        }
    }

    #[test]
    fn hybrid_plan_entries_resolve() {
        let Some(dir) = repo_artifacts() else { return };
        let man = Manifest::load(&dir).unwrap();
        let m = man.model("cf16-bn").unwrap();
        for (ways, plan) in &m.hybrid {
            for l in plan {
                let names: Vec<Option<&String>> = match l {
                    LayerDesc::Conv { fwd, bwd_data, bwd_filter, .. } =>
                        vec![fwd.as_ref(), bwd_data.as_ref(), bwd_filter.as_ref()],
                    LayerDesc::Bn { apply, bwd_partials, bwd_apply, .. } =>
                        vec![apply.as_ref(), bwd_partials.as_ref(), bwd_apply.as_ref()],
                    LayerDesc::Pool { fwd, bwd, .. } => vec![fwd.as_ref(), bwd.as_ref()],
                    LayerDesc::Fc { fwd, bwd, .. } => vec![fwd.as_ref(), bwd.as_ref()],
                    LayerDesc::Mse { fwd_bwd, .. } => vec![fwd_bwd.as_ref()],
                    _ => vec![],
                };
                for n in names.into_iter().flatten() {
                    assert!(man.entries.contains_key(n), "ways={ways}: {n}");
                }
            }
        }
    }

    #[test]
    fn bn_channels_match_plan() {
        let Some(dir) = repo_artifacts() else { return };
        let man = Manifest::load(&dir).unwrap();
        let m = man.model("cf16-bn").unwrap();
        assert_eq!(m.bn_channels(), vec![16, 32]);
        assert_eq!(m.fused.n_bn, 2);
    }
}
