//! Bit-exact checkpoint/restart (ROADMAP item 5a; paper §V operability).
//!
//! A training world checkpoints everything a resumed world needs to
//! continue the *exact* trajectory: replicated parameters, Adam moments
//! and step count, BN running statistics, and the loss records produced so
//! far. The schedule itself is never stored — `sample_schedule_epochs`,
//! `LrSchedule::at` and the dropout instances are all pure functions of
//! the absolute step index, so the shuffle/RNG "cursor" is simply the next
//! step number, and resume-equals-uninterrupted holds at the bits level.
//!
//! On-disk layout under the checkpoint directory:
//!
//! ```text
//! step-<N>.tmp/            written by all ranks (rank 0 adds meta.json)
//! step-<N>/                after rank 0's atomic rename
//! step-<N>/COMMITTED       marker, written last — the commit point
//! ```
//!
//! The commit protocol is rank-0-coordinated: every rank writes its own
//! shard into the temp directory, the world barriers, and only then does
//! rank 0 rename the directory and drop the marker. A crash at any point
//! leaves either a fully committed snapshot or an ignorable temp
//! directory — never a torn snapshot a loader could trust.
//!
//! Shards are per-rank and keyed by the rank's grid geometry (group,
//! (D, H, W) coordinates, hyperslab offset/extents — the same `Grid4`-style
//! shard geometry the data store uses), serialized with the little-endian
//! `to_le_bytes` framing of `comm::socket` and closed by an order-sensitive
//! FNV-1a checksum over the exact bytes. Loading validates magic, version,
//! geometry, tensor shapes and checksum; [`resolve_resume`] walks committed
//! snapshots newest-first and falls back past any snapshot that fails
//! validation (e.g. a hand-truncated shard).

use crate::engine::StepRecord;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Bump on any change to the shard byte layout or meta schema.
pub const CKPT_VERSION: u32 = 1;
/// Shard file magic ("hydra3d checkpoint").
const MAGIC: &[u8; 4] = b"H3CK";
/// Marker file inside a committed snapshot directory (written last).
pub const MARKER_FILE: &str = "COMMITTED";
/// Snapshot metadata file (rank 0 writes it with the shards).
pub const META_FILE: &str = "meta.json";

/// Checkpoint configuration threaded through the engines' options.
#[derive(Clone, Debug)]
pub struct CheckpointCfg {
    /// Snapshot directory (shared by all ranks/processes of the world).
    pub dir: PathBuf,
    /// Save a snapshot every N steps (and at the final step); 0 disables
    /// periodic saves (useful for resume-only runs).
    pub every: usize,
    /// Resume from the newest valid committed snapshot if one exists
    /// (start fresh otherwise).
    pub resume: bool,
}

/// One rank's shard geometry — the key a shard is validated against on
/// load, mirroring the data store's grid-keyed hyperslab layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardGeom {
    pub rank: usize,
    pub world: usize,
    pub group: usize,
    /// (D, H, W) position in the spatial process grid.
    pub coords: [usize; 3],
    /// Hyperslab offset of this rank's shard in the global volume.
    pub shard_off: [usize; 3],
    /// Hyperslab extents of this rank's shard.
    pub shard_len: [usize; 3],
}

/// Run-configuration fingerprint stored in `meta.json` and validated on
/// resume: a snapshot of one configuration must never silently seed a
/// different one (the trajectory would not be the uninterrupted run's).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Fingerprint {
    pub model: String,
    /// `SpatialGrid` key, e.g. "2x1x1".
    pub grid: String,
    pub groups: usize,
    pub batch_global: usize,
    /// Total steps of the run (the LR schedule depends on it).
    pub steps: usize,
    pub seed: u64,
    pub world: usize,
}

/// Borrowed view of everything one rank saves (the loader returns the
/// owned [`RankState`]).
pub struct SaveState<'a> {
    /// First step the resumed world should execute.
    pub next_step: usize,
    pub adam_t: u64,
    pub records: &'a [StepRecord],
    pub params: &'a [Tensor],
    pub adam_m: &'a [Tensor],
    pub adam_v: &'a [Tensor],
    pub run_mean: &'a [Tensor],
    pub run_var: &'a [Tensor],
}

/// One rank's restored state.
#[derive(Debug)]
pub struct RankState {
    pub next_step: usize,
    pub adam_t: u64,
    pub records: Vec<StepRecord>,
    pub params: Vec<Tensor>,
    pub adam_m: Vec<Tensor>,
    pub adam_v: Vec<Tensor>,
    pub run_mean: Vec<Tensor>,
    pub run_var: Vec<Tensor>,
}

/// Committed snapshot directory for `step`.
pub fn step_dir(dir: &Path, step: usize) -> PathBuf {
    dir.join(format!("step-{step}"))
}

fn tmp_dir(dir: &Path, step: usize) -> PathBuf {
    dir.join(format!("step-{step}.tmp"))
}

/// Shard file of `rank` inside a snapshot directory.
pub fn shard_path(snapshot: &Path, rank: usize) -> PathBuf {
    snapshot.join(format!("rank-{rank}.bin"))
}

// ---------------------------------------------------------------------------
// little-endian framing (the `comm::socket::write_frame` idiom: serialize
// into one scratch buffer, then a single write)
// ---------------------------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn tensor(&mut self, t: &Tensor) {
        let shape = t.shape();
        self.u32(shape.len() as u32);
        for &d in shape {
            self.u32(d as u32);
        }
        for &v in t.data() {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn tensors(&mut self, ts: &[Tensor]) {
        self.u32(ts.len() as u32);
        for t in ts {
            self.tensor(t);
        }
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.off + n > self.buf.len() {
            bail!("shard truncated at byte {} (wanted {n} more of {})",
                  self.off, self.buf.len());
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn tensor(&mut self) -> Result<Tensor> {
        let ndim = self.u32()? as usize;
        if ndim > 8 {
            bail!("implausible tensor rank {ndim} (corrupt shard)");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(self.u32()? as usize);
        }
        let elems: usize = shape.iter().product();
        let raw = self.take(4 * elems)?;
        let data = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Tensor::from_vec(&shape, data))
    }

    fn tensors(&mut self) -> Result<Vec<Tensor>> {
        let n = self.u32()? as usize;
        if n > 100_000 {
            bail!("implausible tensor count {n} (corrupt shard)");
        }
        (0..n).map(|_| self.tensor()).collect()
    }
}

/// Order-sensitive FNV-1a over the shard payload — rejects torn or
/// bit-flipped shards that still parse structurally.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// shard read/write
// ---------------------------------------------------------------------------

fn encode_shard(geom: &ShardGeom, st: &SaveState<'_>) -> Vec<u8> {
    let mut e = Enc::new();
    e.buf.extend_from_slice(MAGIC);
    e.u32(CKPT_VERSION);
    e.u32(geom.rank as u32);
    e.u32(geom.world as u32);
    e.u32(geom.group as u32);
    for &c in geom.coords.iter().chain(&geom.shard_off).chain(&geom.shard_len) {
        e.u32(c as u32);
    }
    e.u64(st.next_step as u64);
    e.u64(st.adam_t);
    e.u32(st.records.len() as u32);
    for r in st.records {
        e.u64(r.step as u64);
        e.u32(r.loss.to_bits());
        e.u64(r.lr.to_bits());
        e.u64(r.io_wait.to_bits());
    }
    e.tensors(st.params);
    e.tensors(st.adam_m);
    e.tensors(st.adam_v);
    e.tensors(st.run_mean);
    e.tensors(st.run_var);
    let cs = fnv1a(&e.buf);
    e.u64(cs);
    e.buf
}

fn decode_shard(bytes: &[u8], expect: &ShardGeom) -> Result<RankState> {
    if bytes.len() < MAGIC.len() + 8 {
        bail!("shard too short ({} bytes)", bytes.len());
    }
    let (payload, tail) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(tail.try_into().unwrap());
    let computed = fnv1a(payload);
    if stored != computed {
        bail!("shard checksum mismatch (stored {stored:016x}, computed \
               {computed:016x}) — torn or corrupt snapshot");
    }
    let mut d = Dec { buf: payload, off: 0 };
    if d.take(4)? != MAGIC {
        bail!("bad shard magic");
    }
    let version = d.u32()?;
    if version != CKPT_VERSION {
        bail!("shard version {version} != supported {CKPT_VERSION}");
    }
    let geom = ShardGeom {
        rank: d.u32()? as usize,
        world: d.u32()? as usize,
        group: d.u32()? as usize,
        coords: [d.u32()? as usize, d.u32()? as usize, d.u32()? as usize],
        shard_off: [d.u32()? as usize, d.u32()? as usize, d.u32()? as usize],
        shard_len: [d.u32()? as usize, d.u32()? as usize, d.u32()? as usize],
    };
    if geom != *expect {
        bail!("shard geometry {geom:?} does not match this rank's {expect:?} \
               (grid/topology changed since the snapshot)");
    }
    let next_step = d.u64()? as usize;
    let adam_t = d.u64()?;
    let n_rec = d.u32()? as usize;
    if n_rec > next_step {
        bail!("{n_rec} records for a step-{next_step} snapshot");
    }
    let mut records = Vec::with_capacity(n_rec);
    for _ in 0..n_rec {
        records.push(StepRecord {
            step: d.u64()? as usize,
            loss: f32::from_bits(d.u32()?),
            lr: f64::from_bits(d.u64()?),
            io_wait: f64::from_bits(d.u64()?),
        });
    }
    let params = d.tensors()?;
    let adam_m = d.tensors()?;
    let adam_v = d.tensors()?;
    let run_mean = d.tensors()?;
    let run_var = d.tensors()?;
    if d.off != payload.len() {
        bail!("{} trailing bytes after shard payload", payload.len() - d.off);
    }
    if adam_m.len() != params.len() || adam_v.len() != params.len() {
        bail!("Adam moment count does not match parameter count");
    }
    Ok(RankState {
        next_step,
        adam_t,
        records,
        params,
        adam_m,
        adam_v,
        run_mean,
        run_var,
    })
}

// ---------------------------------------------------------------------------
// commit protocol
// ---------------------------------------------------------------------------

/// Ensure the temp directory for a `step` snapshot exists (idempotent —
/// every rank calls it before writing its shard; processes on a shared
/// filesystem race benignly).
pub fn begin(dir: &Path, step: usize) -> Result<PathBuf> {
    let tmp = tmp_dir(dir, step);
    std::fs::create_dir_all(&tmp)
        .with_context(|| format!("create {}", tmp.display()))?;
    Ok(tmp)
}

/// Rank 0: write the snapshot metadata into the temp directory.
pub fn write_meta(dir: &Path, step: usize, fp: &Fingerprint) -> Result<()> {
    use crate::util::json::obj;
    let doc = obj(vec![
        ("schema", 1usize.into()),
        ("version", (CKPT_VERSION as usize).into()),
        ("step", step.into()),
        ("model", fp.model.as_str().into()),
        ("grid", fp.grid.as_str().into()),
        ("groups", fp.groups.into()),
        ("batch_global", fp.batch_global.into()),
        ("steps", fp.steps.into()),
        ("seed", (fp.seed as usize).into()),
        ("world", fp.world.into()),
    ]);
    let path = tmp_dir(dir, step).join(META_FILE);
    std::fs::write(&path, doc.to_string())
        .with_context(|| format!("write {}", path.display()))?;
    Ok(())
}

/// Every rank: serialize its state into the temp directory in one write.
pub fn write_shard(dir: &Path, step: usize, geom: &ShardGeom,
                   st: &SaveState<'_>) -> Result<()> {
    let bytes = encode_shard(geom, st);
    let path = shard_path(&tmp_dir(dir, step), geom.rank);
    std::fs::write(&path, bytes)
        .with_context(|| format!("write {}", path.display()))?;
    Ok(())
}

/// Rank 0, after the post-write barrier: atomically publish the snapshot
/// (rename temp → final, then drop the marker). If the snapshot was
/// already committed by an earlier run of the same configuration the bits
/// are identical by determinism, so the temp copy is simply discarded.
pub fn commit(dir: &Path, step: usize) -> Result<()> {
    let tmp = tmp_dir(dir, step);
    let fin = step_dir(dir, step);
    if fin.join(MARKER_FILE).exists() {
        std::fs::remove_dir_all(&tmp).ok();
        return Ok(());
    }
    if fin.exists() {
        // a final dir without a marker is a previous crash between rename
        // and marker: discard it, this snapshot supersedes it bit-for-bit
        std::fs::remove_dir_all(&fin)
            .with_context(|| format!("clear stale {}", fin.display()))?;
    }
    std::fs::rename(&tmp, &fin)
        .with_context(|| format!("commit {} -> {}", tmp.display(), fin.display()))?;
    std::fs::write(fin.join(MARKER_FILE), format!("step {step}\n"))
        .with_context(|| format!("write marker in {}", fin.display()))?;
    Ok(())
}

/// Committed snapshot steps (marker present), newest first.
pub fn committed_steps(dir: &Path) -> Vec<usize> {
    let mut steps = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return steps;
    };
    for e in entries.flatten() {
        let name = e.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(num) = name.strip_prefix("step-") else { continue };
        let Ok(step) = num.parse::<usize>() else { continue };
        if e.path().join(MARKER_FILE).exists() {
            steps.push(step);
        }
    }
    steps.sort_unstable_by(|a, b| b.cmp(a));
    steps
}

/// Validate one committed snapshot end to end: meta fingerprint plus every
/// rank shard (checksum + geometry-independent structure).
fn validate_snapshot(dir: &Path, step: usize, fp: &Fingerprint) -> Result<()> {
    let snap = step_dir(dir, step);
    let meta = crate::util::json::Json::parse_file(&snap.join(META_FILE))
        .context("snapshot meta")?;
    let stored = Fingerprint {
        model: meta.req("model")?.as_str()?.to_string(),
        grid: meta.req("grid")?.as_str()?.to_string(),
        groups: meta.req("groups")?.as_usize()?,
        batch_global: meta.req("batch_global")?.as_usize()?,
        steps: meta.req("steps")?.as_usize()?,
        seed: meta.req("seed")?.as_usize()? as u64,
        world: meta.req("world")?.as_usize()?,
    };
    if stored != *fp {
        bail!("snapshot fingerprint {stored:?} does not match this run {fp:?}");
    }
    if meta.req("step")?.as_usize()? != step {
        bail!("snapshot directory step-{step} disagrees with its meta");
    }
    let ver = meta.req("version")?.as_usize()?;
    if ver != CKPT_VERSION as usize {
        bail!("snapshot version {ver} != supported {CKPT_VERSION}");
    }
    for rank in 0..fp.world {
        let path = shard_path(&snap, rank);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("read {}", path.display()))?;
        if bytes.len() < MAGIC.len() + 8 {
            bail!("rank {rank} shard too short");
        }
        let (payload, tail) = bytes.split_at(bytes.len() - 8);
        let stored_cs = u64::from_le_bytes(tail.try_into().unwrap());
        if stored_cs != fnv1a(payload) {
            bail!("rank {rank} shard checksum mismatch (torn snapshot)");
        }
    }
    Ok(())
}

/// Resolve the step a resuming world should restart from: the newest
/// committed snapshot whose meta fingerprint matches and whose shards all
/// pass checksum validation. Snapshots that fail validation are skipped
/// with a warning (fallback to the previous marker); `None` means start
/// fresh. Deterministic across processes — every node of a socket world
/// resolves the same step because nothing writes while worlds are down.
pub fn resolve_resume(dir: &Path, fp: &Fingerprint) -> Result<Option<usize>> {
    for step in committed_steps(dir) {
        match validate_snapshot(dir, step, fp) {
            Ok(()) => return Ok(Some(step)),
            Err(e) => {
                eprintln!(
                    "checkpoint: skipping snapshot step-{step} in {}: {e:#}",
                    dir.display()
                );
            }
        }
    }
    Ok(None)
}

/// Load this rank's shard of a resolved snapshot. Strict: by the time a
/// world agrees on a resume step via [`resolve_resume`], a shard that
/// fails here is a hard error (falling back per-rank would diverge ranks).
pub fn load_shard(dir: &Path, step: usize, geom: &ShardGeom) -> Result<RankState> {
    let path = shard_path(&step_dir(dir, step), geom.rank);
    let bytes = std::fs::read(&path)
        .with_context(|| format!("read {}", path.display()))?;
    let st = decode_shard(&bytes, geom)
        .with_context(|| format!("decode {}", path.display()))?;
    if st.next_step != step {
        bail!("shard {} is for step {}, directory says {step}",
              path.display(), st.next_step);
    }
    Ok(st)
}

/// Shape-check restored tensors against the live model's (manifest-derived)
/// layout before they replace anything.
pub fn check_shapes(st: &RankState, params: &[Tensor], run_mean: &[Tensor])
                    -> Result<()> {
    if st.params.len() != params.len() {
        bail!("snapshot has {} parameters, model has {}",
              st.params.len(), params.len());
    }
    for (i, (a, b)) in st.params.iter().zip(params).enumerate() {
        if a.shape() != b.shape() {
            bail!("parameter {i} shape {:?} != model shape {:?}",
                  a.shape(), b.shape());
        }
    }
    for (i, (a, b)) in st.adam_m.iter().zip(params).enumerate() {
        if a.shape() != b.shape() {
            bail!("Adam m[{i}] shape {:?} != model shape {:?}",
                  a.shape(), b.shape());
        }
    }
    if st.run_mean.len() != run_mean.len() || st.run_var.len() != run_mean.len() {
        bail!("snapshot has {} BN layers, model has {}",
              st.run_mean.len(), run_mean.len());
    }
    Ok(())
}

/// Convenience for the engines: the full rank-side save protocol minus the
/// barrier/commit, which the caller interleaves with its communicator.
pub fn save_rank(cfg: &CheckpointCfg, fp: &Fingerprint, geom: &ShardGeom,
                 st: &SaveState<'_>) -> Result<()> {
    begin(&cfg.dir, st.next_step)?;
    if geom.rank == 0 {
        write_meta(&cfg.dir, st.next_step, fp)?;
    }
    write_shard(&cfg.dir, st.next_step, geom, st)
        .with_context(|| format!("checkpoint step {}", st.next_step))
}

/// Should a snapshot be taken after `step` completes? Keyed on the
/// absolute step index so an interrupted and a resumed run checkpoint at
/// identical points (identical barrier traffic → identical byte counters).
pub fn due_after(cfg: &CheckpointCfg, step: usize, total_steps: usize) -> bool {
    cfg.every > 0 && ((step + 1) % cfg.every == 0 || step + 1 == total_steps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn geom(rank: usize, world: usize) -> ShardGeom {
        ShardGeom {
            rank,
            world,
            group: rank / 2,
            coords: [rank % 2, 0, 0],
            shard_off: [8 * (rank % 2), 0, 0],
            shard_len: [8, 16, 16],
        }
    }

    fn state(seed: u64, next_step: usize) -> RankState {
        let mut rng = crate::util::rng::Pcg::new(seed, 3);
        let mut t = |shape: &[usize]| {
            let n: usize = shape.iter().product();
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 1.5);
            Tensor::from_vec(shape, v)
        };
        let params = vec![t(&[4, 2, 3, 3, 3]), t(&[4]), t(&[10, 6])];
        let adam_m = vec![t(&[4, 2, 3, 3, 3]), t(&[4]), t(&[10, 6])];
        let adam_v = vec![t(&[4, 2, 3, 3, 3]), t(&[4]), t(&[10, 6])];
        RankState {
            next_step,
            adam_t: next_step as u64,
            records: (0..next_step)
                .map(|s| StepRecord {
                    step: s,
                    loss: (s as f32).sin(),
                    lr: 1e-3 / (s + 1) as f64,
                    io_wait: 0.25 * s as f64,
                })
                .collect(),
            params,
            adam_m,
            adam_v,
            run_mean: vec![t(&[4])],
            run_var: vec![t(&[4])],
        }
    }

    fn save_view(st: &RankState) -> SaveState<'_> {
        SaveState {
            next_step: st.next_step,
            adam_t: st.adam_t,
            records: &st.records,
            params: &st.params,
            adam_m: &st.adam_m,
            adam_v: &st.adam_v,
            run_mean: &st.run_mean,
            run_var: &st.run_var,
        }
    }

    fn fp(world: usize) -> Fingerprint {
        Fingerprint {
            model: "cf-nano".into(),
            grid: "2x1x1".into(),
            groups: world / 2,
            batch_global: 4,
            steps: 8,
            seed: 7,
            world,
        }
    }

    fn bits(ts: &[Tensor]) -> Vec<Vec<u32>> {
        ts.iter().map(|t| t.data().iter().map(|v| v.to_bits()).collect())
            .collect()
    }

    fn scratch(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("hydra3d-ckpt-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn commit_world(dir: &Path, step: usize, world: usize, seed: u64)
                    -> Result<()> {
        for rank in 0..world {
            let st = state(seed + rank as u64, step);
            save_rank(
                &CheckpointCfg { dir: dir.into(), every: 1, resume: true },
                &fp(world), &geom(rank, world), &save_view(&st),
            )?;
        }
        commit(dir, step)
    }

    #[test]
    fn roundtrip_bit_exact() {
        let dir = scratch("roundtrip");
        let world = 2;
        commit_world(&dir, 3, world, 11).unwrap();
        for rank in 0..world {
            let orig = state(11 + rank as u64, 3);
            let got = load_shard(&dir, 3, &geom(rank, world)).unwrap();
            assert_eq!(got.next_step, 3);
            assert_eq!(got.adam_t, 3);
            assert_eq!(bits(&got.params), bits(&orig.params));
            assert_eq!(bits(&got.adam_m), bits(&orig.adam_m));
            assert_eq!(bits(&got.adam_v), bits(&orig.adam_v));
            assert_eq!(bits(&got.run_mean), bits(&orig.run_mean));
            assert_eq!(bits(&got.run_var), bits(&orig.run_var));
            assert_eq!(got.records.len(), orig.records.len());
            for (a, b) in got.records.iter().zip(&orig.records) {
                assert_eq!(a.step, b.step);
                assert_eq!(a.loss.to_bits(), b.loss.to_bits());
                assert_eq!(a.lr.to_bits(), b.lr.to_bits());
                assert_eq!(a.io_wait.to_bits(), b.io_wait.to_bits());
            }
        }
        assert_eq!(resolve_resume(&dir, &fp(world)).unwrap(), Some(3));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// proptest: random shapes/values/geometry round-trip bit-identically
    /// (including negative zero, subnormals and extreme exponents from the
    /// normal generator).
    #[test]
    fn prop_shard_roundtrip_bits() {
        prop::check("ckpt-shard-roundtrip", 40, |g| {
            let n_params = g.usize_in(1, 5);
            let mut params = Vec::new();
            for _ in 0..n_params {
                let ndim = g.usize_in(1, 4);
                let shape: Vec<usize> =
                    (0..ndim).map(|_| g.usize_in(1, 6)).collect();
                let n: usize = shape.iter().product();
                params.push(Tensor::from_vec(&shape, g.vec_f32(n, 10.0)));
            }
            let clone_like = |g: &mut prop::Gen, ts: &[Tensor]| -> Vec<Tensor> {
                ts.iter()
                    .map(|t| Tensor::from_vec(t.shape(),
                                              g.vec_f32(t.numel(), 3.0)))
                    .collect()
            };
            let adam_m = clone_like(g, &params);
            let adam_v = clone_like(g, &params);
            let n_bn = g.usize_in(0, 3);
            let run_mean: Vec<Tensor> = (0..n_bn)
                .map(|_| {
                    let c = g.usize_in(1, 8);
                    Tensor::from_vec(&[c], g.vec_f32(c, 2.0))
                })
                .collect();
            let run_var: Vec<Tensor> = run_mean
                .iter()
                .map(|t| Tensor::from_vec(t.shape(), g.vec_f32(t.numel(), 2.0)))
                .collect();
            let next_step = g.usize_in(1, 9);
            let st = RankState {
                next_step,
                adam_t: next_step as u64,
                records: (0..next_step)
                    .map(|s| StepRecord {
                        step: s,
                        loss: g.f32_in(-1e6, 1e6),
                        lr: g.f32_in(0.0, 1.0) as f64,
                        io_wait: g.f32_in(0.0, 2.0) as f64,
                    })
                    .collect(),
                params,
                adam_m,
                adam_v,
                run_mean,
                run_var,
            };
            let world = g.pow2_in(1, 8);
            let gm = ShardGeom {
                rank: g.usize_in(0, world - 1),
                world,
                group: g.usize_in(0, 3),
                coords: [g.usize_in(0, 3), g.usize_in(0, 3), g.usize_in(0, 3)],
                shard_off: [g.usize_in(0, 64), 0, 0],
                shard_len: [g.usize_in(1, 64); 3],
            };
            let bytes = encode_shard(&gm, &save_view(&st));
            let got = decode_shard(&bytes, &gm).map_err(|e| e.to_string())?;
            if bits(&got.params) != bits(&st.params)
                || bits(&got.adam_m) != bits(&st.adam_m)
                || bits(&got.adam_v) != bits(&st.adam_v)
                || bits(&got.run_mean) != bits(&st.run_mean)
                || bits(&got.run_var) != bits(&st.run_var)
            {
                return Err("tensor bits drifted through the shard".into());
            }
            if got.next_step != st.next_step || got.adam_t != st.adam_t {
                return Err("cursor drifted".into());
            }
            for (a, b) in got.records.iter().zip(&st.records) {
                if a.loss.to_bits() != b.loss.to_bits()
                    || a.lr.to_bits() != b.lr.to_bits()
                {
                    return Err("record bits drifted".into());
                }
            }
            // wrong geometry must be rejected
            let mut other = gm;
            other.coords[1] += 1;
            if decode_shard(&bytes, &other).is_ok() {
                return Err("geometry mismatch accepted".into());
            }
            Ok(())
        });
    }

    /// Torn-write recovery: a truncated shard in the newest snapshot is
    /// rejected and resume falls back to the previous committed marker.
    #[test]
    fn torn_snapshot_falls_back_to_previous_marker() {
        let dir = scratch("torn");
        let world = 2;
        commit_world(&dir, 2, world, 5).unwrap();
        commit_world(&dir, 4, world, 6).unwrap();
        assert_eq!(resolve_resume(&dir, &fp(world)).unwrap(), Some(4));
        // tear the newest snapshot: truncate rank 1's shard mid-payload
        let victim = shard_path(&step_dir(&dir, 4), 1);
        let bytes = std::fs::read(&victim).unwrap();
        std::fs::write(&victim, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load_shard(&dir, 4, &geom(1, world)).is_err());
        assert_eq!(resolve_resume(&dir, &fp(world)).unwrap(), Some(2),
                   "must fall back past the torn snapshot");
        // a crash *before* commit leaves only a temp dir: invisible
        begin(&dir, 6).unwrap();
        write_shard(&dir, 6, &geom(0, world),
                    &save_view(&state(9, 6))).unwrap();
        assert_eq!(resolve_resume(&dir, &fp(world)).unwrap(), Some(2));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fingerprint_mismatch_is_skipped() {
        let dir = scratch("fp");
        commit_world(&dir, 2, 2, 5).unwrap();
        let mut other = fp(2);
        other.seed = 8;
        assert_eq!(resolve_resume(&dir, &other).unwrap(), None);
        let mut other = fp(2);
        other.grid = "1x1x1".into();
        assert_eq!(resolve_resume(&dir, &other).unwrap(), None);
        assert_eq!(resolve_resume(&dir, &fp(2)).unwrap(), Some(2));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bit_flip_is_rejected() {
        let dir = scratch("flip");
        commit_world(&dir, 2, 1, 3).unwrap();
        let victim = shard_path(&step_dir(&dir, 2), 0);
        let mut bytes = std::fs::read(&victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&victim, &bytes).unwrap();
        let err = load_shard(&dir, 2, &geom(0, 1)).unwrap_err().to_string();
        let root = format!("{:#}", load_shard(&dir, 2, &geom(0, 1)).unwrap_err());
        assert!(root.contains("checksum"), "{err}: {root}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn due_after_matches_cadence_and_final_step() {
        let c = CheckpointCfg { dir: "x".into(), every: 2, resume: false };
        let hits: Vec<usize> =
            (0..5).filter(|&s| due_after(&c, s, 5)).collect();
        assert_eq!(hits, vec![1, 3, 4]); // steps 2, 4 and the final step 5
        let off = CheckpointCfg { dir: "x".into(), every: 0, resume: true };
        assert!((0..5).all(|s| !due_after(&off, s, 5)));
    }
}
