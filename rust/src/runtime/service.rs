//! The PJRT runtime service thread and its cloneable handle.
//!
//! One OS thread owns the `PjRtClient`, the lazily-compiled executable
//! cache, and all `Literal` marshaling; engine rank threads submit
//! [`RuntimeHandle::call`]s over an mpsc channel. Executables compile on
//! first use (HLO text → `HloModuleProto` → `XlaComputation` → PJRT) and
//! are cached for the life of the service.

use super::manifest::Manifest;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Aggregated runtime statistics (perf pass + Fig 6-style accounting).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    /// entry -> (calls, total seconds, compile seconds)
    pub per_entry: HashMap<String, (u64, f64, f64)>,
}

impl RuntimeStats {
    pub fn total_exec_secs(&self) -> f64 {
        self.per_entry.values().map(|(_, t, _)| t).sum()
    }
    pub fn total_calls(&self) -> u64 {
        self.per_entry.values().map(|(c, _, _)| c).sum()
    }
    pub fn total_compile_secs(&self) -> f64 {
        self.per_entry.values().map(|(_, _, c)| c).sum()
    }
}

enum Request {
    Call { entry: String, inputs: Vec<Tensor>, reply: Sender<Result<Vec<Tensor>>> },
    Stats { reply: Sender<RuntimeStats> },
    /// Pre-compile an entry (warm the cache off the hot path).
    Warm { entry: String, reply: Sender<Result<()>> },
}

/// Cloneable handle to the runtime service.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Request>,
    manifest: Arc<Manifest>,
}

impl RuntimeHandle {
    /// Start the service for an artifact directory.
    pub fn start(artifacts_dir: &Path) -> Result<RuntimeHandle> {
        let manifest = Arc::new(Manifest::load(artifacts_dir)?);
        let (tx, rx) = channel::<Request>();
        let man = manifest.clone();
        std::thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || {
                let mut svc = match Service::new(man) {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("runtime service failed to start: {e:#}");
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    svc.handle(req);
                }
            })
            .context("spawn runtime thread")?;
        Ok(RuntimeHandle { tx, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute `entry` with the given inputs; returns its output tuple.
    pub fn call(&self, entry: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Call { entry: entry.to_string(), inputs, reply })
            .map_err(|_| anyhow!("runtime service is down"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }

    /// Compile an entry ahead of time.
    pub fn warm(&self, entry: &str) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Warm { entry: entry.to_string(), reply })
            .map_err(|_| anyhow!("runtime service is down"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }

    pub fn stats(&self) -> Result<RuntimeStats> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Stats { reply })
            .map_err(|_| anyhow!("runtime service is down"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))
    }
}

struct Service {
    manifest: Arc<Manifest>,
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    stats: RuntimeStats,
}

impl Service {
    fn new(manifest: Arc<Manifest>) -> Result<Service> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Service { manifest, client, cache: HashMap::new(), stats: RuntimeStats::default() })
    }

    fn handle(&mut self, req: Request) {
        match req {
            Request::Call { entry, inputs, reply } => {
                let res = self.call(&entry, inputs);
                let _ = reply.send(res);
            }
            Request::Warm { entry, reply } => {
                let res = self.ensure_compiled(&entry).map(|_| ());
                let _ = reply.send(res);
            }
            Request::Stats { reply } => {
                let _ = reply.send(self.stats.clone());
            }
        }
    }

    fn ensure_compiled(&mut self, entry: &str) -> Result<()> {
        if self.cache.contains_key(entry) {
            return Ok(());
        }
        let e = self.manifest.entry(entry)?;
        let t0 = Instant::now();
        let path = e.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?;
        // HLO *text* interchange: the 0.5.1 extension rejects jax>=0.5
        // serialized protos (64-bit ids); the text parser reassigns ids.
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|err| anyhow!("parse {path}: {err}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|err| anyhow!("compile {entry}: {err}"))?;
        let dt = t0.elapsed().as_secs_f64();
        let s = self.stats.per_entry.entry(entry.to_string()).or_default();
        s.2 += dt;
        self.cache.insert(entry.to_string(), exe);
        Ok(())
    }

    fn call(&mut self, entry: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        self.ensure_compiled(entry)?;
        let e = self.manifest.entry(entry)?.clone();
        if inputs.len() != e.inputs.len() {
            bail!("{entry}: got {} inputs, expected {}", inputs.len(), e.inputs.len());
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (t, want)) in inputs.iter().zip(&e.inputs).enumerate() {
            if t.shape() != &want[..] {
                bail!("{entry}: input {i} shape {:?}, expected {:?}", t.shape(), want);
            }
            let dims: Vec<i64> = want.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(t.data())
                .reshape(&dims)
                .map_err(|err| anyhow!("{entry}: reshape input {i}: {err}"))?;
            literals.push(lit);
        }
        let t0 = Instant::now();
        let exe = self.cache.get(entry).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|err| anyhow!("execute {entry}: {err}"))?;
        let root = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow!("{entry}: empty result"))?
            .to_literal_sync()
            .map_err(|err| anyhow!("{entry}: to_literal: {err}"))?;
        // aot.py lowers with return_tuple=True: always a tuple literal.
        let parts = root
            .to_tuple()
            .map_err(|err| anyhow!("{entry}: decompose tuple: {err}"))?;
        if parts.len() != e.outputs.len() {
            bail!("{entry}: got {} outputs, expected {}", parts.len(), e.outputs.len());
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, shape) in parts.into_iter().zip(&e.outputs) {
            let v = lit
                .to_vec::<f32>()
                .map_err(|err| anyhow!("{entry}: literal to_vec: {err}"))?;
            out.push(Tensor::from_vec(shape, v));
        }
        let dt = t0.elapsed().as_secs_f64();
        let s = self.stats.per_entry.entry(entry.to_string()).or_default();
        s.0 += 1;
        s.1 += dt;
        Ok(out)
    }
}
