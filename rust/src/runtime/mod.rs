//! PJRT runtime: load the AOT artifacts and execute them from Rust.
//!
//! Python is build-time only; this module is the entire compute backend of
//! the training path. It wraps the `xla` crate (PJRT C API, CPU client):
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//!
//! The `xla` crate's client types are `Rc`-based (not `Send`), while the
//! engine runs one thread per simulated GPU. All PJRT state therefore lives
//! on a dedicated **runtime service thread** (the analogue of a GPU stream
//! executor); rank threads hold a cloneable [`RuntimeHandle`] and submit
//! calls over a channel. On the single-core testbed this serialization
//! costs nothing and keeps the FFI perfectly thread-safe.

pub mod checkpoint;
pub mod manifest;
pub mod service;

pub use checkpoint::CheckpointCfg;
pub use manifest::{FusedInfo, LayerDesc, Manifest, ModelInfo};
pub use service::{RuntimeHandle, RuntimeStats};
