//! Experiment configuration: typed configs loadable from TOML or JSON files
//! with CLI overrides.
//!
//! Every entry point (the `hydra3d` binary, examples, benches) builds one
//! [`ExperimentConfig`]; `configs/` in the repo root holds the checked-in
//! experiment files used by EXPERIMENTS.md.

use crate::util::json::Json;
use crate::util::toml;
use anyhow::{anyhow, bail, Result};
use std::path::Path;

/// Training hyper-parameters (paper §IV: Adam, linear LR decay to 0.01x,
/// dropout keep 0.8, MSE loss).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    pub ways: usize,
    pub groups: usize,
    pub batch_global: usize,
    pub steps: usize,
    pub epochs: usize,
    pub lr: f64,
    pub lr_decay_to: f64,
    pub seed: u64,
    pub adam_beta1: f64,
    pub adam_beta2: f64,
    pub adam_eps: f64,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "cf16".into(),
            ways: 1,
            groups: 1,
            batch_global: 4,
            steps: 50,
            epochs: 0, // 0 = use steps
            lr: 1e-3,
            lr_decay_to: 0.01, // paper: decays to 0.01x of initial
            seed: 0xC05,
            adam_beta1: 0.9,
            adam_beta2: 0.999,
            adam_eps: 1e-8,
            log_every: 10,
        }
    }
}

/// Dataset synthesis parameters (GRF universes / CT volumes; DESIGN.md §4).
#[derive(Clone, Debug)]
pub struct DataConfig {
    pub n_train: usize,
    pub n_val: usize,
    pub n_test: usize,
    pub size: usize,
    pub seed: u64,
    /// split each cube into (size/sub)^3 sub-volumes (paper's 128^3 regime)
    pub subvolume: usize,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig { n_train: 64, n_val: 8, n_test: 8, size: 16, seed: 42,
                     subvolume: 0 }
    }
}

/// The simulated cluster (defaults = Lassen, §V-A).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub gpus_per_node: usize,
    /// peak dense f32 TFlop/s per GPU (V100: 15.7)
    pub gpu_tflops: f64,
    /// intra-socket NVLink2 bandwidth, GB/s per direction
    pub nvlink_gbps: f64,
    /// inter-node EDR InfiniBand (dual-rail), GB/s
    pub ib_gbps: f64,
    pub nvlink_latency_us: f64,
    pub ib_latency_us: f64,
    /// parallel file system aggregate bandwidth, GB/s (paper: 240 GB/s)
    pub pfs_gbps: f64,
    /// per-node share cap of PFS bandwidth, GB/s
    pub pfs_per_node_gbps: f64,
    pub gpu_mem_gib: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            gpus_per_node: 4,
            gpu_tflops: 15.7,
            nvlink_gbps: 60.0,
            ib_gbps: 21.0,
            nvlink_latency_us: 2.0,
            ib_latency_us: 4.0,
            pfs_gbps: 240.0,
            pfs_per_node_gbps: 4.0,
            gpu_mem_gib: 16.0,
        }
    }
}

/// Top-level experiment config.
#[derive(Clone, Debug, Default)]
pub struct ExperimentConfig {
    pub train: TrainConfig,
    pub data: DataConfig,
    pub cluster: ClusterConfig,
    pub artifacts_dir: String,
}

impl ExperimentConfig {
    pub fn artifacts(&self) -> String {
        if self.artifacts_dir.is_empty() {
            std::env::var("HYDRA3D_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
        } else {
            self.artifacts_dir.clone()
        }
    }

    /// Load from a `.toml` or `.json` file.
    pub fn load(path: &Path) -> Result<ExperimentConfig> {
        let v = match path.extension().and_then(|e| e.to_str()) {
            Some("toml") => toml::parse_file(path)?,
            Some("json") => Json::parse_file(path)?,
            other => bail!("unknown config extension {other:?}"),
        };
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<ExperimentConfig> {
        let mut cfg = ExperimentConfig::default();
        if let Some(t) = v.get("train") {
            let d = &mut cfg.train;
            set_str(t, "model", &mut d.model)?;
            set_usize(t, "ways", &mut d.ways)?;
            set_usize(t, "groups", &mut d.groups)?;
            set_usize(t, "batch_global", &mut d.batch_global)?;
            set_usize(t, "steps", &mut d.steps)?;
            set_usize(t, "epochs", &mut d.epochs)?;
            set_f64(t, "lr", &mut d.lr)?;
            set_f64(t, "lr_decay_to", &mut d.lr_decay_to)?;
            set_u64(t, "seed", &mut d.seed)?;
            set_usize(t, "log_every", &mut d.log_every)?;
        }
        if let Some(t) = v.get("data") {
            let d = &mut cfg.data;
            set_usize(t, "n_train", &mut d.n_train)?;
            set_usize(t, "n_val", &mut d.n_val)?;
            set_usize(t, "n_test", &mut d.n_test)?;
            set_usize(t, "size", &mut d.size)?;
            set_u64(t, "seed", &mut d.seed)?;
            set_usize(t, "subvolume", &mut d.subvolume)?;
        }
        if let Some(t) = v.get("cluster") {
            let d = &mut cfg.cluster;
            set_usize(t, "gpus_per_node", &mut d.gpus_per_node)?;
            set_f64(t, "gpu_tflops", &mut d.gpu_tflops)?;
            set_f64(t, "nvlink_gbps", &mut d.nvlink_gbps)?;
            set_f64(t, "ib_gbps", &mut d.ib_gbps)?;
            set_f64(t, "nvlink_latency_us", &mut d.nvlink_latency_us)?;
            set_f64(t, "ib_latency_us", &mut d.ib_latency_us)?;
            set_f64(t, "pfs_gbps", &mut d.pfs_gbps)?;
            set_f64(t, "pfs_per_node_gbps", &mut d.pfs_per_node_gbps)?;
            set_f64(t, "gpu_mem_gib", &mut d.gpu_mem_gib)?;
        }
        if let Some(a) = v.get("artifacts_dir") {
            cfg.artifacts_dir = a.as_str()?.to_string();
        }
        Ok(cfg)
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        let t = &self.train;
        if t.batch_global % t.groups != 0 {
            bail!("global batch {} not divisible by {} groups", t.batch_global,
                  t.groups);
        }
        if t.ways == 0 || t.groups == 0 {
            bail!("ways/groups must be positive");
        }
        Ok(())
    }
}

fn set_usize(t: &Json, k: &str, dst: &mut usize) -> Result<()> {
    if let Some(v) = t.get(k) {
        *dst = v.as_usize().map_err(|e| anyhow!("{k}: {e}"))?;
    }
    Ok(())
}
fn set_u64(t: &Json, k: &str, dst: &mut u64) -> Result<()> {
    if let Some(v) = t.get(k) {
        *dst = v.as_f64().map_err(|e| anyhow!("{k}: {e}"))? as u64;
    }
    Ok(())
}
fn set_f64(t: &Json, k: &str, dst: &mut f64) -> Result<()> {
    if let Some(v) = t.get(k) {
        *dst = v.as_f64().map_err(|e| anyhow!("{k}: {e}"))?;
    }
    Ok(())
}
fn set_str(t: &Json, k: &str, dst: &mut String) -> Result<()> {
    if let Some(v) = t.get(k) {
        *dst = v.as_str().map_err(|e| anyhow!("{k}: {e}"))?.to_string();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_lassen() {
        let c = ClusterConfig::default();
        assert_eq!(c.gpus_per_node, 4);
        assert_eq!(c.pfs_gbps, 240.0);
        assert_eq!(c.gpu_mem_gib, 16.0);
    }

    #[test]
    fn toml_roundtrip() {
        let doc = r#"
[train]
model = "cf32"
ways = 4
batch_global = 16
lr = 2e-3

[data]
size = 32
n_train = 128

[cluster]
nvlink_gbps = 50.0
"#;
        let v = toml::parse(doc).unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg.train.model, "cf32");
        assert_eq!(cfg.train.ways, 4);
        assert_eq!(cfg.train.lr, 2e-3);
        assert_eq!(cfg.data.size, 32);
        assert_eq!(cfg.cluster.nvlink_gbps, 50.0);
        assert_eq!(cfg.cluster.ib_gbps, 21.0); // untouched default
        cfg.validate().unwrap();
    }

    #[test]
    fn validation_rejects_bad_batch() {
        let mut cfg = ExperimentConfig::default();
        cfg.train.groups = 3;
        cfg.train.batch_global = 4;
        assert!(cfg.validate().is_err());
    }
}
