//! Process topology and spatial partitioning.
//!
//! The paper's notation (§III): with `G` total GPUs and a `D×H×W`-way
//! spatial split, the GPUs form `G / (D·H·W)` *sample groups*; each group
//! holds one sample, partitioned in the spatial dims, and groups advance
//! the mini-batch in data-parallel fashion ("hybrid parallelism").
//!
//! The functional engine partitions samples over a full 3D process grid
//! ([`SpatialGrid`] + [`GridTopology`]; depth-only splits are the `d×1×1`
//! special case, with [`Topology`] kept as the 1D view). The performance
//! model and simulator use the general grid ([`Grid4`]).

use anyhow::{anyhow, bail, Result};

/// Hybrid topology: `groups x d_ways` ranks; group = data-parallel index,
/// position = depth-shard index within the sample.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub groups: usize,
    pub d_ways: usize,
}

impl Topology {
    pub fn new(groups: usize, d_ways: usize) -> Topology {
        assert!(groups > 0 && d_ways > 0);
        Topology { groups, d_ways }
    }

    pub fn world_size(&self) -> usize {
        self.groups * self.d_ways
    }

    /// Rank layout: group-major (`rank = group * d_ways + pos`), matching
    /// the paper's node-packing (Figure 2: the 4 GPUs of a node hold
    /// adjacent shards of one sample, so halo exchange prefers NVLink).
    pub fn rank_of(&self, group: usize, pos: usize) -> usize {
        debug_assert!(group < self.groups && pos < self.d_ways);
        group * self.d_ways + pos
    }

    pub fn coords_of(&self, rank: usize) -> (usize, usize) {
        debug_assert!(rank < self.world_size());
        (rank / self.d_ways, rank % self.d_ways)
    }

    /// Neighbour toward lower depth (pos-1) if any.
    pub fn up(&self, rank: usize) -> Option<usize> {
        let (g, p) = self.coords_of(rank);
        (p > 0).then(|| self.rank_of(g, p - 1))
    }

    /// Neighbour toward higher depth (pos+1) if any.
    pub fn down(&self, rank: usize) -> Option<usize> {
        let (g, p) = self.coords_of(rank);
        (p + 1 < self.d_ways).then(|| self.rank_of(g, p + 1))
    }

    /// Ranks of one sample group.
    pub fn group_ranks(&self, group: usize) -> Vec<usize> {
        (0..self.d_ways).map(|p| self.rank_of(group, p)).collect()
    }

    /// Ranks holding the same shard position across groups (the BN /
    /// gradient allreduce never needs this split, but the data store does).
    pub fn position_ranks(&self, pos: usize) -> Vec<usize> {
        (0..self.groups).map(|g| self.rank_of(g, pos)).collect()
    }
}

/// Spatial process grid: partition ways along each of (D, H, W). The
/// paper's §III-A decomposition; `d×1×1` is the classic depth-only split.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SpatialGrid {
    pub d: usize,
    pub h: usize,
    pub w: usize,
}

impl SpatialGrid {
    pub fn new(d: usize, h: usize, w: usize) -> SpatialGrid {
        assert!(d > 0 && h > 0 && w > 0, "grid ways must be positive");
        SpatialGrid { d, h, w }
    }

    /// Depth-only split (the 1D special case the depth engine used).
    pub fn depth(ways: usize) -> SpatialGrid {
        SpatialGrid::new(ways, 1, 1)
    }

    pub fn ways(&self) -> usize {
        self.d * self.h * self.w
    }

    pub fn dims(&self) -> [usize; 3] {
        [self.d, self.h, self.w]
    }

    pub fn is_depth_only(&self) -> bool {
        self.h == 1 && self.w == 1
    }

    /// Canonical `dxhxw` key (CLI `--grid` syntax, manifest grid plans).
    pub fn key(&self) -> String {
        format!("{}x{}x{}", self.d, self.h, self.w)
    }

    /// Parse `"8"` (depth-only) or `"dxhxw"` (e.g. `"2x2x2"`).
    pub fn parse(s: &str) -> Result<SpatialGrid> {
        let parts: Vec<usize> = s
            .split('x')
            .map(|p| p.trim().parse::<usize>().map_err(|e| anyhow!("grid {s:?}: {e}")))
            .collect::<Result<Vec<_>>>()?;
        let grid = match parts[..] {
            [d] => SpatialGrid { d, h: 1, w: 1 },
            [d, h, w] => SpatialGrid { d, h, w },
            _ => bail!("grid {s:?}: expected `d` or `dxhxw`"),
        };
        if grid.d == 0 || grid.h == 0 || grid.w == 0 {
            bail!("grid {s:?}: ways must be positive");
        }
        Ok(grid)
    }

    /// Linear position of grid coordinates (row-major D, H, W: adjacent W
    /// neighbours sit on adjacent ranks, so the most frequent faces prefer
    /// the fastest links under the paper's Fig. 2 node packing).
    pub fn pos_of(&self, c: [usize; 3]) -> usize {
        debug_assert!(c[0] < self.d && c[1] < self.h && c[2] < self.w);
        (c[0] * self.h + c[1]) * self.w + c[2]
    }

    /// Grid coordinates of a linear position (inverse of [`pos_of`]).
    pub fn coords(&self, pos: usize) -> [usize; 3] {
        debug_assert!(pos < self.ways());
        [pos / (self.h * self.w), (pos / self.w) % self.h, pos % self.w]
    }

    /// (offset, extents) of the (D, H, W) hyperslab owned by linear
    /// position `pos` when a cubic `size`^3 volume is partitioned over this
    /// grid — [`axis_range`] per axis (floor-even, last shard takes the
    /// remainder), so the data store and the engine agree on shard
    /// geometry for every extent, divisible or not.
    pub fn shard_of(&self, size: usize, pos: usize) -> ([usize; 3], [usize; 3]) {
        let c = self.coords(pos);
        let dims = self.dims();
        let mut off = [0usize; 3];
        let mut len = [0usize; 3];
        for a in 0..3 {
            let (s, l) = axis_range(size, dims[a], c[a]);
            off[a] = s;
            len[a] = l;
        }
        (off, len)
    }
}

impl std::fmt::Display for SpatialGrid {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.d, self.h, self.w)
    }
}

/// Per-axis face neighbours of one rank: `lo[a]` / `hi[a]` hold the rank
/// owning the previous / next shard along spatial axis `a` (0=D, 1=H, 2=W),
/// `None` at the global boundary.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GridNeighbors {
    pub lo: [Option<usize>; 3],
    pub hi: [Option<usize>; 3],
}

/// Hybrid topology over a 3D spatial grid: `groups x grid.ways()` ranks,
/// group-major (`rank = group * ways + pos`), positions row-major in
/// (D, H, W). The generalization of [`Topology`] the engine runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GridTopology {
    pub groups: usize,
    pub grid: SpatialGrid,
}

impl GridTopology {
    pub fn new(groups: usize, grid: SpatialGrid) -> GridTopology {
        assert!(groups > 0);
        GridTopology { groups, grid }
    }

    pub fn world_size(&self) -> usize {
        self.groups * self.grid.ways()
    }

    pub fn rank_of(&self, group: usize, pos: usize) -> usize {
        debug_assert!(group < self.groups && pos < self.grid.ways());
        group * self.grid.ways() + pos
    }

    /// (group, linear position within the sample grid).
    pub fn coords_of(&self, rank: usize) -> (usize, usize) {
        debug_assert!(rank < self.world_size());
        (rank / self.grid.ways(), rank % self.grid.ways())
    }

    /// Face neighbours of `rank` along every partitioned axis.
    pub fn neighbors(&self, rank: usize) -> GridNeighbors {
        let (group, pos) = self.coords_of(rank);
        let c = self.grid.coords(pos);
        let dims = self.grid.dims();
        let mut n = GridNeighbors::default();
        for a in 0..3 {
            if c[a] > 0 {
                let mut lo = c;
                lo[a] -= 1;
                n.lo[a] = Some(self.rank_of(group, self.grid.pos_of(lo)));
            }
            if c[a] + 1 < dims[a] {
                let mut hi = c;
                hi[a] += 1;
                n.hi[a] = Some(self.rank_of(group, self.grid.pos_of(hi)));
            }
        }
        n
    }

    /// Ranks of one sample group, in position order (the gather order at
    /// the flatten boundary).
    pub fn group_ranks(&self, group: usize) -> Vec<usize> {
        (0..self.grid.ways()).map(|p| self.rank_of(group, p)).collect()
    }
}

/// General `N x D x H x W`-way decomposition used by the performance model
/// and the cluster simulator (the paper's Figs. 4/7/8 sweep these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Grid4 {
    pub n: usize,
    pub d: usize,
    pub h: usize,
    pub w: usize,
}

impl Grid4 {
    pub fn depth_only(n: usize, d: usize) -> Grid4 {
        Grid4 { n, d, h: 1, w: 1 }
    }

    pub fn spatial_ways(&self) -> usize {
        self.d * self.h * self.w
    }

    pub fn world_size(&self) -> usize {
        self.n * self.spatial_ways()
    }

    /// Shard extents (ceil-split) of a global (D, H, W) volume.
    pub fn shard_extent(&self, vol: (usize, usize, usize)) -> (usize, usize, usize) {
        (div_ceil(vol.0, self.d), div_ceil(vol.1, self.h), div_ceil(vol.2, self.w))
    }

    /// Per-axis shard `(start, len)` of grid coordinate `coord` over a
    /// (D, H, W) volume: floor-even split, last shard takes the remainder,
    /// so non-power-of-two grids cover 512^3 volumes exactly. When an
    /// extent divides evenly this degenerates to the even split the AOT
    /// functional engine requires — the data store and the engine therefore
    /// share one shard geometry (the §III-B cache/compute alignment).
    pub fn shard_range(&self, vol: (usize, usize, usize),
                       coord: (usize, usize, usize)) -> [(usize, usize); 3] {
        [
            axis_range(vol.0, self.d, coord.0),
            axis_range(vol.1, self.h, coord.1),
            axis_range(vol.2, self.w, coord.2),
        ]
    }

    /// Per-spatial-dim halo *face* areas (elements) for a k^3 stride-1 conv
    /// on a (D, H, W) shard of `c` channels: one face per partitioned dim
    /// side. Dims that are not partitioned contribute no halo.
    pub fn halo_faces(&self, c: usize, vol: (usize, usize, usize), k: usize)
                      -> [usize; 3] {
        let (sd, sh, sw) = self.shard_extent(vol);
        let halo = (k - 1) / 2;
        [
            if self.d > 1 { c * halo * sh * sw } else { 0 },
            if self.h > 1 { c * halo * sd * sw } else { 0 },
            if self.w > 1 { c * halo * sd * sh } else { 0 },
        ]
    }
}

pub fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// `(start, len)` of shard `pos` when `extent` is split `ways`-ways:
/// floor-even with the last shard taking the remainder. Every shard is
/// non-empty as long as `ways <= extent`.
pub fn axis_range(extent: usize, ways: usize, pos: usize) -> (usize, usize) {
    assert!(ways >= 1 && pos < ways, "shard {pos} of {ways} ways");
    assert!(ways <= extent, "extent {extent} over-decomposed into {ways} shards");
    let base = extent / ways;
    let start = pos * base;
    let len = if pos + 1 == ways { extent - start } else { base };
    (start, len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn rank_coord_roundtrip() {
        let t = Topology::new(4, 8);
        assert_eq!(t.world_size(), 32);
        for r in 0..t.world_size() {
            let (g, p) = t.coords_of(r);
            assert_eq!(t.rank_of(g, p), r);
        }
    }

    #[test]
    fn neighbours() {
        let t = Topology::new(2, 4);
        let r = t.rank_of(1, 0);
        assert_eq!(t.up(r), None);
        assert_eq!(t.down(r), Some(t.rank_of(1, 1)));
        let r = t.rank_of(1, 3);
        assert_eq!(t.down(r), None);
        assert_eq!(t.up(r), Some(t.rank_of(1, 2)));
    }

    #[test]
    fn groups_partition_world() {
        let t = Topology::new(3, 4);
        let mut seen = vec![false; t.world_size()];
        for g in 0..t.groups {
            for r in t.group_ranks(g) {
                assert!(!seen[r], "rank {r} in two groups");
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shard_of_matches_axis_range_geometry() {
        // divisible extents: the even split the AOT engine assumes
        let g = SpatialGrid::new(4, 1, 1);
        for pos in 0..4 {
            let (off, len) = g.shard_of(64, pos);
            assert_eq!(off, [pos * 16, 0, 0]);
            assert_eq!(len, [16, 64, 64]);
        }
        // non-divisible: last shard takes the remainder on every axis
        let g = SpatialGrid::new(3, 2, 1);
        let (off, len) = g.shard_of(10, g.pos_of([2, 1, 0]));
        assert_eq!(off, [6, 5, 0]);
        assert_eq!(len, [4, 5, 10]);
    }

    #[test]
    fn grid4_shards_and_halos() {
        let g = Grid4 { n: 2, d: 4, h: 2, w: 1 };
        assert_eq!(g.world_size(), 16);
        assert_eq!(g.shard_extent((512, 512, 512)), (128, 256, 512));
        let faces = g.halo_faces(16, (512, 512, 512), 3);
        assert_eq!(faces, [16 * 1 * 256 * 512, 16 * 1 * 128 * 512, 0]);
    }

    #[test]
    fn spatial_grid_parse_and_coords() {
        assert_eq!(SpatialGrid::parse("8").unwrap(), SpatialGrid::depth(8));
        assert_eq!(SpatialGrid::parse("2x2x2").unwrap(), SpatialGrid::new(2, 2, 2));
        assert_eq!(SpatialGrid::parse("4x2x1").unwrap().ways(), 8);
        assert!(SpatialGrid::parse("2x2").is_err());
        assert!(SpatialGrid::parse("0x2x2").is_err());
        assert!(SpatialGrid::parse("ax2x2").is_err());
        let g = SpatialGrid::new(3, 2, 4);
        for pos in 0..g.ways() {
            assert_eq!(g.pos_of(g.coords(pos)), pos);
        }
        assert_eq!(g.key(), "3x2x4");
        assert!(SpatialGrid::depth(4).is_depth_only());
        assert!(!g.is_depth_only());
    }

    #[test]
    fn grid_topology_neighbors_match_1d() {
        // a dx1x1 grid must reproduce the 1D Topology's neighbour structure
        let t1 = Topology::new(2, 4);
        let tg = GridTopology::new(2, SpatialGrid::depth(4));
        assert_eq!(t1.world_size(), tg.world_size());
        for r in 0..tg.world_size() {
            let n = tg.neighbors(r);
            assert_eq!(n.lo[0], t1.up(r), "rank {r}");
            assert_eq!(n.hi[0], t1.down(r), "rank {r}");
            assert_eq!(n.lo[1], None);
            assert_eq!(n.hi[2], None);
        }
        assert_eq!(tg.group_ranks(1), t1.group_ranks(1));
    }

    #[test]
    fn grid_topology_neighbors_symmetric_3d() {
        let tg = GridTopology::new(2, SpatialGrid::new(2, 3, 2));
        for r in 0..tg.world_size() {
            let n = tg.neighbors(r);
            for a in 0..3 {
                if let Some(lo) = n.lo[a] {
                    assert_eq!(tg.neighbors(lo).hi[a], Some(r), "rank {r} axis {a}");
                }
                if let Some(hi) = n.hi[a] {
                    assert_eq!(tg.neighbors(hi).lo[a], Some(r), "rank {r} axis {a}");
                }
            }
            // neighbours stay within the same sample group
            let (g, _) = tg.coords_of(r);
            for x in n.lo.iter().chain(n.hi.iter()).flatten() {
                assert_eq!(tg.coords_of(*x).0, g);
            }
        }
    }

    #[test]
    fn axis_range_last_shard_takes_remainder() {
        // 512 planes on a non-power-of-two split: exact cover, last shard
        // absorbs the remainder
        assert_eq!(axis_range(512, 3, 0), (0, 170));
        assert_eq!(axis_range(512, 3, 1), (170, 170));
        assert_eq!(axis_range(512, 3, 2), (340, 172));
        assert_eq!(axis_range(512, 5, 4), (408, 104));
        let g = Grid4 { n: 1, d: 3, h: 2, w: 1 };
        let ranges = g.shard_range((512, 512, 512), (2, 1, 0));
        assert_eq!(ranges, [(340, 172), (256, 256), (0, 512)]);
        // exact cover on every axis
        for (extent, ways) in [(512usize, 3usize), (512, 5), (7, 7), (10, 4)] {
            let mut end = 0;
            for pos in 0..ways {
                let (s, len) = axis_range(extent, ways, pos);
                assert_eq!(s, end, "{extent}/{ways} shard {pos}");
                assert!(len > 0);
                end = s + len;
            }
            assert_eq!(end, extent, "{extent}/{ways}");
        }
    }

    #[test]
    fn prop_topology_bijection() {
        prop::check("topology-bijection", 100, |g| {
            let groups = g.usize_in(1, 16);
            let ways = g.pow2_in(1, 32);
            let t = Topology::new(groups, ways);
            for r in 0..t.world_size() {
                let (gr, p) = t.coords_of(r);
                if t.rank_of(gr, p) != r {
                    return Err(format!("rank {r} not stable"));
                }
                // neighbour symmetry: down(up(r)) == r
                if let Some(u) = t.up(r) {
                    if t.down(u) != Some(r) {
                        return Err(format!("asym neighbours at {r}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_grid_shards_exactly_cover_volume() {
        prop::check("grid-shard-cover", 60, |g| {
            let grid = SpatialGrid::new(g.usize_in(1, 4), g.usize_in(1, 3),
                                        g.usize_in(1, 3));
            let size = g.usize_in(4, 24).max(grid.d).max(grid.h).max(grid.w);
            let mut covered = vec![0u8; size * size * size];
            for pos in 0..grid.ways() {
                let (off, len) = grid.shard_of(size, pos);
                for d in off[0]..off[0] + len[0] {
                    for h in off[1]..off[1] + len[1] {
                        for w in off[2]..off[2] + len[2] {
                            covered[(d * size + h) * size + w] += 1;
                        }
                    }
                }
            }
            if covered.iter().all(|&c| c == 1) {
                Ok(())
            } else {
                Err(format!("grid {grid} size {size}: not an exact cover"))
            }
        });
    }
}
