//! Process topology and spatial partitioning.
//!
//! The paper's notation (§III): with `G` total GPUs and a `D×H×W`-way
//! spatial split, the GPUs form `G / (D·H·W)` *sample groups*; each group
//! holds one sample, partitioned in the spatial dims, and groups advance
//! the mini-batch in data-parallel fashion ("hybrid parallelism").
//!
//! The functional engine uses depth-only splits ([`Topology`]); the
//! performance model and simulator use the general grid ([`Grid4`]).

use anyhow::{bail, Result};

/// Hybrid topology: `groups x d_ways` ranks; group = data-parallel index,
/// position = depth-shard index within the sample.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub groups: usize,
    pub d_ways: usize,
}

impl Topology {
    pub fn new(groups: usize, d_ways: usize) -> Topology {
        assert!(groups > 0 && d_ways > 0);
        Topology { groups, d_ways }
    }

    pub fn world_size(&self) -> usize {
        self.groups * self.d_ways
    }

    /// Rank layout: group-major (`rank = group * d_ways + pos`), matching
    /// the paper's node-packing (Figure 2: the 4 GPUs of a node hold
    /// adjacent shards of one sample, so halo exchange prefers NVLink).
    pub fn rank_of(&self, group: usize, pos: usize) -> usize {
        debug_assert!(group < self.groups && pos < self.d_ways);
        group * self.d_ways + pos
    }

    pub fn coords_of(&self, rank: usize) -> (usize, usize) {
        debug_assert!(rank < self.world_size());
        (rank / self.d_ways, rank % self.d_ways)
    }

    /// Neighbour toward lower depth (pos-1) if any.
    pub fn up(&self, rank: usize) -> Option<usize> {
        let (g, p) = self.coords_of(rank);
        (p > 0).then(|| self.rank_of(g, p - 1))
    }

    /// Neighbour toward higher depth (pos+1) if any.
    pub fn down(&self, rank: usize) -> Option<usize> {
        let (g, p) = self.coords_of(rank);
        (p + 1 < self.d_ways).then(|| self.rank_of(g, p + 1))
    }

    /// Ranks of one sample group.
    pub fn group_ranks(&self, group: usize) -> Vec<usize> {
        (0..self.d_ways).map(|p| self.rank_of(group, p)).collect()
    }

    /// Ranks holding the same shard position across groups (the BN /
    /// gradient allreduce never needs this split, but the data store does).
    pub fn position_ranks(&self, pos: usize) -> Vec<usize> {
        (0..self.groups).map(|g| self.rank_of(g, pos)).collect()
    }
}

/// An even depth partition of `d` planes over `ways` shards.
#[derive(Clone, Copy, Debug)]
pub struct DepthPartition {
    pub d: usize,
    pub ways: usize,
}

impl DepthPartition {
    /// The engine requires even splits (the AOT shard executables are
    /// lowered at a single shard shape).
    pub fn new_even(d: usize, ways: usize) -> Result<DepthPartition> {
        if ways == 0 || d % ways != 0 {
            bail!("depth {d} not divisible by {ways} ways");
        }
        Ok(DepthPartition { d, ways })
    }

    pub fn shard_len(&self) -> usize {
        self.d / self.ways
    }

    pub fn shard_start(&self, pos: usize) -> usize {
        debug_assert!(pos < self.ways);
        pos * self.shard_len()
    }

    /// Global depth range [start, end) of shard `pos`.
    pub fn range(&self, pos: usize) -> (usize, usize) {
        let s = self.shard_start(pos);
        (s, s + self.shard_len())
    }
}

/// General `N x D x H x W`-way decomposition used by the performance model
/// and the cluster simulator (the paper's Figs. 4/7/8 sweep these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Grid4 {
    pub n: usize,
    pub d: usize,
    pub h: usize,
    pub w: usize,
}

impl Grid4 {
    pub fn depth_only(n: usize, d: usize) -> Grid4 {
        Grid4 { n, d, h: 1, w: 1 }
    }

    pub fn spatial_ways(&self) -> usize {
        self.d * self.h * self.w
    }

    pub fn world_size(&self) -> usize {
        self.n * self.spatial_ways()
    }

    /// Shard extents (ceil-split) of a global (D, H, W) volume.
    pub fn shard_extent(&self, vol: (usize, usize, usize)) -> (usize, usize, usize) {
        (div_ceil(vol.0, self.d), div_ceil(vol.1, self.h), div_ceil(vol.2, self.w))
    }

    /// Per-spatial-dim halo *face* areas (elements) for a k^3 stride-1 conv
    /// on a (D, H, W) shard of `c` channels: one face per partitioned dim
    /// side. Dims that are not partitioned contribute no halo.
    pub fn halo_faces(&self, c: usize, vol: (usize, usize, usize), k: usize)
                      -> [usize; 3] {
        let (sd, sh, sw) = self.shard_extent(vol);
        let halo = (k - 1) / 2;
        [
            if self.d > 1 { c * halo * sh * sw } else { 0 },
            if self.h > 1 { c * halo * sd * sw } else { 0 },
            if self.w > 1 { c * halo * sd * sh } else { 0 },
        ]
    }
}

pub fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn rank_coord_roundtrip() {
        let t = Topology::new(4, 8);
        assert_eq!(t.world_size(), 32);
        for r in 0..t.world_size() {
            let (g, p) = t.coords_of(r);
            assert_eq!(t.rank_of(g, p), r);
        }
    }

    #[test]
    fn neighbours() {
        let t = Topology::new(2, 4);
        let r = t.rank_of(1, 0);
        assert_eq!(t.up(r), None);
        assert_eq!(t.down(r), Some(t.rank_of(1, 1)));
        let r = t.rank_of(1, 3);
        assert_eq!(t.down(r), None);
        assert_eq!(t.up(r), Some(t.rank_of(1, 2)));
    }

    #[test]
    fn groups_partition_world() {
        let t = Topology::new(3, 4);
        let mut seen = vec![false; t.world_size()];
        for g in 0..t.groups {
            for r in t.group_ranks(g) {
                assert!(!seen[r], "rank {r} in two groups");
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn depth_partition_covers() {
        let p = DepthPartition::new_even(64, 4).unwrap();
        assert_eq!(p.shard_len(), 16);
        let mut end = 0;
        for pos in 0..4 {
            let (s, e) = p.range(pos);
            assert_eq!(s, end);
            end = e;
        }
        assert_eq!(end, 64);
        assert!(DepthPartition::new_even(10, 4).is_err());
    }

    #[test]
    fn grid4_shards_and_halos() {
        let g = Grid4 { n: 2, d: 4, h: 2, w: 1 };
        assert_eq!(g.world_size(), 16);
        assert_eq!(g.shard_extent((512, 512, 512)), (128, 256, 512));
        let faces = g.halo_faces(16, (512, 512, 512), 3);
        assert_eq!(faces, [16 * 1 * 256 * 512, 16 * 1 * 128 * 512, 0]);
    }

    #[test]
    fn prop_topology_bijection() {
        prop::check("topology-bijection", 100, |g| {
            let groups = g.usize_in(1, 16);
            let ways = g.pow2_in(1, 32);
            let t = Topology::new(groups, ways);
            for r in 0..t.world_size() {
                let (gr, p) = t.coords_of(r);
                if t.rank_of(gr, p) != r {
                    return Err(format!("rank {r} not stable"));
                }
                // neighbour symmetry: down(up(r)) == r
                if let Some(u) = t.up(r) {
                    if t.down(u) != Some(r) {
                        return Err(format!("asym neighbours at {r}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_depth_partition_exact_cover() {
        prop::check("depth-cover", 100, |g| {
            let ways = g.pow2_in(1, 16);
            let d = ways * g.usize_in(1, 32);
            let p = DepthPartition::new_even(d, ways).map_err(|e| e.to_string())?;
            let mut covered = vec![0u8; d];
            for pos in 0..ways {
                let (s, e) = p.range(pos);
                for i in s..e {
                    covered[i] += 1;
                }
            }
            if covered.iter().all(|&c| c == 1) {
                Ok(())
            } else {
                Err("not an exact cover".into())
            }
        });
    }
}
