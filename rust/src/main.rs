//! `hydra3d` — the leader CLI.
//!
//! Subcommands:
//!   table1 | table2 | fig --id N   regenerate the paper's tables/figures
//!   train                          functional training (fused or hybrid)
//!   verify                         static communication-schedule checks
//!   info                           artifact/manifest summary
//!
//! Examples:
//!   hydra3d table1
//!   hydra3d fig --id 4
//!   hydra3d train --model cf16 --ways 2 --groups 2 --batch 4 --steps 20
//!   hydra3d train --model cf16 --grid 2x2x2 --batch 2 --steps 10
//!   hydra3d train --model unet16 --ways 2 --task ct

use anyhow::{bail, Result};
use hydra3d::analysis::{self, EngineKind, ModelSpec, VerifyCfg};
use hydra3d::comm::{CommBackend, GradReduce, TraceCollector, DEFAULT_BUCKET_ELEMS};
use hydra3d::config::ClusterConfig;
use hydra3d::coordinator;
use hydra3d::data::container::{write_dataset, write_label_dataset, Container};
use hydra3d::data::ct::ct_dataset;
use hydra3d::data::grf::{GrfConfig, GrfDataset};
use hydra3d::engine::hybrid::{train_hybrid_store, train_hybrid_with, HybridOpts,
                              InMemorySource, IoMode};
use hydra3d::engine::LrSchedule;
use hydra3d::iosim::pipeline::io_time_from_redist_trace;
use hydra3d::partition::SpatialGrid;
use hydra3d::perfmodel::trace::replay;
use hydra3d::perfmodel::{Link, SrModel};
use hydra3d::runtime::RuntimeHandle;
use hydra3d::tensor::Tensor;
use hydra3d::util::cli::Command;
use std::path::PathBuf;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir() -> PathBuf {
    std::env::var("HYDRA3D_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first().map(String::as_str) else {
        println!("{}", usage());
        return Ok(());
    };
    let rest = &args[1..];
    let cluster = ClusterConfig::default();
    match cmd {
        "table1" => print!("{}", coordinator::table1()),
        "table2" => print!("{}", coordinator::table2(&cluster)),
        "fig" => {
            let c = Command::new("fig", "regenerate a paper figure")
                .opt("id", "figure number (4,5,6,7,8)", None)
                .opt("trace-dir", "directory for chrome traces (fig 6)", None);
            let a = c.parse(rest)?;
            let id = a.req("id")?.parse::<usize>()?;
            let out = match id {
                4 => coordinator::fig4(&cluster),
                5 => coordinator::fig5(&cluster),
                6 => coordinator::fig6(
                    &cluster,
                    a.get("trace-dir").map(std::path::Path::new),
                ),
                7 => coordinator::fig7(&cluster),
                8 => coordinator::fig8(&cluster),
                other => bail!("no figure {other} (the paper has 4-8 as \
                                performance figures; 9/10 are produced by \
                                examples/train_cosmoflow)"),
            };
            print!("{out}");
        }
        "train" => train_cmd(rest)?,
        "verify" => verify_cmd(rest)?,
        "info" => info_cmd()?,
        "--help" | "-h" | "help" => println!("{}", usage()),
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
    Ok(())
}

fn usage() -> String {
    "hydra3d — hybrid-parallel 3D CNN training (Oyama et al. 2020 reproduction)\n\
     \n\
     commands:\n\
       table1            Table I analytics (architecture, GFlops, memory)\n\
       table2            Table II achieved-vs-peak conv performance\n\
       fig --id <4..8>   regenerate a performance figure\n\
       train [...]       functional hybrid/fused training on synthetic data\n\
       verify [...]      static communication-schedule checks (deadlock, tag,\n\
                         byte matching); --matrix for the CI sweep,\n\
                         --mutations K for the seeded-defect harness\n\
       info              artifact manifest summary\n"
        .into()
}

fn train_cmd(rest: &[String]) -> Result<()> {
    let c = Command::new("train", "functional training on synthetic data")
        .opt("model", "manifest model name", Some("cf16"))
        .opt("ways", "depth-only spatial partitioning (= --grid Wx1x1)", Some("1"))
        .opt("grid",
             "full 3D spatial process grid `dxhxw` (e.g. 2x2x2); overrides \
              --ways",
             None)
        .opt("groups", "data-parallel groups", Some("1"))
        .opt("batch", "global mini-batch", Some("2"))
        .opt("steps", "training steps", Some("20"))
        .opt("lr", "initial learning rate", Some("1e-3"))
        .opt("seed", "experiment seed", Some("7"))
        .opt("samples", "dataset size", Some("16"))
        .opt("task", "grf | ct", Some("grf"))
        .opt("io",
             "sample source: inmem | store | store-async (store modes write \
              the dataset to a scratch container — the \"PFS\" — and train \
              through the §III-B ingestion/redistribution pipeline)",
             Some("inmem"))
        .opt("comm",
             "communicator backend: channel | loopback | traced (traced is \
              diagnostic: it records every message in memory)",
             Some("channel"))
        .opt("bucket",
             "allreduce bucket size in f32 elems (0 = monolithic; default \
              comm::DEFAULT_BUCKET_ELEMS)",
             None);
    let a = c.parse(rest)?;
    let model = a.req("model")?.to_string();
    let trace = Arc::new(TraceCollector::new());
    let backend = match a.req("comm")? {
        "channel" => CommBackend::Channel,
        "loopback" => CommBackend::Loopback,
        "traced" => CommBackend::Traced(trace.clone()),
        other => bail!("unknown --comm backend {other:?}"),
    };
    let reduce = match a.get_usize("bucket")?.unwrap_or(DEFAULT_BUCKET_ELEMS) {
        0 => GradReduce::Monolithic,
        elems => GradReduce::Bucketed { bucket_elems: elems },
    };
    let rt = RuntimeHandle::start(&artifacts_dir())?;
    let info = rt.manifest().model(&model)?.clone();
    let size = info.input_size;
    let n = a.get_usize("samples")?.unwrap();
    let seed = a.get_usize("seed")?.unwrap() as u64;

    let io = IoMode::parse(a.req("io")?)?;
    let is_ct = a.req("task")? == "ct";
    let (inputs, targets): (Vec<Tensor>, Vec<Tensor>) = if is_ct {
        let (inputs, labels) = ct_dataset(size, info.n_classes.max(2), n, seed);
        (inputs, labels)
    } else {
        let ds = GrfDataset::generate(&GrfConfig { size, seed }, n);
        (ds.inputs, ds.targets)
    };

    let grid = match a.get("grid") {
        Some(g) => SpatialGrid::parse(g)?,
        None => SpatialGrid::depth(a.get_usize("ways")?.unwrap()),
    };
    let steps = a.get_usize("steps")?.unwrap();
    let opts = HybridOpts {
        model,
        grid,
        groups: a.get_usize("groups")?.unwrap(),
        batch_global: a.get_usize("batch")?.unwrap(),
        steps,
        seed,
        schedule: LrSchedule {
            lr0: a.get_f64("lr")?.unwrap(),
            floor_frac: 0.01,
            total_steps: steps,
        },
        log_every: (steps / 10).max(1),
    };
    let t0 = std::time::Instant::now();
    let rep = match io {
        IoMode::InMem => {
            let source = Arc::new(InMemorySource { inputs, targets });
            train_hybrid_with(&rt, &opts, source, &backend, reduce)?
        }
        IoMode::Store | IoMode::StoreAsync => {
            // stand-in PFS: a scratch container file holding the dataset
            let mut path = std::env::temp_dir();
            path.push(format!("hydra3d-train-io-{}", std::process::id()));
            if is_ct {
                // labels are the spatially partitioned ground truth
                write_label_dataset(&path, &inputs, &targets)?;
            } else {
                write_dataset(&path, &inputs, &targets, None)?;
            }
            let container = Arc::new(Container::open(&path)?);
            let rep =
                train_hybrid_store(&rt, &opts, container.clone(), io, &backend,
                                   reduce);
            std::fs::remove_file(&path).ok();
            let rep = rep?;
            // every container byte read over the whole run was epoch-0
            // ingestion: steps (epochs 1+ included) never touch the "PFS"
            let pfs_reads =
                container.bytes_read.load(std::sync::atomic::Ordering::Relaxed);
            println!(
                "io pipeline [{}]: ingest {:.0} KiB (epoch 0), redistribution \
                 {:.0} KiB staged, exposed {:.3}s / overlapped {:.3}s; \
                 container bytes beyond ingest: {}",
                io.name(),
                rep.ingest_bytes as f64 / 1024.0,
                rep.redist_bytes as f64 / 1024.0,
                rep.io_exposed,
                rep.io_overlapped,
                pfs_reads - rep.ingest_bytes,
            );
            rep
        }
    };
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "trained {} (grid {}) for {} steps: loss {:.6} -> {:.6} in {:.1}s \
         ({:.0} KiB comm, halo KiB D/H/W {:.0}/{:.0}/{:.0}, phases: fwd \
         {:.1}s bwd {:.1}s halo {:.2}s ar {:.2}s exposed / {:.2}s overlapped)",
        opts.model,
        opts.grid,
        steps,
        rep.records.first().map(|r| r.loss).unwrap_or(f32::NAN),
        rep.final_loss(),
        dt,
        rep.comm_bytes as f64 / 1024.0,
        rep.halo_bytes[0] as f64 / 1024.0,
        rep.halo_bytes[1] as f64 / 1024.0,
        rep.halo_bytes[2] as f64 / 1024.0,
        rep.phases.fwd_compute,
        rep.phases.bwd_compute,
        rep.phases.halo,
        rep.phases.allreduce,
        rep.phases.allreduce_overlapped,
    );
    if let CommBackend::Traced(tc) = &backend {
        let world = opts.groups * opts.grid.ways();
        let cluster = ClusterConfig::default();
        let link = SrModel::from_cluster(&cluster, Link::NvLink);
        let r = replay(tc, world, &link);
        println!(
            "comm trace: {} messages, {} bytes, {} logical collectives \
             (halo bytes D/H/W {}/{}/{}); §III-C replay: p2p critical \
             {:.2} ms, allreduce model {:.2} ms (NVLink link)",
            r.messages,
            r.bytes,
            r.collectives,
            r.halo_bytes_axis[0],
            r.halo_bytes_axis[1],
            r.halo_bytes_axis[2],
            r.p2p_critical_secs * 1e3,
            r.allreduce_model_secs * 1e3,
        );
        if r.redist_bytes > 0 {
            // calibrate the §III-B spatial-parallel I/O term against the
            // traced (measured) redistribution volume
            let per_rank_iter =
                r.redist_bytes as f64 / (world as f64 * steps as f64);
            println!(
                "  redistribution trace: {} B total; calibrated \
                 spatial-parallel I/O {:.3} ms/iter ({:.0} B/rank/iter over \
                 the IB link)",
                r.redist_bytes,
                io_time_from_redist_trace(per_rank_iter, &cluster) * 1e3,
                per_rank_iter,
            );
        }
    }
    Ok(())
}

fn verify_cmd(rest: &[String]) -> Result<()> {
    let c = Command::new(
        "verify",
        "statically check a configuration's communication schedule",
    )
    .opt("model",
         "built-in spec (cf-sim | cf-sim-bn | unet-sim) or a manifest model \
          name when artifacts are present",
         Some("cf-sim"))
    .opt("grid", "spatial process grid `dxhxw`", Some("1x1x1"))
    .opt("groups", "data-parallel groups", Some("1"))
    .opt("batch", "global mini-batch (default: 2 per group)", None)
    .opt("steps", "steps to extract", Some("2"))
    .opt("samples", "dataset size for the store schedule (default: 4 per \
                     group)", None)
    .opt("seed", "schedule seed", Some("11"))
    .opt("io", "inmem | store | store-async", Some("inmem"))
    .opt("reduce", "bucketed | mono", Some("bucketed"))
    .opt("engine", "hybrid | fused", Some("hybrid"))
    .flag("matrix", "check every CI matrix configuration instead of one")
    .opt("mutations",
         "run the seeded-mutation harness with this many rounds per defect \
          class and require every seeded defect to be caught",
         None);
    let a = c.parse(rest)?;

    if let Some(rounds) = a.get_usize("mutations")? {
        let seed = a.get_usize("seed")?.unwrap() as u64;
        let outcomes = analysis::run_mutation_suite(seed, rounds)?;
        let mut missed = 0usize;
        for o in &outcomes {
            if o.caught {
                let d = o.defect.as_ref().unwrap();
                println!("caught  {:<22} seed {:>3}: {d}", o.kind.name(), o.seed);
            } else {
                missed += 1;
                println!("MISSED  {:<22} seed {:>3}: {}", o.kind.name(), o.seed,
                         o.desc);
            }
        }
        println!(
            "mutation harness: {}/{} seeded defects caught across {} classes",
            outcomes.len() - missed,
            outcomes.len(),
            hydra3d::analysis::MutationKind::ALL.len(),
        );
        if missed > 0 {
            bail!("{missed} seeded schedule defect(s) escaped the checker");
        }
        return Ok(());
    }

    if a.flag("matrix") {
        let mut bad = 0usize;
        let mut total = 0usize;
        for (spec, cfg) in analysis::matrix() {
            total += 1;
            let sched = analysis::extract(&spec, &cfg)?;
            let defects = analysis::check_schedule(&sched);
            if defects.is_empty() {
                println!("ok   {:<10} {} ({} ops)", spec.name, cfg.describe(),
                         sched.total_ops());
            } else {
                bad += 1;
                println!("FAIL {:<10} {}", spec.name, cfg.describe());
                for d in &defects {
                    println!("     {d}");
                }
            }
        }
        println!("verify matrix: {}/{total} configurations clean", total - bad);
        if bad > 0 {
            bail!("{bad} configuration(s) have schedule defects");
        }
        return Ok(());
    }

    let name = a.req("model")?;
    let spec = match ModelSpec::builtin(name) {
        Ok(spec) => spec,
        // fall back to the AOT manifest so real production plans can be
        // checked when artifacts are present
        Err(builtin_err) => match RuntimeHandle::start(&artifacts_dir()) {
            Ok(rt) => ModelSpec::from_model_info(rt.manifest().model(name)?),
            Err(_) => return Err(builtin_err),
        },
    };
    let groups = a.get_usize("groups")?.unwrap();
    let cfg = VerifyCfg {
        grid: SpatialGrid::parse(a.req("grid")?)?,
        groups,
        batch_global: a.get_usize("batch")?.unwrap_or(2 * groups),
        steps: a.get_usize("steps")?.unwrap(),
        samples: a.get_usize("samples")?.unwrap_or(4 * groups),
        seed: a.get_usize("seed")?.unwrap() as u64,
        io: IoMode::parse(a.req("io")?)?,
        reduce: match a.req("reduce")? {
            "bucketed" => GradReduce::default(),
            "mono" => GradReduce::Monolithic,
            other => bail!("unknown --reduce {other:?} (bucketed | mono)"),
        },
        engine: match a.req("engine")? {
            "hybrid" => EngineKind::Hybrid,
            "fused" => EngineKind::Fused,
            other => bail!("unknown --engine {other:?} (hybrid | fused)"),
        },
    };
    let sched = analysis::extract(&spec, &cfg)?;
    let defects = analysis::check_schedule(&sched);
    for w in &sched.worlds {
        println!(
            "world {:<8} {} rank(s), {} ops",
            w.name,
            w.size,
            w.ranks.iter().map(Vec::len).sum::<usize>()
        );
    }
    if defects.is_empty() {
        println!("verify {}: {} — clean ({} ops)", spec.name, cfg.describe(),
                 sched.total_ops());
        Ok(())
    } else {
        for d in &defects {
            println!("{d}");
        }
        bail!("verify {}: {} defect(s) found", spec.name, defects.len());
    }
}

fn info_cmd() -> Result<()> {
    let rt = RuntimeHandle::start(&artifacts_dir())?;
    let man = rt.manifest();
    println!("artifacts: {} entries, {} models", man.entries.len(), man.models.len());
    let mut names: Vec<&String> = man.models.keys().collect();
    names.sort();
    for name in names {
        let m = &man.models[name];
        let mut ways: Vec<&usize> = m.hybrid.keys().collect();
        ways.sort();
        let mut grids: Vec<&String> = m.hybrid_grid.keys().collect();
        grids.sort();
        println!(
            "  {:<12} {:<10} input {:>3}^3  params {:>9}  bn {}  hybrid ways \
             {:?}  grids {:?}",
            name,
            m.kind,
            m.input_size,
            m.param_count(),
            if m.use_bn { "yes" } else { "no " },
            ways,
            grids,
        );
    }
    Ok(())
}
