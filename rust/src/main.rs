//! `hydra3d` — the leader CLI.
//!
//! Subcommands:
//!   table1 | table2 | fig --id N   regenerate the paper's tables/figures
//!   train                          functional training (fused or hybrid)
//!   verify                         static communication-schedule checks
//!   comm-smoke                     multi-process socket-backend smoke run
//!   worker                         one node of a multi-process launch
//!   info                           artifact/manifest summary
//!
//! Examples:
//!   hydra3d table1
//!   hydra3d fig --id 4
//!   hydra3d train --model cf16 --ways 2 --groups 2 --batch 4 --steps 20
//!   hydra3d train --model cf16 --grid 2x2x2 --batch 2 --steps 10
//!   hydra3d train --model unet16 --ways 2 --task ct
//!   hydra3d train --model cf16 --ways 4 --backend socket --ranks-per-node 2
//!   hydra3d comm-smoke --world 4 --ranks-per-node 2

use anyhow::{anyhow, bail, Context, Result};
use hydra3d::analysis::{self, EngineKind, ModelSpec, VerifyCfg};
use hydra3d::comm::launch::{self, LaunchSpec, Manifest};
use hydra3d::comm::{
    allreduce_sum_hier, socket, CommBackend, Communicator, GradReduce, SocketEndpoint,
    TraceCollector, DEFAULT_BUCKET_ELEMS,
};
use hydra3d::config::ClusterConfig;
use hydra3d::coordinator;
use hydra3d::data::container::{write_dataset, write_label_dataset, Container};
use hydra3d::data::ct::ct_dataset;
use hydra3d::data::grf::{GrfConfig, GrfDataset};
use hydra3d::engine::hybrid::{arm_test_die_at_step, train_hybrid_node,
                              train_hybrid_store, train_hybrid_with,
                              HybridOpts, InMemorySource, IoMode, SampleSource};
use hydra3d::engine::{LrSchedule, TrainReport};
use hydra3d::runtime::CheckpointCfg;
use hydra3d::iosim::pipeline::io_time_from_redist_trace;
use hydra3d::partition::SpatialGrid;
use hydra3d::perfmodel::trace::replay;
use hydra3d::perfmodel::{Link, SrModel};
use hydra3d::runtime::RuntimeHandle;
use hydra3d::tensor::Tensor;
use hydra3d::util::cli::{Args, Command};
use hydra3d::util::json::{obj, Json};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir() -> PathBuf {
    std::env::var("HYDRA3D_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first().map(String::as_str) else {
        println!("{}", usage());
        return Ok(());
    };
    let rest = &args[1..];
    let cluster = ClusterConfig::default();
    match cmd {
        "table1" => print!("{}", coordinator::table1()),
        "table2" => print!("{}", coordinator::table2(&cluster)),
        "fig" => {
            let c = Command::new("fig", "regenerate a paper figure")
                .opt("id", "figure number (4,5,6,7,8)", None)
                .opt("trace-dir", "directory for chrome traces (fig 6)", None);
            let a = c.parse(rest)?;
            let id = a.req("id")?.parse::<usize>()?;
            let out = match id {
                4 => coordinator::fig4(&cluster),
                5 => coordinator::fig5(&cluster),
                6 => coordinator::fig6(
                    &cluster,
                    a.get("trace-dir").map(std::path::Path::new),
                ),
                7 => coordinator::fig7(&cluster),
                8 => coordinator::fig8(&cluster),
                other => bail!("no figure {other} (the paper has 4-8 as \
                                performance figures; 9/10 are produced by \
                                examples/train_cosmoflow)"),
            };
            print!("{out}");
        }
        "train" => train_cmd(rest)?,
        "verify" => verify_cmd(rest)?,
        "worker" => worker_cmd(rest)?,
        "comm-smoke" => comm_smoke_cmd(rest)?,
        "info" => info_cmd()?,
        "--help" | "-h" | "help" => println!("{}", usage()),
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
    Ok(())
}

fn usage() -> String {
    "hydra3d — hybrid-parallel 3D CNN training (Oyama et al. 2020 reproduction)\n\
     \n\
     commands:\n\
       table1            Table I analytics (architecture, GFlops, memory)\n\
       table2            Table II achieved-vs-peak conv performance\n\
       fig --id <4..8>   regenerate a performance figure\n\
       train [...]       functional hybrid/fused training on synthetic data\n\
       verify [...]      static communication-schedule checks (deadlock, tag,\n\
                         byte matching); --matrix for the CI sweep,\n\
                         --mutations K for the seeded-defect harness\n\
       comm-smoke [...]  launch a real multi-process socket world and run\n\
                         flat-ring + hierarchical allreduces (no artifacts\n\
                         needed; prints deterministic wire-byte counters)\n\
       worker [...]      one node of a multi-process launch (internal; spawned\n\
                         by `train --backend socket` and `comm-smoke`)\n\
       info              artifact manifest summary\n"
        .into()
}

fn train_cmd(rest: &[String]) -> Result<()> {
    let c = Command::new("train", "functional training on synthetic data")
        .opt("model", "manifest model name", Some("cf16"))
        .opt("ways", "depth-only spatial partitioning (= --grid Wx1x1)", Some("1"))
        .opt("grid",
             "full 3D spatial process grid `dxhxw` (e.g. 2x2x2); overrides \
              --ways",
             None)
        .opt("groups", "data-parallel groups", Some("1"))
        .opt("batch", "global mini-batch", Some("2"))
        .opt("steps", "training steps", Some("20"))
        .opt("lr", "initial learning rate", Some("1e-3"))
        .opt("seed", "experiment seed", Some("7"))
        .opt("samples", "dataset size", Some("16"))
        .opt("task", "grf | ct", Some("grf"))
        .opt("io",
             "sample source: inmem | store | store-async (store modes write \
              the dataset to a scratch container — the \"PFS\" — and train \
              through the §III-B ingestion/redistribution pipeline)",
             Some("inmem"))
        .opt("comm",
             "communicator backend: channel | loopback | traced | socket \
              (traced is diagnostic: it records every message in memory; \
              socket is the in-process socket transport — see --backend for \
              the multi-process launcher)",
             Some("channel"))
        .opt("backend",
             "process backend: channel (ranks are threads of this process) | \
              socket (fork/exec one worker per simulated node and train over \
              Unix-domain sockets; in-memory I/O only)",
             Some("channel"))
        .opt("ranks-per-node",
             "simulated node size: ranks r share node r/N; N > 1 switches \
              the gradient allreduce to the hierarchical two-level schedule",
             Some("1"))
        .opt("report",
             "write a bit-exact run report (losses as f32 bit patterns plus \
              all byte counters) to this JSON path",
             None)
        .opt("bucket",
             "allreduce bucket size in f32 elems (0 = monolithic; default \
              comm::DEFAULT_BUCKET_ELEMS)",
             None)
        .opt("checkpoint-every",
             "snapshot model+optimizer+schedule state every N steps (and at \
              the final step) under --checkpoint-dir; 0 disables periodic \
              saves",
             Some("0"))
        .opt("checkpoint-dir",
             "checkpoint directory (required by --checkpoint-every, \
              --resume and --max-restarts)",
             None)
        .flag("resume",
              "resume from the newest valid committed snapshot in \
               --checkpoint-dir if one exists (start fresh otherwise); the \
               resumed trajectory is bit-identical to an uninterrupted run")
        .opt("max-restarts",
             "--backend socket only: relaunch a world that loses a worker \
              up to N times, resuming from the latest checkpoint",
             Some("0"));
    let a = c.parse(rest)?;
    let model = a.req("model")?.to_string();
    let rpn = a.get_usize("ranks-per-node")?.unwrap();
    if rpn == 0 {
        bail!("--ranks-per-node must be >= 1");
    }
    let reduce = grad_reduce_of(a.get_usize("bucket")?.unwrap_or(DEFAULT_BUCKET_ELEMS),
                                rpn)?;
    let ckpt = checkpoint_cfg_of(&a)?;
    if a.get_usize("max-restarts")?.unwrap() > 0 && a.req("backend")? != "socket" {
        bail!("--max-restarts recovers a multi-process world; it needs \
               --backend socket (the channel backend has no processes to \
               lose)");
    }
    match a.req("backend")? {
        "channel" => {}
        "socket" => return train_socket_cmd(&a, reduce, rpn),
        other => bail!("unknown --backend {other:?} (channel | socket)"),
    }
    let trace = Arc::new(TraceCollector::new());
    let backend = match a.req("comm")? {
        "channel" => CommBackend::Channel,
        "loopback" => CommBackend::Loopback,
        "traced" => CommBackend::Traced(trace.clone()),
        "socket" => CommBackend::Socket { ranks_per_node: rpn },
        other => bail!("unknown --comm backend {other:?}"),
    };
    let rt = RuntimeHandle::start(&artifacts_dir())?;
    let info = rt.manifest().model(&model)?.clone();
    let size = info.input_size;
    let n = a.get_usize("samples")?.unwrap();
    let seed = a.get_usize("seed")?.unwrap() as u64;

    let io = IoMode::parse(a.req("io")?)?;
    let is_ct = a.req("task")? == "ct";
    let (inputs, targets): (Vec<Tensor>, Vec<Tensor>) = if is_ct {
        let (inputs, labels) = ct_dataset(size, info.n_classes.max(2), n, seed);
        (inputs, labels)
    } else {
        let ds = GrfDataset::generate(&GrfConfig { size, seed }, n);
        (ds.inputs, ds.targets)
    };

    let grid = match a.get("grid") {
        Some(g) => SpatialGrid::parse(g)?,
        None => SpatialGrid::depth(a.get_usize("ways")?.unwrap()),
    };
    let steps = a.get_usize("steps")?.unwrap();
    let opts = HybridOpts {
        model,
        grid,
        groups: a.get_usize("groups")?.unwrap(),
        batch_global: a.get_usize("batch")?.unwrap(),
        steps,
        seed,
        schedule: LrSchedule {
            lr0: a.get_f64("lr")?.unwrap(),
            floor_frac: 0.01,
            total_steps: steps,
        },
        log_every: (steps / 10).max(1),
        ckpt,
    };
    let t0 = std::time::Instant::now();
    let rep = match io {
        IoMode::InMem => {
            let source = Arc::new(InMemorySource { inputs, targets });
            train_hybrid_with(&rt, &opts, source, &backend, reduce)?
        }
        IoMode::Store | IoMode::StoreAsync => {
            // stand-in PFS: a scratch container file holding the dataset
            let mut path = std::env::temp_dir();
            path.push(format!("hydra3d-train-io-{}", std::process::id()));
            if is_ct {
                // labels are the spatially partitioned ground truth
                write_label_dataset(&path, &inputs, &targets)?;
            } else {
                write_dataset(&path, &inputs, &targets, None)?;
            }
            let container = Arc::new(Container::open(&path)?);
            let rep =
                train_hybrid_store(&rt, &opts, container.clone(), io, &backend,
                                   reduce);
            std::fs::remove_file(&path).ok();
            let rep = rep?;
            // every container byte read over the whole run was epoch-0
            // ingestion: steps (epochs 1+ included) never touch the "PFS"
            let pfs_reads =
                container.bytes_read.load(std::sync::atomic::Ordering::Relaxed);
            println!(
                "io pipeline [{}]: ingest {:.0} KiB (epoch 0), redistribution \
                 {:.0} KiB staged, exposed {:.3}s / overlapped {:.3}s; \
                 container bytes beyond ingest: {}",
                io.name(),
                rep.ingest_bytes as f64 / 1024.0,
                rep.redist_bytes as f64 / 1024.0,
                rep.io_exposed,
                rep.io_overlapped,
                pfs_reads - rep.ingest_bytes,
            );
            rep
        }
    };
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "trained {} (grid {}) for {} steps: loss {:.6} -> {:.6} in {:.1}s \
         ({:.0} KiB comm, halo KiB D/H/W {:.0}/{:.0}/{:.0}, phases: fwd \
         {:.1}s bwd {:.1}s halo {:.2}s ar {:.2}s exposed / {:.2}s overlapped)",
        opts.model,
        opts.grid,
        steps,
        rep.records.first().map(|r| r.loss).unwrap_or(f32::NAN),
        rep.final_loss(),
        dt,
        rep.comm_bytes as f64 / 1024.0,
        rep.halo_bytes[0] as f64 / 1024.0,
        rep.halo_bytes[1] as f64 / 1024.0,
        rep.halo_bytes[2] as f64 / 1024.0,
        rep.phases.fwd_compute,
        rep.phases.bwd_compute,
        rep.phases.halo,
        rep.phases.allreduce,
        rep.phases.allreduce_overlapped,
    );
    if let Some(path) = a.get("report") {
        RunFingerprint::from_report(backend.name(),
                                    opts.groups * opts.grid.ways(), &rep)
            .write(Path::new(path))?;
    }
    if let CommBackend::Traced(tc) = &backend {
        let world = opts.groups * opts.grid.ways();
        let cluster = ClusterConfig::default();
        let link = SrModel::from_cluster(&cluster, Link::NvLink);
        let r = replay(tc, world, &link);
        println!(
            "comm trace: {} messages, {} bytes, {} logical collectives \
             (halo bytes D/H/W {}/{}/{}); §III-C replay: p2p critical \
             {:.2} ms, allreduce model {:.2} ms (NVLink link)",
            r.messages,
            r.bytes,
            r.collectives,
            r.halo_bytes_axis[0],
            r.halo_bytes_axis[1],
            r.halo_bytes_axis[2],
            r.p2p_critical_secs * 1e3,
            r.allreduce_model_secs * 1e3,
        );
        if r.redist_bytes > 0 {
            // calibrate the §III-B spatial-parallel I/O term against the
            // traced (measured) redistribution volume
            let per_rank_iter =
                r.redist_bytes as f64 / (world as f64 * steps as f64);
            println!(
                "  redistribution trace: {} B total; calibrated \
                 spatial-parallel I/O {:.3} ms/iter ({:.0} B/rank/iter over \
                 the IB link)",
                r.redist_bytes,
                io_time_from_redist_trace(per_rank_iter, &cluster) * 1e3,
                per_rank_iter,
            );
        }
    }
    Ok(())
}

/// Build the checkpoint config from `--checkpoint-every/--checkpoint-dir/
/// --resume` (shared by the channel and socket paths).
fn checkpoint_cfg_of(a: &Args) -> Result<Option<CheckpointCfg>> {
    let every = a.get_usize("checkpoint-every")?.unwrap();
    let resume = a.flag("resume");
    let restarts = a.get_usize("max-restarts")?.unwrap();
    match a.get("checkpoint-dir") {
        Some(dir) => Ok(Some(CheckpointCfg {
            dir: PathBuf::from(dir),
            every,
            resume,
        })),
        None if every > 0 || resume || restarts > 0 => {
            bail!("--checkpoint-every/--resume/--max-restarts need \
                   --checkpoint-dir")
        }
        None => Ok(None),
    }
}

/// Map `--bucket` / `--ranks-per-node` to the gradient-reduction strategy.
fn grad_reduce_of(bucket: usize, ranks_per_node: usize) -> Result<GradReduce> {
    Ok(match (bucket, ranks_per_node) {
        (0, 1) => GradReduce::Monolithic,
        (0, _) => bail!("--bucket 0 (monolithic) has no hierarchical \
                         variant; use a bucketed reduce with --ranks-per-node"),
        (elems, 1) => GradReduce::Bucketed { bucket_elems: elems },
        (elems, rpn) => GradReduce::Hier { bucket_elems: elems, ranks_per_node: rpn },
    })
}

/// Bit-exact run fingerprint: losses as f32 bit patterns plus every byte
/// counter. `tests/socket_backend.rs` diffs these across backends — all
/// fields except `backend` and `socket_frame_bytes` must match exactly
/// between a channel run and the equivalent socket run.
struct RunFingerprint {
    backend: &'static str,
    world: usize,
    losses_bits: Vec<u32>,
    comm_bytes: u64,
    halo_bytes: [u64; 3],
    ingest_bytes: u64,
    redist_bytes: u64,
    socket_frame_bytes: u64,
}

impl RunFingerprint {
    fn from_report(backend: &'static str, world: usize, rep: &TrainReport) -> Self {
        RunFingerprint {
            backend,
            world,
            losses_bits: rep.records.iter().map(|r| r.loss.to_bits()).collect(),
            comm_bytes: rep.comm_bytes,
            halo_bytes: rep.halo_bytes,
            ingest_bytes: rep.ingest_bytes,
            redist_bytes: rep.redist_bytes,
            socket_frame_bytes: rep.socket_frame_bytes,
        }
    }

    fn write(&self, path: &Path) -> Result<()> {
        let losses: Vec<Json> = self
            .losses_bits
            .iter()
            .map(|&b| Json::from(b as usize))
            .collect();
        let halo: Vec<Json> =
            self.halo_bytes.iter().map(|&b| Json::from(b as usize)).collect();
        let doc = obj(vec![
            ("schema", 1usize.into()),
            ("backend", self.backend.into()),
            ("world", self.world.into()),
            ("losses_bits", losses.into()),
            ("comm_bytes", (self.comm_bytes as usize).into()),
            ("halo_bytes", halo.into()),
            ("ingest_bytes", (self.ingest_bytes as usize).into()),
            ("redist_bytes", (self.redist_bytes as usize).into()),
            ("socket_frame_bytes", (self.socket_frame_bytes as usize).into()),
        ]);
        std::fs::write(path, doc.to_string())
            .with_context(|| format!("write report {}", path.display()))?;
        Ok(())
    }
}

/// `train --backend socket`: write a rendezvous manifest, fork/exec one
/// `hydra3d worker` per simulated node, and aggregate their node reports
/// (node 0 carries the loss trajectory; byte counters are summed — they
/// are send-side and therefore disjoint across nodes).
fn train_socket_cmd(a: &Args, reduce: GradReduce, rpn: usize) -> Result<()> {
    if a.req("io")? != "inmem" {
        bail!("--backend socket supports --io inmem only (every worker \
               regenerates the dataset from the seed; the store pipeline is \
               single-process)");
    }
    if a.req("comm")? != "channel" {
        bail!("--backend socket chooses its own transport; drop --comm");
    }
    let grid = match a.get("grid") {
        Some(g) => SpatialGrid::parse(g)?,
        None => SpatialGrid::depth(a.get_usize("ways")?.unwrap()),
    };
    let groups = a.get_usize("groups")?.unwrap();
    let steps = a.get_usize("steps")?.unwrap();
    let world = groups * grid.ways();
    let ckpt = checkpoint_cfg_of(a)?;
    let max_restarts = a.get_usize("max-restarts")?.unwrap();
    let task = obj(vec![
        ("cmd", "train".into()),
        ("model", a.req("model")?.into()),
        ("grid", grid.to_string().into()),
        ("groups", groups.into()),
        ("batch", a.get_usize("batch")?.unwrap().into()),
        ("steps", steps.into()),
        ("lr", a.get_f64("lr")?.unwrap().into()),
        ("seed", a.get_usize("seed")?.unwrap().into()),
        ("samples", a.get_usize("samples")?.unwrap().into()),
        ("dataset", a.req("task")?.into()),
        ("bucket",
         a.get_usize("bucket")?.unwrap_or(DEFAULT_BUCKET_ELEMS).into()),
        ("artifacts",
         artifacts_dir().to_string_lossy().into_owned().into()),
        // checkpoint config: empty dir = checkpointing off
        ("ckpt_dir",
         ckpt.as_ref()
             .map(|c| c.dir.to_string_lossy().into_owned())
             .unwrap_or_default()
             .into()),
        ("ckpt_every", ckpt.as_ref().map(|c| c.every).unwrap_or(0).into()),
        ("resume", ckpt.as_ref().map(|c| c.resume).unwrap_or(false).into()),
    ]);
    let spec = LaunchSpec { world, ranks_per_node: rpn, hosts: vec![], task };
    let scratch = match std::env::var("HYDRA3D_LAUNCH_SCRATCH") {
        Ok(d) if !d.is_empty() => PathBuf::from(d),
        _ => std::env::temp_dir()
            .join(format!("hydra3d-launch-{}", std::process::id())),
    };
    let t0 = std::time::Instant::now();
    let (results, restarts) = launch::launch_with_recovery(
        &std::env::current_exe()?, &spec, &scratch, max_restarts, with_resume)?;
    let dt = t0.elapsed().as_secs_f64();

    let mut fp = RunFingerprint {
        backend: "socket",
        world,
        losses_bits: Vec::new(),
        comm_bytes: 0,
        halo_bytes: [0; 3],
        ingest_bytes: 0,
        redist_bytes: 0,
        socket_frame_bytes: 0,
    };
    for r in &results {
        fp.comm_bytes += r.req("comm_bytes")?.as_usize()? as u64;
        let hb = r.req("halo_bytes")?.as_arr()?;
        for (axis, b) in hb.iter().enumerate().take(3) {
            fp.halo_bytes[axis] += b.as_usize()? as u64;
        }
        fp.socket_frame_bytes += r.req("socket_frame_bytes")?.as_usize()? as u64;
        let lb = r.req("losses_bits")?.as_arr()?;
        if !lb.is_empty() {
            fp.losses_bits = lb
                .iter()
                .map(|v| Ok(v.as_usize()? as u32))
                .collect::<Result<Vec<u32>>>()?;
        }
    }
    if fp.losses_bits.is_empty() {
        bail!("no worker reported a loss trajectory (rank 0 missing?)");
    }
    let first = f32::from_bits(fp.losses_bits[0]);
    let last = f32::from_bits(*fp.losses_bits.last().unwrap());
    println!("world restarts: {restarts}");
    println!(
        "trained {} (grid {}) for {} steps over {} worker processes \
         ({} node(s) x {} rank(s), {:?} reduce): loss {:.6} -> {:.6} in \
         {:.1}s ({:.0} KiB comm, {:.0} KiB inter-node frames, halo KiB \
         D/H/W {:.0}/{:.0}/{:.0})",
        a.req("model")?,
        grid,
        steps,
        results.len(),
        results.len(),
        rpn,
        reduce,
        first,
        last,
        dt,
        fp.comm_bytes as f64 / 1024.0,
        fp.socket_frame_bytes as f64 / 1024.0,
        fp.halo_bytes[0] as f64 / 1024.0,
        fp.halo_bytes[1] as f64 / 1024.0,
        fp.halo_bytes[2] as f64 / 1024.0,
    );
    if let Some(path) = a.get("report") {
        fp.write(Path::new(path))?;
    }
    if std::env::var("HYDRA3D_LAUNCH_SCRATCH").is_err() {
        // the override is CI's: it keeps the logs for artifact upload
        std::fs::remove_dir_all(&scratch).ok();
    }
    Ok(())
}

/// Rewrite a launch task document with `resume` forced on — applied by
/// [`launch::launch_with_recovery`] before every restarted attempt, so the
/// relaunched world picks up from the newest committed snapshot.
fn with_resume(task: &Json) -> Json {
    let Json::Obj(kv) = task else { return task.clone() };
    Json::Obj(
        kv.iter()
            .map(|(k, v)| {
                let v = if k == "resume" { Json::Bool(true) } else { v.clone() };
                (k.clone(), v)
            })
            .collect(),
    )
}

/// The gradient world's rendezvous: same topology as the compute world,
/// distinct socket label — and for TCP rendezvous each node's port
/// shifted by +1, so the two listeners never collide.
fn grad_rendezvous(rv: &socket::Rendezvous) -> Result<socket::Rendezvous> {
    let mut g = rv.clone();
    g.label = format!("{}-grad", rv.label);
    g.hosts = rv
        .hosts
        .iter()
        .map(|h| {
            let (host, port) = h
                .rsplit_once(':')
                .ok_or_else(|| anyhow!("bad host:port {h:?}"))?;
            let port: u32 = port.parse()?;
            Ok(format!("{host}:{}", port + 1))
        })
        .collect::<Result<Vec<String>>>()?;
    Ok(g)
}

/// `hydra3d worker --manifest M --node I` — one node of a multi-process
/// launch. Internal: spawned by [`launch::launch`]; reads the manifest,
/// runs the task, writes `results_dir/node-I.json`, exits 0.
fn worker_cmd(rest: &[String]) -> Result<()> {
    let c = Command::new("worker",
                         "one node of a --backend socket launch (internal)")
        .opt("manifest", "rendezvous manifest path", None)
        .opt("node", "this worker's node index", None);
    let a = c.parse(rest)?;
    let node: usize = a.req("node")?.parse()?;
    // fault-injection hooks: HYDRA3D_TEST_DIE_NODE alone kills the chosen
    // node before rendezvous (the launcher's fail-fast supervision — not a
    // hang — is what the kill-the-child test observes); combined with
    // HYDRA3D_TEST_DIE_AT_STEP it instead arms a mid-training abort at
    // that step, after the world is fully connected and has made progress
    if let Ok(v) = std::env::var("HYDRA3D_TEST_DIE_NODE") {
        if v.parse::<usize>().ok() == Some(node) {
            let at_step = std::env::var("HYDRA3D_TEST_DIE_AT_STEP")
                .ok()
                .and_then(|s| s.parse::<usize>().ok());
            match at_step {
                Some(step) => {
                    eprintln!("worker node {node}: armed to die at step {step} \
                               (HYDRA3D_TEST_DIE_AT_STEP)");
                    arm_test_die_at_step(step);
                }
                None => {
                    eprintln!("worker node {node}: HYDRA3D_TEST_DIE_NODE set, \
                               exiting");
                    std::process::exit(101);
                }
            }
        }
    }
    let m = launch::read_manifest(Path::new(a.req("manifest")?))?;
    let out = match m.task.req("cmd")?.as_str()? {
        "train" => worker_train(&m, node)?,
        "smoke" => worker_smoke(&m, node)?,
        other => bail!("unknown worker task {other:?}"),
    };
    let path = launch::result_path(&m.results_dir, node);
    std::fs::write(&path, out.to_string())
        .with_context(|| format!("write {}", path.display()))?;
    Ok(())
}

/// Worker half of `train --backend socket`: regenerate the dataset from
/// the seed, connect this node's ranks into the compute (and, unless
/// monolithic, gradient) socket worlds, and run
/// [`train_hybrid_node`] over them.
fn worker_train(m: &Manifest, node: usize) -> Result<Json> {
    let t = &m.task;
    let model = t.req("model")?.as_str()?.to_string();
    let grid = SpatialGrid::parse(t.req("grid")?.as_str()?)?;
    let steps = t.req("steps")?.as_usize()?;
    let seed = t.req("seed")?.as_usize()? as u64;
    let n = t.req("samples")?.as_usize()?;
    let reduce =
        grad_reduce_of(t.req("bucket")?.as_usize()?, m.rendezvous.ranks_per_node)?;
    let rt = RuntimeHandle::start(Path::new(t.req("artifacts")?.as_str()?))?;
    let info = rt.manifest().model(&model)?.clone();
    let size = info.input_size;
    let (inputs, targets) = if t.req("dataset")?.as_str()? == "ct" {
        ct_dataset(size, info.n_classes.max(2), n, seed)
    } else {
        let ds = GrfDataset::generate(&GrfConfig { size, seed }, n);
        (ds.inputs, ds.targets)
    };
    let source: Arc<dyn SampleSource> = Arc::new(InMemorySource { inputs, targets });
    let opts = HybridOpts {
        model,
        grid,
        groups: t.req("groups")?.as_usize()?,
        batch_global: t.req("batch")?.as_usize()?,
        steps,
        seed,
        schedule: LrSchedule {
            lr0: t.req("lr")?.as_f64()?,
            floor_frac: 0.01,
            total_steps: steps,
        },
        log_every: 0, // workers stay quiet; the launcher prints the summary
        ckpt: {
            let dir = t.req("ckpt_dir")?.as_str()?;
            (!dir.is_empty()).then(|| -> Result<CheckpointCfg> {
                Ok(CheckpointCfg {
                    dir: PathBuf::from(dir),
                    every: t.req("ckpt_every")?.as_usize()?,
                    resume: t.req("resume")?.as_bool()?,
                })
            })
            .transpose()?
        },
    };
    let eps: Vec<Box<dyn Communicator>> = socket::connect_node(&m.rendezvous, node)?
        .into_iter()
        .map(|e| Box::new(e) as Box<dyn Communicator>)
        .collect();
    let grad_eps: Vec<Option<Box<dyn Communicator>>> =
        if matches!(reduce, GradReduce::Monolithic) {
            eps.iter().map(|_| None).collect()
        } else {
            socket::connect_node(&grad_rendezvous(&m.rendezvous)?, node)?
                .into_iter()
                .map(|e| Some(Box::new(e) as Box<dyn Communicator>))
                .collect()
        };
    let nr = train_hybrid_node(&rt, &opts, source, reduce, eps, grad_eps)?;
    let losses: Vec<Json> = nr
        .report
        .as_ref()
        .map(|r| {
            r.records
                .iter()
                .map(|rec| Json::from(rec.loss.to_bits() as usize))
                .collect()
        })
        .unwrap_or_default();
    let halo: Vec<Json> =
        nr.halo_bytes.iter().map(|&b| Json::from(b as usize)).collect();
    Ok(obj(vec![
        ("node", node.into()),
        ("losses_bits", losses.into()),
        ("comm_bytes", (nr.comm_bytes as usize).into()),
        ("halo_bytes", halo.into()),
        ("socket_frame_bytes", (nr.socket_frame_bytes as usize).into()),
    ]))
}

/// Deterministic adversarial buffer for the smoke allreduces: mixed
/// signs and magnitudes so reduction-order drift cannot cancel out.
fn smoke_val(rank: usize, i: usize) -> f32 {
    let sign = if (rank + i) % 2 == 0 { 1.0f32 } else { -1.0 };
    sign * ((rank + 2) as f32).powi((i % 7) as i32 - 3)
}

/// Order-sensitive FNV-1a fold over the exact bit patterns.
fn bits_checksum(buf: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in buf {
        h ^= v.to_bits() as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Run one collective phase on every local rank (own thread each), assert
/// the ranks agree bitwise, and hand the endpoints back for the next
/// phase. Reading the node's counters between phases is exact: counting
/// is send-side and the local senders have all joined.
fn smoke_phase<F>(
    eps: Vec<SocketEndpoint>,
    elems: usize,
    f: F,
) -> Result<(Vec<SocketEndpoint>, u64)>
where
    F: Fn(&SocketEndpoint, &mut [f32]) -> Result<()> + Sync,
{
    let outs: Vec<Result<(SocketEndpoint, u64)>> = std::thread::scope(|s| {
        eps.into_iter()
            .map(|ep| {
                let f = &f;
                s.spawn(move || -> Result<(SocketEndpoint, u64)> {
                    let mut buf: Vec<f32> =
                        (0..elems).map(|i| smoke_val(ep.rank(), i)).collect();
                    f(&ep, &mut buf)?;
                    let cs = bits_checksum(&buf);
                    Ok((ep, cs))
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().expect("smoke rank panicked"))
            .collect()
    });
    let mut eps = Vec::with_capacity(outs.len());
    let mut checksum = None;
    for out in outs {
        let (ep, cs) = out?;
        match checksum {
            None => checksum = Some(cs),
            Some(c0) if c0 != cs => {
                bail!("smoke: local ranks disagree ({c0:016x} vs {cs:016x})")
            }
            Some(_) => {}
        }
        eps.push(ep);
    }
    Ok((eps, checksum.unwrap()))
}

/// Worker half of `comm-smoke`: flat ring allreduce, then the
/// hierarchical two-level allreduce, reporting bitwise result checksums
/// and this node's inter-node frame bytes per phase.
fn worker_smoke(m: &Manifest, node: usize) -> Result<Json> {
    let elems = m.task.req("elems")?.as_usize()?;
    let rpn = m.rendezvous.ranks_per_node;
    let world = m.rendezvous.world;
    let group: Vec<usize> = (0..world).collect();
    let eps = socket::connect_node(&m.rendezvous, node)?;
    let counters = eps[0].counters().clone();
    let (eps, ring_bits) =
        smoke_phase(eps, elems, |ep, buf| ep.allreduce_sum(buf, &group))?;
    let ring_frames = counters.socket_frame_bytes();
    let (eps, hier_bits) = smoke_phase(eps, elems, |ep, buf| {
        allreduce_sum_hier(ep, buf, &group, rpn)
    })?;
    let hier_frames = counters.socket_frame_bytes() - ring_frames;
    drop(eps);
    Ok(obj(vec![
        ("node", node.into()),
        ("ring_bits", format!("{ring_bits:016x}").into()),
        ("hier_bits", format!("{hier_bits:016x}").into()),
        ("ring_frame_bytes", (ring_frames as usize).into()),
        ("hier_frame_bytes", (hier_frames as usize).into()),
    ]))
}

/// `hydra3d comm-smoke` — launch a real multi-process socket world (no
/// artifacts needed) and run one flat-ring and one hierarchical allreduce
/// over it. Every node must land on bitwise-identical results; the summed
/// per-node frame counters are deterministic and printed for CI.
fn comm_smoke_cmd(rest: &[String]) -> Result<()> {
    let c = Command::new(
        "comm-smoke",
        "multi-process socket smoke: ring + hierarchical allreduce",
    )
    .opt("world", "total ranks", Some("4"))
    .opt("ranks-per-node", "ranks per simulated node", Some("2"))
    .opt("elems", "f32 elements per rank buffer", Some("1024"));
    let a = c.parse(rest)?;
    let world = a.get_usize("world")?.unwrap();
    let rpn = a.get_usize("ranks-per-node")?.unwrap();
    let elems = a.get_usize("elems")?.unwrap();
    if rpn == 0 {
        bail!("--ranks-per-node must be >= 1");
    }
    let task = obj(vec![("cmd", "smoke".into()), ("elems", elems.into())]);
    let spec = LaunchSpec { world, ranks_per_node: rpn, hosts: vec![], task };
    let scratch = match std::env::var("HYDRA3D_LAUNCH_SCRATCH") {
        Ok(d) if !d.is_empty() => PathBuf::from(d),
        _ => std::env::temp_dir()
            .join(format!("hydra3d-smoke-{}", std::process::id())),
    };
    let results = launch::launch(&std::env::current_exe()?, &spec, &scratch)?;
    let ring0 = results[0].req("ring_bits")?.as_str()?.to_string();
    let hier0 = results[0].req("hier_bits")?.as_str()?.to_string();
    let (mut ring_frames, mut hier_frames) = (0usize, 0usize);
    for r in &results {
        if r.req("ring_bits")?.as_str()? != ring0
            || r.req("hier_bits")?.as_str()? != hier0
        {
            bail!("comm-smoke: nodes disagree on allreduce results");
        }
        ring_frames += r.req("ring_frame_bytes")?.as_usize()?;
        hier_frames += r.req("hier_frame_bytes")?.as_usize()?;
    }
    if std::env::var("HYDRA3D_LAUNCH_SCRATCH").is_err() {
        std::fs::remove_dir_all(&scratch).ok();
    }
    println!(
        "comm-smoke ok: world {world} x rpn {rpn} ({} process(es)), {elems} \
         f32/rank; ring {ring0} hier {hier0}; \
         socket_ring_frame_bytes={ring_frames} \
         socket_hier_frame_bytes={hier_frames}",
        results.len(),
    );
    Ok(())
}

fn verify_cmd(rest: &[String]) -> Result<()> {
    let c = Command::new(
        "verify",
        "statically check a configuration's communication schedule",
    )
    .opt("model",
         "built-in spec (cf-sim | cf-sim-bn | unet-sim) or a manifest model \
          name when artifacts are present",
         Some("cf-sim"))
    .opt("grid", "spatial process grid `dxhxw`", Some("1x1x1"))
    .opt("groups", "data-parallel groups", Some("1"))
    .opt("batch", "global mini-batch (default: 2 per group)", None)
    .opt("steps", "steps to extract", Some("2"))
    .opt("samples", "dataset size for the store schedule (default: 4 per \
                     group)", None)
    .opt("seed", "schedule seed", Some("11"))
    .opt("io", "inmem | store | store-async", Some("inmem"))
    .opt("reduce", "bucketed | mono | hier (hier: two-level node-grouped \
                    allreduce, see --ranks-per-node)", Some("bucketed"))
    .opt("ranks-per-node", "node size for --reduce hier", Some("2"))
    .opt("engine", "hybrid | fused", Some("hybrid"))
    .flag("matrix", "check every CI matrix configuration instead of one")
    .opt("mutations",
         "run the seeded-mutation harness with this many rounds per defect \
          class and require every seeded defect to be caught",
         None);
    let a = c.parse(rest)?;

    if let Some(rounds) = a.get_usize("mutations")? {
        let seed = a.get_usize("seed")?.unwrap() as u64;
        let outcomes = analysis::run_mutation_suite(seed, rounds)?;
        let mut missed = 0usize;
        for o in &outcomes {
            if o.caught {
                let d = o.defect.as_ref().unwrap();
                println!("caught  {:<22} seed {:>3}: {d}", o.kind.name(), o.seed);
            } else {
                missed += 1;
                println!("MISSED  {:<22} seed {:>3}: {}", o.kind.name(), o.seed,
                         o.desc);
            }
        }
        println!(
            "mutation harness: {}/{} seeded defects caught across {} classes",
            outcomes.len() - missed,
            outcomes.len(),
            hydra3d::analysis::MutationKind::ALL.len(),
        );
        if missed > 0 {
            bail!("{missed} seeded schedule defect(s) escaped the checker");
        }
        return Ok(());
    }

    if a.flag("matrix") {
        let mut bad = 0usize;
        let mut total = 0usize;
        for (spec, cfg) in analysis::matrix() {
            total += 1;
            let sched = analysis::extract(&spec, &cfg)?;
            let defects = analysis::check_schedule(&sched);
            if defects.is_empty() {
                println!("ok   {:<10} {} ({} ops)", spec.name, cfg.describe(),
                         sched.total_ops());
            } else {
                bad += 1;
                println!("FAIL {:<10} {}", spec.name, cfg.describe());
                for d in &defects {
                    println!("     {d}");
                }
            }
        }
        println!("verify matrix: {}/{total} configurations clean", total - bad);
        if bad > 0 {
            bail!("{bad} configuration(s) have schedule defects");
        }
        return Ok(());
    }

    let name = a.req("model")?;
    let spec = match ModelSpec::builtin(name) {
        Ok(spec) => spec,
        // fall back to the AOT manifest so real production plans can be
        // checked when artifacts are present
        Err(builtin_err) => match RuntimeHandle::start(&artifacts_dir()) {
            Ok(rt) => ModelSpec::from_model_info(rt.manifest().model(name)?),
            Err(_) => return Err(builtin_err),
        },
    };
    let groups = a.get_usize("groups")?.unwrap();
    let cfg = VerifyCfg {
        grid: SpatialGrid::parse(a.req("grid")?)?,
        groups,
        batch_global: a.get_usize("batch")?.unwrap_or(2 * groups),
        steps: a.get_usize("steps")?.unwrap(),
        samples: a.get_usize("samples")?.unwrap_or(4 * groups),
        seed: a.get_usize("seed")?.unwrap() as u64,
        io: IoMode::parse(a.req("io")?)?,
        reduce: match a.req("reduce")? {
            "bucketed" => GradReduce::default(),
            "mono" => GradReduce::Monolithic,
            "hier" => GradReduce::Hier {
                bucket_elems: DEFAULT_BUCKET_ELEMS,
                ranks_per_node: a.get_usize("ranks-per-node")?.unwrap(),
            },
            other => bail!("unknown --reduce {other:?} (bucketed | mono | hier)"),
        },
        engine: match a.req("engine")? {
            "hybrid" => EngineKind::Hybrid,
            "fused" => EngineKind::Fused,
            other => bail!("unknown --engine {other:?} (hybrid | fused)"),
        },
    };
    let sched = analysis::extract(&spec, &cfg)?;
    let defects = analysis::check_schedule(&sched);
    for w in &sched.worlds {
        println!(
            "world {:<8} {} rank(s), {} ops",
            w.name,
            w.size,
            w.ranks.iter().map(Vec::len).sum::<usize>()
        );
    }
    if defects.is_empty() {
        println!("verify {}: {} — clean ({} ops)", spec.name, cfg.describe(),
                 sched.total_ops());
        Ok(())
    } else {
        for d in &defects {
            println!("{d}");
        }
        bail!("verify {}: {} defect(s) found", spec.name, defects.len());
    }
}

fn info_cmd() -> Result<()> {
    let rt = RuntimeHandle::start(&artifacts_dir())?;
    let man = rt.manifest();
    println!("artifacts: {} entries, {} models", man.entries.len(), man.models.len());
    let mut names: Vec<&String> = man.models.keys().collect();
    names.sort();
    for name in names {
        let m = &man.models[name];
        let mut ways: Vec<&usize> = m.hybrid.keys().collect();
        ways.sort();
        let mut grids: Vec<&String> = m.hybrid_grid.keys().collect();
        grids.sort();
        println!(
            "  {:<12} {:<10} input {:>3}^3  params {:>9}  bn {}  hybrid ways \
             {:?}  grids {:?}",
            name,
            m.kind,
            m.input_size,
            m.param_count(),
            if m.use_bn { "yes" } else { "no " },
            ways,
            grids,
        );
    }
    Ok(())
}
