//! Micro-benchmark harness (offline stand-in for `criterion`).
//!
//! Auto-calibrates iteration counts to a target measurement time, discards
//! warmup, and reports median / p10 / p90 over sample batches. Used by the
//! `cargo bench` targets in `rust/benches/` (`harness = false`).

use super::stats;
use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// seconds per iteration
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl Measurement {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.median
    }
}

/// Harness configuration.
pub struct Bench {
    pub warmup_secs: f64,
    pub measure_secs: f64,
    pub samples: usize,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_secs: 0.3, measure_secs: 1.0, samples: 11, results: Vec::new() }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup_secs: 0.05, measure_secs: 0.2, samples: 5, results: Vec::new() }
    }

    /// Run `f` repeatedly and record a measurement under `name`.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Measurement {
        // warmup + calibration
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed().as_secs_f64() < self.warmup_secs {
            f();
            calib_iters += 1;
        }
        let per_iter = t0.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
        let iters = ((self.measure_secs / self.samples as f64) / per_iter.max(1e-9))
            .ceil()
            .max(1.0) as u64;

        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            times.push(t.elapsed().as_secs_f64() / iters as f64);
        }
        let m = Measurement {
            name: name.to_string(),
            median: stats::median(&times),
            p10: stats::percentile(&times, 10.0),
            p90: stats::percentile(&times, 90.0),
            iters_per_sample: iters,
            samples: self.samples,
        };
        println!(
            "{:<48} {:>12}  (p10 {:>10}, p90 {:>10}, {} x {} iters)",
            m.name,
            super::human_time(m.median),
            super::human_time(m.p10),
            super::human_time(m.p90),
            m.samples,
            m.iters_per_sample,
        );
        self.results.push(m.clone());
        m
    }

    /// Time a single execution of `f` (for expensive end-to-end cases).
    pub fn run_once<F: FnOnce()>(&mut self, name: &str, f: F) -> Measurement {
        let t = Instant::now();
        f();
        let dt = t.elapsed().as_secs_f64();
        let m = Measurement {
            name: name.to_string(),
            median: dt,
            p10: dt,
            p90: dt,
            iters_per_sample: 1,
            samples: 1,
        };
        println!("{:<48} {:>12}  (single run)", m.name, super::human_time(dt));
        self.results.push(m.clone());
        m
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Standard header for a bench binary.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::quick();
        let mut acc = 0u64;
        let m = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1u64));
        });
        assert!(m.median > 0.0);
        assert!(m.p10 <= m.median && m.median <= m.p90 * 1.0001);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn run_once_records() {
        let mut b = Bench::quick();
        let m = b.run_once("sleepless", || {
            std::hint::black_box(17);
        });
        assert_eq!(m.samples, 1);
    }
}
