//! Summary statistics and regression — the numeric substrate of the paper's
//! §III-C performance model, which fits:
//!
//! * a **linear** model `t(bytes) = alpha + beta * bytes` to ping-pong
//!   send/recv benchmarks (Aluminum's SR model), and
//! * a **log-log linear** model over (message size, GPU count) to NCCL
//!   allreduce timings (Thakur et al. / Oyama et al. style).

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    match v.len() {
        0 => f64::NAN,
        n if n % 2 == 1 => v[n / 2],
        n => 0.5 * (v[n / 2 - 1] + v[n / 2]),
    }
}

/// Percentile in [0, 100] with linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if v.is_empty() {
        return f64::NAN;
    }
    let idx = (p / 100.0) * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    v[lo] + (v[hi] - v[lo]) * (idx - lo as f64)
}

/// Ordinary least squares for y = a + b*x. Returns (a, b, r2).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a + b * x);
            e * e
        })
        .sum();
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    let _ = n;
    (a, b, r2)
}

/// Multi-variate OLS y = w.x + c via normal equations (tiny systems only:
/// the allreduce model has 3 features). Returns (weights, intercept).
pub fn linreg_multi(xs: &[Vec<f64>], ys: &[f64]) -> (Vec<f64>, f64) {
    let n = xs.len();
    assert!(n > 0 && n == ys.len());
    let d = xs[0].len();
    // design matrix with bias column; solve (A^T A) w = A^T y by Gaussian
    // elimination with partial pivoting.
    let cols = d + 1;
    let mut ata = vec![vec![0.0; cols]; cols];
    let mut aty = vec![0.0; cols];
    for (x, &y) in xs.iter().zip(ys) {
        let mut row = x.clone();
        row.push(1.0);
        for i in 0..cols {
            aty[i] += row[i] * y;
            for j in 0..cols {
                ata[i][j] += row[i] * row[j];
            }
        }
    }
    let w = solve(&mut ata, &mut aty);
    let (weights, bias) = w.split_at(d);
    (weights.to_vec(), bias[0])
}

fn solve(a: &mut [Vec<f64>], b: &mut [f64]) -> Vec<f64> {
    let n = b.len();
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let p = a[col][col];
        if p.abs() < 1e-12 {
            continue; // singular direction; leave zero
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = a[r][col] / p;
            for c in col..n {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    (0..n)
        .map(|i| if a[i][i].abs() < 1e-12 { 0.0 } else { b[i] / a[i][i] })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_summaries() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((stddev(&xs) - 1.118).abs() < 1e-3);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 0.5 * x).collect();
        let (a, b, r2) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 0.5).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linreg_multi_recovers_plane() {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..10 {
            for j in 0..10 {
                let (x1, x2) = (i as f64, j as f64);
                xs.push(vec![x1, x2]);
                ys.push(2.0 * x1 - 1.5 * x2 + 7.0);
            }
        }
        let (w, c) = linreg_multi(&xs, &ys);
        assert!((w[0] - 2.0).abs() < 1e-8);
        assert!((w[1] + 1.5).abs() < 1e-8);
        assert!((c - 7.0).abs() < 1e-8);
    }
}
