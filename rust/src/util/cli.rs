//! Declarative command-line parsing (offline stand-in for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands. Auto-generates `--help` text from the declarations.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// One declared option.
#[derive(Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub takes_value: bool,
}

/// A parsed argument set.
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn req(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing --{name}"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        self.get(name)
            .map(|v| v.parse::<usize>().map_err(|e| anyhow!("--{name}: {e}")))
            .transpose()
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        self.get(name)
            .map(|v| v.parse::<f64>().map_err(|e| anyhow!("--{name}: {e}")))
            .transpose()
    }

    /// Parse a comma-separated usize list (e.g. `--ways 1,2,4`).
    pub fn get_usize_list(&self, name: &str) -> Result<Option<Vec<usize>>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse::<usize>().map_err(|e| anyhow!("--{name}: {e}")))
                .collect::<Result<Vec<_>>>()
                .map(Some),
        }
    }
}

/// A command with declared options.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str,
               default: Option<&'static str>) -> Self {
        self.opts.push(Opt { name, help, default, takes_value: true });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, takes_value: false });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let tail = if o.takes_value {
                format!(" <v>{}", o.default.map(|d| format!("  [default: {d}]"))
                        .unwrap_or_default())
            } else {
                String::new()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", o.name, tail, o.help));
        }
        s
    }

    /// Parse a raw arg list (not including argv[0] / the subcommand name).
    pub fn parse(&self, raw: &[String]) -> Result<Args> {
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        for o in &self.opts {
            if let Some(d) = o.default {
                values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| anyhow!("unknown option --{key}\n{}", self.usage()))?;
                if opt.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .ok_or_else(|| anyhow!("--{key} needs a value"))?
                                .clone()
                        }
                    };
                    values.insert(key.to_string(), v);
                } else {
                    if inline.is_some() {
                        bail!("--{key} takes no value");
                    }
                    flags.push(key.to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { values, flags, positional })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("train", "train a model")
            .opt("model", "model name", Some("cf16"))
            .opt("steps", "number of steps", Some("100"))
            .opt("ways", "partition ways", None)
            .flag("verbose", "chatty")
    }

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cmd().parse(&s(&["--steps", "5", "--verbose", "pos1"])).unwrap();
        assert_eq!(a.get("model"), Some("cf16"));
        assert_eq!(a.get_usize("steps").unwrap(), Some(5));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_form_and_lists() {
        let a = cmd().parse(&s(&["--ways=1,2,4"])).unwrap();
        assert_eq!(a.get_usize_list("ways").unwrap(), Some(vec![1, 2, 4]));
    }

    #[test]
    fn unknown_option_is_error() {
        assert!(cmd().parse(&s(&["--nope"])).is_err());
        assert!(cmd().parse(&s(&["--steps"])).is_err()); // missing value
    }
}
