//! Minimal JSON: value model, recursive-descent parser, writer.
//!
//! Stands in for `serde_json` (unavailable offline). Scope: everything the
//! AOT manifest, configs, and trace emitters need — objects, arrays,
//! numbers (f64), strings with escapes, bools, null. Object key order is
//! preserved (the manifest's param table is ordered).

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        Json::parse(&text)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Like [`get`] but an error mentioning the key if missing.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// `[1, 2, 3]` -> `vec![1usize, 2, 3]` (shapes in the manifest).
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- writer ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for objects: `obj([("a", 1.into()), ...])`.
pub fn obj(kv: Vec<(&str, Json)>) -> Json {
    Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Write a CI bench-artifact document — `{"schema": 1, "kind": ...,
/// "metrics": {...}}`, the one format `ci/bench_gate.py` merges and gates —
/// so every emitter (benches, examples) shares one schema definition.
pub fn write_bench_json(path: &str, kind: &str, metrics: &[(String, f64)])
                        -> std::io::Result<()> {
    let refs: Vec<(&str, Json)> =
        metrics.iter().map(|(k, v)| (k.as_str(), Json::Num(*v))).collect();
    let doc = obj(vec![
        ("schema", 1usize.into()),
        ("kind", kind.into()),
        ("metrics", obj(refs)),
    ]);
    std::fs::write(path, doc.to_string())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i,
                  self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-decode UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "s": "x\n\"y\""}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_bool().unwrap(), true);
    }

    #[test]
    fn shapes() {
        let v = Json::parse("[2, 4, 8]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![2, 4, 8]);
        let empty = Json::parse("[]").unwrap();
        assert_eq!(empty.as_shape().unwrap(), Vec::<usize>::new());
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode() {
        let v = Json::parse(r#""é café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é café ☕");
    }

    #[test]
    fn key_order_preserved() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }
}
