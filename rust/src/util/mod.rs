//! Small self-contained substrates.
//!
//! The build environment is fully offline with a fixed vendored crate set
//! (see `.cargo/config.toml`), so the usual ecosystem crates — `serde`,
//! `clap`, `rand`, `criterion`, `proptest`, `toml` — are unavailable. Each
//! submodule here is a purpose-built replacement scoped to exactly what
//! HYDRA-3D needs (DESIGN.md §8):
//!
//! * [`rng`] — PCG64 PRNG + normal/Bernoulli sampling + shuffles (`rand`)
//! * [`json`] — JSON value model, parser and writer (`serde_json`)
//! * [`toml`] — TOML-subset config parser (`toml`)
//! * [`cli`] — declarative flag/subcommand parser (`clap`)
//! * [`stats`] — summaries + (log-)linear regression for the §III-C model
//! * [`bench`] — micro-benchmark harness with warmup/median (`criterion`)
//! * [`prop`] — seeded property-test runner (`proptest`)
//! * [`fft`] — radix-2 complex FFT (1D/3D) for Gaussian random fields
//! * [`par`] — fork/join helpers for intra-rank loops (`rayon`)

pub mod bench;
pub mod cli;
pub mod fft;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod toml;

/// Format a byte count human-readably (GiB/MiB/KiB).
pub fn human_bytes(b: u64) -> String {
    const G: f64 = (1u64 << 30) as f64;
    const M: f64 = (1u64 << 20) as f64;
    const K: f64 = (1u64 << 10) as f64;
    let b = b as f64;
    if b >= G {
        format!("{:.2} GiB", b / G)
    } else if b >= M {
        format!("{:.2} MiB", b / M)
    } else if b >= K {
        format!("{:.2} KiB", b / K)
    } else {
        format!("{b} B")
    }
}

/// Format a duration in adaptive units.
pub fn human_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.1} us", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2 << 20), "2.00 MiB");
        assert_eq!(human_time(0.25), "250.000 ms");
        assert_eq!(human_time(2.0), "2.000 s");
    }
}
