//! TOML-subset parser for experiment configs (offline stand-in for `toml`).
//!
//! Supported: `[table]` and `[table.sub]` headers, `key = value` with
//! strings, integers, floats, booleans, and flat arrays, plus `#` comments.
//! Values land in a [`crate::util::json::Json`] object tree so the config
//! layer has a single value model for both formats.

use super::json::Json;
use anyhow::{anyhow, bail, Result};

/// Parse TOML text into a JSON object tree.
pub fn parse(text: &str) -> Result<Json> {
    let mut root: Vec<(String, Json)> = Vec::new();
    let mut path: Vec<String> = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let inner = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: unterminated table header", lineno + 1))?;
            path = inner.split('.').map(|s| s.trim().to_string()).collect();
            ensure_table(&mut root, &path)?;
        } else {
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = k.trim().trim_matches('"').to_string();
            let val = parse_value(v.trim())
                .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
            insert(&mut root, &path, key, val)?;
        }
    }
    Ok(Json::Obj(root))
}

pub fn parse_file(path: &std::path::Path) -> Result<Json> {
    parse(&std::fs::read_to_string(path)
        .map_err(|e| anyhow!("read {}: {e}", path.display()))?)
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside of quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Json> {
    if let Some(stripped) = s.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Json::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Json::Bool(true));
    }
    if s == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Json::Arr(vec![]));
        }
        return inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>>>()
            .map(Json::Arr);
    }
    s.replace('_', "")
        .parse::<f64>()
        .map(Json::Num)
        .map_err(|_| anyhow!("cannot parse value {s:?}"))
}

fn ensure_table(root: &mut Vec<(String, Json)>, path: &[String]) -> Result<()> {
    let mut cur = root;
    for seg in path {
        if !cur.iter().any(|(k, _)| k == seg) {
            cur.push((seg.clone(), Json::Obj(vec![])));
        }
        let entry = cur.iter_mut().find(|(k, _)| k == seg).unwrap();
        match &mut entry.1 {
            Json::Obj(o) => cur = o,
            _ => bail!("{seg} is not a table"),
        }
    }
    Ok(())
}

fn insert(root: &mut Vec<(String, Json)>, path: &[String], key: String,
          val: Json) -> Result<()> {
    ensure_table(root, path)?;
    let mut cur = root;
    for seg in path {
        let entry = cur.iter_mut().find(|(k, _)| k == seg).unwrap();
        match &mut entry.1 {
            Json::Obj(o) => cur = o,
            _ => unreachable!(),
        }
    }
    if cur.iter().any(|(k, _)| *k == key) {
        bail!("duplicate key {key}");
    }
    cur.push((key, val));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tables_and_types() {
        let doc = r#"
# experiment config
name = "fig4"     # inline comment
steps = 100
lr = 1.0e-3
verbose = true
ways = [1, 2, 4]

[cluster]
nodes = 512
gpus_per_node = 4

[cluster.links]
nvlink_gbps = 60.0
"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "fig4");
        assert_eq!(v.get("steps").unwrap().as_usize().unwrap(), 100);
        assert_eq!(v.get("lr").unwrap().as_f64().unwrap(), 1.0e-3);
        assert!(v.get("verbose").unwrap().as_bool().unwrap());
        assert_eq!(v.get("ways").unwrap().as_shape().unwrap(), vec![1, 2, 4]);
        let cl = v.get("cluster").unwrap();
        assert_eq!(cl.get("nodes").unwrap().as_usize().unwrap(), 512);
        assert_eq!(
            cl.get("links").unwrap().get("nvlink_gbps").unwrap().as_f64().unwrap(),
            60.0
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("x = @@").is_err());
        assert!(parse("a = 1\na = 2").is_err());
    }
}
