//! Seeded property-test runner (offline stand-in for `proptest`).
//!
//! A property is a closure from a [`Gen`] (a seeded random source with
//! convenience generators) to `Result<(), String>`. The runner executes N
//! cases with derived seeds and reports the first failing seed so a failure
//! is exactly reproducible with `check_seed`.

use super::rng::Pcg;

/// Random case generator handed to properties.
pub struct Gen {
    pub rng: Pcg,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    /// A power of two in [lo, hi] (both must be powers of two).
    pub fn pow2_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo.is_power_of_two() && hi.is_power_of_two() && lo <= hi);
        let lo_e = lo.trailing_zeros() as usize;
        let hi_e = hi.trailing_zeros() as usize;
        1 << self.usize_in(lo_e, hi_e)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        let mut v = vec![0.0; len];
        self.rng.fill_normal(&mut v, scale);
        v
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }
}

/// Run `cases` random cases of `prop`; panic with the failing seed if any
/// case fails.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    check_base_seed(name, 0x9E3779B97F4A7C15, cases, prop)
}

/// Re-run a single failing case by seed (printed on failure).
pub fn check_seed<F>(name: &str, seed: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let mut g = Gen { rng: Pcg::new(seed, 7), case: 0 };
    if let Err(msg) = prop(&mut g) {
        panic!("property {name} failed at seed {seed:#x}: {msg}");
    }
}

fn check_base_seed<F>(name: &str, base: u64, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0xD1B54A32D192ED03);
        let mut g = Gen { rng: Pcg::new(seed, 7), case };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {name} failed (case {case}, seed {seed:#x}):\n  {msg}\n\
                 reproduce with util::prop::check_seed(\"{name}\", {seed:#x}, ...)"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = std::cell::Cell::new(0);
        check("add-commutes", 50, |g| {
            let (a, b) = (g.f32_in(-10.0, 10.0), g.f32_in(-10.0, 10.0));
            count.set(count.get() + 1);
            if a + b == b + a {
                Ok(())
            } else {
                Err(format!("{a} + {b}"))
            }
        });
        assert_eq!(count.get_mut(), &50);
    }

    #[test]
    #[should_panic(expected = "property always-fails failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn pow2_bounds() {
        check("pow2-in-range", 100, |g| {
            let x = g.pow2_in(2, 64);
            if x.is_power_of_two() && (2..=64).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }
}
