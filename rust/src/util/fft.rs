//! Radix-2 complex FFT (1D iterative, plus a 3D transform over packed
//! volumes). Powers the Gaussian-random-field synthesizer in [`crate::data`]
//! that stands in for the CosmoFlow N-body dataset (DESIGN.md §4): a GRF is
//! synthesized in Fourier space with a parameter-dependent power spectrum
//! and inverse-transformed to a density cube.

use std::f64::consts::PI;

/// In-place iterative Cooley–Tukey FFT on interleaved (re, im) f64 pairs.
/// `inverse` applies the conjugate transform and 1/n scaling.
pub fn fft1d(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    assert_eq!(n, im.len());
    assert!(n.is_power_of_two(), "fft size must be a power of two");
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        for start in (0..n).step_by(len) {
            let (mut cr, mut ci) = (1.0, 0.0);
            for k in 0..len / 2 {
                let a = start + k;
                let b = start + k + len / 2;
                let (tr, ti) = (re[b] * cr - im[b] * ci, re[b] * ci + im[b] * cr);
                re[b] = re[a] - tr;
                im[b] = im[a] - ti;
                re[a] += tr;
                im[a] += ti;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
        }
        len <<= 1;
    }
    if inverse {
        let inv = 1.0 / n as f64;
        for i in 0..n {
            re[i] *= inv;
            im[i] *= inv;
        }
    }
}

/// In-place 3D FFT of an n^3 complex volume (row-major, d-h-w order).
pub fn fft3d(re: &mut [f64], im: &mut [f64], n: usize, inverse: bool) {
    assert_eq!(re.len(), n * n * n);
    let mut tr = vec![0.0; n];
    let mut ti = vec![0.0; n];
    // transform along w (contiguous rows)
    for row in 0..n * n {
        let s = row * n;
        fft1d(&mut re[s..s + n], &mut im[s..s + n], inverse);
    }
    // along h
    for d in 0..n {
        for w in 0..n {
            for h in 0..n {
                let idx = (d * n + h) * n + w;
                tr[h] = re[idx];
                ti[h] = im[idx];
            }
            fft1d(&mut tr, &mut ti, inverse);
            for h in 0..n {
                let idx = (d * n + h) * n + w;
                re[idx] = tr[h];
                im[idx] = ti[h];
            }
        }
    }
    // along d
    for h in 0..n {
        for w in 0..n {
            for d in 0..n {
                let idx = (d * n + h) * n + w;
                tr[d] = re[idx];
                ti[d] = im[idx];
            }
            fft1d(&mut tr, &mut ti, inverse);
            for d in 0..n {
                let idx = (d * n + h) * n + w;
                re[idx] = tr[d];
                im[idx] = ti[d];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn fft_roundtrip_1d() {
        let mut rng = Pcg::new(1, 1);
        let n = 64;
        let re0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let im0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let (mut re, mut im) = (re0.clone(), im0.clone());
        fft1d(&mut re, &mut im, false);
        fft1d(&mut re, &mut im, true);
        for i in 0..n {
            assert!((re[i] - re0[i]).abs() < 1e-9);
            assert!((im[i] - im0[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn fft_impulse_is_flat() {
        let n = 16;
        let mut re = vec![0.0; n];
        let mut im = vec![0.0; n];
        re[0] = 1.0;
        fft1d(&mut re, &mut im, false);
        for i in 0..n {
            assert!((re[i] - 1.0).abs() < 1e-12);
            assert!(im[i].abs() < 1e-12);
        }
    }

    #[test]
    fn fft_matches_dft_small() {
        let n = 8;
        let mut rng = Pcg::new(5, 2);
        let re0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let im0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let (mut re, mut im) = (re0.clone(), im0.clone());
        fft1d(&mut re, &mut im, false);
        for k in 0..n {
            let (mut sr, mut si) = (0.0, 0.0);
            for t in 0..n {
                let ang = -2.0 * PI * (k * t) as f64 / n as f64;
                sr += re0[t] * ang.cos() - im0[t] * ang.sin();
                si += re0[t] * ang.sin() + im0[t] * ang.cos();
            }
            assert!((re[k] - sr).abs() < 1e-9, "k={k}");
            assert!((im[k] - si).abs() < 1e-9, "k={k}");
        }
    }

    #[test]
    fn fft_roundtrip_3d() {
        let n = 8;
        let mut rng = Pcg::new(3, 3);
        let re0: Vec<f64> = (0..n * n * n).map(|_| rng.normal()).collect();
        let im0 = vec![0.0; n * n * n];
        let (mut re, mut im) = (re0.clone(), im0.clone());
        fft3d(&mut re, &mut im, n, false);
        fft3d(&mut re, &mut im, n, true);
        for i in 0..re.len() {
            assert!((re[i] - re0[i]).abs() < 1e-9);
            assert!(im[i].abs() < 1e-9);
        }
    }

    #[test]
    fn parseval_3d() {
        let n = 8;
        let mut rng = Pcg::new(9, 4);
        let re0: Vec<f64> = (0..n * n * n).map(|_| rng.normal()).collect();
        let (mut re, mut im) = (re0.clone(), vec![0.0; n * n * n]);
        fft3d(&mut re, &mut im, n, false);
        let e_t: f64 = re0.iter().map(|x| x * x).sum();
        let e_f: f64 = re.iter().zip(&im).map(|(r, i)| r * r + i * i).sum();
        assert!((e_f / (n * n * n) as f64 - e_t).abs() / e_t < 1e-9);
    }
}
