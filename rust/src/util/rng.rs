//! PCG64 (DXSM) pseudo-random generator + sampling helpers.
//!
//! Deterministic across platforms; every stochastic component of HYDRA-3D
//! (parameter init, dataset synthesis, dropout masks, shuffle schedules)
//! derives from one of these streams, so functional runs are exactly
//! reproducible given a seed — a prerequisite for the hybrid-vs-single-rank
//! equivalence tests.

/// PCG64-DXSM generator (O'Neill / NumPy's default bit generator family).
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg {
    /// Create a generator from a seed and a stream id. Distinct streams are
    /// statistically independent — used to give every rank / subsystem its
    /// own stream derived from the experiment seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Next 64 uniformly random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda942042e4dd58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fill a slice with N(0, sigma^2) f32 samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * sigma;
        }
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A deterministic sub-stream (e.g. per rank / per epoch).
    pub fn substream(&self, id: u64) -> Pcg {
        Pcg::new(self.state as u64 ^ (self.state >> 64) as u64, id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_independent() {
        let mut a = Pcg::new(42, 1);
        let mut b = Pcg::new(42, 1);
        let mut c = Pcg::new(42, 2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_moments() {
        let mut r = Pcg::new(7, 0);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(7, 0);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg::new(3, 9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::new(11, 0);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
