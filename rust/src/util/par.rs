//! Intra-rank data parallelism on `std::thread::scope`.
//!
//! The vendored crate set has no `rayon`, so this is a purpose-built
//! fork/join substrate for the per-element inner loops of the hot path
//! (halo pack/unpack, elementwise activations, gradient-bucket sums).
//!
//! Determinism contract
//! --------------------
//! Every helper here partitions the *output* into disjoint contiguous
//! ranges and runs the same scalar code on each range that the serial
//! loop would run. No reductions are reordered across ranges: helpers
//! either touch each element independently (`chunks_mut`, `zip_mut`,
//! `for_units_mut`) or concatenate per-range results in index order
//! (`map_indexed`). Results are therefore bit-identical for any thread
//! count, including 1 — cross-rank training stays deterministic no
//! matter what `HYDRA3D_THREADS` is set to.
//!
//! Small inputs (below [`PAR_CUTOFF`] elements) never spawn threads, so
//! shard sizes typical of a many-way spatial grid keep the serial fast
//! path and rank-per-thread harnesses (tests, `benches/micro.rs`) do
//! not oversubscribe the machine.

use std::sync::OnceLock;

/// Below this many elements all helpers run serially: thread spawn +
/// join costs more than the memory traffic it would hide.
pub const PAR_CUTOFF: usize = 1 << 20;

/// Worker-thread budget for one rank: `HYDRA3D_THREADS` if set, else
/// `available_parallelism`, clamped to [1, 8].
pub fn threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        let n = std::env::var("HYDRA3D_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
            });
        n.clamp(1, 8)
    })
}

/// Split `n` items into at most `threads()` contiguous ranges of
/// near-equal size. Returns the list of `(start, end)` bounds.
fn ranges(n: usize) -> Vec<(usize, usize)> {
    let t = threads().min(n).max(1);
    let base = n / t;
    let rem = n % t;
    let mut out = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let len = base + usize::from(i < rem);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Apply `f` to disjoint contiguous chunks covering `data`. Each element
/// is visited exactly once; `f` must treat elements independently.
pub fn chunks_mut<T: Send, F: Fn(&mut [T]) + Sync>(data: &mut [T], f: F) {
    if data.len() < PAR_CUTOFF || threads() == 1 {
        f(data);
        return;
    }
    let bounds = ranges(data.len());
    let mut rest: &mut [T] = data;
    std::thread::scope(|s| {
        for &(b0, b1) in &bounds {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(b1 - b0);
            rest = tail;
            let f = &f;
            s.spawn(move || f(head));
        }
    });
}

/// Apply `f` to aligned chunk pairs of `dst` and `src` (equal lengths).
/// The workhorse for elementwise `dst[i] op= src[i]` loops.
pub fn zip_mut<T: Send, U: Sync, F: Fn(&mut [T], &[U]) + Sync>(dst: &mut [T], src: &[U], f: F) {
    assert_eq!(dst.len(), src.len(), "par::zip_mut length mismatch");
    if dst.len() < PAR_CUTOFF || threads() == 1 {
        f(dst, src);
        return;
    }
    let bounds = ranges(dst.len());
    let mut rest: &mut [T] = dst;
    std::thread::scope(|s| {
        for &(b0, b1) in &bounds {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(b1 - b0);
            rest = tail;
            let sl = &src[b0..b1];
            let f = &f;
            s.spawn(move || f(head, sl));
        }
    });
}

/// Split `data` into `data.len() / unit` whole blocks of `unit` elements
/// and apply `f(unit_index, block)` to each, distributing whole units
/// over threads. Used for per-(sample, channel) loops where a unit must
/// stay on one thread to preserve its internal accumulation order.
pub fn for_units_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(data: &mut [T], unit: usize, f: F) {
    assert!(unit > 0 && data.len() % unit == 0, "par::for_units_mut bad unit");
    let n_units = data.len() / unit;
    if data.len() < PAR_CUTOFF || threads() == 1 {
        for (u, block) in data.chunks_mut(unit).enumerate() {
            f(u, block);
        }
        return;
    }
    let bounds = ranges(n_units);
    let mut rest: &mut [T] = data;
    std::thread::scope(|s| {
        for &(b0, b1) in &bounds {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut((b1 - b0) * unit);
            rest = tail;
            let f = &f;
            s.spawn(move || {
                for (i, block) in head.chunks_mut(unit).enumerate() {
                    f(b0 + i, block);
                }
            });
        }
    });
}

/// Compute `f(i)` for `i in 0..n` and return the results in index
/// order. Each contiguous index range runs on one thread; the final
/// vector is the in-order concatenation, so the output is identical to
/// the serial `(0..n).map(f).collect()`.
pub fn map_indexed<R: Send, F: Fn(usize) -> R + Sync>(n: usize, per_item: usize, f: F) -> Vec<R> {
    if n * per_item.max(1) < PAR_CUTOFF || threads() == 1 || n < 2 {
        return (0..n).map(f).collect();
    }
    let bounds = ranges(n);
    let mut parts: Vec<Vec<R>> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = bounds
            .iter()
            .map(|&(b0, b1)| {
                let f = &f;
                s.spawn(move || (b0..b1).map(f).collect::<Vec<R>>())
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("par worker panicked"));
        }
    });
    let mut out = Vec::with_capacity(n);
    for p in parts {
        out.extend(p);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_all_elements() {
        let mut v: Vec<f32> = (0..(PAR_CUTOFF + 17)).map(|i| i as f32).collect();
        chunks_mut(&mut v, |c| {
            for x in c.iter_mut() {
                *x += 1.0;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, (i + 1) as f32);
        }
    }

    #[test]
    fn zip_matches_serial() {
        let src: Vec<f32> = (0..(PAR_CUTOFF + 5)).map(|i| (i % 7) as f32).collect();
        let mut a = vec![1.0f32; src.len()];
        let mut b = a.clone();
        zip_mut(&mut a, &src, |d, s| {
            for (x, y) in d.iter_mut().zip(s) {
                *x *= *y + 0.5;
            }
        });
        for (x, y) in b.iter_mut().zip(&src) {
            *x *= *y + 0.5;
        }
        assert_eq!(a, b);
    }

    #[test]
    fn units_get_correct_indices() {
        let unit = 64;
        let n_units = PAR_CUTOFF / unit + 3;
        let mut v = vec![0usize; n_units * unit];
        for_units_mut(&mut v, unit, |u, block| {
            for x in block.iter_mut() {
                *x = u;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i / unit);
        }
    }

    #[test]
    fn map_indexed_is_ordered() {
        let n = PAR_CUTOFF / 128 + 11;
        let out = map_indexed(n, 256, |i| i * 3);
        assert_eq!(out.len(), n);
        for (i, x) in out.iter().enumerate() {
            assert_eq!(*x, i * 3);
        }
    }
}
