//! Integration: PJRT runtime service + AOT artifacts + fused engine.
//!
//! Requires `make artifacts` to have run (skips otherwise).

use hydra3d::engine::dataparallel::{eval_mse, predict_batch, train_fused, FullSource, FusedOpts};
use hydra3d::engine::{init_params, LrSchedule};
use hydra3d::runtime::RuntimeHandle;
use hydra3d::tensor::Tensor;
use hydra3d::util::rng::Pcg;
use std::path::PathBuf;
use std::sync::Arc;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

fn rand_tensor(rng: &mut Pcg, shape: &[usize], sigma: f32) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), sigma);
    t
}

#[test]
fn runtime_executes_shard_conv() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let man = rt.manifest();
    let m = man.model("cf-nano").unwrap();
    // first conv of the 1-way plan: input (1,1,8+2,8,8), w (4,1,3,3,3)
    let plan = &m.hybrid[&1];
    let (fwd_name, in_shapes) = match &plan[0] {
        hydra3d::runtime::LayerDesc::Conv { fwd, .. } => {
            let e = man.entry(fwd.as_ref().unwrap()).unwrap();
            (fwd.clone().unwrap(), e.inputs.clone())
        }
        _ => panic!("plan[0] should be conv"),
    };
    let mut rng = Pcg::new(7, 0);
    let x = rand_tensor(&mut rng, &in_shapes[0], 1.0);
    let w = rand_tensor(&mut rng, &in_shapes[1], 0.3);
    let out = rt.call(&fwd_name, vec![x.clone(), w.clone()]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape(), &[1, 4, 8, 8, 8]);
    // calling twice is deterministic
    let out2 = rt.call(&fwd_name, vec![x, w]).unwrap();
    assert_eq!(out[0].max_abs_diff(&out2[0]), 0.0);
    // stats recorded
    let st = rt.stats().unwrap();
    assert_eq!(st.per_entry[&fwd_name].0, 2);
    assert!(st.per_entry[&fwd_name].2 > 0.0, "compile time recorded");
}

#[test]
fn runtime_rejects_bad_shapes() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let man = rt.manifest();
    let m = man.model("cf-nano").unwrap();
    let name = m.fused.predict.clone();
    let err = rt.call(&name, vec![Tensor::zeros(&[1, 2, 3])]);
    assert!(err.is_err());
    assert!(rt.call("no-such-entry", vec![]).is_err());
}

#[test]
fn fused_train_step_decreases_loss() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let info = rt.manifest().model("cf-nano").unwrap().clone();

    // tiny synthetic regression task: target = f(mean density)
    let mut rng = Pcg::new(3, 1);
    let n = 8;
    let mut inputs = Vec::new();
    let mut targets = Vec::new();
    for _ in 0..n {
        let x = rand_tensor(&mut rng, &[1, 1, 8, 8, 8], 1.0);
        let m: f32 = x.data().iter().sum::<f32>() / x.numel() as f32;
        inputs.push(x);
        targets.push(Tensor::from_vec(&[1, 4], vec![m, -m, 2.0 * m, 0.5]));
    }
    let source = Arc::new(FullSource { inputs: inputs.clone(), targets: targets.clone() });
    let opts = FusedOpts {
        model: "cf-nano".into(),
        groups: 1,
        batch_global: 2,
        steps: 30,
        seed: 9,
        schedule: LrSchedule { lr0: 3e-3, floor_frac: 0.1, total_steps: 30 },
        log_every: 0,
        ckpt: None,
    };
    let rep = train_fused(&rt, &opts, source).unwrap();
    let first = rep.records[0].loss;
    let last = rep.final_loss();
    assert!(last < 0.5 * first, "loss did not train: {first} -> {last}");

    // predict path works with the trained params
    let x = hydra3d::engine::dataparallel::stack_batch(&[&inputs[0], &inputs[1]]);
    let pred = predict_batch(&rt, &info, &rep.params, &rep.running, x).unwrap();
    assert_eq!(pred.shape(), &[2, 4]);
    let mse = eval_mse(&rt, &info, &rep.params, &rep.running, &inputs, &targets).unwrap();
    assert!(mse.is_finite() && mse < first);
}

#[test]
fn fused_dataparallel_groups_match_single_rank() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let mut rng = Pcg::new(5, 2);
    let mut inputs = Vec::new();
    let mut targets = Vec::new();
    for _ in 0..8 {
        inputs.push(rand_tensor(&mut rng, &[1, 1, 8, 8, 8], 1.0));
        targets.push(rand_tensor(&mut rng, &[1, 4], 1.0));
    }
    let src = Arc::new(FullSource { inputs, targets });
    let mk = |groups: usize| FusedOpts {
        model: "cf-nano".into(),
        groups,
        batch_global: 4,
        steps: 4,
        seed: 11,
        schedule: LrSchedule { lr0: 1e-3, floor_frac: 1.0, total_steps: 0 },
        log_every: 0,
        ckpt: None,
    };
    let a = train_fused(&rt, &mk(1), src.clone()).unwrap();
    let b = train_fused(&rt, &mk(2), src).unwrap();
    for (pa, pb) in a.params.iter().zip(&b.params) {
        assert!(pa.max_abs_diff(pb) < 2e-6,
                "dataparallel divergence {}", pa.max_abs_diff(pb));
    }
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert!((ra.loss - rb.loss).abs() < 1e-5);
    }
}

#[test]
fn init_params_shapes_and_determinism() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let info = rt.manifest().model("cf16-bn").unwrap();
    let a = init_params(info, 42);
    let b = init_params(info, 42);
    let c = init_params(info, 43);
    for ((name, shape), (pa, pb)) in info.params.iter().zip(a.iter().zip(&b)) {
        assert_eq!(pa.shape(), &shape[..], "{name}");
        assert_eq!(pa.max_abs_diff(pb), 0.0, "{name}");
        if name.ends_with(".gamma") {
            assert!(pa.data().iter().all(|&x| x == 1.0));
        }
    }
    assert!(a[0].max_abs_diff(&c[0]) > 0.0, "seed must matter");
}
