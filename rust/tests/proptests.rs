//! Cross-module property tests on coordinator invariants (in-repo property
//! harness; see util::prop for the seeded-reproduction story).

use hydra3d::data::grf::{synthesize, GrfConfig, Universe};
use hydra3d::engine::sample_schedule;
use hydra3d::iosim::store::OwnerMap;
use hydra3d::partition::{DepthPartition, Grid4, Topology};
use hydra3d::tensor::Tensor;
use hydra3d::util::prop;

/// Halo-padded shards tile the padded global tensor: the algebraic core of
/// the forward halo exchange, for arbitrary shapes and ways.
#[test]
fn prop_shard_pad_tiles_global() {
    prop::check("shard-pad-tiles", 40, |g| {
        let ways = g.pow2_in(1, 8);
        let dsh = g.usize_in(1, 4);
        let d = ways * dsh;
        let (c, hw) = (g.usize_in(1, 3), g.usize_in(1, 4));
        let mut x = Tensor::zeros(&[1, c, d, hw, hw]);
        let data = g.vec_f32(x.numel(), 1.0);
        x.data_mut().copy_from_slice(&data);
        let halo = 1;
        let padded = x.pad_d(halo, halo);
        let part = DepthPartition::new_even(d, ways).map_err(|e| e.to_string())?;
        for pos in 0..ways {
            let want = padded.slice_d(part.shard_start(pos), part.shard_len() + 2 * halo);
            // reconstruct what exchange_forward produces locally:
            let shard = x.slice_d(part.shard_start(pos), part.shard_len());
            let mut local = shard.pad_d(halo, halo);
            if pos > 0 {
                local.set_slice_d(0, &x.slice_d(part.shard_start(pos) - halo, halo));
            }
            if pos + 1 < ways {
                local.set_slice_d(halo + part.shard_len(),
                                  &x.slice_d(part.shard_start(pos) + part.shard_len(), halo));
            }
            if local != want {
                return Err(format!("ways={ways} pos={pos} mismatch"));
            }
        }
        Ok(())
    });
}

/// The sample schedule is a sequence of full epochs: across any window of
/// ceil(n/b) consecutive steps' batches, sample counts differ by at most 1
/// per epoch boundary, and every index is < n.
#[test]
fn prop_schedule_is_epoch_fair() {
    prop::check("schedule-fair", 60, |g| {
        let n = g.usize_in(2, 40);
        let b = g.usize_in(1, 8);
        let steps = g.usize_in(1, 30);
        let sched = sample_schedule(g.rng.next_u64(), n, b, steps);
        let mut counts = vec![0usize; n];
        for batch in &sched {
            if batch.len() != b {
                return Err("batch size".into());
            }
            for &i in batch {
                if i >= n {
                    return Err(format!("index {i} >= {n}"));
                }
                counts[i] += 1;
            }
        }
        let (lo, hi) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        if hi - lo > 1 {
            return Err(format!("unfair: min {lo} max {hi}"));
        }
        Ok(())
    });
}

/// Owner map + topology: every (sample, position) pair is cached by exactly
/// one rank, and redistribution peers share the position.
#[test]
fn prop_owner_map_exactly_once() {
    prop::check("owner-exactly-once", 60, |g| {
        let groups = g.usize_in(1, 6);
        let ways = g.pow2_in(1, 8);
        let n = g.usize_in(1, 24);
        let topo = Topology::new(groups, ways);
        let om = OwnerMap { n_samples: n, groups };
        let mut seen = vec![0usize; n * ways];
        for r in 0..topo.world_size() {
            let (grp, pos) = topo.coords_of(r);
            for s in om.samples_of(grp) {
                seen[s * ways + pos] += 1;
            }
        }
        if seen.iter().all(|&c| c == 1) {
            Ok(())
        } else {
            Err("coverage violated".into())
        }
    });
}

/// Grid4 shard extents always cover the volume.
#[test]
fn prop_grid4_covers_volume() {
    prop::check("grid4-covers", 80, |g| {
        let grid = Grid4 {
            n: g.usize_in(1, 4),
            d: g.pow2_in(1, 16),
            h: g.pow2_in(1, 4),
            w: g.pow2_in(1, 4),
        };
        let vol = (g.pow2_in(16, 512), g.pow2_in(16, 512), g.pow2_in(16, 512));
        let (sd, sh, sw) = grid.shard_extent(vol);
        if sd * grid.d >= vol.0 && sh * grid.h >= vol.1 && sw * grid.w >= vol.2 {
            Ok(())
        } else {
            Err(format!("{grid:?} does not cover {vol:?}"))
        }
    });
}

/// GRF synthesis is parameter-sensitive: different parameters give
/// different fields; identical parameters give identical fields.
#[test]
fn prop_grf_parameter_sensitivity() {
    prop::check("grf-sensitivity", 8, |g| {
        let cfg = GrfConfig { size: 8, seed: 11 };
        let u1 = Universe {
            amp: g.f32_in(-1.0, 1.0),
            tilt: g.f32_in(-1.0, 1.0),
            large: g.f32_in(-1.0, 1.0),
            cut: g.f32_in(-1.0, 1.0),
        };
        let u2 = Universe { amp: u1.amp + 0.7_f32.copysign(-u1.amp), ..u1 };
        let a = synthesize(&cfg, 0, &u1);
        let b = synthesize(&cfg, 0, &u1);
        let c = synthesize(&cfg, 0, &u2);
        if a.max_abs_diff(&b) != 0.0 {
            return Err("nondeterministic".into());
        }
        if a.max_abs_diff(&c) < 1e-4 {
            return Err("amp change had no effect".into());
        }
        Ok(())
    });
}

/// Tensor slab algebra: concat_d(slices) == identity for arbitrary splits.
#[test]
fn prop_concat_slices_identity() {
    prop::check("concat-identity", 60, |g| {
        let parts = g.usize_in(1, 5);
        let per = g.usize_in(1, 3);
        let d = parts * per;
        let shape = [1, g.usize_in(1, 3), d, g.usize_in(1, 3), g.usize_in(1, 3)];
        let mut x = Tensor::zeros(&shape);
        let data = g.vec_f32(x.numel(), 2.0);
        x.data_mut().copy_from_slice(&data);
        let slabs: Vec<Tensor> = (0..parts).map(|p| x.slice_d(p * per, per)).collect();
        let refs: Vec<&Tensor> = slabs.iter().collect();
        if Tensor::concat_d(&refs) == x {
            Ok(())
        } else {
            Err("concat(slice) != id".into())
        }
    });
}
