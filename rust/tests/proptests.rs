//! Cross-module property tests on coordinator invariants (in-repo property
//! harness; see util::prop for the seeded-reproduction story).

use hydra3d::comm::{world, BucketPlan, Communicator, OverlapAllreduce};
use hydra3d::data::grf::{synthesize, GrfConfig, Universe};
use hydra3d::engine::sample_schedule;
use hydra3d::iosim::store::OwnerMap;
use hydra3d::partition::{axis_range, Grid4, SpatialGrid, Topology};
use hydra3d::tensor::Tensor;
use hydra3d::util::prop;
use std::thread;

/// Halo-padded shards tile the padded global tensor: the algebraic core of
/// the forward halo exchange, for arbitrary shapes and ways.
#[test]
fn prop_shard_pad_tiles_global() {
    prop::check("shard-pad-tiles", 40, |g| {
        let ways = g.pow2_in(1, 8);
        let dsh = g.usize_in(1, 4);
        let d = ways * dsh;
        let (c, hw) = (g.usize_in(1, 3), g.usize_in(1, 4));
        let mut x = Tensor::zeros(&[1, c, d, hw, hw]);
        let data = g.vec_f32(x.numel(), 1.0);
        x.data_mut().copy_from_slice(&data);
        let halo = 1;
        let padded = x.pad_ax(2, halo, halo);
        for pos in 0..ways {
            // even split: axis_range degenerates to pos * dsh for d = ways * dsh
            let (start, len) = axis_range(d, ways, pos);
            let want = padded.slice_ax(2, start, len + 2 * halo);
            // reconstruct what exchange_forward produces locally:
            let shard = x.slice_ax(2, start, len);
            let mut local = shard.pad_ax(2, halo, halo);
            if pos > 0 {
                local.set_slice_ax(2, 0, &x.slice_ax(2, start - halo, halo));
            }
            if pos + 1 < ways {
                local.set_slice_ax(2, halo + len, &x.slice_ax(2, start + len, halo));
            }
            if local != want {
                return Err(format!("ways={ways} pos={pos} mismatch"));
            }
        }
        Ok(())
    });
}

/// Ring allreduce, recursive doubling and the bucketed-overlap path all
/// produce results that are (a) bit-identical across every rank and
/// (b) equal to the element-wise sum within float reduction-order noise,
/// for arbitrary group sizes, buffer lengths and bucket boundaries.
#[test]
fn prop_collectives_bitwise_identical_across_ranks() {
    prop::check("collectives-identical", 10, |g| {
        let n = g.pow2_in(2, 8); // recursive doubling needs 2^k ranks
        let len = g.usize_in(1, 80);
        let vals: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(len, 1.0)).collect();
        let expect: Vec<f32> = (0..len)
            .map(|i| vals.iter().map(|v| v[i]).sum())
            .collect();
        let eps = world(n);
        let outs: Vec<(Vec<f32>, Vec<f32>)> = thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .zip(&vals)
                .map(|(ep, v)| {
                    let group: Vec<usize> = (0..n).collect();
                    let mut ring = v.clone();
                    let mut rd = v.clone();
                    s.spawn(move || {
                        ep.allreduce_sum(&mut ring, &group).unwrap();
                        ep.allreduce_sum_rd(&mut rd, &group).unwrap();
                        (ring, rd)
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in 1..n {
            if outs[r].0 != outs[0].0 {
                return Err(format!("ring rank {r} not bit-identical"));
            }
            if outs[r].1 != outs[0].1 {
                return Err(format!("rd rank {r} not bit-identical"));
            }
        }
        for (alg, got) in [("ring", &outs[0].0), ("rd", &outs[0].1)] {
            for i in 0..len {
                let tol = 1e-4 * expect[i].abs().max(1.0);
                if (got[i] - expect[i]).abs() > tol {
                    return Err(format!("{alg} elt {i}: {} != {}", got[i], expect[i]));
                }
            }
        }
        Ok(())
    });
}

/// The bucketed-overlap gradient path is a sum-allreduce: bit-identical
/// across ranks and equal to the direct sum, for arbitrary group sizes,
/// parameter shapes and bucket capacities.
#[test]
fn prop_bucketed_allreduce_identical_across_ranks() {
    prop::check("bucketed-identical", 8, |g| {
        let n = g.usize_in(2, 5);
        let n_params = g.usize_in(1, 6);
        let sizes: Vec<usize> = (0..n_params).map(|_| g.usize_in(1, 40)).collect();
        let cap = g.usize_in(1, 64);
        let plan = BucketPlan::new(&sizes, cap);
        let vals: Vec<Vec<Vec<f32>>> = (0..n)
            .map(|_| sizes.iter().map(|&sz| g.vec_f32(sz, 1.0)).collect())
            .collect();
        let eps = world(n);
        let outs: Vec<Vec<Vec<f32>>> = thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .zip(&vals)
                .map(|(ep, mine)| {
                    let plan = plan.clone();
                    let group: Vec<usize> = (0..n).collect();
                    s.spawn(move || {
                        let mut ov = OverlapAllreduce::start(Box::new(ep), group, plan);
                        let mut grads: Vec<Tensor> = mine
                            .iter()
                            .map(|v| Tensor::from_vec(&[v.len()], v.clone()))
                            .collect();
                        // mark in reverse order, like a backward walk
                        for pi in (0..grads.len()).rev() {
                            let data = grads[pi].data().to_vec();
                            ov.param_ready(pi, &data);
                        }
                        ov.finish(&mut grads).unwrap();
                        ov.shutdown().unwrap();
                        grads.into_iter().map(Tensor::into_vec).collect::<Vec<_>>()
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in 1..n {
            if outs[r] != outs[0] {
                return Err(format!("bucketed rank {r} not bit-identical"));
            }
        }
        for (pi, &sz) in sizes.iter().enumerate() {
            for i in 0..sz {
                let want: f32 = (0..n).map(|r| vals[r][pi][i]).sum();
                let got = outs[0][pi][i];
                if (got - want).abs() > 1e-4 * want.abs().max(1.0) {
                    return Err(format!("param {pi} elt {i}: {got} != {want}"));
                }
            }
        }
        Ok(())
    });
}

/// Bucket plans partition the parameter list exactly, whatever the sizes
/// and capacity.
#[test]
fn prop_bucket_plan_partitions_params() {
    prop::check("bucket-partition", 100, |g| {
        let n_params = g.usize_in(1, 20);
        let sizes: Vec<usize> = (0..n_params).map(|_| g.usize_in(1, 300)).collect();
        let cap = g.usize_in(1, 256);
        let plan = BucketPlan::new(&sizes, cap);
        let mut seen = vec![0usize; n_params];
        for (bi, b) in plan.buckets.iter().enumerate() {
            if b.params.is_empty() {
                return Err(format!("bucket {bi} empty"));
            }
            let total: usize = b.params.iter().map(|&pi| sizes[pi]).sum();
            if total != b.elems {
                return Err(format!("bucket {bi}: elems {} != sum {total}", b.elems));
            }
            if b.params.len() > 1 && b.elems > cap {
                return Err(format!("bucket {bi} over capacity with {} params",
                                   b.params.len()));
            }
            for (k, &pi) in b.params.iter().enumerate() {
                seen[pi] += 1;
                if plan.locate(pi) != (bi, b.offsets[k]) {
                    return Err(format!("param {pi} location mismatch"));
                }
            }
        }
        if seen.iter().all(|&c| c == 1) {
            Ok(())
        } else {
            Err("params not partitioned exactly once".into())
        }
    });
}

/// The sample schedule is a sequence of full epochs: across any window of
/// ceil(n/b) consecutive steps' batches, sample counts differ by at most 1
/// per epoch boundary, and every index is < n.
#[test]
fn prop_schedule_is_epoch_fair() {
    prop::check("schedule-fair", 60, |g| {
        let n = g.usize_in(2, 40);
        let b = g.usize_in(1, 8);
        let steps = g.usize_in(1, 30);
        let sched = sample_schedule(g.rng.next_u64(), n, b, steps);
        let mut counts = vec![0usize; n];
        for batch in &sched {
            if batch.len() != b {
                return Err("batch size".into());
            }
            for &i in batch {
                if i >= n {
                    return Err(format!("index {i} >= {n}"));
                }
                counts[i] += 1;
            }
        }
        let (lo, hi) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        if hi - lo > 1 {
            return Err(format!("unfair: min {lo} max {hi}"));
        }
        Ok(())
    });
}

/// Owner map + topology: every (sample, position) pair is cached by exactly
/// one rank, and redistribution peers share the position.
#[test]
fn prop_owner_map_exactly_once() {
    prop::check("owner-exactly-once", 60, |g| {
        let groups = g.usize_in(1, 6);
        let ways = g.pow2_in(1, 8);
        let n = g.usize_in(1, 24);
        let topo = Topology::new(groups, ways);
        let om = OwnerMap { n_samples: n, groups };
        let mut seen = vec![0usize; n * ways];
        for r in 0..topo.world_size() {
            let (grp, pos) = topo.coords_of(r);
            for s in om.samples_of(grp) {
                seen[s * ways + pos] += 1;
            }
        }
        if seen.iter().all(|&c| c == 1) {
            Ok(())
        } else {
            Err("coverage violated".into())
        }
    });
}

/// Grid4 shard extents always cover the volume.
#[test]
fn prop_grid4_covers_volume() {
    prop::check("grid4-covers", 80, |g| {
        let grid = Grid4 {
            n: g.usize_in(1, 4),
            d: g.pow2_in(1, 16),
            h: g.pow2_in(1, 4),
            w: g.pow2_in(1, 4),
        };
        let vol = (g.pow2_in(16, 512), g.pow2_in(16, 512), g.pow2_in(16, 512));
        let (sd, sh, sw) = grid.shard_extent(vol);
        if sd * grid.d >= vol.0 && sh * grid.h >= vol.1 && sw * grid.w >= vol.2 {
            Ok(())
        } else {
            Err(format!("{grid:?} does not cover {vol:?}"))
        }
    });
}

/// Remainder-split geometry (Grid4::shard_range / axis_range): shards
/// tile the volume exactly for arbitrary, non-power-of-two grids.
#[test]
fn prop_axis_range_exact_cover() {
    prop::check("axis-range-cover", 80, |g| {
        let ways = g.usize_in(1, 9);
        let extent = ways * g.usize_in(1, 40) + g.usize_in(0, ways - 1);
        let mut covered = vec![0u8; extent];
        for pos in 0..ways {
            let (s, len) = axis_range(extent, ways, pos);
            if len == 0 {
                return Err(format!("{extent}/{ways}: empty shard {pos}"));
            }
            for c in covered.iter_mut().skip(s).take(len) {
                *c += 1;
            }
        }
        if covered.iter().all(|&c| c == 1) {
            Ok(())
        } else {
            Err(format!("{extent}/{ways}: not an exact cover"))
        }
    });
}

/// 3D-grid hyperslabs (even splits) tile the halo-padded global volume —
/// the per-rank view the grid engine feeds its valid convolutions, for
/// arbitrary grids, channels and halo widths. This is the local algebra
/// behind `comm::halo::exchange_forward_grid` (the distributed version is
/// asserted bit-exact in `comm::halo`'s tests).
#[test]
fn prop_grid_shard_pad_tiles_global() {
    prop::check("grid-shard-pad-tiles", 30, |g| {
        let grid = SpatialGrid::new(g.usize_in(1, 3), g.usize_in(1, 3),
                                    g.usize_in(1, 3));
        let halo = g.usize_in(0, 1);
        let sh = [
            g.usize_in(1, 3).max(halo),
            g.usize_in(1, 3).max(halo),
            g.usize_in(1, 3).max(halo),
        ];
        let dims = [grid.d * sh[0], grid.h * sh[1], grid.w * sh[2]];
        let c = g.usize_in(1, 2);
        let mut x = Tensor::zeros(&[1, c, dims[0], dims[1], dims[2]]);
        let data = g.vec_f32(x.numel(), 1.0);
        x.data_mut().copy_from_slice(&data);
        let padded = x.pad_ax(2, halo, halo).pad_ax(3, halo, halo)
            .pad_ax(4, halo, halo);
        for pos in 0..grid.ways() {
            let cc = grid.coords(pos);
            let off = [cc[0] * sh[0], cc[1] * sh[1], cc[2] * sh[2]];
            // in padded coordinates the same offset points at the shard's
            // halo-extended block
            let want = padded.block3(off, [sh[0] + 2 * halo, sh[1] + 2 * halo,
                                           sh[2] + 2 * halo]);
            let shard = x.block3(off, sh);
            if want.block3([halo, halo, halo], sh) != shard {
                return Err(format!("grid {grid} pos {pos}: interior mismatch"));
            }
        }
        Ok(())
    });
}

/// GRF synthesis is parameter-sensitive: different parameters give
/// different fields; identical parameters give identical fields.
#[test]
fn prop_grf_parameter_sensitivity() {
    prop::check("grf-sensitivity", 8, |g| {
        let cfg = GrfConfig { size: 8, seed: 11 };
        let u1 = Universe {
            amp: g.f32_in(-1.0, 1.0),
            tilt: g.f32_in(-1.0, 1.0),
            large: g.f32_in(-1.0, 1.0),
            cut: g.f32_in(-1.0, 1.0),
        };
        let u2 = Universe { amp: u1.amp + 0.7_f32.copysign(-u1.amp), ..u1 };
        let a = synthesize(&cfg, 0, &u1);
        let b = synthesize(&cfg, 0, &u1);
        let c = synthesize(&cfg, 0, &u2);
        if a.max_abs_diff(&b) != 0.0 {
            return Err("nondeterministic".into());
        }
        if a.max_abs_diff(&c) < 1e-4 {
            return Err("amp change had no effect".into());
        }
        Ok(())
    });
}

/// Tensor slab algebra: concat_ax(slices) == identity for arbitrary splits
/// along every spatial axis.
#[test]
fn prop_concat_slices_identity() {
    prop::check("concat-identity", 60, |g| {
        let parts = g.usize_in(1, 5);
        let per = g.usize_in(1, 3);
        let axis = 2 + g.usize_in(0, 2);
        let n = parts * per;
        let mut shape = [1, g.usize_in(1, 3), g.usize_in(1, 3), g.usize_in(1, 3),
                         g.usize_in(1, 3)];
        shape[axis] = n;
        let mut x = Tensor::zeros(&shape);
        let data = g.vec_f32(x.numel(), 2.0);
        x.data_mut().copy_from_slice(&data);
        let slabs: Vec<Tensor> =
            (0..parts).map(|p| x.slice_ax(axis, p * per, per)).collect();
        let refs: Vec<&Tensor> = slabs.iter().collect();
        if Tensor::concat_ax(axis, &refs) == x {
            Ok(())
        } else {
            Err(format!("concat(slice) != id along axis {axis}"))
        }
    });
}
